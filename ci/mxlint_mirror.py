#!/usr/bin/env python3
"""Toolchain-free mirror of the `mxlint` invariant checker.

This is a line-for-line Python port of `rust/src/lint/{lex,rules,mod}.rs`
so the committed `rust/lint.manifest` can be regenerated — and the tree
linted — on machines without a Rust toolchain. The Rust side is the
source of truth; when the lexer or a rule changes there, change it here
in the same commit. `rust/tests/lint.rs` cross-checks the two
implementations by pinning rule behavior on shared fixture snippets.

Usage:
    python3 ci/mxlint_mirror.py [--root PATH] [--json] [--update-manifest]

Exit codes match the Rust binary: 0 clean, 1 findings, 2 error.
"""

import json
import os
import sys

# --------------------------------------------------------------- lexer
# Port of rust/src/lint/lex.rs. Tokens are (kind, text, line) tuples;
# kinds are the strings below. Operates on bytes, like the Rust side.

IDENT, INT, FLOAT, STR, CHAR, LIFETIME, PUNCT = (
    "Ident", "Int", "Float", "Str", "Char", "Lifetime", "Punct",
)

INT_SUFFIXES = [
    "usize", "isize", "u128", "i128", "u64", "i64", "u32", "i32",
    "u16", "i16", "u8", "i8",
]


def _is_ident_start(b):
    return (0x41 <= b <= 0x5A) or (0x61 <= b <= 0x7A) or b == 0x5F or b >= 0x80


def _is_ident_cont(b):
    return _is_ident_start(b) or (0x30 <= b <= 0x39)


def _is_digit(b):
    return 0x30 <= b <= 0x39


def _is_alnum(b):
    return _is_digit(b) or (0x41 <= b <= 0x5A) or (0x61 <= b <= 0x7A)


def _starts_with_radix(text):
    return len(text) >= 2 and text[0:1] == b"0" and text[1:2] in (
        b"x", b"X", b"b", b"B", b"o", b"O",
    )


def classify_number(text):
    b = text.encode("utf-8", "replace")
    if _starts_with_radix(b):
        return INT
    if "." in text:
        return FLOAT
    for suf in INT_SUFFIXES:
        if text.endswith(suf):
            core = text[: -len(suf)]
            if core and all(c.isdigit() or c == "_" for c in core):
                return INT
    if text.endswith("f32") or text.endswith("f64"):
        return FLOAT
    if "e" in text or "E" in text:
        return FLOAT
    return INT


def _contains_safety(bs):
    return b"SAFETY:" in bs


def _scan_string(b, i):
    n = len(b)
    nl = 0
    while i < n:
        c = b[i]
        if c == 0x5C:  # backslash
            i += 2
        elif c == 0x22:  # quote
            return i + 1, nl
        elif c == 0x0A:
            nl += 1
            i += 1
        else:
            i += 1
    return n, nl


def _scan_raw_string(b, i):
    n = len(b)
    hashes = 0
    while i < n and b[i] == 0x23:  # '#'
        hashes += 1
        i += 1
    if i >= n or b[i] != 0x22:
        return None
    i += 1
    nl = 0
    while i < n:
        if b[i] == 0x0A:
            nl += 1
            i += 1
            continue
        if b[i] == 0x22:
            j = i + 1
            h = 0
            while j < n and h < hashes and b[j] == 0x23:
                h += 1
                j += 1
            if h == hashes:
                return j, nl
        i += 1
    return n, nl


def _scan_char_or_lifetime(b, i):
    n = len(b)
    if i >= n:
        return n, CHAR
    if b[i] == 0x5C:  # backslash escape
        j = i + 1
        if j < n:
            esc = b[j]
            j += 1
            if esc == 0x75 and j < n and b[j] == 0x7B:  # u{
                while j < n and b[j] != 0x7D:
                    j += 1
                j += 1
        if j < n and b[j] == 0x27:
            j += 1
        return j, CHAR
    if _is_ident_start(b[i]):
        j = i
        while j < n and _is_ident_cont(b[j]):
            j += 1
        if j < n and b[j] == 0x27:
            return j + 1, CHAR
        return j, LIFETIME
    j = i + 1
    while j < n and b[j] != 0x27 and b[j] != 0x0A:
        j += 1
    if j < n and b[j] == 0x27:
        j += 1
    return j, CHAR


def lex(src):
    """Lex bytes -> (toks, safety_lines). toks are (kind, text, line)."""
    b = src
    n = len(b)
    i = 0
    line = 1
    toks = []
    safety_lines = []

    def push(kind, bs, ln):
        toks.append((kind, bs.decode("utf-8", "replace"), ln))

    while i < n:
        c = b[i]
        if c == 0x0A:  # newline
            line += 1
            i += 1
            continue
        if c in (0x09, 0x0C, 0x0D, 0x20):  # Rust u8::is_ascii_whitespace (no VT)
            i += 1
            continue
        if c == 0x2F and i + 1 < n and b[i + 1] == 0x2F:  # //
            start = i
            while i < n and b[i] != 0x0A:
                i += 1
            if _contains_safety(b[start:i]):
                safety_lines.append(line)
            continue
        if c == 0x2F and i + 1 < n and b[i + 1] == 0x2A:  # /*
            start = i
            start_line = line
            depth = 1
            i += 2
            while i < n and depth > 0:
                if b[i] == 0x0A:
                    line += 1
                    i += 1
                elif b[i] == 0x2F and i + 1 < n and b[i + 1] == 0x2A:
                    depth += 1
                    i += 2
                elif b[i] == 0x2A and i + 1 < n and b[i + 1] == 0x2F:
                    depth -= 1
                    i += 2
                else:
                    i += 1
            if _contains_safety(b[start:i]):
                safety_lines.append(start_line)
            continue
        if c == 0x72 and i + 1 < n and b[i + 1] in (0x22, 0x23):  # r" r#
            r = _scan_raw_string(b, i + 1)
            if r is not None:
                end, nl = r
                push(STR, b[i:end], line)
                line += nl
                i = end
                continue
        if c == 0x62 and i + 1 < n:  # b" b' br
            if b[i + 1] == 0x22:
                end, nl = _scan_string(b, i + 2)
                push(STR, b[i:end], line)
                line += nl
                i = end
                continue
            if b[i + 1] == 0x27:
                end, kind = _scan_char_or_lifetime(b, i + 2)
                push(kind, b[i:end], line)
                i = end
                continue
            if b[i + 1] == 0x72 and i + 2 < n and b[i + 2] in (0x22, 0x23):
                r = _scan_raw_string(b, i + 2)
                if r is not None:
                    end, nl = r
                    push(STR, b[i:end], line)
                    line += nl
                    i = end
                    continue
        if c == 0x22:  # "
            end, nl = _scan_string(b, i + 1)
            push(STR, b[i:end], line)
            line += nl
            i = end
            continue
        if c == 0x27:  # '
            end, kind = _scan_char_or_lifetime(b, i + 1)
            push(kind, b[i:end], line)
            i = end
            continue
        if _is_ident_start(c):
            start = i
            while i < n and _is_ident_cont(b[i]):
                i += 1
            push(IDENT, b[start:i], line)
            continue
        if _is_digit(c):
            start = i
            has_dot = False
            i += 1
            while i < n:
                d = b[i]
                if _is_alnum(d) or d == 0x5F:
                    i += 1
                    continue
                if d == 0x2E and not has_dot and i + 1 < n and _is_digit(b[i + 1]):
                    has_dot = True
                    i += 1
                    continue
                if (
                    d in (0x2B, 0x2D)
                    and b[i - 1] in (0x65, 0x45)
                    and not _starts_with_radix(b[start:i])
                    and i + 1 < n
                    and _is_digit(b[i + 1])
                ):
                    i += 1
                    continue
                break
            text = b[start:i]
            push(classify_number(text.decode("utf-8", "replace")), text, line)
            continue
        push(PUNCT, b[i : i + 1], line)
        i += 1
    return toks, safety_lines


def token_hash(toks):
    """FNV-1a 64 over token texts with \\n separators (lex.rs token_hash)."""
    h = 0xCBF29CE484222325
    prime = 0x100000001B3
    mask = 0xFFFFFFFFFFFFFFFF
    for _, text, _ in toks:
        for byte in text.encode("utf-8", "replace"):
            h = ((h ^ byte) * prime) & mask
        h = ((h ^ 0x0A) * prime) & mask
    return h


# --------------------------------------------------------------- rules
# Port of rust/src/lint/rules.rs. SourceFile = (rel, toks, safety_lines);
# Finding = dict(rule=, file=, line=, message=).


def _is_p(t, s):
    return t[0] == PUNCT and t[1] == s


def _is_i(t, s):
    return t[0] == IDENT and t[1] == s


def allowed(allow, rule, key):
    return any(k == key for k, _ in allow.get(rule, []))


def under_src(rel):
    return rel[len("rust/src/"):] if rel.startswith("rust/src/") else None


def brace_match(toks, open_idx):
    depth = 0
    i = open_idx
    while i < len(toks):
        if _is_p(toks[i], "{"):
            depth += 1
        elif _is_p(toks[i], "}"):
            depth -= 1
            if depth == 0:
                return i
        i += 1
    return len(toks)


def functions(toks):
    """-> list of dict(name, is_pub, line, kw, body=(open, close)|None)."""
    out = []
    i = 0
    while i + 1 < len(toks):
        if _is_i(toks[i], "fn") and toks[i + 1][0] == IDENT:
            name = toks[i + 1][1]
            is_pub = False
            for j in range(i - 1, max(i - 6, 0) - 1, -1):
                if _is_p(toks[j], ";") or _is_p(toks[j], "}") or _is_p(toks[j], "{"):
                    break
                if _is_i(toks[j], "pub"):
                    is_pub = True
                    break
            depth = 0
            j = i + 2
            body = None
            while j < len(toks):
                t = toks[j]
                if t[0] == PUNCT:
                    if t[1] in ("(", "["):
                        depth += 1
                    elif t[1] in (")", "]"):
                        depth -= 1
                    elif t[1] == "{" and depth == 0:
                        body = (j, brace_match(toks, j))
                        break
                    elif t[1] == ";" and depth == 0:
                        break
                j += 1
            out.append(
                {"name": name, "is_pub": is_pub, "line": toks[i + 1][2], "kw": i, "body": body}
            )
            i += 2
        else:
            i += 1
    return out


def test_regions(toks):
    out = []
    i = 0
    while i < len(toks):
        cfg_test = (
            i + 6 < len(toks)
            and _is_p(toks[i], "#")
            and _is_p(toks[i + 1], "[")
            and _is_i(toks[i + 2], "cfg")
            and _is_p(toks[i + 3], "(")
            and _is_i(toks[i + 4], "test")
            and _is_p(toks[i + 5], ")")
            and _is_p(toks[i + 6], "]")
        )
        test_attr = (
            i + 3 < len(toks)
            and _is_p(toks[i], "#")
            and _is_p(toks[i + 1], "[")
            and _is_i(toks[i + 2], "test")
            and _is_p(toks[i + 3], "]")
        )
        if cfg_test or test_attr:
            after = i + 7 if cfg_test else i + 4
            for j in range(after, min(after + 40, len(toks))):
                if _is_p(toks[j], ";"):
                    break
                if _is_p(toks[j], "{"):
                    out.append((i, brace_match(toks, j)))
                    break
        i += 1
    return out


def const_regions(toks):
    out = []
    i = 0
    while i < len(toks):
        if (_is_i(toks[i], "const") or _is_i(toks[i], "static")) and not (
            i + 1 < len(toks) and _is_i(toks[i + 1], "fn")
        ):
            if i + 1 < len(toks) and _is_p(toks[i + 1], "{"):
                close = brace_match(toks, i + 1)
                out.append((i, close))
                i = close + 1
                continue
            depth = 0
            j = i + 1
            while j < len(toks):
                t = toks[j]
                if t[0] == PUNCT:
                    if t[1] in ("(", "[", "{"):
                        depth += 1
                    elif t[1] in (")", "]", "}"):
                        depth -= 1
                    elif t[1] == ";" and depth <= 0:
                        break
                j += 1
            out.append((i, j))
            i = j + 1
            continue
        i += 1
    return out


def in_regions(regions, idx):
    return any(a <= idx <= b for a, b in regions)


def finding(rule, file, line, message):
    return {"rule": rule, "file": file, "line": line, "message": message}


L1_FILES = [
    "rust/src/util/par.rs",
    "rust/src/util/mat.rs",
    "rust/src/mx/tensor.rs",
    "rust/src/pearray/array.rs",
    "rust/src/gemmcore/core.rs",
]
L1_PAR_IDENTS = ["par_map", "par_chunks_mut", "spawn"]


def l1(src, tests, allow):
    out = []
    test_idents = set()
    for _, toks, _ in tests:
        for t in toks:
            if t[0] == IDENT:
                test_idents.add(t[1])
    for rel, toks, _ in src:
        if rel not in L1_FILES:
            continue
        fns = functions(toks)
        tregions = test_regions(toks)
        names = {fi["name"] for fi in fns}
        for fi in fns:
            if not fi["is_pub"] or in_regions(tregions, fi["kw"]):
                continue
            if fi["body"] is None:
                continue
            open_idx, close = fi["body"]
            if fi["name"].endswith("_serial"):
                if fi["name"] not in test_idents and not allowed(allow, "L1", fi["name"]):
                    out.append(finding(
                        "L1", rel, fi["line"],
                        "serial twin `%s` is not referenced from any identity test "
                        "in rust/tests/" % fi["name"],
                    ))
                continue
            body = toks[open_idx + 1 : min(close, len(toks))]
            has_par = any(t[0] == IDENT and t[1] in L1_PAR_IDENTS for t in body)
            if not has_par or allowed(allow, "L1", fi["name"]):
                continue
            twin = fi["name"] + "_serial"
            if twin not in names:
                out.append(finding(
                    "L1", rel, fi["line"],
                    "parallel kernel `%s` has no `%s` twin" % (fi["name"], twin),
                ))
    return out


L2_BANNED = ["log2", "ln", "powf"]


def l2(src, allow):
    out = []
    for rel, toks, _ in src:
        if not rel.startswith("rust/src/mx/"):
            continue
        tregions = test_regions(toks)
        for i in range(max(len(toks) - 1, 0)):
            if (
                toks[i][0] == IDENT
                and toks[i][1] in L2_BANNED
                and _is_p(toks[i + 1], "(")
                and not in_regions(tregions, i)
                and not allowed(allow, "L2", under_src(rel) or rel)
            ):
                out.append(finding(
                    "L2", rel, toks[i][2],
                    "`%s(` in MX exponent code — use element::floor_log2 instead"
                    % toks[i][1],
                ))
    return out


def int_value(text):
    """-> (value, hex_digit_count) or None (rules.rs int_value)."""
    t = text.replace("_", "")
    for suf in INT_SUFFIXES:
        if t.endswith(suf) and len(t) > len(suf):
            t = t[: -len(suf)]
            break
    try:
        if t[:2] in ("0x", "0X"):
            return int(t[2:], 16), len(t) - 2
        if t[:2] in ("0b", "0B"):
            return int(t[2:], 2), 0
        if t[:2] in ("0o", "0O"):
            return int(t[2:], 8), 0
        return int(t, 10), 0
    except ValueError:
        return None


def l3(src, allow):
    out = []
    for rel, toks, _ in src:
        if rel != "rust/src/mx/packed.rs":
            continue
        fns = functions(toks)
        tregions = test_regions(toks)
        cregions = const_regions(toks)
        for i, t in enumerate(toks):
            if t[0] != INT or in_regions(tregions, i) or in_regions(cregions, i):
                continue
            parsed = int_value(t[1])
            if parsed is None:
                continue
            v, hex_digits = parsed
            if not (v in (4, 6, 8) or hex_digits >= 8):
                continue
            in_allowed_fn = False
            for fi in fns:
                end = fi["body"][1] if fi["body"] else fi["kw"]
                if fi["kw"] <= i <= end and allowed(allow, "L3", fi["name"]):
                    in_allowed_fn = True
                    break
            if in_allowed_fn:
                continue
            out.append(finding(
                "L3", rel, t[2],
                "magic bit-width literal `%s` outside a scheme-constant table — "
                "derive from ElementFormat::bits()/scheme constants" % t[1],
            ))
    return out


L4_DIRS = [
    "rust/src/fleet/",
    "rust/src/trainer/",
    "rust/src/backend/",
    "rust/src/coordinator/",
    "rust/src/serve/",
    "rust/src/store/",
]


def l4(src, allow):
    out = []
    for rel, toks, _ in src:
        if not any(rel.startswith(d) for d in L4_DIRS):
            continue
        key = under_src(rel) or rel
        if allowed(allow, "L4", key):
            continue
        tregions = test_regions(toks)
        for i in range(1, max(len(toks) - 1, 1)):
            if (
                toks[i][0] == IDENT
                and toks[i][1] in ("unwrap", "expect")
                and _is_p(toks[i - 1], ".")
                and _is_p(toks[i + 1], "(")
                and not in_regions(tregions, i)
            ):
                out.append(finding(
                    "L4", rel, toks[i][2],
                    "`.%s(` in library code — propagate a structured TrainError "
                    "instead" % toks[i][1],
                ))
    return out


L5_NAMES = ["write_bytes", "read_bytes", "to_bytes", "from_bytes"]


def checkpoint_version(src):
    for rel, toks, _ in src:
        if rel != "rust/src/trainer/checkpoint.rs":
            continue
        for i in range(max(len(toks) - 1, 0)):
            if _is_i(toks[i], "const") and _is_i(toks[i + 1], "VERSION"):
                for t in toks[i + 2 : min(i + 10, len(toks))]:
                    if t[0] == INT:
                        parsed = int_value(t[1])
                        if parsed is not None:
                            return parsed[0] & 0xFFFFFFFF
    return 0


def store_version(src):
    """`const VERSION` from store/mod.rs; 0 when the store layer is absent."""
    for rel, toks, _ in src:
        if rel != "rust/src/store/mod.rs":
            continue
        for i in range(max(len(toks) - 1, 0)):
            if _is_i(toks[i], "const") and _is_i(toks[i + 1], "VERSION"):
                for t in toks[i + 2 : min(i + 10, len(toks))]:
                    if t[0] == INT:
                        parsed = int_value(t[1])
                        if parsed is not None:
                            return parsed[0] & 0xFFFFFFFF
    return 0


def layout_hashes(src):
    """-> list of (key, hash, line, rel), keyed path-under-src::name."""
    seen = {}
    out = []
    for rel, toks, _ in src:
        if not rel.startswith("rust/src/"):
            continue
        tregions = test_regions(toks)
        for fi in functions(toks):
            if fi["name"] not in L5_NAMES or in_regions(tregions, fi["kw"]):
                continue
            if fi["body"] is None:
                continue
            open_idx, close = fi["body"]
            base = "%s::%s" % (under_src(rel) or rel, fi["name"])
            n = seen.get(base, 0) + 1
            seen[base] = n
            key = base if n == 1 else "%s#%d" % (base, n)
            h = token_hash(toks[open_idx + 1 : min(close, len(toks))])
            out.append((key, h, fi["line"], rel))
    return out


def l5(src, manifest):
    out = []
    version = checkpoint_version(src)
    if version != manifest["version"]:
        out.append(finding(
            "L5", "rust/src/trainer/checkpoint.rs", 1,
            "rust/lint.manifest records VERSION %d but checkpoint.rs has VERSION %d "
            "— run `mxlint --update-manifest` and commit the result"
            % (manifest["version"], version),
        ))
        return out
    sversion = store_version(src)
    if sversion != manifest.get("store_version", 0):
        out.append(finding(
            "L5", "rust/src/store/mod.rs", 1,
            "rust/lint.manifest records store VERSION %d but store/mod.rs has "
            "VERSION %d — run `mxlint --update-manifest` and commit the result"
            % (manifest.get("store_version", 0), sversion),
        ))
        return out
    current = layout_hashes(src)
    recorded = dict(manifest["entries"])
    for key, h, line, rel in current:
        if key in recorded:
            want = recorded[key]
            if want != h:
                if key.startswith("store/"):
                    msg = (
                        "byte-layout of `%s` changed (%016x != manifest %016x) "
                        "without a store VERSION bump (still %d) — bump VERSION "
                        "in store/mod.rs and run `mxlint --update-manifest`"
                        % (key, h, want, sversion)
                    )
                else:
                    msg = (
                        "byte-layout of `%s` changed (%016x != manifest %016x) without "
                        "a VERSION bump (still %d) — bump VERSION in "
                        "trainer/checkpoint.rs and run `mxlint --update-manifest`"
                        % (key, h, want, version)
                    )
                out.append(finding("L5", rel, line, msg))
        else:
            out.append(finding(
                "L5", rel, line,
                "byte-layout function `%s` has no entry in rust/lint.manifest — "
                "run `mxlint --update-manifest`" % key,
            ))
    current_keys = {k for k, _, _, _ in current}
    for key, _ in manifest["entries"]:
        if key not in current_keys:
            out.append(finding(
                "L5", "rust/lint.manifest", 1,
                "manifest entry `%s` has no matching function — "
                "run `mxlint --update-manifest`" % key,
            ))
    return out


def l6(src, allow):
    out = []
    for rel, toks, _ in src:
        if not rel.startswith("rust/src/"):
            continue
        tregions = test_regions(toks)
        for fi in functions(toks):
            if in_regions(tregions, fi["kw"]) or fi["body"] is None:
                continue
            open_idx, close = fi["body"]
            body = toks[open_idx + 1 : min(close, len(toks))]
            calls_save = any(
                body[i][0] == IDENT and body[i][1] == "save_json" and _is_p(body[i + 1], "(")
                for i in range(max(len(body) - 1, 0))
            )
            if not calls_save:
                continue
            stamped = any(
                t[0] == IDENT and t[1] in ("bench_doc", "stamped_doc") for t in body
            )
            key = "%s::%s" % (under_src(rel) or rel, fi["name"])
            if not stamped and not allowed(allow, "L6", key):
                out.append(finding(
                    "L6", rel, fi["line"],
                    "`%s` writes results JSON without bench_doc/stamped_doc schema "
                    "stamping" % fi["name"],
                ))
    return out


def l7(src, allow):
    out = []
    for rel, toks, safety_lines in src:
        if not rel.startswith("rust/src/"):
            continue
        name = rel.rsplit("/", 1)[-1]
        if name in ("lib.rs", "main.rs", "mod.rs") or "/bin/" in rel:
            continue
        key = under_src(rel) or rel
        if allowed(allow, "L7", key):
            continue
        unsafe_toks = [t for t in toks if t[0] == IDENT and t[1] == "unsafe"]
        if not unsafe_toks:
            has_forbid = any(
                _is_p(toks[i], "#")
                and _is_p(toks[i + 1], "!")
                and _is_p(toks[i + 2], "[")
                and _is_i(toks[i + 3], "forbid")
                and _is_p(toks[i + 4], "(")
                and _is_i(toks[i + 5], "unsafe_code")
                and _is_p(toks[i + 6], ")")
                and _is_p(toks[i + 7], "]")
                for i in range(max(len(toks) - 7, 0))
            )
            if not has_forbid:
                out.append(finding(
                    "L7", rel, 1,
                    "file has no unsafe code — add #![forbid(unsafe_code)] so "
                    "future unsafe must opt in explicitly",
                ))
        else:
            for t in unsafe_toks:
                covered = any(max(t[2] - 3, 0) <= s <= t[2] for s in safety_lines)
                if not covered:
                    out.append(finding(
                        "L7", rel, t[2],
                        "`unsafe` without a `// SAFETY:` comment within the 3 "
                        "lines above it",
                    ))
    return out


L8_DIR = "rust/src/mx/simd/"
L8_SUFFIXES = ["_avx2", "_sse41", "_neon"]


def _has_arch_gate(toks):
    return any(
        _is_p(toks[i], "#")
        and _is_p(toks[i + 1], "!")
        and _is_p(toks[i + 2], "[")
        and _is_i(toks[i + 3], "cfg")
        and _is_p(toks[i + 4], "(")
        and _is_i(toks[i + 5], "target_arch")
        for i in range(max(len(toks) - 5, 0))
    )


def l8(src, tests, allow):
    out = []
    src_fns = set()
    for rel, toks, _ in src:
        if not rel.startswith("rust/src/"):
            continue
        for fi in functions(toks):
            src_fns.add(fi["name"])
    test_idents = set()
    for _, toks, _ in tests:
        for t in toks:
            if t[0] == IDENT:
                test_idents.add(t[1])
    for rel, toks, _ in src:
        if not rel.startswith("rust/src/"):
            continue
        arch_gated = _has_arch_gate(toks)
        for i in range(max(len(toks) - 2, 0)):
            if not (
                _is_p(toks[i], "#")
                and _is_p(toks[i + 1], "[")
                and _is_i(toks[i + 2], "target_feature")
            ):
                continue
            found = None
            for j in range(i + 3, min(i + 40, max(len(toks) - 1, 0))):
                if _is_i(toks[j], "fn") and toks[j + 1][0] == IDENT:
                    found = (toks[j + 1][1], toks[j + 1][2])
                    break
            if found is None:
                continue
            name, line = found
            if allowed(allow, "L8", name):
                continue
            if not rel.startswith(L8_DIR):
                out.append(finding(
                    "L8", rel, line,
                    "#[target_feature] fn `%s` outside %s — arch kernels live in "
                    "the simd module behind the dispatcher" % (name, L8_DIR),
                ))
                continue
            if not arch_gated:
                out.append(finding(
                    "L8", rel, line,
                    "#[target_feature] fn `%s` in a module without an inner "
                    "`#![cfg(target_arch = ...)]` gate" % name,
                ))
            base = None
            for suf in L8_SUFFIXES:
                if name.endswith(suf):
                    base = name[: -len(suf)]
                    break
            if base is None:
                out.append(finding(
                    "L8", rel, line,
                    "#[target_feature] fn `%s` is not named for its vector path "
                    "(*_avx2 / *_sse41 / *_neon)" % name,
                ))
                continue
            twin = base + "_swar"
            if twin not in src_fns:
                out.append(finding(
                    "L8", rel, line,
                    "vector kernel `%s` has no `%s` scalar twin" % (name, twin),
                ))
            elif twin not in test_idents:
                out.append(finding(
                    "L8", rel, line,
                    "scalar twin `%s` of `%s` is not referenced from any "
                    "bit-identity test in rust/tests/" % (twin, name),
                ))
    return out


L9_DIR = "rust/src/chaos/"


def _has_cfg_attr(toks, kw):
    start = max(kw - 40, 0)
    for i in range(start, max(kw - 3, start)):
        if (
            _is_p(toks[i], "#")
            and _is_p(toks[i + 1], "[")
            and _is_i(toks[i + 2], "cfg")
            and _is_p(toks[i + 3], "(")
        ):
            return True
    return False


def l9(src, tests, allow):
    out = []
    test_idents = set()
    for _, toks, _ in tests:
        for t in toks:
            if t[0] == IDENT:
                test_idents.add(t[1])
    for rel, toks, _ in src:
        if not rel.startswith("rust/src/"):
            continue
        in_chaos = rel.startswith(L9_DIR)
        plan_aware = any(t[0] == IDENT and t[1] == "FaultPlan" for t in toks)
        declared = set()
        for fi in functions(toks):
            name = fi["name"]
            if not name.startswith("inject_"):
                continue
            declared.add(name)
            if allowed(allow, "L9", name):
                continue
            if name not in test_idents:
                out.append(finding(
                    "L9", rel, fi["line"],
                    "chaos seam `%s` is not referenced from any test in "
                    "rust/tests/ — an undrilled injection seam is unproven risk"
                    % name,
                ))
            if not in_chaos and not _has_cfg_attr(toks, fi["kw"]):
                out.append(finding(
                    "L9", rel, fi["line"],
                    "chaos seam `%s` declared outside %s without a #[cfg(...)] "
                    "gate — seams live in the plan-gated chaos module"
                    % (name, L9_DIR),
                ))
        if in_chaos:
            continue
        for t in toks:
            if t[0] != IDENT or not t[1].startswith("inject_"):
                continue
            if t[1] in declared or allowed(allow, "L9", t[1]):
                continue
            if not plan_aware:
                out.append(finding(
                    "L9", rel, t[2],
                    "`%s` referenced without `FaultPlan` anywhere in the file — "
                    "injection seams fire only behind a fault plan" % t[1],
                ))
    return out


def run_all(src, tests, allow, manifest):
    out = []
    out.extend(l1(src, tests, allow))
    out.extend(l2(src, allow))
    out.extend(l3(src, allow))
    out.extend(l4(src, allow))
    out.extend(l5(src, manifest))
    out.extend(l6(src, allow))
    out.extend(l7(src, allow))
    out.extend(l8(src, tests, allow))
    out.extend(l9(src, tests, allow))
    out.sort(key=lambda f: (f["file"], f["line"], f["rule"]))
    return out


# ------------------------------------------------------- config / walk
# Port of rust/src/lint/mod.rs.


def parse_config(text):
    allow = {}
    section = None
    for idx, raw in enumerate(text.splitlines()):
        ln = idx + 1
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("["):
            inner = line[1:]
            if not inner.endswith("]"):
                raise ValueError("line %d: unclosed section" % ln)
            inner = inner[:-1]
            if not inner.startswith("allow."):
                raise ValueError("line %d: unknown section `[%s]`" % (ln, inner))
            section = inner[len("allow."):]
            allow.setdefault(section, [])
            continue
        if section is None:
            raise ValueError("line %d: entry outside an [allow.*] section" % ln)
        key, rest = _parse_quoted(line, ln)
        rest = rest.lstrip()
        if not rest.startswith("="):
            raise ValueError("line %d: expected `=`" % ln)
        reason, tail = _parse_quoted(rest[1:].lstrip(), ln)
        tail = tail.strip()
        if tail and not tail.startswith("#"):
            raise ValueError("line %d: trailing garbage `%s`" % (ln, tail))
        if not reason.strip():
            raise ValueError(
                "line %d: allowlist entry `%s` needs a non-empty reason" % (ln, key)
            )
        allow[section].append((key, reason))
    return allow


def _parse_quoted(s, ln):
    if not s.startswith('"'):
        raise ValueError('line %d: expected "..." string' % ln)
    end = s.find('"', 1)
    if end < 0:
        raise ValueError('line %d: unterminated string' % ln)
    return s[1:end], s[end + 1:]


def parse_manifest(text):
    m = {"version": 0, "store_version": 0, "entries": []}
    saw_version = False
    for idx, raw in enumerate(text.splitlines()):
        ln = idx + 1
        line = raw.strip()
        if not line or line.startswith("#"):
            continue
        if line.startswith("store_version "):
            m["store_version"] = int(line[len("store_version "):].strip())
            continue
        if line.startswith("version "):
            m["version"] = int(line[len("version "):].strip())
            saw_version = True
            continue
        if line.startswith("fn "):
            parts = line[3:].split()
            if len(parts) < 2:
                raise ValueError("line %d: missing key or hash" % ln)
            m["entries"].append((parts[0], int(parts[1], 16)))
            continue
        raise ValueError("line %d: unrecognized `%s`" % (ln, line))
    if not saw_version:
        raise ValueError("manifest has no `version` line")
    return m


def render_manifest(m):
    out = [
        "# Byte-layout manifest for mxlint rule L5. Regenerate with",
        "#   cargo run --release --bin mxlint -- --update-manifest",
        "# (or `python3 ci/mxlint_mirror.py --update-manifest` without a toolchain).",
        "version %d" % m["version"],
        "store_version %d" % m.get("store_version", 0),
    ]
    for k, h in sorted(m["entries"]):
        out.append("fn %s %016x" % (k, h))
    return "\n".join(out) + "\n"


def current_manifest(src):
    return {
        "version": checkpoint_version(src),
        "store_version": store_version(src),
        "entries": [(k, h) for k, h, _, _ in layout_hashes(src)],
    }


def _walk_rs(d, root, out):
    names = sorted(os.listdir(d))
    for name in names:
        path = os.path.join(d, name)
        if os.path.isdir(path):
            _walk_rs(path, root, out)
        elif name.endswith(".rs"):
            rel = os.path.relpath(path, root).replace(os.sep, "/")
            with open(path, "rb") as f:
                toks, safety = lex(f.read())
            out.append((rel, toks, safety))


def collect_sources(root):
    src, tests = [], []
    _walk_rs(os.path.join(root, "rust", "src"), root, src)
    tdir = os.path.join(root, "rust", "tests")
    if os.path.isdir(tdir):
        _walk_rs(tdir, root, tests)
    return src, tests


def render_json(findings):
    counts = {}
    for f in findings:
        counts[f["rule"]] = counts.get(f["rule"], 0) + 1
    doc = {
        "tool": "mxlint",
        "schema_version": 1,
        "findings": findings,
        "counts": dict(sorted(counts.items()), total=len(findings)),
    }
    return json.dumps(doc, indent=2, ensure_ascii=False)


def main(argv):
    root = None
    emit_json = False
    update = False
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--root":
            i += 1
            root = argv[i]
        elif a == "--json":
            emit_json = True
        elif a == "--update-manifest":
            update = True
        elif a in ("-h", "--help"):
            print(__doc__)
            return 0
        else:
            print("mxlint_mirror: unknown argument `%s`" % a, file=sys.stderr)
            return 2
        i += 1
    if root is None:
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

    src, tests = collect_sources(root)
    manifest_path = os.path.join(root, "rust", "lint.manifest")
    if update:
        m = current_manifest(src)
        with open(manifest_path, "w") as f:
            f.write(render_manifest(m))
        print(
            "mxlint_mirror: wrote %s (%d entries, version %d)"
            % (manifest_path, len(m["entries"]), m["version"]),
            file=sys.stderr,
        )
        return 0

    with open(os.path.join(root, "rust", "lint.toml")) as f:
        allow = parse_config(f.read())
    with open(manifest_path) as f:
        manifest = parse_manifest(f.read())
    findings = run_all(src, tests, allow, manifest)
    if emit_json:
        print(render_json(findings))
    else:
        for f in findings:
            print("%s:%d: [%s] %s" % (f["file"], f["line"], f["rule"], f["message"]))
        if not findings:
            print("mxlint_mirror: clean (%d source files)" % len(src), file=sys.stderr)
        else:
            print("mxlint_mirror: %d finding(s)" % len(findings), file=sys.stderr)
    return 0 if not findings else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
