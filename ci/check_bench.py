#!/usr/bin/env python3
"""CI bench-gate: compare fresh BENCH_*.json results against committed
baselines and fail on perf regressions.

Usage:
    check_bench.py --results rust/results --baselines rust/benches/baselines \
                   [--tolerance 0.25] [--require-headline-speedup 2.0] \
                   [--require-simd-speedup 2.0] \
                   [--require-store-max-files 8] [--require-store-advantage 5.0] \
                   [--require-serve-p99-ratio 50.0]
    check_bench.py --mxlint-report rust/mxlint_report.json

Rules:
  * Every numeric metric whose key ends in ``_ns_op``/``ns_per_...`` or
    equals a ``schemes/...`` ns value is lower-is-better: the fresh
    value may exceed baseline * (1 + tolerance) only at the cost of a
    failure.  ``speedup`` metrics are higher-is-better: failure below
    baseline * (1 - tolerance).
  * ``BENCH_packed.json`` must always carry
    ``schemes.int8.headline_speedup >= --require-headline-speedup``
    (the acceptance criterion: the packed SWAR path is at least 2x the
    fake-quant GeMM path for mxint8 at the bench shapes), baseline or
    not.
  * When ``BENCH_packed.json`` carries
    ``schemes.int8.avx2_vs_swar_speedup`` (emitted only on AVX2 hosts),
    it must be ``>= --require-simd-speedup`` (the arch-native AVX2
    kernel is at least 2x the SWAR kernel on the 256^3 mxint8 GeMM).
    On hosts without AVX2 the key is absent and the floor passes with a
    notice — the bit-identity tests still ran, only the perf floor is
    unmeasurable there.
  * ``BENCH_store.json`` must always carry
    ``sharded.files_per_1k_robots <= --require-store-max-files`` (the
    sharding container actually consolidates a 1000-robot fleet) and
    ``partial_read_advantage >= --require-store-advantage`` (a single
    resume reads at most 1/5th of the shard store; the measured value
    is trailer + index + own chunks over the CountingStore wrapper),
    baseline or not.
  * ``BENCH_serve.json`` (the open-stream serving load run) is gated on
    correctness before performance, baseline or not:
    ``sessions_lost``, ``sessions_duplicated``, and ``twin_mismatches``
    must all be present and zero (every offer accounted exactly once,
    every sampled session bitwise equal to its standalone twin), and
    tail latency must hold ``p99_step_ms <= --require-serve-p99-ratio *
    p50_step_ms`` — the admission layer exists to shed load before the
    tail collapses, so a blown-out p99/p50 ratio is a failure even when
    the run "completed".
  * A missing baseline file is a bootstrap, not a failure: the fresh
    JSON is reported so it can be committed as the first baseline.
  * A baseline stamped with a different ``kernel_path`` (or none) is
    skipped with a notice: ns/op measured on different kernel paths are
    not comparable, exactly like a runner-class (thread-count) change.
  * A baseline with a different ``schema_version`` is skipped with a
    notice (incomparable layouts must not produce phantom regressions).
  * ``--mxlint-report`` switches to a separate mode that validates the
    shape of an ``mxlint --json`` report (tool/schema_version header,
    findings records, self-consistent counts) so the CI lint job fails
    loudly if the report format drifts out from under downstream
    tooling. It does NOT gate on the findings themselves — the mxlint
    exit code does that.
"""

import argparse
import json
import pathlib
import sys


def flatten(obj, prefix=""):
    """Yield (dotted_path, value) for every numeric leaf."""
    if isinstance(obj, dict):
        for k, v in obj.items():
            yield from flatten(v, f"{prefix}{k}.")
    elif isinstance(obj, (int, float)) and not isinstance(obj, bool):
        yield prefix.rstrip("."), float(obj)


def metric_kind(path):
    """'lower' | 'higher' | None (not gated)."""
    leaf = path.rsplit(".", 1)[-1]
    if leaf.endswith("_ns_op") or leaf.startswith("ns_per_") or leaf.endswith("_ms"):
        return "lower"
    if "speedup" in leaf:
        return "higher"
    # bench_quantize stores per-scheme ns/elem directly under schemes.*
    if path.startswith("schemes.") and path.count(".") == 1 and "/" in leaf:
        return "lower"
    return None


def validate_mxlint_report(path):
    """Validate an ``mxlint --json`` report (schema_version 1)."""
    try:
        doc = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        print(f"ERROR: cannot read mxlint report {path}: {e}", file=sys.stderr)
        return 1

    errors = []
    if doc.get("tool") != "mxlint":
        errors.append(f"tool is {doc.get('tool')!r}, expected 'mxlint'")
    if doc.get("schema_version") != 1:
        errors.append(f"schema_version is {doc.get('schema_version')!r}, expected 1")
    findings = doc.get("findings")
    if not isinstance(findings, list):
        errors.append("findings is not a list")
        findings = []
    for i, f in enumerate(findings):
        if not isinstance(f, dict):
            errors.append(f"findings[{i}] is not an object")
            continue
        for key, typ in (("rule", str), ("file", str), ("message", str)):
            if not isinstance(f.get(key), typ):
                errors.append(f"findings[{i}].{key} is not a {typ.__name__}")
        line = f.get("line")
        if isinstance(line, bool) or not isinstance(line, int) or line < 1:
            errors.append(f"findings[{i}].line is not a positive integer")
    counts = doc.get("counts")
    if not isinstance(counts, dict):
        errors.append("counts is not an object")
    else:
        tally = {}
        for f in findings:
            if isinstance(f, dict) and isinstance(f.get("rule"), str):
                tally[f["rule"]] = tally.get(f["rule"], 0) + 1
        if counts.get("total") != len(findings):
            errors.append(
                f"counts.total = {counts.get('total')!r} but there are "
                f"{len(findings)} findings"
            )
        for rule, n in tally.items():
            if counts.get(rule) != n:
                errors.append(f"counts.{rule} = {counts.get(rule)!r}, tallied {n}")

    if errors:
        print(f"mxlint report {path} is malformed:", file=sys.stderr)
        for e in errors:
            print(f"  - {e}", file=sys.stderr)
        return 1
    print(f"mxlint report {path} OK: {len(findings)} finding(s), schema v1.")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--results", type=pathlib.Path)
    ap.add_argument("--baselines", type=pathlib.Path)
    ap.add_argument("--tolerance", type=float, default=0.25)
    ap.add_argument("--require-headline-speedup", type=float, default=2.0)
    ap.add_argument("--require-simd-speedup", type=float, default=2.0)
    ap.add_argument("--require-store-max-files", type=float, default=8.0)
    ap.add_argument("--require-store-advantage", type=float, default=5.0)
    ap.add_argument("--require-serve-p99-ratio", type=float, default=50.0)
    ap.add_argument("--mxlint-report", type=pathlib.Path, default=None)
    args = ap.parse_args()

    if args.mxlint_report is not None:
        return validate_mxlint_report(args.mxlint_report)
    if args.results is None or args.baselines is None:
        ap.error("--results and --baselines are required unless --mxlint-report is given")

    failures = []
    fresh_files = sorted(args.results.glob("BENCH_*.json"))
    if not fresh_files:
        print(f"ERROR: no BENCH_*.json under {args.results}", file=sys.stderr)
        return 1

    for fresh_path in fresh_files:
        fresh = json.loads(fresh_path.read_text())
        name = fresh_path.name

        if name == "BENCH_packed.json":
            headline = (
                fresh.get("schemes", {}).get("int8", {}).get("headline_speedup")
            )
            if headline is None:
                failures.append(f"{name}: schemes.int8.headline_speedup missing")
            elif headline < args.require_headline_speedup:
                failures.append(
                    f"{name}: mxint8 packed speedup {headline:.2f}x is below the "
                    f"required {args.require_headline_speedup:.2f}x floor"
                )
            else:
                print(
                    f"{name}: mxint8 packed speedup {headline:.2f}x "
                    f"(floor {args.require_headline_speedup:.2f}x) OK"
                )
            simd = (
                fresh.get("schemes", {}).get("int8", {}).get("avx2_vs_swar_speedup")
            )
            if simd is None:
                print(
                    f"{name}: no avx2_vs_swar_speedup (host without AVX2) — "
                    "SIMD floor not measurable here, passing with notice."
                )
            elif simd < args.require_simd_speedup:
                failures.append(
                    f"{name}: mxint8 avx2-over-swar speedup {simd:.2f}x is below "
                    f"the required {args.require_simd_speedup:.2f}x floor"
                )
            else:
                print(
                    f"{name}: mxint8 avx2-over-swar speedup {simd:.2f}x "
                    f"(floor {args.require_simd_speedup:.2f}x) OK"
                )

        if name == "BENCH_store.json":
            files = fresh.get("sharded", {}).get("files_per_1k_robots")
            if files is None:
                failures.append(f"{name}: sharded.files_per_1k_robots missing")
            elif files > args.require_store_max_files:
                failures.append(
                    f"{name}: {files:.0f} shard files per 1k robots exceeds the "
                    f"{args.require_store_max_files:.0f}-file ceiling"
                )
            else:
                print(
                    f"{name}: {files:.0f} shard files per 1k robots "
                    f"(ceiling {args.require_store_max_files:.0f}) OK"
                )
            advantage = fresh.get("partial_read_advantage")
            if advantage is None:
                failures.append(f"{name}: partial_read_advantage missing")
            elif advantage < args.require_store_advantage:
                failures.append(
                    f"{name}: partial-read advantage {advantage:.2f}x is below "
                    f"the required {args.require_store_advantage:.2f}x floor "
                    "(a resume is reading too much of the shard store)"
                )
            else:
                print(
                    f"{name}: partial-read advantage {advantage:.2f}x "
                    f"(floor {args.require_store_advantage:.2f}x) OK"
                )

        if name == "BENCH_serve.json":
            # correctness first: every offer accounted exactly once and
            # every sampled session bitwise equal to its standalone twin
            for key in ("sessions_lost", "sessions_duplicated", "twin_mismatches"):
                val = fresh.get(key)
                if val is None:
                    failures.append(f"{name}: {key} missing")
                elif val != 0:
                    failures.append(f"{name}: {key} = {val:.0f}, must be 0")
                else:
                    print(f"{name}: {key} = 0 OK")
            # tail latency: admission control exists to shed load before
            # the p99 collapses, so the tail must stay a bounded multiple
            # of the median
            p50 = fresh.get("p50_step_ms")
            p99 = fresh.get("p99_step_ms")
            if p50 is None or p99 is None:
                failures.append(f"{name}: p50_step_ms/p99_step_ms missing")
            elif p50 > 0 and p99 > p50 * args.require_serve_p99_ratio:
                failures.append(
                    f"{name}: p99 {p99:.3f} ms/step is {p99 / p50:.1f}x the p50 "
                    f"{p50:.3f} ms/step (ceiling {args.require_serve_p99_ratio:.0f}x) "
                    "— step latency collapsed under load"
                )
            else:
                ratio = p99 / p50 if p50 > 0 else 0.0
                print(
                    f"{name}: p99/p50 = {ratio:.1f}x "
                    f"(ceiling {args.require_serve_p99_ratio:.0f}x) OK"
                )

        base_path = args.baselines / name
        if not base_path.exists():
            print(f"{name}: no committed baseline yet — bootstrap run, not gated.")
            print(f"  (commit the uploaded artifact to {base_path} to arm the gate)")
            continue
        base = json.loads(base_path.read_text())
        if fresh.get("schema_version") is None:
            # a fresh result without a schema stamp cannot be gated at
            # all — fail loudly, naming the offending bench
            failures.append(
                f"{name}: fresh result carries no schema_version "
                "(report::bench_doc must stamp every BENCH_*.json)"
            )
            continue
        if base.get("schema_version") != fresh.get("schema_version"):
            print(
                f"schema mismatch in {name}: baseline schema "
                f"v{base.get('schema_version')} != fresh "
                f"v{fresh.get('schema_version')} — skipping diff "
                "(re-baseline to re-arm the gate)"
            )
            continue
        if base.get("threads") != fresh.get("threads"):
            # wall-clock and serial/parallel-speedup metrics scale with
            # the worker count; a runner-class change must not read as a
            # perf regression of the code under test
            print(
                f"{name}: baseline ran with threads={base.get('threads')}, "
                f"fresh with threads={fresh.get('threads')} — skipping diff "
                "(re-baseline on the current runner class to re-arm the gate)"
            )
            continue
        if base.get("kernel_path") != fresh.get("kernel_path"):
            # ns/op measured on different kernel paths (or on a baseline
            # predating kernel-path provenance) are not comparable
            print(
                f"{name}: baseline kernel_path={base.get('kernel_path')!r}, "
                f"fresh kernel_path={fresh.get('kernel_path')!r} — skipping diff "
                "(re-baseline on the current kernel path to re-arm the gate)"
            )
            continue

        base_metrics = dict(flatten(base))
        compared = 0
        for path, value in flatten(fresh):
            kind = metric_kind(path)
            if kind is None or path not in base_metrics:
                continue
            ref = base_metrics[path]
            if ref <= 0:
                continue
            compared += 1
            if kind == "lower" and value > ref * (1 + args.tolerance):
                failures.append(
                    f"{name}: {path} regressed {ref:.4g} -> {value:.4g} "
                    f"(+{(value / ref - 1) * 100:.1f}% > {args.tolerance * 100:.0f}%) "
                    f"[baseline {base.get('git_sha', '?')[:12]} vs "
                    f"{fresh.get('git_sha', '?')[:12]}]"
                )
            elif kind == "higher" and value < ref * (1 - args.tolerance):
                failures.append(
                    f"{name}: {path} regressed {ref:.4g} -> {value:.4g} "
                    f"(-{(1 - value / ref) * 100:.1f}% > {args.tolerance * 100:.0f}%)"
                )
        print(f"{name}: {compared} metric(s) compared against committed baseline.")

    if failures:
        print("\nbench-gate FAILED:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        return 1
    print("\nbench-gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
