#!/usr/bin/env python3
"""CI coverage ratchet: enforce line-coverage floors on source subtrees.

Usage:
    check_coverage.py --json coverage.json --floor src/mx/=80 \\
                      --floor src/store/=70 [--floor PATH=PCT ...]
    check_coverage.py --json coverage.json --path src/mx/ --min-lines 80

Reads the ``cargo llvm-cov report --json --summary-only`` document and,
for each floor, aggregates line counts over every file whose path
contains the floor's path fragment (substring match on the normalized
path, so absolute runner paths work), failing when covered/total falls
below the floor's percentage. ``--floor`` is repeatable so one
invocation gates several subtrees against independent floors; the
legacy ``--path``/``--min-lines`` pair is kept as a single-floor
spelling.

This is a *ratchet*: floors should only ever move up. When a change
legitimately raises coverage well above a floor, bump it in
.github/workflows/ci.yml so the gain cannot silently erode.

A floor matching zero files is a failure too — a moved directory must
not turn the gate into a no-op.
"""

import argparse
import json
import pathlib
import sys


def parse_floor(spec):
    path, sep, pct = spec.partition("=")
    if not sep or not path:
        raise argparse.ArgumentTypeError(
            f"bad floor `{spec}` — expected PATH=PCT, e.g. src/mx/=80"
        )
    try:
        return path, float(pct)
    except ValueError:
        raise argparse.ArgumentTypeError(f"bad floor percentage in `{spec}`")


def check_floor(exports, path, min_lines):
    """Gate one subtree; returns True when the floor holds."""
    total = covered = 0
    rows = []
    for export in exports:
        for f in export.get("files", []):
            name = f.get("filename", "").replace("\\", "/")
            if path not in name:
                continue
            lines = f.get("summary", {}).get("lines", {})
            count = int(lines.get("count", 0))
            hit = int(lines.get("covered", 0))
            total += count
            covered += hit
            pct = 100.0 * hit / count if count else 100.0
            rows.append((name, hit, count, pct))

    if not rows:
        print(
            f"ERROR: no files matching `{path}` in the coverage report — "
            "did the directory move? The gate must not become a no-op.",
            file=sys.stderr,
        )
        return False

    rows.sort(key=lambda r: r[3])
    width = max(len(pathlib.Path(name).name) for name, *_ in rows)
    for name, hit, count, pct in rows:
        print(f"  {pathlib.Path(name).name:<{width}}  {hit:>5}/{count:<5}  {pct:6.2f}%")

    pct = 100.0 * covered / total if total else 0.0
    print(f"\n{path}: {covered}/{total} lines covered = {pct:.2f}% "
          f"(floor {min_lines:.2f}%)")
    if pct < min_lines:
        print(
            f"coverage-gate FAILED: {path} line coverage {pct:.2f}% "
            f"is below the {min_lines:.2f}% ratchet floor",
            file=sys.stderr,
        )
        return False
    return True


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", required=True, type=pathlib.Path)
    ap.add_argument(
        "--floor",
        action="append",
        type=parse_floor,
        default=[],
        metavar="PATH=PCT",
        help="repeatable per-subtree floor, e.g. --floor src/mx/=80",
    )
    ap.add_argument("--path", help="legacy single-floor path fragment")
    ap.add_argument("--min-lines", type=float, default=80.0)
    args = ap.parse_args()

    floors = list(args.floor)
    if args.path:
        floors.append((args.path, args.min_lines))
    if not floors:
        print("ERROR: no floors given (use --floor PATH=PCT)", file=sys.stderr)
        return 1

    doc = json.loads(args.json.read_text())
    exports = doc.get("data", [])
    if not exports:
        print(f"ERROR: {args.json} has no coverage data", file=sys.stderr)
        return 1

    failed = [path for path, pct in floors if not check_floor(exports, path, pct)]
    print()
    if failed:
        print(f"coverage-gate FAILED for: {', '.join(failed)}", file=sys.stderr)
        return 1
    print(f"coverage-gate passed ({len(floors)} floor(s)).")
    return 0


if __name__ == "__main__":
    sys.exit(main())
