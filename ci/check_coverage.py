#!/usr/bin/env python3
"""CI coverage ratchet: enforce a line-coverage floor on a source subtree.

Usage:
    check_coverage.py --json coverage.json --path src/mx/ --min-lines 80

Reads the ``cargo llvm-cov report --json --summary-only`` document,
aggregates line counts over every file whose path contains ``--path``
(substring match on the normalized path, so absolute runner paths work),
and fails when covered/total falls below ``--min-lines`` percent.

This is a *ratchet*: the floor should only ever move up. When a change
legitimately raises coverage well above the floor, bump ``--min-lines``
in .github/workflows/ci.yml so the gain cannot silently erode.

Matching zero files is a failure too — a moved directory must not turn
the gate into a no-op.
"""

import argparse
import json
import pathlib
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", required=True, type=pathlib.Path)
    ap.add_argument("--path", required=True, help="path fragment selecting gated files")
    ap.add_argument("--min-lines", type=float, default=80.0)
    args = ap.parse_args()

    doc = json.loads(args.json.read_text())
    exports = doc.get("data", [])
    if not exports:
        print(f"ERROR: {args.json} has no coverage data", file=sys.stderr)
        return 1

    total = covered = 0
    rows = []
    for export in exports:
        for f in export.get("files", []):
            name = f.get("filename", "").replace("\\", "/")
            if args.path not in name:
                continue
            lines = f.get("summary", {}).get("lines", {})
            count = int(lines.get("count", 0))
            hit = int(lines.get("covered", 0))
            total += count
            covered += hit
            pct = 100.0 * hit / count if count else 100.0
            rows.append((name, hit, count, pct))

    if not rows:
        print(
            f"ERROR: no files matching `{args.path}` in {args.json} — "
            "did the directory move? The gate must not become a no-op.",
            file=sys.stderr,
        )
        return 1

    rows.sort(key=lambda r: r[3])
    width = max(len(pathlib.Path(name).name) for name, *_ in rows)
    for name, hit, count, pct in rows:
        print(f"  {pathlib.Path(name).name:<{width}}  {hit:>5}/{count:<5}  {pct:6.2f}%")

    pct = 100.0 * covered / total if total else 0.0
    print(f"\n{args.path}: {covered}/{total} lines covered = {pct:.2f}% "
          f"(floor {args.min_lines:.2f}%)")
    if pct < args.min_lines:
        print(
            f"coverage-gate FAILED: {args.path} line coverage {pct:.2f}% "
            f"is below the {args.min_lines:.2f}% ratchet floor",
            file=sys.stderr,
        )
        return 1
    print("coverage-gate passed.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
