"""L2 model correctness: shapes, training convergence, quantized paths,
and the state-threading contract the Rust runtime relies on."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def toy_batch(batch=32, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(batch, model.DIMS[0])).astype(np.float32)
    # smooth target on the first 8 output dims
    y = np.zeros((batch, model.DIMS[-1]), np.float32)
    y[:, :8] = np.tanh(x[:, :8] * 0.7 + x[:, 8:16]) * 0.5
    return jnp.asarray(x), jnp.asarray(y)


def test_state_layout_contract():
    state = model.init_state(jax.random.PRNGKey(0))
    assert len(state) == model.STATE_LEN == 25
    assert state[0].shape == (1,)
    # per-layer group: w, b, mw, vw, mb, vb
    for i in range(model.N_LAYERS):
        g = state[1 + 6 * i : 7 + 6 * i]
        assert g[0].shape == (model.DIMS[i], model.DIMS[i + 1])
        assert g[1].shape == (model.DIMS[i + 1],)
        assert g[2].shape == g[0].shape and g[3].shape == g[0].shape


@pytest.mark.parametrize("fmt", ["fp32", "int8", "e4m3"])
def test_train_step_io_contract(fmt):
    state = model.init_state(jax.random.PRNGKey(1))
    x, y = toy_batch()
    out = model.train_step(state, x, y, fmt=fmt)
    assert len(out) == 1 + model.STATE_LEN
    loss, new_state = out[0], out[1:]
    assert loss.shape == (1,)
    assert float(new_state[0][0]) == 1.0  # step incremented
    # weights actually moved
    assert not np.allclose(np.asarray(new_state[1]), np.asarray(state[1]))


@pytest.mark.parametrize("fmt", ["fp32", "int8", "e4m3", "e2m1"])
def test_training_reduces_loss(fmt):
    state = model.init_state(jax.random.PRNGKey(2))
    x, y = toy_batch(seed=3)
    step = jax.jit(functools.partial(model.train_step, fmt=fmt, lr=2e-3))
    first = None
    for _ in range(60):
        out = step(state, x, y)
        loss, state = float(out[0][0]), out[1:]
        first = loss if first is None else first
    assert loss < first * 0.7, f"{fmt}: {first} -> {loss}"


def test_eval_loss_matches_forward_mse():
    state = model.init_state(jax.random.PRNGKey(4))
    x, y = toy_batch(seed=5)
    (loss,) = model.eval_loss(state, x, y, fmt="fp32")
    params = [(state[1 + 6 * i], state[2 + 6 * i]) for i in range(model.N_LAYERS)]
    direct = model.mse(model.forward(params, x, "fp32"), y)
    np.testing.assert_allclose(float(loss[0]), float(direct), rtol=1e-6)


def test_quantized_forward_differs_from_fp32():
    state = model.init_state(jax.random.PRNGKey(6))
    x, y = toy_batch(seed=7)
    (l_fp,) = model.eval_loss(state, x, y, fmt="fp32")
    (l_q,) = model.eval_loss(state, x, y, fmt="e2m1")
    assert float(l_fp[0]) != float(l_q[0])


def test_ste_gradients_flow_through_quantization():
    # with straight-through quantization the weight gradients must be
    # nonzero everywhere the fp32 gradients are
    state = model.init_state(jax.random.PRNGKey(8))
    x, y = toy_batch(seed=9)
    params = [(state[1 + 6 * i], state[2 + 6 * i]) for i in range(model.N_LAYERS)]

    def loss_fn(params, fmt):
        return model.mse(model.forward(params, x, fmt), y)

    g_q = jax.grad(lambda p: loss_fn(p, "int8"))(params)
    norms = [float(jnp.linalg.norm(gw)) for gw, _ in g_q]
    assert all(n > 0 for n in norms), norms
