"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracle, and the
oracle vs hand-computed MX semantics. Hypothesis sweeps shapes and
formats (the prompt-level contract for this layer)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import mx_kernels as mk
from compile.kernels import ref

FORMATS = list(ref.ALL_FORMATS)


# ---------- oracle semantics ----------


def test_shared_exponent_matches_spec_examples():
    # max 1.0 under e4m3 (emax 8) -> 2^-8
    assert float(ref.shared_exponent(jnp.asarray(1.0), "e4m3")) == -8.0
    # int8: emax 0 -> floor(log2 max)
    assert float(ref.shared_exponent(jnp.asarray(3.9), "int8")) == 1.0
    # zero block -> min scale
    assert float(ref.shared_exponent(jnp.asarray(0.0), "e2m1")) == ref.SCALE_EMIN


@pytest.mark.parametrize("fmt", FORMATS)
def test_powers_of_two_roundtrip(fmt):
    x = jnp.asarray([[1.0, 0.5, -0.25, 0.125] * 8] * 8, jnp.float32)
    q = ref.fake_quant_square(x, fmt)
    np.testing.assert_array_equal(np.asarray(q), np.asarray(x))


@pytest.mark.parametrize(
    "fmt,maxv",
    [("e5m2", 57344.0), ("e4m3", 448.0), ("e3m2", 28.0), ("e2m3", 7.5), ("e2m1", 6.0)],
)
def test_element_saturation(fmt, maxv):
    # values >> max saturate at max (relative to the block scale of 1.0
    # when the block max is exactly at the format boundary)
    v = jnp.full((8, 8), maxv, jnp.float32)
    q = ref.fake_quant_square(v, fmt)
    np.testing.assert_allclose(np.asarray(q), maxv)


def test_e2m1_grid_values():
    # E2M1 representables (pos): 0, .5, 1, 1.5, 2, 3, 4, 6 — a block with
    # max 6 has scale 1 and must quantize exactly onto that grid
    x = np.zeros((8, 8), np.float32)
    vals = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0]
    x[0, :] = vals
    q = np.asarray(ref.fake_quant_square(jnp.asarray(x), "e2m1"))
    np.testing.assert_array_equal(q[0, :], vals)
    # midpoint 2.5 ties to even (2.0 mantissa code is even -> 2.0)
    x[0, 0] = 2.5
    q = np.asarray(ref.fake_quant_square(jnp.asarray(x), "e2m1"))
    assert q[0, 0] in (2.0, 3.0)


# ---------- pallas kernel vs oracle ----------


@pytest.mark.parametrize("fmt", FORMATS)
def test_pallas_quant_matches_oracle(fmt):
    rng = np.random.default_rng(hash(fmt) % 2**32)
    x = (rng.normal(size=(32, 64)) * 4.0).astype(np.float32)
    a = np.asarray(mk.mx_quant_square(jnp.asarray(x), fmt))
    b = np.asarray(ref.fake_quant_square(jnp.asarray(x), fmt))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=25, deadline=None)
@given(
    fmt=st.sampled_from(FORMATS),
    mb=st.integers(1, 6),
    nb=st.integers(1, 6),
    scale_pow=st.integers(-20, 20),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_quant_matches_oracle_hypothesis(fmt, mb, nb, scale_pow, seed):
    """Shape x format x dynamic-range sweep: kernel == oracle exactly."""
    rng = np.random.default_rng(seed)
    x = (rng.normal(size=(8 * mb, 8 * nb)) * 2.0**scale_pow).astype(np.float32)
    a = np.asarray(mk.mx_quant_square(jnp.asarray(x), fmt))
    b = np.asarray(ref.fake_quant_square(jnp.asarray(x), fmt))
    np.testing.assert_array_equal(a, b)


@settings(max_examples=10, deadline=None)
@given(
    fmt=st.sampled_from(["int8", "e4m3", "e2m1"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_gemm_matches_reference(fmt, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(32, 64)).astype(np.float32)
    w = rng.normal(size=(64, 128)).astype(np.float32)
    g = np.asarray(mk.mx_gemm(jnp.asarray(x), jnp.asarray(w), fmt))
    r = np.asarray(ref.mx_matmul_ref(jnp.asarray(x), jnp.asarray(w), fmt))
    np.testing.assert_allclose(g, r, rtol=1e-6, atol=1e-6)


def test_gemm_f32_is_exact_blocked_matmul():
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 96)).astype(np.float32)
    w = rng.normal(size=(96, 128)).astype(np.float32)
    g = np.asarray(mk.gemm_f32(jnp.asarray(x), jnp.asarray(w), bm=32, bn=128, bk=32))
    np.testing.assert_allclose(g, x @ w, rtol=1e-5, atol=1e-5)


def test_quant_error_ordering():
    # finer formats quantize a gaussian matrix strictly better
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))
    errs = {}
    for fmt in FORMATS:
        q = ref.fake_quant_square(x, fmt)
        errs[fmt] = float(jnp.mean((q - x) ** 2))
    assert errs["int8"] < errs["e2m3"] < errs["e2m1"]
    assert errs["e4m3"] < errs["e5m2"]  # more mantissa on same data
    assert errs["e2m1"] < 1.0
