"""Layer-1 Pallas kernels: MX square-block quantization and blocked GeMM.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's 8x8
square shared-exponent blocks map directly onto Pallas ``BlockSpec``
tiles — the per-tile max-reduce is the hardware's "largest power of two
in the block" scan, the power-of-two scale keeps the MXU fed with plain
f32/bf16 mantissa math, and the GeMM kernel's K-loop accumulates into the
output tile pinned in VMEM before a single writeback (the GeMM core's
output-stationary schedule with requantization on the way out).

All kernels run with ``interpret=True``: on this CPU PJRT stack a real
TPU lowering would emit Mosaic custom-calls the runtime cannot execute
(see /opt/xla-example/README.md); interpret mode lowers to plain HLO so
the AOT artifacts are executable anywhere, numerics identical.

TPU sizing estimate (for DESIGN.md §Perf): the quantize kernel holds one
(8 x n) f32 band in VMEM (n=256: 8 KiB) plus per-block maxima; the GeMM
kernel holds (bm, bk) + (bk, bn) + (bm, bn) f32 tiles (default 32x32 +
32x128 + 32x128 = 36 KiB) — far inside a TensorCore's VMEM, leaving room
for double-buffered HBM prefetch across the K loop.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

SQ = 8  # square-block edge


def _quant_kernel(x_ref, o_ref, *, fmt: str):
    """Quantize-dequantize one row-band of 8x8 blocks.

    The tile is (8, n): a horizontal band of square blocks. Each 8x8
    block derives its own shared exponent (two OCP 32-groups worth).
    """
    x = x_ref[...]
    n = x.shape[1]
    blocks = x.reshape(SQ, n // SQ, SQ).swapaxes(0, 1)  # [nb, 8, 8]
    bmax = jnp.max(jnp.abs(blocks), axis=(1, 2), keepdims=True)
    scale = ref._pow2(ref.shared_exponent(bmax, fmt))
    q = ref.quant_element(blocks / scale, fmt) * scale
    o_ref[...] = q.swapaxes(0, 1).reshape(SQ, n)


def mx_quant_square(x, fmt: str):
    """Pallas square-block fake-quantization of an [m, n] f32 matrix."""
    m, n = x.shape
    assert m % SQ == 0 and n % SQ == 0, (m, n)
    return pl.pallas_call(
        functools.partial(_quant_kernel, fmt=fmt),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // SQ,),
        in_specs=[pl.BlockSpec((SQ, n), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((SQ, n), lambda i: (i, 0)),
        interpret=True,
    )(x)


def _gemm_kernel(x_ref, w_ref, o_ref, *, k_steps: int):
    """Output-stationary blocked GeMM.

    The output tile stays pinned across the sequential K grid dimension
    (output-stationary, like the PE array's accumulators); each step adds
    one (bm, bk) x (bk, bn) product with f32 accumulation.
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    del k_steps  # shape bookkeeping only


def gemm_f32(x, w, bm: int = 32, bn: int = 128, bk: int = 32):
    """Blocked f32 GeMM through the Pallas kernel."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (m, n, k, bm, bn, bk)
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        interpret=True,
    )(x, w)


def mx_gemm(x, w, fmt: str):
    """Quantized GeMM: square-quantize operands (Pallas), then the blocked
    matmul with f32 accumulation (the PE-array semantics)."""
    return gemm_f32(mx_quant_square(x, fmt), mx_quant_square(w, fmt))
