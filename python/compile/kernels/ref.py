"""Pure-jnp oracle for MX quantization (the L1 correctness reference).

Semantics mirror the Rust codecs in ``rust/src/mx`` bit-for-bit on the
values they produce:

* shared exponent ``X = 2^(floor(log2(max|block|)) - emax_elem)``,
  clamped to E8M0's [-127, 127]; all-zero blocks take the minimum scale;
* elements encode with round-to-nearest-even on the mantissa grid,
  saturating at the format's max magnitude, subnormals included;
* MXINT8 elements are 1/64-step fixed point saturating at +-127/64.

Block groupings: 8x8 squares (ours) or 32-wide row vectors (OCP).
"""

from dataclasses import dataclass

import jax.numpy as jnp

SCALE_EMIN = -127.0
SCALE_EMAX = 127.0


@dataclass(frozen=True)
class FpFormat:
    name: str
    exp_bits: int
    mant_bits: int
    bias: int
    emax: int          # largest power-of-two exponent (OCP emax)
    max_value: float   # saturation magnitude


E5M2 = FpFormat("e5m2", 5, 2, 15, 15, 57344.0)
E4M3 = FpFormat("e4m3", 4, 3, 7, 8, 448.0)
E3M2 = FpFormat("e3m2", 3, 2, 3, 4, 28.0)
E2M3 = FpFormat("e2m3", 2, 3, 1, 2, 7.5)
E2M1 = FpFormat("e2m1", 2, 1, 1, 2, 6.0)

FP_FORMATS = {f.name: f for f in (E5M2, E4M3, E3M2, E2M3, E2M1)}
ALL_FORMATS = ("int8",) + tuple(FP_FORMATS)


def format_emax(fmt: str) -> int:
    return 0 if fmt == "int8" else FP_FORMATS[fmt].emax


def _pow2(e):
    """Exact 2^e for integer-valued float exponents (XLA's exp2 lowers
    through exp() and is off by ulps — ldexp is exact). Floored at -126:
    XLA's ldexp flushes subnormal results to 0, and a zero scale would
    turn empty blocks into NaN (0/0). 2^-126 is the smallest *normal*
    f32 scale; blocks that tiny quantize to zero either way."""
    e = jnp.maximum(e, -126.0)
    return jnp.ldexp(jnp.ones_like(e, dtype=jnp.float32), e.astype(jnp.int32))


def _floor_log2(x):
    # floor(log2 x) for x > 0, exact on powers of two (frexp-based)
    _, e = jnp.frexp(x)
    return e.astype(jnp.float32) - 1.0


def shared_exponent(block_max, fmt: str):
    """Shared scale exponent for a block max (array ok). Zero-max -> min."""
    safe = jnp.where(block_max > 0, block_max, 1.0)
    e = _floor_log2(safe) - format_emax(fmt)
    e = jnp.clip(e, SCALE_EMIN, SCALE_EMAX)
    return jnp.where(block_max > 0, e, SCALE_EMIN)


def quant_element(v, fmt: str):
    """Fake-quantize scale-divided values onto the element grid (RNE)."""
    if fmt == "int8":
        q = jnp.round(v * 64.0)  # jnp.round is round-half-to-even
        return jnp.clip(q, -127.0, 127.0) / 64.0
    f = FP_FORMATS[fmt]
    emin = 1 - f.bias
    a = jnp.abs(v)
    sign = jnp.sign(v)
    # exponent of the quantization step; subnormals clamp to emin
    e = jnp.maximum(_floor_log2(jnp.where(a > 0, a, 1.0)), float(emin))
    step = _pow2(e - f.mant_bits)
    q = jnp.round(a / step) * step
    q = jnp.minimum(q, f.max_value)
    return jnp.where(a > 0, sign * q, 0.0 * v)


def fake_quant_square(x, fmt: str):
    """Fake-quantize [m, n] through 8x8 square shared-exponent blocks.

    m and n must be multiples of 8 (the model pads its dims already).
    """
    m, n = x.shape
    assert m % 8 == 0 and n % 8 == 0, (m, n)
    blocks = x.reshape(m // 8, 8, n // 8, 8)
    bmax = jnp.max(jnp.abs(blocks), axis=(1, 3), keepdims=True)
    scale = _pow2(shared_exponent(bmax, fmt))
    q = quant_element(blocks / scale, fmt) * scale
    return q.reshape(m, n)


def fake_quant_vector(x, fmt: str, block: int = 32):
    """Fake-quantize [m, n] through `block`-wide row-vector groups."""
    m, n = x.shape
    assert n % block == 0, (n, block)
    rows = x.reshape(m, n // block, block)
    bmax = jnp.max(jnp.abs(rows), axis=2, keepdims=True)
    scale = _pow2(shared_exponent(bmax, fmt))
    q = quant_element(rows / scale, fmt) * scale
    return q.reshape(m, n)


def mx_matmul_ref(x, w, fmt: str):
    """Reference quantized GeMM: square-quantize both operands, matmul
    with f32 accumulation (what the PE array computes)."""
    return fake_quant_square(x, fmt) @ fake_quant_square(w, fmt)
