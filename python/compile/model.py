"""Layer-2 JAX model: the dynamics MLP's quantization-aware train step.

The paper's 4-layer MLP (32-256-256-256-32, ReLU, MSE on delta-states)
with MX fake-quantization at the Fig. 5 cut points:

* weights and activations quantize (through the L1 Pallas kernel) before
  every GeMM, with straight-through gradient estimation;
* backprop errors quantize on the way down via a custom-VJP hook placed
  on each layer's pre-activation (the cotangent is what the paper's E
  tensors are).

Adam runs on FP32 master weights. ``train_step``/``eval_loss`` are pure
functions over a flat state tuple so ``aot.py`` can lower them once per
format and the Rust runtime can thread the state through PJRT without
any Python at training time.

State layout (all f32):
    state = (step[1],
             w0, b0, mw0, vw0, mb0, vb0,
             ...                      (one group of 6 per layer)
             w3, b3, mw3, vw3, mb3, vb3)
train_step(state, x, y) -> (loss[1], new_state...)
eval_loss(state, x, y) -> loss[1]
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import mx_kernels, ref

DIMS = (32, 256, 256, 256, 32)
N_LAYERS = len(DIMS) - 1
GROUP = 6  # w, b, mw, vw, mb, vb per layer
STATE_LEN = 1 + GROUP * N_LAYERS

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8

SCHEMES = ("fp32", "int8", "e5m2", "e4m3", "e3m2", "e2m3", "e2m1")


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _fq(x, fmt):
    """Forward fake-quantization (Pallas kernel) with a straight-through
    gradient (custom VJP hides the pallas_call from autodiff)."""
    if fmt == "fp32":
        return x
    return mx_kernels.mx_quant_square(x, fmt)


def _fq_fwd(x, fmt):
    return _fq(x, fmt), None


def _fq_bwd(fmt, _res, g):
    return (g,)  # straight-through estimator


_fq.defvjp(_fq_fwd, _fq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _quant_cotangent(x, fmt):
    """Identity in the forward pass; quantizes the *gradient* flowing
    back through it (the paper's quantized error tensors E)."""
    return x


def _qc_fwd(x, fmt):
    return x, None


def _qc_bwd(fmt, _res, g):
    if fmt == "fp32":
        return (g,)
    # errors are (B, dout) with dout in {256, 32}: square-block quantize
    return (ref.fake_quant_square(g, fmt),)


_quant_cotangent.defvjp(_qc_fwd, _qc_bwd)


def init_params(key):
    """He-initialized parameter pytree (list of (w, b))."""
    params = []
    for i in range(N_LAYERS):
        key, sub = jax.random.split(key)
        sigma = (2.0 / DIMS[i]) ** 0.5
        w = jax.random.normal(sub, (DIMS[i], DIMS[i + 1]), jnp.float32) * sigma
        params.append((w, jnp.zeros((DIMS[i + 1],), jnp.float32)))
    return params


def init_state(key):
    """Flat state tuple for step 0."""
    state = [jnp.zeros((1,), jnp.float32)]
    for w, b in init_params(key):
        state += [w, b, jnp.zeros_like(w), jnp.zeros_like(w), jnp.zeros_like(b), jnp.zeros_like(b)]
    return tuple(state)


def forward(params, x, fmt):
    """Quantized forward pass; returns the network output."""
    a = x
    for i, (w, b) in enumerate(params):
        aq = _fq(a, fmt)
        wq = _fq(w, fmt)
        z = aq @ wq + b
        z = _quant_cotangent(z, fmt)  # quantize the backprop error here
        a = jax.nn.relu(z) if i + 1 < N_LAYERS else z
    return a


def mse(out, y):
    return jnp.mean((out - y) ** 2)


def _unpack(state):
    step = state[0]
    layers = []
    for i in range(N_LAYERS):
        g = state[1 + GROUP * i : 1 + GROUP * (i + 1)]
        layers.append(g)
    return step, layers


def train_step(state, x, y, *, fmt: str, lr: float = 1e-3):
    """One QAT train step. Returns (loss[1], *new_state)."""
    step, layers = _unpack(state)
    params = [(g[0], g[1]) for g in layers]

    def loss_fn(params):
        return mse(forward(params, x, fmt), y)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    t = step[0] + 1.0
    bc1 = 1.0 - ADAM_B1**t
    bc2 = 1.0 - ADAM_B2**t
    new_state = [step + 1.0]
    for (w, b), (gw, gb), g in zip(params, grads, layers):
        _, _, mw, vw, mb, vb = g
        mw = ADAM_B1 * mw + (1 - ADAM_B1) * gw
        vw = ADAM_B2 * vw + (1 - ADAM_B2) * gw * gw
        mb = ADAM_B1 * mb + (1 - ADAM_B1) * gb
        vb = ADAM_B2 * vb + (1 - ADAM_B2) * gb * gb
        w = w - lr * (mw / bc1) / (jnp.sqrt(vw / bc2) + ADAM_EPS)
        b = b - lr * (mb / bc1) / (jnp.sqrt(vb / bc2) + ADAM_EPS)
        new_state += [w, b, mw, vw, mb, vb]
    return (jnp.reshape(loss, (1,)), *new_state)


def eval_loss(state, x, y, *, fmt: str):
    """Quantized validation loss. Returns loss[1]."""
    _, layers = _unpack(state)
    params = [(g[0], g[1]) for g in layers]
    return (jnp.reshape(mse(forward(params, x, fmt), y), (1,)),)
