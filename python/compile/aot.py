"""AOT compilation: lower the L2 train/eval graphs to HLO text artifacts.

Emits, for every scheme in ``model.SCHEMES``::

    artifacts/train_step_<scheme>_b<B>.hlo.txt
    artifacts/eval_<scheme>_b<B>.hlo.txt

plus ``artifacts/manifest.txt`` describing shapes and the state layout
for the Rust runtime (a simple ``key value`` line format — no JSON
dependency on the Rust side).

HLO **text** is the interchange format, not serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids that the runtime's
xla_extension 0.5.1 rejects; the text parser reassigns ids cleanly (see
/opt/xla-example/README.md).

Python runs only here, at build time (`make artifacts`); the emitted
artifacts are all the Rust binary needs.
"""

import argparse
import functools
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def state_specs():
    key = jax.random.PRNGKey(0)
    return tuple(
        jax.ShapeDtypeStruct(s.shape, s.dtype) for s in model.init_state(key)
    )


def lower_train(fmt: str, batch: int, lr: float):
    fn = functools.partial(model.train_step, fmt=fmt, lr=lr)
    x = jax.ShapeDtypeStruct((batch, model.DIMS[0]), jnp.float32)
    y = jax.ShapeDtypeStruct((batch, model.DIMS[-1]), jnp.float32)
    return jax.jit(lambda s, xx, yy: fn(s, xx, yy)).lower(state_specs(), x, y)


def lower_eval(fmt: str, batch: int):
    fn = functools.partial(model.eval_loss, fmt=fmt)
    x = jax.ShapeDtypeStruct((batch, model.DIMS[0]), jnp.float32)
    y = jax.ShapeDtypeStruct((batch, model.DIMS[-1]), jnp.float32)
    # keep_unused: the eval graph ignores the Adam moments, but the Rust
    # runtime passes the full state tuple — keep the parameters in place
    return jax.jit(lambda s, xx, yy: fn(s, xx, yy), keep_unused=True).lower(
        state_specs(), x, y
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--eval-batch", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schemes", nargs="*", default=list(model.SCHEMES))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = [
        f"dims {' '.join(str(d) for d in model.DIMS)}",
        f"batch {args.batch}",
        f"eval_batch {args.eval_batch}",
        f"lr {args.lr}",
        f"state_len {model.STATE_LEN}",
        "state_layout step then per-layer w,b,mw,vw,mb,vb",
        "train_io inputs=state,x,y outputs=loss,state",
        "eval_io inputs=state,x,y outputs=loss",
    ]
    for fmt in args.schemes:
        t = to_hlo_text(lower_train(fmt, args.batch, args.lr))
        path = os.path.join(args.out_dir, f"train_step_{fmt}_b{args.batch}.hlo.txt")
        with open(path, "w") as f:
            f.write(t)
        e = to_hlo_text(lower_eval(fmt, args.eval_batch))
        epath = os.path.join(args.out_dir, f"eval_{fmt}_b{args.eval_batch}.hlo.txt")
        with open(epath, "w") as f:
            f.write(e)
        manifest.append(f"train {fmt} {os.path.basename(path)}")
        manifest.append(f"eval {fmt} {os.path.basename(epath)}")
        print(f"{fmt}: {len(t)} + {len(e)} chars")
    with open(os.path.join(args.out_dir, "manifest.txt"), "w") as f:
        f.write("\n".join(manifest) + "\n")
    print(f"wrote {2 * len(args.schemes)} artifacts to {args.out_dir}")


if __name__ == "__main__":
    main()
