//! The headline serving run: 10,000 short-lived tenants arriving on an
//! open stream, admitted/parked/shed by the budget-aware policy, and
//! executed by the work-stealing quantum executor against the real
//! trainer/backends/store stack.
//!
//! Arrival pacing is closed-loop (clients wait for a slot) with every
//! 7th arrival bursting through unpaced, so admission control sees
//! genuine overload pressure. A deterministic sample of completed
//! sessions is re-run standalone — their loss curves must be bitwise
//! identical to the served run despite stealing, parking, and
//! checkpoint-on-evict (the serve layer's core contract, DESIGN.md
//! §12). Writes `results/BENCH_serve.json` and exits nonzero if any
//! session is lost, duplicated, or diverges from its twin.
//!
//! ```bash
//! cargo run --release --example serve_load
//! ```

use mxscale::coordinator::report::save_json;
use mxscale::fleet::StoreSpec;
use mxscale::serve::load::{bench_json, run_load, LoadSpec};
use mxscale::store::StoreLayout;

fn main() {
    let root = std::env::temp_dir().join(format!("mxscale-serve-load-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let spec = LoadSpec {
        // 10k sessions, short leases: most sessions round-trip through
        // the sharded checkpoint store mid-run and resume bit-exactly
        lease_quanta: 2,
        store: Some(StoreSpec {
            dir: root.clone(),
            layout: StoreLayout::Sharded { shards: 8 },
        }),
        ..Default::default()
    };
    println!(
        "serve_load: {} sessions x {} steps, capacity {} (parking {}), quantum {}, \
         lease {} quanta, schemes {:?}\n",
        spec.sessions,
        spec.steps,
        spec.capacity,
        spec.max_parked,
        spec.quantum,
        spec.lease_quanta,
        spec.schemes.iter().map(|s| s.name()).collect::<Vec<_>>(),
    );

    let out = run_load(&spec).expect("synthetic load spec is valid");
    let s = &out.stats;
    println!(
        "offered {} | admitted {} (+{} re-admissions) | completed {} | shed {} | \
         refused {} | failed {} | evicted {}",
        s.offered, s.admitted, s.re_admitted, s.completed, s.shed_overloaded, s.refused,
        s.failed, s.evicted
    );
    println!(
        "latency p50 {:.3} ms/step, p99 {:.3} ms/step ({} samples) | {:.0} steps/s | \
         {} steals | parked peak {}",
        s.p50_step_ms,
        s.p99_step_ms,
        s.latency_samples,
        s.steps_per_sec(),
        s.steals,
        s.parked_peak
    );
    println!(
        "accounting: {} lost, {} duplicated | twins {}/{} matched",
        out.lost,
        out.duplicated,
        out.twins_checked - out.twin_mismatches,
        out.twins_checked
    );
    for line in &out.shed_sample {
        println!("  shed: {line}");
    }

    match save_json(&bench_json(&spec, &out), "BENCH_serve") {
        Ok(p) => println!("\n[saved {}]", p.display()),
        Err(e) => println!("\n[json save failed: {e}]"),
    }
    let _ = std::fs::remove_dir_all(&root);
    if out.lost > 0 || out.duplicated > 0 || out.twin_mismatches > 0 {
        eprintln!(
            "serve_load: accounting violated (lost {}, duplicated {}, twin mismatches {})",
            out.lost, out.duplicated, out.twin_mismatches
        );
        std::process::exit(1);
    }
}
