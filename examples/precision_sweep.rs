//! Precision sweep: train one workload under every MX format (and FP32)
//! on the native golden path, reporting the accuracy/energy tradeoff —
//! the per-workload slice of the paper's Fig. 2 finding that different
//! robotics tasks prefer different MX precisions.
//!
//! The seven runs are independent, so they execute concurrently through
//! the batched engine (one worker per core; results are bit-identical
//! to running them one after another). Set `RAYON_NUM_THREADS=1` to
//! force the serial schedule.
//!
//! ```bash
//! cargo run --release --example precision_sweep -- [workload] [steps]
//! ```

use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::trainer::batched::sweep_schemes;
use mxscale::trainer::budget::step_cost;
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::TrainConfig;
use mxscale::util::par;
use mxscale::workloads::{by_name, Dataset};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let workload = args.first().map(|s| s.as_str()).unwrap_or("reacher").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let env = by_name(&workload).unwrap_or_else(|| {
        eprintln!("unknown workload {workload}; using reacher");
        by_name("reacher").unwrap()
    });
    let ds = Dataset::collect(env.as_ref(), 30, 100, 0x5EEE);
    println!(
        "precision sweep on {workload} ({steps} steps, batch 32, {} worker threads):\n",
        par::threads()
    );
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>14}",
        "scheme", "val loss", "us/step", "uJ/step", "uJ to finish"
    );
    let schemes: Vec<QuantScheme> = std::iter::once(QuantScheme::Fp32)
        .chain(ALL_ELEMENT_FORMATS.into_iter().map(QuantScheme::MxSquare))
        .collect();
    let base = TrainConfig { steps, eval_every: steps, ..Default::default() };
    let t0 = std::time::Instant::now();
    let outcomes = sweep_schemes(&ds, &schemes, &base);
    let wall = t0.elapsed();
    let mut best = (String::new(), f64::INFINITY);
    for (scheme, o) in schemes.iter().zip(&outcomes) {
        let v = o.session.val_loss();
        let cost = step_cost(*scheme, 32);
        println!(
            "{:<10} {:>12.5} {:>12.2} {:>12.2} {:>14.1}",
            o.label,
            v,
            cost.micros,
            cost.microjoules,
            cost.microjoules * steps as f64
        );
        if *scheme != QuantScheme::Fp32 && v < best.1 {
            best = (o.label.clone(), v);
        }
    }
    println!("\nbest MX format for {workload}: {} (val {:.5})", best.0, best.1);
    println!(
        "sweep wall-clock: {:.2} s for {} runs (batched across cores)",
        wall.as_secs_f64(),
        schemes.len()
    );
}
