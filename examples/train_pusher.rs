//! End-to-end driver (DESIGN.md mandate): train the pusher dynamics MLP
//! through the FULL three-layer stack — Pallas-kernel-bearing JAX graphs
//! AOT-compiled to HLO (build time), loaded and executed by the Rust
//! coordinator over PJRT, fed by the Rust physics simulator — while the
//! simulated GeMM core accounts per-step latency and energy. No Python
//! runs during this program.
//!
//! Needs `make artifacts` plus a build with the `xla` feature (see
//! README.md); otherwise it prints what is missing and exits cleanly.
//!
//! ```bash
//! make artifacts && cargo run --release --example train_pusher -- [scheme] [steps]
//! ```

use mxscale::energy::EnergyModel;
use mxscale::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use mxscale::mx::element::ElementFormat;
use mxscale::runtime::{artifact_dir, EvalExecutable, Manifest, TrainExecutable};
use mxscale::util::mat::Mat;
use mxscale::workloads::{by_name, Dataset};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let scheme = args.first().map(|s| s.as_str()).unwrap_or("e4m3").to_string();
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(300);

    let dir = artifact_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("{e}\nrun `make artifacts` first (artifacts dir: {})", dir.display());
            return;
        }
    };
    let Some(train_path) = manifest.train_path(&dir, &scheme) else {
        eprintln!("no train artifact for scheme {scheme}");
        return;
    };
    let Some(eval_path) = manifest.eval_path(&dir, &scheme) else {
        eprintln!("no eval artifact for scheme {scheme}");
        return;
    };

    println!("[1/4] collecting pusher dynamics data from the physics simulator...");
    let env = by_name("pusher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 30, 100, 0xE2E);
    println!("      {} train / {} val transitions", ds.len(), ds.val_x.rows);

    println!("[2/4] compiling AOT artifacts on the PJRT CPU client...");
    let client = match mxscale::runtime::executor::cpu_client() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("      skipped: {e}");
            return;
        }
    };
    let mut train = match TrainExecutable::load(&client, &train_path, 0x5EED) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("      train artifact load failed: {e}");
            std::process::exit(1);
        }
    };
    let eval = match EvalExecutable::load(&client, &eval_path) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("      eval artifact load failed: {e}");
            std::process::exit(1);
        }
    };
    println!("      scheme={scheme} state tensors={}", train.state.len());

    // hardware cost model for this scheme (per batch-32 step)
    let hw = ElementFormat::parse(&scheme).map(|fmt| {
        let c = train_step_cycles(manifest.batch, &PUSHER_DIMS, fmt);
        let m = EnergyModel::proposed();
        (c.micros(500.0), m.core_run_pj(fmt, c.mul_ops) * 1e-6, c.utilization(fmt.mac_mode()))
    });

    println!("[3/4] training {steps} steps (batch {})...", manifest.batch);
    let eval_batch = |ds: &Dataset, n: usize| -> (Mat, Mat) {
        let rows = ds.val_x.rows.min(n);
        (ds.val_x.block(0, 0, rows, 32), ds.val_y.block(0, 0, rows, 32))
    };
    let (vx, vy) = eval_batch(&ds, manifest.eval_batch);
    let t0 = std::time::Instant::now();
    let mut last_loss = f32::NAN;
    for step in 0..steps {
        let batch = ds.batch(step, manifest.batch);
        last_loss = match train.step(&batch.x, &batch.y) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("      step {step} failed: {e}");
                std::process::exit(1);
            }
        };
        if step % 50 == 0 || step + 1 == steps {
            match eval.loss(&train.state, &vx, &vy) {
                Ok(val) => println!("      step {step:>4}  train {last_loss:.5}  val {val:.5}"),
                Err(e) => {
                    eprintln!("      eval failed: {e}");
                    std::process::exit(1);
                }
            }
        }
    }
    let wall = t0.elapsed();

    println!("[4/4] results");
    let val = eval.loss(&train.state, &vx, &vy).unwrap_or(f32::NAN);
    println!("      final val loss: {val:.5} (train {last_loss:.5})");
    println!(
        "      host wall-clock: {:.2} s ({:.2} ms/step on this CPU)",
        wall.as_secs_f64(),
        wall.as_secs_f64() * 1e3 / steps as f64
    );
    if let Some((us, uj, util)) = hw {
        println!(
            "      simulated accelerator: {us:.2} us/step, {uj:.2} uJ/step, {:.0}% MAC utilization",
            util * 100.0
        );
        println!(
            "      {steps} steps would take {:.2} ms and {:.2} mJ on the 16nm core",
            us * steps as f64 / 1e3,
            uj * steps as f64 / 1e3
        );
    }
}
