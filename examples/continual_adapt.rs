//! Continual learning at the edge — the paper's motivating scenario
//! (§I): a robot's environment changes mid-deployment and the on-device
//! learner must adapt without cloud access.
//!
//! We train the pusher dynamics model, then *change the physics* (object
//! mass + friction: the robot picks up a heavier object on rougher
//! ground), and continue training on the new dynamics. The example
//! reports how quickly each precision scheme recovers, and what the
//! adaptation costs on the simulated accelerator vs Dacapo.
//!
//! ```bash
//! cargo run --release --example continual_adapt
//! ```

use mxscale::mx::dacapo::DacapoFormat;
use mxscale::mx::element::ElementFormat;
use mxscale::trainer::budget::step_cost;
use mxscale::trainer::qat::{qat_eval, qat_step, QuantScheme};
use mxscale::trainer::mlp::{Mlp, MLP_DIMS};
use mxscale::util::rng::Pcg64;
use mxscale::workloads::pusher::Pusher;
use mxscale::workloads::Dataset;

fn main() {
    // phase A: nominal dynamics; phase B: heavier object, more friction
    let env_a = Pusher::default();
    let mut env_b = Pusher::default();
    env_b.obj_mass *= 2.5;
    env_b.friction *= 1.8;

    let ds_a = Dataset::collect(&env_a, 24, 80, 0xADA);
    let ds_b = Dataset::collect(&env_b, 24, 80, 0xADB);

    println!("continual adaptation on pusher: nominal -> heavy-object dynamics\n");
    println!(
        "{:<10} {:>10} {:>12} {:>12} {:>12} {:>14}",
        "scheme", "val A", "val B (pre)", "val B (post)", "adapt gain", "adapt cost"
    );
    for scheme in [
        QuantScheme::Fp32,
        QuantScheme::MxSquare(ElementFormat::Int8),
        QuantScheme::MxSquare(ElementFormat::E4M3),
        QuantScheme::Dacapo(DacapoFormat::Mx9),
    ] {
        let mut rng = Pcg64::new(0xC0117);
        let mut mlp = Mlp::new(&MLP_DIMS, &mut rng);
        // phase A: 250 steps on nominal dynamics
        for i in 0..250 {
            let b = ds_a.batch(i, 32);
            qat_step(&mut mlp, &b.x, &b.y, scheme, 1e-3);
        }
        let val_a = qat_eval(&mlp, &ds_a.val_x, &ds_a.val_y, scheme);
        // environment shift
        let val_b_pre = qat_eval(&mlp, &ds_b.val_x, &ds_b.val_y, scheme);
        // phase B: 150 adaptation steps on the new dynamics
        let adapt_steps = 150;
        for i in 0..adapt_steps {
            let b = ds_b.batch(i, 32);
            qat_step(&mut mlp, &b.x, &b.y, scheme, 1e-3);
        }
        let val_b_post = qat_eval(&mlp, &ds_b.val_x, &ds_b.val_y, scheme);
        let improvement = val_b_pre / val_b_post.max(1e-12);
        let cost = step_cost(scheme, 32);
        println!(
            "{:<10} {:>10.4} {:>12.4} {:>12.4} {:>11.1}x {:>10.2} ms / {:>5.2} mJ",
            scheme.name(),
            val_a,
            val_b_pre,
            val_b_post,
            improvement,
            cost.micros * adapt_steps as f64 / 1e3,
            cost.microjoules * adapt_steps as f64 / 1e3,
        );
    }
    println!("\n(adapt cost = {} steps on the respective simulated accelerator)", 150);
}
