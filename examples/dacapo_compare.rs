//! Ours vs Dacapo, side by side: the paper's headline comparison as a
//! runnable program — iso-peak-throughput latency, energy, and memory
//! for the pusher training loop, plus budgeted-training outcomes.
//!
//! ```bash
//! cargo run --release --example dacapo_compare
//! # pick the contender scheme and run it on the bit-exact hardware model:
//! cargo run --release --example dacapo_compare -- --scheme int8 --backend hw
//! ```
//!
//! `--scheme` takes any square MX format (`int8` ... `e2m1`; vector
//! schemes like `mxvec-int8` work on the fast backend); `--backend hw`
//! additionally runs a short measured session through the GemmCore
//! simulation and prints its cost report next to the analytic numbers;
//! `--backend packed` races the sub-word SWAR kernels against the
//! fake-quant path on identical sessions (bit-identical losses) and
//! saves the measured speedup to results/dacapo_packed_speedup.json.

use mxscale::backend::BackendKind;
use mxscale::coordinator::cli::Args;
use mxscale::energy::{calib, EnergyModel};
use mxscale::gemmcore::memory::{footprint_dacapo, footprint_ours, MlpShape};
use mxscale::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use mxscale::mx::dacapo::DacapoFormat;
use mxscale::mx::element::ElementFormat;
use mxscale::pearray::SystolicArray;
use mxscale::trainer::budget::{train_with_budget, Budget};
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::TrainConfig;
use mxscale::workloads::{by_name, Dataset};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = Args::parse(&argv);
    let scheme = match args.get("scheme") {
        Some(s) => QuantScheme::parse(s).unwrap_or_else(|| {
            eprintln!("unknown scheme: {s}");
            std::process::exit(1);
        }),
        None => QuantScheme::MxSquare(ElementFormat::E4M3),
    };
    // the contender must be an MX element scheme — fp32 and the Dacapo
    // formats are the fixed baselines of this comparison
    if scheme.element().is_none() {
        eprintln!(
            "--scheme must be an MX element scheme (int8 ... e2m1, mx-<fmt>, mxvec-<fmt>); \
             got `{}`, which is one of the comparison baselines",
            scheme.name()
        );
        std::process::exit(1);
    }
    let backend = match args.get("backend") {
        Some(b) => BackendKind::parse(b).unwrap_or_else(|| {
            eprintln!("unknown backend: {b} (use fast|hw|packed)");
            std::process::exit(1);
        }),
        None => BackendKind::Fast,
    };

    let shape = MlpShape::pusher();
    let model = EnergyModel::proposed();
    let arr = SystolicArray::dacapo();

    println!("ours (4x16 square-block GeMM core) vs Dacapo (64x64 systolic), 4096 MACs @500MHz\n");
    println!("  area: {:.2} vs {:.2} mm2 ({:.1}% reduction)",
        calib::CORE_AREA_MM2, calib::DACAPO_AREA_MM2,
        100.0 * (1.0 - calib::CORE_AREA_MM2 / calib::DACAPO_AREA_MM2));
    let ours_mem = footprint_ours(&shape, 32, ElementFormat::Int8).total();
    let dac_mem = footprint_dacapo(&shape, 32, DacapoFormat::Mx9).total();
    println!("  memory: {ours_mem:.1} vs {dac_mem:.1} KB ({:.0}% reduction)",
        100.0 * (1.0 - ours_mem / dac_mem));

    println!("\n  pusher train step (batch 32):");
    println!("  {:<24} {:>10} {:>10} {:>9}", "mode pair", "ours [us]", "dacapo", "speedup");
    for (fmt, dfmt) in [
        (ElementFormat::Int8, DacapoFormat::Mx9),
        (ElementFormat::E4M3, DacapoFormat::Mx6),
        (ElementFormat::E2M1, DacapoFormat::Mx4),
    ] {
        let ours = train_step_cycles(32, &PUSHER_DIMS, fmt).micros(500.0);
        let dac = arr.train_step_cycles(32, &PUSHER_DIMS, dfmt).micros(500.0);
        println!(
            "  {:<24} {:>10.2} {:>10.2} {:>8.1}x",
            format!("{} vs {}", fmt.name(), dfmt.name()),
            ours,
            dac,
            dac / ours
        );
        let e_ours = model.core_pj_per_op(fmt);
        let e_dac = calib::dacapo_pj_per_op(dfmt);
        println!(
            "  {:<24} {:>10.2} {:>10.2} {:>8.2}x   (pJ/OP)",
            "", e_ours, e_dac, e_ours / e_dac
        );
    }

    println!("\n  1000 us budget on pusher (who learns more?):");
    let env = by_name("pusher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 20, 80, 0xC0);
    for contender in [scheme, QuantScheme::Dacapo(DacapoFormat::Mx6)] {
        let curve = train_with_budget(
            ds.clone(),
            contender,
            Budget::TimeMicros(1000.0),
            4,
            TrainConfig { eval_every: usize::MAX, ..Default::default() },
        );
        let last = curve.last().unwrap();
        println!(
            "    {:<12} {:>4} steps -> val loss {:.5}",
            contender.name(),
            last.steps,
            last.val_loss
        );
    }

    if backend == BackendKind::Packed {
        use mxscale::coordinator::experiments::race_fast_vs_packed;
        use mxscale::coordinator::report::{bench_doc, save_json};
        println!("\n  measured software execution ({}, 12 steps, batch 32):", scheme.name());
        let race = race_fast_vs_packed(&ds, scheme, 12).unwrap_or_else(|e| {
            eprintln!("    {e}");
            std::process::exit(1);
        });
        println!(
            "    fast {:.3} ms/step | packed {:.3} ms/step | speedup {:.2}x | losses bit-identical: {}",
            race.fast_ms_step(),
            race.packed_ms_step(),
            race.speedup(),
            race.loss_bit_identical,
        );
        let doc = bench_doc("dacapo_packed_speedup").set(scheme.name().as_str(), race.to_json());
        match save_json(&doc, "dacapo_packed_speedup") {
            Ok(p) => println!("    [saved {}]", p.display()),
            Err(e) => println!("    [json save failed: {e}]"),
        }
    }

    if backend == BackendKind::Hardware {
        println!("\n  measured on the bit-exact GemmCore ({} @ 2 training steps):", scheme.name());
        let session = mxscale::trainer::session::TrainSession::try_new(
            ds,
            TrainConfig {
                scheme,
                backend,
                steps: 2,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        match session {
            Ok(mut s) => {
                s.run();
                let r = s.hw_report().expect("hw backend reports cost");
                println!(
                    "    {:.2} us/step ({:.0} steps/s) | {:.2} uJ/step | {:.1} KiB/step traffic | \
                     {:.1} KB resident | datapath dev {:.2e}",
                    r.us_per_step(),
                    r.steps_per_sec(),
                    r.uj_per_step(),
                    r.traffic_kib_per_step(),
                    r.resident_kb,
                    r.datapath_max_rel_err,
                );
            }
            Err(e) => println!("    (skipped: {e})"),
        }
    }
}
