//! Ours vs Dacapo, side by side: the paper's headline comparison as a
//! runnable program — iso-peak-throughput latency, energy, and memory
//! for the pusher training loop, plus budgeted-training outcomes.
//!
//! ```bash
//! cargo run --release --example dacapo_compare
//! ```

use mxscale::energy::{calib, EnergyModel};
use mxscale::gemmcore::memory::{footprint_dacapo, footprint_ours, MlpShape};
use mxscale::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use mxscale::mx::dacapo::DacapoFormat;
use mxscale::mx::element::ElementFormat;
use mxscale::pearray::SystolicArray;
use mxscale::trainer::budget::{train_with_budget, Budget};
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::TrainConfig;
use mxscale::workloads::{by_name, Dataset};

fn main() {
    let shape = MlpShape::pusher();
    let model = EnergyModel::proposed();
    let arr = SystolicArray::dacapo();

    println!("ours (4x16 square-block GeMM core) vs Dacapo (64x64 systolic), 4096 MACs @500MHz\n");
    println!("  area: {:.2} vs {:.2} mm2 ({:.1}% reduction)",
        calib::CORE_AREA_MM2, calib::DACAPO_AREA_MM2,
        100.0 * (1.0 - calib::CORE_AREA_MM2 / calib::DACAPO_AREA_MM2));
    let ours_mem = footprint_ours(&shape, 32, ElementFormat::Int8).total();
    let dac_mem = footprint_dacapo(&shape, 32, DacapoFormat::Mx9).total();
    println!("  memory: {ours_mem:.1} vs {dac_mem:.1} KB ({:.0}% reduction)",
        100.0 * (1.0 - ours_mem / dac_mem));

    println!("\n  pusher train step (batch 32):");
    println!("  {:<24} {:>10} {:>10} {:>9}", "mode pair", "ours [us]", "dacapo", "speedup");
    for (fmt, dfmt) in [
        (ElementFormat::Int8, DacapoFormat::Mx9),
        (ElementFormat::E4M3, DacapoFormat::Mx6),
        (ElementFormat::E2M1, DacapoFormat::Mx4),
    ] {
        let ours = train_step_cycles(32, &PUSHER_DIMS, fmt).micros(500.0);
        let dac = arr.train_step_cycles(32, &PUSHER_DIMS, dfmt).micros(500.0);
        println!(
            "  {:<24} {:>10.2} {:>10.2} {:>8.1}x",
            format!("{} vs {}", fmt.name(), dfmt.name()),
            ours,
            dac,
            dac / ours
        );
        let e_ours = model.core_pj_per_op(fmt);
        let e_dac = calib::dacapo_pj_per_op(dfmt);
        println!(
            "  {:<24} {:>10.2} {:>10.2} {:>8.2}x   (pJ/OP)",
            "", e_ours, e_dac, e_ours / e_dac
        );
    }

    println!("\n  1000 us budget on pusher (who learns more?):");
    let env = by_name("pusher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 20, 80, 0xC0);
    for scheme in [
        QuantScheme::MxSquare(ElementFormat::E4M3),
        QuantScheme::Dacapo(DacapoFormat::Mx6),
    ] {
        let curve = train_with_budget(
            ds.clone(),
            scheme,
            Budget::TimeMicros(1000.0),
            4,
            TrainConfig { eval_every: usize::MAX, ..Default::default() },
        );
        let last = curve.last().unwrap();
        println!(
            "    {:<12} {:>4} steps -> val loss {:.5}",
            scheme.name(),
            last.steps,
            last.val_loss
        );
    }
}
