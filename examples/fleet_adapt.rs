//! Fleet-scale continual learning: many robots, one host, domain shifts.
//!
//! Eight concurrent training sessions (4 workloads x 2 MX schemes)
//! round-robin over the worker pool; halfway through, every robot's
//! environment shifts (heavier object, longer arm, stiffer joints...).
//! Each session checkpoints — MX-natively, square shared-exponent groups
//! stored single-copy — and resumes from the checkpoint on the new
//! dynamics. The run ends with the head-to-head the paper's continual
//! premise implies: adapting from the checkpoint vs retraining from
//! scratch on the shifted data, plus the fleet's effective throughput
//! and the square-vs-vector checkpoint footprint.
//!
//! ```bash
//! cargo run --release --example fleet_adapt
//! ```

use mxscale::coordinator::report::save_json;
use mxscale::fleet::{run_fleet, FleetSpec};

fn main() {
    let spec = FleetSpec::default();
    println!(
        "fleet_adapt: {} sessions, shift at step {}/{}, schemes {:?}\n",
        spec.sessions,
        spec.shift_at,
        spec.steps,
        spec.schemes.iter().map(|s| s.name()).collect::<Vec<_>>(),
    );
    let run = run_fleet(&spec).expect("default fleet spec is valid");

    println!(
        "{:<10} {:<12} {:<8} {:>6} {:>11} {:>8} {:>10}",
        "robot", "workload", "scheme", "steps", "energy[uJ]", "ckpt[B]", "final val"
    );
    for s in &run.sessions {
        println!(
            "{:<10} {:<12} {:<8} {:>6} {:>11.1} {:>8} {:>10.4}",
            s.id, s.workload, s.scheme, s.steps, s.energy_uj, s.payload_bytes, s.final_val
        );
    }
    println!(
        "\neffective throughput: {} steps / {:.2}s = {:.0} steps/s across the fleet",
        run.stats.total_steps,
        run.stats.wall_s,
        run.stats.steps_per_sec()
    );

    if let Some(a) = &run.adapt {
        println!(
            "\nadaptation vs retrain on {} ({}), {} steps after the shift:",
            a.workload, a.scheme, a.steps
        );
        println!("{:>8} {:>14} {:>14}", "step", "adapt", "scratch");
        for (&(s, av), &(_, sv)) in a.adapt_curve.iter().zip(&a.scratch_curve) {
            println!("{s:>8} {av:>14.5} {sv:>14.5}");
        }
        match a.adapt_steps_to_target {
            Some(s) => println!(
                "-> checkpoint adaptation matched the scratch final loss ({:.5}) at step {s} \
                 of {} ({})",
                a.target_loss,
                a.steps,
                if a.adapt_beats_scratch { "adaptation wins" } else { "tie" },
            ),
            None => println!("-> adaptation never reached the scratch loss (unexpected)"),
        }
    }

    match save_json(&run.report, "fleet_report") {
        Ok(p) => println!("\n[saved {}]", p.display()),
        Err(e) => println!("\n[json save failed: {e}]"),
    }
}
