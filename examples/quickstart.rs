//! Quickstart: quantize a matrix into MX formats, run a GeMM through the
//! bit-exact PE-array simulator, and inspect accuracy/cost tradeoffs.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use mxscale::arith::MacVariant;
use mxscale::energy::EnergyModel;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{Layout, MxTensor};
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::pearray::PeArray;
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;

fn main() {
    let mut rng = Pcg64::new(42);
    let a = Mat::randn(32, 64, 1.0, &mut rng);
    let b = Mat::randn(64, 32, 1.0, &mut rng);
    let exact = a.matmul(&b);
    let model = EnergyModel::proposed();

    println!("GeMM 32x64x32 through the square-block PE array, all six MX formats:\n");
    println!(
        "{:<14} {:>10} {:>12} {:>12} {:>12} {:>12}",
        "format", "bits/elem", "rel-rms-err", "cycles", "pJ (model)", "pJ/OP"
    );
    for fmt in ALL_ELEMENT_FORMATS {
        let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let out = pe.gemm(&a, &b);
        let err = out.mse(&exact).sqrt() / (exact.fro_norm() as f64 / (exact.data.len() as f64).sqrt());
        let ev = pe.events();
        let pj = model.run_pj(fmt, &ev);
        println!(
            "{:<14} {:>10.3} {:>12.5} {:>12} {:>12.1} {:>12.3}",
            fmt.display(),
            mxscale::mx::MxFormat::square(fmt).bits_per_element(),
            err,
            pe.cycles,
            pj,
            pj / ev.mul_ops as f64,
        );
    }

    // The storage trick: a quantized weight and its transpose share bits.
    println!("\nSquare-block transpose reuse (the paper's storage contribution):");
    let w = Mat::randn(16, 16, 1.0, &mut rng);
    let qw = MxTensor::quantize(&w, ElementFormat::Int8, Layout::Square8x8);
    let qwt = qw.transpose().unwrap();
    let roundtrip = qwt.dequantize().transpose();
    assert_eq!(roundtrip.data, qw.dequantize().data);
    println!(
        "  quantize(W) stores {:.2} KiB; transpose needs 0 extra bytes (bit-identical: {})",
        qw.storage_kib(),
        roundtrip.data == qw.dequantize().data
    );
    let qv = MxTensor::quantize(&w, ElementFormat::Int8, Layout::Vector32);
    println!(
        "  vector-grouped layout would store {:.2} KiB twice (W and Wt) = {:.2} KiB",
        qv.storage_kib(),
        2.0 * qv.storage_kib()
    );
}
