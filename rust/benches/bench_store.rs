//! Bench: the sharded checkpoint store vs 1000 monolithic `.mxckpt`
//! files — the fleet-persistence trade the store layer exists to win.
//! Hand-rolled harness (criterion unavailable offline; run with
//! `cargo bench --bench bench_store`).
//!
//! A 1000-robot fleet is persisted twice:
//!
//! * **monolithic** — one `.mxckpt` object per robot (the pre-store
//!   layout): 1000 files, and a resume reads one whole file;
//! * **sharded** — `CheckpointStore` with the default 8 shards: a
//!   handful of files, and a resume reads the shard trailer + live
//!   index + that robot's chunks, metered through `CountingStore`
//!   (measured, not assumed).
//!
//! Writes `results/BENCH_store.json` (schema-versioned, git-SHA
//! stamped) with `files_per_1k_robots` and `bytes_read_per_resume` for
//! both layouts plus `partial_read_advantage` — the fraction of the
//! store a single resume does *not* have to read — which the CI
//! bench-gate holds to ≥ 5x (and the file count to ≤ 8).

use std::sync::Arc;
use std::time::Instant;

use mxscale::backend::BackendKind;
use mxscale::coordinator::report::{bench_doc, save_json};
use mxscale::mx::ElementFormat;
use mxscale::store::{CheckpointStore, CountingStore, FilesystemStore, Storage, StoreLayout};
use mxscale::trainer::checkpoint::{weight_payload, Checkpoint};
use mxscale::trainer::mlp::Mlp;
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::TrainConfig;
use mxscale::util::json::Json;
use mxscale::util::rng::Pcg64;

const ROBOTS: u64 = 1000;
const SAMPLE_RESUMES: usize = 50;

fn robot_id(i: u64) -> String {
    format!("robot-{i:04}")
}

/// One robot's checkpoint: a reacher-class MLP with an MX weight image
/// (the shape the fleet scheduler actually persists), no training loop.
fn robot_checkpoint(i: u64) -> Checkpoint {
    let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
    let mut rng = Pcg64::new(0x57011E ^ i);
    let dims = vec![32usize, 16, 32];
    let mlp = Mlp::new(&dims, &mut rng);
    let config = TrainConfig {
        scheme,
        backend: BackendKind::parse("fast").expect("fast backend"),
        dims: Some(dims),
        batch_size: 8,
        lr: 1e-3,
        steps: 100,
        eval_every: 10,
        seed: i,
    };
    Checkpoint {
        config,
        step: 40 + (i as usize % 13),
        adam_step: 40 + (i % 13),
        train_curve: vec![(0, 1.5), (20, 0.8), (40, 0.4)],
        val_curve: vec![(0, 1.6), (40, 0.5)],
        params: mlp.flat_params(),
        opt: mlp.flat_opt_state(),
        scheme_log: vec![(0, scheme.name())],
        payload: weight_payload(&mlp.weights, scheme),
    }
}

fn main() {
    let root = std::env::temp_dir().join(format!("mxscale-bench-store-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let fleet: Vec<(String, Checkpoint)> =
        (0..ROBOTS).map(|i| (robot_id(i), robot_checkpoint(i))).collect();
    println!("persisting a {ROBOTS}-robot fleet, monolithic vs sharded ({})\n", root.display());

    // ------------------------------------------------ monolithic layout
    let mono = FilesystemStore::open(&root.join("mono")).expect("open mono store");
    let t = Instant::now();
    for (id, ck) in &fleet {
        mono.put(&format!("{id}.mxckpt"), &ck.to_bytes()).expect("monolithic put");
    }
    let mono_save_ms = t.elapsed().as_secs_f64() * 1e3;
    let mono_files = mono.list("").expect("list mono").len();
    let mono_total: u64 =
        fleet.iter().map(|(id, _)| mono.size(&format!("{id}.mxckpt")).expect("size")).sum();

    let t = Instant::now();
    let mut mono_read: u64 = 0;
    for k in 0..SAMPLE_RESUMES {
        let id = robot_id((k as u64 * 97) % ROBOTS);
        let bytes = mono.get(&format!("{id}.mxckpt")).expect("monolithic get");
        mono_read += bytes.len() as u64;
        std::hint::black_box(Checkpoint::from_bytes(&bytes).expect("monolithic parse"));
    }
    let mono_resume_ms = t.elapsed().as_secs_f64() * 1e3 / SAMPLE_RESUMES as f64;
    let mono_bytes_per_resume = mono_read / SAMPLE_RESUMES as u64;

    // --------------------------------------------------- sharded layout
    let counting = Arc::new(CountingStore::new(Arc::new(
        FilesystemStore::open(&root.join("sharded")).expect("open sharded store"),
    )));
    let cs = CheckpointStore::new(counting.clone(), StoreLayout::Sharded { shards: 8 });
    let refs: Vec<(String, &Checkpoint)> = fleet.iter().map(|(id, ck)| (id.clone(), ck)).collect();
    let t = Instant::now();
    cs.save_many(&refs).expect("sharded save_many");
    let shard_save_ms = t.elapsed().as_secs_f64() * 1e3;
    let shard_files = cs.shard_files().expect("shard files");
    let shard_total: u64 =
        shard_files.iter().map(|s| counting.size(s).expect("shard size")).sum();

    counting.reset();
    let t = Instant::now();
    for k in 0..SAMPLE_RESUMES {
        let id = robot_id((k as u64 * 97) % ROBOTS);
        std::hint::black_box(cs.load(&id).expect("sharded load"));
    }
    let shard_resume_ms = t.elapsed().as_secs_f64() * 1e3 / SAMPLE_RESUMES as f64;
    let shard_bytes_per_resume = counting.bytes_read() / SAMPLE_RESUMES as u64;

    // how much of the store one resume did NOT have to read
    let partial_read_advantage = shard_total as f64 / shard_bytes_per_resume.max(1) as f64;

    println!(
        "monolithic  {mono_files:>5} files  {mono_total:>9} B total  save {mono_save_ms:8.1} ms  \
         resume {mono_resume_ms:6.3} ms ({mono_bytes_per_resume} B read)"
    );
    println!(
        "sharded     {:>5} files  {shard_total:>9} B total  save {shard_save_ms:8.1} ms  \
         resume {shard_resume_ms:6.3} ms ({shard_bytes_per_resume} B read)",
        shard_files.len()
    );
    println!(
        "\nfiles per 1k robots: {mono_files} -> {}; partial-read advantage {:.1}x \
         (one resume touches 1/{:.0} of the store)",
        shard_files.len(),
        partial_read_advantage,
        partial_read_advantage
    );

    let doc = bench_doc("store")
        .set("unit", "bytes")
        .set("robots", ROBOTS)
        .set("sample_resumes", SAMPLE_RESUMES as u64)
        .set(
            "monolithic",
            Json::obj()
                .set("files_per_1k_robots", mono_files as u64)
                .set("store_bytes", mono_total)
                .set("bytes_read_per_resume", mono_bytes_per_resume)
                .set("save_ms", mono_save_ms)
                .set("resume_ms", mono_resume_ms),
        )
        .set(
            "sharded",
            Json::obj()
                .set("files_per_1k_robots", shard_files.len() as u64)
                .set("store_bytes", shard_total)
                .set("bytes_read_per_resume", shard_bytes_per_resume)
                .set("save_ms", shard_save_ms)
                .set("resume_ms", shard_resume_ms),
        )
        .set("partial_read_advantage", partial_read_advantage);
    match save_json(&doc, "BENCH_store") {
        Ok(p) => println!("\n[saved {}]", p.display()),
        Err(e) => println!("\n[json save failed: {e}]"),
    }
    let _ = std::fs::remove_dir_all(&root);
}
