//! Bench: PE-array block-product simulation rate (Fig. 7 substrate).
//!
//! Besides the human-readable lines, writes the machine-readable
//! baseline `results/BENCH_pearray.json` (ns/op per scheme) that CI
//! uploads so the perf trajectory is tracked PR-over-PR.

use mxscale::arith::MacVariant;
use mxscale::coordinator::report::{bench_doc, save_json};
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{Layout, MxTensor};
use mxscale::pearray::PeArray;
use mxscale::util::json::Json;
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

fn main() {
    let mut rng = Pcg64::new(2);
    let a = Mat::randn(8, 8, 1.0, &mut rng);
    let b = Mat::randn(8, 8, 1.0, &mut rng);
    let mut schemes = Json::obj();
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
        let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
        let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let reps = 2_000;
        pe.mul_block(qa.square_block(0, 0), qb.square_block(0, 0)); // warm
        let t = Instant::now();
        for _ in 0..reps {
            pe.mul_block(qa.square_block(0, 0), qb.square_block(0, 0));
        }
        let dt = t.elapsed().as_secs_f64();
        let macs = reps as f64 * 512.0; // 64 outputs x 8-deep dot
        let ns_per_block = dt / reps as f64 * 1e9;
        println!(
            "pearray/{:<6} {:>10.0} block-mults/s  {:>12.2e} sim MAC-ops/s",
            fmt.name(),
            reps as f64 / dt,
            macs / dt
        );
        schemes = schemes.set(
            fmt.name(),
            Json::obj()
                .set("ns_per_block_mult", ns_per_block)
                .set("ns_per_mac_op", ns_per_block / 512.0),
        );
    }
    let doc = bench_doc("pearray").set("unit", "ns/op").set("schemes", schemes);
    match save_json(&doc, "BENCH_pearray") {
        Ok(p) => println!("[saved {}]", p.display()),
        Err(e) => println!("[json save failed: {e}]"),
    }
}
