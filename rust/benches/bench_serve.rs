//! Bench: the open-stream serving front-end under sustained churn —
//! 2000 short-lived tenants arriving on the synthetic load stream, with
//! short leases so (nearly) every session round-trips through the
//! checkpoint store mid-run. Hand-rolled harness (criterion unavailable
//! offline; run with `cargo bench --bench bench_serve`).
//!
//! Writes `results/BENCH_serve.json` (schema-versioned, git-SHA
//! stamped): p50/p99 per-step latency, steps/s, admission/shed/evict
//! counters, and the accounting the CI bench-gate holds hard —
//! `sessions_lost == 0`, `sessions_duplicated == 0`,
//! `twin_mismatches == 0`, and p99 within a sane multiple of p50.

use mxscale::coordinator::report::save_json;
use mxscale::fleet::StoreSpec;
use mxscale::serve::load::{bench_json, run_load, LoadSpec};
use mxscale::store::StoreLayout;

fn main() {
    let root = std::env::temp_dir().join(format!("mxscale-bench-serve-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    let spec = LoadSpec {
        sessions: 2000,
        steps: 10,
        // lease 2 quanta of 4 steps: every 10-step session is evicted
        // through the store once and re-admitted to finish
        lease_quanta: 2,
        twin_every: 101,
        store: Some(StoreSpec {
            dir: root.clone(),
            layout: StoreLayout::Sharded { shards: 4 },
        }),
        ..Default::default()
    };
    println!(
        "serving {} sessions x {} steps (quantum {}, capacity {}, lease {} quanta, \
         store sharded:4)...\n",
        spec.sessions, spec.steps, spec.quantum, spec.capacity, spec.lease_quanta
    );
    let out = run_load(&spec).expect("load run");
    let s = &out.stats;
    println!(
        "offered {} | admitted {} (+{} re-admissions) | completed {} | shed {} | \
         refused {} | failed {} | evicted {}",
        s.offered, s.admitted, s.re_admitted, s.completed, s.shed_overloaded, s.refused,
        s.failed, s.evicted
    );
    println!(
        "latency p50 {:.3} ms/step, p99 {:.3} ms/step ({} samples) | {:.0} steps/s | \
         {} steals | parked peak {}",
        s.p50_step_ms,
        s.p99_step_ms,
        s.latency_samples,
        s.steps_per_sec(),
        s.steals,
        s.parked_peak
    );
    println!(
        "accounting: {} lost, {} duplicated | twins {}/{} matched",
        out.lost,
        out.duplicated,
        out.twins_checked - out.twin_mismatches,
        out.twins_checked
    );
    assert_eq!(out.lost, 0, "every offer must be accounted");
    assert_eq!(out.duplicated, 0, "no session may finish twice");
    assert_eq!(out.twin_mismatches, 0, "served curves must equal standalone twins");
    assert!(s.evicted > 0, "short leases must exercise the evict path");
    match save_json(&bench_json(&spec, &out), "BENCH_serve") {
        Ok(p) => println!("\n[saved {}]", p.display()),
        Err(e) => println!("\n[json save failed: {e}]"),
    }
    let _ = std::fs::remove_dir_all(&root);
}
