//! Bench: MAC-unit simulation throughput per mode (Table II substrate).
//! Hand-rolled harness (criterion unavailable offline).

use mxscale::arith::{MacUnit, MacVariant, Mode};
use mxscale::util::rng::Pcg64;
use std::time::Instant;

fn bench(name: &str, mut f: impl FnMut() -> u64) {
    // warmup + 3 timed reps, report best
    f();
    let mut best = f64::INFINITY;
    let mut ops = 0;
    for _ in 0..3 {
        let t = Instant::now();
        ops = f();
        best = best.min(t.elapsed().as_secs_f64());
    }
    println!(
        "{name:<28} {:>12.0} ops/s   ({ops} ops in {best:.4}s)",
        ops as f64 / best
    );
}

fn main() {
    let n = 200_000usize;
    let mut rng = Pcg64::new(1);
    let a: Vec<i8> = (0..n).map(|_| rng.int_range(-127, 127) as i8).collect();
    let b: Vec<i8> = (0..n).map(|_| rng.int_range(-127, 127) as i8).collect();
    bench("mac/int8 cycle", || {
        let mut mac = MacUnit::new(Mode::Int8, MacVariant::ExtMantissaBypass);
        for i in 0..n {
            mac.cycle_int8(a[i], b[i], -12);
        }
        std::hint::black_box(mac.acc());
        n as u64
    });
    let codes: Vec<(u8, u8)> = (0..n).map(|_| (rng.bits(8) as u8 & 0x7b, rng.bits(8) as u8 & 0x7b)).collect();
    bench("mac/fp8 cycle (4 ops)", || {
        let mut mac = MacUnit::new(Mode::Fp8Fp6, MacVariant::ExtMantissaBypass);
        for c in codes.chunks_exact(4) {
            mac.cycle_fp86(
                mxscale::mx::element::ElementFormat::E4M3,
                &[c[0], c[1], c[2], c[3]],
                0,
            );
        }
        std::hint::black_box(mac.acc());
        n as u64
    });
    let codes4: Vec<(u8, u8)> = (0..n).map(|_| (rng.bits(4) as u8, rng.bits(4) as u8)).collect();
    bench("mac/fp4 cycle (8 ops)", || {
        let mut mac = MacUnit::new(Mode::Fp4, MacVariant::ExtMantissaBypass);
        for c in codes4.chunks_exact(8) {
            mac.cycle_fp4(&[c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]], 0);
        }
        std::hint::black_box(mac.acc());
        n as u64
    });
}
