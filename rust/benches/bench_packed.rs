//! Bench: the packed GeMM path vs the fake-quant GeMM path — the
//! training hot path's two software executions of the same bit-exact
//! values — plus a per-kernel-path leg (swar vs sse41/avx2/neon where
//! available). Hand-rolled harness (criterion unavailable offline; run
//! with `cargo bench --bench bench_packed`, vary RAYON_NUM_THREADS).
//!
//! Per element format it times one forward-cut GeMM the way each
//! backend actually executes it:
//!
//! * **fake** — `fake_quant_mat_fast(A)` + `fake_quant_mat_fast(W)` +
//!   `Mat::matmul_blocked` (the `FakeQuantBackend` work per cut);
//! * **packed** — `PackedTensor::quantize_pack(A)` + `quantize_pack(W)`
//!   + `packed_gemm` (the `PackedBackend` work per cut);
//! * **kernel_<path>** — the GeMM alone on pre-packed operands, once
//!   per kernel path this CPU can run (quantize excluded, so the ratio
//!   isolates the vector win in the O(n³) walk).
//!
//! Every leg gets **fresh inputs from its own seeded RNG** (shared
//! warm buffers across legs flattered later formats via cache
//! residency), and input generation + packing happens outside the
//! timed region (reported separately). All paths produce bit-identical
//! outputs (asserted here before timing), so every ratio is a pure
//! execution-speed comparison. Writes `results/BENCH_packed.json`
//! (schema-versioned, git-SHA-stamped, kernel-path provenance) with
//! ns/op per format, the fake→packed speedup, and — on AVX2 hosts —
//! `avx2_vs_swar_speedup`, which the CI bench-gate holds to ≥ 2x on
//! the 256³ mxint8 GeMM.

use mxscale::coordinator::report::{bench_doc, save_json};
use mxscale::mx::element::ElementFormat;
use mxscale::mx::packed::{packed_gemm, PackedTensor};
use mxscale::mx::simd::{detect, gemm as simd_gemm, KernelPath, SIMD_FORMATS};
use mxscale::mx::tensor::{fake_quant_mat_fast, Layout};
use mxscale::util::json::Json;
use mxscale::util::mat::Mat;
use mxscale::util::par;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

/// Best-of-3 seconds per call after one warmup call.
fn time_best<R>(reps: usize, mut f: impl FnMut() -> R) -> f64 {
    std::hint::black_box(f());
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        best = best.min(t.elapsed().as_secs_f64() / reps as f64);
    }
    best
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

fn main() {
    // the bench shapes: one square GeMM in the hidden-layer class and
    // one pusher-MLP-shaped cut (batch 32, 256x256 hidden weight)
    let shapes: [(usize, usize, usize, usize); 2] = [(256, 256, 256, 10), (32, 256, 256, 40)];
    let feats = detect::features();
    println!(
        "packed GeMM vs fake-quant GeMM ({} worker threads, cpu features: {}; \
         all paths bit-identical)\n",
        par::threads(),
        feats.describe()
    );
    let mut schemes = Json::obj();
    for (fi, fmt) in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1]
        .into_iter()
        .enumerate()
    {
        let mut per_shape = Json::obj();
        let mut int8_speedup_256 = None;
        let mut int8_avx2_vs_swar_256 = None;
        for &(m, k, n, reps) in &shapes {
            // fresh inputs per (format, shape) leg from a leg-specific
            // seed: no cross-leg cache residency, reproducible runs
            let mut rng = Pcg64::new(0xBE7C ^ ((fi as u64) << 32) ^ ((m * 1000 + n) as u64));
            let t_gen = Instant::now();
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let w = Mat::randn(k, n, 0.5, &mut rng);
            let pa = PackedTensor::quantize_pack(&a, fmt);
            let pw = PackedTensor::quantize_pack(&w, fmt);
            let gen_ms = t_gen.elapsed().as_secs_f64() * 1e3;
            // sanity: the two paths are the same function (theorem)
            let dense = {
                let aq = fake_quant_mat_fast(&a, fmt, Layout::Square8x8);
                let wq = fake_quant_mat_fast(&w, fmt, Layout::Square8x8);
                aq.matmul_blocked(&wq, 8)
            };
            let swar = packed_gemm(&pa, &pw);
            assert_eq!(
                bits(&dense),
                bits(&swar),
                "{fmt:?} {m}x{k}x{n}: packed != fake (theorem violated)"
            );

            let t_fake = time_best(reps, || {
                let aq = fake_quant_mat_fast(&a, fmt, Layout::Square8x8);
                let wq = fake_quant_mat_fast(&w, fmt, Layout::Square8x8);
                aq.matmul_blocked(&wq, 8)
            });
            let t_packed = time_best(reps, || {
                let qa = PackedTensor::quantize_pack(&a, fmt);
                let qw = PackedTensor::quantize_pack(&w, fmt);
                packed_gemm(&qa, &qw)
            });
            let macs = (m * k * n) as f64;
            let speedup = t_fake / t_packed;
            println!(
                "gemm/{:<6} {:>3}x{}x{}  fake {:8.3} ms  packed {:8.3} ms  speedup {:.2}x  \
                 ({:.3} ns/op packed; inputs+pack {:.1} ms untimed)",
                fmt.name(),
                m,
                k,
                n,
                t_fake * 1e3,
                t_packed * 1e3,
                speedup,
                t_packed / macs * 1e9,
                gen_ms
            );
            if fmt == ElementFormat::Int8 && (m, k, n) == (256, 256, 256) {
                int8_speedup_256 = Some(speedup);
            }
            let mut shape_entry = Json::obj()
                .set("fake_ns_op", t_fake / macs * 1e9)
                .set("packed_ns_op", t_packed / macs * 1e9)
                .set("speedup", speedup);
            // per-kernel-path leg: GeMM only, pre-packed operands,
            // every path this CPU can run, pinned to SWAR bits first
            if SIMD_FORMATS.contains(&fmt) {
                let mut t_by_path = Vec::new();
                for path in KernelPath::ALL {
                    if !path.available(feats) {
                        continue;
                    }
                    let out = simd_gemm(path, &pa, &pw);
                    assert_eq!(
                        bits(&out),
                        bits(&swar),
                        "{fmt:?} {m}x{k}x{n}: kernel path {} != swar",
                        path.name()
                    );
                    let t = time_best(reps, || simd_gemm(path, &pa, &pw));
                    println!(
                        "  kernel/{:<6} {:>3}x{}x{}  {:8.3} ms  ({:.3} ns/op)",
                        path.name(),
                        m,
                        k,
                        n,
                        t * 1e3,
                        t / macs * 1e9
                    );
                    shape_entry =
                        shape_entry.set(&format!("kernel_{}_ns_op", path.name()), t / macs * 1e9);
                    t_by_path.push((path, t));
                }
                let t_of = |p: KernelPath| t_by_path.iter().find(|(q, _)| *q == p).map(|(_, t)| *t);
                if let (Some(ts), Some(ta)) = (t_of(KernelPath::Swar), t_of(KernelPath::Avx2)) {
                    let ratio = ts / ta;
                    println!("  kernel/avx2 over swar: {ratio:.2}x");
                    if fmt == ElementFormat::Int8 && (m, k, n) == (256, 256, 256) {
                        int8_avx2_vs_swar_256 = Some(ratio);
                    }
                }
            }
            per_shape = per_shape.set(&format!("{m}x{k}x{n}"), shape_entry);
        }
        let mut entry = per_shape;
        if let Some(s) = int8_speedup_256 {
            entry = entry.set("headline_speedup", s);
        }
        if let Some(s) = int8_avx2_vs_swar_256 {
            entry = entry.set("avx2_vs_swar_speedup", s);
        }
        schemes = schemes.set(fmt.name(), entry);
    }
    let doc = bench_doc("packed").set("unit", "ns/op").set("schemes", schemes);
    match save_json(&doc, "BENCH_packed") {
        Ok(p) => println!("\n[saved {}]", p.display()),
        Err(e) => println!("\n[json save failed: {e}]"),
    }
}
