//! Bench: the packed SWAR GeMM path vs the fake-quant GeMM path — the
//! training hot path's two software executions of the same bit-exact
//! values. Hand-rolled harness (criterion unavailable offline; run with
//! `cargo bench --bench bench_packed`, vary RAYON_NUM_THREADS).
//!
//! Per element format it times one forward-cut GeMM the way each
//! backend actually executes it:
//!
//! * **fake** — `fake_quant_mat_fast(A)` + `fake_quant_mat_fast(W)` +
//!   `Mat::matmul_blocked` (the `FakeQuantBackend` work per cut);
//! * **packed** — `PackedTensor::quantize_pack(A)` + `quantize_pack(W)`
//!   + `packed_gemm` (the `PackedBackend` work per cut).
//!
//! Both produce bit-identical outputs (asserted here before timing), so
//! the ratio is a pure execution-speed comparison. Writes
//! `results/BENCH_packed.json` (schema-versioned, git-SHA-stamped) with
//! ns/op per format and the fake→packed speedup; the CI bench-gate job
//! enforces the mxint8 speedup floor (≥ 2x) and the ±25% ns/op
//! trajectory against the committed baseline.

use mxscale::coordinator::report::{bench_doc, save_json};
use mxscale::mx::element::ElementFormat;
use mxscale::mx::packed::{packed_gemm, PackedTensor};
use mxscale::mx::tensor::{fake_quant_mat_fast, Layout};
use mxscale::util::json::Json;
use mxscale::util::mat::Mat;
use mxscale::util::par;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

/// Best-of-3 seconds per call after one warmup call.
fn time_best<R>(reps: usize, mut f: impl FnMut() -> R) -> f64 {
    std::hint::black_box(f());
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        best = best.min(t.elapsed().as_secs_f64() / reps as f64);
    }
    best
}

fn main() {
    let mut rng = Pcg64::new(7);
    // the bench shapes: one square GeMM in the hidden-layer class and
    // one pusher-MLP-shaped cut (batch 32, 256x256 hidden weight)
    let shapes: [(usize, usize, usize, usize); 2] =
        [(256, 256, 256, 10), (32, 256, 256, 40)];
    println!(
        "packed SWAR GeMM vs fake-quant GeMM ({} worker threads; both paths bit-identical)\n",
        par::threads()
    );
    let mut schemes = Json::obj();
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let mut per_shape = Json::obj();
        let mut int8_speedup_256 = None;
        for &(m, k, n, reps) in &shapes {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let w = Mat::randn(k, n, 0.5, &mut rng);
            // sanity: the two paths are the same function (theorem)
            let dense = {
                let aq = fake_quant_mat_fast(&a, fmt, Layout::Square8x8);
                let wq = fake_quant_mat_fast(&w, fmt, Layout::Square8x8);
                aq.matmul_blocked(&wq, 8)
            };
            let swar = packed_gemm(
                &PackedTensor::quantize_pack(&a, fmt),
                &PackedTensor::quantize_pack(&w, fmt),
            );
            assert_eq!(
                dense.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                swar.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{fmt:?} {m}x{k}x{n}: packed != fake (theorem violated)"
            );

            let t_fake = time_best(reps, || {
                let aq = fake_quant_mat_fast(&a, fmt, Layout::Square8x8);
                let wq = fake_quant_mat_fast(&w, fmt, Layout::Square8x8);
                aq.matmul_blocked(&wq, 8)
            });
            let t_packed = time_best(reps, || {
                let pa = PackedTensor::quantize_pack(&a, fmt);
                let pw = PackedTensor::quantize_pack(&w, fmt);
                packed_gemm(&pa, &pw)
            });
            let macs = (m * k * n) as f64;
            let speedup = t_fake / t_packed;
            println!(
                "gemm/{:<6} {:>3}x{}x{}  fake {:8.3} ms  packed {:8.3} ms  speedup {:.2}x  ({:.3} ns/op packed)",
                fmt.name(),
                m,
                k,
                n,
                t_fake * 1e3,
                t_packed * 1e3,
                speedup,
                t_packed / macs * 1e9
            );
            if fmt == ElementFormat::Int8 && (m, k, n) == (256, 256, 256) {
                int8_speedup_256 = Some(speedup);
            }
            per_shape = per_shape.set(
                &format!("{m}x{k}x{n}"),
                Json::obj()
                    .set("fake_ns_op", t_fake / macs * 1e9)
                    .set("packed_ns_op", t_packed / macs * 1e9)
                    .set("speedup", speedup),
            );
        }
        let mut entry = per_shape;
        if let Some(s) = int8_speedup_256 {
            entry = entry.set("headline_speedup", s);
        }
        schemes = schemes.set(fmt.name(), entry);
    }
    let doc = bench_doc("packed").set("unit", "ns/op").set("schemes", schemes);
    match save_json(&doc, "BENCH_packed") {
        Ok(p) => println!("\n[saved {}]", p.display()),
        Err(e) => println!("\n[json save failed: {e}]"),
    }
}
