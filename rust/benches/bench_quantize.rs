//! Bench: MX quantization throughput (the trainer's QAT hot path).

use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{Layout, MxTensor};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

fn main() {
    let mut rng = Pcg64::new(3);
    let m = Mat::randn(256, 256, 1.0, &mut rng);
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let reps = 50;
            let _ = MxTensor::fake_quant(&m, fmt, layout); // warm
            let t = Instant::now();
            for _ in 0..reps {
                std::hint::black_box(MxTensor::fake_quant(&m, fmt, layout));
            }
            let dt = t.elapsed().as_secs_f64();
            let elems = reps as f64 * 65536.0;
            println!(
                "quantize/{:<6}/{:<10} {:>10.2e} elems/s  ({:.3} ms per 256x256)",
                fmt.name(),
                layout.name(),
                elems / dt,
                dt * 1e3 / reps as f64
            );
        }
    }
}
