//! Bench: MX quantization throughput (the trainer's QAT hot path),
//! including the serial-vs-parallel comparison of the batched engine.
//! Hand-rolled harness (criterion unavailable offline; run with
//! `cargo bench --bench bench_quantize`, vary RAYON_NUM_THREADS).
//!
//! Writes the machine-readable baseline `results/BENCH_quantize.json`
//! (ns/op per scheme x layout + parallel speedups) for the CI perf
//! trajectory.

use mxscale::coordinator::report::{bench_doc, save_json};
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{
    fake_quant_mat_fast, fake_quant_mat_fast_serial, Layout, MxTensor,
};
use mxscale::util::json::Json;
use mxscale::util::mat::Mat;
use mxscale::util::par;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

/// Best-of-3 seconds per call after one warmup call.
fn time_best<R>(reps: usize, mut f: impl FnMut() -> R) -> f64 {
    std::hint::black_box(f());
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(f());
        }
        best = best.min(t.elapsed().as_secs_f64() / reps as f64);
    }
    best
}

fn main() {
    let mut rng = Pcg64::new(3);
    let m = Mat::randn(256, 256, 1.0, &mut rng);
    let mut schemes = Json::obj();
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let dt = time_best(50, || MxTensor::fake_quant(&m, fmt, layout));
            let elems = 65536.0;
            println!(
                "quantize/{:<6}/{:<10} {:>10.2e} elems/s  ({:.3} ms per 256x256)",
                fmt.name(),
                layout.name(),
                elems / dt,
                dt * 1e3
            );
            schemes = schemes
                .set(&format!("{}/{}", fmt.name(), layout.name()), dt / elems * 1e9);
        }
    }

    // §Parallel: the batched engine vs the serial reference on a
    // training-sized tensor. Both paths are bit-identical (asserted in
    // tests/parallel.rs); only the wall-clock differs.
    let big = Mat::randn(1024, 1024, 1.0, &mut rng);
    println!(
        "\nparallel engine: {} worker threads (set RAYON_NUM_THREADS to vary)",
        par::threads()
    );
    let mut parallel = Json::obj();
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3] {
        let ts = time_best(10, || fake_quant_mat_fast_serial(&big, fmt, Layout::Square8x8));
        let tp = time_best(10, || fake_quant_mat_fast(&big, fmt, Layout::Square8x8));
        println!(
            "fake-quant-fast/{:<6} 1024^2  serial {:8.3} ms  parallel {:8.3} ms  speedup {:.2}x",
            fmt.name(),
            ts * 1e3,
            tp * 1e3,
            ts / tp
        );
        parallel = parallel.set(
            &format!("fake_quant_fast/{}", fmt.name()),
            Json::obj()
                .set("serial_ms", ts * 1e3)
                .set("parallel_ms", tp * 1e3)
                .set("speedup", ts / tp),
        );
        let ts = time_best(5, || {
            MxTensor::quantize_serial(&big, fmt, Layout::Square8x8).dequantize_serial()
        });
        let tp = time_best(5, || {
            MxTensor::quantize(&big, fmt, Layout::Square8x8).dequantize()
        });
        println!(
            "codec-roundtrip/{:<6} 1024^2  serial {:8.3} ms  parallel {:8.3} ms  speedup {:.2}x",
            fmt.name(),
            ts * 1e3,
            tp * 1e3,
            ts / tp
        );
        parallel = parallel.set(
            &format!("codec_roundtrip/{}", fmt.name()),
            Json::obj()
                .set("serial_ms", ts * 1e3)
                .set("parallel_ms", tp * 1e3)
                .set("speedup", ts / tp),
        );
    }
    let doc = bench_doc("quantize")
        .set("unit", "ns/elem")
        .set("schemes", schemes)
        .set("parallel", parallel);
    match save_json(&doc, "BENCH_quantize") {
        Ok(p) => println!("[saved {}]", p.display()),
        Err(e) => println!("[json save failed: {e}]"),
    }
}
