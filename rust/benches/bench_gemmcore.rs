//! Bench: GeMM-core schedule + training-step simulation (Table IV
//! substrate) and the golden QAT step (Fig. 2 substrate).

use mxscale::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use mxscale::mx::element::ElementFormat;
use mxscale::trainer::mlp::{Mlp, MLP_DIMS};
use mxscale::trainer::qat::{qat_step, QuantScheme};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

fn main() {
    // schedule computation itself (pure arithmetic, should be ~ns)
    let t = Instant::now();
    let reps = 100_000;
    for _ in 0..reps {
        std::hint::black_box(train_step_cycles(32, &PUSHER_DIMS, ElementFormat::Int8));
    }
    println!(
        "schedule/train_step_cycles  {:>10.0} evals/s",
        reps as f64 / t.elapsed().as_secs_f64()
    );

    // golden QAT step (native Fig. 2 path)
    let mut rng = Pcg64::new(4);
    let mut mlp = Mlp::new(&MLP_DIMS, &mut rng);
    let x = Mat::randn(32, 32, 1.0, &mut rng);
    let y = Mat::randn(32, 32, 0.5, &mut rng);
    for scheme in [
        QuantScheme::Fp32,
        QuantScheme::MxSquare(ElementFormat::Int8),
        QuantScheme::MxSquare(ElementFormat::E4M3),
    ] {
        qat_step(&mut mlp, &x, &y, scheme, 1e-3); // warm
        let reps = 20;
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(qat_step(&mut mlp, &x, &y, scheme, 1e-3));
        }
        println!(
            "qat_step/{:<10} {:>8.2} ms/step",
            scheme.name(),
            t.elapsed().as_secs_f64() * 1e3 / reps as f64
        );
    }
}
