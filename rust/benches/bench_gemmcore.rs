//! Bench: GeMM-core schedule + training-step simulation (Table IV
//! substrate), the golden QAT step (Fig. 2 substrate), the tile-parallel
//! PE-array walk vs its serial reference, and the batched QAT sweep.
//! Hand-rolled harness (criterion unavailable offline); vary worker
//! count with RAYON_NUM_THREADS.

use mxscale::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use mxscale::gemmcore::GemmCore;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{Layout, MxTensor};
use mxscale::trainer::batched::BatchedTrainer;
use mxscale::trainer::mlp::{Mlp, MLP_DIMS};
use mxscale::trainer::qat::{qat_step, QuantScheme};
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::util::mat::Mat;
use mxscale::util::par;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

fn main() {
    // schedule computation itself (pure arithmetic, should be ~ns)
    let t = Instant::now();
    let reps = 100_000;
    for _ in 0..reps {
        std::hint::black_box(train_step_cycles(32, &PUSHER_DIMS, ElementFormat::Int8));
    }
    println!(
        "schedule/train_step_cycles  {:>10.0} evals/s",
        reps as f64 / t.elapsed().as_secs_f64()
    );

    // golden QAT step (native Fig. 2 path)
    let mut rng = Pcg64::new(4);
    let mut mlp = Mlp::new(&MLP_DIMS, &mut rng);
    let x = Mat::randn(32, 32, 1.0, &mut rng);
    let y = Mat::randn(32, 32, 0.5, &mut rng);
    for scheme in [
        QuantScheme::Fp32,
        QuantScheme::MxSquare(ElementFormat::Int8),
        QuantScheme::MxSquare(ElementFormat::E4M3),
    ] {
        qat_step(&mut mlp, &x, &y, scheme, 1e-3); // warm
        let reps = 20;
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(qat_step(&mut mlp, &x, &y, scheme, 1e-3));
        }
        println!(
            "qat_step/{:<10} {:>8.2} ms/step",
            scheme.name(),
            t.elapsed().as_secs_f64() * 1e3 / reps as f64
        );
    }

    // §Parallel: the bit-exact PE-array datapath, serial walk vs the
    // tile-parallel walk (identical outputs/events, see tests/parallel.rs)
    println!(
        "\nparallel engine: {} worker threads (set RAYON_NUM_THREADS to vary)",
        par::threads()
    );
    let a = Mat::randn(128, 128, 1.0, &mut rng);
    let b = Mat::randn(128, 128, 1.0, &mut rng);
    for fmt in [ElementFormat::Int8, ElementFormat::E2M1] {
        let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
        let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
        let reps = 5;
        let mut core = GemmCore::new(fmt);
        core.gemm_serial(&qa, &qb); // warm
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(core.gemm_serial(&qa, &qb));
        }
        let ts = t.elapsed().as_secs_f64() / reps as f64;
        core.gemm(&qa, &qb); // warm
        let t = Instant::now();
        for _ in 0..reps {
            std::hint::black_box(core.gemm(&qa, &qb));
        }
        let tp = t.elapsed().as_secs_f64() / reps as f64;
        println!(
            "gemmcore/128^3/{:<6} serial {:8.2} ms  parallel {:8.2} ms  speedup {:.2}x",
            fmt.name(),
            ts * 1e3,
            tp * 1e3,
            ts / tp
        );
    }

    // §Batched: a 4-scheme QAT sweep, sequential vs BatchedTrainer
    // (the Fig. 2 / precision-sweep shape; results are bit-identical)
    let env = mxscale::workloads::by_name("cartpole").unwrap();
    let ds = mxscale::workloads::Dataset::collect(env.as_ref(), 6, 60, 0xBE);
    let schemes = [
        QuantScheme::Fp32,
        QuantScheme::MxSquare(ElementFormat::Int8),
        QuantScheme::MxSquare(ElementFormat::E4M3),
        QuantScheme::MxSquare(ElementFormat::E2M1),
    ];
    let cfg = TrainConfig { steps: 60, eval_every: usize::MAX, ..Default::default() };
    let t = Instant::now();
    for scheme in schemes {
        let mut s = TrainSession::new(ds.clone(), TrainConfig { scheme, ..cfg.clone() });
        s.run();
        std::hint::black_box(s.val_loss());
    }
    let ts = t.elapsed().as_secs_f64();
    let t = Instant::now();
    let mut batch = BatchedTrainer::new();
    for scheme in schemes {
        batch.push(scheme.name(), ds.clone(), TrainConfig { scheme, ..cfg.clone() });
    }
    std::hint::black_box(batch.run());
    let tp = t.elapsed().as_secs_f64();
    println!(
        "sweep/4-schemes-x60-steps  sequential {:7.0} ms  batched {:7.0} ms  speedup {:.2}x",
        ts * 1e3,
        tp * 1e3,
        ts / tp
    );
}
