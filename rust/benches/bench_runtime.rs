//! Bench: PJRT train-step execution rate (the production path).
//! Requires `make artifacts`. Skips gracefully if artifacts are missing.

use mxscale::runtime::{artifact_dir, Manifest, TrainExecutable};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use std::time::Instant;

fn main() {
    let dir = artifact_dir();
    let Ok(manifest) = Manifest::load(&dir) else {
        println!("runtime bench skipped: no artifacts (run `make artifacts`)");
        return;
    };
    let client = match mxscale::runtime::executor::cpu_client() {
        Ok(c) => c,
        Err(e) => {
            println!("runtime bench skipped: {e}");
            return;
        }
    };
    let mut rng = Pcg64::new(5);
    let x = Mat::randn(manifest.batch, 32, 1.0, &mut rng);
    let y = Mat::randn(manifest.batch, 32, 0.5, &mut rng);
    for scheme in ["fp32", "int8", "e4m3"] {
        let Some(path) = manifest.train_path(&dir, scheme) else { continue };
        let mut exe = match TrainExecutable::load(&client, &path, 1) {
            Ok(e) => e,
            Err(e) => {
                println!("runtime/{scheme} skipped: {e}");
                continue;
            }
        };
        let _ = exe.step(&x, &y); // warm (compile-adjacent costs)
        let reps = 30;
        let t = Instant::now();
        for _ in 0..reps {
            exe.step(&x, &y).unwrap();
        }
        println!(
            "runtime/train_step/{:<6} {:>8.2} ms/step (PJRT CPU)",
            scheme,
            t.elapsed().as_secs_f64() * 1e3 / reps as f64
        );
    }
}
