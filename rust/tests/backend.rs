//! ExecBackend equivalence and hardware cost accounting.
//!
//! The contract under test (DESIGN.md §6): `FakeQuantBackend`,
//! `HardwareBackend`, and `PackedBackend` produce **bit-identical**
//! quantized forward and backward results for all six MX element
//! formats — asserted three-way on the tape, the gradients, five full
//! Adam steps, and whole session loss curves — while the hardware
//! backend additionally accumulates a nonzero cycle/event/energy/
//! memory-traffic ledger whose schedule part matches the analytic model
//! GeMM-for-GeMM. Plus ragged-shape quantization coverage (rectangular
//! and non-multiple-of-8/32 matrices through both block layouts).

use mxscale::backend::{BackendKind, ExecBackend, FakeQuantBackend, HardwareBackend, PackedBackend};
use mxscale::gemmcore::memory::gemm_traffic_bits;
use mxscale::gemmcore::schedule::{gemm_cycles_staged, CycleCost, Stage};
use mxscale::mx::dacapo::DacapoFormat;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{fake_quant_mat_fast, Layout, MxTensor};
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::trainer::mlp::Mlp;
use mxscale::trainer::qat::{qat_forward_backward_with, qat_step_with, QuantScheme};
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// Ragged dims on purpose: 12-row batch, 16→24→8 layers — the 8x8 block
/// grid pads in every direction.
fn toy_mlp(seed: u64) -> (Mlp, Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let mlp = Mlp::new(&[16, 24, 8], &mut rng);
    let x = Mat::randn(12, 16, 1.0, &mut rng);
    let y = Mat::randn(12, 8, 0.5, &mut rng);
    (mlp, x, y)
}

#[test]
fn backends_bit_identical_for_all_six_formats() {
    for fmt in ALL_ELEMENT_FORMATS {
        let scheme = QuantScheme::MxSquare(fmt);
        let (mlp, x, y) = toy_mlp(0xB17 ^ fmt.bits() as u64);
        let mut fake = FakeQuantBackend::new(scheme);
        let mut hw = HardwareBackend::new(scheme).unwrap();
        let mut packed = PackedBackend::new(scheme).unwrap();
        fake.begin_step();
        hw.begin_step();
        packed.begin_step();
        let (tf, gf) = qat_forward_backward_with(&mlp, &x, &y, &mut fake);
        let (th, gh) = qat_forward_backward_with(&mlp, &x, &y, &mut hw);
        let (tp, gp) = qat_forward_backward_with(&mlp, &x, &y, &mut packed);
        for (other, to, go) in [("hw", &th, &gh), ("packed", &tp, &gp)] {
            assert_eq!(bits(&tf.output), bits(&to.output), "{fmt:?} {other} output");
            for (i, (a, b)) in tf.activations.iter().zip(&to.activations).enumerate() {
                assert_eq!(bits(a), bits(b), "{fmt:?} {other} activation {i}");
            }
            for (i, (a, b)) in tf.pre_acts.iter().zip(&to.pre_acts).enumerate() {
                assert_eq!(bits(a), bits(b), "{fmt:?} {other} pre_act {i}");
            }
            for (i, (a, b)) in gf.d_weights.iter().zip(&go.d_weights).enumerate() {
                assert_eq!(bits(a), bits(b), "{fmt:?} {other} d_w {i}");
            }
            for (i, (a, b)) in gf.d_biases.iter().zip(&go.d_biases).enumerate() {
                assert_eq!(a, b, "{fmt:?} {other} d_b {i}");
            }
        }
        // the datapath really ran, and stayed within FP32-accumulation
        // distance of the functional kernel
        let r = hw.cost_report().unwrap();
        assert!(r.cost.total() > 0, "{fmt:?}");
        assert!(r.events.mul_ops > 0, "{fmt:?}");
        assert!(r.datapath_max_rel_err < 1e-3, "{fmt:?}: {}", r.datapath_max_rel_err);
    }
}

#[test]
fn backends_stay_bit_identical_across_training_steps() {
    // Adam compounds any divergence; five full steps must end with
    // bit-identical parameters on all three backends.
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let scheme = QuantScheme::MxSquare(fmt);
        let (mlp0, x, y) = toy_mlp(0x57E9 ^ fmt.bits() as u64);
        let mut mlp_f = mlp0.clone();
        let mut mlp_h = mlp0.clone();
        let mut mlp_p = mlp0;
        let mut fake = FakeQuantBackend::new(scheme);
        let mut hw = HardwareBackend::new(scheme).unwrap();
        let mut packed = PackedBackend::new(scheme).unwrap();
        for step in 0..5 {
            let lf = qat_step_with(&mut mlp_f, &x, &y, &mut fake, 2e-3);
            let lh = qat_step_with(&mut mlp_h, &x, &y, &mut hw, 2e-3);
            let lp = qat_step_with(&mut mlp_p, &x, &y, &mut packed, 2e-3);
            assert_eq!(lf, lh, "{fmt:?} step {step} hw loss");
            assert_eq!(lf, lp, "{fmt:?} step {step} packed loss");
        }
        let pbits = |m: &Mlp| m.flat_params().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(pbits(&mlp_f), pbits(&mlp_h), "{fmt:?} params after 5 steps (hw)");
        assert_eq!(pbits(&mlp_f), pbits(&mlp_p), "{fmt:?} params after 5 steps (packed)");
        assert_eq!(hw.cost_report().unwrap().steps, 5);
    }
}

#[test]
fn hw_schedule_matches_analytic_model_gemm_for_gemm() {
    // one training step of a [16, 24, 8] MLP at batch 12: fwd + wgrad on
    // every layer, error-backprop only above layer 0 (the graph-accurate
    // difference from the closed-form train_step_cycles).
    let fmt = ElementFormat::E4M3;
    let (mut mlp, x, y) = toy_mlp(0xACC);
    let mut hw = HardwareBackend::new(QuantScheme::MxSquare(fmt)).unwrap();
    qat_step_with(&mut mlp, &x, &y, &mut hw, 1e-3);
    let mut want = CycleCost::default();
    let mut want_traffic = 0u64;
    let batch = 12usize;
    let dims = [16usize, 24, 8];
    for (l, w) in dims.windows(2).enumerate() {
        let (din, dout) = (w[0], w[1]);
        want.add(&gemm_cycles_staged(batch, din, dout, fmt, Stage::Forward));
        want_traffic += gemm_traffic_bits(batch, din, dout, fmt, Stage::Forward);
        want.add(&gemm_cycles_staged(din, batch, dout, fmt, Stage::WeightGrad));
        want_traffic += gemm_traffic_bits(din, batch, dout, fmt, Stage::WeightGrad);
        if l > 0 {
            want.add(&gemm_cycles_staged(batch, dout, din, fmt, Stage::Backward));
            want_traffic += gemm_traffic_bits(batch, dout, din, fmt, Stage::Backward);
        }
    }
    let r = hw.cost_report().unwrap();
    assert_eq!(r.cost, want, "schedule cost must match the analytic model");
    assert_eq!(r.mem_traffic_bits, want_traffic);
    assert_eq!(r.gemms, 2 * 2 + 1); // 2 layers x (fwd + wgrad) + 1 bwd
    // datapath event count agrees with the schedule's padded OP count
    assert_eq!(r.events.mul_ops, r.cost.mul_ops);
}

#[test]
fn hw_session_emits_nonzero_cost_report() {
    // the acceptance criterion: a TrainSession on --backend hw reports
    // nonzero cycle / energy / memory-traffic totals in the JSON.
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 0xD5);
    let mut s = TrainSession::new(
        ds,
        TrainConfig {
            scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
            backend: BackendKind::Hardware,
            dims: Some(vec![32, 16, 32]),
            steps: 3,
            eval_every: usize::MAX,
            ..Default::default()
        },
    );
    s.run();
    let r = s.hw_report().expect("hardware backend must account cost");
    assert_eq!(r.steps, 3);
    assert!(r.cost.total() > 0);
    assert!(r.energy_pj() > 0.0);
    assert!(r.mem_traffic_bits > 0);
    assert!(r.resident_kb > 0.0);
    assert!(r.us_per_step() > 0.0 && r.steps_per_sec() > 0.0);
    let json = r.to_json().to_string();
    let keys = ["\"cycles\"", "\"energy\"", "\"traffic_bits\"", "\"steps\":3", "\"backend\":\"hw\""];
    for key in keys {
        assert!(json.contains(key), "JSON missing {key}: {json}");
    }
    // and none of the headline totals serialized as zero
    assert!(!json.contains("\"total\":0,"), "{json}");
    assert!(!json.contains("\"traffic_bits\":0,"), "{json}");
}

#[test]
fn all_backends_match_on_training_session_losses() {
    // same session config, all three backends: identical loss curves
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 0xD6);
    let run = |backend: BackendKind| {
        let mut s = TrainSession::new(
            ds.clone(),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E2M1),
                backend,
                dims: Some(vec![32, 16, 32]),
                steps: 4,
                eval_every: 2,
                ..Default::default()
            },
        );
        s.run();
        (s.val_curve.clone(), s.val_loss(), s.train_curve.clone())
    };
    let (curve_f, loss_f, train_f) = run(BackendKind::Fast);
    let (curve_h, loss_h, train_h) = run(BackendKind::Hardware);
    let (curve_p, loss_p, train_p) = run(BackendKind::Packed);
    assert_eq!(curve_f, curve_h);
    assert_eq!(loss_f, loss_h);
    assert_eq!(train_f, train_h);
    assert_eq!(curve_f, curve_p);
    assert_eq!(loss_f, loss_p);
    assert_eq!(train_f, train_p);
}

#[test]
fn packed_session_loss_curves_match_fast_for_all_six_formats() {
    // the acceptance criterion spelled out: --backend packed is
    // bit-identical to fast on whole session loss curves, per format
    let env = by_name("reacher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 3, 30, 0xD7);
    for fmt in ALL_ELEMENT_FORMATS {
        let run = |backend: BackendKind| {
            let mut s = TrainSession::new(
                ds.clone(),
                TrainConfig {
                    scheme: QuantScheme::MxSquare(fmt),
                    backend,
                    dims: Some(vec![32, 16, 32]),
                    steps: 6,
                    eval_every: 2,
                    ..Default::default()
                },
            );
            s.run();
            (s.train_curve.clone(), s.val_curve.clone())
        };
        assert_eq!(run(BackendKind::Fast), run(BackendKind::Packed), "{fmt:?}");
    }
}

// ---------------------------------------------------------------------
// Ragged-shape quantization coverage (satellite): rectangular and
// non-multiple-of-8/32 matrices through both layouts.
// ---------------------------------------------------------------------

const RAGGED_SHAPES: [(usize, usize); 7] =
    [(1, 1), (7, 5), (13, 21), (8, 40), (40, 8), (5, 64), (9, 33)];

fn ragged_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.wide_f32().clamp(-1e6, 1e6))
}

#[test]
fn ragged_shapes_quantize_consistently_in_both_layouts() {
    for (rows, cols) in RAGGED_SHAPES {
        let m = ragged_mat(rows, cols, 0x4A6 + rows as u64 * 131 + cols as u64);
        for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
            for layout in [Layout::Square8x8, Layout::Vector32] {
                let q = MxTensor::quantize(&m, fmt, layout);
                let d = q.dequantize();
                assert_eq!((d.rows, d.cols), (rows, cols), "{fmt:?} {layout:?}");
                // codec path == fast fake-quant path, bit for bit
                let fast = fake_quant_mat_fast(&m, fmt, layout);
                assert_eq!(bits(&d), bits(&fast), "{fmt:?} {layout:?} {rows}x{cols}");
                // padding must not corrupt in-bounds values
                assert!(
                    d.mse(&m) < (m.max_abs() as f64).powi(2).max(1e-30) * 0.01,
                    "{fmt:?} {layout:?} {rows}x{cols}: mse {}",
                    d.mse(&m)
                );
            }
        }
    }
}

#[test]
fn ragged_square_transpose_is_still_bit_identical() {
    // the paper's free-transpose claim must survive edge padding
    for (rows, cols) in RAGGED_SHAPES {
        for fmt in ALL_ELEMENT_FORMATS {
            let m = ragged_mat(rows, cols, 0x7A0 + rows as u64 + fmt.bits() as u64 * 997);
            let q = MxTensor::quantize(&m, fmt, Layout::Square8x8);
            let qt = q.transpose().unwrap();
            assert_eq!((qt.rows, qt.cols), (cols, rows));
            let direct = MxTensor::quantize(&m.transpose(), fmt, Layout::Square8x8);
            assert_eq!(bits(&qt.dequantize()), bits(&direct.dequantize()), "{fmt:?} {rows}x{cols}");
            assert_eq!(bits(&qt.dequantize()), bits(&q.dequantize().transpose()));
        }
    }
}

#[test]
fn quant_for_transpose_on_non_square_mats() {
    for (rows, cols) in [(13, 21), (8, 40), (9, 33)] {
        let m = ragged_mat(rows, cols, 0x9F1 + rows as u64 * 7 + cols as u64);
        for scheme in [
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxVector(ElementFormat::Int8),
            QuantScheme::MxVector(ElementFormat::E2M1),
            QuantScheme::Dacapo(DacapoFormat::Mx9),
        ] {
            let qt = scheme.quant_for_transpose(&m);
            assert_eq!((qt.rows, qt.cols), (rows, cols), "{}", scheme.name());
            match scheme {
                // square grouping: the transposed consumer reuses the
                // forward quantization verbatim
                QuantScheme::MxSquare(_) => {
                    assert_eq!(bits(&qt), bits(&scheme.quant(&m)), "{}", scheme.name());
                }
                // vector/Dacapo grouping: quantized along the *other*
                // direction — transposing recovers quant of the transpose
                _ => {
                    assert_eq!(
                        bits(&qt.transpose()),
                        bits(&scheme.quant(&m.transpose())),
                        "{}",
                        scheme.name()
                    );
                }
            }
        }
    }
}

#[test]
fn backends_agree_on_ragged_batch_sizes() {
    // batch not a multiple of 8 and hidden width not a multiple of 8:
    // the backends must stay bit-identical under edge-tile padding
    let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
    let mut rng = Pcg64::new(0x8A6);
    let mlp = Mlp::new(&[10, 9, 3], &mut rng);
    let x = Mat::randn(5, 10, 1.0, &mut rng);
    let y = Mat::randn(5, 3, 0.5, &mut rng);
    let mut fake = FakeQuantBackend::new(scheme);
    let mut hw = HardwareBackend::new(scheme).unwrap();
    let mut packed = PackedBackend::new(scheme).unwrap();
    fake.begin_step();
    hw.begin_step();
    packed.begin_step();
    let (tf, gf) = qat_forward_backward_with(&mlp, &x, &y, &mut fake);
    let (th, gh) = qat_forward_backward_with(&mlp, &x, &y, &mut hw);
    let (tp, gp) = qat_forward_backward_with(&mlp, &x, &y, &mut packed);
    assert_eq!(bits(&tf.output), bits(&th.output));
    assert_eq!(bits(&tf.output), bits(&tp.output));
    for ((a, b), c) in gf.d_weights.iter().zip(&gh.d_weights).zip(&gp.d_weights) {
        assert_eq!(bits(a), bits(b));
        assert_eq!(bits(a), bits(c));
    }
    assert_eq!(gf.d_biases, gp.d_biases);
}
