//! ExecBackend equivalence and hardware cost accounting.
//!
//! The contract under test (DESIGN.md §6): `FakeQuantBackend`,
//! `HardwareBackend`, and `PackedBackend` produce **bit-identical**
//! quantized forward and backward results for all six MX element
//! formats — asserted three-way on the tape, the gradients, five full
//! Adam steps, and whole session loss curves — while the hardware
//! backend additionally accumulates a nonzero cycle/event/energy/
//! memory-traffic ledger whose schedule part matches the analytic model
//! GeMM-for-GeMM. Plus ragged-shape quantization coverage (rectangular
//! and non-multiple-of-8/32 matrices through both block layouts).

use mxscale::backend::{
    make_backend, BackendKind, ExecBackend, FakeQuantBackend, HardwareBackend, PackedBackend,
};
use mxscale::trainer::policy::PrecisionPolicy;
use mxscale::gemmcore::memory::gemm_traffic_bits;
use mxscale::gemmcore::schedule::{gemm_cycles_staged, CycleCost, Stage};
use mxscale::mx::dacapo::DacapoFormat;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{fake_quant_mat_fast, Layout, MxTensor};
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::trainer::mlp::Mlp;
use mxscale::trainer::qat::{qat_forward_backward_with, qat_step_with, QuantScheme};
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// Ragged dims on purpose: 12-row batch, 16→24→8 layers — the 8x8 block
/// grid pads in every direction.
fn toy_mlp(seed: u64) -> (Mlp, Mat, Mat) {
    let mut rng = Pcg64::new(seed);
    let mlp = Mlp::new(&[16, 24, 8], &mut rng);
    let x = Mat::randn(12, 16, 1.0, &mut rng);
    let y = Mat::randn(12, 8, 0.5, &mut rng);
    (mlp, x, y)
}

#[test]
fn backends_bit_identical_for_all_six_formats() {
    for fmt in ALL_ELEMENT_FORMATS {
        let scheme = QuantScheme::MxSquare(fmt);
        let (mlp, x, y) = toy_mlp(0xB17 ^ fmt.bits() as u64);
        let mut fake = FakeQuantBackend::new(scheme);
        let mut hw = HardwareBackend::new(scheme).unwrap();
        let mut packed = PackedBackend::new(scheme).unwrap();
        fake.begin_step();
        hw.begin_step();
        packed.begin_step();
        let (tf, gf) = qat_forward_backward_with(&mlp, &x, &y, &mut fake);
        let (th, gh) = qat_forward_backward_with(&mlp, &x, &y, &mut hw);
        let (tp, gp) = qat_forward_backward_with(&mlp, &x, &y, &mut packed);
        for (other, to, go) in [("hw", &th, &gh), ("packed", &tp, &gp)] {
            assert_eq!(bits(&tf.output), bits(&to.output), "{fmt:?} {other} output");
            for (i, (a, b)) in tf.activations.iter().zip(&to.activations).enumerate() {
                assert_eq!(bits(a), bits(b), "{fmt:?} {other} activation {i}");
            }
            for (i, (a, b)) in tf.pre_acts.iter().zip(&to.pre_acts).enumerate() {
                assert_eq!(bits(a), bits(b), "{fmt:?} {other} pre_act {i}");
            }
            for (i, (a, b)) in gf.d_weights.iter().zip(&go.d_weights).enumerate() {
                assert_eq!(bits(a), bits(b), "{fmt:?} {other} d_w {i}");
            }
            for (i, (a, b)) in gf.d_biases.iter().zip(&go.d_biases).enumerate() {
                assert_eq!(a, b, "{fmt:?} {other} d_b {i}");
            }
        }
        // the datapath really ran, and stayed within FP32-accumulation
        // distance of the functional kernel
        let r = hw.cost_report().unwrap();
        assert!(r.cost.total() > 0, "{fmt:?}");
        assert!(r.events.mul_ops > 0, "{fmt:?}");
        assert!(r.datapath_max_rel_err < 1e-3, "{fmt:?}: {}", r.datapath_max_rel_err);
    }
}

#[test]
fn backends_stay_bit_identical_across_training_steps() {
    // Adam compounds any divergence; five full steps must end with
    // bit-identical parameters on all three backends.
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let scheme = QuantScheme::MxSquare(fmt);
        let (mlp0, x, y) = toy_mlp(0x57E9 ^ fmt.bits() as u64);
        let mut mlp_f = mlp0.clone();
        let mut mlp_h = mlp0.clone();
        let mut mlp_p = mlp0;
        let mut fake = FakeQuantBackend::new(scheme);
        let mut hw = HardwareBackend::new(scheme).unwrap();
        let mut packed = PackedBackend::new(scheme).unwrap();
        for step in 0..5 {
            let lf = qat_step_with(&mut mlp_f, &x, &y, &mut fake, 2e-3);
            let lh = qat_step_with(&mut mlp_h, &x, &y, &mut hw, 2e-3);
            let lp = qat_step_with(&mut mlp_p, &x, &y, &mut packed, 2e-3);
            assert_eq!(lf, lh, "{fmt:?} step {step} hw loss");
            assert_eq!(lf, lp, "{fmt:?} step {step} packed loss");
        }
        let pbits = |m: &Mlp| m.flat_params().iter().map(|v| v.to_bits()).collect::<Vec<u32>>();
        assert_eq!(pbits(&mlp_f), pbits(&mlp_h), "{fmt:?} params after 5 steps (hw)");
        assert_eq!(pbits(&mlp_f), pbits(&mlp_p), "{fmt:?} params after 5 steps (packed)");
        assert_eq!(hw.cost_report().unwrap().steps, 5);
    }
}

#[test]
fn hw_schedule_matches_analytic_model_gemm_for_gemm() {
    // one training step of a [16, 24, 8] MLP at batch 12: fwd + wgrad on
    // every layer, error-backprop only above layer 0 (the graph-accurate
    // difference from the closed-form train_step_cycles).
    let fmt = ElementFormat::E4M3;
    let (mut mlp, x, y) = toy_mlp(0xACC);
    let mut hw = HardwareBackend::new(QuantScheme::MxSquare(fmt)).unwrap();
    qat_step_with(&mut mlp, &x, &y, &mut hw, 1e-3);
    let mut want = CycleCost::default();
    let mut want_traffic = 0u64;
    let batch = 12usize;
    let dims = [16usize, 24, 8];
    for (l, w) in dims.windows(2).enumerate() {
        let (din, dout) = (w[0], w[1]);
        want.add(&gemm_cycles_staged(batch, din, dout, fmt, Stage::Forward));
        want_traffic += gemm_traffic_bits(batch, din, dout, fmt, Stage::Forward);
        want.add(&gemm_cycles_staged(din, batch, dout, fmt, Stage::WeightGrad));
        want_traffic += gemm_traffic_bits(din, batch, dout, fmt, Stage::WeightGrad);
        if l > 0 {
            want.add(&gemm_cycles_staged(batch, dout, din, fmt, Stage::Backward));
            want_traffic += gemm_traffic_bits(batch, dout, din, fmt, Stage::Backward);
        }
    }
    let r = hw.cost_report().unwrap();
    assert_eq!(r.cost, want, "schedule cost must match the analytic model");
    assert_eq!(r.mem_traffic_bits, want_traffic);
    assert_eq!(r.gemms, 2 * 2 + 1); // 2 layers x (fwd + wgrad) + 1 bwd
    // datapath event count agrees with the schedule's padded OP count
    assert_eq!(r.events.mul_ops, r.cost.mul_ops);
}

#[test]
fn hw_session_emits_nonzero_cost_report() {
    // the acceptance criterion: a TrainSession on --backend hw reports
    // nonzero cycle / energy / memory-traffic totals in the JSON.
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 0xD5);
    let mut s = TrainSession::new(
        ds,
        TrainConfig {
            scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
            backend: BackendKind::Hardware,
            dims: Some(vec![32, 16, 32]),
            steps: 3,
            eval_every: usize::MAX,
            ..Default::default()
        },
    );
    s.run();
    let r = s.hw_report().expect("hardware backend must account cost");
    assert_eq!(r.steps, 3);
    assert!(r.cost.total() > 0);
    assert!(r.energy_pj() > 0.0);
    assert!(r.mem_traffic_bits > 0);
    assert!(r.resident_kb > 0.0);
    assert!(r.us_per_step() > 0.0 && r.steps_per_sec() > 0.0);
    let json = r.to_json().to_string();
    let keys = ["\"cycles\"", "\"energy\"", "\"traffic_bits\"", "\"steps\":3", "\"backend\":\"hw\""];
    for key in keys {
        assert!(json.contains(key), "JSON missing {key}: {json}");
    }
    // and none of the headline totals serialized as zero
    assert!(!json.contains("\"total\":0,"), "{json}");
    assert!(!json.contains("\"traffic_bits\":0,"), "{json}");
}

#[test]
fn all_backends_match_on_training_session_losses() {
    // same session config, all three backends: identical loss curves
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 0xD6);
    let run = |backend: BackendKind| {
        let mut s = TrainSession::new(
            ds.clone(),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E2M1),
                backend,
                dims: Some(vec![32, 16, 32]),
                steps: 4,
                eval_every: 2,
                ..Default::default()
            },
        );
        s.run();
        (s.val_curve.clone(), s.val_loss(), s.train_curve.clone())
    };
    let (curve_f, loss_f, train_f) = run(BackendKind::Fast);
    let (curve_h, loss_h, train_h) = run(BackendKind::Hardware);
    let (curve_p, loss_p, train_p) = run(BackendKind::Packed);
    assert_eq!(curve_f, curve_h);
    assert_eq!(loss_f, loss_h);
    assert_eq!(train_f, train_h);
    assert_eq!(curve_f, curve_p);
    assert_eq!(loss_f, loss_p);
    assert_eq!(train_f, train_p);
}

#[test]
fn packed_session_loss_curves_match_fast_for_all_six_formats() {
    // the acceptance criterion spelled out: --backend packed is
    // bit-identical to fast on whole session loss curves, per format
    let env = by_name("reacher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 3, 30, 0xD7);
    for fmt in ALL_ELEMENT_FORMATS {
        let run = |backend: BackendKind| {
            let mut s = TrainSession::new(
                ds.clone(),
                TrainConfig {
                    scheme: QuantScheme::MxSquare(fmt),
                    backend,
                    dims: Some(vec![32, 16, 32]),
                    steps: 6,
                    eval_every: 2,
                    ..Default::default()
                },
            );
            s.run();
            (s.train_curve.clone(), s.val_curve.clone())
        };
        assert_eq!(run(BackendKind::Fast), run(BackendKind::Packed), "{fmt:?}");
    }
}

// ---------------------------------------------------------------------
// Transition oracles: a mid-session MX format switch is bit-identical
// (a) to starting fresh at the new format with the same master/Adam
// state, (b) across all three backends, and (c) to checkpoint→resume
// across the transition boundary — for all six formats. This is the
// contract that makes runtime precision scheduling *safe*: a schedule
// changes throughput and quantization error, never the semantics of
// the training graph (DESIGN.md §8).
// ---------------------------------------------------------------------

const ALL_BACKENDS: [BackendKind; 3] =
    [BackendKind::Fast, BackendKind::Hardware, BackendKind::Packed];

fn pbits(m: &Mlp) -> Vec<u32> {
    m.flat_params().iter().map(|v| v.to_bits()).collect()
}

/// A start format different from `target`, so the transition is real.
fn other_fmt(target: ElementFormat) -> ElementFormat {
    if target == ElementFormat::E4M3 {
        ElementFormat::Int8
    } else {
        ElementFormat::E4M3
    }
}

#[test]
fn transition_equals_fresh_start_at_the_new_format() {
    // session A trains 4 steps at a start format, transitions, and
    // trains 4 more; session B is built from A's step-4 master/Adam
    // state as if it had *always* been a target-format session. The
    // continuation must match bit for bit on every backend × format —
    // the "requantize from the FP32 master" definition of a transition.
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 0x7A1);
    for backend in ALL_BACKENDS {
        for fmt in ALL_ELEMENT_FORMATS {
            let target = QuantScheme::MxSquare(fmt);
            let start = QuantScheme::MxSquare(other_fmt(fmt));
            let label = format!("{} {}->{}", backend.name(), start.name(), target.name());
            let mut a = TrainSession::new(
                ds.clone(),
                TrainConfig {
                    scheme: start,
                    backend,
                    dims: Some(vec![32, 16, 32]),
                    steps: 0,
                    eval_every: 4,
                    ..Default::default()
                },
            );
            for _ in 0..4 {
                a.step_once();
            }
            // B: the same master/Adam state, reborn at the target format
            let mut ck = a.save_checkpoint();
            ck.config.scheme = target;
            ck.scheme_log = vec![(0, target.name())];
            ck.payload = Vec::new();
            let mut b = TrainSession::resume(ds.clone(), &ck).unwrap();
            a.transition_scheme(target).unwrap_or_else(|e| panic!("{label}: {e}"));
            for _ in 0..4 {
                a.step_once();
                b.step_once();
            }
            assert_eq!(pbits(&a.mlp), pbits(&b.mlp), "{label} params");
            assert_eq!(a.train_curve, b.train_curve, "{label} train curve");
            assert_eq!(a.val_curve, b.val_curve, "{label} val curve");
            assert_eq!(a.val_loss().to_bits(), b.val_loss().to_bits(), "{label} final val");
            assert_eq!(a.scheme_history().len(), 2, "{label} history");
        }
    }
}

#[test]
fn transition_stays_three_way_bit_identical_across_backends() {
    // mid-session switch with live per-layer caches: fast/hw/packed
    // must agree bitwise on losses and Adam params through the boundary
    for fmt in ALL_ELEMENT_FORMATS {
        let target = QuantScheme::MxSquare(fmt);
        let start = QuantScheme::MxSquare(other_fmt(fmt));
        let (mlp0, x, y) = toy_mlp(0x7A2 ^ fmt.bits() as u64);
        let mut outcomes: Vec<(Vec<u64>, Vec<u32>)> = Vec::new();
        for kind in ALL_BACKENDS {
            let mut be = make_backend(kind, start).unwrap();
            let mut mlp = mlp0.clone();
            let mut losses = Vec::new();
            for _ in 0..3 {
                losses.push(qat_step_with(&mut mlp, &x, &y, be.as_mut(), 2e-3).to_bits());
            }
            be.transition(target).unwrap_or_else(|e| panic!("{}: {e}", kind.name()));
            for _ in 0..3 {
                losses.push(qat_step_with(&mut mlp, &x, &y, be.as_mut(), 2e-3).to_bits());
            }
            outcomes.push((losses, pbits(&mlp)));
        }
        for (kind, o) in ALL_BACKENDS.iter().zip(&outcomes).skip(1) {
            assert_eq!(o.0, outcomes[0].0, "{fmt:?} {} losses", kind.name());
            assert_eq!(o.1, outcomes[0].1, "{fmt:?} {} params", kind.name());
        }
    }
}

#[test]
fn hw_transition_attributes_cost_per_format_segment() {
    // the precision-scheduled hw session must keep its ledger split by
    // format: cycles/energy/traffic of each segment stay attributed to
    // the format that incurred them, and the totals are their sums
    let (mlp0, x, y) = toy_mlp(0x7A3);
    let start = QuantScheme::MxSquare(ElementFormat::Int8);
    let target = QuantScheme::MxSquare(ElementFormat::E2M1);
    let mut hw = HardwareBackend::new(start).unwrap();
    let mut mlp = mlp0;
    for _ in 0..2 {
        qat_step_with(&mut mlp, &x, &y, &mut hw, 1e-3);
    }
    hw.transition(target).unwrap();
    for _ in 0..3 {
        qat_step_with(&mut mlp, &x, &y, &mut hw, 1e-3);
    }
    let r = hw.cost_report().unwrap();
    assert_eq!(r.steps, 5);
    assert_eq!(r.segments.len(), 2);
    let (s0, s1) = (&r.segments[0], &r.segments[1]);
    assert_eq!((s0.scheme.as_str(), s0.steps), ("mx-int8", 2));
    assert_eq!((s1.scheme.as_str(), s1.steps), ("mx-e2m1", 3));
    assert!(s0.cost.total() > 0 && s1.cost.total() > 0);
    assert_eq!(s0.cost.total() + s1.cost.total(), r.cost.total());
    assert_eq!(s0.traffic_bits + s1.traffic_bits, r.mem_traffic_bits);
    assert!((s0.energy_pj() + s1.energy_pj() - r.energy_pj()).abs() < 1e-6);
    // INT8 mode runs 8 cycles/block vs FP4's 1: per-step cycles of the
    // int8 segment must dominate
    assert!(
        s0.cost.total() / s0.steps > s1.cost.total() / s1.steps,
        "int8 {} vs e2m1 {}",
        s0.cost.total(),
        s1.cost.total()
    );
    let json = r.to_json().to_string();
    assert!(json.contains("\"segments\""), "{json}");
    assert!(json.contains("\"mx-e2m1\""), "{json}");
}

#[test]
fn all_backends_refuse_a_mid_step_transition() {
    // the trait contract: a pending forward tape (forward ran, backward
    // has not) must refuse to switch formats — a transition there would
    // mix formats inside one backward pass
    let (mlp, x, y) = toy_mlp(0x7A5);
    for kind in ALL_BACKENDS {
        let start = QuantScheme::MxSquare(ElementFormat::E4M3);
        let mut be = make_backend(kind, start).unwrap();
        be.begin_step();
        let tape = mlp.forward_exec(&x, be.as_mut());
        let e = be
            .transition(QuantScheme::MxSquare(ElementFormat::Int8))
            .expect_err(&format!("{}: mid-step transition must refuse", kind.name()));
        assert!(e.contains("mid-step"), "{}: {e}", kind.name());
        // draining the tape re-arms the transition
        let _ = mlp.backward_exec(&tape, &y, be.as_mut());
        be.transition(QuantScheme::MxSquare(ElementFormat::Int8))
            .unwrap_or_else(|e| panic!("{}: post-step transition: {e}", kind.name()));
    }
}

#[test]
fn checkpoint_resume_across_a_transition_boundary_is_bit_identical() {
    // a scheduled session checkpointed either side of its transition
    // and resumed must reproduce the uninterrupted run exactly — the
    // "resume mid-schedule" contract, for all six formats × backends
    let env = by_name("reacher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 3, 30, 0x7A4);
    for backend in ALL_BACKENDS {
        for fmt in ALL_ELEMENT_FORMATS {
            let target = QuantScheme::MxSquare(fmt);
            let start = QuantScheme::MxSquare(other_fmt(fmt));
            let label = format!("{} ->{}", backend.name(), target.name());
            let cfg = TrainConfig {
                scheme: start,
                backend,
                dims: Some(vec![32, 16, 32]),
                steps: 8,
                eval_every: 3,
                ..Default::default()
            };
            let spec = format!("4:{}", target.name());
            let run_to = |session: &mut TrainSession, to: usize| {
                let mut policy = PrecisionPolicy::parse(&spec).unwrap();
                while session.step_count() < to {
                    session.step_with_policy(&mut policy).unwrap();
                }
            };
            // uninterrupted reference
            let mut full = TrainSession::new(ds.clone(), cfg.clone());
            run_to(&mut full, 8);
            // checkpoint *before* the boundary (step 2): the resumed
            // session re-joins the schedule and transitions on time
            let mut pre = TrainSession::new(ds.clone(), cfg.clone());
            run_to(&mut pre, 2);
            let mut pre = TrainSession::resume(ds.clone(), &pre.save_checkpoint()).unwrap();
            run_to(&mut pre, 8);
            // checkpoint *after* the boundary (step 6): the checkpoint
            // itself carries the mid-schedule format
            let mut post = TrainSession::new(ds.clone(), cfg.clone());
            run_to(&mut post, 6);
            let ck = post.save_checkpoint();
            assert_eq!(ck.config.scheme, target, "{label}: active format in the image");
            assert_eq!(ck.scheme_log.len(), 2, "{label}");
            // through the v2 binary format: the segment log survives disk
            let ck = mxscale::trainer::checkpoint::Checkpoint::from_bytes(&ck.to_bytes())
                .unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(ck.scheme_log.len(), 2, "{label}: serialized log");
            let mut post = TrainSession::resume(ds.clone(), &ck).unwrap();
            run_to(&mut post, 8);
            for (other, s) in [("pre", &pre), ("post", &post)] {
                assert_eq!(pbits(&full.mlp), pbits(&s.mlp), "{label} {other} params");
                assert_eq!(full.train_curve, s.train_curve, "{label} {other} train curve");
                assert_eq!(full.val_curve, s.val_curve, "{label} {other} val curve");
                assert_eq!(
                    full.scheme_history(),
                    s.scheme_history(),
                    "{label} {other} history"
                );
            }
        }
    }
}

// ---------------------------------------------------------------------
// Ragged-shape quantization coverage (satellite): rectangular and
// non-multiple-of-8/32 matrices through both layouts.
// ---------------------------------------------------------------------

const RAGGED_SHAPES: [(usize, usize); 7] =
    [(1, 1), (7, 5), (13, 21), (8, 40), (40, 8), (5, 64), (9, 33)];

fn ragged_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.wide_f32().clamp(-1e6, 1e6))
}

#[test]
fn ragged_shapes_quantize_consistently_in_both_layouts() {
    for (rows, cols) in RAGGED_SHAPES {
        let m = ragged_mat(rows, cols, 0x4A6 + rows as u64 * 131 + cols as u64);
        for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
            for layout in [Layout::Square8x8, Layout::Vector32] {
                let q = MxTensor::quantize(&m, fmt, layout);
                let d = q.dequantize();
                assert_eq!((d.rows, d.cols), (rows, cols), "{fmt:?} {layout:?}");
                // codec path == fast fake-quant path, bit for bit
                let fast = fake_quant_mat_fast(&m, fmt, layout);
                assert_eq!(bits(&d), bits(&fast), "{fmt:?} {layout:?} {rows}x{cols}");
                // padding must not corrupt in-bounds values
                assert!(
                    d.mse(&m) < (m.max_abs() as f64).powi(2).max(1e-30) * 0.01,
                    "{fmt:?} {layout:?} {rows}x{cols}: mse {}",
                    d.mse(&m)
                );
            }
        }
    }
}

#[test]
fn ragged_square_transpose_is_still_bit_identical() {
    // the paper's free-transpose claim must survive edge padding
    for (rows, cols) in RAGGED_SHAPES {
        for fmt in ALL_ELEMENT_FORMATS {
            let m = ragged_mat(rows, cols, 0x7A0 + rows as u64 + fmt.bits() as u64 * 997);
            let q = MxTensor::quantize(&m, fmt, Layout::Square8x8);
            let qt = q.transpose().unwrap();
            assert_eq!((qt.rows, qt.cols), (cols, rows));
            let direct = MxTensor::quantize(&m.transpose(), fmt, Layout::Square8x8);
            assert_eq!(bits(&qt.dequantize()), bits(&direct.dequantize()), "{fmt:?} {rows}x{cols}");
            assert_eq!(bits(&qt.dequantize()), bits(&q.dequantize().transpose()));
        }
    }
}

#[test]
fn quant_for_transpose_on_non_square_mats() {
    for (rows, cols) in [(13, 21), (8, 40), (9, 33)] {
        let m = ragged_mat(rows, cols, 0x9F1 + rows as u64 * 7 + cols as u64);
        for scheme in [
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxVector(ElementFormat::Int8),
            QuantScheme::MxVector(ElementFormat::E2M1),
            QuantScheme::Dacapo(DacapoFormat::Mx9),
        ] {
            let qt = scheme.quant_for_transpose(&m);
            assert_eq!((qt.rows, qt.cols), (rows, cols), "{}", scheme.name());
            match scheme {
                // square grouping: the transposed consumer reuses the
                // forward quantization verbatim
                QuantScheme::MxSquare(_) => {
                    assert_eq!(bits(&qt), bits(&scheme.quant(&m)), "{}", scheme.name());
                }
                // vector/Dacapo grouping: quantized along the *other*
                // direction — transposing recovers quant of the transpose
                _ => {
                    assert_eq!(
                        bits(&qt.transpose()),
                        bits(&scheme.quant(&m.transpose())),
                        "{}",
                        scheme.name()
                    );
                }
            }
        }
    }
}

#[test]
fn backends_agree_on_ragged_batch_sizes() {
    // batch not a multiple of 8 and hidden width not a multiple of 8:
    // the backends must stay bit-identical under edge-tile padding
    let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
    let mut rng = Pcg64::new(0x8A6);
    let mlp = Mlp::new(&[10, 9, 3], &mut rng);
    let x = Mat::randn(5, 10, 1.0, &mut rng);
    let y = Mat::randn(5, 3, 0.5, &mut rng);
    let mut fake = FakeQuantBackend::new(scheme);
    let mut hw = HardwareBackend::new(scheme).unwrap();
    let mut packed = PackedBackend::new(scheme).unwrap();
    fake.begin_step();
    hw.begin_step();
    packed.begin_step();
    let (tf, gf) = qat_forward_backward_with(&mlp, &x, &y, &mut fake);
    let (th, gh) = qat_forward_backward_with(&mlp, &x, &y, &mut hw);
    let (tp, gp) = qat_forward_backward_with(&mlp, &x, &y, &mut packed);
    assert_eq!(bits(&tf.output), bits(&th.output));
    assert_eq!(bits(&tf.output), bits(&tp.output));
    for ((a, b), c) in gf.d_weights.iter().zip(&gh.d_weights).zip(&gp.d_weights) {
        assert_eq!(bits(a), bits(b));
        assert_eq!(bits(a), bits(c));
    }
    assert_eq!(gf.d_biases, gp.d_biases);
}
