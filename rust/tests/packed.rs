//! Packed-tensor properties and the SWAR-kernel bit-identity theorem.
//!
//! Two families of assertions, both with `==` on bits (no tolerances):
//!
//! 1. **Round trips** — `quantize → pack → unpack` reproduces the codec
//!    tensor code-for-code and scale-for-scale for all six element
//!    formats on ragged shapes; the fused `quantize_pack` equals
//!    `pack(quantize(..))`; `dequantize` equals the codec dequantize
//!    bit for bit; the packed transpose is the same pure block
//!    permutation the paper's storage claim rests on.
//! 2. **GeMM identity** — `packed_gemm` / `packed_gemm_nt` /
//!    `packed_dot` equal the dense block-ordered kernels
//!    (`Mat::matmul_blocked*`, chunk 8) on the dequantized operands:
//!    the in-block integer SWAR dots are exactly the f64 block partials
//!    of the dense kernel, so equality is a theorem over fake-quant
//!    values, not a tolerance.

use mxscale::mx::packed::{packed_dot, packed_gemm, packed_gemm_nt, PackedTensor};
use mxscale::mx::tensor::{Layout, MxTensor};
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;

/// Magnitudes spanning many binades — the adversarial input for
/// shared-exponent kernels (subnormal codes next to near-max codes).
fn wide_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.wide_f32().clamp(-1e6, 1e6))
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

const RAGGED_SHAPES: [(usize, usize); 7] =
    [(1, 1), (7, 5), (13, 21), (8, 40), (40, 8), (5, 64), (9, 33)];

#[test]
fn pack_unpack_round_trips_all_six_codecs_on_ragged_shapes() {
    for fmt in ALL_ELEMENT_FORMATS {
        for (rows, cols) in RAGGED_SHAPES {
            let m = wide_mat(rows, cols, 0xAC4 + rows as u64 * 131 + fmt.bits() as u64);
            let q = MxTensor::quantize(&m, fmt, Layout::Square8x8);
            let p = q.pack().unwrap();
            let back = p.unpack();
            assert_eq!(back.blocks, q.blocks, "{fmt:?} {rows}x{cols} codes/scales");
            assert_eq!((back.rows, back.cols), (rows, cols));
            // the fused quantize_pack is the same packing, bit for bit
            let fused = PackedTensor::quantize_pack(&m, fmt);
            assert_eq!(fused, p, "{fmt:?} {rows}x{cols} fused packing");
            // dequantize through the packed image equals the codec path
            assert_eq!(bits(&p.dequantize()), bits(&q.dequantize()), "{fmt:?} {rows}x{cols}");
        }
    }
}

#[test]
fn packed_rejects_vector_layout() {
    let m = wide_mat(8, 32, 3);
    let q = MxTensor::quantize(&m, ALL_ELEMENT_FORMATS[0], Layout::Vector32);
    let e = q.pack().err().unwrap();
    assert!(e.contains("square"), "{e}");
}

#[test]
fn packed_transpose_is_the_block_permutation() {
    for fmt in ALL_ELEMENT_FORMATS {
        for (rows, cols) in [(13, 21), (8, 40), (24, 16)] {
            let m = wide_mat(rows, cols, 0x7A9 + cols as u64 + fmt.bits() as u64 * 997);
            let q = MxTensor::quantize(&m, fmt, Layout::Square8x8);
            let via_packed = q.pack().unwrap().transpose();
            let via_tensor = q.transpose().unwrap().pack().unwrap();
            assert_eq!(via_packed, via_tensor, "{fmt:?} {rows}x{cols}");
        }
    }
}

#[test]
fn packed_storage_is_dense() {
    // 64 codes at the format width in 8 lanes + one scale byte per block
    let m = wide_mat(16, 16, 9);
    for fmt in ALL_ELEMENT_FORMATS {
        let p = PackedTensor::quantize_pack(&m, fmt);
        assert_eq!(p.lanes.len(), 4 * 8, "{fmt:?}");
        assert_eq!(p.storage_bytes(), 4 * 8 * 8 + 4, "{fmt:?}");
        // no code strays outside its lane width
        let w = fmt.bits();
        if w < 8 {
            for lane in &p.lanes {
                assert_eq!(lane >> (8 * w), 0, "{fmt:?} lane overflow");
            }
        }
    }
}

// ---------------------------------------------------------------- GeMMs

#[test]
fn packed_gemm_is_bit_identical_to_dense_blocked_kernel() {
    // THE theorem: sub-word integer block dots == f64 dense block
    // partials, for every format, on ragged shapes, over wide data
    for fmt in ALL_ELEMENT_FORMATS {
        for (m, k, n) in [(12, 16, 24), (13, 21, 9), (8, 40, 7), (1, 1, 1), (9, 33, 17)] {
            let a = wide_mat(m, k, 0x6E0 + m as u64 * 7 + fmt.bits() as u64);
            let b = wide_mat(k, n, 0x6E1 + n as u64 * 11 + fmt.bits() as u64);
            let pa = PackedTensor::quantize_pack(&a, fmt);
            let pb = PackedTensor::quantize_pack(&b, fmt);
            let got = packed_gemm(&pa, &pb);
            let want = pa.dequantize().matmul_blocked(&pb.dequantize(), 8);
            assert_eq!(bits(&got), bits(&want), "{fmt:?} {m}x{k}x{n}");
        }
    }
}

#[test]
fn packed_gemm_nt_consumes_the_transpose_for_free() {
    for fmt in ALL_ELEMENT_FORMATS {
        for (m, k, n) in [(12, 16, 24), (13, 21, 9), (5, 64, 8)] {
            let a = wide_mat(m, k, 0x9E0 + m as u64 + fmt.bits() as u64);
            let bt = wide_mat(n, k, 0x9E1 + n as u64 + fmt.bits() as u64);
            let pa = PackedTensor::quantize_pack(&a, fmt);
            let pbt = PackedTensor::quantize_pack(&bt, fmt);
            let got = packed_gemm_nt(&pa, &pbt);
            let want = pa.dequantize().matmul_blocked_nt(&pbt.dequantize(), 8);
            assert_eq!(bits(&got), bits(&want), "{fmt:?} {m}x{k}x{n}");
            // and it equals multiplying against the permuted copy — the
            // single-copy claim: no second packed image is ever needed
            let via_transpose = packed_gemm(&pa, &pbt.transpose());
            assert_eq!(bits(&got), bits(&via_transpose), "{fmt:?} {m}x{k}x{n} vs transpose");
        }
    }
}

#[test]
fn packed_tn_path_matches_dense_tn_kernel() {
    // the weight-gradient shape: Aᵀ @ E via the free block-permutation
    // transpose of the stored packed activation
    for fmt in ALL_ELEMENT_FORMATS {
        let a = wide_mat(12, 16, 0xAE0 + fmt.bits() as u64); // [batch, din]
        let e = wide_mat(12, 24, 0xAE1 + fmt.bits() as u64); // [batch, dout]
        let pa = PackedTensor::quantize_pack(&a, fmt);
        let pe = PackedTensor::quantize_pack(&e, fmt);
        let got = packed_gemm(&pa.transpose(), &pe);
        let want = pa.dequantize().matmul_blocked_tn(&pe.dequantize(), 8);
        assert_eq!(bits(&got), bits(&want), "{fmt:?}");
    }
}

#[test]
fn packed_dot_matches_gemm_elements() {
    for fmt in [ALL_ELEMENT_FORMATS[0], ALL_ELEMENT_FORMATS[2], ALL_ELEMENT_FORMATS[5]] {
        let a = wide_mat(9, 33, 0xBE0 + fmt.bits() as u64);
        let b = wide_mat(7, 33, 0xBE1 + fmt.bits() as u64);
        let pa = PackedTensor::quantize_pack(&a, fmt);
        let pb = PackedTensor::quantize_pack(&b, fmt);
        let full = packed_gemm_nt(&pa, &pb);
        for r in [0usize, 4, 8] {
            for c in [0usize, 3, 6] {
                let d = packed_dot(&pa, r, &pb, c);
                assert_eq!(d.to_bits(), full.at(r, c).to_bits(), "{fmt:?} ({r},{c})");
            }
        }
    }
}

#[test]
fn packed_col_sums_match_dense_col_sums() {
    for fmt in ALL_ELEMENT_FORMATS {
        let m = wide_mat(13, 21, 0xCE0 + fmt.bits() as u64);
        let p = PackedTensor::quantize_pack(&m, fmt);
        let want = p.dequantize().col_sums();
        let got = p.col_sums();
        let b = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(b(&got), b(&want), "{fmt:?}");
    }
}

#[test]
fn mxtensor_convenience_layer_works() {
    let fmt = ALL_ELEMENT_FORMATS[0];
    let a = wide_mat(16, 16, 0xDE0);
    let b = wide_mat(16, 16, 0xDE1);
    let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
    let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
    let got = qa.packed_gemm(&qb).unwrap();
    let want = qa.dequantize().matmul_blocked(&qb.dequantize(), 8);
    assert_eq!(bits(&got), bits(&want));
    let qbt = qb.transpose().unwrap();
    let d = qa.packed_dot(3, &qbt, 5).unwrap();
    assert_eq!(d.to_bits(), got.at(3, 5).to_bits());
}
