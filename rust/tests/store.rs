//! End-to-end conformance tests for the sharded checkpoint store
//! (DESIGN.md §11): a resume that goes disk → index → chunks must be
//! bitwise indistinguishable from one that never touched storage, for
//! all six MX formats and all three execution backends; partial reads
//! must be *measured* (via `CountingStore`), not assumed; legacy
//! monolithic `.mxckpt` files (v1 and v2) must load through the compat
//! shim; corruption must surface as structured errors, never a panic
//! and never a silent fallback; and concurrent writers on one shard
//! must serialize through the advisory lock without losing a robot.

use std::sync::Arc;
use std::time::Duration;

use mxscale::backend::BackendKind;
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::store::shard::{read_index, ENTRY_BYTES, TRAILER_BYTES};
use mxscale::store::{
    CheckpointStore, CountingStore, MemoryStore, Storage, StoreError, StoreLayout, StoreLock,
};
use mxscale::trainer::checkpoint::{weight_payload, Checkpoint};
use mxscale::trainer::mlp::Mlp;
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::{TrainConfig, TrainError, TrainSession};
use mxscale::util::bytes::ByteWriter;
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

fn dataset(seed: u64) -> Dataset {
    let env = by_name("reacher").unwrap();
    Dataset::collect(env.as_ref(), 5, 40, seed)
}

fn scratch(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("mxscale-store-test-{name}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A small hand-built checkpoint (no training loop) for store-shape
/// tests: `scheme` decides the payload arity, `tag` varies the content.
fn tiny_checkpoint(scheme: QuantScheme, tag: u64) -> Checkpoint {
    let mut rng = Pcg64::new(tag.wrapping_add(1));
    let dims = vec![8usize, 4, 8];
    let mlp = Mlp::new(&dims, &mut rng);
    let config = TrainConfig {
        scheme,
        backend: BackendKind::parse("fast").unwrap(),
        dims: Some(dims),
        batch_size: 4,
        lr: 1e-3,
        steps: 10,
        eval_every: 5,
        seed: tag,
    };
    Checkpoint {
        config,
        step: tag as usize % 97,
        adam_step: tag,
        train_curve: vec![(0, 1.0 + tag as f64)],
        val_curve: vec![],
        params: mlp.flat_params(),
        opt: mlp.flat_opt_state(),
        scheme_log: vec![(0, scheme.name())],
        payload: weight_payload(&mlp.weights, scheme),
    }
}

/// Exact byte budget for resuming `id`: the shard trailer, the live
/// index of the one shard holding `id`, and `id`'s own chunks — nothing
/// else. Computed from the store's actual contents, not the writer's.
fn expected_resume_bytes(cs: &CheckpointStore, id: &str) -> u64 {
    let own: u64 = cs.chunk_manifest(id).unwrap().iter().map(|(_, len)| *len).sum();
    let prefix = format!("{id}/");
    let storage = cs.storage();
    for shard in cs.shard_files().unwrap() {
        let entries = read_index(storage.as_ref(), &shard).unwrap();
        if entries.iter().any(|e| e.key.starts_with(&prefix)) {
            return TRAILER_BYTES as u64 + entries.len() as u64 * ENTRY_BYTES as u64 + own;
        }
    }
    panic!("session {id} not found in any shard");
}

// ------------------------------------------------- bit-exact resume

/// Train to step `k`, persist through a sharded store that also holds
/// decoy robots, reload (counting every byte), resume, and train `m`
/// more steps: the result must be bitwise identical to never pausing,
/// and the reload must read only the index plus the session's chunks.
fn assert_store_resume_matches(scheme: QuantScheme, backend: BackendKind, k: usize, m: usize) {
    let label = format!("{}/{}", scheme.name(), backend.name());
    let config = TrainConfig {
        scheme,
        backend,
        dims: Some(vec![32, 16, 32]),
        batch_size: 8,
        steps: 0,
        eval_every: 3,
        ..Default::default()
    };
    let ds = dataset(0x570E);

    let mut full = TrainSession::try_new(ds.clone(), config.clone()).unwrap();
    let mut half = TrainSession::try_new(ds.clone(), config).unwrap();
    for _ in 0..k {
        full.step_once();
        half.step_once();
    }
    let ck = half.save_checkpoint();

    let counting = Arc::new(CountingStore::new(Arc::new(MemoryStore::new())));
    let cs = CheckpointStore::new(counting.clone(), StoreLayout::Sharded { shards: 2 });
    let decoys: Vec<(String, Checkpoint)> =
        (0..6).map(|i| (format!("decoy-{i}"), tiny_checkpoint(QuantScheme::Fp32, i))).collect();
    let mut batch: Vec<(String, &Checkpoint)> =
        decoys.iter().map(|(id, d)| (id.clone(), d)).collect();
    batch.push(("hero".to_string(), &ck));
    cs.save_many(&batch).unwrap();

    let budget = expected_resume_bytes(&cs, "hero");
    counting.reset();
    let reread = cs.load("hero").unwrap();
    assert_eq!(counting.bytes_read(), budget, "{label}: resume read more than index + own chunks");
    assert_eq!(reread.to_bytes(), ck.to_bytes(), "{label}: store round trip");

    let mut resumed = TrainSession::resume(ds.clone(), &reread).unwrap();
    for _ in 0..m {
        full.step_once();
        resumed.step_once();
    }
    assert_eq!(resumed.mlp.flat_params(), full.mlp.flat_params(), "{label}: params");
    assert_eq!(resumed.mlp.flat_opt_state(), full.mlp.flat_opt_state(), "{label}: moments");
    assert_eq!(resumed.train_curve, full.train_curve, "{label}: train curve");
    assert_eq!(resumed.val_curve, full.val_curve, "{label}: val curve");
    assert_eq!(resumed.val_loss(), full.val_loss(), "{label}: val loss");
}

#[test]
fn store_resume_is_bit_exact_all_six_formats_fast_backend() {
    for fmt in ALL_ELEMENT_FORMATS {
        assert_store_resume_matches(QuantScheme::MxSquare(fmt), BackendKind::Fast, 7, 5);
    }
}

#[test]
fn store_resume_is_bit_exact_all_six_formats_hw_backend() {
    for fmt in ALL_ELEMENT_FORMATS {
        assert_store_resume_matches(QuantScheme::MxSquare(fmt), BackendKind::Hardware, 3, 2);
    }
}

#[test]
fn store_resume_is_bit_exact_all_six_formats_packed_backend() {
    for fmt in ALL_ELEMENT_FORMATS {
        assert_store_resume_matches(QuantScheme::MxSquare(fmt), BackendKind::Packed, 7, 5);
    }
}

#[test]
fn store_resume_is_bit_exact_for_baseline_schemes() {
    for scheme in [
        QuantScheme::Fp32,
        QuantScheme::MxVector(mxscale::mx::ElementFormat::E4M3),
        QuantScheme::Dacapo(mxscale::mx::DacapoFormat::Mx9),
    ] {
        assert_store_resume_matches(scheme, BackendKind::Fast, 5, 4);
    }
}

// ------------------------------------------------- legacy compat shim

/// Serialize a v1 `.mxckpt` body by hand (v1 predates the scheme log).
fn v1_bytes(ck: &Checkpoint) -> Vec<u8> {
    let mut w = ByteWriter::new();
    for b in *b"MXCK" {
        w.put_u8(b);
    }
    w.put_u32(1);
    w.put_str(&ck.config.scheme.name());
    w.put_str(ck.config.backend.name());
    let dims = ck.dims();
    w.put_u32(dims.len() as u32);
    for &d in dims {
        w.put_u32(d as u32);
    }
    w.put_u32(ck.config.batch_size as u32);
    w.put_f32(ck.config.lr);
    w.put_u64(ck.config.eval_every as u64);
    w.put_u64(ck.config.steps as u64);
    w.put_u64(ck.config.seed);
    w.put_u64(ck.step as u64);
    w.put_u64(ck.adam_step);
    for curve in [&ck.train_curve, &ck.val_curve] {
        w.put_u64(curve.len() as u64);
        for &(step, loss) in curve.iter() {
            w.put_u64(step as u64);
            w.put_f64(loss);
        }
    }
    w.put_f32s(&ck.params);
    w.put_f32s(&ck.opt);
    w.put_u32(ck.payload.len() as u32);
    for t in &ck.payload {
        t.write_bytes(&mut w);
    }
    w.into_bytes()
}

#[test]
fn legacy_v1_and_v2_files_load_and_migrate_to_chunks() {
    let fmt = mxscale::mx::ElementFormat::E3M2;
    let cs = CheckpointStore::new(
        Arc::new(MemoryStore::new()),
        StoreLayout::Sharded { shards: 4 },
    );

    // v2: today's monolithic bytes dropped in as `<id>.mxckpt`
    let ck2 = tiny_checkpoint(QuantScheme::MxSquare(fmt), 7);
    cs.storage().put("legacy-v2.mxckpt", &ck2.to_bytes()).unwrap();
    assert_eq!(cs.load("legacy-v2").unwrap().to_bytes(), ck2.to_bytes());

    // v1: no scheme-log section; the shim synthesizes a one-segment log
    let ck1 = tiny_checkpoint(QuantScheme::MxVector(fmt), 8);
    cs.storage().put("legacy-v1.mxckpt", &v1_bytes(&ck1)).unwrap();
    let loaded = cs.load("legacy-v1").unwrap();
    assert_eq!(loaded.scheme_log, vec![(0, ck1.config.scheme.name())]);
    assert_eq!(loaded.params, ck1.params);
    assert_eq!(loaded.step, ck1.step);

    // migrate: resave chunked, reload — the chunked copy now wins and
    // round-trips the same bytes the shim produced
    cs.save("legacy-v1", &loaded).unwrap();
    assert_eq!(cs.load("legacy-v1").unwrap().to_bytes(), loaded.to_bytes());
    assert!(!cs.shard_files().unwrap().is_empty(), "migration wrote chunks");
    let mut ids = cs.sessions().unwrap();
    ids.sort();
    assert_eq!(ids, vec!["legacy-v1".to_string(), "legacy-v2".to_string()]);
}

// ------------------------------------------------- corruption handling

#[test]
fn truncated_shards_and_flipped_bytes_are_structured_errors() {
    let cs = CheckpointStore::new(
        Arc::new(MemoryStore::new()),
        StoreLayout::Sharded { shards: 1 },
    );
    let ck = tiny_checkpoint(QuantScheme::MxSquare(mxscale::mx::ElementFormat::Int8), 3);
    cs.save("r", &ck).unwrap();
    let shard = &cs.shard_files().unwrap()[0];
    let whole = cs.storage().get(shard).unwrap();

    // truncation anywhere → BadIndex, and the legacy fallback must NOT
    // mask it as a missing session
    for cut in [whole.len() - 1, whole.len() - TRAILER_BYTES, whole.len() / 2, 5] {
        cs.storage().put(shard, &whole[..cut]).unwrap();
        let err = cs.load("r").unwrap_err();
        assert!(matches!(err, StoreError::BadIndex { .. }), "cut at {cut}: {err}");
    }

    // a flipped byte inside a chunk body → ChecksumMismatch naming the
    // damaged chunk key
    let mut flipped = whole.clone();
    flipped[4] ^= 0x40;
    cs.storage().put(shard, &flipped).unwrap();
    let err = cs.load("r").unwrap_err();
    assert!(matches!(err, StoreError::ChecksumMismatch { .. }), "{err}");

    // restore the good bytes: loads work again (the store held no state)
    cs.storage().put(shard, &whole).unwrap();
    assert_eq!(cs.load("r").unwrap().to_bytes(), ck.to_bytes());

    // and the structured store error folds into the trainer's error type
    cs.storage().put(shard, &flipped).unwrap();
    let err = cs.resume("r", dataset(1)).unwrap_err();
    assert!(matches!(err, TrainError::BadCheckpoint { .. }), "{err:?}");
}

// ------------------------------------------------- concurrent writers

#[test]
fn concurrent_writers_on_one_shard_lose_nothing() {
    let dir = scratch("concurrent");
    let cs = Arc::new(
        CheckpointStore::open_dir(&dir, StoreLayout::Sharded { shards: 1 })
            .unwrap()
            .with_lock_timeout(Duration::from_secs(30)),
    );
    let n = 8;
    std::thread::scope(|s| {
        for i in 0..n {
            let cs = cs.clone();
            s.spawn(move || {
                let ck = tiny_checkpoint(QuantScheme::Fp32, i);
                cs.save(&format!("robot-{i}"), &ck).unwrap();
            });
        }
    });
    assert_eq!(cs.shard_files().unwrap().len(), 1, "one shard serializes all writers");
    assert_eq!(cs.sessions().unwrap().len(), n as usize);
    for i in 0..n {
        let want = tiny_checkpoint(QuantScheme::Fp32, i);
        let got = cs.load(&format!("robot-{i}")).unwrap();
        assert_eq!(got.to_bytes(), want.to_bytes(), "robot-{i}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn a_held_lock_times_out_as_lock_held() {
    let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
    let cs = CheckpointStore::new(store.clone(), StoreLayout::Sharded { shards: 1 })
        .with_lock_timeout(Duration::from_millis(25));
    // occupy the single shard's lock out-of-band
    let lock =
        StoreLock::acquire(store, "shard-0000.mxshard.lock", Duration::from_secs(1)).unwrap();
    let ck = tiny_checkpoint(QuantScheme::Fp32, 1);
    let err = cs.save("r", &ck).unwrap_err();
    assert!(matches!(err, StoreError::LockHeld { .. }), "{err}");
    assert!(err.to_string().contains("held by another writer"), "{err}");
    lock.release().unwrap();
    cs.save("r", &ck).unwrap();
}

// ------------------------------------------------- 1000-robot acceptance

#[test]
fn a_thousand_robots_fit_in_eight_shards_and_resume_reads_stay_small() {
    let counting = Arc::new(CountingStore::new(Arc::new(MemoryStore::new())));
    let cs = CheckpointStore::new(counting.clone(), StoreLayout::Sharded { shards: 8 });

    let fleet: Vec<(String, Checkpoint)> = (0..1000)
        .map(|i| (format!("robot-{i:04}"), tiny_checkpoint(QuantScheme::Fp32, i)))
        .collect();
    let refs: Vec<(String, &Checkpoint)> = fleet.iter().map(|(id, ck)| (id.clone(), ck)).collect();
    cs.save_many(&refs).unwrap();

    // ≤ 8 files for the whole fleet (vs 1000 monolithic `.mxckpt`s)
    let shards = cs.shard_files().unwrap();
    assert!(shards.len() <= 8, "{} shard files", shards.len());

    // resuming one robot reads exactly trailer + live index + its own
    // chunks — and far less than the fleet's total footprint
    let total: u64 = shards.iter().map(|s| counting.size(s).unwrap()).sum();
    let budget = expected_resume_bytes(&cs, "robot-0500");
    counting.reset();
    let back = cs.load("robot-0500").unwrap();
    assert_eq!(counting.bytes_read(), budget);
    assert!(
        counting.bytes_read() * 4 < total,
        "partial read {} should be well under the {total}-byte store",
        counting.bytes_read()
    );
    assert_eq!(back.to_bytes(), fleet[500].1.to_bytes());
    assert_eq!(cs.sessions().unwrap().len(), 1000);
}

// ------------------------------------------------- per-layer partial read

#[test]
fn single_payload_tensor_reads_skip_the_rest_of_the_checkpoint() {
    let counting = Arc::new(CountingStore::new(Arc::new(MemoryStore::new())));
    let cs = CheckpointStore::new(counting.clone(), StoreLayout::Sharded { shards: 2 });
    let ck = tiny_checkpoint(QuantScheme::MxSquare(mxscale::mx::ElementFormat::E2M1), 5);
    cs.save("r", &ck).unwrap();

    let manifest = cs.chunk_manifest("r").unwrap();
    let tensor_len = manifest.iter().find(|(k, _)| k == "r/payload/0").unwrap().1;
    let full_len: u64 = manifest.iter().map(|(_, len)| *len).sum();

    counting.reset();
    let t = cs.load_payload_tensor("r", 0).unwrap();
    let mut w = ByteWriter::new();
    t.write_bytes(&mut w);
    let mut want = ByteWriter::new();
    ck.payload[0].write_bytes(&mut want);
    assert_eq!(w.into_bytes(), want.into_bytes());

    // index + one tensor chunk, strictly less than the whole session
    let index_overhead = counting.bytes_read() - tensor_len;
    assert!(counting.bytes_read() < index_overhead + full_len, "read the whole session");
    assert_eq!(counting.read_calls(), 3, "trailer, index, one chunk");
}
