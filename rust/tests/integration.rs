//! Cross-module integration tests: quantizers -> PE array -> GeMM core ->
//! trainer, plus the PJRT runtime path when artifacts exist.

use mxscale::arith::MacVariant;
use mxscale::backend::BackendKind;
use mxscale::energy::EnergyModel;
use mxscale::gemmcore::GemmCore;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{Layout, MxTensor};
use mxscale::pearray::PeArray;
use mxscale::trainer::qat::{qat_eval, qat_step, QuantScheme};
use mxscale::trainer::mlp::Mlp;
use mxscale::trainer::session::{TrainConfig, TrainError, TrainSession};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

#[test]
fn full_training_step_on_simulated_hardware() {
    // run one complete fwd/bwd/wgrad of the pusher MLP entirely through
    // the bit-exact GeMM core and compare against the golden QAT step.
    let fmt = ElementFormat::Int8;
    let mut rng = Pcg64::new(0xE2E);
    let mlp = Mlp::new(&[32, 64, 32], &mut rng);
    let x = Mat::randn(16, 32, 1.0, &mut rng);

    // forward through the hardware: X@W per layer with ReLU between
    let mut core = GemmCore::new(fmt);
    let mut a_hw = x.clone();
    for (i, w) in mlp.weights.iter().enumerate() {
        let qa = MxTensor::quantize(&a_hw, fmt, Layout::Square8x8);
        let qw = MxTensor::quantize(w, fmt, Layout::Square8x8);
        let z = core.gemm(&qa, &qw).add_bias(&mlp.biases[i]);
        a_hw = if i + 1 < mlp.weights.len() { z.map(|v| v.max(0.0)) } else { z };
    }

    // golden: fake-quant forward
    let scheme = QuantScheme::MxSquare(fmt);
    let tape = mlp.forward_with(&x, |_, w| scheme.quant(w), |_, a| scheme.quant(a));
    let rel = a_hw.mse(&tape.output).sqrt() / (tape.output.max_abs() as f64 + 1e-9);
    assert!(rel < 1e-5, "hardware fwd vs golden fwd: rel {rel}");
    assert!(core.cost.total() > 0);
}

#[test]
fn energy_accounting_consistent_between_mac_and_array() {
    let fmt = ElementFormat::E4M3;
    let model = EnergyModel::proposed();
    let mut rng = Pcg64::new(7);
    let a = Mat::randn(8, 8, 1.0, &mut rng);
    let b = Mat::randn(8, 8, 1.0, &mut rng);
    let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
    pe.gemm(&a, &b);
    let ev = pe.events();
    let pj = model.run_pj(fmt, &ev);
    let per_op = pj / ev.mul_ops as f64;
    // array per-op energy stays within 25% of the calibrated MAC value
    // (data-dependent register modulation is the only difference)
    let nominal = model.mac_pj_per_op(fmt);
    assert!((per_op - nominal).abs() / nominal < 0.25, "{per_op} vs {nominal}");
}

#[test]
fn square_vs_dacapo_training_quality_same_ballpark() {
    // Fig. 8's premise: per *step* the two quantizations learn similarly;
    // ours wins on steps-per-budget, not per-step quality.
    let env = by_name("pusher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 6, 50, 0xF00);
    let run = |scheme: QuantScheme| {
        let mut rng = Pcg64::new(1);
        let mut mlp = Mlp::new(&[32, 128, 128, 32], &mut rng);
        for i in 0..150 {
            let b = ds.batch(i, 32);
            qat_step(&mut mlp, &b.x, &b.y, scheme, 2e-3);
        }
        qat_eval(&mlp, &ds.val_x, &ds.val_y, scheme)
    };
    let ours = run(QuantScheme::MxSquare(ElementFormat::Int8));
    let dacapo = run(QuantScheme::Dacapo(mxscale::mx::dacapo::DacapoFormat::Mx9));
    assert!(ours / dacapo < 2.0 && dacapo / ours < 2.0, "ours {ours} dacapo {dacapo}");
}

#[test]
fn try_new_reports_structured_errors() {
    let ds = || {
        let env = by_name("cartpole").unwrap();
        Dataset::collect(env.as_ref(), 2, 20, 0xE44)
    };
    // dims that don't match the 32-wide dataset IO
    let e = TrainSession::try_new(
        ds(),
        TrainConfig { dims: Some(vec![16, 8, 8]), ..Default::default() },
    )
    .unwrap_err();
    match &e {
        TrainError::BadDims { dims, reason } => {
            assert_eq!(dims, &vec![16, 8, 8]);
            assert!(reason.contains("32-wide"), "{reason}");
        }
        other => panic!("expected BadDims, got {other}"),
    }
    // zero-width layer
    let e = TrainSession::try_new(
        ds(),
        TrainConfig { dims: Some(vec![32, 0, 32]), ..Default::default() },
    )
    .unwrap_err();
    assert!(matches!(e, TrainError::BadDims { .. }), "{e}");
    // a scheme the hardware backend has no datapath for
    let e = TrainSession::try_new(
        ds(),
        TrainConfig {
            scheme: QuantScheme::Dacapo(mxscale::mx::dacapo::DacapoFormat::Mx6),
            backend: BackendKind::Hardware,
            ..Default::default()
        },
    )
    .unwrap_err();
    match &e {
        TrainError::UnsupportedScheme { scheme, backend, .. } => {
            assert_eq!(scheme, "mx6");
            assert_eq!(*backend, "hw");
        }
        other => panic!("expected UnsupportedScheme, got {other}"),
    }
    // zero batch
    let e = TrainSession::try_new(ds(), TrainConfig { batch_size: 0, ..Default::default() })
        .unwrap_err();
    assert!(matches!(e, TrainError::BadConfig { .. }), "{e}");
    // errors render through Display for the CLI
    assert!(!format!("{e}").is_empty());
}

#[test]
fn runtime_path_trains_when_artifacts_present() {
    // the end-to-end PJRT path; skips (passes) when artifacts are absent
    let dir = mxscale::runtime::artifact_dir();
    let Ok(manifest) = mxscale::runtime::Manifest::load(&dir) else {
        eprintln!("skipping: no artifacts");
        return;
    };
    let Some(path) = manifest.train_path(&dir, "fp32") else { return };
    let client = mxscale::runtime::executor::cpu_client().unwrap();
    let mut exe = mxscale::runtime::TrainExecutable::load(&client, &path, 3).unwrap();
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 2);
    let mut first = None;
    let mut last = 0.0;
    for i in 0..40 {
        let b = ds.batch(i, manifest.batch);
        last = exe.step(&b.x, &b.y).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap(), "loss should drop: {first:?} -> {last}");
    assert_eq!(exe.steps_run, 40);
}
