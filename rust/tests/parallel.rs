//! The parallel-engine contract: every rayon-style path must be
//! **bit-identical** to its serial reference — same bytes out of the
//! quantizers, same FP32 bits out of the PE array, same `Events` and
//! `CycleCost` — plus the OCP MX v1.0 codec audit (exhaustive
//! round-trips for all six element formats) and the square-block
//! transpose property the paper's storage claim rests on.

use mxscale::arith::MacVariant;
use mxscale::gemmcore::GemmCore;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::tensor::{
    fake_quant_mat_fast, fake_quant_mat_fast_serial, Layout, MxTensor,
};
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::pearray::PeArray;
use mxscale::trainer::batched::sweep_schemes;
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

/// A matrix whose magnitudes span many binades — the adversarial input
/// for shared-exponent extraction.
fn wide_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::new(seed);
    Mat::from_fn(rows, cols, |_, _| rng.wide_f32().clamp(-1e20, 1e20))
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

// ---------------------------------------------------------------- codecs

#[test]
fn exhaustive_roundtrip_all_six_codecs() {
    // Satellite: every code point of every format decodes and re-encodes
    // to itself. Exclusions are exactly the spec's: E5M2/E4M3 Inf/NaN
    // codes (never produced by the saturating datapath) and INT8 -128
    // (the encoder saturates symmetric at +-127 per the MX references).
    for fmt in ALL_ELEMENT_FORMATS {
        for code in 0..fmt.code_count() {
            let code = code as u8;
            if fmt.is_special(code) {
                continue;
            }
            if fmt == ElementFormat::Int8 && code as i8 == -128 {
                continue;
            }
            let v = fmt.decode(code);
            let re = fmt.encode(v);
            assert_eq!(re, code, "{fmt:?}: code {code:#04x} -> {v} -> {re:#04x}");
            assert_eq!(
                fmt.decode(re).to_bits(),
                v.to_bits(),
                "{fmt:?}: decode(encode({v})) drifted"
            );
        }
    }
}

#[test]
fn codec_constants_match_ocp_mx_v1() {
    // Satellite audit anchors: E4M3 reclaims the top binade (emax 8,
    // saturation 448), E5M2 without specials tops at 57344, MXINT8 is a
    // two's-complement grid of 2^-6.
    assert_eq!(ElementFormat::E4M3.emax(), 8);
    assert_eq!(ElementFormat::E4M3.max_value(), 448.0);
    assert_eq!(ElementFormat::E5M2.emax(), 15);
    assert_eq!(ElementFormat::E5M2.max_value(), 57344.0);
    assert_eq!(ElementFormat::Int8.decode(64), 1.0); // 64 * 2^-6
    assert_eq!(ElementFormat::Int8.decode(1), 1.0 / 64.0);
    assert_eq!(ElementFormat::E2M1.max_value(), 6.0);
    assert_eq!(ElementFormat::E2M3.max_value(), 7.5);
    assert_eq!(ElementFormat::E3M2.max_value(), 28.0);
}

// ------------------------------------------------- transpose property

#[test]
fn square_transpose_is_quantize_of_transpose_bitwise() {
    // Satellite property test: on Square8x8, transposing the quantized
    // tensor is *block-for-block, code-for-code* identical to quantizing
    // the transposed matrix — the paper's single-copy storage claim.
    for fmt in ALL_ELEMENT_FORMATS {
        for (rows, cols, seed) in [(24, 16, 11u64), (13, 37, 12), (64, 64, 13), (8, 8, 14)] {
            let m = wide_mat(rows, cols, seed ^ ((fmt.bits() as u64) << 8));
            let qt = MxTensor::quantize(&m, fmt, Layout::Square8x8).transpose().unwrap();
            let direct = MxTensor::quantize(&m.transpose(), fmt, Layout::Square8x8);
            assert_eq!(qt.rows, direct.rows);
            assert_eq!(qt.cols, direct.cols);
            assert_eq!(
                qt.blocks, direct.blocks,
                "{fmt:?} {rows}x{cols}: transpose must be a pure permutation"
            );
        }
    }
}

// ------------------------------------------------- quantizer identity

#[test]
fn parallel_quantize_is_byte_identical_to_serial() {
    for fmt in ALL_ELEMENT_FORMATS {
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let m = wide_mat(200, 168, 21 ^ fmt.bits() as u64);
            let par = MxTensor::quantize(&m, fmt, layout);
            let ser = MxTensor::quantize_serial(&m, fmt, layout);
            assert_eq!(par.blocks, ser.blocks, "{fmt:?} {layout:?} quantize");
            assert_eq!(
                bits(&par.dequantize()),
                bits(&ser.dequantize_serial()),
                "{fmt:?} {layout:?} dequantize"
            );
            assert_eq!(
                bits(&fake_quant_mat_fast(&m, fmt, layout)),
                bits(&fake_quant_mat_fast_serial(&m, fmt, layout)),
                "{fmt:?} {layout:?} fake-quant fast path"
            );
        }
    }
}

#[test]
fn parallel_quantize_identity_on_awkward_shapes() {
    // non-multiples of the block edge, single-band, and tall-skinny
    for (rows, cols) in [(7, 300), (300, 7), (65, 129), (1, 1024), (1024, 1)] {
        let m = wide_mat(rows, cols, 0x5e3d + rows as u64);
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let par = MxTensor::quantize(&m, ElementFormat::E4M3, layout);
            let ser = MxTensor::quantize_serial(&m, ElementFormat::E4M3, layout);
            assert_eq!(par.blocks, ser.blocks, "{rows}x{cols} {layout:?}");
            assert_eq!(bits(&par.dequantize()), bits(&ser.dequantize_serial()));
        }
    }
}

// ------------------------------------------------- PE array identity

#[test]
fn parallel_gemm_matches_serial_outputs_events_cycles() {
    let a = wide_mat(64, 96, 31);
    let b = wide_mat(96, 64, 32);
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
        let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
        // 8x8 output tiles x 12 K-blocks: well above the parallel cutover
        let mut pe_s = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let out_s = pe_s.gemm_quantized_serial(&qa, &qb);
        let mut pe_p = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let out_p = pe_p.gemm_quantized(&qa, &qb);
        assert_eq!(bits(&out_p), bits(&out_s), "{fmt:?}: FP32 output bits");
        assert_eq!(pe_p.cycles, pe_s.cycles, "{fmt:?}: cycle count");
        assert_eq!(pe_p.events(), pe_s.events(), "{fmt:?}: event counters");
    }
}

#[test]
fn gemmcore_parallel_matches_serial_cost() {
    let a = wide_mat(64, 64, 41);
    let b = wide_mat(64, 64, 42);
    let fmt = ElementFormat::E4M3;
    let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
    let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
    let mut core_s = GemmCore::new(fmt);
    let out_s = core_s.gemm_serial(&qa, &qb);
    let mut core_p = GemmCore::new(fmt);
    let out_p = core_p.gemm(&qa, &qb);
    assert_eq!(bits(&out_p), bits(&out_s));
    assert_eq!(core_p.cost, core_s.cost, "CycleCost must not depend on host threads");
    assert_eq!(core_p.events(), core_s.events());
    assert_eq!(core_p.pe_cycles(), core_s.pe_cycles());
}

// ------------------------------------------------- engine primitives

#[test]
fn par_map_matches_its_serial_twin() {
    use mxscale::util::par::{par_map, par_map_serial};
    let got = par_map(1000, 2, |i| (i as f32).sin().to_bits());
    let want = par_map_serial(1000, |i| (i as f32).sin().to_bits());
    assert_eq!(got, want);
}

#[test]
fn par_chunks_mut_matches_its_serial_twin() {
    use mxscale::util::par::{par_chunks_mut, par_chunks_mut_serial};
    let f = |i: usize, chunk: &mut [f32]| {
        for (j, v) in chunk.iter_mut().enumerate() {
            *v = (*v + i as f32) * (j as f32 + 0.5);
        }
    };
    let mut a: Vec<f32> = (0..10_007).map(|i| i as f32 * 0.25).collect();
    let mut b = a.clone();
    par_chunks_mut(&mut a, 97, 2, f);
    par_chunks_mut_serial(&mut b, 97, f);
    let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    assert_eq!(bits(&a), bits(&b));
}

#[test]
fn matmul_kernels_match_their_serial_twins() {
    // all six GeMM kernels, above the fork threshold, against the
    // `_serial` twins that share their exact loop bodies
    let a = wide_mat(128, 96, 71);
    let b = wide_mat(96, 160, 72);
    assert_eq!(bits(&a.matmul(&b)), bits(&a.matmul_serial(&b)));
    assert_eq!(
        bits(&a.matmul_blocked(&b, 8)),
        bits(&a.matmul_blocked_serial(&b, 8))
    );
    let bt = wide_mat(160, 96, 73); // for the nt kernels: out = a @ btᵀ
    assert_eq!(bits(&a.matmul_nt(&bt)), bits(&a.matmul_nt_serial(&bt)));
    assert_eq!(
        bits(&a.matmul_blocked_nt(&bt, 8)),
        bits(&a.matmul_blocked_nt_serial(&bt, 8))
    );
    let at = wide_mat(96, 128, 74); // for the tn kernels: out = atᵀ @ b
    assert_eq!(bits(&at.matmul_tn(&b)), bits(&at.matmul_tn_serial(&b)));
    assert_eq!(
        bits(&at.matmul_blocked_tn(&b, 8)),
        bits(&at.matmul_blocked_tn_serial(&b, 8))
    );
}

// ------------------------------------------------- golden-path identity

#[test]
fn parallel_matmul_is_bit_identical_to_serial_reference() {
    // replicate the serial triple loop verbatim and compare against the
    // (internally banded) Mat::matmul on a size above its fork threshold
    let a = wide_mat(128, 96, 51);
    let b = wide_mat(96, 160, 52);
    let got = a.matmul(&b);
    let mut want = Mat::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        for k in 0..a.cols {
            let av = a.at(r, k);
            if av == 0.0 {
                continue;
            }
            for c in 0..b.cols {
                *want.at_mut(r, c) += av * b.at(k, c);
            }
        }
    }
    assert_eq!(bits(&got), bits(&want));
}

#[test]
fn parallel_blocked_matmul_is_bit_identical_to_serial_reference() {
    // the MX-blocked kernel above its fork threshold vs a verbatim
    // serial replica of its per-element semantics (f64 chain per
    // 8-chunk, f32 chain across chunks, left-operand zero skip)
    let a = wide_mat(128, 96, 53).map(|v| if v.abs() < 1.0 { 0.0 } else { v });
    let b = wide_mat(96, 160, 54);
    let got = a.matmul_blocked(&b, 8);
    let mut want = Mat::zeros(a.rows, b.cols);
    for r in 0..a.rows {
        for c in 0..b.cols {
            let mut s = 0.0f32;
            let mut k0 = 0;
            while k0 < a.cols {
                let kend = (k0 + 8).min(a.cols);
                let mut p = 0.0f64;
                for k in k0..kend {
                    let av = a.at(r, k);
                    if av == 0.0 {
                        continue;
                    }
                    p += av as f64 * b.at(k, c) as f64;
                }
                s += p as f32;
                k0 = kend;
            }
            *want.at_mut(r, c) = s;
        }
    }
    assert_eq!(bits(&got), bits(&want));
}

#[test]
fn parallel_packed_gemm_is_bit_identical_above_fork_threshold() {
    // 256x256x256 is far above the packed kernel's banding gate; the
    // result must still equal the dense blocked kernel bit for bit
    use mxscale::mx::packed::{packed_gemm, PackedTensor};
    let a = wide_mat(256, 192, 61);
    let b = wide_mat(192, 256, 62);
    for fmt in [ElementFormat::Int8, ElementFormat::E5M2] {
        let pa = PackedTensor::quantize_pack(&a, fmt);
        let pb = PackedTensor::quantize_pack(&b, fmt);
        let got = packed_gemm(&pa, &pb);
        let want = pa.dequantize().matmul_blocked(&pb.dequantize(), 8);
        assert_eq!(bits(&got), bits(&want), "{fmt:?}");
    }
}

#[test]
fn batched_sweep_reproduces_sequential_losses() {
    // the end-to-end claim: a concurrent format sweep returns exactly
    // the numbers the one-at-a-time loop produces
    let env = by_name("pusher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 4, 40, 0x99);
    let schemes = [
        QuantScheme::MxSquare(ElementFormat::Int8),
        QuantScheme::MxSquare(ElementFormat::E2M1),
    ];
    let base = TrainConfig { steps: 30, eval_every: 10, ..Default::default() };
    let batched = sweep_schemes(&ds, &schemes, &base);
    for (scheme, outcome) in schemes.iter().zip(&batched) {
        let mut s = TrainSession::new(ds.clone(), TrainConfig { scheme: *scheme, ..base.clone() });
        s.run();
        assert_eq!(outcome.session.val_loss(), s.val_loss(), "{}", scheme.name());
        assert_eq!(outcome.session.val_curve, s.val_curve, "{}", scheme.name());
    }
}
