//! Integration tests for the open-stream serving front-end: admission
//! edge cases, drain semantics, priority starvation, and the headline
//! contract — every admitted session (stolen, parked, lease-evicted,
//! re-admitted) finishes bitwise identical to a standalone run.

use mxscale::fleet::{SessionBudget, SessionSpec};
use mxscale::mx::element::ElementFormat;
use mxscale::serve::{
    serve, Arrival, BudgetAware, FixedRoster, ServeConfig, ServeError, SessionOffer,
    MAX_PRIORITY,
};
use mxscale::store::{CheckpointStore, MemoryStore, StoreLayout};
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::TrainConfig;
use mxscale::workloads::{by_name, Dataset};
use std::sync::Arc;

fn dataset(seed: u64) -> Dataset {
    let env = by_name("cartpole").unwrap();
    Dataset::collect(env.as_ref(), 2, 24, seed)
}

fn config(scheme: QuantScheme, steps: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        scheme,
        dims: Some(vec![32, 8, 32]),
        steps,
        batch_size: 8,
        eval_every: usize::MAX,
        seed,
        ..Default::default()
    }
}

/// One synthetic arrival; the spec is a pure function of the inputs, so
/// tests can rebuild an identical standalone twin at will.
fn arrival(id: &str, priority: u8, steps: usize, seed: u64, ds: &Dataset) -> Arrival {
    let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
    let offer = SessionOffer { id: id.into(), priority, budget_steps: steps };
    let spec =
        SessionSpec::new(id, "cartpole", ds.clone(), config(scheme, steps, seed)).priority(priority);
    Arrival { offer, spec }
}

#[test]
fn zero_budget_session_is_refused_at_admit() {
    let ds = dataset(1);
    let mut bad = arrival("t-zero", 1, 4, 7, &ds);
    bad.offer.budget_steps = 0;
    let bad = Arrival { offer: bad.offer, spec: bad.spec.budget(SessionBudget::steps(0)) };
    let good = arrival("t-good", 1, 4, 8, &ds);
    let cfg = ServeConfig { workers: 1, quantum: 2, ..Default::default() };
    let served = serve(vec![bad, good].into_iter(), &FixedRoster, &cfg).unwrap();
    assert_eq!(served.stats.offered, 2);
    assert_eq!(served.stats.refused, 1);
    assert_eq!(served.stats.completed, 1);
    assert_eq!(served.shed.len(), 1);
    match &served.shed[0] {
        (id, ServeError::BadOffer { reason, .. }) => {
            assert_eq!(id, "t-zero");
            assert!(reason.contains("zero-step"), "{reason}");
        }
        other => panic!("expected BadOffer, got {other:?}"),
    }
}

#[test]
fn overload_sheds_with_structured_errors_and_loses_nothing() {
    // one core, capacity 1, no parking lot: a back-to-back flood of
    // arrivals must shed almost everything with the load snapshot that
    // justified it — and every offer still lands in exactly one bucket
    let ds = dataset(2);
    let arrivals: Vec<Arrival> =
        (0..8).map(|i| arrival(&format!("t-{i}"), 1, 40, 100 + i as u64, &ds)).collect();
    let cfg = ServeConfig { workers: 1, quantum: 4, capacity: 1, ..Default::default() };
    let admission = BudgetAware { max_parked: 0 };
    let served = serve(arrivals.into_iter(), &admission, &cfg).unwrap();
    assert_eq!(served.stats.offered, 8);
    assert_eq!(served.stats.completed + served.shed.len(), 8, "nothing lost");
    assert!(served.stats.shed_overloaded >= 1, "{:?}", served.stats);
    for (_, e) in &served.shed {
        match e {
            ServeError::Overloaded { capacity, live, .. } => {
                assert_eq!(*capacity, 1);
                assert!(*live >= 1);
            }
            other => panic!("expected Overloaded, got {other}"),
        }
    }
}

#[test]
fn executor_drains_cleanly_when_the_stream_closes_mid_run() {
    // the vec stream closes immediately after the third arrival, while
    // all three sessions are still mid-quantum: serve() must run every
    // admitted session to its budget and then stop
    let ds = dataset(3);
    let steps = 9;
    let arrivals: Vec<Arrival> =
        (0..3).map(|i| arrival(&format!("t-{i}"), 1, steps, 200 + i as u64, &ds)).collect();
    let cfg = ServeConfig { workers: 2, quantum: 2, ..Default::default() };
    let served = serve(arrivals.into_iter(), &BudgetAware::default(), &cfg).unwrap();
    assert!(served.shed.is_empty(), "{:?}", served.shed);
    assert_eq!(served.stats.completed, 3);
    assert_eq!(served.stats.total_steps, 3 * steps);
    let mut ids: Vec<&str> = served.completed.iter().map(|s| s.id.as_str()).collect();
    ids.sort_unstable();
    assert_eq!(ids, ["t-0", "t-1", "t-2"]);
    for s in &served.completed {
        assert!(s.done());
        assert!(s.error().is_none());
        assert_eq!(s.steps_done(), steps);
    }
}

#[test]
fn low_priority_session_completes_under_a_high_priority_flood() {
    // injector aging bounds starvation: the single priority-0 session
    // must still run to its budget while priority-3 arrivals keep coming
    let ds = dataset(4);
    let mut arrivals = vec![arrival("t-low", 0, 6, 300, &ds)];
    for i in 0..12 {
        arrivals.push(arrival(&format!("t-hi-{i}"), MAX_PRIORITY, 6, 310 + i as u64, &ds));
    }
    let cfg = ServeConfig { workers: 1, quantum: 3, capacity: 16, ..Default::default() };
    let served = serve(arrivals.into_iter(), &BudgetAware::default(), &cfg).unwrap();
    assert_eq!(served.stats.completed, 13);
    let low = served.completed.iter().find(|s| s.id == "t-low").expect("low-priority ran");
    assert!(low.done() && low.error().is_none());
    assert_eq!(low.steps_done(), 6);
}

#[test]
fn evict_checkpoint_readmit_is_bitwise_identical_to_standalone() {
    // the headline contract, end to end: short leases force every
    // session through evict -> checkpoint store -> re-admission while
    // two workers steal from each other, and every finished curve must
    // equal its uninterrupted standalone twin bit for bit
    let ds = dataset(5);
    let steps = 12;
    let arrivals: Vec<Arrival> =
        (0..8).map(|i| arrival(&format!("t-{i}"), (i % 4) as u8, steps, 400 + i as u64, &ds)).collect();
    let store =
        Arc::new(CheckpointStore::new(Arc::new(MemoryStore::new()), StoreLayout::Sharded { shards: 2 }));
    let cfg = ServeConfig {
        workers: 2,
        quantum: 3,
        capacity: 3,
        lease_quanta: 2,
        store: Some(store),
    };
    let served = serve(arrivals.into_iter(), &BudgetAware::default(), &cfg).unwrap();
    assert!(served.shed.is_empty(), "{:?}", served.shed);
    assert_eq!(served.stats.completed, 8);
    assert!(served.stats.evicted >= 1, "short leases must evict: {:?}", served.stats);
    assert_eq!(served.stats.evicted, served.stats.re_admitted);
    for s in &served.completed {
        let i: u64 = s.id.strip_prefix("t-").unwrap().parse().unwrap();
        let mut twin = arrival(&s.id, 0, steps, 400 + i, &ds).spec.build().unwrap();
        while twin.run_quantum(cfg.quantum) > 0 {}
        let (a, b) = (&twin.session().train_curve, &s.session().train_curve);
        assert_eq!(a.len(), b.len(), "{}", s.id);
        for (x, y) in a.iter().zip(b) {
            assert_eq!(x.0, y.0, "{}", s.id);
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{}: curve diverged", s.id);
        }
        assert_eq!(
            twin.session().val_loss().to_bits(),
            s.session().val_loss().to_bits(),
            "{}: val loss diverged",
            s.id
        );
    }
}
