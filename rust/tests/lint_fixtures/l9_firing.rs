//! L9 fixture: an undrilled injection seam outside the chaos module,
//! plus a seam call in a file that never names a FaultPlan.

pub fn inject_orphan_seam(x: u64) -> u64 {
    x ^ 1
}

pub fn quantum(x: u64) -> u64 {
    inject_remote_seam(x)
}
