// mxlint fixture: L1 — a `_serial` twin no identity test references.
// Lexed under a fake `rust/src/util/mat.rs` path; never compiled.

pub fn orphan_kernel_serial(n: usize) -> usize {
    n * 2
}
