//! L8 fixture: arch kernels missing the `#![cfg(target_arch = ...)]`
//! gate, the vector-path naming suffix, and a SWAR twin.

#[target_feature(enable = "avx2")]
pub unsafe fn tile_sum_avx2(x: &[i8; 64]) -> i32 {
    x.iter().map(|&v| v as i32).sum()
}

#[target_feature(enable = "avx2")]
pub unsafe fn dot8_fast(x: &[i8; 8]) -> i32 {
    x.iter().map(|&v| v as i32).sum()
}
