// mxlint fixture: L5 store pins — a minimal store module whose
// byte-layout function is hashed against a synthetic manifest by
// rust/tests/lint.rs. Lexed under a fake `rust/src/store/mod.rs` path;
// never compiled.

pub const VERSION: u32 = 1;

pub fn write_bytes(key: &str, offset: u64) -> Vec<u8> {
    let mut out = key.as_bytes().to_vec();
    out.extend_from_slice(&offset.to_le_bytes());
    out
}
