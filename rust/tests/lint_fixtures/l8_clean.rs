//! L8 fixture: a compliant arch-gated kernel with a SWAR twin.

#![cfg(target_arch = "x86_64")]

pub fn tile_sum_swar(x: &[i8; 64]) -> i32 {
    x.iter().map(|&v| v as i32).sum()
}

// SAFETY: caller checked avx2 at runtime (dispatcher guard).
#[target_feature(enable = "avx2")]
pub unsafe fn tile_sum_avx2(x: &[i8; 64]) -> i32 {
    tile_sum_swar(x)
}
