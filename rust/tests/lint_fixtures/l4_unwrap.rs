// mxlint fixture: L4 — `.unwrap()` in training-stack library code.
// Lexed under a fake `rust/src/trainer/session.rs` path; never compiled.

pub fn load_weights(path: &str) -> Vec<u8> {
    std::fs::read(path).unwrap()
}
