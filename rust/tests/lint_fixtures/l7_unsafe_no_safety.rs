// mxlint fixture: L7 — `unsafe` with no `// SAFETY:` comment in the
// three lines above it. Lexed under a fake `rust/src/mx/block.rs`
// path; never compiled.

pub fn first_unchecked(v: &[u8]) -> u8 {
    unsafe { *v.get_unchecked(0) }
}
