// mxlint fixture: L3 — magic bit-width literals in packed-kernel code.
// Lexed under a fake `rust/src/mx/packed.rs` path; never compiled.
// Line 6 fires on the `4`, line 7 on the 16-hex-digit lane mask.

pub fn lane_extract(word: u64) -> u64 {
    let hi = word >> 4;
    hi & 0x0101_0101_0101_0101
}
