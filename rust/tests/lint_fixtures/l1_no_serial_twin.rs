// mxlint fixture: L1 — public parallel kernel with no `_serial` twin.
// Lexed under a fake `rust/src/util/mat.rs` path by rust/tests/lint.rs;
// never compiled.

pub fn scaled_sum(out: &mut [f64], n: usize) {
    let parts = par_map(n, 1, |i| i as f64);
    out[0] = parts.iter().sum();
}
