// mxlint fixture: L2 — float log in shared-exponent code. The
// `log2().floor()` idiom misrounds near powers of two (PR 1); exponents
// must come from element::floor_log2. Never compiled.

pub fn shared_exponent(max_abs: f64) -> i32 {
    max_abs.log2().floor() as i32
}
