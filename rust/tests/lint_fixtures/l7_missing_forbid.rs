// mxlint fixture: L7 — a leaf module with no unsafe code and no
// `#![forbid(unsafe_code)]`. Lexed under a fake `rust/src/mx/block.rs`
// path; never compiled.

pub fn identity(x: u32) -> u32 {
    x
}
