// mxlint fixture: L5 — a minimal checkpoint module whose byte-layout
// function is hashed against a synthetic manifest by rust/tests/lint.rs.
// Lexed under a fake `rust/src/trainer/checkpoint.rs` path; never
// compiled.

pub const VERSION: u32 = 2;

pub fn to_bytes(x: u32) -> Vec<u8> {
    x.to_le_bytes().to_vec()
}
