// mxlint fixture: L6 — a results-JSON writer that skips the
// bench_doc/stamped_doc schema stamp. Lexed under a fake
// `rust/src/coordinator/report.rs` path; never compiled.

pub fn save_run(doc: &Json) -> std::io::Result<()> {
    save_json(doc, "fixture_run")?;
    Ok(())
}
