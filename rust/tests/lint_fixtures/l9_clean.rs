//! L9 fixture: a cfg-gated seam outside the chaos module, in a file
//! that consults a FaultPlan before firing anything.

pub struct FaultPlan;

#[cfg(any(test, debug_assertions))]
pub fn inject_gated_seam(x: u64) -> u64 {
    x ^ 1
}

pub fn quantum(plan: &FaultPlan, x: u64) -> u64 {
    let _ = plan;
    inject_gated_seam(x)
}
