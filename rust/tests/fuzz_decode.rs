//! Dependency-free deterministic fuzz harness for the decode surfaces
//! (DESIGN.md §13): seeded [`Pcg64`] mutations — truncations, bit
//! flips, byte splices — of shard images and monolithic `.mxckpt`
//! checkpoint bytes. The contract under mutation:
//!
//! * **never panic** — every decoder failure is a structured
//!   [`StoreError`] or an `Err(String)` from `Checkpoint::from_bytes`;
//! * **never silently wrong** — when a mutated shard still reads clean,
//!   every chunk that comes back must be bitwise a value some committed
//!   generation actually wrote (the mutation landed in dead bytes, or
//!   sheared the log exactly at an older commit point).
//!
//! Each case runs a fixed seed, so a failure here reproduces exactly.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Duration;

use mxscale::chaos::recover_generations;
use mxscale::store::shard::{append_chunks, read_chunk, read_index};
use mxscale::store::{MemoryStore, Storage, StoreError};
use mxscale::trainer::checkpoint::Checkpoint;
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

const LOCK_T: Duration = Duration::from_secs(2);

/// One seeded mutation of `bytes`: truncate, flip a few bits, or
/// overwrite one byte. Returns the mutated copy.
fn mutate(rng: &mut Pcg64, bytes: &[u8]) -> Vec<u8> {
    let mut out = bytes.to_vec();
    match rng.below(3) {
        0 => {
            out.truncate(rng.below(bytes.len() as u64 + 1) as usize);
        }
        1 => {
            for _ in 0..=rng.below(4) {
                let at = rng.below(bytes.len() as u64) as usize;
                out[at] ^= 1u8 << rng.below(8);
            }
        }
        _ => {
            let at = rng.below(bytes.len() as u64) as usize;
            out[at] = rng.below(256) as u8;
        }
    }
    out
}

fn training_checkpoints(seed: u64) -> (Checkpoint, Checkpoint) {
    let env = by_name("cartpole").unwrap();
    let ds = Dataset::collect(env.as_ref(), 2, 20, seed);
    let config = TrainConfig {
        scheme: QuantScheme::MxSquare(mxscale::mx::ALL_ELEMENT_FORMATS[0]),
        dims: Some(vec![32, 8, 32]),
        batch_size: 8,
        steps: 6,
        eval_every: usize::MAX,
        seed,
        ..Default::default()
    };
    let mut session = TrainSession::try_new(ds, config).unwrap();
    let ck1 = session.save_checkpoint();
    for _ in 0..2 {
        session.step_once();
    }
    (ck1, session.save_checkpoint())
}

#[test]
fn mutated_shards_read_structured_or_bitwise_committed() {
    let (ck1, ck2) = training_checkpoints(0xF522);
    let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
    let gen1: Vec<(String, Vec<u8>)> = mxscale::store::chunk::split_checkpoint(&ck1)
        .into_iter()
        .map(|(leaf, bytes)| (format!("t-fuzz/{leaf}"), bytes))
        .collect();
    append_chunks(&store, "base.mxshard", &gen1, LOCK_T).unwrap();
    let gen2: Vec<(String, Vec<u8>)> = mxscale::store::chunk::split_checkpoint(&ck2)
        .into_iter()
        .map(|(leaf, bytes)| (format!("t-fuzz/{leaf}"), bytes))
        .collect();
    append_chunks(&store, "base.mxshard", &gen2, LOCK_T).unwrap();
    let pristine = store.get("base.mxshard").unwrap();
    // every byte string any generation ever committed under each key —
    // a clean read may legitimately surface an older generation's value
    // (the mutation sheared the log at an old commit point), but never
    // bytes nobody wrote
    let mut committed: BTreeMap<&str, Vec<&[u8]>> = BTreeMap::new();
    for (key, bytes) in gen1.iter().chain(gen2.iter()) {
        committed.entry(key).or_default().push(bytes);
    }

    let mut rng = Pcg64::new(0xDECODE);
    let (mut clean, mut rejected) = (0usize, 0usize);
    for case in 0..300u64 {
        let mutated = mutate(&mut rng, &pristine);
        store.put("fuzz.mxshard", &mutated).unwrap();
        // backward recovery scan must also survive arbitrary bytes
        let generations = recover_generations(store.as_ref(), "fuzz.mxshard").unwrap();
        assert!(generations.len() <= 2, "case {case}: phantom generation");
        match read_index(store.as_ref(), "fuzz.mxshard") {
            Err(StoreError::BadIndex { .. }) => rejected += 1,
            Err(other) => panic!("case {case}: unstructured index failure {other:?}"),
            Ok(entries) => {
                for entry in &entries {
                    match read_chunk(store.as_ref(), "fuzz.mxshard", entry) {
                        Err(
                            StoreError::ChecksumMismatch { .. } | StoreError::BadIndex { .. },
                        ) => rejected += 1,
                        Err(other) => {
                            panic!("case {case}/{}: unstructured {other:?}", entry.key)
                        }
                        Ok(bytes) => {
                            clean += 1;
                            let wrote = committed.get(entry.key.as_str()).unwrap_or_else(|| {
                                panic!("case {case}: key `{}` nobody wrote", entry.key)
                            });
                            assert!(
                                wrote.iter().any(|w| *w == bytes.as_slice()),
                                "case {case}: `{}` read bytes no generation committed",
                                entry.key
                            );
                        }
                    }
                }
            }
        }
    }
    // the corpus must actually exercise both sides of the contract
    assert!(clean > 0, "no mutation ever left a readable shard");
    assert!(rejected > 0, "no mutation was ever detected");
}

#[test]
fn mutated_checkpoints_parse_structured_or_reencode_canonically() {
    let (_, ck) = training_checkpoints(0xC0DE);
    let pristine = ck.to_bytes();
    assert!(Checkpoint::from_bytes(&pristine).is_ok(), "pristine image parses");

    let mut rng = Pcg64::new(0xF00D);
    let (mut accepted, mut rejected) = (0usize, 0usize);
    for case in 0..300u64 {
        let mutated = mutate(&mut rng, &pristine);
        // the only acceptable outcomes: a structured Err(String), or a
        // checkpoint whose canonical re-encode parses again — never a
        // panic, never a value that can't survive its own round trip
        match Checkpoint::from_bytes(&mutated) {
            Err(reason) => {
                rejected += 1;
                assert!(!reason.is_empty(), "case {case}: empty decode error");
            }
            Ok(decoded) => {
                accepted += 1;
                let reencoded = decoded.to_bytes();
                let twice = Checkpoint::from_bytes(&reencoded)
                    .unwrap_or_else(|e| panic!("case {case}: re-encode unparseable: {e}"));
                assert_eq!(
                    twice.to_bytes(),
                    reencoded,
                    "case {case}: decode/encode not idempotent"
                );
            }
        }
    }
    assert_eq!(accepted + rejected, 300);
    // flips inside f32 payload regions legitimately decode (different
    // params, still structurally valid) — but structural damage must
    // show up in the corpus, and so must at least one acceptance
    assert!(rejected >= 20, "mutations barely ever rejected ({rejected}/300)");
    assert!(accepted >= 1, "no mutation ever decoded ({accepted}/300)");
}

#[test]
fn degenerate_inputs_never_panic() {
    // the classic fuzz corpus floor: empty, tiny, saturated, random
    let mut rng = Pcg64::new(7);
    let mut corpus: Vec<Vec<u8>> = vec![
        Vec::new(),
        vec![0u8],
        b"MXCK".to_vec(),
        b"MXSH".to_vec(),
        vec![0u8; 64],
        vec![0xFF; 256],
    ];
    corpus.push((0..512).map(|_| rng.below(256) as u8).collect());
    let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
    for (i, bytes) in corpus.iter().enumerate() {
        assert!(Checkpoint::from_bytes(bytes).is_err(), "corpus {i} parsed as a checkpoint");
        store.put("junk.mxshard", bytes).unwrap();
        match read_index(store.as_ref(), "junk.mxshard") {
            Err(StoreError::BadIndex { .. }) => {}
            other => panic!("corpus {i}: {other:?}"),
        }
        assert!(
            recover_generations(store.as_ref(), "junk.mxshard").unwrap().is_empty(),
            "corpus {i}: generation recovered from junk"
        );
    }
}
