//! Chaos conformance suite (DESIGN.md §13): every injected fault must
//! end in exactly one of two outcomes — a structured error naming the
//! fault site, or a recovery proven bitwise identical to the fault-free
//! twin. A third outcome (silently wrong state) is a test failure.
//!
//! Coverage grid:
//! * memory faults (`inject_lane_flip`, `inject_scale_flip`) × all six
//!   OCP element formats;
//! * storage faults (`inject_shard_truncate`, `inject_chunk_flip`,
//!   `inject_stale_lock`) against checkpoints written by all three
//!   backends (fast / hw / packed);
//! * executor faults (`inject_panic`, worker crash) through the serving
//!   front-end across formats and backends;
//! * a null test: a plan that attacks nothing changes nothing.

use std::sync::Arc;
use std::time::Duration;

use mxscale::backend::BackendKind;
use mxscale::chaos::memory::packed_image;
use mxscale::chaos::storage::{assemble_from_generation, read_live_chunk};
use mxscale::chaos::{
    inject_chunk_flip, inject_shard_truncate, inject_stale_lock, prove_bit_identical,
    recover_generations, ChaosError, ExecFault, FaultClass, FaultOutcome, FaultPlan,
    GuardedTensor,
};
use mxscale::fleet::SessionSpec;
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::serve::{serve, Arrival, BudgetAware, ServeConfig, SessionOffer};
use mxscale::store::shard::{append_chunks, read_index};
use mxscale::store::{chunk, CheckpointStore, MemoryStore, Storage, StoreError, StoreLayout};
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use mxscale::workloads::{by_name, Dataset};

const LOCK_T: Duration = Duration::from_secs(2);

fn dataset(seed: u64) -> Dataset {
    let env = by_name("cartpole").unwrap();
    Dataset::collect(env.as_ref(), 2, 20, seed)
}

fn config(scheme: QuantScheme, backend: BackendKind, steps: usize, seed: u64) -> TrainConfig {
    TrainConfig {
        scheme,
        backend,
        dims: Some(vec![32, 8, 32]),
        steps,
        batch_size: 8,
        eval_every: usize::MAX,
        seed,
        ..Default::default()
    }
}

// ---------------------------------------------------------------- memory

#[test]
fn memory_faults_detect_the_exact_block_for_every_format() {
    let mut rng = Pcg64::new(0xC4A05);
    for (layer, &format) in ALL_ELEMENT_FORMATS.iter().enumerate() {
        let master = Mat::from_fn(19, 13, |_, _| rng.wide_f32());
        // null: an untouched tensor verifies clean
        let mut guarded = GuardedTensor::quantize(layer, &master, format);
        assert!(guarded.verify().is_ok(), "{format:?}: pristine tensor failed verify");

        // lane flip: detection must name this layer and this block
        guarded.inject_lane_flip(1, 0, 3, 17);
        match guarded.verify() {
            Err(ChaosError::BlockCorrupt { layer: l, brow, bcol }) => {
                assert_eq!((l, brow, bcol), (layer, 1, 0), "{format:?}: wrong site");
            }
            other => panic!("{format:?}: lane flip not detected as BlockCorrupt: {other:?}"),
        }

        // scale flip on a different block: same contract
        let mut guarded = GuardedTensor::quantize(layer, &master, format);
        guarded.inject_scale_flip(0, 1, 6);
        match guarded.verify() {
            Err(ChaosError::BlockCorrupt { layer: l, brow, bcol }) => {
                assert_eq!((l, brow, bcol), (layer, 0, 1), "{format:?}: wrong site");
            }
            other => panic!("{format:?}: scale flip not detected as BlockCorrupt: {other:?}"),
        }
    }
}

#[test]
fn memory_recovery_is_bit_identical_for_every_format() {
    let mut rng = Pcg64::new(0x5EED);
    for (layer, &format) in ALL_ELEMENT_FORMATS.iter().enumerate() {
        let master = Mat::from_fn(17, 23, |_, _| rng.wide_f32());
        let mut guarded = GuardedTensor::quantize(layer, &master, format);
        let pristine = packed_image(guarded.packed());
        guarded.inject_lane_flip(0, 2, 5, 41);
        guarded.inject_scale_flip(1, 1, 2);
        assert!(guarded.verify().is_err(), "{format:?}: double fault not detected");
        // recovery re-quantizes from the FP32 master; fq∘fq == fq makes
        // the repaired image equal the never-corrupted one byte for byte
        match guarded.recover() {
            Ok(FaultOutcome::Recovered { site, proof }) => {
                assert!(site.contains(&format!("layer {layer}")), "{format:?}: site `{site}`");
                assert_eq!(proof.bytes_compared(), pristine.len(), "{format:?}");
            }
            other => panic!("{format:?}: recovery failed: {other:?}"),
        }
        prove_bit_identical("post-recovery image", &packed_image(guarded.packed()), &pristine)
            .unwrap_or_else(|e| panic!("{format:?}: {e}"));
    }
}

// --------------------------------------------------------------- storage

/// Write two checkpoint generations of one training session into a
/// fresh in-memory shard; returns (store, shard, id, ck1_bytes,
/// gen1_end, gen2_end).
fn two_checkpoint_generations(
    backend: BackendKind,
    scheme: QuantScheme,
    seed: u64,
) -> (Arc<dyn Storage>, String, String, Vec<u8>, usize, usize) {
    let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
    let shard = "chaos-0.mxshard".to_string();
    let id = "t-chaos".to_string();
    let mut session = TrainSession::try_new(dataset(seed), config(scheme, backend, 8, seed))
        .expect("session builds");
    let ck1 = session.save_checkpoint();
    let chunks1: Vec<(String, Vec<u8>)> = chunk::split_checkpoint(&ck1)
        .into_iter()
        .map(|(leaf, bytes)| (format!("{id}/{leaf}"), bytes))
        .collect();
    append_chunks(&store, &shard, &chunks1, LOCK_T).unwrap();
    let gen1_end = store.size(&shard).unwrap() as usize;
    for _ in 0..3 {
        session.step_once();
    }
    let ck2 = session.save_checkpoint();
    let chunks2: Vec<(String, Vec<u8>)> = chunk::split_checkpoint(&ck2)
        .into_iter()
        .map(|(leaf, bytes)| (format!("{id}/{leaf}"), bytes))
        .collect();
    append_chunks(&store, &shard, &chunks2, LOCK_T).unwrap();
    let gen2_end = store.size(&shard).unwrap() as usize;
    (store, shard, id, ck1.to_bytes(), gen1_end, gen2_end)
}

#[test]
fn torn_append_detects_then_recovers_for_every_backend() {
    for (i, backend) in [BackendKind::Fast, BackendKind::Hardware, BackendKind::Packed]
        .into_iter()
        .enumerate()
    {
        let scheme = QuantScheme::MxSquare(ALL_ELEMENT_FORMATS[i % ALL_ELEMENT_FORMATS.len()]);
        let (store, shard, id, ck1_bytes, gen1_end, gen2_end) =
            two_checkpoint_generations(backend, scheme, 100 + i as u64);
        // shear the second append short of its commit point
        inject_shard_truncate(store.as_ref(), &shard, gen2_end - 5).unwrap();
        // detection: the live reader fails structured, naming the shard
        match read_index(store.as_ref(), &shard) {
            Err(StoreError::BadIndex { key, .. }) => assert_eq!(key, shard, "{backend:?}"),
            other => panic!("{backend:?}: torn shard read gave {other:?}"),
        }
        // recovery: the previous generation's commit point survives as
        // dead bytes; the rebuilt checkpoint is bitwise checkpoint 1
        let gens = recover_generations(store.as_ref(), &shard).unwrap();
        assert_eq!(gens[0].end as usize, gen1_end, "{backend:?}: newest surviving generation");
        let recovered = assemble_from_generation(store.as_ref(), &shard, &gens[0], &id)
            .unwrap_or_else(|e| panic!("{backend:?}: {e}"));
        prove_bit_identical("recovered checkpoint", &recovered.to_bytes(), &ck1_bytes)
            .unwrap_or_else(|e| panic!("{backend:?}: {e}"));
        // truncating past every commit point leaves nothing — and says so
        inject_shard_truncate(store.as_ref(), &shard, 8).unwrap();
        assert!(recover_generations(store.as_ref(), &shard).unwrap().is_empty(), "{backend:?}");
    }
}

#[test]
fn chunk_bit_rot_detects_with_the_exact_key_then_recovers() {
    let scheme = QuantScheme::MxSquare(ALL_ELEMENT_FORMATS[0]);
    let (store, shard, id, ck1_bytes, gen1_end, gen2_end) =
        two_checkpoint_generations(BackendKind::Fast, scheme, 7);
    // rot one byte inside generation 2's chunk region
    let offset = gen1_end + (gen2_end - gen1_end) / 3;
    inject_chunk_flip(store.as_ref(), &shard, offset, 4).unwrap();
    // detection: either a chunk checksum trips (rot hit a chunk) or the
    // index/trailer fails (rot hit the commit structures) — both are
    // structured and both name their site
    let index = read_index(store.as_ref(), &shard);
    match index {
        Ok(entries) => {
            let leaves: Vec<&str> =
                entries.iter().map(|e| e.key.as_str()).filter(|k| k.starts_with(&id)).collect();
            let hit = leaves.iter().find(|key| {
                matches!(
                    read_live_chunk(store.as_ref(), &shard, key),
                    Err(ChaosError::Store { source: StoreError::ChecksumMismatch { .. }, .. })
                )
            });
            assert!(hit.is_some(), "flipped byte at {offset} went undetected");
        }
        Err(StoreError::BadIndex { key, .. }) => assert_eq!(key, shard),
        Err(other) => panic!("unexpected detection shape: {other:?}"),
    }
    // recovery: generation 1 predates the rot entirely
    let gens = recover_generations(store.as_ref(), &shard).unwrap();
    let gen1 = gens.iter().find(|g| g.end as usize == gen1_end).expect("gen1 survives rot");
    let recovered = assemble_from_generation(store.as_ref(), &shard, gen1, &id).unwrap();
    prove_bit_identical("post-rot rebuild", &recovered.to_bytes(), &ck1_bytes).unwrap();
}

#[test]
fn stale_lock_from_a_crashed_writer_is_broken_and_writes_proceed() {
    let scheme = QuantScheme::MxSquare(ALL_ELEMENT_FORMATS[1]);
    let (store, shard, id, _, _, _) = two_checkpoint_generations(BackendKind::Fast, scheme, 11);
    // the crashed writer died an hour ago, lock still on disk
    inject_stale_lock(store.as_ref(), &shard, Duration::from_secs(3600)).unwrap();
    let probe = vec![(format!("{id}/probe"), b"written past a corpse".to_vec())];
    append_chunks(&store, &shard, &probe, Duration::from_millis(300))
        .expect("staleness takeover breaks the dead writer's lock");
    let read_back = read_live_chunk(store.as_ref(), &shard, &probe[0].0).unwrap();
    prove_bit_identical("post-takeover chunk", &read_back, &probe[0].1).unwrap();
    assert!(!store.exists(&format!("{shard}.lock")).unwrap(), "takeover lock released");
}

// -------------------------------------------------------------- executor

#[test]
fn injected_panic_is_catchable_and_names_the_session() {
    let caught =
        std::panic::catch_unwind(|| mxscale::chaos::inject_panic("t-blast-radius")).unwrap_err();
    let message = caught.downcast_ref::<String>().expect("panic payload is a formatted string");
    assert!(message.contains("t-blast-radius"), "payload `{message}` must name the session");
}

/// Pick session ids the plan faults / spares, deterministically.
fn ids_for(plan: &FaultPlan, crashes: usize, panics: usize, spared: usize) -> Vec<String> {
    let mut ids = Vec::new();
    let (mut c, mut p, mut s) = (0usize, 0usize, 0usize);
    for i in 0.. {
        let id = format!("t-{i:03}");
        match plan.executor_fault(&id) {
            Some(ExecFault::WorkerCrash) if c < crashes => c += 1,
            Some(ExecFault::SessionPanic) if p < panics => p += 1,
            None if s < spared => s += 1,
            _ => continue,
        }
        ids.push(id);
        if c == crashes && p == panics && s == spared {
            return ids;
        }
    }
    unreachable!()
}

/// Arrival whose spec is a pure function of (id, scheme, backend, seed),
/// so a bitwise-identical standalone twin can be rebuilt at will.
fn arrival(id: &str, scheme: QuantScheme, backend: BackendKind, ds: &Dataset) -> Arrival {
    let seed = 0xFEED ^ id.len() as u64 ^ (id.as_bytes()[id.len() - 1] as u64);
    Arrival {
        offer: SessionOffer { id: id.into(), priority: 1, budget_steps: 6 },
        spec: SessionSpec::new(id, "cartpole", ds.clone(), config(scheme, backend, 6, seed)),
    }
}

#[test]
fn executor_faults_recover_bit_identically_across_formats_and_backends() {
    let plan = FaultPlan::new(&[FaultClass::Executor], 0xABAD1DEA);
    let ds = dataset(21);
    // 2 crashes + 2 panics + 2 bystanders, cycling through all six
    // element formats; fast and packed backends interleaved (hardware is
    // exercised by the torn-append grid — here it would dominate runtime)
    let ids = ids_for(&plan, 2, 2, 2);
    let arrivals: Vec<Arrival> = ids
        .iter()
        .enumerate()
        .map(|(i, id)| {
            let scheme = QuantScheme::MxSquare(ALL_ELEMENT_FORMATS[i % ALL_ELEMENT_FORMATS.len()]);
            let backend = if i % 2 == 0 { BackendKind::Fast } else { BackendKind::Packed };
            arrival(id, scheme, backend, &ds)
        })
        .collect();
    let store =
        Arc::new(CheckpointStore::new(Arc::new(MemoryStore::new()), StoreLayout::Sharded {
            shards: 2,
        }));
    let cfg = ServeConfig {
        workers: 2,
        quantum: 2,
        store: Some(store),
        chaos: Some(plan.clone()),
        ..Default::default()
    };
    let twins: Vec<Arrival> = ids
        .iter()
        .enumerate()
        .map(|(i, id)| {
            let scheme = QuantScheme::MxSquare(ALL_ELEMENT_FORMATS[i % ALL_ELEMENT_FORMATS.len()]);
            let backend = if i % 2 == 0 { BackendKind::Fast } else { BackendKind::Packed };
            arrival(id, scheme, backend, &ds)
        })
        .collect();
    let served = serve(arrivals.into_iter(), &BudgetAware::default(), &cfg).unwrap();
    assert_eq!(served.stats.offered, 6);
    assert_eq!(served.stats.recovered, 4, "both crashes and both panics recovered");
    assert_eq!(served.stats.re_admitted, 4, "every recovery came back through admission");
    assert_eq!(served.stats.completed, 6, "every session finished: {:?}", served.stats);
    assert!(served.shed.is_empty(), "{:?}", served.shed);
    // the accounting identity holds with the recovery term
    assert_eq!(
        served.stats.offered + served.stats.re_admitted,
        served.stats.completed + served.shed.len() + served.stats.evicted + served.stats.recovered,
    );
    for (twin_arrival, id) in twins.into_iter().zip(&ids) {
        let done = served.completed.iter().find(|s| &s.id == id).expect("completed");
        assert!(done.error().is_none(), "{id}: {:?}", done.error());
        let mut twin = twin_arrival.spec.build().unwrap();
        while twin.run_quantum(cfg.quantum) > 0 {}
        let (a, b) = (&done.session().train_curve, &twin.session().train_curve);
        assert_eq!(a.len(), b.len(), "{id}: curve length");
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.0, y.0, "{id}: curve step");
            assert_eq!(x.1.to_bits(), y.1.to_bits(), "{id}: curve diverged after recovery");
        }
        assert_eq!(
            done.session().val_loss().to_bits(),
            twin.session().val_loss().to_bits(),
            "{id}: val loss diverged"
        );
    }
}

// ------------------------------------------------------------------ null

#[test]
fn inert_plan_changes_nothing_and_costs_nothing() {
    // a memory-only plan gives the executor nothing to do: no admission
    // checkpoints, no recovery — the run must be bitwise the chaos-free
    // run, and the store must stay untouched
    let ds = dataset(33);
    let ids = ["t-null-a", "t-null-b", "t-null-c"];
    let build = |_with_chaos: bool| -> Vec<Arrival> {
        ids.iter()
            .enumerate()
            .map(|(i, id)| {
                let scheme = QuantScheme::MxSquare(ALL_ELEMENT_FORMATS[i]);
                arrival(id, scheme, BackendKind::Fast, &ds)
            })
            .collect()
    };
    let store =
        Arc::new(CheckpointStore::new(Arc::new(MemoryStore::new()), StoreLayout::Plain));
    let quiet = ServeConfig { workers: 2, quantum: 2, ..Default::default() };
    let inert = ServeConfig {
        workers: 2,
        quantum: 2,
        store: Some(store.clone()),
        chaos: Some(FaultPlan::new(&[FaultClass::Memory], 9)),
        ..Default::default()
    };
    let a = serve(build(false).into_iter(), &BudgetAware::default(), &quiet).unwrap();
    let b = serve(build(true).into_iter(), &BudgetAware::default(), &inert).unwrap();
    // every discrete counter identical (wall-clock fields excepted)
    for (name, x, y) in [
        ("offered", a.stats.offered, b.stats.offered),
        ("admitted", a.stats.admitted, b.stats.admitted),
        ("completed", a.stats.completed, b.stats.completed),
        ("refused", a.stats.refused, b.stats.refused),
        ("failed", a.stats.failed, b.stats.failed),
        ("evicted", a.stats.evicted, b.stats.evicted),
        ("recovered", a.stats.recovered, b.stats.recovered),
        ("re_admitted", a.stats.re_admitted, b.stats.re_admitted),
        ("total_steps", a.stats.total_steps, b.stats.total_steps),
    ] {
        assert_eq!(x, y, "inert plan perturbed `{name}`");
    }
    assert_eq!(b.stats.recovered, 0);
    assert!(store.sessions().unwrap().is_empty(), "inert plan wrote admission checkpoints");
    for id in &ids {
        let x = a.completed.iter().find(|s| &s.id == id).unwrap();
        let y = b.completed.iter().find(|s| &s.id == id).unwrap();
        let (cx, cy) = (&x.session().train_curve, &y.session().train_curve);
        assert_eq!(cx.len(), cy.len(), "{id}");
        for (p, q) in cx.iter().zip(cy.iter()) {
            assert_eq!((p.0, p.1.to_bits()), (q.0, q.1.to_bits()), "{id}: curve diverged");
        }
    }
}
