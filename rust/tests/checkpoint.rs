//! Checkpoint round-trip property tests: save/resume at step k must be
//! bitwise indistinguishable from an uninterrupted run to step k+m —
//! tape, Adam moments, and loss curves — for all six MX formats, all
//! three execution backends, and with the serialized byte format in the loop
//! (every resume below goes through `to_bytes` -> `from_bytes`).

use mxscale::backend::BackendKind;
use mxscale::mx::dacapo::DacapoFormat;
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::trainer::checkpoint::Checkpoint;
use mxscale::trainer::qat::QuantScheme;
use mxscale::trainer::session::{TrainConfig, TrainSession};
use mxscale::workloads::{by_name, Dataset};

fn dataset(seed: u64) -> Dataset {
    let env = by_name("reacher").unwrap();
    Dataset::collect(env.as_ref(), 5, 40, seed)
}

/// Run the save -> serialize -> parse -> resume loop at step `k` and
/// compare against the uninterrupted run at step `k + m`.
fn assert_resume_matches(scheme: QuantScheme, backend: BackendKind, k: usize, m: usize) {
    let label = format!("{}/{}", scheme.name(), backend.name());
    let config = TrainConfig {
        scheme,
        backend,
        dims: Some(vec![32, 16, 32]),
        batch_size: 8,
        steps: 0,
        eval_every: 3,
        ..Default::default()
    };
    let ds = dataset(0xC4E0);

    let mut full = TrainSession::try_new(ds.clone(), config.clone()).unwrap();
    let mut half = TrainSession::try_new(ds.clone(), config).unwrap();
    for _ in 0..k {
        full.step_once();
        half.step_once();
    }

    // serialize through the binary format — corruption-prone path included
    let ck = half.save_checkpoint();
    let bytes = ck.to_bytes();
    let ck2 = Checkpoint::from_bytes(&bytes).unwrap_or_else(|e| panic!("{label}: {e}"));
    assert_eq!(ck2.to_bytes(), bytes, "{label}: reserialization must be identical");
    assert_eq!(ck2.step, k, "{label}");

    let mut resumed = TrainSession::resume(ds.clone(), &ck2).unwrap();
    for _ in 0..m {
        full.step_once();
        resumed.step_once();
    }

    // Adam moments + masters bitwise
    assert_eq!(resumed.mlp.flat_params(), full.mlp.flat_params(), "{label}: params");
    assert_eq!(resumed.mlp.flat_opt_state(), full.mlp.flat_opt_state(), "{label}: moments");
    assert_eq!(resumed.mlp.step, full.mlp.step, "{label}: adam step");
    // loss curves (pre-checkpoint history restored + post-resume identical)
    assert_eq!(resumed.train_curve, full.train_curve, "{label}: train curve");
    assert_eq!(resumed.val_curve, full.val_curve, "{label}: val curve");
    // tape: one forward over the validation split, bit-equal outputs
    let tape_full = full.mlp.forward(&ds.val_x);
    let tape_res = resumed.mlp.forward(&ds.val_x);
    assert_eq!(tape_res.output.data, tape_full.output.data, "{label}: tape");
    assert_eq!(resumed.val_loss(), full.val_loss(), "{label}: val loss");
}

#[test]
fn resume_is_bit_exact_all_six_formats_fast_backend() {
    for fmt in ALL_ELEMENT_FORMATS {
        assert_resume_matches(QuantScheme::MxSquare(fmt), BackendKind::Fast, 7, 5);
    }
}

#[test]
fn resume_is_bit_exact_all_six_formats_hw_backend() {
    for fmt in ALL_ELEMENT_FORMATS {
        assert_resume_matches(QuantScheme::MxSquare(fmt), BackendKind::Hardware, 3, 2);
    }
}

#[test]
fn resume_is_bit_exact_all_six_formats_packed_backend() {
    // the checkpoint names `packed` as its backend and resumes onto the
    // SWAR kernels bitwise, like the other two backends
    for fmt in ALL_ELEMENT_FORMATS {
        assert_resume_matches(QuantScheme::MxSquare(fmt), BackendKind::Packed, 7, 5);
    }
}

#[test]
fn resume_is_bit_exact_for_baseline_schemes() {
    for scheme in [
        QuantScheme::Fp32,
        QuantScheme::MxVector(mxscale::mx::ElementFormat::E4M3),
        QuantScheme::Dacapo(DacapoFormat::Mx9),
    ] {
        assert_resume_matches(scheme, BackendKind::Fast, 5, 4);
    }
}

#[test]
fn square_image_is_single_copy_vector_is_two_and_smaller_on_disk() {
    let run = |scheme: QuantScheme| {
        let mut s = TrainSession::new(
            dataset(0x51DE),
            TrainConfig {
                scheme,
                dims: Some(vec![32, 64, 32]),
                steps: 0,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        for _ in 0..3 {
            s.step_once();
        }
        s.save_checkpoint()
    };
    let fmt = mxscale::mx::ElementFormat::Int8;
    let sq = run(QuantScheme::MxSquare(fmt));
    let vec = run(QuantScheme::MxVector(fmt));
    assert_eq!(sq.payload.len(), 2, "square: one tensor per layer");
    assert_eq!(vec.payload.len(), 4, "vector: W and W-transposed groupings per layer");
    let reduction = 1.0 - sq.payload_bytes() as f64 / vec.payload_bytes() as f64;
    assert!(
        (0.45..0.55).contains(&reduction),
        "square single-copy should store ~51% less: {} vs {} ({reduction})",
        sq.payload_bytes(),
        vec.payload_bytes()
    );
}

#[test]
fn checkpoint_file_round_trips_and_rejects_corruption() {
    let mut s = TrainSession::new(
        dataset(0xF11E),
        TrainConfig {
            scheme: QuantScheme::MxSquare(mxscale::mx::ElementFormat::E5M2),
            dims: Some(vec![32, 16, 32]),
            steps: 0,
            eval_every: 4,
            ..Default::default()
        },
    );
    for _ in 0..6 {
        s.step_once();
    }
    let ck = s.save_checkpoint();
    let dir = std::env::temp_dir().join(format!("mxckpt-test-{}", std::process::id()));
    let path = dir.join("robot.mxckpt");
    ck.save(&path).unwrap();
    let loaded = Checkpoint::load(&path).unwrap();
    assert_eq!(loaded.to_bytes(), ck.to_bytes());
    assert_eq!(loaded.payload_bytes(), ck.payload_bytes());

    // truncation at every section boundary-ish point must error, not panic
    let bytes = ck.to_bytes();
    for cut in [0, 3, 8, bytes.len() / 4, bytes.len() / 2, bytes.len() - 1] {
        assert!(Checkpoint::from_bytes(&bytes[..cut]).is_err(), "cut at {cut}");
    }
    // bad magic
    let mut bad = bytes.clone();
    bad[0] = b'Z';
    assert!(Checkpoint::from_bytes(&bad).is_err());
    // bad version
    let mut bad = bytes.clone();
    bad[4] = 99;
    assert!(Checkpoint::from_bytes(&bad).is_err());
    // trailing garbage
    let mut bad = bytes;
    bad.push(0);
    assert!(Checkpoint::from_bytes(&bad).is_err());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_onto_a_shifted_dataset_adapts_without_reinit() {
    // the continual-learning move: checkpoint on nominal physics, resume
    // on shifted physics — weights carry over (no re-init), and the
    // session keeps improving on the new dynamics from the first step.
    let scheme = QuantScheme::MxSquare(mxscale::mx::ElementFormat::Int8);
    let config = TrainConfig {
        scheme,
        dims: Some(vec![32, 48, 48, 32]),
        steps: 0,
        lr: 2e-3,
        eval_every: usize::MAX,
        ..Default::default()
    };
    let env = by_name("pusher").unwrap();
    let ds = Dataset::collect(env.as_ref(), 8, 50, 0xA);
    let mut s = TrainSession::try_new(ds, config).unwrap();
    for _ in 0..150 {
        s.step_once();
    }
    let ck = s.save_checkpoint();
    let senv = mxscale::workloads::shifted_by_name("pusher").unwrap();
    let sds = Dataset::collect(senv.as_ref(), 8, 50, 0xB);
    let mut adapted = TrainSession::resume(sds, &ck).unwrap();
    assert_eq!(adapted.mlp.flat_params(), ck.params, "no re-init on resume");
    let before = adapted.val_loss();
    for _ in 0..80 {
        adapted.step_once();
    }
    let after = adapted.val_loss();
    assert!(after < before, "adaptation must improve on the shift: {before} -> {after}");
}
