//! SIMD kernel conformance: every dispatchable kernel path is
//! bit-identical to the SWAR twin (which is itself pinned to the dense
//! fake-quant oracle), forced-unavailable paths are structured errors,
//! and the `MXSCALE_KERNEL` / `--kernel` overrides resolve as
//! documented. The twin-oracle tests below reference every `*_swar`
//! scalar twin by name — lint rule L8 requires exactly that.

use mxscale::backend::{force_kernel_path, KernelRegistry, KERNEL_ENV};
use mxscale::mx::block::shared_exponent_from_max;
use mxscale::mx::element::ElementFormat;
use mxscale::mx::packed::{packed_gemm, packed_gemm_nt, PackedTensor};
use mxscale::mx::simd::detect::{self, CpuFeatures};
use mxscale::mx::simd::{
    decode_tile_e2m1_swar, gemm, gemm_nt, max_abs_swar, quantize_pack, quantize_tile_int8_swar,
    tile_dots_i8_swar, transpose8x8_i8_swar, KernelPath,
};
use mxscale::mx::tensor::{fake_quant_mat_fast, Layout};
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;

/// The kernel paths this host can actually execute.
fn live_paths() -> Vec<KernelPath> {
    let feats = detect::features();
    KernelPath::ALL.into_iter().filter(|p| p.available(feats)).collect()
}

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

// ------------------------------------------------- forced-path identity

/// The headline invariant: on every path this CPU offers, every format,
/// and ragged shapes, the SIMD GeMM drivers return the same f32 bits as
/// the SWAR kernels *and* the dense fake-quant oracle (both cuts).
#[test]
fn every_live_path_is_bit_identical_across_formats_and_shapes() {
    let mut rng = Pcg64::new(0x51D0);
    for fmt in ALL_ELEMENT_FORMATS {
        for (m, k, n) in [(8, 8, 8), (16, 24, 16), (13, 9, 17)] {
            let a = Mat::randn(m, k, 1.0, &mut rng);
            let w = Mat::randn(k, n, 0.5, &mut rng);
            let bt = Mat::randn(n, k, 0.5, &mut rng);
            let pa = PackedTensor::quantize_pack(&a, fmt);
            let pw = PackedTensor::quantize_pack(&w, fmt);
            let pbt = PackedTensor::quantize_pack(&bt, fmt);
            let dense = {
                let aq = fake_quant_mat_fast(&a, fmt, Layout::Square8x8);
                let wq = fake_quant_mat_fast(&w, fmt, Layout::Square8x8);
                aq.matmul_blocked(&wq, 8)
            };
            let swar = packed_gemm(&pa, &pw);
            let swar_nt = packed_gemm_nt(&pa, &pbt);
            assert_eq!(bits(&dense), bits(&swar), "{fmt:?} {m}x{k}x{n}: swar != dense");
            for path in live_paths() {
                let g = gemm(path, &pa, &pw);
                assert_eq!(
                    bits(&g),
                    bits(&swar),
                    "{fmt:?} {m}x{k}x{n}: gemm path {} != swar",
                    path.name()
                );
                let gnt = gemm_nt(path, &pa, &pbt);
                assert_eq!(
                    bits(&gnt),
                    bits(&swar_nt),
                    "{fmt:?} {m}x{k}x{n}: gemm_nt path {} != swar",
                    path.name()
                );
            }
        }
    }
}

/// Vectorized quantize-pack produces the exact packed tensor the scalar
/// path produces — codes, lanes, and scales — on SIMD formats and on
/// formats that fall back to SWAR alike.
#[test]
fn quantize_pack_matches_scalar_on_every_live_path() {
    let mut rng = Pcg64::new(0xACE5);
    for fmt in [ElementFormat::Int8, ElementFormat::E2M1, ElementFormat::E4M3] {
        for (r, c) in [(8, 8), (13, 21), (64, 64)] {
            let m = Mat::randn(r, c, 1.5, &mut rng);
            let want = PackedTensor::quantize_pack(&m, fmt);
            for path in live_paths() {
                let got = quantize_pack(path, &m, fmt);
                assert_eq!(got, want, "{fmt:?} {r}x{c}: quantize path {}", path.name());
            }
        }
    }
}

// ---------------------------------------------------- registry behavior

/// Forcing a path the CPU cannot run is a structured error naming the
/// path and the always-available fallback — not a panic, and never a
/// silent downgrade.
#[test]
fn forcing_an_unavailable_path_errors_structurally() {
    for path in [KernelPath::Sse41, KernelPath::Avx2, KernelPath::Neon] {
        let err = match KernelRegistry::with(CpuFeatures::NONE, Some(path)) {
            Ok(_) => panic!("forcing {} on a featureless CPU must fail", path.name()),
            Err(e) => e,
        };
        assert!(err.contains(path.name()), "{err}");
        assert!(err.contains("swar"), "{err}");
    }
    // swar is always forceable, and a featureless CPU resolves to it
    let reg = match KernelRegistry::with(CpuFeatures::NONE, Some(KernelPath::Swar)) {
        Ok(r) => r,
        Err(e) => panic!("swar must always be forceable: {e}"),
    };
    assert_eq!(reg.default_path(), KernelPath::Swar);
    let auto = match KernelRegistry::with(CpuFeatures::NONE, None) {
        Ok(r) => r,
        Err(e) => panic!("auto on a featureless CPU must succeed: {e}"),
    };
    assert_eq!(auto.default_path(), KernelPath::Swar);
}

#[test]
fn parse_accepts_the_documented_vocabulary() {
    assert_eq!(KernelPath::parse("swar"), Ok(KernelPath::Swar));
    assert_eq!(KernelPath::parse("sse41"), Ok(KernelPath::Sse41));
    assert_eq!(KernelPath::parse("sse4.1"), Ok(KernelPath::Sse41));
    assert_eq!(KernelPath::parse("avx2"), Ok(KernelPath::Avx2));
    assert_eq!(KernelPath::parse("neon"), Ok(KernelPath::Neon));
    let err = match KernelPath::parse("warp9") {
        Ok(p) => panic!("bogus path parsed as {}", p.name()),
        Err(e) => e,
    };
    assert!(err.contains("avx2"), "error should name the vocabulary: {err}");
}

/// `MXSCALE_KERNEL` and the CLI `--kernel` override share process-global
/// state, so every case runs in this ONE test (the test harness runs
/// sibling tests in parallel threads).
#[test]
fn env_and_cli_overrides_resolve_in_priority_order() {
    std::env::set_var(KERNEL_ENV, "swar");
    let r = match KernelRegistry::from_env() {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    };
    assert_eq!(r.forced(), Some(KernelPath::Swar));
    std::env::set_var(KERNEL_ENV, "warp9");
    let err = match KernelRegistry::from_env() {
        Ok(_) => panic!("bogus MXSCALE_KERNEL must fail"),
        Err(e) => e,
    };
    assert!(err.contains(KERNEL_ENV), "{err}");
    // the CLI force outranks the (still bogus) env var
    force_kernel_path(Some(KernelPath::Swar));
    let r = match KernelRegistry::from_env() {
        Ok(r) => r,
        Err(e) => panic!("CLI force should outrank the env var: {e}"),
    };
    assert_eq!(r.forced(), Some(KernelPath::Swar));
    force_kernel_path(None);
    std::env::remove_var(KERNEL_ENV);
    let r = match KernelRegistry::from_env() {
        Ok(r) => r,
        Err(e) => panic!("{e}"),
    };
    assert_eq!(r.forced(), None);
}

// ------------------------------------------------------- twin oracles
//
// Each `*_swar` twin is pinned against an independent reference here;
// the vector legs are pinned against the twins by the identity tests
// above (and by the in-module per-kernel tests). L8 requires every
// twin to be referenced from rust/tests/ — this is that reference.

#[test]
fn tile_dots_swar_twin_matches_an_f64_reference() {
    let mut rng = Pcg64::new(0x7D07);
    let mut a = [0i8; 64];
    let mut b = [0i8; 64];
    for v in a.iter_mut() {
        *v = (rng.next_u64() % 255) as i8;
    }
    for v in b.iter_mut() {
        *v = (rng.next_u64() % 255) as i8;
    }
    let mut dots = [0i32; 64];
    tile_dots_i8_swar(&a, &b, &mut dots);
    for i in 0..8 {
        for j in 0..8 {
            let mut want = 0.0f64;
            for k in 0..8 {
                want += a[i * 8 + k] as f64 * b[k * 8 + j] as f64;
            }
            assert_eq!(dots[i * 8 + j] as f64, want, "({i},{j})");
        }
    }
}

#[test]
fn decode_e2m1_swar_twin_is_twice_the_format_decode() {
    let mut lanes = [0u64; 8];
    for (i, lane) in lanes.iter_mut().enumerate() {
        for j in 0..8 {
            let code = ((i * 8 + j) % 16) as u64;
            *lane |= code << (j * 4);
        }
    }
    let mut out = [0i8; 64];
    decode_tile_e2m1_swar(&lanes, &mut out);
    for i in 0..8 {
        for j in 0..8 {
            let code = ((i * 8 + j) % 16) as u8;
            let want = 2.0 * ElementFormat::E2M1.decode(code);
            assert_eq!(out[i * 8 + j] as f64, want, "code {code}");
        }
    }
}

#[test]
fn transpose_swar_twin_roundtrips_and_places_elements() {
    let mut x = [0i8; 64];
    for (i, v) in x.iter_mut().enumerate() {
        *v = i as i8;
    }
    let mut t = [0i8; 64];
    let mut back = [0i8; 64];
    transpose8x8_i8_swar(&x, &mut t);
    transpose8x8_i8_swar(&t, &mut back);
    assert_eq!(x, back);
    for i in 0..8 {
        for j in 0..8 {
            assert_eq!(t[j * 8 + i], x[i * 8 + j]);
        }
    }
}

#[test]
fn max_abs_swar_twin_skips_nan_and_ignores_sign() {
    let mut vals = [0.0f32; 64];
    vals[0] = f32::NAN;
    vals[1] = -3.5;
    vals[2] = 2.0;
    vals[3] = -0.0;
    assert_eq!(max_abs_swar(&vals), 3.5);
    let zeros = [0.0f32; 64];
    assert_eq!(max_abs_swar(&zeros), 0.0);
}

#[test]
fn quantize_tile_int8_swar_twin_matches_quantize_pack_lanes() {
    let mut rng = Pcg64::new(0x1A7E);
    let m = Mat::randn(8, 8, 2.0, &mut rng);
    let p = PackedTensor::quantize_pack(&m, ElementFormat::Int8);
    let mut vals = [0.0f32; 64];
    vals.copy_from_slice(&m.data);
    let se = shared_exponent_from_max(max_abs_swar(&vals), ElementFormat::Int8);
    assert_eq!(se as i8, p.scales[0]);
    let mut lanes = [0u64; 8];
    quantize_tile_int8_swar(&vals, se, &mut lanes);
    assert_eq!(&lanes[..], &p.lanes[..8]);
}
