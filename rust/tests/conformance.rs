//! OCP-spec conformance: committed golden vectors pin the six element
//! codecs (and the shared-exponent derivation) to hand-checked values,
//! so codec drift fails loudly with the exact code/value that moved.
//!
//! The vectors live in `tests/data/mx_golden.json` — every pair was
//! derived by hand from the OCP MX v1.0 bit layouts (sign/exponent/
//! mantissa fields, RNE on the mantissa grid, saturation at the
//! format max, subnormal flush below half the smallest subnormal) and
//! the paper's Table I. The test layer deliberately reads them through
//! [`Json::parse`] rather than hardcoding Rust literals: the golden
//! file is the artifact a hardware team would diff against an RTL
//! testbench, and it must stay language-neutral.

use mxscale::mx::block::{fake_quant_block_fast, quantize_block, shared_exponent};
use mxscale::mx::element::ElementFormat;
use mxscale::mx::ALL_ELEMENT_FORMATS;
use mxscale::util::json::Json;

const GOLDEN: &str = include_str!("data/mx_golden.json");

fn golden() -> Json {
    Json::parse(GOLDEN).expect("tests/data/mx_golden.json must parse")
}

fn fmt_by_name(name: &str) -> ElementFormat {
    ElementFormat::parse(name).unwrap_or_else(|| panic!("golden names unknown format `{name}`"))
}

fn pairs(spec: &Json, key: &str, fmt_name: &str) -> Vec<(f64, f64)> {
    spec.get(key)
        .and_then(|v| v.items())
        .unwrap_or_else(|| panic!("{fmt_name}: missing `{key}` table"))
        .iter()
        .map(|pair| {
            let xs = pair.items().expect("pair");
            assert_eq!(xs.len(), 2, "{fmt_name} {key}: pairs are [a, b]");
            (xs[0].as_f64().unwrap(), xs[1].as_f64().unwrap())
        })
        .collect()
}

#[test]
fn golden_covers_all_six_formats() {
    let g = golden();
    let formats = g.get("formats").and_then(|f| f.entries()).expect("formats object");
    assert_eq!(formats.len(), 6, "every Table I format must be pinned");
    for fmt in ALL_ELEMENT_FORMATS {
        assert!(
            formats.iter().any(|(name, _)| fmt_by_name(name) == fmt),
            "{fmt:?} missing from the golden file"
        );
    }
}

#[test]
fn golden_static_properties_match_table1() {
    let g = golden();
    for (name, spec) in g.get("formats").unwrap().entries().unwrap() {
        let fmt = fmt_by_name(name);
        let num = |k: &str| {
            spec.get(k)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("{name}: missing `{k}`"))
        };
        assert_eq!(num("bits") as u32, fmt.bits(), "{name} bits");
        assert_eq!(num("exp_bits") as u32, fmt.exp_bits(), "{name} exp_bits");
        assert_eq!(num("mant_bits") as u32, fmt.mant_bits(), "{name} mant_bits");
        assert_eq!(num("bias") as i32, fmt.bias(), "{name} bias");
        assert_eq!(num("emax") as i32, fmt.emax(), "{name} emax");
        assert_eq!(num("max"), fmt.max_value(), "{name} max");
        assert_eq!(num("min_subnormal"), fmt.min_subnormal(), "{name} min_subnormal");
        if let Some(emin) = spec.get("emin").and_then(|v| v.as_f64()) {
            assert_eq!(emin as i32, fmt.emin(), "{name} emin");
        }
    }
}

#[test]
fn golden_decode_tables_pin_the_codecs() {
    let g = golden();
    for (name, spec) in g.get("formats").unwrap().entries().unwrap() {
        let fmt = fmt_by_name(name);
        for (code, want) in pairs(spec, "decode", name) {
            let code = code as u8;
            let got = fmt.decode(code);
            assert_eq!(got, want, "{name}: decode({code:#04x}) = {got}, golden {want}");
            // exact: the golden values are on the format grid, so the
            // f64 comparison above must hold bitwise too
            assert_eq!(got.to_bits(), want.to_bits(), "{name}: decode({code:#04x}) bits");
        }
    }
}

#[test]
fn golden_fake_quant_pins_rounding_saturation_and_flushes() {
    let g = golden();
    for (name, spec) in g.get("formats").unwrap().entries().unwrap() {
        let fmt = fmt_by_name(name);
        for (input, want) in pairs(spec, "fake_quant", name) {
            let got = fmt.fake_quant(input);
            assert_eq!(got, want, "{name}: fake_quant({input}) = {got}, golden {want}");
            // and the quantized value is a fixpoint of the codec
            assert_eq!(fmt.fake_quant(got), got, "{name}: fake_quant({input}) not on-grid");
        }
    }
}

#[test]
fn golden_encode_codes_match_bit_layouts() {
    let g = golden();
    for (name, spec) in g.get("formats").unwrap().entries().unwrap() {
        let fmt = fmt_by_name(name);
        for (input, want) in pairs(spec, "encode", name) {
            let got = fmt.encode(input);
            assert_eq!(got, want as u8, "{name}: encode({input}) = {got:#04x}");
        }
    }
}

#[test]
fn golden_block_scales_match_spec_derivation() {
    // shared_exp = floor(log2(max_abs)) - emax, clamped to E8M0 — the
    // OCP §5.2 / §6.3 derivation, pinned on hand-computed blocks
    let g = golden();
    let blocks = g.get("blocks").and_then(|b| b.items()).expect("blocks");
    assert!(blocks.len() >= 6, "block-scale coverage");
    for b in blocks {
        let fmt = fmt_by_name(b.get("format").and_then(|v| v.as_str()).unwrap());
        let values: Vec<f32> = b
            .get("values")
            .and_then(|v| v.items())
            .unwrap()
            .iter()
            .map(|v| v.as_f64().unwrap() as f32)
            .collect();
        let want = b.get("scale_exp").and_then(|v| v.as_f64()).unwrap() as i32;
        let got = shared_exponent(&values, fmt);
        assert_eq!(got, want, "{fmt:?} {values:?}: scale_exp {got}, golden {want}");
        // the full block quantizer derives the same scale, and the fast
        // in-place QAT path reproduces the codec path bit for bit on
        // these (finite) golden blocks
        let q = quantize_block(&values, fmt);
        assert_eq!(q.scale_exp, want, "{fmt:?} {values:?}: quantize_block scale");
        let mut fast = values.clone();
        fake_quant_block_fast(&mut fast, fmt);
        for (i, &v) in values.iter().enumerate() {
            let codec = q.decode(i) as f32;
            assert_eq!(
                codec.to_bits(),
                fast[i].to_bits(),
                "{fmt:?} elem {i} ({v}): codec {codec} vs fast {}",
                fast[i]
            );
        }
    }
}
