//! Deterministic-PRNG property suite over all six MX codecs.
//!
//! Each property pins an invariant the training stack leans on:
//!
//! * **Idempotence** — `fq(fq(x)) == fq(x)` bitwise, both layouts,
//!   both the codec path and the fast QAT path. This is why a
//!   precision *transition* that requantizes from the FP32 master is
//!   exact: quantized values are fixpoints of their own format.
//! * **Monotonicity / sign preservation** within a block — fake
//!   quantization never reorders values sharing a scale and never
//!   flips a sign (gradients keep their direction).
//! * **Scale-byte bounds** — every shared exponent stays in the E8M0
//!   clamp range and fits the one `i8` byte the checkpoint/packed
//!   formats store.
//! * **Edge handling** — zeros, −0.0, subnormals, ±Inf, NaN behave as
//!   specified (and *as implemented*: the fast matrix path flushes
//!   −0.0 and zeroes non-finite blocks; the element codecs saturate
//!   ±Inf and never emit specials).
//! * **Pack fixpoint** — `pack → unpack → pack` is the identity on
//!   [`PackedTensor`], and `quantize_pack` equals `quantize` + `pack`.

use mxscale::mx::block::{fake_quant_block_fast, quantize_block, shared_exponent};
use mxscale::mx::element::ElementFormat;
use mxscale::mx::packed::PackedTensor;
use mxscale::mx::tensor::{fake_quant_mat_fast, Layout, MxTensor};
use mxscale::mx::{ALL_ELEMENT_FORMATS, SCALE_EMAX, SCALE_EMIN};
use mxscale::util::mat::Mat;
use mxscale::util::rng::Pcg64;
use mxscale::util::testing::forall;

fn bits(m: &Mat) -> Vec<u32> {
    m.data.iter().map(|v| v.to_bits()).collect()
}

/// A ragged matrix of finite wide-dynamic-range values.
fn gen_mat(r: &mut Pcg64) -> (ElementFormat, Mat) {
    let fmt = ALL_ELEMENT_FORMATS[r.below(6) as usize];
    let rows = 1 + r.below(33) as usize;
    let cols = 1 + r.below(33) as usize;
    let m = Mat::from_fn(rows, cols, |_, _| r.wide_f32().clamp(-1e30, 1e30));
    (fmt, m)
}

#[test]
fn fast_fake_quant_is_idempotent_bitwise() {
    forall(0x1DE0, 96, gen_mat, |(fmt, m)| {
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let once = fake_quant_mat_fast(m, *fmt, layout);
            let twice = fake_quant_mat_fast(&once, *fmt, layout);
            if bits(&once) != bits(&twice) {
                let i = once.data.iter().zip(&twice.data).position(|(a, b)| a != b).unwrap();
                return Err(format!(
                    "{fmt:?} {layout:?} elem {i}: {} requantized to {} (input {})",
                    once.data[i], twice.data[i], m.data[i]
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn codec_fake_quant_is_idempotent_bitwise() {
    forall(0x1DE1, 64, gen_mat, |(fmt, m)| {
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let once = MxTensor::fake_quant(m, *fmt, layout);
            let twice = MxTensor::fake_quant(&once, *fmt, layout);
            if bits(&once) != bits(&twice) {
                return Err(format!("{fmt:?} {layout:?}: codec path not idempotent"));
            }
        }
        Ok(())
    });
}

#[test]
fn block_fake_quant_is_weakly_monotone_and_sign_preserving() {
    forall(
        0x3070,
        128,
        |r| {
            let fmt = ALL_ELEMENT_FORMATS[r.below(6) as usize];
            let mut v = [0.0f32; 64];
            for x in v.iter_mut() {
                *x = r.wide_f32().clamp(-1e30, 1e30);
            }
            (fmt, v)
        },
        |(fmt, v)| {
            let mut q = *v;
            fake_quant_block_fast(&mut q, *fmt);
            for i in 0..v.len() {
                // no sign flip (−0.0 flushing to +0.0 is ±0, allowed)
                if (q[i] as f64) * (v[i] as f64) < 0.0 {
                    return Err(format!("{fmt:?}: sign flip {} -> {}", v[i], q[i]));
                }
                for j in 0..v.len() {
                    if v[i] <= v[j] && q[i] > q[j] {
                        return Err(format!(
                            "{fmt:?}: order broken: fq({}) = {} > fq({}) = {}",
                            v[i], q[i], v[j], q[j]
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn shared_exponents_stay_in_the_e8m0_clamp_and_fit_one_byte() {
    forall(
        0x5CA1E,
        256,
        |r| {
            let fmt = ALL_ELEMENT_FORMATS[r.below(6) as usize];
            let n = 1 + r.below(64) as usize;
            let mut v = vec![0.0f32; n];
            for x in v.iter_mut() {
                // span the entire finite f32 range, subnormals included
                *x = match r.below(5) {
                    0 => 0.0,
                    1 => f32::MAX * r.range_f32(-1.0, 1.0),
                    2 => f32::MIN_POSITIVE * r.range_f32(-0.5, 0.5), // f32 subnormals
                    _ => r.wide_f32(),
                };
            }
            (fmt, v)
        },
        |(fmt, v)| {
            let se = shared_exponent(v, *fmt);
            if !(SCALE_EMIN..=SCALE_EMAX).contains(&se) {
                return Err(format!("{fmt:?}: scale exponent {se} out of E8M0 range"));
            }
            if i8::try_from(se).is_err() {
                return Err(format!("{fmt:?}: scale exponent {se} does not fit i8"));
            }
            let b = quantize_block(v, *fmt);
            if b.scale_exp != se {
                return Err(format!("{fmt:?}: quantize_block scale {} != {se}", b.scale_exp));
            }
            Ok(())
        },
    );
}

#[test]
fn zero_blocks_and_negative_zero_quantize_to_positive_zero_on_the_fast_path() {
    for fmt in ALL_ELEMENT_FORMATS {
        // an all-zero block (signed zeros included) quantizes to +0.0
        let m = Mat::from_fn(8, 8, |r, c| if (r + c) % 2 == 0 { 0.0 } else { -0.0 });
        for layout in [Layout::Square8x8, Layout::Vector32] {
            let q = fake_quant_mat_fast(&m, fmt, layout);
            for (i, v) in q.data.iter().enumerate() {
                assert_eq!(v.to_bits(), 0.0f32.to_bits(), "{fmt:?} {layout:?} elem {i}");
            }
        }
        // −0.0 among finite values still flushes to +0.0 on the fast
        // matrix path (INT8: two's complement has no signed zero; FP:
        // the in-place kernel flushes — pinned so drift fails loudly)
        let m = Mat::from_fn(1, 8, |_, c| if c == 3 { -0.0 } else { 1.0 + c as f32 });
        let q = fake_quant_mat_fast(&m, fmt, Layout::Square8x8);
        assert_eq!(q.data[3].to_bits(), 0.0f32.to_bits(), "{fmt:?} -0.0 must flush");
    }
}

#[test]
fn non_finite_blocks_zero_out_on_the_fast_path() {
    // the training path never produces non-finite values; the fast
    // kernel's defined degradation is to zero the whole block rather
    // than poison the scale derivation — pinned here
    for fmt in ALL_ELEMENT_FORMATS {
        for bad in [f32::INFINITY, f32::NEG_INFINITY] {
            let mut v = [1.0f32; 64];
            v[17] = bad;
            fake_quant_block_fast(&mut v, fmt);
            assert!(v.iter().all(|&x| x == 0.0), "{fmt:?} {bad} block must zero");
        }
        let mut v = [f32::NAN; 64];
        fake_quant_block_fast(&mut v, fmt);
        assert!(v.iter().all(|&x| x == 0.0), "{fmt:?} all-NaN block must zero");
    }
}

#[test]
fn element_codecs_saturate_infinities_and_never_emit_specials() {
    for fmt in ALL_ELEMENT_FORMATS {
        let max = fmt.max_value();
        assert_eq!(fmt.fake_quant(f64::INFINITY), max, "{fmt:?} +inf");
        assert_eq!(fmt.fake_quant(f64::NEG_INFINITY), -max, "{fmt:?} -inf");
        assert!(!fmt.is_special(fmt.encode(f64::INFINITY)), "{fmt:?} inf code");
        // NaN: INT8 encodes the zero code; FP formats map to the max
        // magnitude (the saturating datapath has no NaN to hand back)
        let nan_q = fmt.fake_quant(f64::NAN);
        if fmt == ElementFormat::Int8 {
            assert_eq!(nan_q, 0.0, "{fmt:?} NaN");
        } else {
            assert_eq!(nan_q.abs(), max, "{fmt:?} NaN");
        }
        assert!(!fmt.is_special(fmt.encode(f64::NAN)), "{fmt:?} NaN code");
        // subnormal edge: the smallest subnormal is a fixpoint, half of
        // it flushes to zero
        let eps = fmt.min_subnormal();
        assert_eq!(fmt.fake_quant(eps), eps, "{fmt:?} min subnormal");
        assert_eq!(fmt.fake_quant(eps * 0.499), 0.0, "{fmt:?} sub-half flush");
        assert_eq!(fmt.fake_quant(-eps), -eps, "{fmt:?} -min subnormal");
    }
}

#[test]
fn negative_zero_through_the_element_codecs_is_pinned() {
    // INT8 is two's complement: no signed zero, −0.0 encodes to code 0
    // and decodes +0.0. The FP codecs keep the sign bit (a signed-zero
    // code exists), so their −0.0 round-trips with the sign intact.
    assert_eq!(ElementFormat::Int8.encode(-0.0), 0);
    assert!(!ElementFormat::Int8.fake_quant(-0.0).is_sign_negative());
    for fmt in ALL_ELEMENT_FORMATS {
        if fmt == ElementFormat::Int8 {
            continue;
        }
        let q = fmt.fake_quant(-0.0);
        assert_eq!(q, 0.0, "{fmt:?}");
        assert!(q.is_sign_negative(), "{fmt:?}: FP codec keeps the zero sign");
    }
}

#[test]
fn pack_unpack_pack_is_a_fixpoint() {
    forall(0xF1A7, 96, gen_mat, |(fmt, m)| {
        let q = MxTensor::quantize(m, *fmt, Layout::Square8x8);
        let p = PackedTensor::pack(&q).expect("square layout packs");
        let u = p.unpack();
        if u.blocks != q.blocks {
            return Err(format!("{fmt:?}: unpack(pack(q)) != q"));
        }
        if (u.rows, u.cols, u.brows, u.bcols) != (q.rows, q.cols, q.brows, q.bcols) {
            return Err(format!("{fmt:?}: unpack changed the shape"));
        }
        let p2 = PackedTensor::pack(&u).expect("square layout packs");
        if p2 != p {
            return Err(format!("{fmt:?}: pack -> unpack -> pack moved bits"));
        }
        // the fused quantize_pack is the same object, and packed scales
        // are exactly the block scale bytes
        let fused = PackedTensor::quantize_pack(m, *fmt);
        if fused != p {
            return Err(format!("{fmt:?}: quantize_pack != quantize + pack"));
        }
        for (i, b) in q.blocks.iter().enumerate() {
            if p.scales[i] as i32 != b.scale_exp {
                return Err(format!("{fmt:?} block {i}: packed scale byte mismatch"));
            }
        }
        if bits(&p.dequantize()) != bits(&q.dequantize()) {
            return Err(format!("{fmt:?}: packed dequantize diverged"));
        }
        Ok(())
    });
}
