//! mxlint fixture and self-run tests (DESIGN.md §9).
//!
//! Each rule L1–L9 gets a known-bad snippet from `lint_fixtures/` that
//! must fire, plus a negative case that must not. The self-run tests
//! then hold the real tree to the same standard: HEAD lints clean, the
//! committed byte-layout manifest is current (which also cross-checks
//! the Rust lexer against the `ci/mxlint_mirror.py` port that generated
//! it), and the allowlist contains exactly the reviewed entries.

use std::path::PathBuf;

use mxscale::lint::{self, lex, rules, Allow, Manifest, SourceFile};

fn sf(rel: &str, text: &str) -> SourceFile {
    SourceFile { rel: rel.to_string(), lexed: lex::lex(text.as_bytes()) }
}

fn no_allow() -> Allow {
    Allow::new()
}

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).parent().expect("crate has a parent dir").into()
}

fn read(path: PathBuf) -> String {
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {}: {e}", path.display()))
}

// ---------------------------------------------------------------- L1

#[test]
fn l1_flags_kernel_without_serial_twin() {
    let src = [sf("rust/src/util/mat.rs", include_str!("lint_fixtures/l1_no_serial_twin.rs"))];
    let f = rules::l1(&src, &[], &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L1", 5));
    assert!(f[0].message.contains("has no `scaled_sum_serial` twin"), "{}", f[0].message);
}

#[test]
fn l1_flags_serial_twin_unreferenced_by_tests() {
    let src =
        [sf("rust/src/util/mat.rs", include_str!("lint_fixtures/l1_unreferenced_serial.rs"))];
    let f = rules::l1(&src, &[], &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L1", 4));
    assert!(
        f[0].message.contains("is not referenced from any identity test"),
        "{}",
        f[0].message
    );
}

#[test]
fn l1_accepts_referenced_serial_twin() {
    let src =
        [sf("rust/src/util/mat.rs", include_str!("lint_fixtures/l1_unreferenced_serial.rs"))];
    let tests = [sf("rust/tests/parallel.rs", "fn t() { orphan_kernel_serial(3); }")];
    assert!(rules::l1(&src, &tests, &no_allow()).is_empty());
}

#[test]
fn l1_ignores_files_outside_scope() {
    let src = [sf("rust/src/energy/model.rs", include_str!("lint_fixtures/l1_no_serial_twin.rs"))];
    assert!(rules::l1(&src, &[], &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L2

#[test]
fn l2_flags_float_log_in_mx_code() {
    let src = [sf("rust/src/mx/block.rs", include_str!("lint_fixtures/l2_float_log.rs"))];
    let f = rules::l2(&src, &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L2", 6));
    assert!(f[0].message.contains("`log2(`"), "{}", f[0].message);
    assert!(f[0].message.contains("floor_log2"), "{}", f[0].message);
}

#[test]
fn l2_scope_is_mx_only() {
    let src = [sf("rust/src/trainer/mlp.rs", include_str!("lint_fixtures/l2_float_log.rs"))];
    assert!(rules::l2(&src, &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L3

#[test]
fn l3_flags_magic_widths_and_lane_masks() {
    let src = [sf("rust/src/mx/packed.rs", include_str!("lint_fixtures/l3_magic_width.rs"))];
    let f = rules::l3(&src, &no_allow());
    assert_eq!(f.len(), 2, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L3", 6));
    assert!(f[0].message.contains("magic bit-width literal `4`"), "{}", f[0].message);
    assert_eq!(f[1].line, 7);
    assert!(f[1].message.contains("0x0101_0101_0101_0101"), "{}", f[1].message);
}

#[test]
fn l3_exempts_const_tables() {
    let src = [sf("rust/src/mx/packed.rs", "const LANES: usize = 8;\n")];
    assert!(rules::l3(&src, &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L4

#[test]
fn l4_flags_unwrap_in_library_code() {
    let src = [sf("rust/src/trainer/session.rs", include_str!("lint_fixtures/l4_unwrap.rs"))];
    let f = rules::l4(&src, &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L4", 5));
    assert!(f[0].message.contains("`.unwrap(`"), "{}", f[0].message);
    assert!(f[0].message.contains("TrainError"), "{}", f[0].message);
}

#[test]
fn l4_exempts_test_modules() {
    let snippet = "#[cfg(test)]\nmod tests {\n    fn f() {\n        g().unwrap();\n    }\n}\n";
    let src = [sf("rust/src/trainer/session.rs", snippet)];
    assert!(rules::l4(&src, &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L5

fn l5_fixture_src() -> Vec<SourceFile> {
    vec![sf("rust/src/trainer/checkpoint.rs", include_str!("lint_fixtures/l5_layout.rs"))]
}

#[test]
fn l5_flags_layout_drift_without_version_bump() {
    let src = l5_fixture_src();
    let m = Manifest {
        version: 2,
        store_version: 0,
        entries: vec![("trainer/checkpoint.rs::to_bytes".into(), 0xdead)],
    };
    let f = rules::l5(&src, &m);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].rule, "L5");
    assert!(f[0].message.contains("without a VERSION bump (still 2)"), "{}", f[0].message);
}

#[test]
fn l5_flags_stale_manifest_version() {
    let f = rules::l5(
        &l5_fixture_src(),
        &Manifest { version: 3, store_version: 0, entries: vec![] },
    );
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(
        f[0].message.contains("records VERSION 3 but checkpoint.rs has VERSION 2"),
        "{}",
        f[0].message
    );
}

#[test]
fn l5_accepts_matching_hash_and_version() {
    let src = l5_fixture_src();
    let m = lint::current_manifest(&src);
    assert!(rules::l5(&src, &m).is_empty());
}

/// The acceptance check for the whole rule: seed a body edit into the
/// *real* `trainer/checkpoint.rs` without bumping `VERSION` and assert
/// the committed manifest catches it.
#[test]
fn l5_catches_seeded_drift_in_real_checkpoint() {
    let root = repo_root();
    let text = read(root.join("rust/src/trainer/checkpoint.rs"));
    let marker = "pub fn to_bytes(&self) -> Vec<u8> {";
    let seeded = text.replacen(marker, "pub fn to_bytes(&self) -> Vec<u8> { let _seeded = 1;", 1);
    assert_ne!(seeded, text, "to_bytes marker not found; update this test");
    let (mut src, _tests) = lint::collect_sources(&root).expect("collect sources");
    for f in &mut src {
        if f.rel == "rust/src/trainer/checkpoint.rs" {
            *f = sf("rust/src/trainer/checkpoint.rs", &seeded);
        }
    }
    let manifest = lint::parse_manifest(&read(root.join("rust/lint.manifest"))).expect("manifest");
    let f = rules::l5(&src, &manifest);
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("trainer/checkpoint.rs::to_bytes"), "{}", f[0].message);
    assert!(f[0].message.contains("without a VERSION bump"), "{}", f[0].message);
}

// ------------------------------------------------------ L5 store pins

/// Checkpoint + store fixtures together: the dual-versioned manifest
/// governs `trainer/*` keys with `version` and `store/*` keys with
/// `store_version`.
fn l5_store_fixture_src() -> Vec<SourceFile> {
    vec![
        sf("rust/src/trainer/checkpoint.rs", include_str!("lint_fixtures/l5_layout.rs")),
        sf("rust/src/store/mod.rs", include_str!("lint_fixtures/l5_store_layout.rs")),
    ]
}

#[test]
fn l5_flags_store_layout_drift_without_store_version_bump() {
    let src = l5_store_fixture_src();
    let mut m = lint::current_manifest(&src);
    for (key, hash) in &mut m.entries {
        if key == "store/mod.rs::write_bytes" {
            *hash ^= 1;
        }
    }
    let f = rules::l5(&src, &m);
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!(f[0].file, "rust/src/store/mod.rs");
    assert!(
        f[0].message.contains("without a store VERSION bump (still 1)"),
        "{}",
        f[0].message
    );
    assert!(f[0].message.contains("bump VERSION in store/mod.rs"), "{}", f[0].message);
}

#[test]
fn l5_flags_stale_store_version() {
    let src = l5_store_fixture_src();
    let mut m = lint::current_manifest(&src);
    m.store_version = 9;
    let f = rules::l5(&src, &m);
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(
        f[0].message.contains("records store VERSION 9 but store/mod.rs has VERSION 1"),
        "{}",
        f[0].message
    );
}

#[test]
fn l5_accepts_matching_store_hashes_and_versions() {
    let src = l5_store_fixture_src();
    let m = lint::current_manifest(&src);
    assert_eq!(m.store_version, 1);
    assert!(m.entries.iter().any(|(k, _)| k == "store/mod.rs::write_bytes"), "{m:?}");
    assert!(rules::l5(&src, &m).is_empty());
}

/// Seed a body edit into the *real* shard codec without bumping the
/// store VERSION and assert the committed manifest catches it.
#[test]
fn l5_catches_seeded_drift_in_real_shard_codec() {
    let root = repo_root();
    let text = read(root.join("rust/src/store/shard.rs"));
    let marker = "pub fn write_bytes(&self, w: &mut ByteWriter) {";
    let seeded = text.replacen(
        marker,
        "pub fn write_bytes(&self, w: &mut ByteWriter) { let _seeded = 1;",
        1,
    );
    assert_ne!(seeded, text, "write_bytes marker not found; update this test");
    let (mut src, _tests) = lint::collect_sources(&root).expect("collect sources");
    for f in &mut src {
        if f.rel == "rust/src/store/shard.rs" {
            *f = sf("rust/src/store/shard.rs", &seeded);
        }
    }
    let manifest = lint::parse_manifest(&read(root.join("rust/lint.manifest"))).expect("manifest");
    let f = rules::l5(&src, &manifest);
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(f[0].message.contains("store/shard.rs::write_bytes"), "{}", f[0].message);
    assert!(f[0].message.contains("without a store VERSION bump"), "{}", f[0].message);
}

// ---------------------------------------------------------------- L6

#[test]
fn l6_flags_unstamped_results_writer() {
    let fixture = include_str!("lint_fixtures/l6_unstamped_writer.rs");
    let src = [sf("rust/src/coordinator/report.rs", fixture)];
    let f = rules::l6(&src, &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L6", 5));
    assert!(f[0].message.contains("`save_run` writes results JSON"), "{}", f[0].message);
}

#[test]
fn l6_accepts_stamped_writer() {
    let snippet = "pub fn save_run() {\n    let doc = stamped_doc(\"run\");\n    \
                   save_json(&doc, \"run\");\n}\n";
    let src = [sf("rust/src/coordinator/report.rs", snippet)];
    assert!(rules::l6(&src, &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L7

#[test]
fn l7_flags_missing_forbid() {
    let src = [sf("rust/src/mx/block.rs", include_str!("lint_fixtures/l7_missing_forbid.rs"))];
    let f = rules::l7(&src, &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L7", 1));
    assert!(f[0].message.contains("#![forbid(unsafe_code)]"), "{}", f[0].message);
}

#[test]
fn l7_flags_unsafe_without_safety_comment() {
    let src = [sf("rust/src/mx/block.rs", include_str!("lint_fixtures/l7_unsafe_no_safety.rs"))];
    let f = rules::l7(&src, &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L7", 6));
    assert!(f[0].message.contains("SAFETY"), "{}", f[0].message);
}

#[test]
fn l7_accepts_unsafe_with_adjacent_safety_comment() {
    let snippet = "pub fn first(v: &[u8]) -> u8 {\n    // SAFETY: caller guarantees non-empty\n    \
                   unsafe { *v.get_unchecked(0) }\n}\n";
    let src = [sf("rust/src/mx/block.rs", snippet)];
    assert!(rules::l7(&src, &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L8

#[test]
fn l8_flags_ungated_unsuffixed_untwinned_kernels() {
    let src = [sf("rust/src/mx/simd/x86.rs", include_str!("lint_fixtures/l8_firing.rs"))];
    let f = rules::l8(&src, &[], &no_allow());
    assert_eq!(f.len(), 4, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L8", 5));
    assert!(f[0].message.contains("without an inner"), "{}", f[0].message);
    assert_eq!(f[1].line, 5);
    assert!(f[1].message.contains("has no `tile_sum_swar` scalar twin"), "{}", f[1].message);
    assert_eq!(f[2].line, 10);
    assert!(f[2].message.contains("without an inner"), "{}", f[2].message);
    assert_eq!(f[3].line, 10);
    assert!(f[3].message.contains("not named for its vector path"), "{}", f[3].message);
}

#[test]
fn l8_flags_target_feature_outside_the_simd_module() {
    let src = [sf("rust/src/mx/packed.rs", include_str!("lint_fixtures/l8_firing.rs"))];
    let f = rules::l8(&src, &[], &no_allow());
    assert_eq!(f.len(), 2, "{f:?}");
    assert!(f[0].message.contains("outside rust/src/mx/simd/"), "{}", f[0].message);
    assert!(f[1].message.contains("outside rust/src/mx/simd/"), "{}", f[1].message);
}

#[test]
fn l8_flags_twin_unreferenced_by_tests() {
    let src = [sf("rust/src/mx/simd/x86.rs", include_str!("lint_fixtures/l8_clean.rs"))];
    let f = rules::l8(&src, &[], &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert!(
        f[0].message.contains("`tile_sum_swar` of `tile_sum_avx2` is not referenced"),
        "{}",
        f[0].message
    );
}

#[test]
fn l8_accepts_gated_suffixed_kernel_with_tested_twin() {
    let src = [sf("rust/src/mx/simd/x86.rs", include_str!("lint_fixtures/l8_clean.rs"))];
    let tests = [sf("rust/tests/simd.rs", "fn t() { tile_sum_swar(&[0; 64]); }")];
    assert!(rules::l8(&src, &tests, &no_allow()).is_empty());
}

// ---------------------------------------------------------------- L9

#[test]
fn l9_flags_undrilled_ungated_and_planless_seams() {
    let src = [sf("rust/src/serve/executor.rs", include_str!("lint_fixtures/l9_firing.rs"))];
    let f = rules::l9(&src, &[], &no_allow());
    assert_eq!(f.len(), 3, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L9", 4));
    assert!(
        f[0].message.contains("`inject_orphan_seam` is not referenced from any test"),
        "{}",
        f[0].message
    );
    assert_eq!(f[1].line, 4);
    assert!(f[1].message.contains("outside rust/src/chaos/"), "{}", f[1].message);
    assert_eq!(f[2].line, 9);
    assert!(
        f[2].message.contains("`inject_remote_seam` referenced without `FaultPlan`"),
        "{}",
        f[2].message
    );
}

#[test]
fn l9_scopes_the_gating_requirements_to_files_outside_chaos() {
    let src = [sf("rust/src/chaos/memory.rs", include_str!("lint_fixtures/l9_firing.rs"))];
    let f = rules::l9(&src, &[], &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L9", 4));
    assert!(f[0].message.contains("not referenced from any test"), "{}", f[0].message);
}

#[test]
fn l9_still_requires_a_drill_for_gated_plan_aware_seams() {
    let src = [sf("rust/src/serve/executor.rs", include_str!("lint_fixtures/l9_clean.rs"))];
    let f = rules::l9(&src, &[], &no_allow());
    assert_eq!(f.len(), 1, "{f:?}");
    assert_eq!((f[0].rule, f[0].line), ("L9", 7));
    assert!(f[0].message.contains("not referenced from any test"), "{}", f[0].message);
}

#[test]
fn l9_accepts_gated_plan_aware_drilled_seams() {
    let src = [sf("rust/src/serve/executor.rs", include_str!("lint_fixtures/l9_clean.rs"))];
    let tests = [sf("rust/tests/chaos.rs", "fn t() { inject_gated_seam(1); }")];
    assert!(rules::l9(&src, &tests, &no_allow()).is_empty());
}

// ------------------------------------------------------------ self-run

/// HEAD must lint clean under the committed allowlist and manifest —
/// the same invariant the CI `lint` job enforces with the binary.
#[test]
fn self_run_is_clean_on_head() {
    let root = repo_root();
    let (src, tests) = lint::collect_sources(&root).expect("collect sources");
    let cfg = lint::parse_config(&read(root.join("rust/lint.toml"))).expect("lint.toml");
    let manifest = lint::parse_manifest(&read(root.join("rust/lint.manifest"))).expect("manifest");
    let findings = lint::lint(&src, &tests, &cfg, &manifest);
    let rendered: Vec<String> = findings
        .iter()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(findings.is_empty(), "mxlint findings on HEAD:\n{}", rendered.join("\n"));
}

/// The committed manifest must match what the Rust lexer computes from
/// the tree. Because `rust/lint.manifest` is (re)generated by the
/// Python mirror on toolchain-free machines, this doubles as a
/// conformance test between the two lexer implementations.
#[test]
fn committed_manifest_is_current() {
    let root = repo_root();
    let (src, _tests) = lint::collect_sources(&root).expect("collect sources");
    let want = lint::render_manifest(&lint::current_manifest(&src));
    let got = read(root.join("rust/lint.manifest"));
    assert_eq!(got, want, "rust/lint.manifest is stale — run `mxlint --update-manifest`");
}

/// Pin the allowlist to exactly the reviewed entries so additions (and
/// stale leftovers) show up as a test diff, not a silent waiver.
#[test]
fn allowlist_is_exactly_the_reviewed_set() {
    let root = repo_root();
    let cfg = lint::parse_config(&read(root.join("rust/lint.toml"))).expect("lint.toml");
    let got: Vec<(String, Vec<String>)> = cfg
        .allow
        .iter()
        .map(|(rule, v)| (rule.clone(), v.iter().map(|(k, _)| k.clone()).collect()))
        .collect();
    let want = vec![
        ("L1".to_string(), vec!["fake_quant_mat_fast_into".to_string()]),
        (
            "L3".to_string(),
            vec![
                "dot8_i8".to_string(),
                "transpose8x8_bytes".to_string(),
                "e2m1_pair_lut".to_string(),
            ],
        ),
        ("L4".to_string(), vec!["backend/hw.rs".to_string(), "backend/packed.rs".to_string()]),
        (
            "L6".to_string(),
            vec![
                "coordinator/cli.rs::cmd_fleet".to_string(),
                "coordinator/cli.rs::cmd_serve".to_string(),
                "coordinator/experiments.rs::precision_schedule".to_string(),
            ],
        ),
    ];
    assert_eq!(got, want);
}
