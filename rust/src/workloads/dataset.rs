//! Dataset collection for dynamics-model learning.
//!
//! Rolls out a random policy in an [`Env`], recording `(state, action) ->
//! next_state - state` transitions (PETS-style delta prediction), then
//! normalizes and packs them into the 32-wide input / 32-wide output
//! layout of the paper's 4-layer MLP (extra dimensions zero-padded).

#![forbid(unsafe_code)]

use crate::util::mat::Mat;
use crate::util::rng::Pcg64;
use crate::workloads::env::Env;

/// Input/output width of the paper's dynamics MLP.
pub const IO_DIM: usize = 32;

/// One minibatch view.
#[derive(Debug, Clone)]
pub struct Batch {
    /// `[batch, 32]` normalized (state, action) rows.
    pub x: Mat,
    /// `[batch, 32]` normalized delta-state targets.
    pub y: Mat,
}

/// A collected, normalized dynamics dataset.
#[derive(Debug, Clone)]
pub struct Dataset {
    pub name: &'static str,
    pub state_dim: usize,
    pub action_dim: usize,
    /// Train inputs `[n, 32]` / targets `[n, 32]`.
    pub train_x: Mat,
    pub train_y: Mat,
    /// Held-out validation split.
    pub val_x: Mat,
    pub val_y: Mat,
    /// Per-column input means/stds used for normalization.
    pub x_mean: Vec<f32>,
    pub x_std: Vec<f32>,
    pub y_mean: Vec<f32>,
    pub y_std: Vec<f32>,
}

impl Dataset {
    /// Roll out `episodes` episodes of `horizon` random-policy steps.
    pub fn collect(env: &dyn Env, episodes: usize, horizon: usize, seed: u64) -> Dataset {
        let mut rng = Pcg64::with_stream(seed, 0xDA7A);
        let n = episodes * horizon;
        let (sd, ad) = (env.state_dim(), env.action_dim());
        assert!(sd + ad <= IO_DIM, "state+action must fit the 32-wide MLP input");
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for _ in 0..episodes {
            let mut s = env.reset(&mut rng);
            for _ in 0..horizon {
                let a: Vec<f32> = (0..ad)
                    .map(|_| rng.range_f32(-env.action_limit(), env.action_limit()))
                    .collect();
                let s2 = env.step(&s, &a);
                let mut row_x = vec![0.0f32; IO_DIM];
                row_x[..sd].copy_from_slice(&s);
                row_x[sd..sd + ad].copy_from_slice(&a);
                let mut row_y = vec![0.0f32; IO_DIM];
                for i in 0..sd {
                    row_y[i] = s2[i] - s[i];
                }
                xs.push(row_x);
                ys.push(row_y);
                s = s2;
            }
        }
        // shuffle before splitting (episodes are temporally correlated)
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_val = (n / 8).max(1);
        let flat = |rows: &[usize], src: &[Vec<f32>]| {
            let mut m = Mat::zeros(rows.len(), IO_DIM);
            for (r, &i) in rows.iter().enumerate() {
                m.data[r * IO_DIM..(r + 1) * IO_DIM].copy_from_slice(&src[i]);
            }
            m
        };
        let val_idx = &idx[..n_val];
        let train_idx = &idx[n_val..];
        let mut ds = Dataset {
            name: env.name(),
            state_dim: sd,
            action_dim: ad,
            train_x: flat(train_idx, &xs),
            train_y: flat(train_idx, &ys),
            val_x: flat(val_idx, &xs),
            val_y: flat(val_idx, &ys),
            x_mean: vec![0.0; IO_DIM],
            x_std: vec![1.0; IO_DIM],
            y_mean: vec![0.0; IO_DIM],
            y_std: vec![1.0; IO_DIM],
        };
        ds.normalize();
        ds
    }

    /// Column-wise standardization fit on train, applied to both splits.
    /// Padded (all-zero) columns keep std 1 so they stay exactly zero.
    fn normalize(&mut self) {
        let fit = |m: &Mat| -> (Vec<f32>, Vec<f32>) {
            let n = m.rows.max(1) as f32;
            let mut mean = vec![0.0f32; m.cols];
            let mut var = vec![0.0f32; m.cols];
            for r in 0..m.rows {
                for c in 0..m.cols {
                    mean[c] += m.at(r, c);
                }
            }
            for v in mean.iter_mut() {
                *v /= n;
            }
            for r in 0..m.rows {
                for c in 0..m.cols {
                    let d = m.at(r, c) - mean[c];
                    var[c] += d * d;
                }
            }
            let std: Vec<f32> =
                var.iter().map(|&v| (v / n).sqrt()).map(|s| if s < 1e-6 { 1.0 } else { s }).collect();
            (mean, std)
        };
        let (xm, xs) = fit(&self.train_x);
        let (ym, ys) = fit(&self.train_y);
        let apply = |m: &mut Mat, mean: &[f32], std: &[f32]| {
            for r in 0..m.rows {
                for c in 0..m.cols {
                    *m.at_mut(r, c) = (m.at(r, c) - mean[c]) / std[c];
                }
            }
        };
        apply(&mut self.train_x, &xm, &xs);
        apply(&mut self.val_x, &xm, &xs);
        apply(&mut self.train_y, &ym, &ys);
        apply(&mut self.val_y, &ym, &ys);
        self.x_mean = xm;
        self.x_std = xs;
        self.y_mean = ym;
        self.y_std = ys;
    }

    /// Number of training rows.
    pub fn len(&self) -> usize {
        self.train_x.rows
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Fetch minibatch `i` of size `bs` (wraps around; deterministic).
    pub fn batch(&self, i: usize, bs: usize) -> Batch {
        let n = self.len();
        let mut x = Mat::zeros(bs, IO_DIM);
        let mut y = Mat::zeros(bs, IO_DIM);
        for r in 0..bs {
            let src = (i * bs + r) % n;
            x.data[r * IO_DIM..(r + 1) * IO_DIM]
                .copy_from_slice(self.train_x.row(src));
            y.data[r * IO_DIM..(r + 1) * IO_DIM]
                .copy_from_slice(self.train_y.row(src));
        }
        Batch { x, y }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::by_name;

    #[test]
    fn collects_normalized_padded_data() {
        let env = by_name("cartpole").unwrap();
        let ds = Dataset::collect(env.as_ref(), 8, 50, 1);
        assert_eq!(ds.train_x.cols, 32);
        assert_eq!(ds.len() + ds.val_x.rows, 400);
        // padded columns are exactly zero
        for r in 0..ds.train_x.rows {
            for c in (ds.state_dim + ds.action_dim)..32 {
                assert_eq!(ds.train_x.at(r, c), 0.0);
            }
        }
        // live columns are standardized
        let col_std = |m: &Mat, c: usize| {
            let mean: f32 = (0..m.rows).map(|r| m.at(r, c)).sum::<f32>() / m.rows as f32;
            ((0..m.rows).map(|r| (m.at(r, c) - mean).powi(2)).sum::<f32>() / m.rows as f32).sqrt()
        };
        let s = col_std(&ds.train_x, 0);
        assert!((s - 1.0).abs() < 0.05, "std {s}");
    }

    #[test]
    fn batches_cycle_deterministically() {
        let env = by_name("reacher").unwrap();
        let ds = Dataset::collect(env.as_ref(), 4, 30, 2);
        let b0 = ds.batch(0, 32);
        let b0b = ds.batch(0, 32);
        assert_eq!(b0.x.data, b0b.x.data);
        let b1 = ds.batch(1, 32);
        assert_ne!(b0.x.data, b1.x.data);
    }

    #[test]
    fn same_seed_same_dataset() {
        let env = by_name("pusher").unwrap();
        let a = Dataset::collect(env.as_ref(), 2, 20, 7);
        let b = Dataset::collect(env.as_ref(), 2, 20, 7);
        assert_eq!(a.train_x.data, b.train_x.data);
    }

    #[test]
    fn all_envs_fit_io_layout() {
        for name in crate::workloads::ALL_WORKLOADS {
            let env = by_name(name).unwrap();
            assert!(env.state_dim() + env.action_dim() <= IO_DIM, "{name}");
        }
    }
}
