//! HalfCheetah surrogate (balancing/locomotion class).
//!
//! MuJoCo's halfcheetah is a 17-dim-state, 6-action articulated body.
//! Without MuJoCo we substitute a dynamically similar system: a chain of
//! six actuated, damped, nonlinearly coupled rotational joints riding on
//! a body with forward velocity driven by "ground reaction" terms from
//! the joint motion (a standard locomotion caricature). Dimensions match
//! the original (17 states, 6 actions), dynamics are smooth but strongly
//! coupled — the property that makes halfcheetah the heaviest of the four
//! fits. Substitution documented in DESIGN.md §2.

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;
use crate::workloads::env::{substep, Env};

#[derive(Debug, Clone)]
pub struct HalfCheetah {
    pub dt: f32,
    pub substeps: usize,
    pub damping: f32,
    pub coupling: f32,
    pub gear: f32,
}

impl Default for HalfCheetah {
    fn default() -> Self {
        Self { dt: 0.05, substeps: 5, damping: 1.5, coupling: 0.8, gear: 6.0 }
    }
}

// state layout: [z, pitch, vx, vz, vpitch, th1..th6, w1..w6] = 17 dims
const NJ: usize = 6;

impl Env for HalfCheetah {
    fn name(&self) -> &'static str {
        "halfcheetah"
    }

    fn state_dim(&self) -> usize {
        17
    }

    fn action_dim(&self) -> usize {
        NJ
    }

    fn action_limit(&self) -> f32 {
        1.0
    }

    fn reset(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut s = vec![0.0f32; 17];
        s[0] = rng.range_f32(-0.1, 0.1); // z
        s[1] = rng.range_f32(-0.2, 0.2); // pitch
        for i in 5..5 + NJ {
            s[i] = rng.range_f32(-0.5, 0.5); // joint angles
        }
        for i in 11..11 + NJ {
            s[i] = rng.range_f32(-0.3, 0.3); // joint velocities
        }
        s
    }

    fn step(&self, state: &[f32], action: &[f32]) -> Vec<f32> {
        let mut s = state.to_vec();
        let (damping, coupling, gear) = (self.damping, self.coupling, self.gear);
        substep(self.substeps, self.dt / self.substeps as f32, &mut s, |s, d| {
            let (z, pitch, vx, vz, vpitch) = (s[0], s[1], s[2], s[3], s[4]);
            let th = &s[5..5 + NJ];
            let w = &s[11..11 + NJ];
            // joint dynamics: actuated, damped, chain-coupled
            let mut wdot = [0.0f32; NJ];
            let mut ground_fx = 0.0;
            let mut ground_fz = 0.0;
            for j in 0..NJ {
                let left = if j > 0 { th[j - 1] - th[j] } else { -th[j] };
                let right = if j < NJ - 1 { th[j + 1] - th[j] } else { -th[j] };
                let a = action[j].clamp(-1.0, 1.0);
                wdot[j] = gear * a + coupling * (left + right) * 3.0 - damping * w[j]
                    - 2.0 * th[j]            // joint spring to rest pose
                    - 0.5 * pitch;           // body attitude couples in
                // "ground reaction": leg motion propels the body
                ground_fx += 0.35 * w[j] * th[j].cos();
                ground_fz += 0.15 * w[j] * th[j].sin();
            }
            d[0] = vz;
            d[1] = vpitch;
            d[2] = ground_fx - 0.8 * vx;
            d[3] = ground_fz - 4.0 * z - 1.2 * vz; // suspension
            d[4] = 0.3 * (th[0] - th[NJ - 1]) - 1.0 * vpitch - 2.0 * pitch;
            for j in 0..NJ {
                d[5 + j] = w[j];
                d[11 + j] = wdot[j];
            }
        });
        // soft clamps (joint stops, body limits)
        for (i, lim) in [(0usize, 1.0f32), (1, 1.5), (2, 8.0), (3, 5.0), (4, 8.0)] {
            s[i] = s[i].clamp(-lim, lim);
        }
        for i in 5..5 + NJ {
            s[i] = s[i].clamp(-2.5, 2.5);
        }
        for i in 11..11 + NJ {
            s[i] = s[i].clamp(-15.0, 15.0);
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dims_match_mujoco_halfcheetah() {
        let env = HalfCheetah::default();
        assert_eq!(env.state_dim(), 17);
        assert_eq!(env.action_dim(), 6);
    }

    #[test]
    fn actuation_drives_joints() {
        let env = HalfCheetah::default();
        let s = vec![0.0; 17];
        let mut a = vec![0.0; 6];
        a[2] = 1.0;
        let n = env.step(&s, &a);
        assert!(n[11 + 2] > 0.0, "actuated joint must accelerate: {n:?}");
    }

    #[test]
    fn leg_motion_propels_body() {
        let env = HalfCheetah::default();
        let mut s = vec![0.0; 17];
        // legs extended forward, swinging
        for j in 0..6 {
            s[5 + j] = 0.3;
            s[11 + j] = 2.0;
        }
        let n = env.step(&s, &[0.0; 6]);
        assert!(n[2] > 0.0, "forward velocity should build: {}", n[2]);
    }

    #[test]
    fn damping_settles_passive_system() {
        let env = HalfCheetah::default();
        let mut rng = Pcg64::new(5);
        let mut s = env.reset(&mut rng);
        for _ in 0..400 {
            s = env.step(&s, &[0.0; 6]);
        }
        let energy: f32 = s[11..17].iter().map(|w| w * w).sum();
        assert!(energy < 0.1, "joint velocities should decay: {energy}");
    }
}
