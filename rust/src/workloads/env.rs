//! The dynamics-environment trait.

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;

/// A continuous-control environment whose dynamics an MLP learns to
/// predict: given (state, action), produce the next state.
pub trait Env {
    /// Name used in CLIs and artifact files.
    fn name(&self) -> &'static str;
    /// State vector length.
    fn state_dim(&self) -> usize;
    /// Action vector length.
    fn action_dim(&self) -> usize;
    /// Sample an initial state.
    fn reset(&self, rng: &mut Pcg64) -> Vec<f32>;
    /// Advance one control step (typically several integrator substeps).
    fn step(&self, state: &[f32], action: &[f32]) -> Vec<f32>;
    /// Per-dimension action magnitude bound (exploration noise scale).
    fn action_limit(&self) -> f32 {
        1.0
    }
}

/// Semi-implicit Euler substepping helper shared by the physics sims.
pub fn substep(n: usize, dt: f32, state: &mut [f32], mut deriv: impl FnMut(&[f32], &mut [f32])) {
    let mut d = vec![0.0f32; state.len()];
    for _ in 0..n {
        deriv(state, &mut d);
        for (s, dd) in state.iter_mut().zip(&d) {
            *s += dt * dd;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::{by_name, ALL_WORKLOADS};

    #[test]
    fn all_workloads_constructible_and_deterministic() {
        for name in ALL_WORKLOADS {
            let env = by_name(name).unwrap();
            assert_eq!(env.name(), name);
            let mut rng = Pcg64::new(42);
            let s0 = env.reset(&mut rng);
            assert_eq!(s0.len(), env.state_dim());
            let a = vec![0.1; env.action_dim()];
            let s1 = env.step(&s0, &a);
            let s1b = env.step(&s0, &a);
            assert_eq!(s1, s1b, "{name} must be deterministic");
            assert_eq!(s1.len(), env.state_dim());
            assert!(s1.iter().all(|x| x.is_finite()), "{name} produced non-finite state");
        }
    }

    #[test]
    fn dynamics_respond_to_actions() {
        for name in ALL_WORKLOADS {
            let env = by_name(name).unwrap();
            let mut rng = Pcg64::new(7);
            let s0 = env.reset(&mut rng);
            let a0 = vec![0.0; env.action_dim()];
            let a1 = vec![env.action_limit(); env.action_dim()];
            let n0 = env.step(&s0, &a0);
            let n1 = env.step(&s0, &a1);
            assert_ne!(n0, n1, "{name} ignores its action input");
        }
    }

    #[test]
    fn trajectories_stay_bounded() {
        // run 500 random-policy steps; states must not blow up
        for name in ALL_WORKLOADS {
            let env = by_name(name).unwrap();
            let mut rng = Pcg64::new(9);
            let mut s = env.reset(&mut rng);
            for _ in 0..500 {
                let a: Vec<f32> = (0..env.action_dim())
                    .map(|_| rng.range_f32(-env.action_limit(), env.action_limit()))
                    .collect();
                s = env.step(&s, &a);
                let m = s.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                assert!(m < 1e4 && m.is_finite(), "{name} diverged: max |s| = {m}");
            }
        }
    }
}
