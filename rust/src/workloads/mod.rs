//! The four robotics dynamics-learning workloads (paper Fig. 2).
//!
//! The paper trains NNs to predict system dynamics for the continuous-
//! control tasks of Chua et al. (PETS, NeurIPS'18): **cartpole**,
//! **reacher**, **pusher**, **halfcheetah**. MuJoCo is not available in
//! this environment, so each task is a deterministic Rust physics
//! simulator with matching state/action dimensionality and qualitatively
//! similar dynamics (see DESIGN.md §2 — what matters for Fig. 2 is the
//! *relative trainability of a dynamics-model MLP under MX quantization*,
//! which any smooth nonlinear dynamical system of comparable conditioning
//! exercises through the identical code path).
//!
//! All workloads expose the [`env::Env`] trait and feed
//! [`dataset::Dataset`], which packs `(state, action) -> delta-state`
//! pairs into the 32-wide input/output layout of the paper's 4-layer MLP.

pub mod cartpole;
pub mod dataset;
pub mod env;
pub mod halfcheetah;
pub mod pusher;
pub mod reacher;

pub use dataset::{Batch, Dataset};
pub use env::Env;

/// Construct a workload by name.
pub fn by_name(name: &str) -> Option<Box<dyn Env>> {
    match name {
        "cartpole" => Some(Box::new(cartpole::Cartpole::default())),
        "reacher" => Some(Box::new(reacher::Reacher::default())),
        "pusher" => Some(Box::new(pusher::Pusher::default())),
        "halfcheetah" => Some(Box::new(halfcheetah::HalfCheetah::default())),
        _ => None,
    }
}

/// The four workload names in the paper's Fig. 2 order.
pub const ALL_WORKLOADS: [&str; 4] = ["cartpole", "halfcheetah", "pusher", "reacher"];
