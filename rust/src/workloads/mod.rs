//! The four robotics dynamics-learning workloads (paper Fig. 2).
//!
//! The paper trains NNs to predict system dynamics for the continuous-
//! control tasks of Chua et al. (PETS, NeurIPS'18): **cartpole**,
//! **reacher**, **pusher**, **halfcheetah**. MuJoCo is not available in
//! this environment, so each task is a deterministic Rust physics
//! simulator with matching state/action dimensionality and qualitatively
//! similar dynamics (see DESIGN.md §2 — what matters for Fig. 2 is the
//! *relative trainability of a dynamics-model MLP under MX quantization*,
//! which any smooth nonlinear dynamical system of comparable conditioning
//! exercises through the identical code path).
//!
//! All workloads expose the [`env::Env`] trait and feed
//! [`dataset::Dataset`], which packs `(state, action) -> delta-state`
//! pairs into the 32-wide input/output layout of the paper's 4-layer MLP.

pub mod cartpole;
pub mod dataset;
pub mod env;
pub mod halfcheetah;
pub mod pusher;
pub mod reacher;

pub use dataset::{Batch, Dataset};
pub use env::Env;

/// Construct a workload by name.
pub fn by_name(name: &str) -> Option<Box<dyn Env>> {
    match name {
        "cartpole" => Some(Box::new(cartpole::Cartpole::default())),
        "reacher" => Some(Box::new(reacher::Reacher::default())),
        "pusher" => Some(Box::new(pusher::Pusher::default())),
        "halfcheetah" => Some(Box::new(halfcheetah::HalfCheetah::default())),
        _ => None,
    }
}

/// The four workload names in the paper's Fig. 2 order.
pub const ALL_WORKLOADS: [&str; 4] = ["cartpole", "halfcheetah", "pusher", "reacher"];

/// The domain-shifted variant of a workload — same state/action layout,
/// perturbed physics (the paper's §I continual-learning premise: the
/// robot's environment changes mid-deployment). Used by the fleet layer
/// to swap a live session's dataset: a pusher picks up a heavier object
/// on rougher ground, a reacher's arm grows and stiffens, a cartpole's
/// pole doubles in mass, a halfcheetah's joints get stiffer with weaker
/// actuators.
pub fn shifted_by_name(name: &str) -> Option<Box<dyn Env>> {
    match name {
        "cartpole" => {
            let mut env = cartpole::Cartpole::default();
            env.pole_mass *= 2.0;
            env.pole_half_len *= 1.3;
            Some(Box::new(env))
        }
        "reacher" => {
            let mut env = reacher::Reacher::default();
            env.link_len *= 1.25;
            env.damping *= 2.0;
            Some(Box::new(env))
        }
        "pusher" => {
            let mut env = pusher::Pusher::default();
            env.obj_mass *= 2.5;
            env.friction *= 1.8;
            Some(Box::new(env))
        }
        "halfcheetah" => {
            let mut env = halfcheetah::HalfCheetah::default();
            env.damping *= 2.0;
            env.gear *= 0.7;
            Some(Box::new(env))
        }
        _ => None,
    }
}

#[cfg(test)]
mod shift_tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn shifted_variants_exist_and_differ_from_nominal() {
        for name in ALL_WORKLOADS {
            let nominal = by_name(name).unwrap();
            let shifted = shifted_by_name(name).unwrap();
            assert_eq!(nominal.state_dim(), shifted.state_dim(), "{name}");
            assert_eq!(nominal.action_dim(), shifted.action_dim(), "{name}");
            // same state + action must evolve differently under the shift
            let mut rng = Pcg64::new(0x5F1F7);
            let s = nominal.reset(&mut rng);
            let a = vec![0.3; nominal.action_dim()];
            assert_ne!(nominal.step(&s, &a), shifted.step(&s, &a), "{name}");
        }
        assert!(shifted_by_name("nope").is_none());
    }
}
