//! Planar 2-link reacher (robot-object interaction class).
//!
//! A two-joint arm driven by joint torques must reach a target point.
//! State: `[th1, th2, w1, w2, tx, ty]` (joint angles, joint velocities,
//! target position), action: two torques. Dynamics use a simplified
//! decoupled-inertia model with centripetal coupling — smooth, nonlinear,
//! and representative of the paper's reacher dynamics-learning task.

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;
use crate::workloads::env::{substep, Env};

#[derive(Debug, Clone)]
pub struct Reacher {
    pub link_len: f32,
    pub inertia: f32,
    pub damping: f32,
    pub dt: f32,
    pub substeps: usize,
}

impl Default for Reacher {
    fn default() -> Self {
        Self { link_len: 0.5, inertia: 0.05, damping: 0.3, dt: 0.02, substeps: 4 }
    }
}

impl Reacher {
    /// Forward kinematics of the fingertip.
    pub fn fingertip(&self, th1: f32, th2: f32) -> (f32, f32) {
        let x = self.link_len * th1.cos() + self.link_len * (th1 + th2).cos();
        let y = self.link_len * th1.sin() + self.link_len * (th1 + th2).sin();
        (x, y)
    }
}

impl Env for Reacher {
    fn name(&self) -> &'static str {
        "reacher"
    }

    fn state_dim(&self) -> usize {
        6
    }

    fn action_dim(&self) -> usize {
        2
    }

    fn action_limit(&self) -> f32 {
        1.0
    }

    fn reset(&self, rng: &mut Pcg64) -> Vec<f32> {
        let r = rng.range_f32(0.3, 0.9);
        let phi = rng.range_f32(-std::f32::consts::PI, std::f32::consts::PI);
        vec![
            rng.range_f32(-std::f32::consts::PI, std::f32::consts::PI),
            rng.range_f32(-2.0, 2.0),
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-1.0, 1.0),
            r * phi.cos(),
            r * phi.sin(),
        ]
    }

    fn step(&self, state: &[f32], action: &[f32]) -> Vec<f32> {
        let mut s = state.to_vec();
        let t1 = action[0].clamp(-1.0, 1.0);
        let t2 = action[1].clamp(-1.0, 1.0);
        let (inertia, damping) = (self.inertia, self.damping);
        substep(self.substeps, self.dt / self.substeps as f32, &mut s[..4], |s, d| {
            let (th2, w1, w2) = (s[1], s[2], s[3]);
            // inertia of joint 1 varies with elbow angle; centripetal
            // coupling between the links provides the nonlinearity
            let i1 = inertia * (1.5 + th2.cos());
            let i2 = inertia;
            let coriolis = 0.02 * w1 * w2 * th2.sin();
            d[0] = w1;
            d[1] = w2;
            d[2] = (t1 - damping * w1 * inertia / 0.05 * 0.05 - coriolis) / i1;
            d[3] = (t2 - damping * w2 * inertia / 0.05 * 0.05 + coriolis) / i2;
        });
        // wrap joint angles
        for i in 0..2 {
            if s[i] > std::f32::consts::PI {
                s[i] -= std::f32::consts::TAU;
            } else if s[i] < -std::f32::consts::PI {
                s[i] += std::f32::consts::TAU;
            }
        }
        // clamp runaway velocities (joint stops)
        s[2] = s[2].clamp(-20.0, 20.0);
        s[3] = s[3].clamp(-20.0, 20.0);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn torque_accelerates_joint() {
        let env = Reacher::default();
        let s = vec![0.0, 0.0, 0.0, 0.0, 0.5, 0.0];
        let n = env.step(&s, &[1.0, 0.0]);
        assert!(n[2] > 0.0);
        let n2 = env.step(&s, &[0.0, 1.0]);
        assert!(n2[3] > 0.0);
    }

    #[test]
    fn target_is_static() {
        let env = Reacher::default();
        let mut rng = Pcg64::new(3);
        let s = env.reset(&mut rng);
        let n = env.step(&s, &[0.5, -0.5]);
        assert_eq!(n[4], s[4]);
        assert_eq!(n[5], s[5]);
    }

    #[test]
    fn fingertip_kinematics() {
        let env = Reacher::default();
        let (x, y) = env.fingertip(0.0, 0.0);
        assert!((x - 1.0).abs() < 1e-6 && y.abs() < 1e-6);
        let (x, y) = env.fingertip(std::f32::consts::FRAC_PI_2, 0.0);
        assert!(x.abs() < 1e-6 && (y - 1.0).abs() < 1e-6);
    }
}
