//! Cartpole swing-up dynamics (balancing task class in the paper).
//!
//! Classic cart-pole ODE (Barto/Sutton form with a continuous force
//! action), integrated with semi-implicit Euler substeps. State:
//! `[x, x_dot, theta, theta_dot]`, action: horizontal force.

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;
use crate::workloads::env::{substep, Env};

#[derive(Debug, Clone)]
pub struct Cartpole {
    pub cart_mass: f32,
    pub pole_mass: f32,
    pub pole_half_len: f32,
    pub gravity: f32,
    pub dt: f32,
    pub substeps: usize,
}

impl Default for Cartpole {
    fn default() -> Self {
        Self {
            cart_mass: 1.0,
            pole_mass: 0.1,
            pole_half_len: 0.5,
            gravity: 9.81,
            dt: 0.02,
            substeps: 4,
        }
    }
}

impl Env for Cartpole {
    fn name(&self) -> &'static str {
        "cartpole"
    }

    fn state_dim(&self) -> usize {
        4
    }

    fn action_dim(&self) -> usize {
        1
    }

    fn action_limit(&self) -> f32 {
        10.0
    }

    fn reset(&self, rng: &mut Pcg64) -> Vec<f32> {
        // near-hanging start with noise (swing-up regime, wide dynamics)
        vec![
            rng.range_f32(-1.0, 1.0),
            rng.range_f32(-0.5, 0.5),
            std::f32::consts::PI + rng.range_f32(-0.8, 0.8),
            rng.range_f32(-1.0, 1.0),
        ]
    }

    fn step(&self, state: &[f32], action: &[f32]) -> Vec<f32> {
        let mut s = state.to_vec();
        let f = action[0].clamp(-self.action_limit(), self.action_limit());
        let (mc, mp, l, g) = (self.cart_mass, self.pole_mass, self.pole_half_len, self.gravity);
        substep(self.substeps, self.dt / self.substeps as f32, &mut s, |s, d| {
            let (x_dot, th, th_dot) = (s[1], s[2], s[3]);
            let (sin, cos) = th.sin_cos();
            let total = mc + mp;
            let tmp = (f + mp * l * th_dot * th_dot * sin) / total;
            let th_acc = (g * sin - cos * tmp) / (l * (4.0 / 3.0 - mp * cos * cos / total));
            let x_acc = tmp - mp * l * th_acc * cos / total;
            // mild friction keeps long random rollouts bounded
            d[0] = x_dot;
            d[1] = x_acc - 0.05 * x_dot;
            d[2] = th_dot;
            d[3] = th_acc - 0.05 * th_dot;
        });
        // wrap the cart within a track (reflecting) and the angle into
        // [-pi, pi] to keep the learned mapping compact
        s[0] = s[0].clamp(-3.0, 3.0);
        if s[2] > std::f32::consts::PI {
            s[2] -= std::f32::consts::TAU;
        } else if s[2] < -std::f32::consts::PI {
            s[2] += std::f32::consts::TAU;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gravity_pulls_pole_down() {
        let env = Cartpole::default();
        // slightly off vertical-up (theta=0 is up in this convention's
        // sin/cos usage): theta small positive should accelerate outward
        let s = vec![0.0, 0.0, 0.3, 0.0];
        let n = env.step(&s, &[0.0]);
        assert!(n[3] > 0.0, "theta_dot should grow: {n:?}");
    }

    #[test]
    fn force_moves_cart() {
        let env = Cartpole::default();
        let s = vec![0.0, 0.0, std::f32::consts::PI, 0.0];
        let n = env.step(&s, &[10.0]);
        assert!(n[1] > 0.0, "positive force -> positive cart velocity");
    }

    #[test]
    fn angle_stays_wrapped() {
        let env = Cartpole::default();
        let mut rng = Pcg64::new(1);
        let mut s = env.reset(&mut rng);
        for _ in 0..200 {
            s = env.step(&s, &[rng.range_f32(-10.0, 10.0)]);
            assert!(s[2].abs() <= std::f32::consts::PI + 1e-3);
        }
    }
}
