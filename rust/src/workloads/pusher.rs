//! Planar pusher: 2-link arm pushing a sliding object to a goal.
//!
//! The paper's flagship workload (Tables III/IV and Fig. 8 all use the
//! pusher MLP). State: `[th1, th2, w1, w2, ox, oy, ovx, ovy, gx, gy]`
//! (arm joints + velocities, object pose + velocity, goal), action: two
//! joint torques. The fingertip pushes the object on contact; the object
//! slides with Coulomb-like friction. Contact switching makes this the
//! hardest of the four dynamics to fit — mirroring the paper's finding
//! that pusher benefits from FP precision (MXFP8 E4M3 wins on it).

#![forbid(unsafe_code)]

use crate::util::rng::Pcg64;
use crate::workloads::env::{substep, Env};
use crate::workloads::reacher::Reacher;

#[derive(Debug, Clone)]
pub struct Pusher {
    pub arm: Reacher,
    pub obj_mass: f32,
    pub friction: f32,
    pub contact_radius: f32,
    pub contact_stiffness: f32,
}

impl Default for Pusher {
    fn default() -> Self {
        Self {
            arm: Reacher::default(),
            obj_mass: 0.3,
            friction: 1.2,
            contact_radius: 0.12,
            contact_stiffness: 30.0,
        }
    }
}

impl Env for Pusher {
    fn name(&self) -> &'static str {
        "pusher"
    }

    fn state_dim(&self) -> usize {
        10
    }

    fn action_dim(&self) -> usize {
        2
    }

    fn action_limit(&self) -> f32 {
        1.0
    }

    fn reset(&self, rng: &mut Pcg64) -> Vec<f32> {
        let mut s = vec![
            rng.range_f32(-1.5, 1.5),
            rng.range_f32(-1.5, 1.5),
            rng.range_f32(-0.5, 0.5),
            rng.range_f32(-0.5, 0.5),
            rng.range_f32(-0.6, 0.6),
            rng.range_f32(-0.6, 0.6),
            0.0,
            0.0,
            rng.range_f32(-0.8, 0.8),
            rng.range_f32(-0.8, 0.8),
        ];
        // keep object within the arm's annulus so contact happens
        let r = (s[4] * s[4] + s[5] * s[5]).sqrt();
        if r < 0.2 {
            s[4] += 0.3;
        }
        s
    }

    fn step(&self, state: &[f32], action: &[f32]) -> Vec<f32> {
        // 1. arm dynamics through the reacher model
        let arm_state = [state[0], state[1], state[2], state[3], 0.0, 0.0];
        let (tip_x0, tip_y0) = self.arm.fingertip(state[0], state[1]);
        let arm_next = self.arm.step(&arm_state, action);
        let (tip_x1, tip_y1) = self.arm.fingertip(arm_next[0], arm_next[1]);
        let tip_vx = (tip_x1 - tip_x0) / self.arm.dt;
        let tip_vy = (tip_y1 - tip_y0) / self.arm.dt;

        // 2. object dynamics: penalty contact with the fingertip + friction
        let mut obj = [state[4], state[5], state[6], state[7]];
        let (stiff, radius, mass, fric) = (
            self.contact_stiffness,
            self.contact_radius,
            self.obj_mass,
            self.friction,
        );
        substep(self.arm.substeps, self.arm.dt / self.arm.substeps as f32, &mut obj, |o, d| {
            let dx = o[0] - tip_x1;
            let dy = o[1] - tip_y1;
            let dist = (dx * dx + dy * dy).sqrt().max(1e-6);
            let (mut fx, mut fy) = (0.0, 0.0);
            if dist < radius {
                // penalty spring pushes the object away from the tip and
                // drags it with the tip's velocity
                let pen = radius - dist;
                fx = stiff * pen * dx / dist + 0.5 * tip_vx;
                fy = stiff * pen * dy / dist + 0.5 * tip_vy;
            }
            // Coulomb-like friction (smoothed)
            let v = (o[2] * o[2] + o[3] * o[3]).sqrt().max(1e-6);
            fx -= fric * o[2] / v * v.min(1.0);
            fy -= fric * o[3] / v * v.min(1.0);
            d[0] = o[2];
            d[1] = o[3];
            d[2] = fx / mass;
            d[3] = fy / mass;
        });

        vec![
            arm_next[0], arm_next[1], arm_next[2], arm_next[3],
            obj[0].clamp(-2.0, 2.0), obj[1].clamp(-2.0, 2.0),
            obj[2].clamp(-5.0, 5.0), obj[3].clamp(-5.0, 5.0),
            state[8], state[9],
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn object_rests_without_contact() {
        let env = Pusher::default();
        // arm far from object, object at rest
        let s = vec![0.0, 0.0, 0.0, 0.0, -0.9, -0.9, 0.0, 0.0, 0.5, 0.5];
        let n = env.step(&s, &[0.0, 0.0]);
        assert!((n[4] - s[4]).abs() < 1e-4 && (n[5] - s[5]).abs() < 1e-4, "{n:?}");
    }

    #[test]
    fn contact_pushes_object() {
        let env = Pusher::default();
        // fingertip at (1, 0) when th1=th2=0; object just beside it
        let s = vec![0.0, 0.0, 0.0, 0.0, 1.05, 0.0, 0.0, 0.0, 0.5, 0.5];
        let n = env.step(&s, &[0.0, 0.0]);
        assert!(n[4] > 1.05, "object should be pushed away: {n:?}");
    }

    #[test]
    fn friction_damps_object() {
        let env = Pusher::default();
        let s = vec![0.0, 0.0, 0.0, 0.0, -0.9, -0.9, 2.0, 0.0, 0.5, 0.5];
        let n = env.step(&s, &[0.0, 0.0]);
        assert!(n[6] < 2.0 && n[6] > 0.0, "{n:?}");
    }

    #[test]
    fn goal_is_static() {
        let env = Pusher::default();
        let mut rng = Pcg64::new(4);
        let s = env.reset(&mut rng);
        let n = env.step(&s, &[0.3, -0.3]);
        assert_eq!(&n[8..10], &s[8..10]);
    }
}
