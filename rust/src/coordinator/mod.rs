//! Coordinator: CLI, experiment configs, and the per-table / per-figure
//! reproduction harnesses.
//!
//! `mxscale repro <id>` regenerates every quantitative artefact of the
//! paper's evaluation section (see DESIGN.md §5):
//!
//! | id     | paper artefact                                      |
//! |--------|-----------------------------------------------------|
//! | table2 | MAC variant area / pJ-per-OP comparison             |
//! | table3 | memory footprint: FP32 / Dacapo / ours, 3 batches   |
//! | table4 | core comparison: area, BW, mem, E/op, train latency |
//! | fig2   | validation-loss curves, 6 MX formats x 4 workloads  |
//! | fig7   | PE-array area & energy breakdown per component      |
//! | fig8   | pusher loss under time / energy budgets vs Dacapo   |
//! | throughput | measured-on-model training cost via `--backend hw` |

pub mod cli;
pub mod experiments;
pub mod report;

pub use cli::run_cli;
