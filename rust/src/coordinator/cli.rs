//! Hand-rolled CLI (clap is unavailable in the offline environment).
//!
//! ```text
//! mxscale repro <table2|table3|table4|fig2|fig7|fig8|throughput|ablation|all>... [--steps N]
//! mxscale train --workload pusher --scheme e4m3 --backend hw [--steps N] [--hidden N]
//! mxscale fleet --sessions 8 --steps 280 --shift-at 140
//! mxscale serve --load --sessions 10000 --steps 12
//! mxscale quantize --format e4m3 [--rows N --cols N]
//! mxscale info
//! ```
//!
//! Flag values with a domain (`--backend`, `--scheme`, `--policy`,
//! `--kernel`, `--store`) parse through one [`FromArg`] trait, so
//! every subcommand rejects a bad value with the same structured
//! `TrainError::BadConfig` message: flag name, offending value,
//! accepted values.

#![forbid(unsafe_code)]

use crate::backend::BackendKind;
use crate::chaos::{FaultClass, FaultOutcome, FaultPlan};
use crate::coordinator::experiments;
use crate::coordinator::report::{save_csv, save_hw_report, save_json, Table};
use crate::fleet::{run_fleet, FleetSpec, StoreSpec};
use crate::mx::element::ElementFormat;
use crate::mx::simd::KernelPath;
use crate::mx::tensor::{Layout, MxTensor};
use crate::serve::load::{bench_json, run_load, LoadSpec};
use crate::store::StoreLayout;
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainConfig, TrainError, TrainSession};
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;
use crate::workloads::{by_name, Dataset};

/// Parsed flag set: positionals + `--key value` options.
#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: std::collections::HashMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let tok = &argv[i];
            if let Some(key) = tok.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                a.flags.insert(key.to_string(), val);
            } else {
                a.positional.push(tok.clone());
            }
            i += 1;
        }
        a
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

/// A CLI flag value with a closed domain: the flag it rides on, the
/// accepted values (for the error message), and the parse itself.
/// `train`, `fleet`, and `serve` all go through [`flag_opt`] /
/// [`flag_list`], so a bad value fails identically everywhere.
pub trait FromArg: Sized {
    /// Flag name, without the leading dashes.
    const FLAG: &'static str;
    /// Human-readable accepted values, quoted in errors.
    const ACCEPTED: &'static str;
    fn from_arg(s: &str) -> Result<Self, String>;
}

impl FromArg for BackendKind {
    const FLAG: &'static str = "backend";
    const ACCEPTED: &'static str = "fast|hw|packed";
    fn from_arg(s: &str) -> Result<Self, String> {
        BackendKind::parse(s).ok_or_else(|| "unrecognized backend".to_string())
    }
}

impl FromArg for QuantScheme {
    const FLAG: &'static str = "scheme";
    const ACCEPTED: &'static str =
        "fp32|int8|e5m2|e4m3|e3m2|e2m3|e2m1|mx-<fmt>|mxvec-<fmt>|mx9|mx6|mx4";
    fn from_arg(s: &str) -> Result<Self, String> {
        QuantScheme::parse(s).ok_or_else(|| "unrecognized scheme".to_string())
    }
}

impl FromArg for PrecisionPolicy {
    const FLAG: &'static str = "policy";
    const ACCEPTED: &'static str =
        "<step:scheme>[,<step:scheme>...] or adaptive:<s>[>s...] (DESIGN.md \u{a7}8)";
    fn from_arg(s: &str) -> Result<Self, String> {
        PrecisionPolicy::parse(s)
    }
}

impl FromArg for KernelPath {
    const FLAG: &'static str = "kernel";
    const ACCEPTED: &'static str = "swar|sse41|avx2|neon";
    fn from_arg(s: &str) -> Result<Self, String> {
        KernelPath::parse(s)
    }
}

impl FromArg for StoreLayout {
    const FLAG: &'static str = "store";
    const ACCEPTED: &'static str = "plain|sharded|sharded:N (N in 1..=4096)";
    fn from_arg(s: &str) -> Result<Self, String> {
        StoreLayout::parse(s).ok_or_else(|| "unrecognized layout".to_string())
    }
}

impl FromArg for FaultPlan {
    const FLAG: &'static str = "chaos";
    const ACCEPTED: &'static str = "<mem|storage|exec|all>[,<class>...][@seed] (DESIGN.md \u{a7}13)";
    fn from_arg(s: &str) -> Result<Self, String> {
        FaultPlan::parse(s).ok_or_else(|| "unrecognized fault plan".to_string())
    }
}

/// Parse the optional `--<T::FLAG>` flag into its value type, shaping
/// failures into the uniform message: flag + offending value +
/// accepted values.
fn flag_opt<T: FromArg>(args: &Args) -> Result<Option<T>, TrainError> {
    match args.get(T::FLAG) {
        None => Ok(None),
        Some(v) => T::from_arg(v).map(Some).map_err(|detail| TrainError::BadConfig {
            reason: format!("--{} {v}: {detail}; accepted: {}", T::FLAG, T::ACCEPTED),
        }),
    }
}

/// Comma-separated variant (e.g. `--scheme int8,e4m3`); any bad
/// element fails the whole flag with the element named.
fn flag_list<T: FromArg>(args: &Args) -> Result<Option<Vec<T>>, TrainError> {
    match args.get(T::FLAG) {
        None => Ok(None),
        Some(list) => {
            let mut out = Vec::new();
            for v in list.split(',') {
                let v = v.trim();
                out.push(T::from_arg(v).map_err(|detail| TrainError::BadConfig {
                    reason: format!("--{} {v}: {detail}; accepted: {}", T::FLAG, T::ACCEPTED),
                })?);
            }
            Ok(Some(out))
        }
    }
}

const USAGE: &str = "\
mxscale - precision-scalable MX processing for robotics learning (ISLPED'25 reproduction)

USAGE:
  mxscale repro <table2|table3|table4|fig2|fig7|fig8|throughput|precision-schedule|ablation|all>...
                [--steps N] [--eval-every N] [--hw-steps N] [--static-steps N]
                # ids may be listed together; --static-steps sizes the
                # precision-schedule race's static-INT8 budget
  mxscale train --workload <cartpole|reacher|pusher|halfcheetah>
                --scheme <fp32|int8|e5m2|e4m3|e3m2|e2m3|e2m1|mxvec-<fmt>|mx9|mx6|mx4>
                [--backend fast|hw|packed] [--steps N] [--lr F] [--batch N] [--hidden N]
                [--policy <spec>]                         # runtime precision scheduling
                [--kernel swar|sse41|avx2|neon]           # force a packed kernel path
  mxscale fleet [--sessions N] [--steps N] [--quantum N] [--shift-at N]
                [--scheme <s>[,<s>...]] [--backend fast|hw|packed] [--hidden N]
                [--energy-budget UJ] [--policy <spec>] [--seed N]   # continual learning
                [--store plain|sharded|sharded:N] [--store-dir DIR] # checkpoint store
                [--chaos <mem|storage|exec|all>[,...][@seed]]       # fault-injection drill
  mxscale serve --load [--sessions N] [--steps N] [--quantum N] [--capacity N]
                [--workers N] [--max-parked N] [--burst-every N] [--twin-every N]
                [--lease N] [--store plain|sharded|sharded:N] [--store-dir DIR]
                [--scheme <s>[,<s>...]] [--backend fast|hw|packed] [--hidden N]
                [--seed N] [--chaos <classes>[@seed]]   # open-stream serving (BENCH_serve.json)
  mxscale quantize --format <fmt> [--rows N] [--cols N]   # quantization demo + stats
  mxscale info                                            # architecture summary

  --backend hw runs every training GeMM through the bit-exact GemmCore
  simulation and saves a per-session cycle/energy/memory-traffic report
  (results/*_hw_report.json). --backend packed runs the GeMMs on the
  sub-word-parallel kernels over bit-packed element codes — same losses
  bit for bit, fastest software path. Square MX schemes only. The
  kernel registry picks the widest vector path the CPU supports (avx2 >
  neon > sse41 > swar, bit-identical by construction); --kernel or
  MXSCALE_KERNEL forces one, erroring if the CPU can't run it.

  --policy schedules the MX format *while training* (DESIGN.md §8):
  `0:mx-e2m1,200:mx-int8` switches formats at step indices;
  `adaptive:mx-int8>mx-e2m3>mx-e2m1` runs a Dacapo-style loss watchdog
  that demotes precision on plateau and promotes it on divergence.
  Transitions requantize from the FP32 masters — a switch is
  bit-identical to starting fresh at the new format with the same
  master/Adam state. `repro precision-schedule` races a scheduled run
  against static baselines (results/precision_schedule.json).

  fleet multiplexes N concurrent training sessions (round-robin step
  quanta over the worker pool) with per-session step/energy budgets and
  a mid-run domain-shift event per session: each robot checkpoints
  (MX-native, square groups single-copy) and adapts from the checkpoint
  on its perturbed environment. Writes results/fleet_report.json with
  effective throughput, checkpoint bytes (square vs vector grouping),
  and the adaptation-vs-retrain loss curves.

  --store persists every robot's checkpoints through the chunked store
  (DESIGN.md §11): `plain` writes one object per chunk, `sharded[:N]`
  packs the whole fleet into N shard files (default 8) with trailing
  indexes, so resuming one robot reads only the index plus its own
  chunks. --store-dir picks the root (default results/fleet_store).
  Legacy monolithic .mxckpt files in that directory stay readable.

  serve is the open-stream front-end over the fleet (DESIGN.md §12):
  sessions arrive continuously with priorities and budgets, admission
  control sheds load before step latency collapses (structured
  Overloaded errors), and a work-stealing executor runs admitted
  sessions in quanta. --load drives the deterministic synthetic
  generator (10k sessions by default); --capacity bounds live sessions,
  --max-parked bounds the parking lot, --lease N evicts a session
  through the checkpoint store every N quanta (requires --store) and
  re-admits it bit-identically. Writes results/BENCH_serve.json
  (p50/p99 step latency, steps/s, shed counts, twin-check results) and
  exits nonzero if any session is lost, duplicated, or diverges from
  its standalone twin.

  --chaos injects deterministic faults (DESIGN.md §13). `fleet --chaos
  <plan>` runs the self-contained drill: seeded bit flips in packed MX
  blocks, torn shard appends, chunk bit rot, a crashed writer's stale
  lock — each printed as a structured detection naming its exact site
  or a recovery *proven* bit-identical to the fault-free twin. `serve
  --chaos <plan>` attacks the live serving run: planned sessions are
  checkpointed at admission, crashed or panicked mid-quantum, then
  re-admitted from the checkpoint (requires --store for exec faults);
  the twin check must still come back 100% bitwise. Same plan, same
  faults — chaos runs replay exactly.
";

/// Entry point used by `main.rs`. Returns a process exit code.
pub fn run_cli(argv: &[String]) -> i32 {
    let args = Args::parse(argv);
    match args.positional.first().map(|s| s.as_str()) {
        Some("repro") => cmd_repro(&args),
        Some("train") => cmd_train(&args),
        Some("fleet") => cmd_fleet(&args),
        Some("serve") => cmd_serve(&args),
        Some("quantize") => cmd_quantize(&args),
        Some("info") => {
            print!("{}", info_text());
            0
        }
        _ => {
            print!("{USAGE}");
            1
        }
    }
}

fn emit(t: &Table, name: &str) {
    print!("{}", t.render());
    match save_csv(t, name) {
        Ok(p) => println!("[saved {}]\n", p.display()),
        Err(e) => println!("[csv save failed: {e}]\n"),
    }
}

/// Parse the shared `--hidden` flag (None = paper MLP width).
fn parse_hidden(args: &Args) -> Result<Option<usize>, String> {
    match args.get("hidden") {
        None => Ok(None),
        Some(h) => match h.parse::<usize>() {
            Ok(h) if h > 0 => Ok(Some(h)),
            _ => Err(format!("invalid --hidden: {h} (positive integer expected)")),
        },
    }
}

fn cmd_repro(args: &Args) -> i32 {
    let steps = args.usize_or("steps", 300);
    let eval_every = args.usize_or("eval-every", 25);
    let run_inner = |id: &str| -> Result<(), String> {
        let err = |e: crate::trainer::session::TrainError| e.to_string();
        match id {
            "table2" => emit(&experiments::table2(), "table2"),
            "table3" => emit(&experiments::table3(), "table3"),
            "table4" => emit(&experiments::table4(), "table4"),
            "fig7" => {
                let (e, a) = experiments::fig7();
                emit(&e, "fig7_energy");
                emit(&a, "fig7_area");
            }
            "fig2" => emit(&experiments::fig2(steps, eval_every).map_err(err)?, "fig2_final"),
            "throughput" => emit(
                &experiments::throughput(args.usize_or("hw-steps", 2)).map_err(err)?,
                "throughput_measured",
            ),
            "precision-schedule" => emit(
                &experiments::precision_schedule(args.usize_or("static-steps", 160), None)
                    .map_err(err)?,
                "precision_schedule",
            ),
            "ablation" => emit(&experiments::ablation().map_err(err)?, "ablation_blocksize"),
            "fig8" => emit(
                &experiments::fig8(
                    args.f64_or("time-budget", 1000.0),
                    args.f64_or("energy-budget", 120.0),
                )
                .map_err(err)?,
                "fig8_final",
            ),
            other => return Err(format!("unknown experiment: {other}")),
        }
        Ok(())
    };
    // A failing id must not abort the ids that follow: CI's repro-smoke
    // job lists several experiments in one invocation, and an early
    // panic used to hide whether the later CSVs still regenerate. Each
    // id runs behind a panic boundary; failures are collected and all
    // reported at exit.
    let run = |id: &str, failures: &mut Vec<String>| {
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| run_inner(id)));
        match outcome {
            Ok(Ok(())) => {}
            Ok(Err(msg)) => {
                eprintln!("experiment {id} failed: {msg}");
                failures.push(format!("{id} ({msg})"));
            }
            Err(payload) => {
                let msg = payload
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| payload.downcast_ref::<&str>().copied())
                    .unwrap_or("panic");
                eprintln!("experiment {id} panicked: {msg}");
                failures.push(format!("{id} (panicked: {msg})"));
            }
        }
    };
    // any number of experiment ids may be listed in one invocation
    // (e.g. `repro table2 table3`); no ids means `all`
    let ids: Vec<&str> = if args.positional.len() > 1 {
        args.positional[1..].iter().map(|s| s.as_str()).collect()
    } else {
        vec!["all"]
    };
    let mut failures: Vec<String> = Vec::new();
    for which in ids {
        if which == "all" {
            let every = [
                "table2",
                "table3",
                "table4",
                "fig7",
                "fig2",
                "fig8",
                "throughput",
                "precision-schedule",
                "ablation",
            ];
            for id in every {
                run(id, &mut failures);
            }
        } else {
            run(which, &mut failures);
        }
    }
    if failures.is_empty() {
        0
    } else {
        eprintln!("repro: {} experiment(s) failed: {}", failures.len(), failures.join(", "));
        1
    }
}

/// `mxscale fleet --chaos <plan>`: run the deterministic
/// fault-injection drill — one line per injected fault, each ending in
/// a structured detection or a proven bit-identical recovery. CI greps
/// the lines; any third ending exits nonzero.
fn cmd_chaos_drill(plan: &FaultPlan) -> i32 {
    println!("chaos drill: plan {} (deterministic; same plan, same faults)...", plan.name());
    match crate::chaos::run_chaos_drill(plan) {
        Ok(records) => {
            for r in &records {
                println!("{}", r.describe());
            }
            let recovered = records
                .iter()
                .filter(|r| matches!(r.outcome, FaultOutcome::Recovered { .. }))
                .count();
            println!(
                "chaos drill: {} faults injected, {} detected structured, \
                 {} recovered bit-identically",
                records.len(),
                records.len() - recovered,
                recovered
            );
            0
        }
        Err(e) => {
            eprintln!("chaos drill failed: {e}");
            1
        }
    }
}

fn cmd_fleet(args: &Args) -> i32 {
    // --chaos short-circuits into the fault-injection drill
    match flag_opt::<FaultPlan>(args) {
        Ok(Some(plan)) => return cmd_chaos_drill(&plan),
        Ok(None) => {}
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    }
    let d = FleetSpec::default();
    let mut spec = FleetSpec {
        sessions: args.usize_or("sessions", d.sessions),
        steps: args.usize_or("steps", d.steps),
        quantum: args.usize_or("quantum", d.quantum),
        shift_at: args.usize_or("shift-at", d.shift_at),
        eval_every: args.usize_or("eval-every", d.eval_every),
        batch: args.usize_or("batch", d.batch),
        lr: args.f64_or("lr", d.lr as f64) as f32,
        seed: args.usize_or("seed", d.seed as usize) as u64,
        energy_budget_uj: args.f64_or("energy-budget", f64::INFINITY),
        ..d
    };
    match parse_hidden(args) {
        Ok(h) => spec.hidden = h,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    }
    let flags = (|| -> Result<(), TrainError> {
        if let Some(schemes) = flag_list::<QuantScheme>(args)? {
            spec.schemes = schemes;
        }
        if let Some(b) = flag_opt::<BackendKind>(args)? {
            spec.backend = b;
        }
        if let Some(p) = flag_opt::<PrecisionPolicy>(args)? {
            spec.policy = Some(p);
        }
        if let Some(layout) = flag_opt::<StoreLayout>(args)? {
            let dir = args.get("store-dir").unwrap_or("results/fleet_store");
            spec.store = Some(StoreSpec { dir: dir.into(), layout });
        }
        Ok(())
    })();
    if let Err(e) = flags {
        eprintln!("{e}");
        return 1;
    }
    println!(
        "fleet: {} sessions x {} steps (quantum {}, shift at {}) on the {} backend...",
        spec.sessions,
        spec.steps,
        spec.quantum,
        spec.shift_at,
        spec.backend.name()
    );
    let run = match run_fleet(&spec) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut t = Table::new(
        "fleet outcome",
        &["robot", "workload", "scheme", "steps", "energy[uJ]", "shifts", "ckpt[B]", "final val"],
    );
    for s in &run.sessions {
        t.row(vec![
            s.id.clone(),
            s.workload.clone(),
            s.scheme.clone(),
            s.steps.to_string(),
            format!("{:.1}", s.energy_uj),
            s.shifts.to_string(),
            s.payload_bytes.to_string(),
            format!("{:.4}", s.final_val),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\neffective throughput: {} steps over {:.2}s = {:.0} steps/s across the fleet",
        run.stats.total_steps,
        run.stats.wall_s,
        run.stats.steps_per_sec()
    );
    if let Some(a) = &run.adapt {
        let reached = a
            .adapt_steps_to_target
            .map(|s| s.to_string())
            .unwrap_or_else(|| "never".to_string());
        println!(
            "adaptation ({} / {}): checkpoint-resume reached the {}-step scratch loss \
             ({:.4}) after {} steps -> {}",
            a.workload,
            a.scheme,
            a.steps,
            a.target_loss,
            reached,
            if a.adapt_beats_scratch { "adaptation wins" } else { "no win" },
        );
    }
    if let Some(ss) = &spec.store {
        println!(
            "store: {} checkpoints persisted under {} ({})",
            run.sessions.len(),
            ss.dir.display(),
            ss.layout.name()
        );
    }
    match save_json(&run.report, "fleet_report") {
        Ok(p) => println!("[saved {}]", p.display()),
        Err(e) => {
            eprintln!("[json save failed: {e}]");
            return 1;
        }
    }
    // A parked session is a failed session: the report above still
    // covers it (steps so far, the error string), but the process must
    // not exit as if the fleet ran clean.
    if run.stats.parked > 0 {
        for s in run.sessions.iter().filter(|s| s.error.is_some()) {
            eprintln!(
                "fleet: session {} parked on error: {}",
                s.id,
                s.error.as_deref().unwrap_or("unknown")
            );
        }
        eprintln!("fleet: {} session(s) parked on error", run.stats.parked);
        return 1;
    }
    0
}

fn cmd_train(args: &Args) -> i32 {
    let workload = args.get("workload").unwrap_or("pusher");
    let parsed = (|| -> Result<(QuantScheme, BackendKind, Option<KernelPath>), TrainError> {
        let scheme = flag_opt::<QuantScheme>(args)?.unwrap_or(QuantScheme::Fp32);
        let backend = flag_opt::<BackendKind>(args)?.unwrap_or_default();
        let kernel = flag_opt::<KernelPath>(args)?;
        Ok((scheme, backend, kernel))
    })();
    let (scheme, backend, kernel) = match parsed {
        Ok(t) => t,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    if let Some(p) = kernel {
        crate::backend::force_kernel_path(Some(p));
        println!("kernel path forced: {}", p.name());
    }
    let Some(env) = by_name(workload) else {
        eprintln!("unknown workload: {workload}");
        return 1;
    };
    let steps = args.usize_or("steps", 400);
    let dims = match parse_hidden(args) {
        Ok(h) => h.map(crate::trainer::mlp::hidden_dims),
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let ds = Dataset::collect(env.as_ref(), 30, 100, 0x7EA1);
    let session = TrainSession::try_new(
        ds,
        TrainConfig {
            scheme,
            backend,
            dims,
            steps,
            lr: args.f64_or("lr", 1e-3) as f32,
            batch_size: args.usize_or("batch", 32),
            eval_every: args.usize_or("eval-every", 25),
            ..Default::default()
        },
    );
    let mut session = match session {
        Ok(s) => s,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let mut policy = match flag_opt::<PrecisionPolicy>(args) {
        Ok(p) => p.unwrap_or(PrecisionPolicy::Static),
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    // reject a policy this backend can never execute before step 0,
    // not at the (possibly distant) transition step
    if let Err(e) = policy.validate(backend) {
        eprintln!("bad --policy: {e}");
        return 1;
    }
    println!(
        "training {workload} under {} on the {} backend for {steps} steps...",
        scheme.name(),
        backend.name()
    );
    if let Err(e) = session.run_with_policy(&mut policy) {
        eprintln!("{e}");
        return 1;
    }
    if session.scheme_history().len() > 1 {
        let hops: Vec<String> = session
            .scheme_history()
            .iter()
            .map(|(at, s)| format!("{}@{at}", s.name()))
            .collect();
        println!("precision schedule ran: {}", hops.join(" -> "));
    }
    let mut t = Table::new(
        &format!("{workload} / {} / {}", scheme.name(), backend.name()),
        &["step", "val_loss"],
    );
    for (s, v) in &session.val_curve {
        t.row(vec![s.to_string(), format!("{v:.6}")]);
    }
    emit(&t, &format!("train_{workload}_{}", scheme.name()));
    if let Some(r) = session.hw_report() {
        println!(
            "hardware cost: {} steps, {} GeMMs | {:.2} us/step ({:.0} steps/s) | {:.2} uJ/step | \
             {:.1} KiB/step traffic | {:.1} KB resident | util {:.1}% | datapath dev {:.2e}",
            r.steps,
            r.gemms,
            r.us_per_step(),
            r.steps_per_sec(),
            r.uj_per_step(),
            r.traffic_kib_per_step(),
            r.resident_kb,
            100.0 * r.cost.utilization(r.element.mac_mode()),
            r.datapath_max_rel_err,
        );
        match save_hw_report(&r, &format!("train_{workload}_{}", scheme.name())) {
            Ok(p) => println!("[saved {}]\n", p.display()),
            Err(e) => println!("[json save failed: {e}]\n"),
        }
    }
    0
}

fn cmd_serve(args: &Args) -> i32 {
    if args.get("load").is_none() {
        eprintln!(
            "serve: only the synthetic load generator is wired up; pass --load \
             (open-socket front-ends mount the same executor, DESIGN.md \u{a7}12)"
        );
        return 1;
    }
    let d = LoadSpec::default();
    let mut spec = LoadSpec {
        sessions: args.usize_or("sessions", d.sessions),
        steps: args.usize_or("steps", d.steps),
        hidden: args.usize_or("hidden", d.hidden),
        episodes: args.usize_or("episodes", d.episodes),
        horizon: args.usize_or("horizon", d.horizon),
        batch: args.usize_or("batch", d.batch),
        eval_every: args.usize_or("eval-every", d.eval_every),
        quantum: args.usize_or("quantum", d.quantum),
        workers: args.usize_or("workers", d.workers),
        capacity: args.usize_or("capacity", d.capacity),
        max_parked: args.usize_or("max-parked", d.max_parked),
        lease_quanta: args.usize_or("lease", d.lease_quanta),
        burst_every: args.usize_or("burst-every", d.burst_every),
        twin_every: args.usize_or("twin-every", d.twin_every),
        seed: args.usize_or("seed", d.seed as usize) as u64,
        ..d
    };
    let flags = (|| -> Result<(), TrainError> {
        if let Some(schemes) = flag_list::<QuantScheme>(args)? {
            spec.schemes = schemes;
        }
        if let Some(b) = flag_opt::<BackendKind>(args)? {
            spec.backend = b;
        }
        if let Some(layout) = flag_opt::<StoreLayout>(args)? {
            let dir = args.get("store-dir").unwrap_or("results/serve_store");
            spec.store = Some(StoreSpec { dir: dir.into(), layout });
        }
        if let Some(plan) = flag_opt::<FaultPlan>(args)? {
            spec.chaos = Some(plan);
        }
        Ok(())
    })();
    if let Err(e) = flags {
        eprintln!("{e}");
        return 1;
    }
    if spec.lease_quanta > 0 && spec.store.is_none() {
        eprintln!("serve: --lease requires --store (eviction checkpoints through the store)");
        return 1;
    }
    if spec.chaos.as_ref().is_some_and(|p| p.covers(FaultClass::Executor)) && spec.store.is_none()
    {
        eprintln!(
            "serve: --chaos with executor faults requires --store \
             (recovery resumes from admission checkpoints)"
        );
        return 1;
    }
    println!(
        "serve: {} sessions x {} steps (quantum {}, capacity {}, lease {}) on the {} backend...",
        spec.sessions,
        spec.steps,
        spec.quantum,
        spec.capacity,
        spec.lease_quanta,
        spec.backend.name()
    );
    let out = match run_load(&spec) {
        Ok(o) => o,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let s = &out.stats;
    println!(
        "outcome: {} offered | {} admitted ({} re-admitted) | {} completed | {} shed | \
         {} refused | {} failed | {} evicted | {} chaos-recovered",
        s.offered, s.admitted, s.re_admitted, s.completed, s.shed_overloaded, s.refused,
        s.failed, s.evicted, s.recovered
    );
    println!(
        "latency: p50 {:.3} ms/step, p99 {:.3} ms/step over {} samples | {:.0} steps/s | \
         {} steals | parked peak {}",
        s.p50_step_ms,
        s.p99_step_ms,
        s.latency_samples,
        s.steps_per_sec(),
        s.steals,
        s.parked_peak
    );
    println!(
        "accounting: {} lost, {} duplicated | twins: {}/{} matched",
        out.lost,
        out.duplicated,
        out.twins_checked - out.twin_mismatches,
        out.twins_checked
    );
    for line in &out.shed_sample {
        println!("  shed: {line}");
    }
    match save_json(&bench_json(&spec, &out), "BENCH_serve") {
        Ok(p) => println!("[saved {}]", p.display()),
        Err(e) => {
            eprintln!("[json save failed: {e}]");
            return 1;
        }
    }
    if out.lost > 0 || out.duplicated > 0 || out.twin_mismatches > 0 {
        eprintln!(
            "serve: accounting violated (lost {}, duplicated {}, twin mismatches {})",
            out.lost, out.duplicated, out.twin_mismatches
        );
        return 1;
    }
    0
}

fn cmd_quantize(args: &Args) -> i32 {
    let fmt_name = args.get("format").unwrap_or("e4m3");
    let Some(fmt) = ElementFormat::parse(fmt_name) else {
        eprintln!("unknown format: {fmt_name}");
        return 1;
    };
    let rows = args.usize_or("rows", 64);
    let cols = args.usize_or("cols", 64);
    let mut rng = Pcg64::new(args.usize_or("seed", 7) as u64);
    let m = Mat::randn(rows, cols, 1.0, &mut rng);
    let mut t = Table::new(
        &format!("quantization stats: {} {}x{}", fmt.display(), rows, cols),
        &["layout", "bits/elem", "storage[KiB]", "rms-error"],
    );
    for layout in [Layout::Square8x8, Layout::Vector32] {
        let q = MxTensor::quantize(&m, fmt, layout);
        let deq = q.dequantize();
        t.row(vec![
            layout.name().to_string(),
            format!("{:.3}", crate::mx::MxFormat { element: fmt, layout }.bits_per_element()),
            format!("{:.2}", q.storage_kib()),
            format!("{:.6}", deq.mse(&m).sqrt()),
        ]);
    }
    print!("{}", t.render());
    0
}

fn info_text() -> String {
    format!(
        "mxscale: {} MACs ({}x{} PE arrays of 64), {} b/cycle interface @500 MHz\n\
         modes: INT8 (8 cyc/block), FP8/FP6 (2), FP4 (1); square 8x8 shared-exponent blocks\n\
         artifacts: {}\n",
        crate::gemmcore::TOTAL_MACS,
        crate::gemmcore::GRID_ROWS,
        crate::gemmcore::GRID_COLS,
        crate::gemmcore::BW_BITS_PER_CYCLE,
        crate::runtime::artifact_dir().display(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|t| t.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_positionals() {
        let a = Args::parse(&argv("repro fig2 --steps 100 --quick"));
        assert_eq!(a.positional, vec!["repro", "fig2"]);
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("quick"), Some("true"));
        assert_eq!(a.usize_or("steps", 5), 100);
        assert_eq!(a.usize_or("missing", 5), 5);
    }

    #[test]
    fn unknown_command_prints_usage() {
        assert_eq!(run_cli(&argv("bogus")), 1);
    }

    #[test]
    fn quantize_command_runs() {
        assert_eq!(run_cli(&argv("quantize --format int8 --rows 16 --cols 16")), 0);
    }

    #[test]
    fn train_rejects_bad_scheme_backend_combos() {
        assert_eq!(run_cli(&argv("train --scheme nope")), 1);
        assert_eq!(run_cli(&argv("train --backend warp")), 1);
        // hardware and packed backends can't run the FP32 baseline
        assert_eq!(run_cli(&argv("train --scheme fp32 --backend hw")), 1);
        assert_eq!(run_cli(&argv("train --scheme fp32 --backend packed")), 1);
        assert_eq!(run_cli(&argv("train --scheme mxvec-int8 --backend packed")), 1);
    }

    #[test]
    fn train_packed_backend_reachable_from_cli() {
        let code = run_cli(&argv(
            "train --workload cartpole --scheme int8 --backend packed --steps 3 --eval-every 1000000 --hidden 16",
        ));
        assert_eq!(code, 0);
    }

    #[test]
    fn train_kernel_flag_forces_and_rejects() {
        // bogus path name: structured error, exit 1
        assert_eq!(run_cli(&argv("train --kernel warp9")), 1);
        // forcing swar is always available and bit-identical, so the
        // tiny training run must succeed on any host
        let code = run_cli(&argv(
            "train --workload cartpole --scheme int8 --backend packed --steps 3 \
             --eval-every 1000000 --hidden 16 --kernel swar",
        ));
        crate::backend::force_kernel_path(None);
        assert_eq!(code, 0);
    }

    #[test]
    fn train_mxvec_scheme_reachable_from_cli() {
        let code = run_cli(&argv(
            "train --workload cartpole --scheme mxvec-int8 --steps 3 --eval-every 1000000 --hidden 16",
        ));
        assert_eq!(code, 0);
    }

    #[test]
    fn train_hw_backend_emits_report() {
        // tiny MLP so the bit-exact datapath walk stays fast
        let code = run_cli(&argv(
            "train --workload cartpole --scheme e2m1 --backend hw --steps 2 --eval-every 1000000 --hidden 8",
        ));
        assert_eq!(code, 0);
    }

    #[test]
    fn info_mentions_grid() {
        assert!(info_text().contains("4096"));
    }

    #[test]
    fn repro_accepts_multiple_ids_and_rejects_unknown() {
        assert_eq!(run_cli(&argv("repro nope")), 1);
        assert_eq!(run_cli(&argv("repro table2 nope")), 1, "any unknown id fails the run");
        // a failing id must not abort the ids after it: the run still
        // exits nonzero, but the later artefacts regenerate
        assert_eq!(run_cli(&argv("repro nope table2")), 1);
        // two cheap analytic artefacts in one invocation (the CI
        // repro-smoke shape: `repro table2 table3`)
        assert_eq!(run_cli(&argv("repro table2 table3")), 0);
    }

    #[test]
    fn train_policy_reachable_and_validated_from_cli() {
        // a scheduled run on the packed backend, e2m1 -> int8 at step 2
        let code = run_cli(&argv(
            "train --workload cartpole --scheme e2m1 --backend packed --steps 4 \
             --eval-every 1000000 --hidden 16 --policy 2:mx-int8",
        ));
        assert_eq!(code, 0);
        // malformed spec and a scheme the backend cannot execute
        assert_eq!(run_cli(&argv("train --steps 2 --policy nope")), 1);
        let code = run_cli(&argv(
            "train --workload cartpole --scheme int8 --backend packed --steps 4 \
             --eval-every 1000000 --hidden 16 --policy 2:fp32",
        ));
        assert_eq!(code, 1, "fp32 transition must fail on the packed backend");
    }

    #[test]
    fn fleet_policy_flag_parses_and_rejects() {
        assert_eq!(run_cli(&argv("fleet --policy nope")), 1);
        let code = run_cli(&argv(
            "fleet --sessions 2 --steps 6 --quantum 3 --shift-at 0 --hidden 8 --eval-every 3 \
             --scheme e2m1 --policy 3:mx-int8",
        ));
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_command_runs_small() {
        let code = run_cli(&argv(
            "fleet --sessions 2 --steps 8 --quantum 3 --shift-at 4 --hidden 8 --eval-every 4",
        ));
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_rejects_bad_flags() {
        assert_eq!(run_cli(&argv("fleet --scheme nope")), 1);
        assert_eq!(run_cli(&argv("fleet --backend warp")), 1);
        assert_eq!(run_cli(&argv("fleet --hidden 0")), 1);
        assert_eq!(run_cli(&argv("fleet --store monolith")), 1);
        assert_eq!(run_cli(&argv("fleet --store sharded:0")), 1);
    }

    #[test]
    fn serve_requires_the_load_flag() {
        assert_eq!(run_cli(&argv("serve")), 1);
    }

    #[test]
    fn fleet_chaos_flag_drills_and_rejects_bad_plans() {
        assert_eq!(run_cli(&argv("fleet --chaos disk")), 1, "unknown fault class");
        assert_eq!(run_cli(&argv("fleet --chaos mem@nope")), 1, "unparseable seed");
        // the mem+storage drill is self-contained and fast; every fault
        // must end detected-structured or recovered-bit-identically
        assert_eq!(run_cli(&argv("fleet --chaos mem,storage@7")), 0);
    }

    #[test]
    fn serve_chaos_requires_a_store_for_executor_faults() {
        assert_eq!(run_cli(&argv("serve --load --sessions 4 --chaos exec")), 1);
        assert_eq!(run_cli(&argv("serve --load --sessions 4 --chaos bogus")), 1);
    }

    #[test]
    fn serve_chaos_load_recovers_with_clean_twins() {
        let dir = std::env::temp_dir().join(format!("mxscale-cli-chaos-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // every completed session twin-checked: injected crashes/panics
        // must leave curves bitwise equal to the fault-free standalone
        let cmd = format!(
            "serve --load --sessions 6 --steps 4 --quantum 2 --capacity 6 --workers 2 \
             --twin-every 1 --eval-every 2 --hidden 8 --episodes 1 --horizon 16 \
             --store sharded:2 --store-dir {} --chaos exec@3",
            dir.display()
        );
        assert_eq!(run_cli(&argv(&cmd)), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn serve_rejects_bad_flags() {
        assert_eq!(run_cli(&argv("serve --load --sessions 4 --backend warp")), 1);
        assert_eq!(run_cli(&argv("serve --load --sessions 4 --scheme nope")), 1);
        assert_eq!(run_cli(&argv("serve --load --sessions 4 --store monolith")), 1);
        // lease-based eviction needs somewhere to checkpoint to
        assert_eq!(run_cli(&argv("serve --load --sessions 4 --lease 2")), 1);
    }

    #[test]
    fn serve_small_load_runs_clean() {
        let code = run_cli(&argv(
            "serve --load --sessions 6 --steps 4 --quantum 2 --capacity 3 --workers 2 \
             --twin-every 3 --eval-every 2 --hidden 8 --episodes 1 --horizon 16",
        ));
        assert_eq!(code, 0);
    }

    #[test]
    fn fleet_store_flag_persists_checkpoints() {
        let dir = std::env::temp_dir().join(format!("mxscale-cli-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cmd = format!(
            "fleet --sessions 2 --steps 8 --quantum 3 --shift-at 4 --hidden 8 --eval-every 4 \
             --store sharded:2 --store-dir {}",
            dir.display()
        );
        assert_eq!(run_cli(&argv(&cmd)), 0);
        let store = crate::store::CheckpointStore::open_dir(
            &dir,
            StoreLayout::Sharded { shards: 2 },
        )
        .unwrap();
        let ids = store.sessions().unwrap();
        assert_eq!(ids.len(), 2, "{ids:?}");
        for id in &ids {
            assert!(store.load(id).is_ok(), "{id}");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }
}
