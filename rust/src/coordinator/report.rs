//! Report writers: aligned-text tables for the terminal, plus CSV/JSON
//! files under `results/` for downstream plotting.

#![forbid(unsafe_code)]

use crate::util::json::Json;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// A simple aligned-text table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", cell, w = widths[c]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// CSV rendering.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Results directory (`$MXSCALE_RESULTS` or `./results`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("MXSCALE_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Persist a table as CSV under results/.
pub fn save_csv(table: &Table, name: &str) -> std::io::Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

/// Persist a JSON value under results/.
pub fn save_json(value: &Json, name: &str) -> std::io::Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.pretty())?;
    Ok(path)
}

/// Persist a session's hardware cost report as
/// `results/<prefix>_hw_report.json` (the `--backend hw` artifact),
/// stamped like every other results document.
pub fn save_hw_report(
    report: &crate::backend::HwCostReport,
    prefix: &str,
) -> std::io::Result<PathBuf> {
    let mut doc = stamped_doc("hw_report");
    if let Some(entries) = report.to_json().entries() {
        for (k, v) in entries {
            doc = doc.set(k, v.clone());
        }
    }
    save_json(&doc, &format!("{prefix}_hw_report"))
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Version of the `results/BENCH_*.json` layout. Bump when a bench
/// renames or restructures its metrics so the CI bench-gate can refuse
/// to diff incomparable baselines instead of mis-reading them.
pub const BENCH_SCHEMA_VERSION: u32 = 1;

/// The git commit the process is running from: `$GITHUB_SHA` in CI,
/// else `git rev-parse HEAD`, else "unknown" — benches stamp it into
/// their baselines so a regression report names both commits.
pub fn git_sha() -> String {
    if let Ok(sha) = std::env::var("GITHUB_SHA") {
        if !sha.trim().is_empty() {
            return sha.trim().to_string();
        }
    }
    std::process::Command::new("git")
        .args(["rev-parse", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .unwrap_or_else(|| "unknown".to_string())
}

/// Start a `BENCH_*.json` document with the shared stamp every bench
/// carries: bench name, schema version, git SHA, worker count, the
/// detected CPU features, and the kernel path an unbounded GeMM would
/// resolve to — the provenance `ci/check_bench.py` keys on so an AVX2
/// runner never diffs a SWAR baseline (or vice versa).
pub fn bench_doc(bench: &str) -> Json {
    let registry = crate::backend::KernelRegistry::from_env()
        .unwrap_or_else(|_| crate::backend::KernelRegistry::auto());
    Json::obj()
        .set("bench", bench)
        .set("schema_version", BENCH_SCHEMA_VERSION as f64)
        .set("git_sha", git_sha())
        .set("threads", crate::util::par::threads() as f64)
        .set("cpu_features", crate::mx::simd::detect::features().describe())
        .set("kernel_path", registry.default_path().name())
}

/// Version of the non-bench `results/*.json` layouts (fleet report,
/// precision-schedule report, hw report). Bump when any of them renames
/// or restructures fields.
pub const REPORT_SCHEMA_VERSION: u32 = 1;

/// Start a non-bench results document with the shared provenance stamp:
/// document kind, schema version, git SHA, and worker count. Every
/// `results/*.json` writer routes through this or [`bench_doc`] (the
/// mxlint L6 invariant), so downstream tooling can always identify a
/// document and refuse incomparable schema versions.
pub fn stamped_doc(kind: &str) -> Json {
    Json::obj()
        .set("kind", kind)
        .set("schema_version", REPORT_SCHEMA_VERSION as f64)
        .set("git_sha", git_sha())
        .set("threads", crate::util::par::threads() as f64)
}

/// Write a file only when the parent dir exists/creatable (test helper).
pub fn save_text(dir: &Path, name: &str, text: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, text)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1.00".into()]);
        t.row(vec!["longer".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn bench_doc_carries_schema_and_sha() {
        let s = bench_doc("demo").to_string();
        assert!(s.contains("\"bench\":\"demo\""), "{s}");
        assert!(s.contains("\"schema_version\":1"), "{s}");
        assert!(s.contains("\"git_sha\":"), "{s}");
        assert!(s.contains("\"threads\":"), "{s}");
        assert!(s.contains("\"cpu_features\":"), "{s}");
        assert!(s.contains("\"kernel_path\":"), "{s}");
        assert!(!git_sha().is_empty());
    }

    #[test]
    fn stamped_doc_carries_kind_and_schema() {
        let s = stamped_doc("fleet_report").to_string();
        assert!(s.contains("\"kind\":\"fleet_report\""), "{s}");
        assert!(s.contains("\"schema_version\":1"), "{s}");
        assert!(s.contains("\"git_sha\":"), "{s}");
        assert!(s.contains("\"threads\":"), "{s}");
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1,5".into(), "plain".into()]);
        assert!(t.to_csv().contains("\"1,5\",plain"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
