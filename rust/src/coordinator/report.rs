//! Report writers: aligned-text tables for the terminal, plus CSV/JSON
//! files under `results/` for downstream plotting.

use crate::util::json::Json;
use std::fmt::Write as _;
use std::path::{Path, PathBuf};

/// A simple aligned-text table.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Render with per-column alignment.
    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..ncol {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            for (c, cell) in cells.iter().enumerate() {
                let _ = write!(out, "{:<w$}  ", cell, w = widths[c]);
            }
            out.push('\n');
        };
        line(&mut out, &self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&mut out, &sep);
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    /// CSV rendering.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let _ = writeln!(out, "{}", self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        for row in &self.rows {
            let _ = writeln!(out, "{}", row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }
}

/// Results directory (`$MXSCALE_RESULTS` or `./results`).
pub fn results_dir() -> PathBuf {
    std::env::var_os("MXSCALE_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Persist a table as CSV under results/.
pub fn save_csv(table: &Table, name: &str) -> std::io::Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

/// Persist a JSON value under results/.
pub fn save_json(value: &Json, name: &str) -> std::io::Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, value.pretty())?;
    Ok(path)
}

/// Persist a session's hardware cost report as
/// `results/<prefix>_hw_report.json` (the `--backend hw` artifact).
pub fn save_hw_report(
    report: &crate::backend::HwCostReport,
    prefix: &str,
) -> std::io::Result<PathBuf> {
    save_json(&report.to_json(), &format!("{prefix}_hw_report"))
}

/// Format a float with fixed decimals.
pub fn f(v: f64, decimals: usize) -> String {
    format!("{v:.decimals$}")
}

/// Write a file only when the parent dir exists/creatable (test helper).
pub fn save_text(dir: &Path, name: &str, text: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = dir.join(name);
    std::fs::write(&path, text)?;
    Ok(path)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned_table() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1.00".into()]);
        t.row(vec!["longer".into(), "2.50".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("longer"));
        assert!(s.lines().count() >= 5);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1,5".into(), "plain".into()]);
        assert!(t.to_csv().contains("\"1,5\",plain"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn rejects_wrong_arity() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}
