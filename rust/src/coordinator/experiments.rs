//! The per-table / per-figure reproduction harnesses (DESIGN.md §5).
//!
//! Each function regenerates one artefact of the paper's evaluation
//! section, prints it in the paper's row/column layout alongside the
//! published values, and saves a CSV under `results/`.

#![forbid(unsafe_code)]

use crate::arith::MacVariant;
use crate::backend::BackendKind;
use crate::coordinator::report::{f, save_csv, save_hw_report, save_json, Table};
use crate::energy::{calib, EnergyModel};
use crate::gemmcore::memory::{footprint_dacapo, footprint_fp32, footprint_ours, MlpShape};
use crate::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use crate::mx::dacapo::DacapoFormat;
use crate::mx::element::ElementFormat;
use crate::mx::ALL_ELEMENT_FORMATS;
use crate::pearray::{PeArray, SystolicArray};
use crate::trainer::batched::sweep_schemes;
use crate::trainer::budget::{step_cost, step_cost_for, train_with_budget, Budget};
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainConfig, TrainError, TrainSession};
use crate::util::mat::Mat;
use crate::util::par;
use crate::util::rng::Pcg64;
use crate::workloads::{by_name, Dataset, Env, ALL_WORKLOADS};

/// [`by_name`] as a structured error (for the `Result`-shaped harnesses).
fn workload(name: &str) -> Result<Box<dyn Env>, TrainError> {
    by_name(name)
        .ok_or_else(|| TrainError::BadConfig { reason: format!("unknown workload `{name}`") })
}

/// Paper's Table II values for side-by-side display.
const TABLE2_PAPER: [(&str, f64, f64, [f64; 6]); 3] = [
    ("normalize-l2", 500.0, 3281.63, [5.08, 2.4, 2.49, 2.29, 2.51, 0.43]),
    ("ext-no-bypass", 417.0, 3395.00, [6.35, 3.2, 3.38, 3.21, 3.38, 0.67]),
    ("ext+bypass", 500.0, 1589.05, [4.41, 1.11, 1.169, 1.05, 1.13, 0.39]),
];

/// Table II — MAC implementation variants: area + energy/OP per format.
pub fn table2() -> Table {
    let mut t = Table::new(
        "Table II - precision-scalable MX MAC variants (model vs paper)",
        &[
            "variant", "freq[MHz]", "area[um2]", "INT8", "E5M2", "E4M3", "E3M2", "E2M3", "E2M1",
        ],
    );
    for (variant, (name, freq, area, paper)) in [
        MacVariant::NormalizeL2,
        MacVariant::ExtMantissaNoBypass,
        MacVariant::ExtMantissaBypass,
    ]
    .into_iter()
    .zip(TABLE2_PAPER)
    {
        let m = EnergyModel::new(variant);
        let mut cells = vec![name.to_string(), f(m.freq_mhz(), 0), f(m.mac_area_um2(), 2)];
        for fmt in ALL_ELEMENT_FORMATS {
            cells.push(f(m.mac_pj_per_op(fmt), 3));
        }
        t.row(cells);
        let mut paper_cells = vec!["  (paper)".to_string(), f(freq, 0), f(area, 2)];
        for v in paper {
            paper_cells.push(f(v, 3));
        }
        t.row(paper_cells);
    }
    t
}

/// Table III — memory footprint for the pusher MLP, batch 16/32/64.
pub fn table3() -> Table {
    let shape = MlpShape::pusher();
    let mut t = Table::new(
        "Table III - memory footprint [KB], pusher MLP (W/A inference, Wt/At/E training)",
        &["batch", "method", "W", "A", "Wt", "At", "E(row)", "E(col)", "total", "vs FP32"],
    );
    for batch in [16usize, 32, 64] {
        let fp32 = footprint_fp32(&shape, batch);
        let dac = footprint_dacapo(&shape, batch, DacapoFormat::Mx9);
        let ours = footprint_ours(&shape, batch, ElementFormat::Int8);
        for (name, fp) in [("FP32", fp32), ("Dacapo", dac), ("Ours", ours)] {
            t.row(vec![
                batch.to_string(),
                name.to_string(),
                f(fp.w, 1),
                f(fp.a_inference, 1),
                f(fp.w_t, 1),
                f(fp.a_t_training, 1),
                f(fp.e_row, 1),
                f(fp.e_col, 1),
                f(fp.total(), 1),
                format!("{}x", f(fp32.total() / fp.total(), 2)),
            ]);
        }
    }
    t
}

/// Table IV — comprehensive core comparison vs Dacapo.
pub fn table4() -> Table {
    let mut t = Table::new(
        "Table IV - ours vs Dacapo (iso-peak-throughput, 4096 MACs, 500 MHz)",
        &["metric", "ours", "dacapo", "paper(ours)", "paper(dacapo)"],
    );
    let m = EnergyModel::proposed();
    let shape = MlpShape::pusher();
    let mem_ours = footprint_ours(&shape, 32, ElementFormat::Int8).total();
    let mem_dac = footprint_dacapo(&shape, 32, DacapoFormat::Mx9).total();
    t.row(vec!["area [mm2]".into(), f(calib::CORE_AREA_MM2, 2), f(calib::DACAPO_AREA_MM2, 2), "6.44".into(), "8.66".into()]);
    t.row(vec!["max BW [GB/s]".into(), f(calib::CORE_BW_GBS, 0), f(calib::DACAPO_BW_GBS, 0), "330".into(), "640".into()]);
    t.row(vec!["memory [KB]".into(), f(mem_ours, 2), f(mem_dac, 2), "179.78".into(), "370.13".into()]);
    t.row(vec!["MACs".into(), "4096".into(), "4096".into(), "4096".into(), "4096".into()]);
    for (label, fmt, dfmt, p_ours, p_dac) in [
        ("E/op MXINT8 vs MX9 [pJ]", ElementFormat::Int8, DacapoFormat::Mx9, "3.20", "3.08"),
        ("E/op MXFP8/6 vs MX6 [pJ]", ElementFormat::E4M3, DacapoFormat::Mx6, "1.87-1.88", "1.80"),
        ("E/op MXFP4 vs MX4 [pJ]", ElementFormat::E2M1, DacapoFormat::Mx4, "0.43", "0.48"),
    ] {
        t.row(vec![
            label.into(),
            f(m.core_pj_per_op(fmt), 2),
            f(calib::dacapo_pj_per_op(dfmt), 2),
            p_ours.into(),
            p_dac.into(),
        ]);
    }
    let arr = SystolicArray::dacapo();
    for (label, fmt, dfmt, p_ours, p_dac) in [
        ("latency MXINT8 vs MX9 [us]", ElementFormat::Int8, DacapoFormat::Mx9, "10.86", "40.4"),
        ("latency MXFP8/6 vs MX6 [us]", ElementFormat::E4M3, DacapoFormat::Mx6, "4.82", "24.56"),
        ("latency MXFP4 vs MX4 [us]", ElementFormat::E2M1, DacapoFormat::Mx4, "3.81", "20.6"),
    ] {
        let ours = train_step_cycles(32, &PUSHER_DIMS, fmt).micros(500.0);
        let dac = arr.train_step_cycles(32, &PUSHER_DIMS, dfmt).micros(500.0);
        t.row(vec![label.into(), f(ours, 2), f(dac, 2), p_ours.into(), p_dac.into()]);
    }
    t
}

/// Fig. 7 — PE-array area & energy/OP breakdown by component and mode.
/// Runs 100 random block multiplications per mode through the bit-exact
/// array (51,200 mult OPs in INT8 terms) as the paper does.
pub fn fig7() -> (Table, Table) {
    let model = EnergyModel::proposed();
    let mut e = Table::new(
        "Fig. 7 - PE array energy/OP breakdown [pJ] (100 random block mults)",
        &["component", "INT8", "FP8/FP6", "FP4"],
    );
    let mut measured = Vec::new();
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let mut rng = Pcg64::new(0xF16_7 ^ fmt.bits() as u64);
        for _ in 0..100 {
            let a = Mat::randn(8, 8, 1.0, &mut rng);
            let b = Mat::randn(8, 8, 1.0, &mut rng);
            pe.gemm(&a, &b);
        }
        let ev = pe.events();
        let pj = model.run_pj(fmt, &ev);
        measured.push((fmt, pj / ev.mul_ops as f64));
    }
    let comps: Vec<&str> = calib::energy_share(crate::arith::Mode::Int8).iter().map(|c| c.0).collect();
    for comp in &comps {
        let mut cells = vec![comp.to_string()];
        for (fmt, _) in &measured {
            let b = model.pe_energy_breakdown(*fmt);
            let v = b.components.iter().find(|(n, _)| n == comp).map_or(f64::NAN, |(_, v)| *v);
            cells.push(f(v, 3));
        }
        e.row(cells);
    }
    let mut cells = vec!["TOTAL (event-priced)".to_string()];
    for (_, pj_op) in &measured {
        cells.push(f(*pj_op, 3));
    }
    e.row(cells);

    let mut a = Table::new(
        "Fig. 7 - MAC area breakdown [um2]",
        &["component", "area", "share"],
    );
    let ab = model.mac_area_breakdown();
    for (name, v) in &ab.components {
        a.row(vec![name.to_string(), f(*v, 1), format!("{}%", f(100.0 * v / ab.total_um2, 1))]);
    }
    a.row(vec!["TOTAL".into(), f(ab.total_um2, 1), "100%".into()]);
    (e, a)
}

/// Fig. 2 — validation-loss curves of all formats on the 4 workloads.
/// Returns one table of the final losses; full curves are saved as CSV.
///
/// The 7 schemes of each workload train concurrently through the
/// batched engine — the sweep is embarrassingly parallel and the
/// results are bit-identical to the sequential loop (each session is
/// seeded independently and the parallel kernels are exact).
pub fn fig2(steps: usize, eval_every: usize) -> Result<Table, TrainError> {
    let schemes: Vec<QuantScheme> = std::iter::once(QuantScheme::Fp32)
        .chain(ALL_ELEMENT_FORMATS.into_iter().map(QuantScheme::MxSquare))
        .collect();
    let mut t = Table::new(
        "Fig. 2 - final validation loss (lower is better)",
        &["workload", "fp32", "int8", "e5m2", "e4m3", "e3m2", "e2m3", "e2m1", "best-mx"],
    );
    for wl in ALL_WORKLOADS {
        let env = workload(wl)?;
        let ds = Dataset::collect(env.as_ref(), 30, 100, 0xF16_2);
        let base = TrainConfig { steps, eval_every, lr: 1e-3, ..Default::default() };
        let outcomes = sweep_schemes(&ds, &schemes, &base);
        let mut cells = vec![wl.to_string()];
        let mut curves = Table::new(
            &format!("fig2 curves - {wl}"),
            &["scheme", "step", "val_loss"],
        );
        let mut best: Option<(String, f64)> = None;
        for (scheme, o) in schemes.iter().zip(&outcomes) {
            let v = o.session.val_loss();
            cells.push(f(v, 4));
            for (step, loss) in &o.session.val_curve {
                curves.row(vec![scheme.name(), step.to_string(), format!("{loss:.6}")]);
            }
            if *scheme != QuantScheme::Fp32 && best.as_ref().map(|b| v < b.1).unwrap_or(true) {
                best = Some((scheme.name(), v));
            }
        }
        cells.push(best.map(|b| b.0).unwrap_or_default());
        t.row(cells);
        let _ = save_csv(&curves, &format!("fig2_{wl}"));
    }
    Ok(t)
}

/// Fig. 8 — pusher validation loss under a 1000 us time budget and a
/// 120 uJ-class energy budget, ours (MXINT8/MXFP8) vs Dacapo (MX9/MX6).
pub fn fig8(time_budget_us: f64, energy_budget_uj: f64) -> Result<Table, TrainError> {
    let env = workload("pusher")?;
    let ds = Dataset::collect(env.as_ref(), 30, 100, 0xF16_8);
    let contenders = [
        QuantScheme::MxSquare(ElementFormat::Int8),
        QuantScheme::MxSquare(ElementFormat::E4M3),
        QuantScheme::Dacapo(DacapoFormat::Mx9),
        QuantScheme::Dacapo(DacapoFormat::Mx6),
    ];
    let mut t = Table::new(
        &format!(
            "Fig. 8 - pusher budgeted training ({time_budget_us} us / {energy_budget_uj} uJ)"
        ),
        &["scheme", "us/step", "uJ/step", "steps@time", "loss@time", "steps@energy", "loss@energy"],
    );
    let mut curves = Table::new("fig8 curves", &["scheme", "budget", "consumed", "steps", "val_loss"]);
    // every (scheme x budget) run is independent: one batched fan-out
    let specs: Vec<(QuantScheme, Budget)> = contenders
        .iter()
        .flat_map(|&s| {
            [
                (s, Budget::TimeMicros(time_budget_us)),
                (s, Budget::EnergyMicrojoules(energy_budget_uj)),
            ]
        })
        .collect();
    let runs = par::par_map(specs.len(), 1, |i| {
        let (scheme, budget) = specs[i];
        let cfg = TrainConfig { eval_every: usize::MAX, ..Default::default() };
        train_with_budget(ds.clone(), scheme, budget, 8, cfg)
    });
    for (ci, scheme) in contenders.into_iter().enumerate() {
        let cost = step_cost(scheme, 32);
        let tc = &runs[2 * ci];
        let ec = &runs[2 * ci + 1];
        for p in tc {
            curves.row(vec![scheme.name(), "time".into(), f(p.consumed, 1), p.steps.to_string(), format!("{:.6}", p.val_loss)]);
        }
        for p in ec {
            curves.row(vec![scheme.name(), "energy".into(), f(p.consumed, 2), p.steps.to_string(), format!("{:.6}", p.val_loss)]);
        }
        let (Some(lt), Some(le)) = (tc.last(), ec.last()) else {
            // train_with_budget always samples at least once; an empty
            // curve would mean the budget priced to zero steps
            continue;
        };
        t.row(vec![
            scheme.name(),
            f(cost.micros, 2),
            f(cost.microjoules, 2),
            lt.steps.to_string(),
            f(lt.val_loss, 4),
            le.steps.to_string(),
            f(le.val_loss, 4),
        ]);
    }
    let _ = save_csv(&curves, "fig8_curves");
    Ok(t)
}

/// Measured-on-model training throughput: drive real QAT steps through
/// the hardware backend (bit-exact GemmCore, stage-aware schedule,
/// event-priced energy) and report them next to the analytic Table IV
/// numbers. "Analytic" charges 3 GeMMs to every layer; the measured
/// graph skips layer 0's error-backprop GeMM (nothing upstream), so the
/// measured step is slightly cheaper — that gap is the point of
/// measuring on the model instead of trusting the closed form.
pub fn throughput(steps: usize) -> Result<Table, TrainError> {
    let env = workload("pusher")?;
    let ds = Dataset::collect(env.as_ref(), 6, 60, 0x7409);
    let mut t = Table::new(
        "Measured training cost on the hardware backend (pusher MLP, batch 32)",
        &[
            "format", "steps", "us/step", "us/step(analytic)", "steps/s", "uJ/step",
            "traffic KiB/step", "resident KB", "util %", "datapath dev",
        ],
    );
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let mut s = TrainSession::new(
            ds.clone(),
            TrainConfig {
                scheme: QuantScheme::MxSquare(fmt),
                backend: BackendKind::Hardware,
                steps,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        s.run();
        let r = s.hw_report().ok_or_else(|| TrainError::BadConfig {
            reason: "hardware backend produced no cost report".into(),
        })?;
        let analytic = train_step_cycles(32, &PUSHER_DIMS, fmt).micros(500.0);
        t.row(vec![
            fmt.name().to_string(),
            r.steps.to_string(),
            f(r.us_per_step(), 2),
            f(analytic, 2),
            f(r.steps_per_sec(), 0),
            f(r.uj_per_step(), 2),
            f(r.traffic_kib_per_step(), 1),
            f(r.resident_kb, 1),
            f(100.0 * r.cost.utilization(fmt.mac_mode()), 1),
            format!("{:.2e}", r.datapath_max_rel_err),
        ]);
        if let Err(e) = save_hw_report(&r, &format!("throughput_{}", fmt.name())) {
            println!("[json save failed: {e}]");
        }
    }
    // the software side of the same story: the packed SWAR backend vs
    // the fake-quant backend, measured wall-clock on identical sessions
    // (bit-identical losses — only execution speed differs); lands in
    // results/ next to the analytic hardware numbers above
    let sw = sw_backend_wallclock(12)?;
    print!("{}", sw.render());
    match save_csv(&sw, "throughput_sw_packed") {
        Ok(p) => println!("[saved {}]\n", p.display()),
        Err(e) => println!("[csv save failed: {e}]\n"),
    }
    Ok(t)
}

/// Outcome of one [`race_fast_vs_packed`] run.
pub struct BackendRace {
    /// Wall-clock seconds of the whole `fast` run / the `packed` run.
    pub fast_s: f64,
    pub packed_s: f64,
    /// Final validation losses agreed bit for bit (the equivalence
    /// contract; anything else is a bug).
    pub loss_bit_identical: bool,
    pub steps: usize,
}

impl BackendRace {
    pub fn fast_ms_step(&self) -> f64 {
        self.fast_s / self.steps as f64 * 1e3
    }

    pub fn packed_ms_step(&self) -> f64 {
        self.packed_s / self.steps as f64 * 1e3
    }

    pub fn speedup(&self) -> f64 {
        self.fast_s / self.packed_s
    }

    /// The JSON fragment both artifact writers publish.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::util::json::Json::obj()
            .set("fast_ms_step", self.fast_ms_step())
            .set("packed_ms_step", self.packed_ms_step())
            .set("speedup", self.speedup())
            .set("loss_bit_identical", self.loss_bit_identical)
    }
}

/// Race the `fast` (dense fake-quant) backend against the `packed`
/// (sub-word SWAR) backend on identical sessions over `ds` — shared by
/// `repro throughput` and `examples/dacapo_compare.rs` so the two
/// published speedup artifacts can never drift apart. Errors when the
/// scheme has no packed datapath (non-square schemes).
///
/// The timed window contains training steps only: one warmup step runs
/// first (it carries the step-0 eval and fills the backends' scratch /
/// packed-weight state), and the final validation eval — a dense pass
/// identical on both backends, which would only dilute the ratio —
/// happens after the clock stops.
pub fn race_fast_vs_packed(
    ds: &Dataset,
    scheme: QuantScheme,
    steps: usize,
) -> Result<BackendRace, String> {
    use std::time::Instant;
    let steps = steps.max(1);
    let run = |backend: BackendKind| -> Result<(f64, f64), String> {
        let mut s = TrainSession::try_new(
            ds.clone(),
            TrainConfig {
                scheme,
                backend,
                steps: steps + 1,
                eval_every: usize::MAX,
                ..Default::default()
            },
        )
        .map_err(|e| e.to_string())?;
        s.step_once(); // warmup: step-0 eval + scratch-buffer fill
        let t0 = Instant::now();
        for _ in 0..steps {
            s.step_once();
        }
        let dt = t0.elapsed().as_secs_f64();
        Ok((dt, s.val_loss()))
    };
    let (fast_s, loss_fast) = run(BackendKind::Fast)?;
    let (packed_s, loss_packed) = run(BackendKind::Packed)?;
    Ok(BackendRace {
        fast_s,
        packed_s,
        loss_bit_identical: loss_fast.to_bits() == loss_packed.to_bits(),
        steps,
    })
}

/// Wall-clock of the two software backends on the same pusher sessions:
/// `fast` (dense fake-quant GeMMs) vs `packed` (sub-word SWAR kernels).
/// The loss columns must agree bit for bit (the backend equivalence
/// contract); the speedup is what the packed execution path buys.
/// Also saves `results/throughput_packed.json` for the perf trajectory.
pub fn sw_backend_wallclock(steps: usize) -> Result<Table, TrainError> {
    use crate::coordinator::report::bench_doc;
    use crate::util::json::Json;
    let env = workload("pusher")?;
    let ds = Dataset::collect(env.as_ref(), 6, 60, 0x7410);
    let mut t = Table::new(
        "Measured software training throughput (pusher MLP, batch 32): fast vs packed",
        &["format", "steps", "fast ms/step", "packed ms/step", "speedup", "bit-identical"],
    );
    let mut schemes = Json::obj();
    for fmt in [ElementFormat::Int8, ElementFormat::E4M3, ElementFormat::E2M1] {
        let race = race_fast_vs_packed(&ds, QuantScheme::MxSquare(fmt), steps)
            .map_err(|reason| TrainError::BadConfig { reason })?;
        t.row(vec![
            fmt.name().to_string(),
            steps.to_string(),
            f(race.fast_ms_step(), 3),
            f(race.packed_ms_step(), 3),
            format!("{:.2}x", race.speedup()),
            if race.loss_bit_identical { "yes".into() } else { "NO".into() },
        ]);
        schemes = schemes.set(fmt.name(), race.to_json());
    }
    let doc = bench_doc("throughput_packed").set("steps", steps).set("schemes", schemes);
    if let Err(e) = crate::coordinator::report::save_json(&doc, "throughput_packed") {
        println!("[json save failed: {e}]");
    }
    Ok(t)
}

/// Runtime precision scheduling — the paper's precision-*scalable*
/// datapath exercised as a dynamic system: a scheduled session (coarse
/// cheap formats early, MXINT8 late, policy-driven transitions through
/// the FP32 masters) races a static-MXINT8 session under **one shared
/// accelerator time budget** (the analytic step cost at 500 MHz prices
/// each step at its active format). MXINT8 is both the
/// highest-precision MX mode and the analytically slowest (8
/// cycles/block vs 2 for FP8/FP6 and 1 for FP4), so the scheduled run
/// completes more steps inside the budget — and, having banked the
/// cheap coarse descent, finishes its final MXINT8 segment at a lower
/// eval loss than static-MXINT8 reaches with the same budget. Both
/// sessions execute on the packed SWAR backend (host wall-clock is
/// reported per segment alongside the analytic numbers). Emits the
/// table and returns the `results/precision_schedule.json` document.
pub fn precision_schedule_report(
    static_steps: usize,
    dims: Option<Vec<usize>>,
) -> Result<(Table, crate::util::json::Json), TrainError> {
    use crate::util::json::Json;
    use std::time::Instant;
    let static_steps = static_steps.max(8);
    let env = workload("cartpole")?;
    let ds = Dataset::collect(env.as_ref(), 20, 80, 0x5C4ED);
    let dims_vec = dims.clone().unwrap_or_else(|| crate::trainer::mlp::MLP_DIMS.to_vec());
    let batch = 32usize;
    let cost_us = |s: QuantScheme| step_cost_for(s, batch, &dims_vec).micros;
    let cost_uj = |s: QuantScheme| step_cost_for(s, batch, &dims_vec).microjoules;
    // the promotion ladder and each rung's share of the time budget:
    // MXFP4 opens (1 cycle/block — the cheapest descent), MXFP8 carries
    // the bulk at 2 cycles/block, MXINT8 (8 cycles/block, the finest
    // and slowest mode) finishes. Every rung is cheaper per step than
    // static-MXINT8, so the same budget buys ~2x the steps.
    let ladder = [
        (QuantScheme::MxSquare(ElementFormat::E2M1), 0.20),
        (QuantScheme::MxSquare(ElementFormat::E4M3), 0.40),
        (QuantScheme::MxSquare(ElementFormat::Int8), 0.40),
    ];
    let static_scheme = QuantScheme::MxSquare(ElementFormat::Int8);
    let budget_us = static_steps as f64 * cost_us(static_scheme);
    let seg_steps: Vec<(QuantScheme, usize)> = ladder
        .iter()
        .map(|&(scheme, frac)| {
            let n = ((frac * budget_us) / cost_us(scheme)).floor() as usize;
            (scheme, n.max(1))
        })
        .collect();
    let total_steps: usize = seg_steps.iter().map(|&(_, n)| n).sum();
    let consumed_us: f64 = seg_steps.iter().map(|&(s, n)| n as f64 * cost_us(s)).sum();
    let mut entries = Vec::new();
    let mut at = 0usize;
    for &(scheme, n) in &seg_steps {
        entries.push((at, scheme));
        at += n;
    }
    let policy = PrecisionPolicy::schedule(entries)
        .map_err(|reason| TrainError::BadConfig { reason })?;
    let config = |scheme: QuantScheme, steps: usize| TrainConfig {
        scheme,
        backend: BackendKind::Packed,
        dims: dims.clone(),
        batch_size: batch,
        lr: 2e-3,
        steps,
        eval_every: usize::MAX,
        ..Default::default()
    };
    // static contender: highest precision, full budget. Timed over the
    // training steps only — the scheduled run's segment timers stop
    // before each eval, so the eval must stay outside this window too
    // or the wall-clock race would be asymmetric.
    let mut stat = TrainSession::new(ds.clone(), config(static_scheme, static_steps));
    let t0 = Instant::now();
    while stat.step_count() < static_steps {
        stat.step_once();
    }
    let static_wall = t0.elapsed().as_secs_f64();
    let static_loss = stat.val_loss();
    // scheduled contender: same budget, policy-driven transitions
    let mut driver = policy.clone();
    let mut sched = TrainSession::new(ds.clone(), config(seg_steps[0].0, total_steps));
    let mut seg_rows: Vec<(String, usize, f64, f64)> = Vec::new();
    let mut boundary = 0usize;
    for &(scheme, n) in &seg_steps {
        boundary += n;
        let t0 = Instant::now();
        while sched.step_count() < boundary {
            sched.step_with_policy(&mut driver)?;
        }
        let wall = t0.elapsed().as_secs_f64();
        seg_rows.push((scheme.name(), n, wall, sched.val_loss()));
    }
    let sched_loss = sched.val_loss();
    let sched_wall: f64 = seg_rows.iter().map(|r| r.2).sum();
    assert_eq!(sched.scheme_history().len(), seg_steps.len(), "every transition must fire");
    let speedup_analytic =
        (total_steps as f64 / consumed_us) / (static_steps as f64 / budget_us);
    let speedup_wall = (total_steps as f64 / sched_wall) / (static_steps as f64 / static_wall);

    let mut t = Table::new(
        &format!(
            "Runtime precision scheduling - one {budget_us:.0} us accelerator budget (packed backend)"
        ),
        &["run", "steps", "hw us", "final val", "steps/us", "wall ms/step", "speedup"],
    );
    t.row(vec![
        format!("static {}", static_scheme.name()),
        static_steps.to_string(),
        f(budget_us, 1),
        f(static_loss, 4),
        f(static_steps as f64 / budget_us, 3),
        f(static_wall / static_steps as f64 * 1e3, 3),
        "1.00x".into(),
    ]);
    t.row(vec![
        format!("scheduled ({})", policy.name()),
        total_steps.to_string(),
        f(consumed_us, 1),
        f(sched_loss, 4),
        f(total_steps as f64 / consumed_us, 3),
        f(sched_wall / total_steps as f64 * 1e3, 3),
        format!("{:.2}x", speedup_analytic),
    ]);
    for (&(scheme, _), (name, n, wall, val)) in seg_steps.iter().zip(&seg_rows) {
        t.row(vec![
            format!("  segment {name}"),
            n.to_string(),
            f(*n as f64 * cost_us(scheme), 1),
            f(*val, 4),
            "".into(),
            f(wall / (*n as f64) * 1e3, 3),
            "".into(),
        ]);
    }

    let mut seg_json = Json::arr();
    for ((scheme, n), (name, _, wall, val)) in seg_steps.iter().zip(&seg_rows) {
        seg_json = seg_json.push(
            Json::obj()
                .set("scheme", name.clone())
                .set("steps", *n)
                .set("analytic_us_per_step", cost_us(*scheme))
                .set("analytic_uj_per_step", cost_uj(*scheme))
                .set("wall_ms_per_step", wall / (*n as f64) * 1e3)
                .set("val_loss_at_end", *val),
        );
    }
    let doc = crate::coordinator::report::stamped_doc("precision_schedule")
        .set("workload", "cartpole")
        .set("backend", "packed")
        .set("policy", policy.name())
        .set("dims", dims_vec.clone())
        .set("budget_us", budget_us)
        .set(
            "static_int8",
            Json::obj()
                .set("scheme", static_scheme.name())
                .set("steps", static_steps)
                .set("final_val_loss", static_loss)
                .set("analytic_us_per_step", cost_us(static_scheme))
                .set("analytic_uj_per_step", cost_uj(static_scheme))
                .set("wall_s", static_wall),
        )
        .set(
            "scheduled",
            Json::obj()
                .set("steps", total_steps)
                .set("final_val_loss", sched_loss)
                .set("consumed_us", consumed_us)
                .set("wall_s", sched_wall)
                .set("segments", seg_json),
        )
        .set(
            "race",
            Json::obj()
                .set("scheduled_beats_static_loss", sched_loss < static_loss)
                .set("loss_static_int8", static_loss)
                .set("loss_scheduled", sched_loss)
                .set("throughput_speedup_analytic", speedup_analytic)
                .set("throughput_speedup_wall", speedup_wall)
                .set("meets_1p5x_floor", speedup_analytic >= 1.5),
        );
    Ok((t, doc))
}

/// [`precision_schedule_report`] + `results/precision_schedule.json`
/// emission (the `mxscale repro precision-schedule` artefact). The doc
/// is already provenance-stamped by `stamped_doc`.
pub fn precision_schedule(
    static_steps: usize,
    dims: Option<Vec<usize>>,
) -> Result<Table, TrainError> {
    let (t, doc) = precision_schedule_report(static_steps, dims)?;
    match save_json(&doc, "precision_schedule") {
        Ok(p) => println!("[saved {}]", p.display()),
        Err(e) => println!("[json save failed: {e}]"),
    }
    Ok(t)
}

/// Ablation — square-block granularity (the paper's 8x8 design choice).
/// Sweeps k x k squares over weight/activation tensors captured from a
/// trained pusher MLP, reporting error vs storage vs MX compatibility.
pub fn ablation() -> Result<Table, TrainError> {
    use crate::mx::ablation::ablate;
    let env = workload("pusher")?;
    let ds = Dataset::collect(env.as_ref(), 10, 60, 0xAB1);
    // train briefly so the ablated tensors have realistic statistics
    let mut s = TrainSession::new(
        ds,
        TrainConfig { steps: 100, eval_every: usize::MAX, ..Default::default() },
    );
    s.run();
    let w = &s.mlp.weights[1]; // a hidden 256x256 weight
    let mut t = Table::new(
        "Ablation - square block size k (weights of trained pusher MLP, MXINT8)",
        &["k", "elems/block", "bits/elem", "weight MSE", "MX-standard"],
    );
    for (k, bpe, mse, ok) in ablate(w, ElementFormat::Int8, &[2, 4, 8, 16, 32]) {
        t.row(vec![
            k.to_string(),
            (k * k).to_string(),
            f(bpe, 3),
            format!("{mse:.3e}"),
            if ok { "yes".into() } else { "no".into() },
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_has_model_and_paper_rows() {
        let t = table2();
        assert_eq!(t.rows.len(), 6); // 3 variants x (model + paper)
    }

    #[test]
    fn table3_has_nine_rows() {
        let t = table3();
        assert_eq!(t.rows.len(), 9);
        // our batch-32 total ~179.8
        let ours32: f64 = t.rows[5][8].parse().unwrap();
        assert!((ours32 - 179.8).abs() < 1.0, "{ours32}");
    }

    #[test]
    fn table4_latency_rows_show_speedup() {
        let t = table4();
        let lat_row = t.rows.iter().find(|r| r[0].starts_with("latency MXINT8")).unwrap();
        let ours: f64 = lat_row[1].parse().unwrap();
        let dac: f64 = lat_row[2].parse().unwrap();
        assert!(dac / ours > 2.5, "{ours} vs {dac}");
    }

    #[test]
    fn fig7_breakdown_totals_positive() {
        let (e, a) = fig7();
        assert!(e.rows.len() >= 8);
        assert!(a.rows.len() == 8);
    }

    #[test]
    fn precision_schedule_wins_the_budget_race() {
        // the acceptance shape at test size: under one accelerator time
        // budget the scheduled run must (a) complete >= 1.5x the steps
        // per microsecond of static-MXINT8 (which is both the highest-
        // precision and the analytically slowest mode), and (b) use
        // those extra steps to reach a lower final eval loss
        let (t, doc) = precision_schedule_report(40, Some(vec![32, 48, 48, 32])).unwrap();
        assert_eq!(t.rows.len(), 2 + 3, "static + scheduled + 3 segments");
        let race = doc.get("race").expect("race section");
        let speedup = race
            .get("throughput_speedup_analytic")
            .and_then(|v| v.as_f64())
            .expect("speedup");
        assert!(speedup >= 1.5, "scheduled must beat the 1.5x floor: {speedup}");
        assert_eq!(race.get("meets_1p5x_floor").and_then(|v| v.as_bool()), Some(true));
        let static_loss =
            race.get("loss_static_int8").and_then(|v| v.as_f64()).expect("static loss");
        let sched_loss = race.get("loss_scheduled").and_then(|v| v.as_f64()).expect("sched loss");
        assert!(static_loss.is_finite() && sched_loss.is_finite());
        assert!(
            sched_loss < static_loss,
            "budgeted scheduling must win the loss race: {sched_loss} vs {static_loss}"
        );
        let sched = doc.get("scheduled").expect("scheduled section");
        let steps = sched.get("steps").and_then(|v| v.as_f64()).unwrap() as usize;
        assert!(steps > 40, "same budget must buy more scheduled steps: {steps}");
    }

    #[test]
    fn sw_wallclock_backends_stay_bit_identical() {
        // the measured fast-vs-packed table must report identical losses
        // on every row — speed is the only thing allowed to differ
        let t = sw_backend_wallclock(2).unwrap();
        assert_eq!(t.rows.len(), 3);
        for r in &t.rows {
            assert_eq!(r[5], "yes", "{r:?}");
        }
    }
}
