//! # mxscale
//!
//! Reproduction of *"Efficient Precision-Scalable Hardware for Microscaling
//! (MX) Processing in Robotics Learning"* (ISLPED 2025, Cuyckens et al.).
//!
//! The crate implements, in software, every system the paper describes:
//!
//! * [`mx`] — bit-exact codecs for all six OCP MX formats (MXINT8,
//!   MXFP8 E5M2/E4M3, MXFP6 E3M2/E2M3, MXFP4 E2M1), vector (32-element)
//!   and square (8x8, 64-element) shared-exponent block quantizers, the
//!   Dacapo MX9/MX6/MX4 two-level shared-microexponent baseline, and
//!   [`mx::packed`] — sub-word-parallel bit-packed tensors with SWAR
//!   dot-product/GeMM kernels (the paper's sub-word parallelism,
//!   executed in software).
//! * [`arith`] — a bit-exact, cycle-annotated model of the paper's
//!   precision-scalable MAC unit: sixteen 2-bit multipliers, the
//!   hierarchical L1/L2 adders, FP32 accumulation with a 26(+2)-bit
//!   mantissa datapath, and the mode-specific bypass network.
//! * [`pearray`] — the 64-MAC square-block PE array (8/2/1 cycles per
//!   block product in INT8/FP8-FP6/FP4 mode) plus a cycle-accurate
//!   Dacapo-style weight-stationary systolic array baseline.
//! * [`gemmcore`] — the learning-enabled 4x16 GeMM core: output-stationary
//!   dataflow, 5280 bit/cycle bandwidth model, quantizer unit, and the
//!   forward / backward / weight-gradient execution schedules.
//! * [`energy`] — component-level area/energy models for both designs,
//!   calibrated per the paper's synthesis data (TSMC 16nm, 500 MHz);
//!   regenerates Tables II-IV and Fig. 7.
//! * [`workloads`] — the four robotics dynamics-learning workloads
//!   (cartpole, pusher, reacher, halfcheetah) as deterministic physics
//!   simulators producing (state, action) -> next-state datasets.
//! * [`trainer`] — the continual-learning loop: MX quantization-aware
//!   training of the 4-layer dynamics MLP, with per-step latency/energy
//!   accounting on the simulated hardware; regenerates Figs. 2 and 8.
//!   Sessions checkpoint MX-natively ([`trainer::checkpoint`]): the
//!   quantized weight image (square groups single-copy on disk) plus a
//!   bit-exact FP32 master/optimizer sidecar.
//! * [`fleet`] — the multi-tenant continual-learning layer: a
//!   round-robin scheduler multiplexing many concurrent sessions
//!   ("robots") over the worker pool with per-session step/energy
//!   budgets and mid-run domain-shift events, where sessions adapt from
//!   their checkpoint instead of retraining (`mxscale fleet`,
//!   `results/fleet_report.json`). Sessions are built through the
//!   [`fleet::SessionSpec`] builder, validated once at `build()`.
//! * [`serve`] — the open-stream serving front-end over the fleet:
//!   sessions arrive continuously with priorities and budgets, an
//!   [`serve::Admission`] policy admits/parks/sheds them before step
//!   latency collapses, and a dep-less work-stealing executor
//!   (per-worker deques + steal over [`util::par::WorkStealQueues`])
//!   runs them in quanta with checkpoint-on-evict through [`store`] —
//!   every session bit-identical to a standalone run (`mxscale serve
//!   --load`, `BENCH_serve.json`, DESIGN.md §12).
//! * [`chaos`] — deterministic fault injection: a seeded [`chaos::FaultPlan`]
//!   drives bit flips in packed MX blocks, torn shard appends, corrupt
//!   chunks, stale writer locks, and mid-quantum worker crashes/panics,
//!   with every fault ending in a [`chaos::FaultOutcome`] — a structured
//!   error naming the exact site, or a recovery *proven* bit-identical
//!   to the fault-free twin (`mxscale fleet --chaos`, `mxscale serve
//!   --chaos`, `tests/chaos.rs`, DESIGN.md §13).
//! * [`backend`] — the pluggable `ExecBackend` seam between the trainer
//!   and the hardware model: the fast buffer-reusing fake-quant path,
//!   the bit-exact `GemmCore` path (accumulating a per-session
//!   `HwCostReport` — cycles, events, energy, memory traffic), and the
//!   packed SWAR path all produce bit-identical training-graph values.
//! * [`runtime`] — PJRT/XLA execution of AOT-compiled JAX train/eval
//!   graphs (`artifacts/*.hlo.txt`); Python never runs at training time.
//!   Gated behind the `xla` cargo feature (graceful stubs otherwise).
//! * [`store`] — the sharded, partially-readable checkpoint store: a
//!   dependency-free `Storage` trait (filesystem now, object-store
//!   pluggable later), per-section/per-tensor chunking of each
//!   checkpoint, and a sharding container packing thousands of robots
//!   into a few shard files with trailing indexes — a resume reads the
//!   index plus one session's chunks, bit-exactly, with legacy
//!   `.mxckpt` files still readable through a compat shim (`mxscale
//!   fleet --store`, DESIGN.md §11).
//! * [`coordinator`] — experiment configs, the CLI, and the per-table /
//!   per-figure reproduction harnesses.
//! * [`lint`] — `mxlint`, the dependency-free static-analysis pass
//!   enforcing the contracts above (serial twins, exact exponent math,
//!   checkpoint layout versioning, schema-stamped reports; DESIGN.md §9)
//!   as a CI gate via the `mxlint` binary.
//!
//! The hot path — block quantization, the PE-array walk, the QAT sweep —
//! runs on a batched parallel engine ([`util::par`], rayon-style
//! fork-join honoring `RAYON_NUM_THREADS`): MX blocks, output tiles, and
//! training runs are independent by construction, so every parallel
//! result is bit-identical to the serial reference (`tests/parallel.rs`
//! asserts it).
//!
//! See `DESIGN.md` (repo root) for the system inventory and the
//! paper-to-module map, and `EXPERIMENTS.md` for how to regenerate every
//! table and figure plus the benchmark methodology.

pub mod arith;
pub mod backend;
pub mod chaos;
pub mod coordinator;
pub mod energy;
pub mod fleet;
pub mod gemmcore;
pub mod lint;
pub mod mx;
pub mod pearray;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod store;
pub mod trainer;
pub mod util;
pub mod workloads;

pub use mx::{ElementFormat, MxFormat};
