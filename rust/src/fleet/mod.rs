//! The continual-learning fleet layer: many robots, one machine.
//!
//! The paper's premise is *continual* learning at the edge — Dacapo-class
//! processors retrain on-device as the environment shifts. This module
//! scales that premise out: a [`FleetScheduler`] multiplexes many
//! concurrent [`crate::trainer::TrainSession`]s ("robots") over the
//! [`crate::util::par`] worker pool in round-robin step quanta, each
//! session carrying its own step/energy budget (priced by
//! [`crate::trainer::budget::step_cost`], plus the measured
//! [`crate::backend::HwCostReport`] ledger on the hardware backend) and
//! its own queue of **domain-shift events**. When a shift fires, the
//! session checkpoints (MX-native, square groups single-copy —
//! [`crate::trainer::checkpoint`]), the dataset is swapped for the
//! perturbed-physics variant ([`crate::workloads::shifted_by_name`]),
//! and training resumes *from the checkpoint* — demonstrating adaptation
//! instead of retraining from scratch, which [`report::adapt_vs_retrain`]
//! quantifies head-to-head.
//!
//! Persistence: with a [`StoreSpec`] attached (`mxscale fleet --store`),
//! shift checkpoints round-trip through the sharded
//! [`crate::store::CheckpointStore`] — save, partial read-back, resume,
//! bit-exact — and every robot's final state is batch-persisted into a
//! handful of shard files at the end of the run.
//!
//! Determinism: sessions are mutually independent and internally seeded,
//! so a fleet run is bit-identical to running its sessions one at a time
//! (asserted by `scheduler::tests`), and block-level parallelism inside
//! each session degrades to serial on fleet workers (no nested forks).
//!
//! Construction: every [`FleetSession`] is built through the
//! [`SessionSpec`] builder (`SessionSpec::new(..).policy(..).store(..)
//! .budget(..).build()?`), which validates the whole bundle once at
//! `build()`. The open-stream serving layer ([`crate::serve`]) admits
//! the same specs and evicts sessions back *into* specs
//! ([`FleetSession::evict`]) for checkpoint-backed re-admission.
//!
//! Entry points: `mxscale fleet` (CLI), `examples/fleet_adapt.rs`, and
//! [`report::run_fleet`] which both share — it writes
//! `results/fleet_report.json`.

pub mod report;
pub mod scheduler;
pub mod spec;

pub use report::{
    adapt_vs_retrain, run_fleet, AdaptComparison, FleetRun, FleetSpec, SessionSummary, StoreSpec,
};
pub use scheduler::{
    DomainShift, FleetScheduler, FleetSession, FleetStats, FormatSpend, SessionBudget, ShiftRecord,
};
pub use spec::SessionSpec;
