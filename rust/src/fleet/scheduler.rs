//! Round-robin multiplexing of training sessions over the worker pool.

#![forbid(unsafe_code)]

use crate::store::CheckpointStore;
use crate::trainer::budget::step_cost_for;
use crate::trainer::checkpoint::Checkpoint;
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainConfig, TrainError, TrainSession};
use crate::util::par;
use crate::workloads::Dataset;
use std::sync::Arc;
use std::time::Instant;

/// What a fleet session is allowed to consume before it parks.
#[derive(Debug, Clone, Copy)]
pub struct SessionBudget {
    /// Training-step ceiling.
    pub max_steps: usize,
    /// Accelerator-energy ceiling [uJ], priced per step by the analytic
    /// model ([`step_cost_for`]); `f64::INFINITY` disables it.
    pub max_energy_uj: f64,
}

impl SessionBudget {
    /// Step budget only.
    pub fn steps(max_steps: usize) -> Self {
        Self { max_steps, max_energy_uj: f64::INFINITY }
    }
}

/// A scheduled environment change: at `at_step`, the session checkpoints
/// and resumes on `dataset`.
#[derive(Debug, Clone)]
pub struct DomainShift {
    pub at_step: usize,
    pub label: String,
    pub dataset: Dataset,
}

/// What happened at one domain-shift event.
#[derive(Debug, Clone)]
pub struct ShiftRecord {
    pub at_step: usize,
    pub label: String,
    /// Bytes of the MX weight image in the shift checkpoint.
    pub payload_bytes: usize,
    /// Bytes of the full serialized checkpoint file.
    pub total_bytes: usize,
    /// Validation loss of the pre-shift model on the *new* dataset —
    /// how much the domain shift broke the model.
    pub val_before: f64,
    /// The checkpoint taken at the shift (kept for adapt-vs-retrain
    /// analysis; not serialized into reports).
    pub checkpoint: Checkpoint,
}

/// Analytic energy/steps attributed to one scheme a session ran under
/// (the per-format-segment accounting of a precision-scheduled robot).
#[derive(Debug, Clone)]
pub struct FormatSpend {
    /// Scheme name (e.g. "mx-e2m1").
    pub scheme: String,
    /// Steps executed under this scheme.
    pub steps: usize,
    /// Analytic energy those steps cost [uJ].
    pub uj: f64,
}

/// One robot: a training session plus its budget, shift schedule, and
/// (optionally) a per-robot precision policy.
pub struct FleetSession {
    pub id: String,
    pub workload: String,
    session: TrainSession,
    pub budget: SessionBudget,
    /// Pending shifts, ascending by `at_step`.
    shifts: Vec<DomainShift>,
    /// Per-robot precision policy (static by default).
    policy: PrecisionPolicy,
    /// Analytic energy consumed so far [uJ].
    pub energy_uj: f64,
    /// Per-step energy price under this session's **active** scheme
    /// [uJ] — repriced whenever the policy transitions.
    pub step_uj: f64,
    /// Scheme the current `step_uj` was priced for.
    priced_scheme: QuantScheme,
    /// Analytic energy/steps per scheme the session has run under.
    pub format_spend: Vec<FormatSpend>,
    pub shift_log: Vec<ShiftRecord>,
    /// Measured hw-backend energy of completed (pre-shift) segments
    /// [uJ] — the checkpoint does not carry the cost ledger, so the
    /// scheduler accumulates it across resumes itself.
    hw_uj_carried: f64,
    /// Checkpoint store this robot persists through. When attached, a
    /// domain shift saves the checkpoint *into the store* and resumes
    /// from a store read-back (partial read under a sharded layout), so
    /// the fleet's save→resume cycle exercises the real persistence
    /// path; `None` keeps the in-memory handoff.
    store: Option<Arc<CheckpointStore>>,
    /// Steps executed in the most recent quantum (scheduler bookkeeping).
    last_ran: usize,
    /// First error this session hit mid-run (a failed shift resume or a
    /// rejected policy transition). An errored session parks — `done()`
    /// turns true and further quanta run nothing — instead of panicking
    /// the whole fleet round.
    error: Option<TrainError>,
}

impl FleetSession {
    pub fn new(
        id: impl Into<String>,
        workload: impl Into<String>,
        dataset: Dataset,
        config: TrainConfig,
        budget: SessionBudget,
        mut shifts: Vec<DomainShift>,
    ) -> Result<Self, TrainError> {
        shifts.sort_by_key(|s| s.at_step);
        let session = TrainSession::try_new(dataset, config)?;
        // price steps for the *actual* MLP shape (dims-aware, so a
        // --hidden override doesn't get billed for the paper MLP)
        let step_uj = step_cost_for(
            session.config.scheme,
            session.config.batch_size,
            session.dims(),
        )
        .microjoules;
        // shift datasets must fit the session's IO widths — reject now
        // instead of panicking when the shift fires mid-run
        let dims = session.dims();
        let (din, dout) = (dims[0], dims[dims.len() - 1]);
        for s in &shifts {
            if s.dataset.train_x.cols != din || s.dataset.train_y.cols != dout {
                return Err(TrainError::BadConfig {
                    reason: format!(
                        "shift `{}` dataset is {}/{} wide, session expects {din}/{dout}",
                        s.label, s.dataset.train_x.cols, s.dataset.train_y.cols
                    ),
                });
            }
        }
        let priced_scheme = session.config.scheme;
        Ok(Self {
            id: id.into(),
            workload: workload.into(),
            session,
            budget,
            shifts,
            policy: PrecisionPolicy::Static,
            energy_uj: 0.0,
            step_uj,
            priced_scheme,
            format_spend: Vec::new(),
            shift_log: Vec::new(),
            hw_uj_carried: 0.0,
            store: None,
            last_ran: 0,
            error: None,
        })
    }

    /// Attach a per-robot precision policy. Every scheme the policy can
    /// reach is validated against the session's backend now, so a
    /// mismatch is a structured construction error instead of a panic
    /// mid-quantum.
    pub fn with_policy(mut self, policy: PrecisionPolicy) -> Result<Self, TrainError> {
        let backend = self.session.config.backend;
        policy.validate(backend).map_err(|reason| TrainError::BadConfig { reason })?;
        policy
            .validate_start(self.session.config.scheme)
            .map_err(|reason| TrainError::BadConfig { reason })?;
        self.policy = policy;
        Ok(self)
    }

    /// Persist this robot's shift checkpoints through `store` (shared
    /// across the fleet — [`CheckpointStore`] is cheap to clone and its
    /// backend is `Send + Sync`).
    pub fn with_store(mut self, store: Arc<CheckpointStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// The wrapped session (read access for reports).
    pub fn session(&self) -> &TrainSession {
        &self.session
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.session.step_count()
    }

    /// Whether some budget dimension is exhausted — or the session hit
    /// a mid-run error ([`FleetSession::error`]) — and the session parks.
    pub fn done(&self) -> bool {
        self.error.is_some()
            || self.steps_done() >= self.budget.max_steps
            || self.energy_uj >= self.budget.max_energy_uj
    }

    /// The error that parked this session, if any.
    pub fn error(&self) -> Option<&TrainError> {
        self.error.as_ref()
    }

    /// Measured accelerator energy across every segment of this session
    /// [uJ] — resume replaces the backend (and its ledger), so pre-shift
    /// segments are summed from `hw_uj_carried`. None on the fast
    /// backend, which measures nothing.
    pub fn hw_measured_uj(&self) -> Option<f64> {
        self.session.hw_report().map(|r| r.uj_total() + self.hw_uj_carried)
    }

    /// Fire a pending shift scheduled at (or before) the current step:
    /// checkpoint, swap the dataset, resume from the checkpoint.
    fn fire_shift(&mut self, shift: DomainShift) -> Result<(), TrainError> {
        // bank the finished segment's measured ledger before the
        // resumed session starts a fresh one
        if let Some(r) = self.session.hw_report() {
            self.hw_uj_carried += r.uj_total();
        }
        let ck = self.session.save_checkpoint();
        // through the store when attached: persist, read back (a
        // partial read under a sharded layout), resume from the bytes
        // that actually hit storage — bit-exact by the store contract
        let resumed = match &self.store {
            Some(store) => {
                store.save(&self.id, &ck)?;
                let reread = store.load(&self.id)?;
                TrainSession::resume(shift.dataset, &reread)?
            }
            None => TrainSession::resume(shift.dataset, &ck)?,
        };
        let val_before = resumed.val_loss();
        self.shift_log.push(ShiftRecord {
            at_step: shift.at_step,
            label: shift.label,
            payload_bytes: ck.payload_bytes(),
            total_bytes: ck.to_bytes().len(),
            val_before,
            checkpoint: ck,
        });
        self.session = resumed;
        Ok(())
    }

    /// Run up to `quantum` training steps, honoring budgets, firing due
    /// shifts, and letting the per-robot policy transition precision.
    /// Every step is priced (and its energy attributed) under the
    /// scheme it actually ran at. Returns the steps executed.
    pub fn run_quantum(&mut self, quantum: usize) -> usize {
        let mut ran = 0;
        while ran < quantum && !self.done() {
            if self.shifts.first().is_some_and(|s| self.steps_done() >= s.at_step) {
                let shift = self.shifts.remove(0);
                if let Err(e) = self.fire_shift(shift) {
                    self.error = Some(e);
                    break;
                }
                continue;
            }
            // policy schemes were validated against this backend at
            // attach time, so this only fails on a logic error — park
            // the session and surface it instead of panicking the round
            if let Err(e) = self.session.step_with_policy(&mut self.policy) {
                self.error = Some(e);
                break;
            }
            // the step ran under the (possibly just-transitioned)
            // active scheme: reprice if it changed, then attribute
            let scheme = self.session.config.scheme;
            if scheme != self.priced_scheme {
                self.step_uj =
                    step_cost_for(scheme, self.session.config.batch_size, self.session.dims())
                        .microjoules;
                self.priced_scheme = scheme;
            }
            self.energy_uj += self.step_uj;
            let name = scheme.name();
            match self.format_spend.iter_mut().find(|f| f.scheme == name) {
                Some(f) => {
                    f.steps += 1;
                    f.uj += self.step_uj;
                }
                None => {
                    self.format_spend.push(FormatSpend { scheme: name, steps: 1, uj: self.step_uj })
                }
            }
            ran += 1;
        }
        self.last_ran = ran;
        ran
    }
}

/// Aggregate outcome of a fleet run.
#[derive(Debug, Clone, Copy)]
pub struct FleetStats {
    /// Round-robin passes that made progress.
    pub rounds: usize,
    /// Training steps executed across all sessions.
    pub total_steps: usize,
    /// Host wall-clock of the run [s].
    pub wall_s: f64,
}

impl FleetStats {
    /// Effective fleet throughput [training steps / host second].
    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_steps as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Multiplexes [`FleetSession`]s over the worker pool: each round hands
/// every live session one `quantum` of steps, sessions running
/// concurrently (they share nothing), rounds repeating until every
/// budget is exhausted.
pub struct FleetScheduler {
    pub quantum: usize,
    sessions: Vec<FleetSession>,
}

impl FleetScheduler {
    pub fn new(quantum: usize) -> Self {
        Self { quantum: quantum.max(1), sessions: Vec::new() }
    }

    pub fn push(&mut self, session: FleetSession) {
        self.sessions.push(session);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn sessions(&self) -> &[FleetSession] {
        &self.sessions
    }

    /// One round-robin pass: every live session gets up to `quantum`
    /// steps, sessions running in parallel. Returns total steps run.
    pub fn run_round(&mut self) -> usize {
        let quantum = self.quantum;
        par::par_chunks_mut(&mut self.sessions, 1, 2, |_, chunk| {
            chunk[0].run_quantum(quantum);
        });
        self.sessions.iter().map(|s| s.last_ran).sum()
    }

    /// Round-robin until every session's budget is exhausted.
    pub fn run(&mut self) -> FleetStats {
        let t0 = Instant::now();
        let mut rounds = 0;
        let mut total_steps = 0;
        loop {
            let ran = self.run_round();
            if ran == 0 {
                break;
            }
            rounds += 1;
            total_steps += ran;
        }
        FleetStats { rounds, total_steps, wall_s: t0.elapsed().as_secs_f64() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::mx::element::ElementFormat;
    use crate::trainer::qat::QuantScheme;
    use crate::workloads::{by_name, shifted_by_name};

    fn quick_dataset(name: &str, seed: u64) -> Dataset {
        let env = by_name(name).unwrap();
        Dataset::collect(env.as_ref(), 4, 40, seed)
    }

    fn quick_config(scheme: QuantScheme, steps: usize) -> TrainConfig {
        TrainConfig {
            scheme,
            dims: Some(vec![32, 24, 32]),
            steps,
            eval_every: 10,
            ..Default::default()
        }
    }

    #[test]
    fn fleet_is_bit_identical_to_standalone_sessions() {
        let schemes = [
            QuantScheme::Fp32,
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxSquare(ElementFormat::E4M3),
        ];
        // standalone reference runs
        let reference: Vec<f64> = schemes
            .iter()
            .map(|&scheme| {
                let mut s =
                    TrainSession::new(quick_dataset("cartpole", 7), quick_config(scheme, 30));
                for _ in 0..30 {
                    s.step_once();
                }
                s.val_loss()
            })
            .collect();
        // the same runs through the round-robin scheduler
        let mut sched = FleetScheduler::new(4);
        for (i, &scheme) in schemes.iter().enumerate() {
            sched.push(
                FleetSession::new(
                    format!("robot-{i}"),
                    "cartpole",
                    quick_dataset("cartpole", 7),
                    quick_config(scheme, 30),
                    SessionBudget::steps(30),
                    Vec::new(),
                )
                .unwrap(),
            );
        }
        let stats = sched.run();
        assert_eq!(stats.total_steps, 90);
        assert_eq!(stats.rounds, 30usize.div_ceil(4));
        for (s, want) in sched.sessions().iter().zip(&reference) {
            assert_eq!(s.steps_done(), 30);
            assert_eq!(s.session().val_loss(), *want, "{}", s.id);
        }
    }

    #[test]
    fn energy_budget_parks_a_session_early() {
        let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
        // priced for the session's actual dims, not the paper MLP
        let per_step = step_cost_for(scheme, 32, &[32, 24, 32]).microjoules;
        let budget = SessionBudget {
            max_steps: 1000,
            max_energy_uj: per_step * 7.5, // room for exactly 8 steps
        };
        let mut s = FleetSession::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 1),
            quick_config(scheme, 1000),
            budget,
            Vec::new(),
        )
        .unwrap();
        let ran = s.run_quantum(100);
        assert_eq!(ran, 8, "energy ceiling must stop the quantum");
        assert!(s.done());
        assert_eq!(s.run_quantum(100), 0);
    }

    #[test]
    fn mismatched_shift_dataset_is_rejected_at_construction() {
        let mut bad = quick_dataset("cartpole", 3);
        bad.train_y.cols = 16; // deliberately malformed target width
        let r = FleetSession::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 3),
            quick_config(QuantScheme::Fp32, 10),
            SessionBudget::steps(10),
            vec![DomainShift { at_step: 5, label: "bad".into(), dataset: bad }],
        );
        assert!(matches!(r, Err(TrainError::BadConfig { .. })));
    }

    #[test]
    fn domain_shift_checkpoints_and_resumes() {
        let shifted_env = shifted_by_name("cartpole").unwrap();
        let shifted = Dataset::collect(shifted_env.as_ref(), 4, 40, 9);
        let mut s = FleetSession::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 9),
            quick_config(QuantScheme::MxSquare(ElementFormat::Int8), 40),
            SessionBudget::steps(40),
            vec![DomainShift { at_step: 20, label: "heavier-pole".into(), dataset: shifted }],
        )
        .unwrap();
        while s.run_quantum(6) > 0 {}
        assert_eq!(s.steps_done(), 40);
        assert_eq!(s.shift_log.len(), 1);
        let rec = &s.shift_log[0];
        assert_eq!(rec.at_step, 20);
        assert_eq!(rec.checkpoint.step, 20);
        assert!(rec.payload_bytes > 0, "square MX image must be present");
        assert!(rec.total_bytes > rec.payload_bytes);
        assert!(rec.val_before.is_finite());
        // the session now trains the shifted dataset, curves intact
        assert_eq!(s.session().dataset.name, "cartpole");
        assert!(s.session().train_curve.iter().any(|&(step, _)| step < 20));
        assert!(s.session().train_curve.iter().any(|&(step, _)| step >= 20));
        // fast backend measures nothing
        assert!(s.hw_measured_uj().is_none());
    }

    #[test]
    fn policy_repriced_steps_attribute_energy_per_format() {
        // a scheduled robot: e2m1 for steps 0..10, int8 after — energy
        // must be priced per segment and attributed to each format
        let scheme = QuantScheme::MxSquare(ElementFormat::E2M1);
        let mut s = FleetSession::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 5),
            quick_config(scheme, 20),
            SessionBudget::steps(20),
            Vec::new(),
        )
        .unwrap()
        .with_policy(PrecisionPolicy::parse("10:mx-int8").unwrap())
        .unwrap();
        while s.run_quantum(7) > 0 {}
        assert_eq!(s.steps_done(), 20);
        assert_eq!(s.session().scheme_history().len(), 2);
        assert_eq!(s.format_spend.len(), 2);
        let e2m1 = &s.format_spend[0];
        let int8 = &s.format_spend[1];
        assert_eq!((e2m1.scheme.as_str(), e2m1.steps), ("mx-e2m1", 10));
        assert_eq!((int8.scheme.as_str(), int8.steps), ("mx-int8", 10));
        // int8 steps are analytically dearer than e2m1 steps (8 vs 1
        // cycles/block), and the total must be the sum of the segments
        assert!(int8.uj > e2m1.uj, "int8 {} vs e2m1 {}", int8.uj, e2m1.uj);
        let total: f64 = s.format_spend.iter().map(|f| f.uj).sum();
        assert!((total - s.energy_uj).abs() < 1e-9 * total.max(1.0));
    }

    #[test]
    fn store_attached_shift_is_bit_identical_to_in_memory_handoff() {
        use crate::store::{CheckpointStore, MemoryStore, StoreLayout};
        let build = |store: Option<Arc<CheckpointStore>>| {
            let shifted_env = shifted_by_name("cartpole").unwrap();
            let shifted = Dataset::collect(shifted_env.as_ref(), 4, 40, 9);
            let mut s = FleetSession::new(
                "r0",
                "cartpole",
                quick_dataset("cartpole", 9),
                quick_config(QuantScheme::MxSquare(ElementFormat::E2M1), 30),
                SessionBudget::steps(30),
                vec![DomainShift { at_step: 15, label: "shift".into(), dataset: shifted }],
            )
            .unwrap();
            if let Some(store) = store {
                s = s.with_store(store);
            }
            while s.run_quantum(7) > 0 {}
            assert!(s.error().is_none(), "{:?}", s.error());
            s
        };
        let reference = build(None);
        let store = Arc::new(CheckpointStore::new(
            Arc::new(MemoryStore::new()),
            StoreLayout::Sharded { shards: 2 },
        ));
        let through_store = build(Some(store.clone()));
        assert_eq!(reference.session().val_loss(), through_store.session().val_loss());
        assert_eq!(
            reference.session().train_curve,
            through_store.session().train_curve,
            "resume through the store must be bitwise indistinguishable"
        );
        // the shift checkpoint is now readable from the store too
        assert_eq!(store.load("r0").unwrap().step, 15);
    }

    #[test]
    fn policy_backend_mismatch_is_rejected_at_attach() {
        let s = FleetSession::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 6),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                backend: BackendKind::Packed,
                dims: Some(vec![32, 24, 32]),
                steps: 10,
                eval_every: 10,
                ..Default::default()
            },
            SessionBudget::steps(10),
            Vec::new(),
        )
        .unwrap();
        let r = s.with_policy(PrecisionPolicy::parse("5:mxvec-int8").unwrap());
        assert!(matches!(r, Err(TrainError::BadConfig { .. })));
    }

    #[test]
    fn hw_measured_energy_carries_across_a_shift() {
        // resume replaces the hw backend (fresh cost ledger); the fleet
        // session must keep accounting the pre-shift segment
        let shifted_env = shifted_by_name("cartpole").unwrap();
        let shifted = Dataset::collect(shifted_env.as_ref(), 3, 30, 11);
        let config = TrainConfig {
            scheme: QuantScheme::MxSquare(ElementFormat::E2M1),
            backend: BackendKind::Hardware,
            dims: Some(vec![32, 8, 32]),
            batch_size: 8,
            steps: 8,
            eval_every: usize::MAX,
            ..Default::default()
        };
        let mut s = FleetSession::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 11),
            config,
            SessionBudget::steps(8),
            vec![DomainShift { at_step: 4, label: "shift".into(), dataset: shifted }],
        )
        .unwrap();
        while s.run_quantum(3) > 0 {}
        assert_eq!(s.steps_done(), 8);
        let total = s.hw_measured_uj().unwrap();
        let post_shift_only = s.session().hw_report().unwrap().uj_total();
        assert!(
            total > post_shift_only && post_shift_only > 0.0,
            "pre-shift ledger must be carried: total {total} vs post-shift {post_shift_only}"
        );
    }
}
