//! Round-robin multiplexing of training sessions over the worker pool.
//!
//! Sessions are constructed through [`crate::fleet::SessionSpec`] (the
//! single validated builder); this module owns what a session *is* once
//! built and how a fixed roster of them is multiplexed.

#![forbid(unsafe_code)]

use crate::fleet::spec::SessionSpec;
use crate::store::CheckpointStore;
use crate::trainer::budget::step_cost_for;
use crate::trainer::checkpoint::Checkpoint;
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainError, TrainSession};
use crate::util::par;
use crate::workloads::Dataset;
use std::sync::Arc;
use std::time::Instant;

/// What a fleet session is allowed to consume before it parks.
#[derive(Debug, Clone, Copy)]
pub struct SessionBudget {
    /// Training-step ceiling.
    pub max_steps: usize,
    /// Accelerator-energy ceiling [uJ], priced per step by the analytic
    /// model ([`step_cost_for`]); `f64::INFINITY` disables it.
    pub max_energy_uj: f64,
}

impl SessionBudget {
    /// Step budget only.
    pub fn steps(max_steps: usize) -> Self {
        Self { max_steps, max_energy_uj: f64::INFINITY }
    }
}

/// A scheduled environment change: at `at_step`, the session checkpoints
/// and resumes on `dataset`.
#[derive(Debug, Clone)]
pub struct DomainShift {
    pub at_step: usize,
    pub label: String,
    pub dataset: Dataset,
}

/// What happened at one domain-shift event.
#[derive(Debug, Clone)]
pub struct ShiftRecord {
    pub at_step: usize,
    pub label: String,
    /// Bytes of the MX weight image in the shift checkpoint.
    pub payload_bytes: usize,
    /// Bytes of the full serialized checkpoint file.
    pub total_bytes: usize,
    /// Validation loss of the pre-shift model on the *new* dataset —
    /// how much the domain shift broke the model.
    pub val_before: f64,
    /// The checkpoint taken at the shift (kept for adapt-vs-retrain
    /// analysis; not serialized into reports).
    pub checkpoint: Checkpoint,
}

/// Analytic energy/steps attributed to one scheme a session ran under
/// (the per-format-segment accounting of a precision-scheduled robot).
#[derive(Debug, Clone)]
pub struct FormatSpend {
    /// Scheme name (e.g. "mx-e2m1").
    pub scheme: String,
    /// Steps executed under this scheme.
    pub steps: usize,
    /// Analytic energy those steps cost [uJ].
    pub uj: f64,
}

/// Fleet-level accounting that survives an eviction. The checkpoint
/// carries the model/optimizer/curve state; this ledger carries what the
/// scheduler knows *around* the session — analytic energy spent,
/// per-format spend, the shift history, and banked hw measurements —
/// so an evict→re-admit cycle reports identically to an uninterrupted
/// run. Filled only by [`FleetSession::evict`].
#[derive(Debug, Clone, Default)]
pub(crate) struct CarriedLedger {
    pub(crate) energy_uj: f64,
    pub(crate) format_spend: Vec<FormatSpend>,
    pub(crate) shift_log: Vec<ShiftRecord>,
    pub(crate) hw_uj_carried: f64,
}

/// One robot: a training session plus its budget, shift schedule, and
/// (optionally) a per-robot precision policy.
pub struct FleetSession {
    pub id: String,
    pub workload: String,
    session: TrainSession,
    pub budget: SessionBudget,
    /// Serving priority (higher dispatches sooner under contention);
    /// set through [`SessionSpec::priority`], ignored by the
    /// round-robin [`FleetScheduler`].
    pub priority: u8,
    /// Pending shifts, ascending by `at_step`.
    shifts: Vec<DomainShift>,
    /// Per-robot precision policy (static by default).
    policy: PrecisionPolicy,
    /// Analytic energy consumed so far [uJ].
    pub energy_uj: f64,
    /// Per-step energy price under this session's **active** scheme
    /// [uJ] — repriced whenever the policy transitions.
    pub step_uj: f64,
    /// Scheme the current `step_uj` was priced for.
    priced_scheme: QuantScheme,
    /// Analytic energy/steps per scheme the session has run under.
    pub format_spend: Vec<FormatSpend>,
    pub shift_log: Vec<ShiftRecord>,
    /// Measured hw-backend energy of completed (pre-shift) segments
    /// [uJ] — the checkpoint does not carry the cost ledger, so the
    /// scheduler accumulates it across resumes itself.
    hw_uj_carried: f64,
    /// Checkpoint store this robot persists through. When attached, a
    /// domain shift saves the checkpoint *into the store* and resumes
    /// from a store read-back (partial read under a sharded layout), so
    /// the fleet's save→resume cycle exercises the real persistence
    /// path; `None` keeps the in-memory handoff.
    store: Option<Arc<CheckpointStore>>,
    /// Steps executed in the most recent quantum (scheduler bookkeeping).
    last_ran: usize,
    /// First error this session hit mid-run (a failed shift resume or a
    /// rejected policy transition). An errored session parks — `done()`
    /// turns true and further quanta run nothing — instead of panicking
    /// the whole fleet round.
    error: Option<TrainError>,
}

impl FleetSession {
    /// Construct from a validated [`SessionSpec`] — the only entry
    /// point (reached through [`SessionSpec::build`]). Validates the
    /// whole bundle at once: session dims, shift dataset widths, and —
    /// on the fresh path — every scheme the policy can reach against
    /// the backend, so a mismatch is a structured construction error
    /// instead of a panic mid-quantum. On the resume path the session
    /// is rebuilt from the store's checkpoint under this spec's id, and
    /// policy validation is skipped (it was validated at first build;
    /// re-checking `validate_start` against a post-transition scheme
    /// would falsely reject).
    pub(crate) fn from_spec(spec: SessionSpec) -> Result<Self, TrainError> {
        let SessionSpec {
            id,
            workload,
            dataset,
            config,
            budget,
            mut shifts,
            policy,
            store,
            priority,
            resume,
            carried,
        } = spec;
        shifts.sort_by_key(|s| s.at_step);
        let session = if resume {
            let store_ref = store.as_ref().ok_or_else(|| TrainError::BadConfig {
                reason: format!("session `{id}` resumes from the store but has none attached"),
            })?;
            let ck = store_ref.load(&id)?;
            TrainSession::resume(dataset, &ck)?
        } else {
            TrainSession::try_new(dataset, config)?
        };
        // price steps for the *actual* MLP shape (dims-aware, so a
        // --hidden override doesn't get billed for the paper MLP)
        let step_uj = step_cost_for(
            session.config.scheme,
            session.config.batch_size,
            session.dims(),
        )
        .microjoules;
        // shift datasets must fit the session's IO widths — reject now
        // instead of panicking when the shift fires mid-run
        let dims = session.dims();
        let (din, dout) = (dims[0], dims[dims.len() - 1]);
        for s in &shifts {
            if s.dataset.train_x.cols != din || s.dataset.train_y.cols != dout {
                return Err(TrainError::BadConfig {
                    reason: format!(
                        "shift `{}` dataset is {}/{} wide, session expects {din}/{dout}",
                        s.label, s.dataset.train_x.cols, s.dataset.train_y.cols
                    ),
                });
            }
        }
        let policy = match policy {
            Some(p) => {
                if !resume {
                    p.validate(session.config.backend)
                        .map_err(|reason| TrainError::BadConfig { reason })?;
                    p.validate_start(session.config.scheme)
                        .map_err(|reason| TrainError::BadConfig { reason })?;
                }
                p
            }
            None => PrecisionPolicy::Static,
        };
        let priced_scheme = session.config.scheme;
        let carried = carried.unwrap_or_default();
        Ok(Self {
            id,
            workload,
            session,
            budget,
            priority,
            shifts,
            policy,
            energy_uj: carried.energy_uj,
            step_uj,
            priced_scheme,
            format_spend: carried.format_spend,
            shift_log: carried.shift_log,
            hw_uj_carried: carried.hw_uj_carried,
            store,
            last_ran: 0,
            error: None,
        })
    }

    /// Checkpoint this session into `store` and dissolve it back into a
    /// resumable [`SessionSpec`]. Rebuilding the returned spec (its
    /// `resume` flag is set and `store` attached) yields a session
    /// whose curves continue bitwise as if it had never been evicted:
    /// the checkpoint carries the model/optimizer/curve state (store
    /// save→resume contract) and the spec carries the fleet ledger,
    /// remaining shifts, budget, policy, and priority. On a save error
    /// the session is consumed — callers that must account for it
    /// (the serving executor) clone the id first.
    pub fn evict(mut self, store: &Arc<CheckpointStore>) -> Result<SessionSpec, TrainError> {
        let ck = self.session.save_checkpoint();
        store.save(&self.id, &ck)?;
        // bank the live segment's measured hw ledger — resume replaces
        // the backend, so the next segment starts a fresh one
        if let Some(r) = self.session.hw_report() {
            self.hw_uj_carried += r.uj_total();
        }
        Ok(SessionSpec {
            id: self.id,
            workload: self.workload,
            dataset: self.session.dataset,
            config: self.session.config,
            budget: self.budget,
            shifts: self.shifts,
            policy: Some(self.policy),
            store: Some(store.clone()),
            priority: self.priority,
            resume: true,
            carried: Some(CarriedLedger {
                energy_uj: self.energy_uj,
                format_spend: self.format_spend,
                shift_log: self.shift_log,
                hw_uj_carried: self.hw_uj_carried,
            }),
        })
    }

    /// Dissolve a *crashed* session into a resumable [`SessionSpec`]
    /// **without saving anything**: the in-memory model, optimizer,
    /// curves, and the live segment's hw ledger are gone — exactly what
    /// a worker crash or a caught session panic costs. The returned
    /// spec resumes from whatever checkpoint `store` already holds
    /// under this id (the chaos admission checkpoint, or the last
    /// eviction); the deterministic trainer then re-runs the lost steps
    /// bit-identically, which is what lets the serving layer prove
    /// recovered curves equal the fault-free twin's. If the store holds
    /// no checkpoint, rebuilding the spec fails structured at
    /// `build()` — the session is lost loudly, never silently.
    pub fn crash_respec(self, store: &Arc<CheckpointStore>) -> SessionSpec {
        SessionSpec {
            id: self.id,
            workload: self.workload,
            dataset: self.session.dataset,
            config: self.session.config,
            budget: self.budget,
            shifts: self.shifts,
            policy: Some(self.policy),
            store: Some(store.clone()),
            priority: self.priority,
            resume: true,
            // the ledger restarts at the checkpoint: a crash loses the
            // segment's accounting along with its steps
            carried: None,
        }
    }

    /// The wrapped session (read access for reports).
    pub fn session(&self) -> &TrainSession {
        &self.session
    }

    /// Steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.session.step_count()
    }

    /// Whether some budget dimension is exhausted — or the session hit
    /// a mid-run error ([`FleetSession::error`]) — and the session parks.
    pub fn done(&self) -> bool {
        self.error.is_some()
            || self.steps_done() >= self.budget.max_steps
            || self.energy_uj >= self.budget.max_energy_uj
    }

    /// The error that parked this session, if any.
    pub fn error(&self) -> Option<&TrainError> {
        self.error.as_ref()
    }

    /// Measured accelerator energy across every segment of this session
    /// [uJ] — resume replaces the backend (and its ledger), so pre-shift
    /// segments are summed from `hw_uj_carried`. None on the fast
    /// backend, which measures nothing.
    pub fn hw_measured_uj(&self) -> Option<f64> {
        self.session.hw_report().map(|r| r.uj_total() + self.hw_uj_carried)
    }

    /// Fire a pending shift scheduled at (or before) the current step:
    /// checkpoint, swap the dataset, resume from the checkpoint.
    fn fire_shift(&mut self, shift: DomainShift) -> Result<(), TrainError> {
        // bank the finished segment's measured ledger before the
        // resumed session starts a fresh one
        if let Some(r) = self.session.hw_report() {
            self.hw_uj_carried += r.uj_total();
        }
        let ck = self.session.save_checkpoint();
        // through the store when attached: persist, read back (a
        // partial read under a sharded layout), resume from the bytes
        // that actually hit storage — bit-exact by the store contract
        let resumed = match &self.store {
            Some(store) => {
                store.save(&self.id, &ck)?;
                let reread = store.load(&self.id)?;
                TrainSession::resume(shift.dataset, &reread)?
            }
            None => TrainSession::resume(shift.dataset, &ck)?,
        };
        let val_before = resumed.val_loss();
        self.shift_log.push(ShiftRecord {
            at_step: shift.at_step,
            label: shift.label,
            payload_bytes: ck.payload_bytes(),
            total_bytes: ck.to_bytes().len(),
            val_before,
            checkpoint: ck,
        });
        self.session = resumed;
        Ok(())
    }

    /// Run up to `quantum` training steps, honoring budgets, firing due
    /// shifts, and letting the per-robot policy transition precision.
    /// Every step is priced (and its energy attributed) under the
    /// scheme it actually ran at. Returns the steps executed.
    pub fn run_quantum(&mut self, quantum: usize) -> usize {
        let mut ran = 0;
        while ran < quantum && !self.done() {
            if self.shifts.first().is_some_and(|s| self.steps_done() >= s.at_step) {
                let shift = self.shifts.remove(0);
                if let Err(e) = self.fire_shift(shift) {
                    self.error = Some(e);
                    break;
                }
                continue;
            }
            // policy schemes were validated against this backend at
            // attach time, so this only fails on a logic error — park
            // the session and surface it instead of panicking the round
            if let Err(e) = self.session.step_with_policy(&mut self.policy) {
                self.error = Some(e);
                break;
            }
            // the step ran under the (possibly just-transitioned)
            // active scheme: reprice if it changed, then attribute
            let scheme = self.session.config.scheme;
            if scheme != self.priced_scheme {
                self.step_uj =
                    step_cost_for(scheme, self.session.config.batch_size, self.session.dims())
                        .microjoules;
                self.priced_scheme = scheme;
            }
            self.energy_uj += self.step_uj;
            let name = scheme.name();
            match self.format_spend.iter_mut().find(|f| f.scheme == name) {
                Some(f) => {
                    f.steps += 1;
                    f.uj += self.step_uj;
                }
                None => {
                    self.format_spend.push(FormatSpend { scheme: name, steps: 1, uj: self.step_uj })
                }
            }
            ran += 1;
        }
        self.last_ran = ran;
        ran
    }
}

/// Aggregate outcome of a fleet run.
#[derive(Debug, Clone, Copy)]
pub struct FleetStats {
    /// Round-robin passes that made progress.
    pub rounds: usize,
    /// Training steps executed across all sessions.
    pub total_steps: usize,
    /// Sessions that ended parked on a mid-run error instead of
    /// exhausting their budget. A roster where every session parks
    /// "finishes" just like a healthy one (no further quantum makes
    /// progress) — this count is how callers tell the two apart, and
    /// the CLI exits nonzero when it is > 0.
    pub parked: usize,
    /// Host wall-clock of the run [s].
    pub wall_s: f64,
}

impl FleetStats {
    /// Effective fleet throughput [training steps / host second].
    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_steps as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Multiplexes [`FleetSession`]s over the worker pool: each round hands
/// every live session one `quantum` of steps, sessions running
/// concurrently (they share nothing), rounds repeating until every
/// budget is exhausted.
pub struct FleetScheduler {
    pub quantum: usize,
    sessions: Vec<FleetSession>,
}

impl FleetScheduler {
    pub fn new(quantum: usize) -> Self {
        Self { quantum: quantum.max(1), sessions: Vec::new() }
    }

    pub fn push(&mut self, session: FleetSession) {
        self.sessions.push(session);
    }

    pub fn len(&self) -> usize {
        self.sessions.len()
    }

    pub fn is_empty(&self) -> bool {
        self.sessions.is_empty()
    }

    pub fn sessions(&self) -> &[FleetSession] {
        &self.sessions
    }

    /// One round-robin pass: every live session gets up to `quantum`
    /// steps, sessions running in parallel. Returns total steps run.
    pub fn run_round(&mut self) -> usize {
        let quantum = self.quantum;
        par::par_chunks_mut(&mut self.sessions, 1, 2, |_, chunk| {
            chunk[0].run_quantum(quantum);
        });
        self.sessions.iter().map(|s| s.last_ran).sum()
    }

    /// Round-robin until every session's budget is exhausted.
    pub fn run(&mut self) -> FleetStats {
        let t0 = Instant::now();
        let mut rounds = 0;
        let mut total_steps = 0;
        loop {
            let ran = self.run_round();
            if ran == 0 {
                break;
            }
            rounds += 1;
            total_steps += ran;
        }
        let parked = self.sessions.iter().filter(|s| s.error.is_some()).count();
        FleetStats { rounds, total_steps, parked, wall_s: t0.elapsed().as_secs_f64() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::mx::element::ElementFormat;
    use crate::trainer::qat::QuantScheme;
    use crate::trainer::session::TrainConfig;
    use crate::workloads::{by_name, shifted_by_name};

    fn quick_dataset(name: &str, seed: u64) -> Dataset {
        let env = by_name(name).unwrap();
        Dataset::collect(env.as_ref(), 4, 40, seed)
    }

    fn quick_config(scheme: QuantScheme, steps: usize) -> TrainConfig {
        TrainConfig {
            scheme,
            dims: Some(vec![32, 24, 32]),
            steps,
            eval_every: 10,
            ..Default::default()
        }
    }

    #[test]
    fn fleet_is_bit_identical_to_standalone_sessions() {
        let schemes = [
            QuantScheme::Fp32,
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxSquare(ElementFormat::E4M3),
        ];
        // standalone reference runs
        let reference: Vec<f64> = schemes
            .iter()
            .map(|&scheme| {
                let mut s =
                    TrainSession::new(quick_dataset("cartpole", 7), quick_config(scheme, 30));
                for _ in 0..30 {
                    s.step_once();
                }
                s.val_loss()
            })
            .collect();
        // the same runs through the round-robin scheduler
        let mut sched = FleetScheduler::new(4);
        for (i, &scheme) in schemes.iter().enumerate() {
            sched.push(
                SessionSpec::new(
                    format!("robot-{i}"),
                    "cartpole",
                    quick_dataset("cartpole", 7),
                    quick_config(scheme, 30),
                )
                .build()
                .unwrap(),
            );
        }
        let stats = sched.run();
        assert_eq!(stats.total_steps, 90);
        assert_eq!(stats.rounds, 30usize.div_ceil(4));
        assert_eq!(stats.parked, 0);
        for (s, want) in sched.sessions().iter().zip(&reference) {
            assert_eq!(s.steps_done(), 30);
            assert_eq!(s.session().val_loss(), *want, "{}", s.id);
        }
    }

    #[test]
    fn energy_budget_parks_a_session_early() {
        let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
        // priced for the session's actual dims, not the paper MLP
        let per_step = step_cost_for(scheme, 32, &[32, 24, 32]).microjoules;
        let budget = SessionBudget {
            max_steps: 1000,
            max_energy_uj: per_step * 7.5, // room for exactly 8 steps
        };
        let mut s = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 1),
            quick_config(scheme, 1000),
        )
        .budget(budget)
        .build()
        .unwrap();
        let ran = s.run_quantum(100);
        assert_eq!(ran, 8, "energy ceiling must stop the quantum");
        assert!(s.done());
        assert_eq!(s.run_quantum(100), 0);
    }

    #[test]
    fn mismatched_shift_dataset_is_rejected_at_construction() {
        let mut bad = quick_dataset("cartpole", 3);
        bad.train_y.cols = 16; // deliberately malformed target width
        let r = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 3),
            quick_config(QuantScheme::Fp32, 10),
        )
        .shifts(vec![DomainShift { at_step: 5, label: "bad".into(), dataset: bad }])
        .build();
        assert!(matches!(r, Err(TrainError::BadConfig { .. })));
    }

    #[test]
    fn domain_shift_checkpoints_and_resumes() {
        let shifted_env = shifted_by_name("cartpole").unwrap();
        let shifted = Dataset::collect(shifted_env.as_ref(), 4, 40, 9);
        let mut s = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 9),
            quick_config(QuantScheme::MxSquare(ElementFormat::Int8), 40),
        )
        .shifts(vec![DomainShift { at_step: 20, label: "heavier-pole".into(), dataset: shifted }])
        .build()
        .unwrap();
        while s.run_quantum(6) > 0 {}
        assert_eq!(s.steps_done(), 40);
        assert_eq!(s.shift_log.len(), 1);
        let rec = &s.shift_log[0];
        assert_eq!(rec.at_step, 20);
        assert_eq!(rec.checkpoint.step, 20);
        assert!(rec.payload_bytes > 0, "square MX image must be present");
        assert!(rec.total_bytes > rec.payload_bytes);
        assert!(rec.val_before.is_finite());
        // the session now trains the shifted dataset, curves intact
        assert_eq!(s.session().dataset.name, "cartpole");
        assert!(s.session().train_curve.iter().any(|&(step, _)| step < 20));
        assert!(s.session().train_curve.iter().any(|&(step, _)| step >= 20));
        // fast backend measures nothing
        assert!(s.hw_measured_uj().is_none());
    }

    #[test]
    fn policy_repriced_steps_attribute_energy_per_format() {
        // a scheduled robot: e2m1 for steps 0..10, int8 after — energy
        // must be priced per segment and attributed to each format
        let scheme = QuantScheme::MxSquare(ElementFormat::E2M1);
        let mut s = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 5),
            quick_config(scheme, 20),
        )
        .policy(PrecisionPolicy::parse("10:mx-int8").unwrap())
        .build()
        .unwrap();
        while s.run_quantum(7) > 0 {}
        assert_eq!(s.steps_done(), 20);
        assert_eq!(s.session().scheme_history().len(), 2);
        assert_eq!(s.format_spend.len(), 2);
        let e2m1 = &s.format_spend[0];
        let int8 = &s.format_spend[1];
        assert_eq!((e2m1.scheme.as_str(), e2m1.steps), ("mx-e2m1", 10));
        assert_eq!((int8.scheme.as_str(), int8.steps), ("mx-int8", 10));
        // int8 steps are analytically dearer than e2m1 steps (8 vs 1
        // cycles/block), and the total must be the sum of the segments
        assert!(int8.uj > e2m1.uj, "int8 {} vs e2m1 {}", int8.uj, e2m1.uj);
        let total: f64 = s.format_spend.iter().map(|f| f.uj).sum();
        assert!((total - s.energy_uj).abs() < 1e-9 * total.max(1.0));
    }

    #[test]
    fn store_attached_shift_is_bit_identical_to_in_memory_handoff() {
        use crate::store::{CheckpointStore, MemoryStore, StoreLayout};
        let build = |store: Option<Arc<CheckpointStore>>| {
            let shifted_env = shifted_by_name("cartpole").unwrap();
            let shifted = Dataset::collect(shifted_env.as_ref(), 4, 40, 9);
            let mut spec = SessionSpec::new(
                "r0",
                "cartpole",
                quick_dataset("cartpole", 9),
                quick_config(QuantScheme::MxSquare(ElementFormat::E2M1), 30),
            )
            .shifts(vec![DomainShift { at_step: 15, label: "shift".into(), dataset: shifted }]);
            if let Some(store) = store {
                spec = spec.store(store);
            }
            let mut s = spec.build().unwrap();
            while s.run_quantum(7) > 0 {}
            assert!(s.error().is_none(), "{:?}", s.error());
            s
        };
        let reference = build(None);
        let store = Arc::new(CheckpointStore::new(
            Arc::new(MemoryStore::new()),
            StoreLayout::Sharded { shards: 2 },
        ));
        let through_store = build(Some(store.clone()));
        assert_eq!(reference.session().val_loss(), through_store.session().val_loss());
        assert_eq!(
            reference.session().train_curve,
            through_store.session().train_curve,
            "resume through the store must be bitwise indistinguishable"
        );
        // the shift checkpoint is now readable from the store too
        assert_eq!(store.load("r0").unwrap().step, 15);
    }

    #[test]
    fn policy_backend_mismatch_is_rejected_at_build() {
        let r = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 6),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                backend: BackendKind::Packed,
                dims: Some(vec![32, 24, 32]),
                steps: 10,
                eval_every: 10,
                ..Default::default()
            },
        )
        .policy(PrecisionPolicy::parse("5:mxvec-int8").unwrap())
        .build();
        assert!(matches!(r, Err(TrainError::BadConfig { .. })));
    }

    #[test]
    fn evict_then_resume_continues_bitwise_and_carries_the_ledger() {
        use crate::store::{CheckpointStore, MemoryStore, StoreLayout};
        let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
        // uninterrupted reference
        let mut reference = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 13),
            quick_config(scheme, 24),
        )
        .build()
        .unwrap();
        while reference.run_quantum(5) > 0 {}
        // same run, evicted to the store at step 10 and re-admitted
        let store =
            Arc::new(CheckpointStore::new(Arc::new(MemoryStore::new()), StoreLayout::Plain));
        let mut first = SessionSpec::new(
            "r0",
            "cartpole",
            quick_dataset("cartpole", 13),
            quick_config(scheme, 24),
        )
        .build()
        .unwrap();
        first.run_quantum(10);
        let energy_at_evict = first.energy_uj;
        let spec = first.evict(&store).unwrap();
        let mut resumed = spec.build().unwrap();
        assert_eq!(resumed.steps_done(), 10);
        assert_eq!(resumed.energy_uj, energy_at_evict, "ledger must carry");
        while resumed.run_quantum(5) > 0 {}
        assert_eq!(resumed.steps_done(), 24);
        assert_eq!(
            resumed.session().train_curve,
            reference.session().train_curve,
            "evict→re-admit must be bitwise identical to an uninterrupted run"
        );
        assert_eq!(resumed.session().val_loss(), reference.session().val_loss());
        assert_eq!(resumed.energy_uj, reference.energy_uj);
    }

    #[test]
    fn all_parked_roster_reports_parked_stats() {
        use crate::store::{CheckpointStore, MemoryStore, StoreLayout};
        // a packed-backend session, evicted and re-admitted with a
        // schedule whose target scheme the backend cannot execute: the
        // resume path skips policy validation (by design), so the bad
        // transition surfaces mid-quantum and parks the session
        let store =
            Arc::new(CheckpointStore::new(Arc::new(MemoryStore::new()), StoreLayout::Plain));
        let config = TrainConfig {
            scheme: QuantScheme::MxSquare(ElementFormat::Int8),
            backend: BackendKind::Packed,
            dims: Some(vec![32, 24, 32]),
            steps: 20,
            eval_every: 10,
            ..Default::default()
        };
        let mut s =
            SessionSpec::new("r0", "cartpole", quick_dataset("cartpole", 6), config)
                .build()
                .unwrap();
        s.run_quantum(2);
        let spec = s.evict(&store).unwrap();
        let poisoned = SessionSpec {
            policy: Some(PrecisionPolicy::parse("4:mxvec-int8").unwrap()),
            ..spec
        }
        .build()
        .unwrap();
        let mut sched = FleetScheduler::new(4);
        sched.push(poisoned);
        let stats = sched.run();
        assert_eq!(stats.parked, 1, "the errored session must be reported, not dressed as done");
        assert!(stats.total_steps < 18, "the run must stop at the bad transition");
        let parked = &sched.sessions()[0];
        assert!(parked.error().is_some());
        assert!(parked.done(), "a parked session runs no further quanta");
    }

    #[test]
    fn hw_measured_energy_carries_across_a_shift() {
        // resume replaces the hw backend (fresh cost ledger); the fleet
        // session must keep accounting the pre-shift segment
        let shifted_env = shifted_by_name("cartpole").unwrap();
        let shifted = Dataset::collect(shifted_env.as_ref(), 3, 30, 11);
        let config = TrainConfig {
            scheme: QuantScheme::MxSquare(ElementFormat::E2M1),
            backend: BackendKind::Hardware,
            dims: Some(vec![32, 8, 32]),
            batch_size: 8,
            steps: 8,
            eval_every: usize::MAX,
            ..Default::default()
        };
        let mut s = SessionSpec::new("r0", "cartpole", quick_dataset("cartpole", 11), config)
            .budget(SessionBudget::steps(8))
            .shifts(vec![DomainShift { at_step: 4, label: "shift".into(), dataset: shifted }])
            .build()
            .unwrap();
        while s.run_quantum(3) > 0 {}
        assert_eq!(s.steps_done(), 8);
        let total = s.hw_measured_uj().unwrap();
        let post_shift_only = s.session().hw_report().unwrap().uj_total();
        assert!(
            total > post_shift_only && post_shift_only > 0.0,
            "pre-shift ledger must be carried: total {total} vs post-shift {post_shift_only}"
        );
    }
}
