//! Fleet experiment runner + `results/fleet_report.json` emission.

#![forbid(unsafe_code)]

use crate::backend::BackendKind;
use crate::fleet::scheduler::{DomainShift, FleetScheduler, FleetStats, SessionBudget};
use crate::fleet::spec::SessionSpec;
use crate::mx::element::ElementFormat;
use crate::store::{CheckpointStore, StoreLayout};
use crate::trainer::checkpoint::{grouping_footprint, image_bytes, weight_payload, Checkpoint};
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainConfig, TrainError, TrainSession};
use crate::util::json::Json;
use crate::util::par;
use crate::workloads::{by_name, shifted_by_name, Dataset, ALL_WORKLOADS};
use std::path::PathBuf;
use std::sync::Arc;

/// Where (and how) a fleet run persists its checkpoints
/// (`mxscale fleet --store <layout> --store-dir <dir>`).
#[derive(Debug, Clone)]
pub struct StoreSpec {
    /// Root directory of the `FilesystemStore`.
    pub dir: PathBuf,
    /// Chunk layout: one object per chunk, or packed shards.
    pub layout: StoreLayout,
}

/// Parameters of one fleet run (CLI defaults in [`Default`]).
#[derive(Debug, Clone)]
pub struct FleetSpec {
    /// Concurrent sessions; session `i` trains workload `i % 4` under
    /// scheme `(i / 4) % schemes.len()`.
    pub sessions: usize,
    pub schemes: Vec<QuantScheme>,
    pub backend: BackendKind,
    /// Per-session step budget (includes post-shift adaptation steps).
    pub steps: usize,
    /// Round-robin quantum (steps per session per round).
    pub quantum: usize,
    /// Step at which every session's environment shifts (0 disables).
    pub shift_at: usize,
    /// Hidden width override (`None` = the paper MLP).
    pub hidden: Option<usize>,
    /// Dataset size: rollout episodes × horizon.
    pub episodes: usize,
    pub horizon: usize,
    pub batch: usize,
    pub lr: f32,
    pub eval_every: usize,
    /// Per-session energy ceiling [uJ] (`INFINITY` = step-bounded only).
    pub energy_budget_uj: f64,
    /// Precision policy attached to every session (`None` = static) —
    /// each robot gets its own clone, so adaptive watchdogs judge each
    /// robot's loss stream independently.
    pub policy: Option<PrecisionPolicy>,
    /// Checkpoint persistence (`None` = in-memory only). When set,
    /// every domain-shift checkpoint round-trips through the store and
    /// every session's final checkpoint is persisted at the end of the
    /// run (one shard append per shard under a sharded layout).
    pub store: Option<StoreSpec>,
    pub seed: u64,
}

impl Default for FleetSpec {
    fn default() -> Self {
        Self {
            sessions: 8,
            schemes: vec![
                QuantScheme::MxSquare(ElementFormat::Int8),
                QuantScheme::MxSquare(ElementFormat::E4M3),
            ],
            backend: BackendKind::Fast,
            steps: 280,
            quantum: 16,
            shift_at: 140,
            hidden: None,
            episodes: 10,
            horizon: 60,
            batch: 32,
            lr: 1e-3,
            eval_every: 20,
            energy_budget_uj: f64::INFINITY,
            policy: None,
            store: None,
            seed: 0xF1EE7,
        }
    }
}

/// Adaptation-from-checkpoint vs retrain-from-scratch, stepped in
/// lockstep on the same shifted dataset.
#[derive(Debug, Clone)]
pub struct AdaptComparison {
    pub workload: String,
    pub scheme: String,
    /// Steps both contenders were given.
    pub steps: usize,
    /// The scratch run's final validation loss — the bar to clear.
    pub target_loss: f64,
    /// (steps-since-shift, val-loss) for the checkpoint-resumed session.
    pub adapt_curve: Vec<(usize, f64)>,
    /// Same sampling for the from-scratch session.
    pub scratch_curve: Vec<(usize, f64)>,
    /// First sampled step at which the adapting session met the target.
    pub adapt_steps_to_target: Option<usize>,
    /// Whether adaptation reached the scratch final loss in strictly
    /// fewer steps — the continual-learning payoff.
    pub adapt_beats_scratch: bool,
}

/// Race a checkpoint-resumed session against a from-scratch session on
/// a shifted dataset for `steps` steps, sampling every `eval_every`.
pub fn adapt_vs_retrain(
    ck: &Checkpoint,
    shifted: &Dataset,
    steps: usize,
    eval_every: usize,
) -> Result<AdaptComparison, TrainError> {
    let eval_every = eval_every.clamp(1, steps.max(1));
    let mut adapt = TrainSession::resume(shifted.clone(), ck)?;
    let mut scratch = TrainSession::try_new(shifted.clone(), ck.config.clone())?;
    let mut adapt_curve = vec![(0usize, adapt.val_loss())];
    let mut scratch_curve = vec![(0usize, scratch.val_loss())];
    for i in 1..=steps {
        adapt.step_once();
        scratch.step_once();
        if i % eval_every == 0 || i == steps {
            adapt_curve.push((i, adapt.val_loss()));
            scratch_curve.push((i, scratch.val_loss()));
        }
    }
    let target_loss = scratch_curve.last().map(|&(_, v)| v).unwrap_or(f64::INFINITY);
    let adapt_steps_to_target =
        adapt_curve.iter().find(|&&(_, v)| v <= target_loss).map(|&(s, _)| s);
    let adapt_beats_scratch = adapt_steps_to_target.is_some_and(|s| s < steps);
    Ok(AdaptComparison {
        workload: shifted.name.to_string(),
        scheme: ck.config.scheme.name(),
        steps,
        target_loss,
        adapt_curve,
        scratch_curve,
        adapt_steps_to_target,
        adapt_beats_scratch,
    })
}

/// Per-session outcome summary (for tables and JSON).
#[derive(Debug, Clone)]
pub struct SessionSummary {
    pub id: String,
    pub workload: String,
    pub scheme: String,
    pub backend: String,
    pub steps: usize,
    pub energy_uj: f64,
    /// Measured accelerator energy when the hardware backend ran [uJ].
    pub hw_energy_uj: Option<f64>,
    pub final_val: f64,
    pub shifts: usize,
    /// Precision transitions the session's policy fired.
    pub transitions: usize,
    /// MX weight-image bytes of this session's checkpoint.
    pub payload_bytes: usize,
    /// The error that parked this session mid-run, if any — a parked
    /// session's numbers above are partial.
    pub error: Option<String>,
}

/// Everything a fleet run produced.
pub struct FleetRun {
    pub stats: FleetStats,
    pub sessions: Vec<SessionSummary>,
    pub adapt: Option<AdaptComparison>,
    /// The `results/fleet_report.json` document.
    pub report: Json,
}

fn curve_json(curve: &[(usize, f64)]) -> Json {
    let mut arr = Json::arr();
    for &(s, v) in curve {
        arr = arr.push(Json::arr().push(s).push(v));
    }
    arr
}

/// Build and run a fleet per `spec`, then analyze adaptation and
/// assemble the report document. The caller decides where to save it
/// (the CLI writes `results/fleet_report.json`).
pub fn run_fleet(spec: &FleetSpec) -> Result<FleetRun, TrainError> {
    if spec.sessions == 0 || spec.schemes.is_empty() {
        return Err(TrainError::BadConfig {
            reason: "fleet needs at least one session and one scheme".into(),
        });
    }
    let dims = spec.hidden.map(crate::trainer::mlp::hidden_dims);
    let store = match &spec.store {
        Some(ss) => Some(Arc::new(CheckpointStore::open_dir(&ss.dir, ss.layout)?)),
        None => None,
    };
    let mut sched = FleetScheduler::new(spec.quantum);
    for i in 0..spec.sessions {
        let workload = ALL_WORKLOADS[i % ALL_WORKLOADS.len()];
        let scheme = spec.schemes[(i / ALL_WORKLOADS.len()) % spec.schemes.len()];
        let env = by_name(workload).ok_or_else(|| TrainError::BadConfig {
            reason: format!("unknown workload `{workload}`"),
        })?;
        let ds = Dataset::collect(env.as_ref(), spec.episodes, spec.horizon, spec.seed + i as u64);
        let config = TrainConfig {
            scheme,
            backend: spec.backend,
            dims: dims.clone(),
            batch_size: spec.batch,
            lr: spec.lr,
            steps: spec.steps,
            eval_every: spec.eval_every,
            seed: spec.seed ^ ((i as u64 + 1) << 8),
        };
        let shifts = if spec.shift_at > 0 && spec.shift_at < spec.steps {
            let senv = shifted_by_name(workload).ok_or_else(|| TrainError::BadConfig {
                reason: format!("workload `{workload}` has no shifted variant"),
            })?;
            let shift_seed = spec.seed + 104_729 + i as u64;
            let sds = Dataset::collect(senv.as_ref(), spec.episodes, spec.horizon, shift_seed);
            vec![DomainShift {
                at_step: spec.shift_at,
                label: format!("{workload}-shifted"),
                dataset: sds,
            }]
        } else {
            Vec::new()
        };
        let budget =
            SessionBudget { max_steps: spec.steps, max_energy_uj: spec.energy_budget_uj };
        let id = format!("robot-{i:02}");
        let mut session_spec = SessionSpec::new(id, workload, ds, config)
            .budget(budget)
            .shifts(shifts);
        if let Some(policy) = &spec.policy {
            session_spec = session_spec.policy(policy.clone());
        }
        if let Some(store) = &store {
            session_spec = session_spec.store(store.clone());
        }
        sched.push(session_spec.build()?);
    }

    let stats = sched.run();
    // parked-on-error sessions mean the fleet result is partial; the
    // report still covers every session (each summary carries its
    // error), `stats.parked` counts them, and the CLI exits nonzero

    // persist every session's final state — batched, so the sharded
    // layout locks and re-indexes each shard exactly once
    if let Some(store) = &store {
        let finals: Vec<(String, Checkpoint)> = sched
            .sessions()
            .iter()
            .map(|s| (s.id.clone(), s.session().save_checkpoint()))
            .collect();
        let refs: Vec<(String, &Checkpoint)> =
            finals.iter().map(|(id, ck)| (id.clone(), ck)).collect();
        store.save_many(&refs)?;
    }

    // adaptation-vs-retrain: replay the first shifted session's
    // checkpoint against a scratch run on its shifted dataset
    let adapt = match sched.sessions().iter().find(|s| !s.shift_log.is_empty()) {
        Some(s) => {
            let rec = &s.shift_log[0];
            let window = spec.steps.saturating_sub(rec.at_step).max(1);
            Some(adapt_vs_retrain(
                &rec.checkpoint,
                &s.session().dataset,
                window,
                spec.eval_every,
            )?)
        }
        None => None,
    };

    let sessions: Vec<SessionSummary> = sched
        .sessions()
        .iter()
        .map(|s| {
            let payload_bytes = s.shift_log.first().map(|r| r.payload_bytes).unwrap_or_else(|| {
                // quantize the weight image alone — no need to clone the
                // whole trainer sidecar just to size the MX payload
                let scheme = s.session().config.scheme;
                image_bytes(&weight_payload(&s.session().mlp.weights, scheme))
            });
            SessionSummary {
                id: s.id.clone(),
                workload: s.workload.clone(),
                scheme: s.session().config.scheme.name(),
                backend: s.session().config.backend.name().to_string(),
                steps: s.steps_done(),
                energy_uj: s.energy_uj,
                hw_energy_uj: s.hw_measured_uj(),
                final_val: s.session().val_loss(),
                shifts: s.shift_log.len(),
                transitions: s.session().scheme_history().len() - 1,
                payload_bytes,
                error: s.error().map(|e| e.to_string()),
            }
        })
        .collect();

    // checkpoint-footprint comparison on a representative weight stack
    let rep = &sched.sessions()[0];
    let rep_fmt = rep.session().config.scheme.element().unwrap_or(ElementFormat::Int8);
    let (square_bytes, vector_bytes) = grouping_footprint(&rep.session().mlp.weights, rep_fmt);

    let mut spec_json = Json::obj()
        .set("sessions", spec.sessions)
        .set("quantum", spec.quantum)
        .set("steps", spec.steps)
        .set("shift_at", spec.shift_at)
        .set("backend", spec.backend.name())
        .set(
            "policy",
            spec.policy.as_ref().map(|p| Json::from(p.name())).unwrap_or(Json::Null),
        )
        .set("workers", par::threads());
    let mut scheme_arr = Json::arr();
    for s in &spec.schemes {
        scheme_arr = scheme_arr.push(s.name());
    }
    spec_json = spec_json.set("schemes", scheme_arr);

    let stats_json = Json::obj()
        .set("rounds", stats.rounds)
        .set("total_steps", stats.total_steps)
        .set("parked", stats.parked)
        .set("wall_s", stats.wall_s)
        .set("eff_steps_per_sec", stats.steps_per_sec());

    let mut sess_arr = Json::arr();
    for (s, fs) in sessions.iter().zip(sched.sessions()) {
        let mut shifts = Json::arr();
        for r in &fs.shift_log {
            shifts = shifts.push(
                Json::obj()
                    .set("at_step", r.at_step)
                    .set("label", r.label.clone())
                    .set("payload_bytes", r.payload_bytes)
                    .set("total_bytes", r.total_bytes)
                    .set("val_before", r.val_before),
            );
        }
        let mut history = Json::arr();
        for &(at, scheme) in fs.session().scheme_history() {
            history = history.push(Json::arr().push(at).push(scheme.name()));
        }
        let mut spend = Json::arr();
        for f in &fs.format_spend {
            spend = spend.push(
                Json::obj()
                    .set("scheme", f.scheme.clone())
                    .set("steps", f.steps)
                    .set("uj", f.uj),
            );
        }
        let mut o = Json::obj()
            .set("id", s.id.clone())
            .set("workload", s.workload.clone())
            .set("scheme", s.scheme.clone())
            .set("backend", s.backend.clone())
            .set("steps", s.steps)
            .set("energy_uj", s.energy_uj)
            .set("final_val", s.final_val)
            .set("ckpt_payload_bytes", s.payload_bytes)
            .set("scheme_history", history)
            .set("format_spend", spend)
            .set("shifts", shifts)
            .set(
                "error",
                s.error.as_ref().map(|e| Json::from(e.as_str())).unwrap_or(Json::Null),
            );
        if let Some(uj) = s.hw_energy_uj {
            o = o.set("hw_measured_uj", uj);
        }
        sess_arr = sess_arr.push(o);
    }

    let ckpt_json = Json::obj()
        .set("element", rep_fmt.name())
        .set("square_single_copy_bytes", square_bytes)
        .set("vector_two_copy_bytes", vector_bytes)
        .set("reduction_pct", 100.0 * (1.0 - square_bytes as f64 / vector_bytes as f64));

    let adapt_json = match &adapt {
        Some(a) => Json::obj()
            .set("workload", a.workload.clone())
            .set("scheme", a.scheme.clone())
            .set("steps", a.steps)
            .set("target_loss", a.target_loss)
            .set(
                "adapt_steps_to_target",
                a.adapt_steps_to_target.map(Json::from).unwrap_or(Json::Null),
            )
            .set("adapt_beats_scratch", a.adapt_beats_scratch)
            .set("adapt_curve", curve_json(&a.adapt_curve))
            .set("scratch_curve", curve_json(&a.scratch_curve)),
        None => Json::Null,
    };

    let store_json = match (&spec.store, &store) {
        (Some(ss), Some(store)) => {
            let shard_files = store.shard_files()?;
            let stored = store.sessions()?;
            Json::obj()
                .set("layout", ss.layout.name())
                .set("dir", ss.dir.display().to_string())
                .set("sessions_stored", stored.len())
                .set("shard_files", shard_files.len())
        }
        _ => Json::Null,
    };

    let report = crate::coordinator::report::stamped_doc("fleet_report")
        .set("spec", spec_json)
        .set("stats", stats_json)
        .set("sessions", sess_arr)
        .set("checkpoint_footprint", ckpt_json)
        .set("adaptation", adapt_json)
        .set("store", store_json);

    Ok(FleetRun { stats, sessions, adapt, report })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptation_from_checkpoint_beats_retrain_from_scratch() {
        // phase A: learn nominal cartpole dynamics, then shift the
        // physics and race checkpoint-adaptation against scratch.
        let env = by_name("cartpole").unwrap();
        let ds = Dataset::collect(env.as_ref(), 8, 50, 0xADA17);
        let mut phase_a = TrainSession::new(
            ds,
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                dims: Some(vec![32, 48, 48, 32]),
                steps: 0,
                lr: 2e-3,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        for _ in 0..200 {
            phase_a.step_once();
        }
        let ck = phase_a.save_checkpoint();
        let senv = shifted_by_name("cartpole").unwrap();
        let shifted = Dataset::collect(senv.as_ref(), 8, 50, 0xADB17);
        let cmp = adapt_vs_retrain(&ck, &shifted, 120, 10).unwrap();
        assert_eq!(cmp.adapt_curve.len(), cmp.scratch_curve.len());
        assert!(
            cmp.adapt_beats_scratch,
            "adapt should reach the scratch loss early: target {} adapt_curve {:?}",
            cmp.target_loss, cmp.adapt_curve
        );
        let reached = cmp.adapt_steps_to_target.unwrap();
        assert!(reached < 120, "reached at {reached}");
    }

    #[test]
    fn run_fleet_produces_full_report() {
        let spec = FleetSpec {
            sessions: 8,
            steps: 24,
            quantum: 7,
            shift_at: 12,
            hidden: Some(16),
            episodes: 3,
            horizon: 30,
            eval_every: 6,
            ..Default::default()
        };
        let run = run_fleet(&spec).unwrap();
        assert_eq!(run.sessions.len(), 8);
        assert_eq!(run.stats.total_steps, 8 * 24);
        for s in &run.sessions {
            assert_eq!(s.steps, 24);
            assert_eq!(s.shifts, 1, "{}", s.id);
            assert!(s.payload_bytes > 0);
            assert!(s.final_val.is_finite());
        }
        let adapt = run.adapt.as_ref().expect("shifted fleet must analyze adaptation");
        assert_eq!(adapt.steps, 12);
        let text = run.report.pretty();
        for key in [
            "\"spec\"",
            "\"stats\"",
            "\"sessions\"",
            "\"checkpoint_footprint\"",
            "\"adaptation\"",
            "\"eff_steps_per_sec\"",
            "\"square_single_copy_bytes\"",
        ] {
            assert!(text.contains(key), "missing {key} in report");
        }
    }

    #[test]
    fn run_fleet_with_policy_schedules_every_robot() {
        let spec = FleetSpec {
            sessions: 4,
            schemes: vec![QuantScheme::MxSquare(ElementFormat::E2M1)],
            steps: 16,
            quantum: 5,
            shift_at: 0,
            hidden: Some(16),
            episodes: 3,
            horizon: 30,
            eval_every: 8,
            policy: Some(PrecisionPolicy::parse("8:mx-int8").unwrap()),
            ..Default::default()
        };
        let run = run_fleet(&spec).unwrap();
        for s in &run.sessions {
            assert_eq!(s.transitions, 1, "{}", s.id);
            assert_eq!(s.scheme, "mx-int8", "{}: final scheme must be the scheduled one", s.id);
        }
        let text = run.report.pretty();
        for key in ["\"policy\"", "\"scheme_history\"", "\"format_spend\"", "\"mx-e2m1\""] {
            assert!(text.contains(key), "missing {key} in report");
        }
    }

    #[test]
    fn run_fleet_persists_through_a_sharded_store() {
        let dir = std::env::temp_dir()
            .join(format!("mxscale-fleet-store-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let spec = FleetSpec {
            sessions: 4,
            steps: 20,
            quantum: 6,
            shift_at: 10,
            hidden: Some(16),
            episodes: 3,
            horizon: 30,
            eval_every: 10,
            store: Some(StoreSpec {
                dir: dir.clone(),
                layout: StoreLayout::Sharded { shards: 2 },
            }),
            ..Default::default()
        };
        let run = run_fleet(&spec).unwrap();
        assert_eq!(run.sessions.len(), 4);
        // every robot's final checkpoint is readable back from the store
        let store = CheckpointStore::open_dir(&dir, StoreLayout::Sharded { shards: 2 }).unwrap();
        let ids = store.sessions().unwrap();
        assert_eq!(ids.len(), 4, "{ids:?}");
        for id in &ids {
            let ck = store.load(id).unwrap();
            assert_eq!(ck.step, 20, "{id}");
        }
        assert!(store.shard_files().unwrap().len() <= 2);
        let text = run.report.pretty();
        for key in ["\"store\"", "\"shard_files\"", "\"sessions_stored\"", "sharded:2"] {
            assert!(text.contains(key), "missing {key} in report");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn run_fleet_rejects_empty_spec() {
        let spec = FleetSpec { sessions: 0, ..Default::default() };
        assert!(matches!(run_fleet(&spec), Err(TrainError::BadConfig { .. })));
    }
}
