//! [`SessionSpec`] — the one way to construct a [`FleetSession`].
//!
//! The fleet layer used to grow construction surface ad hoc: a
//! six-positional-argument `FleetSession::new` plus `with_policy` /
//! `with_store` half-builders, each validating a different slice of the
//! invariants at a different time. `SessionSpec` replaces all of it
//! with a single builder that collects *everything* a session is —
//! identity, dataset, training config, budget, shift schedule,
//! precision policy, checkpoint store, serving priority — and validates
//! the whole bundle exactly once at [`SessionSpec::build`]. The
//! scheduler, the serving front-end (`crate::serve`), the CLI, the
//! examples, and every test construct sessions through this type.
//!
//! Re-admission: [`SessionSpec::resume_from_store`] flips the build
//! path from `TrainSession::try_new` to a store read-back +
//! `TrainSession::resume`, which is how the serving layer re-admits a
//! session it evicted (checkpoint-on-evict) — bit-identical to never
//! having been evicted, by the store's save→resume contract.

#![forbid(unsafe_code)]

use crate::fleet::scheduler::{CarriedLedger, DomainShift, FleetSession, SessionBudget};
use crate::store::CheckpointStore;
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::session::{TrainConfig, TrainError};
use crate::workloads::Dataset;
use std::sync::Arc;

/// Declarative description of one fleet session, validated at
/// [`SessionSpec::build`]. The step budget defaults to the config's
/// `steps`; everything else defaults to "off".
pub struct SessionSpec {
    pub(crate) id: String,
    pub(crate) workload: String,
    pub(crate) dataset: Dataset,
    pub(crate) config: TrainConfig,
    pub(crate) budget: SessionBudget,
    pub(crate) shifts: Vec<DomainShift>,
    pub(crate) policy: Option<PrecisionPolicy>,
    pub(crate) store: Option<Arc<CheckpointStore>>,
    pub(crate) priority: u8,
    pub(crate) resume: bool,
    /// Fleet-level accounting carried across an eviction (energy,
    /// per-format spend, shift log) — filled by [`FleetSession::evict`],
    /// never by callers.
    pub(crate) carried: Option<CarriedLedger>,
}

impl SessionSpec {
    /// Start a spec: identity, workload label, dataset, and training
    /// config. The budget defaults to `config.steps` steps with no
    /// energy ceiling.
    pub fn new(
        id: impl Into<String>,
        workload: impl Into<String>,
        dataset: Dataset,
        config: TrainConfig,
    ) -> Self {
        let budget = SessionBudget::steps(config.steps);
        Self {
            id: id.into(),
            workload: workload.into(),
            dataset,
            config,
            budget,
            shifts: Vec::new(),
            policy: None,
            store: None,
            priority: 0,
            resume: false,
            carried: None,
        }
    }

    /// Override the step/energy budget.
    pub fn budget(mut self, budget: SessionBudget) -> Self {
        self.budget = budget;
        self
    }

    /// Attach the domain-shift schedule (sorted by `at_step` at build).
    pub fn shifts(mut self, shifts: Vec<DomainShift>) -> Self {
        self.shifts = shifts;
        self
    }

    /// Attach a per-robot precision policy (validated against the
    /// backend at build, not at the first transition mid-quantum).
    pub fn policy(mut self, policy: PrecisionPolicy) -> Self {
        self.policy = Some(policy);
        self
    }

    /// Persist this session's checkpoints through `store` (shared
    /// across the fleet; the store's backend is `Send + Sync`).
    pub fn store(mut self, store: Arc<CheckpointStore>) -> Self {
        self.store = Some(store);
        self
    }

    /// Serving priority (higher runs sooner under contention; clamped
    /// to [`crate::serve::MAX_PRIORITY`] by the executor). The
    /// round-robin `FleetScheduler` ignores it.
    pub fn priority(mut self, priority: u8) -> Self {
        self.priority = priority;
        self
    }

    /// Build by resuming from the attached store instead of training
    /// from scratch: `build()` reads the checkpoint saved under this
    /// spec's `id` and continues it on this spec's dataset. Requires
    /// [`SessionSpec::store`]; the checkpoint's own config supersedes
    /// the spec's. Policy validation is skipped on this path — the
    /// policy was validated when the session was first built, and its
    /// step-indexed state re-joins the schedule bitwise.
    pub fn resume_from_store(mut self) -> Self {
        self.resume = true;
        self
    }

    /// Validate everything at once and construct the session. Errors
    /// are structured [`TrainError`]s naming what failed: bad dims, a
    /// shift dataset that doesn't fit the session's IO widths, a policy
    /// the backend can't execute, or a missing/unreadable checkpoint on
    /// the resume path.
    pub fn build(self) -> Result<FleetSession, TrainError> {
        FleetSession::from_spec(self)
    }
}
