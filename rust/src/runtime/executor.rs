//! Typed executors over the PJRT CPU client.
//!
//! `TrainExecutable` owns one compiled train-step graph and threads the
//! flat training state through repeated `step()` calls; `EvalExecutable`
//! computes the validation loss. State initialization happens on the
//! Rust side (He init with the deterministic PCG64), so the whole
//! training loop is Python-free.
//!
//! The real implementation needs the git-only `xla` crate and compiles
//! only with the `xla-sys` cargo feature (plus the dependency added to
//! Cargo.toml — see README.md). Both the default build and the
//! dependency-free `xla` plumbing feature (which CI builds and tests)
//! get API-compatible stubs whose constructors return errors, so every
//! caller can compile and skip gracefully when the runtime is
//! unavailable.

#![forbid(unsafe_code)]

#[cfg(feature = "xla-sys")]
mod pjrt {
    use std::path::Path;

    use crate::runtime::{err, Result};
    use crate::trainer::mlp::MLP_DIMS;
    use crate::util::mat::Mat;
    use crate::util::rng::Pcg64;

    /// Shared PJRT client (compile once, reuse across executables).
    pub fn cpu_client() -> Result<xla::PjRtClient> {
        Ok(xla::PjRtClient::cpu()?)
    }

    fn compile(client: &xla::PjRtClient, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| err("utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(client.compile(&comp)?)
    }

    fn literal_2d(m: &Mat) -> Result<xla::Literal> {
        Ok(xla::Literal::vec1(&m.data).reshape(&[m.rows as i64, m.cols as i64])?)
    }

    /// Build the flat initial training state (mirrors model.init_state):
    /// `[step, (w, b, mw, vw, mb, vb) x layers]`, He-initialized weights.
    pub fn init_state(seed: u64) -> Result<Vec<xla::Literal>> {
        let mut rng = Pcg64::with_stream(seed, 0x57A7E);
        let mut state = vec![xla::Literal::vec1(&[0.0f32]).reshape(&[1])?];
        for w in MLP_DIMS.windows(2) {
            let (din, dout) = (w[0], w[1]);
            let sigma = (2.0 / din as f32).sqrt();
            let wm = Mat::randn(din, dout, sigma, &mut rng);
            let zeros_w = Mat::zeros(din, dout);
            let zeros_b = vec![0.0f32; dout];
            state.push(literal_2d(&wm)?);
            state.push(xla::Literal::vec1(&zeros_b).reshape(&[dout as i64])?);
            state.push(literal_2d(&zeros_w)?);
            state.push(literal_2d(&zeros_w)?);
            state.push(xla::Literal::vec1(&zeros_b).reshape(&[dout as i64])?);
            state.push(xla::Literal::vec1(&zeros_b).reshape(&[dout as i64])?);
        }
        Ok(state)
    }

    /// A compiled train-step graph plus its threaded state.
    pub struct TrainExecutable {
        exe: xla::PjRtLoadedExecutable,
        pub state: Vec<xla::Literal>,
        pub steps_run: u64,
    }

    impl TrainExecutable {
        /// Load + compile the artifact and initialize fresh state.
        pub fn load(client: &xla::PjRtClient, path: &Path, seed: u64) -> Result<Self> {
            Ok(Self { exe: compile(client, path)?, state: init_state(seed)?, steps_run: 0 })
        }

        /// Run one training step on a `[B,32]` batch; returns the loss.
        pub fn step(&mut self, x: &Mat, y: &Mat) -> Result<f32> {
            let mut args: Vec<&xla::Literal> = self.state.iter().collect();
            let (xl, yl) = (literal_2d(x)?, literal_2d(y)?);
            args.push(&xl);
            args.push(&yl);
            let result = self.exe.execute(&args)?[0][0].to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != self.state.len() + 1 {
                return Err(err("unexpected output arity"));
            }
            let mut it = parts.into_iter();
            let loss = it.next().unwrap().to_vec::<f32>()?[0];
            self.state = it.collect();
            self.steps_run += 1;
            Ok(loss)
        }

        /// Copy the current parameters (w, b per layer) out of the state.
        pub fn params(&self) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
            let mut out = Vec::new();
            for i in 0..MLP_DIMS.len() - 1 {
                let w = self.state[1 + 6 * i].to_vec::<f32>()?;
                let b = self.state[2 + 6 * i].to_vec::<f32>()?;
                out.push((w, b));
            }
            Ok(out)
        }
    }

    /// A compiled eval graph (quantized validation loss).
    pub struct EvalExecutable {
        exe: xla::PjRtLoadedExecutable,
    }

    impl EvalExecutable {
        pub fn load(client: &xla::PjRtClient, path: &Path) -> Result<Self> {
            Ok(Self { exe: compile(client, path)? })
        }

        /// Validation loss of `state` on a `[B,32]` eval batch.
        pub fn loss(&self, state: &[xla::Literal], x: &Mat, y: &Mat) -> Result<f32> {
            let mut args: Vec<&xla::Literal> = state.iter().collect();
            let (xl, yl) = (literal_2d(x)?, literal_2d(y)?);
            args.push(&xl);
            args.push(&yl);
            let result = self.exe.execute(&args)?[0][0].to_literal_sync()?;
            let out = result.to_tuple1()?;
            Ok(out.to_vec::<f32>()?[0])
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn init_state_layout() {
            let s = init_state(1).unwrap();
            // 1 step scalar + 6 per layer x 4 layers
            assert_eq!(s.len(), 25);
            assert_eq!(s[0].to_vec::<f32>().unwrap(), vec![0.0]);
            // weights are randomized, moments zero
            let w0 = s[1].to_vec::<f32>().unwrap();
            assert_eq!(w0.len(), 32 * 256);
            assert!(w0.iter().any(|&v| v != 0.0));
            let mw0 = s[3].to_vec::<f32>().unwrap();
            assert!(mw0.iter().all(|&v| v == 0.0));
        }
    }
}

#[cfg(feature = "xla-sys")]
pub use pjrt::*;

#[cfg(not(feature = "xla-sys"))]
mod stub {
    use std::path::Path;

    use crate::runtime::{err, Result};
    use crate::util::mat::Mat;

    /// The two stub configurations report distinct causes: with the
    /// `xla` plumbing feature on, only the crate-backed layer is
    /// missing; without it, the runtime path was never requested. The
    /// CI feature-matrix leg asserts this split, which keeps the
    /// dependency-free `xla` feature observable (not inert).
    #[cfg(feature = "xla")]
    const UNAVAILABLE: &str = "mxscale was built with the `xla` runtime plumbing but \
         without the `xla-sys` crate layer (the git-only xla dependency); the PJRT \
         runtime path is unavailable (see README.md, section 'The PJRT runtime path')";
    #[cfg(not(feature = "xla"))]
    const UNAVAILABLE: &str = "mxscale was built without the `xla` feature; \
         the PJRT runtime path is unavailable (see README.md, section \
         'The PJRT runtime path')";

    /// Placeholder for `xla::PjRtClient` in `xla`-less builds.
    #[derive(Debug, Clone, Copy)]
    pub struct PjRtClient;

    /// Always errors in `xla`-less builds; callers skip gracefully.
    pub fn cpu_client() -> Result<PjRtClient> {
        Err(err(UNAVAILABLE))
    }

    /// Stub train executable: same surface as the PJRT-backed one, but
    /// unconstructible (load errors), so downstream code typechecks.
    pub struct TrainExecutable {
        /// Flat state tensors (mirrors the literal layout; always empty).
        pub state: Vec<Vec<f32>>,
        pub steps_run: u64,
    }

    impl TrainExecutable {
        pub fn load(_client: &PjRtClient, _path: &Path, _seed: u64) -> Result<Self> {
            Err(err(UNAVAILABLE))
        }

        pub fn step(&mut self, _x: &Mat, _y: &Mat) -> Result<f32> {
            Err(err(UNAVAILABLE))
        }

        pub fn params(&self) -> Result<Vec<(Vec<f32>, Vec<f32>)>> {
            Err(err(UNAVAILABLE))
        }
    }

    /// Stub eval executable.
    pub struct EvalExecutable;

    impl EvalExecutable {
        pub fn load(_client: &PjRtClient, _path: &Path) -> Result<Self> {
            Err(err(UNAVAILABLE))
        }

        pub fn loss(&self, _state: &[Vec<f32>], _x: &Mat, _y: &Mat) -> Result<f32> {
            Err(err(UNAVAILABLE))
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_client_reports_missing_feature() {
            let e = cpu_client().unwrap_err().to_string();
            assert!(e.contains("xla"));
            // the error names the exact layer this build is missing
            if cfg!(feature = "xla") {
                assert!(e.contains("xla-sys"), "{e}");
            } else {
                assert!(e.contains("without the `xla` feature"), "{e}");
            }
        }
    }
}

#[cfg(not(feature = "xla-sys"))]
pub use stub::*;
