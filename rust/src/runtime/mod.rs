//! PJRT/XLA runtime: load and execute the AOT-compiled JAX graphs.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! request-path side: it loads `artifacts/*.hlo.txt` (HLO **text** — the
//! xla_extension 0.5.1 in the `xla` crate rejects jax>=0.5 serialized
//! protos), compiles them on the PJRT CPU client, and threads the flat
//! training state through repeated executions with zero Python.

pub mod artifact;
pub mod executor;

pub use artifact::{artifact_dir, Manifest};
pub use executor::{EvalExecutable, TrainExecutable};
