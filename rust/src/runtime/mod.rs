//! PJRT/XLA runtime: load and execute the AOT-compiled JAX graphs.
//!
//! Python runs once at build time (`make artifacts`); this module is the
//! request-path side: it loads `artifacts/*.hlo.txt` (HLO **text** — the
//! xla_extension 0.5.1 in the `xla` crate rejects jax>=0.5 serialized
//! protos), compiles them on the PJRT CPU client, and threads the flat
//! training state through repeated executions with zero Python.
//!
//! The `xla` crate is git-only and cannot be vendored in the offline
//! dependency closure, so the executors are gated in two stages: the
//! dependency-free `xla` feature selects the runtime plumbing (always
//! buildable — CI exercises `--features xla` build+test), and `xla-sys`
//! (enabled together with the git dependency in a connected
//! environment) swaps in the real PJRT path. Without `xla-sys`,
//! API-compatible stubs return descriptive errors and every caller —
//! `tests/integration.rs`, `benches/bench_runtime.rs`,
//! `examples/train_pusher.rs` — skips gracefully. `anyhow` is likewise
//! replaced by the boxed [`Error`] alias below.

pub mod artifact;
pub mod executor;

pub use artifact::{artifact_dir, Manifest};
pub use executor::{EvalExecutable, TrainExecutable};

/// Boxed error shared across the runtime layer (stands in for `anyhow`,
/// which is unavailable offline).
pub type Error = Box<dyn std::error::Error + Send + Sync + 'static>;

/// Runtime result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Build an [`Error`] from a message.
pub(crate) fn err(msg: impl Into<String>) -> Error {
    msg.into().into()
}
