//! Artifact locations and the build manifest.

#![forbid(unsafe_code)]

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Locate the artifacts directory: `$MXSCALE_ARTIFACTS` or `./artifacts`.
pub fn artifact_dir() -> PathBuf {
    std::env::var_os("MXSCALE_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

/// Parsed `manifest.txt` (simple `key value...` lines from aot.py).
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dims: Vec<usize>,
    pub batch: usize,
    pub eval_batch: usize,
    pub lr: f64,
    pub state_len: usize,
    /// scheme -> train artifact filename
    pub train: HashMap<String, String>,
    /// scheme -> eval artifact filename
    pub eval: HashMap<String, String>,
}

impl Manifest {
    pub fn load(dir: &Path) -> crate::runtime::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.txt"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> crate::runtime::Result<Manifest> {
        let mut m = Manifest {
            dims: Vec::new(),
            batch: 0,
            eval_batch: 0,
            lr: 0.0,
            state_len: 0,
            train: HashMap::new(),
            eval: HashMap::new(),
        };
        for line in text.lines() {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("dims") => m.dims = it.map(|t| t.parse().unwrap_or(0)).collect(),
                Some("batch") => m.batch = it.next().unwrap_or("0").parse()?,
                Some("eval_batch") => m.eval_batch = it.next().unwrap_or("0").parse()?,
                Some("lr") => m.lr = it.next().unwrap_or("0").parse()?,
                Some("state_len") => m.state_len = it.next().unwrap_or("0").parse()?,
                Some("train") => {
                    if let (Some(s), Some(f)) = (it.next(), it.next()) {
                        m.train.insert(s.to_string(), f.to_string());
                    }
                }
                Some("eval") => {
                    if let (Some(s), Some(f)) = (it.next(), it.next()) {
                        m.eval.insert(s.to_string(), f.to_string());
                    }
                }
                _ => {}
            }
        }
        if m.dims.is_empty() {
            return Err(crate::runtime::err("manifest missing dims"));
        }
        if m.state_len == 0 {
            return Err(crate::runtime::err("manifest missing state_len"));
        }
        Ok(m)
    }

    pub fn train_path(&self, dir: &Path, scheme: &str) -> Option<PathBuf> {
        self.train.get(scheme).map(|f| dir.join(f))
    }

    pub fn eval_path(&self, dir: &Path, scheme: &str) -> Option<PathBuf> {
        self.eval.get(scheme).map(|f| dir.join(f))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_lines() {
        let text = "dims 32 256 256 256 32\nbatch 32\neval_batch 256\nlr 0.001\n\
                    state_len 25\nstate_layout step then per-layer w,b,mw,vw,mb,vb\n\
                    train fp32 train_step_fp32_b32.hlo.txt\neval fp32 eval_fp32_b256.hlo.txt\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.dims, vec![32, 256, 256, 256, 32]);
        assert_eq!(m.batch, 32);
        assert_eq!(m.state_len, 25);
        assert_eq!(m.train["fp32"], "train_step_fp32_b32.hlo.txt");
        assert!(m.eval_path(Path::new("/a"), "fp32").unwrap().ends_with("eval_fp32_b256.hlo.txt"));
    }

    #[test]
    fn rejects_empty_manifest() {
        assert!(Manifest::parse("").is_err());
    }
}
