//! Area / energy models for both designs (TSMC 16nm @ 500 MHz).
//!
//! No silicon in this environment, so synthesis is replaced by a
//! component-level analytical model (DESIGN.md §2): the bit-exact
//! simulators supply *event counts* (multiplier activations, aligned
//! terms, accumulator register toggles, SRAM traffic), and this module
//! prices them with per-event constants calibrated once against the
//! paper's own synthesis data (Table II and Fig. 7). Everything else —
//! the other Table II rows, Table IV, Fig. 8's energy axis — is then
//! *predicted* by the model, which is what makes regenerating those
//! tables a meaningful check rather than an identity.

pub mod calib;
pub mod model;

pub use model::{AreaBreakdown, EnergyBreakdown, EnergyModel};
