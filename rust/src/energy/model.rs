//! The analytical area/energy model over simulator event counts.

#![forbid(unsafe_code)]

use crate::arith::{Events, MacVariant};
use crate::energy::calib;
use crate::mx::dacapo::DacapoFormat;
use crate::mx::element::ElementFormat;

/// Area/energy model instance (per MAC variant).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    pub variant: MacVariant,
}

/// Per-component breakdown [pJ per OP] (Fig. 7 energy panel).
#[derive(Debug, Clone)]
pub struct EnergyBreakdown {
    pub components: Vec<(&'static str, f64)>,
    pub total_pj_per_op: f64,
}

/// Per-component breakdown [um^2] (Fig. 7 area panel).
#[derive(Debug, Clone)]
pub struct AreaBreakdown {
    pub components: Vec<(&'static str, f64)>,
    pub total_um2: f64,
}

impl EnergyModel {
    pub fn new(variant: MacVariant) -> Self {
        Self { variant }
    }

    pub fn proposed() -> Self {
        Self::new(MacVariant::ExtMantissaBypass)
    }

    /// Per-cycle MAC energy [pJ] in the given format's mode.
    pub fn mac_cycle_pj(&self, fmt: ElementFormat) -> f64 {
        let mode = fmt.mac_mode();
        let core = calib::core_cycle_pj(fmt);
        let n = calib::aligned_terms(fmt, self.variant) as f64;
        let a = calib::align_term_pj(mode, self.variant);
        calib::variant_global_factor(self.variant) * (core + n * a)
    }

    /// Standalone-MAC energy per multiplication OP [pJ] (Table II).
    pub fn mac_pj_per_op(&self, fmt: ElementFormat) -> f64 {
        self.mac_cycle_pj(fmt) / fmt.mac_mode().pairs_per_cycle() as f64
    }

    /// Energy of a simulated run from its event counts [pJ]:
    /// cycles priced at the calibrated per-cycle rate, modulated by the
    /// observed accumulator-register switching activity relative to the
    /// random-data nominal (the data-dependence the simulator captures).
    pub fn run_pj(&self, fmt: ElementFormat, ev: &Events) -> f64 {
        if ev.cycles == 0 {
            return 0.0;
        }
        let base = self.mac_cycle_pj(fmt) * ev.cycles as f64;
        // nominal toggle rate for random data: ~12 bits/cycle of the
        // 32-bit accumulator; the register component scales with actual
        let share: f64 = calib::energy_share(fmt.mac_mode())
            .iter()
            .find(|(n, _)| *n == "acc_register")
            .map(|(_, s)| *s)
            .unwrap_or(0.0);
        let nominal_toggles = 12.0 * ev.cycles as f64;
        let actual = ev.acc_reg_toggles as f64;
        let modulation = if nominal_toggles > 0.0 {
            1.0 + share * (actual / nominal_toggles - 1.0)
        } else {
            1.0
        };
        base * modulation
    }

    /// Core-level energy per multiplication OP [pJ] (Table IV row).
    pub fn core_pj_per_op(&self, fmt: ElementFormat) -> f64 {
        let mode = fmt.mac_mode();
        self.mac_pj_per_op(fmt) * calib::array_factor(mode) + calib::SRAM_PJ_PER_OP
    }

    /// Fig. 7 energy panel: per-component pJ/OP for the PE array.
    pub fn pe_energy_breakdown(&self, fmt: ElementFormat) -> EnergyBreakdown {
        let total = self.mac_pj_per_op(fmt);
        let components = calib::energy_share(fmt.mac_mode())
            .iter()
            .map(|&(n, s)| (n, s * total))
            .collect();
        EnergyBreakdown { components, total_pj_per_op: total }
    }

    /// Fig. 7 area panel: per-component um^2 for one MAC of the array.
    pub fn mac_area_breakdown(&self) -> AreaBreakdown {
        let total = calib::mac_area_um2(self.variant);
        let components = calib::AREA_SHARE.iter().map(|&(n, s)| (n, s * total)).collect();
        AreaBreakdown { components, total_um2: total }
    }

    /// Standalone MAC area [um^2] (Table II).
    pub fn mac_area_um2(&self) -> f64 {
        calib::mac_area_um2(self.variant)
    }

    /// Achievable frequency [MHz] (Table II).
    pub fn freq_mhz(&self) -> f64 {
        self.variant.freq_mhz()
    }

    /// Whole-core training energy for a cycle cost + op count [pJ].
    pub fn core_run_pj(&self, fmt: ElementFormat, mul_ops: u64) -> f64 {
        self.core_pj_per_op(fmt) * mul_ops as f64
    }

    /// Dacapo-side core energy [pJ] for a run.
    pub fn dacapo_run_pj(fmt: DacapoFormat, mul_ops: u64) -> f64 {
        calib::dacapo_pj_per_op(fmt) * mul_ops as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;

    /// Paper Table II, pJ/OP: (variant, [int8, e5m2, e4m3, e3m2, e2m3, e2m1]).
    const TABLE2: [(MacVariant, [f64; 6]); 3] = [
        (MacVariant::NormalizeL2, [5.08, 2.4, 2.49, 2.29, 2.51, 0.43]),
        (MacVariant::ExtMantissaNoBypass, [6.35, 3.2, 3.38, 3.21, 3.38, 0.67]),
        (MacVariant::ExtMantissaBypass, [4.41, 1.11, 1.169, 1.05, 1.13, 0.39]),
    ];

    #[test]
    fn table2_reproduction_within_5pct() {
        for (variant, row) in TABLE2 {
            let m = EnergyModel::new(variant);
            for (fmt, want) in ALL_ELEMENT_FORMATS.iter().zip(row) {
                let got = m.mac_pj_per_op(*fmt);
                let err = (got - want).abs() / want;
                assert!(err < 0.05, "{variant:?} {fmt:?}: {got:.3} vs {want} ({:.1}%)", err * 100.0);
            }
        }
    }

    #[test]
    fn bypass_variant_halves_area() {
        // "a 50% reduction in area" (paper §V-A)
        let b = calib::mac_area_um2(MacVariant::ExtMantissaBypass);
        let n = calib::mac_area_um2(MacVariant::NormalizeL2);
        let x = calib::mac_area_um2(MacVariant::ExtMantissaNoBypass);
        assert!(b / n < 0.55 && b / x < 0.55);
    }

    #[test]
    fn table4_core_energy_reproduction() {
        let m = EnergyModel::proposed();
        // ours: 3.20 / 1.87-1.88 / 0.43
        let int8 = m.core_pj_per_op(ElementFormat::Int8);
        assert!((int8 - 3.20).abs() / 3.20 < 0.03, "{int8}");
        for fmt in [ElementFormat::E5M2, ElementFormat::E4M3, ElementFormat::E3M2, ElementFormat::E2M3] {
            let e = m.core_pj_per_op(fmt);
            assert!((1.70..2.05).contains(&e), "{fmt:?}: {e}");
        }
        let fp4 = m.core_pj_per_op(ElementFormat::E2M1);
        assert!((fp4 - 0.43).abs() / 0.43 < 0.05, "{fp4}");
    }

    #[test]
    fn table4_relative_energy_vs_dacapo() {
        // paper: 1.04x more in INT8/FP8 classes, 0.9x in FP4
        let m = EnergyModel::proposed();
        let r8 = m.core_pj_per_op(ElementFormat::Int8) / calib::dacapo_pj_per_op(DacapoFormat::Mx9);
        assert!((r8 - 1.04).abs() < 0.05, "{r8}");
        let r4 = m.core_pj_per_op(ElementFormat::E2M1) / calib::dacapo_pj_per_op(DacapoFormat::Mx4);
        assert!((r4 - 0.9).abs() < 0.05, "{r4}");
    }

    #[test]
    fn fig7_energy_shares_narrative() {
        let m = EnergyModel::proposed();
        for fmt in ALL_ELEMENT_FORMATS {
            let b = m.pe_energy_breakdown(fmt);
            let get = |name: &str| b.components.iter().find(|(n, _)| *n == name).unwrap().1;
            // FP accumulation is the most energy-intensive component
            for (n, v) in &b.components {
                if *n != "fp_acc_adder" {
                    assert!(get("fp_acc_adder") >= *v, "{fmt:?}: {n} {v}");
                }
            }
            // shared-exponent overhead negligible (<5%)
            assert!(get("shared_exp") / b.total_pj_per_op < 0.05);
            // components sum to total
            let sum: f64 = b.components.iter().map(|(_, v)| v).sum();
            assert!((sum - b.total_pj_per_op).abs() < 1e-9 * b.total_pj_per_op.max(1.0));
        }
    }

    #[test]
    fn fig7_acc_register_asymmetry_int8_vs_fp() {
        // "the increased frequency of register data switching" in INT8
        let m = EnergyModel::proposed();
        let int8 = m.pe_energy_breakdown(ElementFormat::Int8);
        let fp8 = m.pe_energy_breakdown(ElementFormat::E4M3);
        let share = |b: &EnergyBreakdown| {
            b.components.iter().find(|(n, _)| *n == "acc_register").unwrap().1 / b.total_pj_per_op
        };
        assert!(share(&int8) > share(&fp8));
    }

    #[test]
    fn fig7_area_shares_narrative() {
        let m = EnergyModel::proposed();
        let a = m.mac_area_breakdown();
        let get = |name: &str| a.components.iter().find(|(n, _)| *n == name).unwrap().1;
        // L1 + L2 adders account for the largest portion of area
        assert!(get("l1_adder") + get("l2_adder") > 0.5 * a.total_um2);
        assert!(get("multipliers") < get("l2_adder"));
        let sum: f64 = a.components.iter().map(|(_, v)| v).sum();
        assert!((sum - a.total_um2).abs() < 1.0);
    }

    #[test]
    fn run_energy_scales_with_cycles() {
        let m = EnergyModel::proposed();
        let mut ev = Events::default();
        ev.cycles = 100;
        ev.acc_reg_toggles = 1200;
        let e100 = m.run_pj(ElementFormat::Int8, &ev);
        ev.cycles = 200;
        ev.acc_reg_toggles = 2400;
        let e200 = m.run_pj(ElementFormat::Int8, &ev);
        assert!((e200 / e100 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn area_comparison_table4() {
        // ours 6.44 vs Dacapo 8.66 mm^2 -> 25.6% area reduction
        let red = 1.0 - calib::CORE_AREA_MM2 / calib::DACAPO_AREA_MM2;
        assert!((red - 0.256).abs() < 0.01, "{red}");
        // 1.94x less bandwidth
        assert!((calib::DACAPO_BW_GBS / calib::CORE_BW_GBS - 1.94).abs() < 0.01);
    }
}
