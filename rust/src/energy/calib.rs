//! Calibration constants for the area/energy model.
//!
//! ## How these were derived
//!
//! The per-cycle energy of a MAC in mode m decomposes as
//!
//! ```text
//!   E_cycle(f, v) = g(v) * [ core(f) + n_align(f, v) * a(mode(f), v) ]
//! ```
//!
//! * `core(f)` — the mode-intrinsic datapath energy (multipliers, L1
//!   compressor, FP32 accumulation add, accumulation register, operand
//!   registers). Calibrated from Table II row 3 (the proposed
//!   ext-mantissa + bypass variant at 500 MHz), which *is* the paper's
//!   measurement of exactly this quantity.
//! * `n_align(f, v)` — how many terms traverse the L2 alignment stage:
//!   4 for FP8/FP6 always; 1 (INT8) / 2 (FP4) only in variants without
//!   the bypass network (the "initial version" of §III-B).
//! * `a(mode, v)` — alignment/normalization energy per term.
//!   `NormalizeL2` pays input normalization; `ExtMantissaNoBypass` pays
//!   oversized drive strength on the unbalanced critical path, with the
//!   whole unit inflated by `g = 1.2` (it also only closes 417 MHz).
//!
//! Fit residuals against all 18 Table II entries are <= ~4% (asserted in
//! `model::tests::table2_reproduction`).
//!
//! Core-level (Table IV) constants add SRAM traffic and array-level
//! switching effects, calibrated on the paper's three core E/op figures;
//! Dacapo-side constants come from the paper's Table IV Dacapo column
//! and the ISCA'24 paper. Fig. 7 component proportions follow the
//! paper's qualitative findings (FP accumulation dominates energy; L1+L2
//! adders dominate area).

#![forbid(unsafe_code)]

use crate::arith::{MacVariant, Mode};
use crate::mx::dacapo::DacapoFormat;
use crate::mx::element::ElementFormat;

/// Mode-intrinsic per-cycle core energy [pJ] (bypass variant, 500 MHz).
/// From Table II row 3: pJ/OP x OPs-per-cycle.
pub fn core_cycle_pj(fmt: ElementFormat) -> f64 {
    match fmt {
        ElementFormat::Int8 => 4.41,  // 1 op/cycle
        ElementFormat::E5M2 => 4.44,  // 4 ops/cycle x 1.11
        ElementFormat::E4M3 => 4.676, // 4 x 1.169
        ElementFormat::E3M2 => 4.20,  // 4 x 1.05
        ElementFormat::E2M3 => 4.52,  // 4 x 1.13
        ElementFormat::E2M1 => 3.12,  // 8 x 0.39
    }
}

/// Terms traversing L2 alignment per cycle for a format under a variant.
pub fn aligned_terms(fmt: ElementFormat, variant: MacVariant) -> u32 {
    let bypassed = variant == MacVariant::ExtMantissaBypass;
    match fmt.mac_mode() {
        Mode::Fp8Fp6 => 4,
        Mode::Int8 => {
            if bypassed {
                0
            } else {
                1
            }
        }
        Mode::Fp4 => {
            if bypassed {
                0
            } else {
                2
            }
        }
    }
}

/// Alignment / normalization energy per aligned term [pJ].
pub fn align_term_pj(mode: Mode, variant: MacVariant) -> f64 {
    match (variant, mode) {
        (MacVariant::ExtMantissaBypass, Mode::Fp8Fp6) => 0.0, // folded in core
        (MacVariant::ExtMantissaBypass, _) => 0.0,            // bypassed
        // NormalizeL2: per-input normalizer (find-MSB + shift)
        (MacVariant::NormalizeL2, Mode::Fp8Fp6) => 1.30,
        (MacVariant::NormalizeL2, Mode::Int8) => 0.67,
        (MacVariant::NormalizeL2, Mode::Fp4) => 0.16,
        // NoBypass: unbalanced critical path -> oversized drive strength
        (MacVariant::ExtMantissaNoBypass, Mode::Fp8Fp6) => 1.63,
        (MacVariant::ExtMantissaNoBypass, Mode::Int8) => 0.88,
        (MacVariant::ExtMantissaNoBypass, Mode::Fp4) => 0.63,
    }
}

/// Global inflation factor of a variant (drive strength / buffering).
pub fn variant_global_factor(variant: MacVariant) -> f64 {
    match variant {
        MacVariant::ExtMantissaBypass => 1.0,
        MacVariant::NormalizeL2 => 1.0,
        MacVariant::ExtMantissaNoBypass => 1.2,
    }
}

/// Standalone-MAC area [um^2] per variant (Table II column 2).
pub fn mac_area_um2(variant: MacVariant) -> f64 {
    match variant {
        MacVariant::NormalizeL2 => 3281.63,
        MacVariant::ExtMantissaNoBypass => 3395.00,
        MacVariant::ExtMantissaBypass => 1589.05,
    }
}

/// Component share of the proposed MAC's area (sums to 1).
/// Qualitative constraint from Fig. 7: L1 + L2 adders dominate area
/// (mode-specific datapaths), multipliers are small.
pub const AREA_SHARE: [(&str, f64); 7] = [
    ("multipliers", 0.145),
    ("l1_adder", 0.265),
    ("l2_adder", 0.275),
    ("fp_acc_adder", 0.165),
    ("acc_register", 0.085),
    ("exp_adders", 0.025),
    ("shared_exp", 0.040),
];

/// Component share of per-cycle energy by mode (sums to 1 each).
/// Qualitative constraints from Fig. 7: FP accumulation addition is the
/// most energy-intensive component; the accumulation register switches
/// *more* in INT8 mode (8 aligned partial accumulations per output vs.
/// exponent-misaligned FP adds); shared-exponent logic is negligible.
pub fn energy_share(mode: Mode) -> [(&'static str, f64); 7] {
    match mode {
        Mode::Int8 => [
            ("multipliers", 0.190),
            ("l1_adder", 0.150),
            ("l2_adder", 0.075),
            ("fp_acc_adder", 0.330),
            ("acc_register", 0.215),
            ("exp_adders", 0.000),
            ("shared_exp", 0.040),
        ],
        Mode::Fp8Fp6 => [
            ("multipliers", 0.165),
            ("l1_adder", 0.135),
            ("l2_adder", 0.200),
            ("fp_acc_adder", 0.330),
            ("acc_register", 0.105),
            ("exp_adders", 0.030),
            ("shared_exp", 0.035),
        ],
        Mode::Fp4 => [
            ("multipliers", 0.110),
            ("l1_adder", 0.190),
            ("l2_adder", 0.090),
            ("fp_acc_adder", 0.400),
            ("acc_register", 0.130),
            ("exp_adders", 0.045),
            ("shared_exp", 0.035),
        ],
    }
}

/// Core-level (4x16 grid) energy per multiplication OP [pJ]:
/// `E_core/op = mac_pj_per_op * array_factor(mode) + sram_pj_per_op`.
/// Calibrated on Table IV "ours" column: 3.20 / 1.87-1.88 / 0.43.
/// INT8's factor < 1 reflects in-array operand reuse and a constant
/// shared exponent over the 8-cycle block (less switching); FP modes
/// pay exponent-diverse alignment toggling and denser SRAM traffic.
pub fn array_factor(mode: Mode) -> f64 {
    match mode {
        Mode::Int8 => 0.669,
        Mode::Fp8Fp6 => 1.438,
        Mode::Fp4 => 0.462,
    }
}

/// SRAM / interface energy per multiplication OP at core level [pJ].
pub const SRAM_PJ_PER_OP: f64 = 0.25;

/// Our core area [mm^2] (Table IV).
pub const CORE_AREA_MM2: f64 = 6.44;
/// Dacapo core area [mm^2] (Table IV).
pub const DACAPO_AREA_MM2: f64 = 8.66;
/// Peak bandwidths [GB/s] (Table IV).
pub const CORE_BW_GBS: f64 = 330.0;
pub const DACAPO_BW_GBS: f64 = 640.0;

/// Dacapo core energy per OP [pJ] (Table IV Dacapo column; from their
/// ISCA'24 synthesis, same 16nm node).
pub fn dacapo_pj_per_op(fmt: DacapoFormat) -> f64 {
    match fmt {
        DacapoFormat::Mx9 => 3.08,
        DacapoFormat::Mx6 => 1.80,
        DacapoFormat::Mx4 => 0.48,
    }
}
