//! Storage-layer chaos: torn appends, corrupt chunks, stale locks —
//! and generation-level recovery.
//!
//! The injection seams forge the three storage faults a crashed or
//! byte-rotting writer leaves behind:
//!
//! * [`inject_shard_truncate`] — a torn append: the writer died after
//!   some of its bytes landed but before the trailer (the commit point)
//!   was complete.
//! * [`inject_chunk_flip`] — bit rot inside an already-committed chunk
//!   or index region.
//! * [`inject_stale_lock`] — the writer died *between* `try_create`
//!   and release, leaving its advisory lock behind with an old birth
//!   stamp.
//!
//! Detection needs nothing new: `read_index` / `read_chunk` already
//! surface every structural fault as [`StoreError::BadIndex`] /
//! [`StoreError::ChecksumMismatch`], and the stale lock is broken by
//! [`crate::store::StoreLock::acquire_with_staleness`].
//!
//! Recovery exploits the layout: appends are **log-structured**, so a
//! shard damaged at its tail still contains every previous generation's
//! index as dead-but-intact bytes. [`recover_generations`] scans
//! backward from EOF for valid commit points (trailer parses, index
//! region sits exactly below it, index checksum matches, every entry's
//! chunk range is in bounds) and returns them newest-first;
//! [`assemble_from_generation`] then rebuilds a checkpoint from an
//! older generation's entries, chunk checksums still enforced — so the
//! recovered checkpoint is bitwise the one that generation committed,
//! never a guess.

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::{Duration, SystemTime};

use crate::store::shard::{read_chunk, IndexEntry, ShardTrailer, ENTRY_BYTES, TRAILER_BYTES};
use crate::store::{Storage, StoreError};
use crate::trainer::checkpoint::Checkpoint;
use crate::util::bytes::{fnv1a64, ByteReader};

use super::ChaosError;

fn store_fault(object: &str, source: StoreError) -> ChaosError {
    ChaosError::Store { object: object.to_string(), source }
}

/// Tear `shard` down to its first `keep` bytes — the image a writer
/// that crashed mid-append leaves behind. Plan-gated: only chaos
/// drills and tests call this.
pub fn inject_shard_truncate(
    store: &dyn Storage,
    shard: &str,
    keep: usize,
) -> Result<(), ChaosError> {
    let whole = store.get(shard).map_err(|e| store_fault(shard, e))?;
    let keep = keep.min(whole.len());
    store.put(shard, &whole[..keep]).map_err(|e| store_fault(shard, e))
}

/// Flip one bit of one byte of `object` — bit rot in a committed
/// region. Errors (structured, not panicking) when `offset` is out of
/// bounds. Plan-gated like [`inject_shard_truncate`].
pub fn inject_chunk_flip(
    store: &dyn Storage,
    object: &str,
    offset: usize,
    bit: u8,
) -> Result<(), ChaosError> {
    let mut bytes = store.get(object).map_err(|e| store_fault(object, e))?;
    if offset >= bytes.len() {
        return Err(ChaosError::Plan {
            reason: format!(
                "chunk flip at byte {offset} of `{object}` ({} bytes)",
                bytes.len()
            ),
        });
    }
    bytes[offset] ^= 1u8 << (bit % u8::BITS as u8);
    store.put(object, &bytes).map_err(|e| store_fault(object, e))
}

/// Forge the lock a writer that crashed `age` ago left on `shard` —
/// birth-stamped in the past so the staleness takeover can prove it
/// breaks crashed locks without waiting out real wall-clock time.
pub fn inject_stale_lock(
    store: &dyn Storage,
    shard: &str,
    age: Duration,
) -> Result<(), ChaosError> {
    let key = format!("{shard}.lock");
    let birth = SystemTime::now() - age;
    store.erase(&key).map_err(|e| store_fault(&key, e))?;
    let bytes = crate::store::lock::stamped_lock_bytes(birth);
    if !store.try_create(&key, &bytes).map_err(|e| store_fault(&key, e))? {
        return Err(ChaosError::Plan { reason: format!("lock `{key}` reappeared mid-injection") });
    }
    Ok(())
}

/// One committed shard generation found by the backward scan: the byte
/// offset just past its trailer and the index entries it committed.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardGeneration {
    /// End offset (exclusive) of this generation's trailer.
    pub end: u64,
    /// The generation's full index (sorted, deduped — as committed).
    pub entries: Vec<IndexEntry>,
}

/// Try to parse a committed generation whose trailer ends exactly at
/// `end` within `bytes`. Every check the live reader makes is repeated
/// here against the historical region.
fn generation_at(bytes: &[u8], end: usize) -> Option<ShardGeneration> {
    if end < TRAILER_BYTES {
        return None;
    }
    let trailer = ShardTrailer::read_bytes(&mut ByteReader::new(&bytes[end - TRAILER_BYTES..end]))
        .ok()?;
    let index_len = (trailer.n_entries as usize).checked_mul(ENTRY_BYTES)?;
    let index_off = usize::try_from(trailer.index_off).ok()?;
    if index_off.checked_add(index_len)? != end - TRAILER_BYTES {
        return None;
    }
    let index_bytes = &bytes[index_off..index_off + index_len];
    if fnv1a64(index_bytes) != trailer.index_checksum {
        return None;
    }
    let mut r = ByteReader::new(index_bytes);
    let mut entries = Vec::with_capacity(trailer.n_entries as usize);
    for _ in 0..trailer.n_entries {
        let e = IndexEntry::read_bytes(&mut r).ok()?;
        // a committed generation's chunks all live below its index
        let chunk_end = e.offset.checked_add(e.len)?;
        if chunk_end > trailer.index_off {
            return None;
        }
        entries.push(e);
    }
    Some(ShardGeneration { end: end as u64, entries })
}

/// Scan `shard` backward from EOF for committed generations,
/// newest-first. The scan walks candidate trailer ends one byte at a
/// time (a torn append can shear at any offset), validating each
/// candidate exactly as the live reader would; after a hit it jumps to
/// that generation's index offset, since anything between belongs to
/// the generation just found. An empty result means no generation ever
/// committed (or the damage reached all of them).
pub fn recover_generations(
    store: &dyn Storage,
    shard: &str,
) -> Result<Vec<ShardGeneration>, ChaosError> {
    let bytes = store.get(shard).map_err(|e| store_fault(shard, e))?;
    let mut generations = Vec::new();
    let mut end = bytes.len();
    while end >= TRAILER_BYTES {
        match generation_at(&bytes, end) {
            Some(generation) => {
                // anything between this generation's index offset and
                // its trailer belongs to *this* generation; the previous
                // trailer ends at or below the index offset (appends
                // start at the prior EOF), so resume the scan there
                end -= TRAILER_BYTES + generation.entries.len() * ENTRY_BYTES;
                generations.push(generation);
            }
            None => end -= 1,
        }
    }
    Ok(generations)
}

/// Rebuild session `id`'s checkpoint from one recovered generation's
/// entries, chunk checksums still enforced — the result is bitwise the
/// checkpoint that generation committed. Chunks named by the index but
/// damaged on disk surface as structured store errors, never as a
/// silently-wrong checkpoint.
pub fn assemble_from_generation(
    store: &dyn Storage,
    shard: &str,
    generation: &ShardGeneration,
    id: &str,
) -> Result<Checkpoint, ChaosError> {
    crate::store::chunk::assemble_checkpoint(|leaf| {
        let key = format!("{id}/{leaf}");
        let entry = generation
            .entries
            .iter()
            .find(|e| e.key == key)
            .ok_or(StoreError::MissingChunk { key: key.clone() })?;
        store.get_range(shard, entry.offset, entry.len).and_then(|bytes| {
            if fnv1a64(&bytes) != entry.checksum {
                return Err(StoreError::ChecksumMismatch { key: key.clone() });
            }
            Ok(bytes)
        })
    })
    .map_err(|e| store_fault(shard, e))
}

/// Convenience for drills: read one chunk through the *live* index
/// path, mapping store errors into the chaos taxonomy.
pub fn read_live_chunk(
    store: &dyn Storage,
    shard: &str,
    key: &str,
) -> Result<Vec<u8>, ChaosError> {
    let index = crate::store::shard::read_index(store, shard).map_err(|e| store_fault(shard, e))?;
    let entry = index
        .iter()
        .find(|e| e.key == key)
        .ok_or_else(|| store_fault(shard, StoreError::MissingChunk { key: key.to_string() }))?;
    read_chunk(store, shard, entry).map_err(|e| store_fault(shard, e))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::shard::append_chunks;
    use crate::store::MemoryStore;

    fn mem() -> Arc<dyn Storage> {
        Arc::new(MemoryStore::new())
    }

    const T: Duration = Duration::from_secs(2);

    fn two_generations(store: &Arc<dyn Storage>) -> (usize, usize) {
        let gen1 = vec![("r/one".to_string(), vec![1u8; 40]), ("r/two".to_string(), vec![2u8; 9])];
        append_chunks(store, "s.mxshard", &gen1, T).unwrap();
        let gen1_end = store.size("s.mxshard").unwrap() as usize;
        let gen2 = vec![("r/two".to_string(), vec![3u8; 21])];
        append_chunks(store, "s.mxshard", &gen2, T).unwrap();
        (gen1_end, store.size("s.mxshard").unwrap() as usize)
    }

    #[test]
    fn backward_scan_finds_every_committed_generation() {
        let store = mem();
        let (gen1_end, gen2_end) = two_generations(&store);
        let gens = recover_generations(store.as_ref(), "s.mxshard").unwrap();
        assert_eq!(gens.len(), 2, "both commit points found");
        assert_eq!(gens[0].end as usize, gen2_end, "newest first");
        assert_eq!(gens[1].end as usize, gen1_end);
        assert_eq!(gens[0].entries.len(), 2, "gen2 merged index");
        assert_eq!(gens[1].entries.len(), 2);
        let two2 = gens[0].entries.iter().find(|e| e.key == "r/two").unwrap();
        let two1 = gens[1].entries.iter().find(|e| e.key == "r/two").unwrap();
        assert_eq!(two2.len, 21, "newest generation sees the rewrite");
        assert_eq!(two1.len, 9, "old generation still names the original bytes");
    }

    #[test]
    fn torn_append_recovers_the_previous_generation() {
        let store = mem();
        let (gen1_end, gen2_end) = two_generations(&store);
        // shear the second append at every byte between the commits:
        // the live reader must fail structured, the scan must still
        // find generation 1, and its chunks must read back bitwise
        for cut in [gen1_end + 1, (gen1_end + gen2_end) / 2, gen2_end - 1] {
            store.put("torn.mxshard", &store.get("s.mxshard").unwrap()[..cut]).unwrap();
            let live = crate::store::shard::read_index(store.as_ref(), "torn.mxshard");
            assert!(matches!(live, Err(StoreError::BadIndex { .. })), "cut {cut}: {live:?}");
            let gens = recover_generations(store.as_ref(), "torn.mxshard").unwrap();
            assert_eq!(gens[0].end as usize, gen1_end, "cut {cut}");
            let one = gens[0].entries.iter().find(|e| e.key == "r/one").unwrap();
            let bytes = store.get_range("torn.mxshard", one.offset, one.len).unwrap();
            assert_eq!(fnv1a64(&bytes), one.checksum, "cut {cut}: gen1 chunk intact");
        }
    }

    #[test]
    fn injection_seams_are_bounded_and_structured() {
        let store = mem();
        two_generations(&store);
        let size = store.size("s.mxshard").unwrap() as usize;
        let err = inject_chunk_flip(store.as_ref(), "s.mxshard", size, 0).unwrap_err();
        assert!(matches!(err, ChaosError::Plan { .. }), "{err}");
        let err = inject_shard_truncate(store.as_ref(), "missing.mxshard", 0).unwrap_err();
        assert!(matches!(err, ChaosError::Store { .. }), "{err}");

        inject_chunk_flip(store.as_ref(), "s.mxshard", 3, 7).unwrap();
        let err = read_live_chunk(store.as_ref(), "s.mxshard", "r/one").unwrap_err();
        assert!(
            matches!(
                &err,
                ChaosError::Store { source: StoreError::ChecksumMismatch { key }, .. }
                    if key == "r/one"
            ),
            "{err}"
        );
    }

    #[test]
    fn stale_lock_injection_parks_strict_writers_but_not_takeover() {
        let store = mem();
        two_generations(&store);
        inject_stale_lock(store.as_ref(), "s.mxshard", Duration::from_secs(3600)).unwrap();
        // a strict append (no takeover) would park; the production path
        // (append_chunks) uses the staleness-aware acquire and proceeds
        let gen3 = vec![("r/three".to_string(), vec![7u8; 4])];
        append_chunks(&store, "s.mxshard", &gen3, Duration::from_millis(200)).unwrap();
        let index = crate::store::shard::read_index(store.as_ref(), "s.mxshard").unwrap();
        assert!(index.iter().any(|e| e.key == "r/three"), "append proceeded past the stale lock");
        assert!(!store.exists("s.mxshard.lock").unwrap(), "fresh lock released");
    }
}
