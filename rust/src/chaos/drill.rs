//! The CLI-facing chaos drill: one deterministic pass over every fault
//! class a [`FaultPlan`] covers, returning a record per injected fault.
//!
//! `mxscale fleet --chaos <spec>` runs this and prints one line per
//! record; CI greps the lines. Each record carries a [`FaultOutcome`]
//! — so a drill that "passes" has, for every fault, either a structured
//! detection naming the site or a machine-checked bit-identity proof of
//! recovery. Any third ending (panic, silent divergence) fails the
//! drill with a [`ChaosError`].

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::Duration;

use crate::mx::ALL_ELEMENT_FORMATS;
use crate::serve::admission::{BudgetAware, SessionOffer};
use crate::serve::executor::{serve, Arrival, ServeConfig};
use crate::store::shard::append_chunks;
use crate::store::{chunk, CheckpointStore, MemoryStore, Storage, StoreLayout};
use crate::trainer::session::{TrainConfig, TrainSession};
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;
use crate::workloads::{by_name, Dataset};

use super::memory::GuardedTensor;
use super::storage::{
    inject_chunk_flip, inject_shard_truncate, inject_stale_lock, recover_generations,
    read_live_chunk,
};
use super::{
    prove_bit_identical, ChaosError, FaultClass, FaultOutcome, FaultPlan,
};

/// One injected fault and how it ended.
#[derive(Debug, Clone)]
pub struct DrillRecord {
    pub class: FaultClass,
    /// Human-readable fault label (`CI greps describe() lines`).
    pub label: String,
    pub outcome: FaultOutcome,
}

impl DrillRecord {
    /// The one-line form the CLI prints and CI greps.
    pub fn describe(&self) -> String {
        format!("chaos[{}] {}: {}", self.class.name(), self.label, self.outcome.describe())
    }
}

fn detected(class: FaultClass, label: &str, site: String, error: String) -> DrillRecord {
    DrillRecord {
        class,
        label: label.to_string(),
        outcome: FaultOutcome::Detected { site, error },
    }
}

// ---------------------------------------------------------------- memory

fn memory_drill(plan: &FaultPlan, out: &mut Vec<DrillRecord>) -> Result<(), ChaosError> {
    let mut rng = Pcg64::new(plan.seed).split("chaos-memory");
    for (layer, &fmt) in ALL_ELEMENT_FORMATS.iter().enumerate() {
        let master = Mat::from_fn(24, 17, |_, _| rng.wide_f32());
        let mut g = GuardedTensor::quantize(layer, &master, fmt);
        let (brow, bcol) = (
            rng.below(g.packed().brows as u64) as usize,
            rng.below(g.packed().bcols as u64) as usize,
        );
        // alternate lane-bit and scale-bit faults across the formats so
        // one drill covers both injection seams
        if layer % 2 == 0 {
            g.inject_lane_flip(brow, bcol, rng.below(8) as usize, rng.below(63) as u32);
        } else {
            g.inject_scale_flip(brow, bcol, rng.below(8) as u32);
        }
        let err = g.verify().err().ok_or_else(|| ChaosError::Plan {
            reason: format!("{fmt:?}: injected flip at ({brow},{bcol}) went undetected"),
        })?;
        out.push(detected(
            FaultClass::Memory,
            &format!("{fmt:?} bit flip"),
            format!("layer {layer} block ({brow}, {bcol})"),
            err.to_string(),
        ));
        let recovered = g.recover()?;
        out.push(DrillRecord {
            class: FaultClass::Memory,
            label: format!("{fmt:?} requantize"),
            outcome: recovered,
        });
    }
    Ok(())
}

// --------------------------------------------------------------- storage

const LOCK_T: Duration = Duration::from_secs(2);

/// A small deterministic training session whose checkpoints seed the
/// storage drill's shard generations.
fn drill_session(seed: u64) -> Result<TrainSession, ChaosError> {
    let env = by_name("cartpole")
        .ok_or_else(|| ChaosError::Plan { reason: "cartpole workload missing".into() })?;
    let ds = Dataset::collect(env.as_ref(), 2, 20, seed);
    let config = TrainConfig {
        dims: Some(vec![32, 8, 32]),
        batch_size: 8,
        steps: 8,
        eval_every: usize::MAX,
        seed,
        ..Default::default()
    };
    TrainSession::try_new(ds, config)
        .map_err(|e| ChaosError::Plan { reason: format!("drill session: {e}") })
}

fn storage_drill(plan: &FaultPlan, out: &mut Vec<DrillRecord>) -> Result<(), ChaosError> {
    let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
    let shard = "chaos.mxshard";
    let id = "drill";
    let mut session = drill_session(plan.seed)?;

    // generation 1: the committed state a torn generation 2 falls back to
    let ck1 = session.save_checkpoint();
    let chunks1: Vec<(String, Vec<u8>)> = chunk::split_checkpoint(&ck1)
        .into_iter()
        .map(|(leaf, bytes)| (format!("{id}/{leaf}"), bytes))
        .collect();
    append_chunks(&store, shard, &chunks1, LOCK_T)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })?;
    let gen1_end = store
        .size(shard)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })? as usize;

    // generation 2: a few steps later
    for _ in 0..3 {
        session.step_once();
    }
    let ck2 = session.save_checkpoint();
    let chunks2: Vec<(String, Vec<u8>)> = chunk::split_checkpoint(&ck2)
        .into_iter()
        .map(|(leaf, bytes)| (format!("{id}/{leaf}"), bytes))
        .collect();
    append_chunks(&store, shard, &chunks2, LOCK_T)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })?;
    let gen2_end = store
        .size(shard)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })? as usize;
    let pristine = store
        .get(shard)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })?;

    // ---- fault: torn append (truncate inside generation 2) ----------
    let mut rng = Pcg64::new(plan.seed).split("chaos-storage");
    let cut = gen1_end + 1 + rng.below((gen2_end - gen1_end - 1) as u64) as usize;
    inject_shard_truncate(store.as_ref(), shard, cut)?;
    let live = crate::store::shard::read_index(store.as_ref(), shard);
    let err = live.err().ok_or_else(|| ChaosError::Plan {
        reason: format!("torn shard (cut {cut}) read back a live index"),
    })?;
    out.push(detected(
        FaultClass::Storage,
        "torn append",
        format!("{shard} cut at byte {cut}"),
        err.to_string(),
    ));
    // recovery: backward-scan to the previous committed generation and
    // rebuild the checkpoint it committed, bit-for-bit
    let gens = recover_generations(store.as_ref(), shard)?;
    let gen1 = gens.first().ok_or_else(|| ChaosError::Plan {
        reason: format!("no committed generation survives a cut at {cut}"),
    })?;
    let recovered = super::storage::assemble_from_generation(store.as_ref(), shard, gen1, id)?;
    let site = format!("{shard} generation ending at {}", gen1.end);
    let proof = prove_bit_identical(&site, &recovered.to_bytes(), &ck1.to_bytes())?;
    out.push(DrillRecord {
        class: FaultClass::Storage,
        label: "previous-generation rebuild".into(),
        outcome: FaultOutcome::Recovered { site, proof },
    });

    // ---- fault: bit rot in a committed chunk ------------------------
    store
        .put(shard, &pristine)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })?;
    // flip inside generation 2's chunk region: the live index still
    // reads, the chunk fetch must fail its checksum
    let flip_at = gen1_end + rng.below((chunks2[0].1.len().max(2) - 1) as u64) as usize;
    inject_chunk_flip(store.as_ref(), shard, flip_at, rng.below(8) as u8)?;
    let key = &chunks2[0].0;
    let err = read_live_chunk(store.as_ref(), shard, key).err().ok_or_else(|| {
        ChaosError::Plan { reason: format!("flipped byte {flip_at} of `{key}` went undetected") }
    })?;
    out.push(detected(
        FaultClass::Storage,
        "chunk bit rot",
        format!("{shard} byte {flip_at} (`{key}`)"),
        err.to_string(),
    ));
    // recovery: generation 1 still holds the key's previous committed
    // bytes — rebuild from it and prove against checkpoint 1
    let gens = recover_generations(store.as_ref(), shard)?;
    let gen1 = gens
        .iter()
        .find(|g| g.end as usize == gen1_end)
        .ok_or_else(|| ChaosError::Plan { reason: "generation 1 lost to a chunk flip".into() })?;
    let recovered = super::storage::assemble_from_generation(store.as_ref(), shard, gen1, id)?;
    let site = format!("{shard} generation ending at {gen1_end}");
    let proof = prove_bit_identical(&site, &recovered.to_bytes(), &ck1.to_bytes())?;
    out.push(DrillRecord {
        class: FaultClass::Storage,
        label: "previous-generation rebuild after bit rot".into(),
        outcome: FaultOutcome::Recovered { site, proof },
    });

    // ---- fault: crashed lock-holder ---------------------------------
    store
        .put(shard, &pristine)
        .map_err(|e| ChaosError::Store { object: shard.into(), source: e })?;
    inject_stale_lock(store.as_ref(), shard, Duration::from_secs(3600))?;
    let gen3 = vec![(format!("{id}/probe"), b"after-takeover".to_vec())];
    append_chunks(&store, shard, &gen3, LOCK_T)
        .map_err(|e| ChaosError::Store { object: format!("{shard}.lock"), source: e })?;
    let read_back = read_live_chunk(store.as_ref(), shard, &gen3[0].0)?;
    let site = format!("{shard}.lock stale takeover");
    let proof = prove_bit_identical(&site, &read_back, &gen3[0].1)?;
    out.push(DrillRecord {
        class: FaultClass::Storage,
        label: "stale lock takeover".into(),
        outcome: FaultOutcome::Recovered { site, proof },
    });
    Ok(())
}

// -------------------------------------------------------------- executor

/// Little-endian byte image of a loss curve, for bit-identity proofs.
fn curve_bytes(curve: &[(usize, f64)]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(curve.len() * 16);
    for (step, loss) in curve {
        bytes.extend_from_slice(&(*step as u64).to_le_bytes());
        bytes.extend_from_slice(&loss.to_bits().to_le_bytes());
    }
    bytes
}

fn executor_drill(plan: &FaultPlan, out: &mut Vec<DrillRecord>) -> Result<(), ChaosError> {
    let env = by_name("cartpole")
        .ok_or_else(|| ChaosError::Plan { reason: "cartpole workload missing".into() })?;
    let ds = Dataset::collect(env.as_ref(), 2, 20, plan.seed);
    // pick ids until the plan faults at least 3 and spares at least 3 —
    // robust for any seed, still fully deterministic
    let mut ids = Vec::new();
    let (mut faulted, mut spared) = (0usize, 0usize);
    for i in 0.. {
        let id = format!("drill-{i:03}");
        match plan.executor_fault(&id) {
            Some(_) if faulted < 3 => {
                faulted += 1;
                ids.push(id);
            }
            None if spared < 3 => {
                spared += 1;
                ids.push(id);
            }
            _ => {}
        }
        if faulted == 3 && spared == 3 {
            break;
        }
    }
    let spec_for = |id: &str| {
        let config = TrainConfig {
            dims: Some(vec![32, 8, 32]),
            batch_size: 8,
            steps: 6,
            eval_every: usize::MAX,
            seed: plan.seed ^ crate::util::bytes::fnv1a64(id.as_bytes()),
            ..Default::default()
        };
        crate::fleet::spec::SessionSpec::new(id, "cartpole", ds.clone(), config)
    };
    let store = Arc::new(CheckpointStore::new(
        Arc::new(MemoryStore::new()),
        StoreLayout::Sharded { shards: 2 },
    ));
    let arrivals: Vec<Arrival> = ids
        .iter()
        .map(|id| Arrival {
            offer: SessionOffer { id: id.clone(), priority: 1, budget_steps: 6 },
            spec: spec_for(id),
        })
        .collect();
    let cfg = ServeConfig {
        workers: 2,
        quantum: 2,
        store: Some(store),
        chaos: Some(plan.clone()),
        ..Default::default()
    };
    let served = serve(arrivals.into_iter(), &BudgetAware::default(), &cfg)
        .map_err(|e| ChaosError::Session { id: "<serve>".into(), reason: e.to_string() })?;
    if served.stats.recovered != 3 {
        return Err(ChaosError::Plan {
            reason: format!("planned 3 executor faults, recovered {}", served.stats.recovered),
        });
    }
    for id in &ids {
        let done = served.completed.iter().find(|s| s.id == *id).ok_or_else(|| {
            ChaosError::Session { id: id.clone(), reason: "did not complete".into() }
        })?;
        if let Some(e) = done.error() {
            return Err(ChaosError::Session { id: id.clone(), reason: e.to_string() });
        }
        // fault-free twin, standalone: curves must match bit for bit
        let mut twin = spec_for(id)
            .build()
            .map_err(|e| ChaosError::Session { id: id.clone(), reason: e.to_string() })?;
        while twin.run_quantum(cfg.quantum) > 0 {}
        let site = format!("session `{id}` train curve");
        let proof = prove_bit_identical(
            &site,
            &curve_bytes(&done.session().train_curve),
            &curve_bytes(&twin.session().train_curve),
        )?;
        let label = match plan.executor_fault(id) {
            Some(fault) => format!("{fault:?} replay"),
            None => "spared bystander".to_string(),
        };
        out.push(DrillRecord {
            class: FaultClass::Executor,
            label,
            outcome: FaultOutcome::Recovered { site, proof },
        });
    }
    Ok(())
}

/// Run every fault class `plan` covers, in a fixed order, against
/// self-contained in-memory targets. Returns one record per injected
/// fault — each a detection naming its site or a proven bit-identical
/// recovery — or the first [`ChaosError`] if any fault ended a third
/// way.
pub fn run_chaos_drill(plan: &FaultPlan) -> Result<Vec<DrillRecord>, ChaosError> {
    let mut out = Vec::new();
    if plan.covers(FaultClass::Memory) {
        memory_drill(plan, &mut out)?;
    }
    if plan.covers(FaultClass::Storage) {
        storage_drill(plan, &mut out)?;
    }
    if plan.covers(FaultClass::Executor) {
        executor_drill(plan, &mut out)?;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_and_storage_drills_detect_then_recover() {
        let plan = FaultPlan::new(&[FaultClass::Memory, FaultClass::Storage], 0xD1AB0);
        let records = run_chaos_drill(&plan).expect("drill completes");
        assert!(records.len() >= 12 + 5, "{} records", records.len());
        let detected = records
            .iter()
            .filter(|r| matches!(r.outcome, FaultOutcome::Detected { .. }))
            .count();
        let recovered = records.len() - detected;
        assert!(detected >= 8, "{detected} detections");
        assert!(recovered >= 8, "{recovered} recoveries");
        for r in &records {
            assert!(!r.outcome.site().is_empty(), "{}", r.describe());
        }
    }

    #[test]
    fn executor_drill_recovers_bit_identically() {
        let plan = FaultPlan::new(&[FaultClass::Executor], 0xD1AB0);
        let records = run_chaos_drill(&plan).expect("executor drill completes");
        assert_eq!(records.len(), 6, "3 faulted + 3 spared sessions");
        assert!(records.iter().all(|r| matches!(r.outcome, FaultOutcome::Recovered { .. })));
        assert!(records.iter().any(|r| r.label.contains("WorkerCrash")
            || r.label.contains("SessionPanic")));
        assert!(records.iter().any(|r| r.label == "spared bystander"));
    }
}
