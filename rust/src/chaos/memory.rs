//! Memory-layer chaos: bit flips in live packed MX tensors.
//!
//! A [`GuardedTensor`] wraps one layer's [`PackedTensor`] with its
//! recorded per-block FNV-1a checksums
//! ([`PackedTensor::block_checksums`]) and the FP32 master it was
//! quantized from. The injection seams flip exactly one bit in a code
//! lane or a scale byte — the two places a radiation event (or DMA bug)
//! hurts an MX tensor, and the scale byte is the nasty one: a single
//! flipped bit of shared exponent rescales all 64 elements of the
//! block.
//!
//! Detection is [`GuardedTensor::verify`]: O(blocks) checksum sweep
//! naming the exact `(layer, brow, bcol)` site. Recovery is
//! [`GuardedTensor::recover`]: re-quantize the afflicted layer from the
//! FP32 master. Because quantization is deterministic and idempotent
//! (fq∘fq == fq — `tests/formats.rs` pins it), the rebuilt tensor is
//! **bitwise identical** to a never-corrupted one, and the returned
//! [`FaultOutcome::Recovered`] carries the [`prove_bit_identical`]
//! proof over the full packed byte image to show it.

#![forbid(unsafe_code)]

use crate::mx::element::ElementFormat;
use crate::mx::packed::{BlockCorruption, PackedTensor};
use crate::util::mat::Mat;

use super::{prove_bit_identical, ChaosError, FaultOutcome};

/// Serialize a packed tensor's fault-relevant bytes — every code lane
/// (little-endian) then every scale byte — the image bit-identity
/// proofs compare.
pub fn packed_image(p: &PackedTensor) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(p.storage_bytes());
    for lane in &p.lanes {
        bytes.extend_from_slice(&lane.to_le_bytes());
    }
    bytes.extend(p.scales.iter().map(|s| *s as u8));
    bytes
}

/// One layer's packed tensor guarded by recorded block checksums and
/// backed by its FP32 master for bit-exact recovery.
#[derive(Debug, Clone)]
pub struct GuardedTensor {
    layer: usize,
    format: ElementFormat,
    master: Mat,
    packed: PackedTensor,
    recorded: Vec<u64>,
    pristine: Vec<u8>,
}

impl GuardedTensor {
    /// Quantize `master` into a guarded packed tensor, recording the
    /// per-block checksums and the pristine byte image the recovery
    /// proof will compare against.
    pub fn quantize(layer: usize, master: &Mat, format: ElementFormat) -> GuardedTensor {
        let packed = PackedTensor::quantize_pack(master, format);
        let recorded = packed.block_checksums();
        let pristine = packed_image(&packed);
        GuardedTensor { layer, format, master: master.clone(), packed, recorded, pristine }
    }

    /// The (possibly corrupted) packed tensor.
    pub fn packed(&self) -> &PackedTensor {
        &self.packed
    }

    /// Which layer this tensor belongs to (named in detection errors).
    pub fn layer(&self) -> usize {
        self.layer
    }

    /// Flip one bit of one code lane of block `(brow, bcol)` — a
    /// corrupted element code. Plan-gated: only chaos drills and tests
    /// call this.
    pub fn inject_lane_flip(&mut self, brow: usize, bcol: usize, lane: usize, bit: u32) {
        let t = (brow * self.packed.bcols + bcol) * crate::mx::tensor::SQ + lane;
        self.packed.lanes[t] ^= 1u64 << bit;
    }

    /// Flip one bit of block `(brow, bcol)`'s shared-exponent byte —
    /// the worst single-bit fault an MX tensor admits, rescaling all 64
    /// elements at once. Plan-gated like [`Self::inject_lane_flip`].
    pub fn inject_scale_flip(&mut self, brow: usize, bcol: usize, bit: u32) {
        let t = brow * self.packed.bcols + bcol;
        self.packed.scales[t] = (self.packed.scales[t] as u8 ^ (1u8 << bit)) as i8;
    }

    /// Checksum sweep: `Ok` when every block still matches its recorded
    /// sum, else [`ChaosError::BlockCorrupt`] naming the exact site.
    pub fn verify(&self) -> Result<(), ChaosError> {
        match self.packed.verify_block_checksums(&self.recorded) {
            Ok(()) => Ok(()),
            Err(BlockCorruption::Block { brow, bcol }) => {
                Err(ChaosError::BlockCorrupt { layer: self.layer, brow, bcol })
            }
            Err(BlockCorruption::ShapeMismatch { recorded, blocks }) => Err(ChaosError::Plan {
                reason: format!(
                    "layer {}: recorded {recorded} checksums for {blocks} blocks",
                    self.layer
                ),
            }),
        }
    }

    /// Re-quantize from the FP32 master, verify every block checksum
    /// reproduces, and prove the rebuilt image bit-identical to the
    /// pristine one. fq∘fq == fq makes this exact — recovery is a
    /// *proof*, not a best effort.
    pub fn recover(&mut self) -> Result<FaultOutcome, ChaosError> {
        self.packed = PackedTensor::quantize_pack(&self.master, self.format);
        self.verify()?;
        let site = format!("layer {} ({:?} packed image)", self.layer, self.format);
        let proof = prove_bit_identical(&site, &packed_image(&self.packed), &self.pristine)?;
        Ok(FaultOutcome::Recovered { site, proof })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;
    use crate::util::rng::Pcg64;

    #[test]
    fn flip_detect_recover_is_bit_exact_for_every_format() {
        let mut rng = Pcg64::new(0x5EED);
        for (layer, &fmt) in ALL_ELEMENT_FORMATS.iter().enumerate() {
            let master = Mat::from_fn(17, 11, |_, _| rng.wide_f32());
            let mut g = GuardedTensor::quantize(layer, &master, fmt);
            g.verify().expect("pristine tensor verifies");

            let (brow, bcol) = (
                rng.below(g.packed().brows as u64) as usize,
                rng.below(g.packed().bcols as u64) as usize,
            );
            g.inject_lane_flip(brow, bcol, rng.below(8) as usize, rng.below(63) as u32);
            assert_eq!(
                g.verify(),
                Err(ChaosError::BlockCorrupt { layer, brow, bcol }),
                "{fmt:?} lane flip must name its exact site"
            );

            let outcome = g.recover().expect("recovery is bit-exact");
            assert!(matches!(outcome, FaultOutcome::Recovered { .. }), "{fmt:?}");
            g.verify().expect("recovered tensor verifies");

            // the scale byte is the high-blast-radius fault: same contract
            g.inject_scale_flip(brow, bcol, rng.below(8) as u32);
            assert_eq!(g.verify(), Err(ChaosError::BlockCorrupt { layer, brow, bcol }), "{fmt:?}");
            let outcome = g.recover().expect("scale recovery is bit-exact");
            assert_eq!(outcome.site(), format!("layer {layer} ({fmt:?} packed image)"));
        }
    }

    #[test]
    fn packed_image_covers_every_lane_and_scale_byte() {
        let mut rng = Pcg64::new(9);
        let master = Mat::from_fn(9, 9, |_, _| rng.wide_f32());
        let p = PackedTensor::quantize_pack(&master, ElementFormat::E4M3);
        let img = packed_image(&p);
        assert_eq!(img.len(), p.storage_bytes());
        assert_eq!(img.len(), p.lanes.len() * 8 + p.scales.len());
    }
}
