//! Deterministic fault injection: detect, recover, and prove
//! bit-identity under hostile conditions (DESIGN.md §13).
//!
//! The paper's edge-training story assumes robots running unattended in
//! the field, where flipped bits in packed MX codes, torn shard writes,
//! and mid-step worker crashes are facts of life — and the
//! shared-exponent encoding makes a single corrupted E8M0 scale byte
//! catastrophic for a whole 8×8 block. This module turns the repo's
//! bit-identity test culture into a resilience story, with seams at
//! three layers:
//!
//! * **memory** ([`memory`]) — bit flips in [`crate::mx::packed::PackedTensor`]
//!   code lanes and per-block scale bytes, detected by per-block FNV-1a
//!   checksums ([`crate::mx::packed::PackedTensor::block_checksums`])
//!   and recovered by re-quantizing the afflicted layer from its FP32
//!   master — bitwise equal to a never-corrupted run, since fq∘fq == fq.
//! * **storage** ([`storage`]) — truncated shards, flipped chunk bytes,
//!   and a crashed lock-holder's stale lock, detected by the store's
//!   existing `BadIndex`/`ChecksumMismatch` paths and recovered by
//!   re-reading the previous committed shard generation (appends are
//!   log-structured — the old index survives as dead bytes) or by the
//!   staleness takeover in [`crate::store::StoreLock`].
//! * **executor** — a worker "crash" mid-quantum and a session panic,
//!   injected by the serving executor's plan-gated seam
//!   ([`crate::serve::ServeConfig`]), recovered by re-admitting the
//!   session from its last checkpoint with `ServeStats.recovered`
//!   accounting.
//!
//! **The contract:** every fault class ends in exactly one of two
//! outcomes — [`FaultOutcome::Detected`] (a structured error naming the
//! fault site) or [`FaultOutcome::Recovered`] (carrying a
//! [`BitIdentity`] proof, constructible only through
//! [`prove_bit_identical`], that the recovered state equals the
//! fault-free twin byte for byte). There is no third variant: silent
//! corruption is unrepresentable in the type.
//!
//! Determinism: a [`FaultPlan`] is seeded; the same plan injects the
//! same faults at the same sites, so every chaos test (and the CLI
//! drill, `mxscale fleet --chaos`) replays exactly. All `inject_*`
//! seams are plan-gated and exercised from `rust/tests/` — mxlint rule
//! L9 pins both properties.

#![forbid(unsafe_code)]

pub mod drill;
pub mod memory;
pub mod storage;

pub use drill::{run_chaos_drill, DrillRecord};
pub use memory::GuardedTensor;
pub use storage::{
    inject_chunk_flip, inject_shard_truncate, inject_stale_lock, recover_generations,
    ShardGeneration,
};

use crate::store::StoreError;
use crate::util::bytes::fnv1a64;

/// Seed a [`FaultPlan`] uses when the CLI spec names none.
pub const DEFAULT_CHAOS_SEED: u64 = 0xC0FFEE;

/// The three injection layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultClass {
    /// Bit flips in live packed MX tensors (code lanes, scale bytes).
    Memory,
    /// Torn shard appends, corrupt chunk bytes, stale writer locks.
    Storage,
    /// Worker crashes and session panics mid-quantum.
    Executor,
}

impl FaultClass {
    /// Canonical CLI spelling.
    pub fn name(&self) -> &'static str {
        match self {
            FaultClass::Memory => "mem",
            FaultClass::Storage => "storage",
            FaultClass::Executor => "exec",
        }
    }
}

/// Which executor fault a plan assigns to one session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecFault {
    /// The worker loses the in-memory session mid-quantum (no unwind).
    WorkerCrash,
    /// The session panics; the worker catches the unwind.
    SessionPanic,
}

/// A seeded, deterministic fault plan: which layers to attack and the
/// seed every site/trigger choice derives from. The same plan replays
/// the same faults — chaos runs are as reproducible as everything else
/// in this repo.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    /// Seed for every site/trigger decision the plan makes.
    pub seed: u64,
    classes: Vec<FaultClass>,
}

impl FaultPlan {
    /// A plan covering `classes` (deduplicated, order-insensitive).
    pub fn new(classes: &[FaultClass], seed: u64) -> FaultPlan {
        let mut classes = classes.to_vec();
        classes.sort();
        classes.dedup();
        FaultPlan { seed, classes }
    }

    /// A plan covering every layer.
    pub fn all(seed: u64) -> FaultPlan {
        FaultPlan::new(&[FaultClass::Memory, FaultClass::Storage, FaultClass::Executor], seed)
    }

    /// Parse a CLI spec: comma-separated classes (`mem`, `storage`,
    /// `exec`, or `all`), optionally `@seed` (decimal or `0x` hex).
    /// `None` on anything else — the CLI folds that into a structured
    /// flag error.
    pub fn parse(spec: &str) -> Option<FaultPlan> {
        let (classes_part, seed) = match spec.split_once('@') {
            Some((c, s)) => {
                let seed = match s.strip_prefix("0x") {
                    Some(hex) => u64::from_str_radix(hex, 16).ok()?,
                    None => s.parse::<u64>().ok()?,
                };
                (c, seed)
            }
            None => (spec, DEFAULT_CHAOS_SEED),
        };
        let mut classes = Vec::new();
        for part in classes_part.split(',') {
            match part {
                "mem" | "memory" => classes.push(FaultClass::Memory),
                "storage" | "store" => classes.push(FaultClass::Storage),
                "exec" | "executor" => classes.push(FaultClass::Executor),
                "all" => {
                    classes.extend([FaultClass::Memory, FaultClass::Storage, FaultClass::Executor])
                }
                _ => return None,
            }
        }
        if classes.is_empty() {
            return None;
        }
        Some(FaultPlan::new(&classes, seed))
    }

    /// Canonical spelling; `FaultPlan::parse(plan.name())` round-trips.
    pub fn name(&self) -> String {
        let classes: Vec<&str> = self.classes.iter().map(|c| c.name()).collect();
        format!("{}@{}", classes.join(","), self.seed)
    }

    /// Whether this plan attacks `class`.
    pub fn covers(&self, class: FaultClass) -> bool {
        self.classes.contains(&class)
    }

    /// The executor fault (if any) this plan assigns to session `id`.
    /// Deterministic in (seed, id); roughly half of all ids are spared,
    /// a quarter crash, a quarter panic.
    pub fn executor_fault(&self, id: &str) -> Option<ExecFault> {
        if !self.covers(FaultClass::Executor) {
            return None;
        }
        match (fnv1a64(id.as_bytes()) ^ self.seed) & 3 {
            0 => Some(ExecFault::WorkerCrash),
            1 => Some(ExecFault::SessionPanic),
            _ => None,
        }
    }
}

/// Structured chaos failure: every variant names the exact fault site.
#[derive(Debug, Clone, PartialEq)]
pub enum ChaosError {
    /// A packed block failed its checksum (memory-layer detection).
    BlockCorrupt { layer: usize, brow: usize, bcol: usize },
    /// A storage operation surfaced a structured store error.
    Store { object: String, source: StoreError },
    /// An executor-layer session fault could not be recovered.
    Session { id: String, reason: String },
    /// A claimed recovery failed its bit-identity proof — the one
    /// outcome the chaos contract exists to make loud.
    NotBitIdentical { site: String, first_diff: usize },
    /// The plan or drill itself is misconfigured.
    Plan { reason: String },
}

impl std::fmt::Display for ChaosError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ChaosError::BlockCorrupt { layer, brow, bcol } => {
                write!(f, "layer {layer} packed block ({brow}, {bcol}) fails its checksum")
            }
            ChaosError::Store { object, source } => {
                write!(f, "storage fault in `{object}`: {source}")
            }
            ChaosError::Session { id, reason } => {
                write!(f, "session `{id}` fault not recovered: {reason}")
            }
            ChaosError::NotBitIdentical { site, first_diff } => {
                write!(f, "recovery at {site} is NOT bit-identical (first diff at byte {first_diff})")
            }
            ChaosError::Plan { reason } => write!(f, "bad fault plan: {reason}"),
        }
    }
}

impl std::error::Error for ChaosError {}

/// Proof that a recovery reproduced the fault-free bytes exactly. The
/// field is private: the only way to obtain one is
/// [`prove_bit_identical`], which compares every byte — a
/// [`FaultOutcome::Recovered`] therefore cannot be fabricated around a
/// lossy repair.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BitIdentity {
    bytes: usize,
}

impl BitIdentity {
    /// How many bytes the proof compared.
    pub fn bytes_compared(&self) -> usize {
        self.bytes
    }
}

/// Compare a recovered byte image against its fault-free reference.
/// Equal → a [`BitIdentity`] proof; any difference (length or content)
/// → [`ChaosError::NotBitIdentical`] naming the first diverging byte.
pub fn prove_bit_identical(
    site: &str,
    recovered: &[u8],
    reference: &[u8],
) -> Result<BitIdentity, ChaosError> {
    let first_diff = recovered
        .iter()
        .zip(reference.iter())
        .position(|(a, b)| a != b)
        .or_else(|| (recovered.len() != reference.len()).then(|| recovered.len().min(reference.len())));
    match first_diff {
        None => Ok(BitIdentity { bytes: recovered.len() }),
        Some(at) => Err(ChaosError::NotBitIdentical { site: site.to_string(), first_diff: at }),
    }
}

/// How one injected fault ended. Exactly two variants — a structured
/// detection naming the site, or a proven bit-identical recovery —
/// so "silently wrong" has no representation.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultOutcome {
    /// The fault was detected and surfaced as a structured error.
    Detected { site: String, error: String },
    /// The fault was repaired; `proof` certifies the repaired state
    /// equals the fault-free twin byte for byte.
    Recovered { site: String, proof: BitIdentity },
}

impl FaultOutcome {
    /// The fault site, whichever way the fault ended.
    pub fn site(&self) -> &str {
        match self {
            FaultOutcome::Detected { site, .. } | FaultOutcome::Recovered { site, .. } => site,
        }
    }

    /// One line for the CLI drill / CI grep.
    pub fn describe(&self) -> String {
        match self {
            FaultOutcome::Detected { site, error } => format!("detected at {site}: {error}"),
            FaultOutcome::Recovered { site, proof } => {
                format!("recovered at {site} ({} bytes proven identical)", proof.bytes_compared())
            }
        }
    }
}

/// Plan-gated panic seam: the serving executor calls this (under
/// `catch_unwind`) only for sessions a [`FaultPlan`] marked
/// [`ExecFault::SessionPanic`]. Never reached without a plan.
pub fn inject_panic(id: &str) -> ! {
    panic!("chaos: injected panic in session `{id}`")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn plan_parse_round_trips_and_rejects_garbage() {
        let p = FaultPlan::parse("mem,exec@42").unwrap();
        assert_eq!(p.seed, 42);
        assert!(p.covers(FaultClass::Memory) && p.covers(FaultClass::Executor));
        assert!(!p.covers(FaultClass::Storage));
        assert_eq!(FaultPlan::parse(&p.name()), Some(p.clone()));

        let all = FaultPlan::parse("all@0xBEEF").unwrap();
        assert_eq!(all.seed, 0xBEEF);
        assert_eq!(all, FaultPlan::all(0xBEEF));
        assert_eq!(FaultPlan::parse("storage").unwrap().seed, DEFAULT_CHAOS_SEED);

        for bad in ["", "mem,", "disk", "mem@", "mem@0x", "mem@-1", "@7"] {
            assert_eq!(FaultPlan::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn executor_faults_are_deterministic_and_gated_by_class() {
        let plan = FaultPlan::all(7);
        for i in 0..64 {
            let id = format!("tenant-{i:05}");
            assert_eq!(plan.executor_fault(&id), plan.executor_fault(&id), "stable");
        }
        let kinds: Vec<_> = (0..64)
            .filter_map(|i| plan.executor_fault(&format!("tenant-{i:05}")))
            .collect();
        assert!(kinds.contains(&ExecFault::WorkerCrash));
        assert!(kinds.contains(&ExecFault::SessionPanic));
        assert!(kinds.len() < 64, "some sessions must be spared");
        let no_exec = FaultPlan::new(&[FaultClass::Memory], 7);
        assert_eq!(no_exec.executor_fault("tenant-00000"), None);
    }

    #[test]
    fn bit_identity_proof_is_exact() {
        let proof = prove_bit_identical("site", b"abc", b"abc").unwrap();
        assert_eq!(proof.bytes_compared(), 3);
        let err = prove_bit_identical("site", b"abc", b"abd").unwrap_err();
        assert_eq!(err, ChaosError::NotBitIdentical { site: "site".into(), first_diff: 2 });
        let err = prove_bit_identical("site", b"ab", b"abc").unwrap_err();
        assert_eq!(err, ChaosError::NotBitIdentical { site: "site".into(), first_diff: 2 });
        // outcomes always name their site
        let o = FaultOutcome::Recovered { site: "layer 0".into(), proof };
        assert_eq!(o.site(), "layer 0");
        assert!(o.describe().contains("recovered"));
    }
}
