//! Chunked checkpoint layout: one [`Checkpoint`] split into
//! independently-addressable chunks, keyed per session as
//!
//! ```text
//! meta         session config + counters + payload arity   (MXCM header)
//! params       FP32 master parameters (raw bit patterns)
//! opt          Adam moments (raw bit patterns)
//! curves       train + val loss curves
//! scheme_log   precision-segment history
//! payload/<i>  one MX weight-image tensor (MxTensor::write_bytes)
//! ```
//!
//! Splitting is bitwise lossless: every field of the monolithic
//! `.mxckpt` v2 body lands in exactly one chunk, so
//! `assemble(split(ck)).to_bytes() == ck.to_bytes()` — the bit-exact
//! resume contract survives chunking by construction (asserted in this
//! module's tests and end-to-end in `tests/store.rs`). A partial
//! reader can pull a single `payload/<i>` tensor (layer migration) or
//! skip the payload entirely (the masters alone reconstruct it).
//!
//! Reassembly applies the same plausibility validation as
//! `Checkpoint::from_bytes` — dims bounds, parameter counts against
//! [`expected_params`], known scheme/backend names — so a corrupt chunk
//! that slipped past its checksum still cannot smuggle an implausible
//! checkpoint into the trainer.

#![forbid(unsafe_code)]

use crate::backend::BackendKind;
use crate::trainer::checkpoint::{expected_params, read_curve, write_curve, Checkpoint};
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::TrainConfig;
use crate::util::bytes::{ByteReader, ByteWriter};

use super::{StoreError, VERSION};

/// Chunk-key leaf names within one session.
pub const META: &str = "meta";
pub const PARAMS: &str = "params";
pub const OPT: &str = "opt";
pub const CURVES: &str = "curves";
pub const SCHEME_LOG: &str = "scheme_log";

/// Key of the `i`-th MX weight-image tensor chunk.
pub fn payload_key(i: usize) -> String {
    format!("payload/{i}")
}

/// Meta-chunk magic ("MX Chunk Meta").
pub const META_MAGIC: [u8; 4] = *b"MXCM";

/// The `meta` chunk: everything scalar about a session, plus how many
/// `payload/<i>` chunks to expect.
#[derive(Debug, Clone)]
pub struct MetaChunk {
    pub config: TrainConfig,
    pub step: usize,
    pub adam_step: u64,
    pub n_payload: usize,
}

impl MetaChunk {
    /// Serialize (magic + store VERSION + config + counters).
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        for b in META_MAGIC {
            w.put_u8(b);
        }
        w.put_u32(VERSION);
        w.put_str(&self.config.scheme.name());
        w.put_str(self.config.backend.name());
        let dims = self.config.dims.as_deref().unwrap_or(&[]);
        w.put_u32(dims.len() as u32);
        for &d in dims {
            w.put_u32(d as u32);
        }
        w.put_u32(self.config.batch_size as u32);
        w.put_f32(self.config.lr);
        w.put_u64(self.config.eval_every as u64);
        w.put_u64(self.config.steps as u64);
        w.put_u64(self.config.seed);
        w.put_u64(self.step as u64);
        w.put_u64(self.adam_step);
        w.put_u32(self.n_payload as u32);
    }

    /// Inverse of [`MetaChunk::write_bytes`], with the same
    /// plausibility bounds as `Checkpoint::from_bytes`.
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<MetaChunk, String> {
        let magic = [r.get_u8()?, r.get_u8()?, r.get_u8()?, r.get_u8()?];
        if magic != META_MAGIC {
            return Err("not a checkpoint meta chunk (bad magic)".into());
        }
        let version = r.get_u32()?;
        if version == 0 || version > VERSION {
            return Err(format!(
                "unsupported store version {version} (this build reads ≤ {VERSION})"
            ));
        }
        let scheme_name = r.get_str()?;
        let scheme = QuantScheme::parse(&scheme_name)
            .ok_or_else(|| format!("meta chunk names unknown scheme `{scheme_name}`"))?;
        let backend_name = r.get_str()?;
        let backend = BackendKind::parse(&backend_name)
            .ok_or_else(|| format!("meta chunk names unknown backend `{backend_name}`"))?;
        let ndims = r.get_u32()? as usize;
        if !(2..=64).contains(&ndims) {
            return Err(format!("implausible layer count {ndims}"));
        }
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let d = r.get_u32()? as usize;
            if d == 0 || d > (1 << 20) {
                return Err(format!("implausible layer width {d}"));
            }
            dims.push(d);
        }
        let batch_size = r.get_u32()? as usize;
        let lr = r.get_f32()?;
        let eval_every = r.get_u64()? as usize;
        let steps = r.get_u64()? as usize;
        let seed = r.get_u64()?;
        let step = r.get_u64()? as usize;
        let adam_step = r.get_u64()?;
        let n_payload = r.get_u32()? as usize;
        if n_payload > 4096 {
            return Err(format!("implausible payload tensor count {n_payload}"));
        }
        let config = TrainConfig {
            scheme,
            backend,
            dims: Some(dims),
            batch_size,
            lr,
            steps,
            eval_every,
            seed,
        };
        Ok(MetaChunk { config, step, adam_step, n_payload })
    }
}

/// The `curves` chunk: train + val loss histories.
#[derive(Debug, Clone, PartialEq)]
pub struct CurvesChunk {
    pub train: Vec<(usize, f64)>,
    pub val: Vec<(usize, f64)>,
}

impl CurvesChunk {
    /// Serialize both curves (same wire format as the monolithic file).
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        write_curve(w, &self.train);
        write_curve(w, &self.val);
    }

    /// Inverse of [`CurvesChunk::write_bytes`].
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<CurvesChunk, String> {
        let train = read_curve(r)?;
        let val = read_curve(r)?;
        Ok(CurvesChunk { train, val })
    }
}

/// The `scheme_log` chunk: precision segments `(start_step, scheme)`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SchemeLogChunk {
    pub segments: Vec<(usize, String)>,
}

impl SchemeLogChunk {
    /// Serialize the segment list.
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        w.put_u32(self.segments.len() as u32);
        for (at, name) in &self.segments {
            w.put_u64(*at as u64);
            w.put_str(name);
        }
    }

    /// Inverse of [`SchemeLogChunk::write_bytes`], validating scheme
    /// names and the segment-count bound.
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<SchemeLogChunk, String> {
        let n = r.get_u32()? as usize;
        if n > 65536 {
            return Err(format!("implausible precision-segment count {n}"));
        }
        let mut segments = Vec::with_capacity(n);
        for _ in 0..n {
            let at = r.get_u64()? as usize;
            let name = r.get_str()?;
            if QuantScheme::parse(&name).is_none() {
                return Err(format!("scheme log names unknown scheme `{name}`"));
            }
            segments.push((at, name));
        }
        Ok(SchemeLogChunk { segments })
    }
}

fn decode_err(key: &str, reason: impl Into<String>) -> StoreError {
    StoreError::BadIndex { key: key.to_string(), reason: reason.into() }
}

/// Encode one chunk through a closure over a fresh writer, checking
/// that nothing is left over on the read side at decode time instead.
fn encode(f: impl FnOnce(&mut ByteWriter)) -> Vec<u8> {
    let mut w = ByteWriter::new();
    f(&mut w);
    w.into_bytes()
}

/// Split a checkpoint into its `(relative key, bytes)` chunks. The
/// inverse is [`assemble_checkpoint`]; round-tripping is bitwise
/// lossless.
pub fn split_checkpoint(ck: &Checkpoint) -> Vec<(String, Vec<u8>)> {
    let meta = MetaChunk {
        config: ck.config.clone(),
        step: ck.step,
        adam_step: ck.adam_step,
        n_payload: ck.payload.len(),
    };
    let curves = CurvesChunk { train: ck.train_curve.clone(), val: ck.val_curve.clone() };
    let log = SchemeLogChunk { segments: ck.scheme_log.clone() };
    let mut chunks = vec![
        (META.to_string(), encode(|w| meta.write_bytes(w))),
        (PARAMS.to_string(), encode(|w| w.put_f32s(&ck.params))),
        (OPT.to_string(), encode(|w| w.put_f32s(&ck.opt))),
        (CURVES.to_string(), encode(|w| curves.write_bytes(w))),
        (SCHEME_LOG.to_string(), encode(|w| log.write_bytes(w))),
    ];
    for (i, t) in ck.payload.iter().enumerate() {
        chunks.push((payload_key(i), encode(|w| t.write_bytes(w))));
    }
    chunks
}

/// Decode one whole chunk, requiring the decoder to consume every byte.
fn decode_all<T>(
    key: &str,
    bytes: &[u8],
    f: impl FnOnce(&mut ByteReader<'_>) -> Result<T, String>,
) -> Result<T, StoreError> {
    let mut r = ByteReader::new(bytes);
    let v = f(&mut r).map_err(|e| decode_err(key, e))?;
    if r.remaining() != 0 {
        return Err(decode_err(key, format!("{} trailing bytes after chunk body", r.remaining())));
    }
    Ok(v)
}

/// Reassemble a checkpoint by fetching chunks on demand. `fetch`
/// receives *relative* keys ([`META`], [`PARAMS`], …, `payload/<i>`);
/// the caller scopes them to a session and a backing store. Only the
/// chunks a full checkpoint needs are requested — nothing else in the
/// shard is touched, which is what makes resume reads proportional to
/// one session, not the fleet.
pub fn assemble_checkpoint(
    mut fetch: impl FnMut(&str) -> Result<Vec<u8>, StoreError>,
) -> Result<Checkpoint, StoreError> {
    let meta = decode_all(META, &fetch(META)?, MetaChunk::read_bytes)?;
    let params = decode_all(PARAMS, &fetch(PARAMS)?, |r| r.get_f32s())?;
    let opt = decode_all(OPT, &fetch(OPT)?, |r| r.get_f32s())?;
    let curves = decode_all(CURVES, &fetch(CURVES)?, CurvesChunk::read_bytes)?;
    let log = decode_all(SCHEME_LOG, &fetch(SCHEME_LOG)?, SchemeLogChunk::read_bytes)?;

    let dims = meta.config.dims.as_deref().unwrap_or(&[]);
    let expected =
        expected_params(dims).ok_or_else(|| decode_err(META, "parameter count overflow"))?;
    if params.len() != expected {
        return Err(decode_err(
            PARAMS,
            format!(
                "parameter chunk holds {} values, dims {:?} imply {}",
                params.len(),
                dims,
                expected
            ),
        ));
    }
    if opt.len() != 2 * expected {
        return Err(decode_err(
            OPT,
            format!("optimizer chunk holds {} values, expected {}", opt.len(), 2 * expected),
        ));
    }

    let mut payload = Vec::with_capacity(meta.n_payload);
    for i in 0..meta.n_payload {
        let key = payload_key(i);
        payload.push(decode_all(&key, &fetch(&key)?, |r| {
            crate::mx::tensor::MxTensor::read_bytes(r)
        })?);
    }

    Ok(Checkpoint {
        config: meta.config,
        step: meta.step,
        adam_step: meta.adam_step,
        train_curve: curves.train,
        val_curve: curves.val,
        params,
        opt,
        scheme_log: log.segments,
        payload,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::trainer::checkpoint::weight_payload;
    use crate::util::rng::Pcg64;
    use std::collections::BTreeMap;

    fn sample_checkpoint(scheme: QuantScheme) -> Checkpoint {
        let mut rng = Pcg64::new(11);
        let dims = vec![32usize, 16, 32];
        let mlp = crate::trainer::mlp::Mlp::new(&dims, &mut rng);
        let config = TrainConfig {
            scheme,
            backend: BackendKind::parse("fast").expect("fast backend"),
            dims: Some(dims),
            batch_size: 16,
            lr: 1e-3,
            steps: 40,
            eval_every: 10,
            seed: 0xBEEF,
        };
        Checkpoint {
            config,
            step: 7,
            adam_step: 7,
            train_curve: vec![(0, 1.25), (5, 0.5)],
            val_curve: vec![(0, 1.5)],
            params: mlp.flat_params(),
            opt: mlp.flat_opt_state(),
            scheme_log: vec![(0, scheme.name())],
            payload: weight_payload(&mlp.weights, scheme),
        }
    }

    fn as_map(chunks: Vec<(String, Vec<u8>)>) -> BTreeMap<String, Vec<u8>> {
        chunks.into_iter().collect()
    }

    #[test]
    fn split_then_assemble_is_bitwise_lossless() {
        for scheme in [
            QuantScheme::MxSquare(ElementFormat::E4M3),
            QuantScheme::MxVector(ElementFormat::Int8),
            QuantScheme::Fp32,
        ] {
            let ck = sample_checkpoint(scheme);
            let map = as_map(split_checkpoint(&ck));
            let back = assemble_checkpoint(|k| {
                map.get(k).cloned().ok_or(StoreError::MissingChunk { key: k.to_string() })
            })
            .unwrap();
            assert_eq!(back.to_bytes(), ck.to_bytes(), "{scheme:?}");
        }
    }

    #[test]
    fn payload_tensors_chunk_per_layer() {
        let ck = sample_checkpoint(QuantScheme::MxSquare(ElementFormat::E2M1));
        let map = as_map(split_checkpoint(&ck));
        assert_eq!(ck.payload.len(), 2, "two layers, square single-copy");
        assert!(map.contains_key("payload/0") && map.contains_key("payload/1"));
        // one tensor is independently decodable — the partial-read unit
        let t = decode_all("payload/1", &map["payload/1"], |r| {
            crate::mx::tensor::MxTensor::read_bytes(r)
        })
        .unwrap();
        assert_eq!(encode(|w| t.write_bytes(w)), map["payload/1"]);
    }

    #[test]
    fn missing_and_corrupt_chunks_surface_structured_errors() {
        let ck = sample_checkpoint(QuantScheme::MxSquare(ElementFormat::E5M2));
        let mut map = as_map(split_checkpoint(&ck));
        map.remove(OPT);
        let err = assemble_checkpoint(|k| {
            map.get(k).cloned().ok_or(StoreError::MissingChunk { key: k.to_string() })
        })
        .unwrap_err();
        assert!(matches!(err, StoreError::MissingChunk { ref key } if key == OPT), "{err}");

        let mut map = as_map(split_checkpoint(&ck));
        map.get_mut(META).map(|b| b.truncate(10));
        let err = assemble_checkpoint(|k| {
            map.get(k).cloned().ok_or(StoreError::MissingChunk { key: k.to_string() })
        })
        .unwrap_err();
        assert!(matches!(err, StoreError::BadIndex { .. }), "{err}");

        // params chunk whose length contradicts the dims
        let mut map = as_map(split_checkpoint(&ck));
        map.insert(PARAMS.into(), encode(|w| w.put_f32s(&[1.0, 2.0])));
        let err = assemble_checkpoint(|k| {
            map.get(k).cloned().ok_or(StoreError::MissingChunk { key: k.to_string() })
        })
        .unwrap_err();
        assert!(matches!(err, StoreError::BadIndex { ref key, .. } if key == PARAMS), "{err}");
    }

    #[test]
    fn meta_chunk_rejects_future_store_versions() {
        let ck = sample_checkpoint(QuantScheme::Fp32);
        let mut bytes = encode(|w| {
            MetaChunk {
                config: ck.config.clone(),
                step: ck.step,
                adam_step: ck.adam_step,
                n_payload: 0,
            }
            .write_bytes(w)
        });
        bytes[4] = 0xFF; // version field LE low byte
        let err = MetaChunk::read_bytes(&mut ByteReader::new(&bytes)).unwrap_err();
        assert!(err.contains("unsupported store version"), "{err}");
    }
}
