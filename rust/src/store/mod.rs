//! The checkpoint store: a dependency-free object-storage layer for
//! fleet-scale training state (DESIGN.md §11).
//!
//! Three layers, bottom up:
//!
//! 1. **[`Storage`]** — a minimal byte-object trait (get / byte-range
//!    get / put / append / list / erase, plus `try_create` as the
//!    advisory-lock primitive). [`FilesystemStore`] implements it over
//!    `std::fs`; [`MemoryStore`] over a `BTreeMap` (tests, and the
//!    proof that an object store can slot in later); [`CountingStore`]
//!    wraps any of them and meters bytes moved — how the partial-read
//!    guarantee is *asserted*, not just claimed.
//! 2. **Chunked checkpoint layout** ([`chunk`]) — one
//!    [`crate::trainer::checkpoint::Checkpoint`] splits into per-section,
//!    per-tensor chunks addressed by key: `meta`, `params` (FP32
//!    masters), `opt` (Adam moments), `curves`, `scheme_log`, and one
//!    `payload/<i>` per MX weight-image tensor. Reassembly is bitwise
//!    lossless: `assemble(split(ck))` reproduces `ck.to_bytes()`
//!    exactly, so the bit-exact resume contract survives chunking.
//! 3. **Sharding container** ([`shard`]) — thousands of robots' chunks
//!    pack into a few large `shard-*.mxshard` objects, each ending in a
//!    fixed-size index (chunk key → offset/len/FNV-1a checksum) plus a
//!    fixed-size trailer. A resume reads the trailer, the index, and
//!    only the chunks it needs — never the other robots' state.
//!    Appends are log-structured (old index regions become dead bytes;
//!    the trailer at EOF always names the live index) and serialized
//!    per shard by a [`lock::StoreLock`], so concurrent fleet writers
//!    to different shards never contend.
//!
//! [`CheckpointStore`] is the facade every checkpoint entry point goes
//! through (trainer save/load, fleet domain shifts, `mxscale fleet
//! --store`); the legacy monolithic `.mxckpt` file is just the
//! single-chunk `FilesystemStore` case read through its compat shim.
//!
//! Everything returns structured [`StoreError`]s — no stringly errors,
//! no panics on corrupt input — and the trainer boundary folds them
//! into `TrainError::BadCheckpoint`.

#![forbid(unsafe_code)]

pub mod chunk;
pub mod ckpt;
pub mod fs;
pub mod lock;
pub mod shard;

pub use ckpt::{CheckpointStore, StoreLayout};
pub use fs::FilesystemStore;
pub use lock::StoreLock;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// On-disk format version of the store layer (chunk codecs + shard
/// index/trailer). mxlint rule L5 pins every `write_bytes`/`read_bytes`
/// body under `store/` against this constant: the layout can only
/// change together with a bump here.
///
/// v1: chunked checkpoint sections (`MXCM` meta) + sharded container
/// (`MXSH` trailer, 88-byte index entries, FNV-1a checksums).
const VERSION: u32 = 1;

/// The store-format version (see [`VERSION`]).
pub fn store_version() -> u32 {
    VERSION
}

/// Structured store failure. `MissingChunk` doubles as "missing
/// object" for whole-object gets, so callers can distinguish
/// not-found (try the compat shim, report a clean error) from
/// corruption (`BadIndex`/`ChecksumMismatch` — never silently retried).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum StoreError {
    /// The addressed chunk/object does not exist.
    MissingChunk { key: String },
    /// A shard trailer/index (or a chunk's framing) failed validation.
    BadIndex { key: String, reason: String },
    /// Stored bytes do not match their recorded FNV-1a checksum.
    ChecksumMismatch { key: String },
    /// The advisory lock could not be acquired within the timeout.
    LockHeld { key: String },
    /// An underlying storage operation failed.
    Io { op: &'static str, key: String, reason: String },
}

impl std::fmt::Display for StoreError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            StoreError::MissingChunk { key } => write!(f, "missing chunk `{key}`"),
            StoreError::BadIndex { key, reason } => {
                write!(f, "bad shard index in `{key}`: {reason}")
            }
            StoreError::ChecksumMismatch { key } => {
                write!(f, "checksum mismatch reading chunk `{key}` (corrupt store?)")
            }
            StoreError::LockHeld { key } => {
                write!(f, "store lock `{key}` is held by another writer")
            }
            StoreError::Io { op, key, reason } => write!(f, "store {op} `{key}`: {reason}"),
        }
    }
}

impl std::error::Error for StoreError {}

/// The trainer boundary: every store failure surfaces as a structured
/// checkpoint error, so `?` works across the seam.
impl From<StoreError> for crate::trainer::session::TrainError {
    fn from(e: StoreError) -> Self {
        crate::trainer::session::TrainError::BadCheckpoint { reason: e.to_string() }
    }
}

/// Reject keys that could escape the store root or break the shard
/// index framing. Keys are `/`-separated relative paths of
/// `[A-Za-z0-9._-]` components (no empty components, no `.`/`..`).
pub fn validate_key(key: &str) -> Result<(), StoreError> {
    let bad = |reason: &str| {
        Err(StoreError::Io { op: "validate", key: key.to_string(), reason: reason.to_string() })
    };
    if key.is_empty() {
        return bad("empty key");
    }
    for comp in key.split('/') {
        if comp.is_empty() {
            return bad("empty path component");
        }
        if comp == "." || comp == ".." {
            return bad("relative path component");
        }
        if !comp.bytes().all(|b| b.is_ascii_alphanumeric() || b"._-".contains(&b)) {
            return bad("component has characters outside [A-Za-z0-9._-]");
        }
    }
    Ok(())
}

/// A minimal byte-object store. Implementations must be `Send + Sync`:
/// fleet writers share one handle across worker threads.
///
/// Contract notes:
/// * `get`/`size` on a missing object return [`StoreError::MissingChunk`].
/// * `get_range` past the object end is an error, never a short read.
/// * `append` returns the offset the write began at; shard appends call
///   it under a [`StoreLock`], which is what makes the returned offset
///   meaningful.
/// * `try_create` is atomic create-if-absent — the advisory-lock
///   primitive ([`StoreLock`] is built on nothing else, so any
///   conforming backend gets locking for free).
/// * `erase` of a missing object is `Ok` (idempotent — lock release
///   must not fail a run that already crashed once).
pub trait Storage: Send + Sync {
    /// Read a whole object.
    fn get(&self, key: &str) -> Result<Vec<u8>, StoreError>;
    /// Read exactly `len` bytes starting at `offset`.
    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>, StoreError>;
    /// Object size in bytes.
    fn size(&self, key: &str) -> Result<u64, StoreError>;
    /// Create or replace a whole object.
    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError>;
    /// Append to an object (creating it), returning the offset the
    /// write began at.
    fn append(&self, key: &str, bytes: &[u8]) -> Result<u64, StoreError>;
    /// Atomically create the object iff absent; `Ok(false)` when it
    /// already exists.
    fn try_create(&self, key: &str, bytes: &[u8]) -> Result<bool, StoreError>;
    /// Sorted keys under `prefix` ("" lists everything).
    fn list(&self, prefix: &str) -> Result<Vec<String>, StoreError>;
    /// Delete an object (idempotent).
    fn erase(&self, key: &str) -> Result<(), StoreError>;

    /// Whether the object exists (derived from `size`).
    fn exists(&self, key: &str) -> Result<bool, StoreError> {
        match self.size(key) {
            Ok(_) => Ok(true),
            Err(StoreError::MissingChunk { .. }) => Ok(false),
            Err(e) => Err(e),
        }
    }
}

/// In-memory [`Storage`] — the tests' scratch backend and the proof the
/// trait carries everything an object-store adapter needs.
#[derive(Debug, Default)]
pub struct MemoryStore {
    objects: Mutex<BTreeMap<String, Vec<u8>>>,
}

impl MemoryStore {
    pub fn new() -> Self {
        Self::default()
    }

    fn guard(&self) -> Result<std::sync::MutexGuard<'_, BTreeMap<String, Vec<u8>>>, StoreError> {
        self.objects.lock().map_err(|_| StoreError::Io {
            op: "lock",
            key: String::new(),
            reason: "memory store mutex poisoned".into(),
        })
    }
}

impl Storage for MemoryStore {
    fn get(&self, key: &str) -> Result<Vec<u8>, StoreError> {
        validate_key(key)?;
        self.guard()?
            .get(key)
            .cloned()
            .ok_or_else(|| StoreError::MissingChunk { key: key.to_string() })
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>, StoreError> {
        let obj = self.get(key)?;
        let (start, end) = (offset as usize, (offset + len) as usize);
        if end > obj.len() || end < start {
            return Err(StoreError::Io {
                op: "get_range",
                key: key.to_string(),
                reason: format!("range {offset}+{len} exceeds object of {} bytes", obj.len()),
            });
        }
        Ok(obj[start..end].to_vec())
    }

    fn size(&self, key: &str) -> Result<u64, StoreError> {
        validate_key(key)?;
        self.guard()?
            .get(key)
            .map(|b| b.len() as u64)
            .ok_or_else(|| StoreError::MissingChunk { key: key.to_string() })
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        validate_key(key)?;
        self.guard()?.insert(key.to_string(), bytes.to_vec());
        Ok(())
    }

    fn append(&self, key: &str, bytes: &[u8]) -> Result<u64, StoreError> {
        validate_key(key)?;
        let mut objects = self.guard()?;
        let obj = objects.entry(key.to_string()).or_default();
        let at = obj.len() as u64;
        obj.extend_from_slice(bytes);
        Ok(at)
    }

    fn try_create(&self, key: &str, bytes: &[u8]) -> Result<bool, StoreError> {
        validate_key(key)?;
        let mut objects = self.guard()?;
        if objects.contains_key(key) {
            return Ok(false);
        }
        objects.insert(key.to_string(), bytes.to_vec());
        Ok(true)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, StoreError> {
        Ok(self.guard()?.keys().filter(|k| k.starts_with(prefix)).cloned().collect())
    }

    fn erase(&self, key: &str) -> Result<(), StoreError> {
        validate_key(key)?;
        self.guard()?.remove(key);
        Ok(())
    }
}

/// Metering wrapper: delegates every operation and counts the bytes
/// that actually moved. The partial-read acceptance criterion —
/// "resuming one robot from a 1000-robot shard store reads no more
/// than the index plus that robot's chunks" — is asserted through this
/// type in `tests/store.rs` and measured by `benches/bench_store.rs`.
pub struct CountingStore {
    inner: Arc<dyn Storage>,
    read_bytes: AtomicU64,
    read_calls: AtomicU64,
    write_bytes: AtomicU64,
}

impl CountingStore {
    pub fn new(inner: Arc<dyn Storage>) -> Self {
        Self {
            inner,
            read_bytes: AtomicU64::new(0),
            read_calls: AtomicU64::new(0),
            write_bytes: AtomicU64::new(0),
        }
    }

    /// Bytes returned by `get`/`get_range` since construction (or the
    /// last [`CountingStore::reset`]).
    pub fn bytes_read(&self) -> u64 {
        self.read_bytes.load(Ordering::Relaxed)
    }

    /// Number of `get`/`get_range` calls.
    pub fn read_calls(&self) -> u64 {
        self.read_calls.load(Ordering::Relaxed)
    }

    /// Bytes accepted by `put`/`append`/`try_create`.
    pub fn bytes_written(&self) -> u64 {
        self.write_bytes.load(Ordering::Relaxed)
    }

    /// Zero all counters (e.g. after populating, before measuring).
    pub fn reset(&self) {
        self.read_bytes.store(0, Ordering::Relaxed);
        self.read_calls.store(0, Ordering::Relaxed);
        self.write_bytes.store(0, Ordering::Relaxed);
    }
}

impl Storage for CountingStore {
    fn get(&self, key: &str) -> Result<Vec<u8>, StoreError> {
        let bytes = self.inner.get(key)?;
        self.read_calls.fetch_add(1, Ordering::Relaxed);
        self.read_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(bytes)
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>, StoreError> {
        let bytes = self.inner.get_range(key, offset, len)?;
        self.read_calls.fetch_add(1, Ordering::Relaxed);
        self.read_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        Ok(bytes)
    }

    fn size(&self, key: &str) -> Result<u64, StoreError> {
        self.inner.size(key)
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        self.write_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.inner.put(key, bytes)
    }

    fn append(&self, key: &str, bytes: &[u8]) -> Result<u64, StoreError> {
        self.write_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.inner.append(key, bytes)
    }

    fn try_create(&self, key: &str, bytes: &[u8]) -> Result<bool, StoreError> {
        self.write_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.inner.try_create(key, bytes)
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, StoreError> {
        self.inner.list(prefix)
    }

    fn erase(&self, key: &str) -> Result<(), StoreError> {
        self.inner.erase(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_validation_rejects_escapes_and_accepts_store_keys() {
        for good in ["a", "robot-07/params", "shard-0003.mxshard", "sessions/r1/payload/2"] {
            assert!(validate_key(good).is_ok(), "{good}");
        }
        for bad in ["", "/abs", "a//b", "../up", "a/./b", "a/..", "sp ace", "uni\u{e9}"] {
            assert!(validate_key(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn memory_store_round_trips_and_ranges() {
        let s = MemoryStore::new();
        s.put("k/v", b"hello world").unwrap();
        assert_eq!(s.get("k/v").unwrap(), b"hello world");
        assert_eq!(s.size("k/v").unwrap(), 11);
        assert_eq!(s.get_range("k/v", 6, 5).unwrap(), b"world");
        assert!(s.get_range("k/v", 6, 6).is_err(), "over-read must error, not truncate");
        assert!(matches!(s.get("k/other"), Err(StoreError::MissingChunk { .. })));
        assert_eq!(s.append("k/v", b"!").unwrap(), 11);
        assert_eq!(s.size("k/v").unwrap(), 12);
        assert!(!s.try_create("k/v", b"x").unwrap());
        assert!(s.try_create("k/new", b"x").unwrap());
        assert_eq!(s.list("k/").unwrap(), vec!["k/new".to_string(), "k/v".to_string()]);
        s.erase("k/v").unwrap();
        s.erase("k/v").unwrap(); // idempotent
        assert!(!s.exists("k/v").unwrap());
    }

    #[test]
    fn counting_store_meters_reads_and_writes() {
        let inner = Arc::new(MemoryStore::new());
        let c = CountingStore::new(inner);
        c.put("obj", &[7u8; 100]).unwrap();
        assert_eq!(c.bytes_written(), 100);
        assert_eq!(c.get_range("obj", 10, 25).unwrap().len(), 25);
        assert_eq!(c.get("obj").unwrap().len(), 100);
        assert_eq!(c.bytes_read(), 125);
        assert_eq!(c.read_calls(), 2);
        c.reset();
        assert_eq!((c.bytes_read(), c.read_calls(), c.bytes_written()), (0, 0, 0));
    }

    #[test]
    fn store_errors_render_their_structure() {
        let e = StoreError::MissingChunk { key: "r1/meta".into() };
        assert!(e.to_string().contains("r1/meta"));
        let e = StoreError::ChecksumMismatch { key: "r1/params".into() };
        assert!(e.to_string().contains("checksum"));
        let e = StoreError::LockHeld { key: "shard-0001.mxshard.lock".into() };
        assert!(e.to_string().contains("lock"));
        // and the trainer boundary folds into BadCheckpoint
        let t: crate::trainer::session::TrainError =
            StoreError::ChecksumMismatch { key: "k".into() }.into();
        assert!(matches!(
            t,
            crate::trainer::session::TrainError::BadCheckpoint { ref reason }
                if reason.contains("checksum")
        ));
    }
}
