//! The sharding container: thousands of robots' checkpoint chunks
//! packed into a few large shard objects, each ending in a fixed-size
//! index (chunk key → offset/len/FNV-1a checksum) plus a fixed-size
//! trailer.
//!
//! Layout (all little-endian, per `util::bytes`):
//!
//! ```text
//! [chunk bytes …][chunk bytes …] … [index: n × 88-byte entries][trailer: 32 bytes]
//! entry   = key (64 bytes, zero-padded ASCII) · offset u64 · len u64 · fnv1a64(chunk) u64
//! trailer = magic "MXSH" · store VERSION u32 · n_entries u64 · index_off u64 · fnv1a64(index) u64
//! ```
//!
//! Appends are **log-structured**: a writer (holding the shard's
//! [`StoreLock`]) reads the live index, appends its new chunks followed
//! by a *complete* rewritten index and a fresh trailer at EOF. The old
//! index region becomes dead bytes; a reader always finds the live
//! index through the trailer at EOF, so a crash mid-append leaves the
//! previous generation intact (the trailer is the commit point). Same
//! key appended twice → the newest entry wins at index-merge time.
//!
//! A resume therefore reads: 32 trailer bytes + `n × 88` index bytes +
//! exactly the chunks it asks for — never another robot's state. That
//! bound is asserted (not assumed) via `store::CountingStore` in
//! `tests/store.rs`.

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::Duration;

use crate::util::bytes::{fnv1a64, ByteReader, ByteWriter};

use super::lock::StoreLock;
use super::{Storage, StoreError, VERSION};

/// Shard trailer magic.
pub const SHARD_MAGIC: [u8; 4] = *b"MXSH";
/// Fixed key field width inside an index entry.
pub const KEY_BYTES: usize = 64;
/// Serialized size of one [`IndexEntry`].
pub const ENTRY_BYTES: usize = KEY_BYTES + 8 + 8 + 8;
/// Serialized size of a [`ShardTrailer`].
pub const TRAILER_BYTES: usize = 4 + 4 + 8 + 8 + 8;
/// Plausibility cap on entries per shard (1M chunks ≈ 88 MB of index).
const MAX_ENTRIES: u64 = 1 << 20;

/// One chunk's address within a shard: key → byte range + checksum.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct IndexEntry {
    pub key: String,
    pub offset: u64,
    pub len: u64,
    pub checksum: u64,
}

impl IndexEntry {
    /// Serialize as a fixed 88-byte record (key zero-padded to 64).
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        let kb = self.key.as_bytes();
        debug_assert!(kb.len() <= KEY_BYTES, "key `{}` overflows index field", self.key);
        for i in 0..KEY_BYTES {
            w.put_u8(kb.get(i).copied().unwrap_or(0));
        }
        w.put_u64(self.offset);
        w.put_u64(self.len);
        w.put_u64(self.checksum);
    }

    /// Inverse of [`IndexEntry::write_bytes`].
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<IndexEntry, String> {
        let mut kb = [0u8; KEY_BYTES];
        for b in kb.iter_mut() {
            *b = r.get_u8()?;
        }
        let end = kb.iter().position(|&b| b == 0).unwrap_or(KEY_BYTES);
        if kb[end..].iter().any(|&b| b != 0) {
            return Err("index key has bytes after NUL padding".into());
        }
        let key = std::str::from_utf8(&kb[..end])
            .map_err(|e| format!("index key is not UTF-8: {e}"))?
            .to_string();
        if key.is_empty() {
            return Err("empty index key".into());
        }
        let offset = r.get_u64()?;
        let len = r.get_u64()?;
        let checksum = r.get_u64()?;
        Ok(IndexEntry { key, offset, len, checksum })
    }
}

/// The 32-byte commit record at a shard's EOF.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardTrailer {
    pub n_entries: u64,
    pub index_off: u64,
    pub index_checksum: u64,
}

impl ShardTrailer {
    /// Serialize (magic + store VERSION + counts).
    pub fn write_bytes(&self, w: &mut ByteWriter) {
        for b in SHARD_MAGIC {
            w.put_u8(b);
        }
        w.put_u32(VERSION);
        w.put_u64(self.n_entries);
        w.put_u64(self.index_off);
        w.put_u64(self.index_checksum);
    }

    /// Inverse of [`ShardTrailer::write_bytes`], validating magic and
    /// version.
    pub fn read_bytes(r: &mut ByteReader<'_>) -> Result<ShardTrailer, String> {
        let mut magic = [0u8; 4];
        for b in magic.iter_mut() {
            *b = r.get_u8()?;
        }
        if magic != SHARD_MAGIC {
            return Err(format!("bad shard magic {magic:02x?} (want {SHARD_MAGIC:02x?})"));
        }
        let version = r.get_u32()?;
        if version == 0 || version > VERSION {
            return Err(format!(
                "unsupported shard version {version} (this build reads ≤ {VERSION})"
            ));
        }
        let n_entries = r.get_u64()?;
        if n_entries > MAX_ENTRIES {
            return Err(format!("implausible shard entry count {n_entries}"));
        }
        let index_off = r.get_u64()?;
        let index_checksum = r.get_u64()?;
        Ok(ShardTrailer { n_entries, index_off, index_checksum })
    }
}

fn bad_index(shard: &str, reason: impl Into<String>) -> StoreError {
    StoreError::BadIndex { key: shard.to_string(), reason: reason.into() }
}

/// Read a shard's live index: trailer at EOF, then the index region it
/// names, checksum-verified. A missing shard surfaces as
/// [`StoreError::MissingChunk`]; any structural damage as `BadIndex`.
pub fn read_index(store: &dyn Storage, shard: &str) -> Result<Vec<IndexEntry>, StoreError> {
    let size = store.size(shard)?;
    if size < TRAILER_BYTES as u64 {
        return Err(bad_index(shard, format!("shard of {size} bytes is smaller than a trailer")));
    }
    let tb = store.get_range(shard, size - TRAILER_BYTES as u64, TRAILER_BYTES as u64)?;
    let trailer =
        ShardTrailer::read_bytes(&mut ByteReader::new(&tb)).map_err(|e| bad_index(shard, e))?;
    let index_len = trailer.n_entries * ENTRY_BYTES as u64;
    let expect_end = trailer
        .index_off
        .checked_add(index_len)
        .and_then(|v| v.checked_add(TRAILER_BYTES as u64));
    if expect_end != Some(size) {
        return Err(bad_index(
            shard,
            format!(
                "trailer names index at {}+{} but shard ends at {} (truncated append?)",
                trailer.index_off, index_len, size
            ),
        ));
    }
    let ib = store.get_range(shard, trailer.index_off, index_len)?;
    if fnv1a64(&ib) != trailer.index_checksum {
        return Err(bad_index(shard, "index bytes do not match trailer checksum"));
    }
    let mut r = ByteReader::new(&ib);
    let mut entries = Vec::with_capacity(trailer.n_entries as usize);
    for _ in 0..trailer.n_entries {
        entries.push(IndexEntry::read_bytes(&mut r).map_err(|e| bad_index(shard, e))?);
    }
    Ok(entries)
}

/// Fetch one chunk by its index entry, verifying its checksum.
pub fn read_chunk(
    store: &dyn Storage,
    shard: &str,
    entry: &IndexEntry,
) -> Result<Vec<u8>, StoreError> {
    let bytes = store.get_range(shard, entry.offset, entry.len)?;
    if fnv1a64(&bytes) != entry.checksum {
        return Err(StoreError::ChecksumMismatch { key: entry.key.clone() });
    }
    Ok(bytes)
}

/// Append `chunks` to `shard` under its advisory lock: new chunk bytes,
/// then the full merged index (newest entry per key wins), then a fresh
/// trailer — one atomic-at-the-trailer generation per call.
pub fn append_chunks(
    store: &Arc<dyn Storage>,
    shard: &str,
    chunks: &[(String, Vec<u8>)],
    lock_timeout: Duration,
) -> Result<(), StoreError> {
    for (key, _) in chunks {
        super::validate_key(key)?;
        if key.len() > KEY_BYTES {
            return Err(StoreError::Io {
                op: "append_chunks",
                key: key.clone(),
                reason: format!("chunk key longer than the {KEY_BYTES}-byte index field"),
            });
        }
    }
    // staleness-aware: a writer that crashed mid-append must not park
    // every later writer in LockHeld retries forever (chaos class
    // `StaleLock`); a minute-old lock is presumed crashed and broken
    let lock = StoreLock::acquire_with_staleness(
        store.clone(),
        &format!("{shard}.lock"),
        lock_timeout,
        super::lock::STALE_LOCK_AFTER,
    )?;
    let old = match read_index(store.as_ref(), shard) {
        Ok(entries) => entries,
        Err(StoreError::MissingChunk { .. }) => Vec::new(),
        Err(e) => return Err(e),
    };
    let base = match store.size(shard) {
        Ok(n) => n,
        Err(StoreError::MissingChunk { .. }) => 0,
        Err(e) => return Err(e),
    };

    let mut blob = ByteWriter::new();
    let mut entries: Vec<IndexEntry> =
        old.into_iter().filter(|e| !chunks.iter().any(|(k, _)| *k == e.key)).collect();
    let mut cursor = base;
    for (key, bytes) in chunks {
        entries.push(IndexEntry {
            key: key.clone(),
            offset: cursor,
            len: bytes.len() as u64,
            checksum: fnv1a64(bytes),
        });
        for &b in bytes {
            blob.put_u8(b);
        }
        cursor += bytes.len() as u64;
    }
    entries.sort_by(|a, b| a.key.cmp(&b.key));

    let mut iw = ByteWriter::new();
    for e in &entries {
        e.write_bytes(&mut iw);
    }
    let index_bytes = iw.into_bytes();
    let trailer = ShardTrailer {
        n_entries: entries.len() as u64,
        index_off: cursor,
        index_checksum: fnv1a64(&index_bytes),
    };
    let mut tw = ByteWriter::new();
    trailer.write_bytes(&mut tw);

    let mut out = blob.into_bytes();
    out.extend_from_slice(&index_bytes);
    out.extend_from_slice(&tw.into_bytes());
    store.append(shard, &out)?;
    lock.release()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemoryStore;

    fn mem() -> Arc<dyn Storage> {
        Arc::new(MemoryStore::new())
    }

    const T: Duration = Duration::from_secs(2);

    #[test]
    fn entry_and_trailer_round_trip_at_fixed_widths() {
        let e = IndexEntry { key: "robot-07/params".into(), offset: 1234, len: 56, checksum: 99 };
        let mut w = ByteWriter::new();
        e.write_bytes(&mut w);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), ENTRY_BYTES);
        assert_eq!(IndexEntry::read_bytes(&mut ByteReader::new(&bytes)).unwrap(), e);

        let t = ShardTrailer { n_entries: 3, index_off: 777, index_checksum: 0xabc };
        let mut w = ByteWriter::new();
        t.write_bytes(&mut w);
        let bytes = w.into_bytes();
        assert_eq!(bytes.len(), TRAILER_BYTES);
        assert_eq!(ShardTrailer::read_bytes(&mut ByteReader::new(&bytes)).unwrap(), t);
    }

    #[test]
    fn appended_chunks_read_back_and_newest_generation_wins() {
        let store = mem();
        let gen1 =
            vec![("r1/meta".to_string(), vec![1u8; 10]), ("r1/params".to_string(), vec![2u8; 30])];
        append_chunks(&store, "s.mxshard", &gen1, T).unwrap();
        let gen2 =
            vec![("r2/meta".to_string(), vec![3u8; 5]), ("r1/params".to_string(), vec![4u8; 8])];
        append_chunks(&store, "s.mxshard", &gen2, T).unwrap();

        let index = read_index(store.as_ref(), "s.mxshard").unwrap();
        let keys: Vec<&str> = index.iter().map(|e| e.key.as_str()).collect();
        assert_eq!(keys, ["r1/meta", "r1/params", "r2/meta"], "sorted, deduped by key");
        let params = index.iter().find(|e| e.key == "r1/params").unwrap();
        assert_eq!(read_chunk(store.as_ref(), "s.mxshard", params).unwrap(), vec![4u8; 8]);
        let meta = index.iter().find(|e| e.key == "r1/meta").unwrap();
        assert_eq!(read_chunk(store.as_ref(), "s.mxshard", meta).unwrap(), vec![1u8; 10]);
        assert!(!store.exists("s.mxshard.lock").unwrap(), "lock released");
    }

    #[test]
    fn truncation_and_tampering_surface_structured_errors() {
        let store = mem();
        let chunks = vec![("r1/meta".to_string(), vec![9u8; 40])];
        append_chunks(&store, "s.mxshard", &chunks, T).unwrap();
        let whole = store.get("s.mxshard").unwrap();

        // Truncate at several cut points: always BadIndex, never panic.
        for cut in [whole.len() - 1, whole.len() - TRAILER_BYTES, 10, 0] {
            store.put("cut.mxshard", &whole[..cut]).unwrap();
            let err = read_index(store.as_ref(), "cut.mxshard").unwrap_err();
            assert!(matches!(err, StoreError::BadIndex { .. }), "cut at {cut}: {err}");
        }

        // Flip a byte inside the chunk region: index still reads, the
        // chunk fetch reports the checksum mismatch.
        let mut flipped = whole.clone();
        flipped[5] ^= 0x80;
        store.put("flip.mxshard", &flipped).unwrap();
        let index = read_index(store.as_ref(), "flip.mxshard").unwrap();
        let err = read_chunk(store.as_ref(), "flip.mxshard", &index[0]).unwrap_err();
        assert!(matches!(err, StoreError::ChecksumMismatch { .. }), "{err}");

        // Flip a byte inside the index region: BadIndex at read time.
        let mut flipped = whole.clone();
        let idx_pos = whole.len() - TRAILER_BYTES - ENTRY_BYTES + 70; // offset field of the entry
        flipped[idx_pos] ^= 0x01;
        store.put("flipidx.mxshard", &flipped).unwrap();
        let err = read_index(store.as_ref(), "flipidx.mxshard").unwrap_err();
        assert!(matches!(err, StoreError::BadIndex { .. }), "{err}");
    }

    #[test]
    fn oversized_keys_are_rejected_before_touching_the_shard() {
        let store = mem();
        let long = "k".repeat(KEY_BYTES + 1);
        let err = append_chunks(&store, "s.mxshard", &[(long, vec![1])], T).unwrap_err();
        assert!(matches!(err, StoreError::Io { .. }), "{err}");
        assert!(!store.exists("s.mxshard").unwrap());
    }
}
