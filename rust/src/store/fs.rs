//! [`FilesystemStore`]: the [`Storage`] trait over `std::fs`.
//!
//! Keys map to paths under a root directory. Whole-object `put` is
//! write-to-temp-then-rename, so a concurrent reader never observes a
//! half-written object; `append` relies on the caller holding the
//! per-shard [`crate::store::StoreLock`] (which is what makes the
//! returned start offset trustworthy); `try_create` is `O_EXCL`
//! (`OpenOptions::create_new`), atomic across both threads and
//! processes — the primitive the advisory lock is built on.

#![forbid(unsafe_code)]

use std::fs::{self, OpenOptions};
use std::io::{Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::{validate_key, Storage, StoreError};

/// Directory-rooted byte-object store.
#[derive(Debug, Clone)]
pub struct FilesystemStore {
    root: PathBuf,
}

impl FilesystemStore {
    /// Open (creating the root directory if needed).
    pub fn open(root: &Path) -> Result<Self, StoreError> {
        fs::create_dir_all(root).map_err(|e| StoreError::Io {
            op: "create root",
            key: root.display().to_string(),
            reason: e.to_string(),
        })?;
        Ok(Self { root: root.to_path_buf() })
    }

    /// The root directory this store is anchored at.
    pub fn root(&self) -> &Path {
        &self.root
    }

    fn path_for(&self, key: &str) -> Result<PathBuf, StoreError> {
        validate_key(key)?;
        let mut p = self.root.clone();
        for comp in key.split('/') {
            p.push(comp);
        }
        Ok(p)
    }

    fn io(op: &'static str, key: &str, e: std::io::Error) -> StoreError {
        if e.kind() == std::io::ErrorKind::NotFound {
            StoreError::MissingChunk { key: key.to_string() }
        } else {
            StoreError::Io { op, key: key.to_string(), reason: e.to_string() }
        }
    }

    fn ensure_parent(&self, path: &Path, key: &str) -> Result<(), StoreError> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent).map_err(|e| StoreError::Io {
                op: "create dir",
                key: key.to_string(),
                reason: e.to_string(),
            })?;
        }
        Ok(())
    }

    fn collect(
        &self,
        dir: &Path,
        rel: &mut Vec<String>,
        out: &mut Vec<String>,
    ) -> std::io::Result<()> {
        for entry in fs::read_dir(dir)? {
            let entry = entry?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let path = entry.path();
            rel.push(name);
            if path.is_dir() {
                self.collect(&path, rel, out)?;
            } else {
                out.push(rel.join("/"));
            }
            rel.pop();
        }
        Ok(())
    }
}

impl Storage for FilesystemStore {
    fn get(&self, key: &str) -> Result<Vec<u8>, StoreError> {
        let path = self.path_for(key)?;
        fs::read(&path).map_err(|e| Self::io("get", key, e))
    }

    fn get_range(&self, key: &str, offset: u64, len: u64) -> Result<Vec<u8>, StoreError> {
        let path = self.path_for(key)?;
        let mut f = fs::File::open(&path).map_err(|e| Self::io("get_range", key, e))?;
        f.seek(SeekFrom::Start(offset)).map_err(|e| Self::io("get_range", key, e))?;
        let mut buf = vec![0u8; len as usize];
        f.read_exact(&mut buf).map_err(|e| StoreError::Io {
            op: "get_range",
            key: key.to_string(),
            reason: format!("short read of {len} bytes at {offset}: {e}"),
        })?;
        Ok(buf)
    }

    fn size(&self, key: &str) -> Result<u64, StoreError> {
        let path = self.path_for(key)?;
        let meta = fs::metadata(&path).map_err(|e| Self::io("size", key, e))?;
        if meta.is_dir() {
            return Err(StoreError::MissingChunk { key: key.to_string() });
        }
        Ok(meta.len())
    }

    fn put(&self, key: &str, bytes: &[u8]) -> Result<(), StoreError> {
        let path = self.path_for(key)?;
        self.ensure_parent(&path, key)?;
        // Temp file beside the target so the rename stays on one mount.
        let tmp = path.with_extension("tmp-put");
        fs::write(&tmp, bytes).map_err(|e| Self::io("put", key, e))?;
        fs::rename(&tmp, &path).map_err(|e| Self::io("put", key, e))
    }

    fn append(&self, key: &str, bytes: &[u8]) -> Result<u64, StoreError> {
        let path = self.path_for(key)?;
        self.ensure_parent(&path, key)?;
        let mut f = OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)
            .map_err(|e| Self::io("append", key, e))?;
        let at = f.metadata().map_err(|e| Self::io("append", key, e))?.len();
        f.write_all(bytes).map_err(|e| Self::io("append", key, e))?;
        Ok(at)
    }

    fn try_create(&self, key: &str, bytes: &[u8]) -> Result<bool, StoreError> {
        let path = self.path_for(key)?;
        self.ensure_parent(&path, key)?;
        match OpenOptions::new().write(true).create_new(true).open(&path) {
            Ok(mut f) => {
                f.write_all(bytes).map_err(|e| Self::io("try_create", key, e))?;
                Ok(true)
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => Ok(false),
            Err(e) => Err(StoreError::Io {
                op: "try_create",
                key: key.to_string(),
                reason: e.to_string(),
            }),
        }
    }

    fn list(&self, prefix: &str) -> Result<Vec<String>, StoreError> {
        let mut out = Vec::new();
        let mut rel = Vec::new();
        if self.root.is_dir() {
            self.collect(&self.root, &mut rel, &mut out).map_err(|e| StoreError::Io {
                op: "list",
                key: prefix.to_string(),
                reason: e.to_string(),
            })?;
        }
        out.retain(|k| k.starts_with(prefix));
        out.sort();
        Ok(out)
    }

    fn erase(&self, key: &str) -> Result<(), StoreError> {
        let path = self.path_for(key)?;
        match fs::remove_file(&path) {
            Ok(()) => Ok(()),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(()),
            Err(e) => {
                Err(StoreError::Io { op: "erase", key: key.to_string(), reason: e.to_string() })
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scratch(name: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("mxscale-store-fs-{name}-{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn filesystem_store_round_trips_nested_keys() {
        let dir = scratch("roundtrip");
        let s = FilesystemStore::open(&dir).unwrap();
        s.put("sessions/r1/meta", b"abc").unwrap();
        s.put("sessions/r1/params", b"defgh").unwrap();
        assert_eq!(s.get("sessions/r1/meta").unwrap(), b"abc");
        assert_eq!(s.size("sessions/r1/params").unwrap(), 5);
        assert_eq!(s.get_range("sessions/r1/params", 1, 3).unwrap(), b"efg");
        assert!(s.get_range("sessions/r1/params", 3, 3).is_err());
        assert_eq!(
            s.list("sessions/").unwrap(),
            vec!["sessions/r1/meta".to_string(), "sessions/r1/params".to_string()]
        );
        assert!(matches!(s.get("nope"), Err(StoreError::MissingChunk { .. })));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_returns_start_offsets_and_try_create_is_exclusive() {
        let dir = scratch("append");
        let s = FilesystemStore::open(&dir).unwrap();
        assert_eq!(s.append("shard.mxshard", b"aaaa").unwrap(), 0);
        assert_eq!(s.append("shard.mxshard", b"bb").unwrap(), 4);
        assert_eq!(s.get("shard.mxshard").unwrap(), b"aaaabb");
        assert!(s.try_create("shard.mxshard.lock", b"w1").unwrap());
        assert!(!s.try_create("shard.mxshard.lock", b"w2").unwrap());
        assert_eq!(s.get("shard.mxshard.lock").unwrap(), b"w1");
        s.erase("shard.mxshard.lock").unwrap();
        s.erase("shard.mxshard.lock").unwrap(); // idempotent
        assert!(s.try_create("shard.mxshard.lock", b"w3").unwrap());
        let _ = fs::remove_dir_all(&dir);
    }
}
