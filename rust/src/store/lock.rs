//! Store-level advisory locking.
//!
//! A [`StoreLock`] is an RAII guard over a lock *object* created with
//! [`crate::store::Storage::try_create`] (atomic create-if-absent, so
//! it excludes across threads and processes alike). Shard appends take
//! the shard's lock for the duration of one read-index → append →
//! rewrite-index cycle; writers targeting *different* shards never
//! touch each other's lock, which is what keeps a fleet of concurrent
//! writers from serializing behind a single mutex.
//!
//! Acquisition spins with exponential backoff (1 ms → 16 ms) up to a
//! caller-chosen timeout, then fails with
//! [`crate::store::StoreError::LockHeld`] — a structured error the
//! fleet can surface or retry on, never a deadlock.
//!
//! **Staleness takeover:** each lock object records its birth time (a
//! backend-portable mtime equivalent — the `Storage` trait has no
//! metadata surface, so the stamp rides in the lock bytes). A writer
//! that crashes between `try_create` and release leaves its lock
//! behind, and every later [`StoreLock::acquire`] would park in
//! `LockHeld` retries forever. [`StoreLock::acquire_with_staleness`]
//! breaks a lock whose recorded birth is older than `stale_after`
//! (erase + re-`try_create`; the create is atomic, so exactly one
//! contender wins the broken lock). Shard appends use it with
//! [`STALE_LOCK_AFTER`] — far above any real append, so a live writer
//! is never robbed, only a presumed-crashed one. Legacy or unparseable
//! lock bytes are never broken (conservative: no stamp, no takeover),
//! and plain [`StoreLock::acquire`] keeps the strict no-takeover
//! semantics for callers that prefer an explicit `LockHeld`.

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use super::{Storage, StoreError};

/// Identifies a lock object (and versions its byte layout: prefix then
/// a 64-bit little-endian unix-nanos birth stamp).
const LOCK_PREFIX: &[u8] = b"mxscale-store-lock";

/// How old a lock must be before [`StoreLock::acquire_with_staleness`]
/// presumes its writer crashed. One shard append holds the lock for
/// milliseconds; a minute-old lock means the holder died between
/// `try_create` and release.
pub const STALE_LOCK_AFTER: Duration = Duration::from_secs(60);

/// Lock-object bytes recording `birth` as the holder's start time.
/// `pub(crate)` so the chaos layer can forge a crashed writer's lock.
pub(crate) fn stamped_lock_bytes(birth: SystemTime) -> Vec<u8> {
    let nanos = birth.duration_since(UNIX_EPOCH).map(|d| d.as_nanos() as u64).unwrap_or(0);
    let mut bytes = LOCK_PREFIX.to_vec();
    bytes.extend_from_slice(&nanos.to_le_bytes());
    bytes
}

/// Parse a lock object's birth stamp. `None` for legacy/foreign bytes —
/// those locks are never broken.
fn lock_birth_nanos(bytes: &[u8]) -> Option<u64> {
    let stamp = bytes.strip_prefix(LOCK_PREFIX)?;
    let stamp: [u8; std::mem::size_of::<u64>()] = stamp.try_into().ok()?;
    Some(u64::from_le_bytes(stamp))
}

/// Age of the lock described by `bytes` at wall-clock `now`. `None`
/// when the bytes carry no stamp (or the clock predates the stamp —
/// skew reads as "not stale", never as instant takeover).
fn lock_age(bytes: &[u8], now: SystemTime) -> Option<Duration> {
    let birth = lock_birth_nanos(bytes)?;
    let now_nanos = now.duration_since(UNIX_EPOCH).map(|d| d.as_nanos() as u64).unwrap_or(0);
    Some(Duration::from_nanos(now_nanos.checked_sub(birth)?))
}

/// RAII advisory lock over a [`Storage`] object. Dropping the guard
/// releases the lock (best-effort; [`StoreLock::release`] reports the
/// error for callers who care).
pub struct StoreLock {
    store: Arc<dyn Storage>,
    key: String,
    held: bool,
}

impl StoreLock {
    /// Acquire `key` within `timeout`, spinning with backoff. Never
    /// breaks an existing lock — a crashed holder surfaces as
    /// [`StoreError::LockHeld`] (see
    /// [`StoreLock::acquire_with_staleness`] for the takeover path).
    pub fn acquire(
        store: Arc<dyn Storage>,
        key: &str,
        timeout: Duration,
    ) -> Result<Self, StoreError> {
        Self::spin_acquire(store, key, timeout, None)
    }

    /// Acquire `key` within `timeout`, breaking any existing lock whose
    /// recorded birth stamp is older than `stale_after` (a crashed
    /// writer's leftover). The break is erase-then-`try_create`; the
    /// create is atomic, so concurrent contenders race fairly and
    /// exactly one wins. This is advisory best-effort: a holder that is
    /// merely *slower* than `stale_after` can be robbed, which is why
    /// the shard path uses [`STALE_LOCK_AFTER`] — orders of magnitude
    /// above a real append.
    pub fn acquire_with_staleness(
        store: Arc<dyn Storage>,
        key: &str,
        timeout: Duration,
        stale_after: Duration,
    ) -> Result<Self, StoreError> {
        Self::spin_acquire(store, key, timeout, Some(stale_after))
    }

    fn spin_acquire(
        store: Arc<dyn Storage>,
        key: &str,
        timeout: Duration,
        stale_after: Option<Duration>,
    ) -> Result<Self, StoreError> {
        let start = Instant::now();
        let mut backoff = Duration::from_millis(1);
        loop {
            if store.try_create(key, &stamped_lock_bytes(SystemTime::now()))? {
                return Ok(Self { store, key: key.to_string(), held: true });
            }
            if let Some(stale_after) = stale_after {
                // the holder may release between our try_create and
                // this read — a vanished lock just means retry
                let held = match store.get(key) {
                    Ok(bytes) => Some(bytes),
                    Err(StoreError::MissingChunk { .. }) => None,
                    Err(e) => return Err(e),
                };
                let stale = held
                    .as_deref()
                    .and_then(|b| lock_age(b, SystemTime::now()))
                    .is_some_and(|age| age > stale_after);
                if stale {
                    store.erase(key)?;
                    continue; // race the other contenders for the create
                }
            }
            if start.elapsed() >= timeout {
                return Err(StoreError::LockHeld { key: key.to_string() });
            }
            std::thread::sleep(backoff.min(timeout.saturating_sub(start.elapsed())));
            backoff = (backoff * 2).min(Duration::from_millis(16));
        }
    }

    /// The lock object's key.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Release explicitly, surfacing any erase error (Drop swallows it).
    pub fn release(mut self) -> Result<(), StoreError> {
        self.held = false;
        self.store.erase(&self.key)
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        if self.held {
            let _ = self.store.erase(&self.key);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemoryStore;

    #[test]
    fn lock_excludes_until_released_and_drop_releases() {
        let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
        let lock = StoreLock::acquire(store.clone(), "s.lock", Duration::from_millis(50)).unwrap();
        let contender = StoreLock::acquire(store.clone(), "s.lock", Duration::from_millis(20));
        assert!(matches!(contender, Err(StoreError::LockHeld { .. })));
        drop(lock);
        let relock =
            StoreLock::acquire(store.clone(), "s.lock", Duration::from_millis(50)).unwrap();
        relock.release().unwrap();
        assert!(!store.exists("s.lock").unwrap());
    }

    #[test]
    fn waiting_acquire_succeeds_once_holder_drops() {
        let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
        let lock = StoreLock::acquire(store.clone(), "w.lock", Duration::from_millis(50)).unwrap();
        let store2 = store.clone();
        let waiter = std::thread::spawn(move || {
            StoreLock::acquire(store2, "w.lock", Duration::from_secs(5)).map(|l| l.release())
        });
        std::thread::sleep(Duration::from_millis(10));
        drop(lock);
        waiter.join().expect("waiter thread").expect("acquire after drop").unwrap();
    }

    /// A writer that crashed an hour ago left this lock behind.
    fn crashed_writer_lock(store: &dyn Storage, key: &str) {
        let birth = SystemTime::now() - Duration::from_secs(3600);
        assert!(store.try_create(key, &stamped_lock_bytes(birth)).unwrap());
    }

    #[test]
    fn crash_then_reacquire_breaks_the_stale_lock() {
        let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
        crashed_writer_lock(store.as_ref(), "c.lock");
        // strict acquire still parks — the takeover is opt-in
        let strict = StoreLock::acquire(store.clone(), "c.lock", Duration::from_millis(20));
        assert!(matches!(strict, Err(StoreError::LockHeld { .. })));
        // staleness-aware acquire breaks it without waiting out retries
        let lock = StoreLock::acquire_with_staleness(
            store.clone(),
            "c.lock",
            Duration::from_millis(50),
            STALE_LOCK_AFTER,
        )
        .expect("stale lock must be broken, not parked behind");
        lock.release().unwrap();
        assert!(!store.exists("c.lock").unwrap());
    }

    #[test]
    fn fresh_and_unparseable_locks_are_never_broken() {
        let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
        // a *live* holder's lock (fresh stamp) survives the takeover path
        let holder =
            StoreLock::acquire(store.clone(), "f.lock", Duration::from_millis(50)).unwrap();
        let r = StoreLock::acquire_with_staleness(
            store.clone(),
            "f.lock",
            Duration::from_millis(20),
            STALE_LOCK_AFTER,
        );
        assert!(matches!(r, Err(StoreError::LockHeld { .. })), "fresh lock robbed");
        drop(holder);
        // legacy bytes (no stamp) are conservative: held, never broken
        assert!(store.try_create("legacy.lock", b"mxscale-store-lock").unwrap());
        let r = StoreLock::acquire_with_staleness(
            store.clone(),
            "legacy.lock",
            Duration::from_millis(20),
            Duration::ZERO,
        );
        assert!(matches!(r, Err(StoreError::LockHeld { .. })), "unstamped lock broken");
    }

    #[test]
    fn lock_bytes_round_trip_their_birth_stamp() {
        let birth = UNIX_EPOCH + Duration::from_secs(1_000_000);
        let bytes = stamped_lock_bytes(birth);
        let now = birth + Duration::from_secs(90);
        assert_eq!(lock_age(&bytes, now), Some(Duration::from_secs(90)));
        assert_eq!(lock_age(b"mxscale-store-lock", now), None, "legacy bytes have no age");
        assert_eq!(lock_age(b"something-else", now), None);
        // clock skew (now before birth) reads as not-stale, not as 0-age
        assert_eq!(lock_age(&bytes, birth - Duration::from_secs(1)), None);
    }
}
