//! Store-level advisory locking.
//!
//! A [`StoreLock`] is an RAII guard over a lock *object* created with
//! [`crate::store::Storage::try_create`] (atomic create-if-absent, so
//! it excludes across threads and processes alike). Shard appends take
//! the shard's lock for the duration of one read-index → append →
//! rewrite-index cycle; writers targeting *different* shards never
//! touch each other's lock, which is what keeps a fleet of concurrent
//! writers from serializing behind a single mutex.
//!
//! Acquisition spins with exponential backoff (1 ms → 16 ms) up to a
//! caller-chosen timeout, then fails with
//! [`crate::store::StoreError::LockHeld`] — a structured error the
//! fleet can surface or retry on, never a deadlock.

#![forbid(unsafe_code)]

use std::sync::Arc;
use std::time::{Duration, Instant};

use super::{Storage, StoreError};

/// RAII advisory lock over a [`Storage`] object. Dropping the guard
/// releases the lock (best-effort; [`StoreLock::release`] reports the
/// error for callers who care).
pub struct StoreLock {
    store: Arc<dyn Storage>,
    key: String,
    held: bool,
}

impl StoreLock {
    /// Acquire `key` within `timeout`, spinning with backoff.
    pub fn acquire(
        store: Arc<dyn Storage>,
        key: &str,
        timeout: Duration,
    ) -> Result<Self, StoreError> {
        let start = Instant::now();
        let mut backoff = Duration::from_millis(1);
        loop {
            if store.try_create(key, b"mxscale-store-lock")? {
                return Ok(Self { store, key: key.to_string(), held: true });
            }
            if start.elapsed() >= timeout {
                return Err(StoreError::LockHeld { key: key.to_string() });
            }
            std::thread::sleep(backoff.min(timeout.saturating_sub(start.elapsed())));
            backoff = (backoff * 2).min(Duration::from_millis(16));
        }
    }

    /// The lock object's key.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Release explicitly, surfacing any erase error (Drop swallows it).
    pub fn release(mut self) -> Result<(), StoreError> {
        self.held = false;
        self.store.erase(&self.key)
    }
}

impl Drop for StoreLock {
    fn drop(&mut self) {
        if self.held {
            let _ = self.store.erase(&self.key);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::store::MemoryStore;

    #[test]
    fn lock_excludes_until_released_and_drop_releases() {
        let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
        let lock = StoreLock::acquire(store.clone(), "s.lock", Duration::from_millis(50)).unwrap();
        let contender = StoreLock::acquire(store.clone(), "s.lock", Duration::from_millis(20));
        assert!(matches!(contender, Err(StoreError::LockHeld { .. })));
        drop(lock);
        let relock =
            StoreLock::acquire(store.clone(), "s.lock", Duration::from_millis(50)).unwrap();
        relock.release().unwrap();
        assert!(!store.exists("s.lock").unwrap());
    }

    #[test]
    fn waiting_acquire_succeeds_once_holder_drops() {
        let store: Arc<dyn Storage> = Arc::new(MemoryStore::new());
        let lock = StoreLock::acquire(store.clone(), "w.lock", Duration::from_millis(50)).unwrap();
        let store2 = store.clone();
        let waiter = std::thread::spawn(move || {
            StoreLock::acquire(store2, "w.lock", Duration::from_secs(5)).map(|l| l.release())
        });
        std::thread::sleep(Duration::from_millis(10));
        drop(lock);
        waiter.join().expect("waiter thread").expect("acquire after drop").unwrap();
    }
}
