//! [`CheckpointStore`]: the one facade every checkpoint entry point
//! goes through.
//!
//! The trainer (`Checkpoint::save`/`load`), the fleet scheduler's
//! domain-shift save→resume cycle, and the `mxscale fleet --store` CLI
//! all address sessions by id through this type; none of them touch
//! `std::fs` or shard internals directly. Two layouts:
//!
//! * **Plain** — one object per chunk under `sessions/<id>/…`. Simple,
//!   debuggable, `O(chunks)` files per robot.
//! * **Sharded** — chunks packed into `shards` large
//!   `shard-NNNN.mxshard` objects (session → shard by FNV-1a of the
//!   id), each with a trailing index. A 1000-robot fleet persists into
//!   a handful of files, and resuming one robot reads the index plus
//!   that robot's chunks only.
//!
//! **Compat shim:** a legacy monolithic `.mxckpt` file dropped into the
//! store root as `<id>.mxckpt` (v1 or v2) is found by [`CheckpointStore::load`]
//! when no chunked session exists — the monolithic format is just the
//! single-chunk case. Loading goes through `Checkpoint::from_bytes`
//! unchanged, so both legacy versions keep their exact semantics.

#![forbid(unsafe_code)]

use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use crate::trainer::checkpoint::Checkpoint;
use crate::trainer::session::{TrainError, TrainSession};
use crate::util::bytes::{fnv1a64, ByteReader};
use crate::workloads::Dataset;

use super::chunk::{self, payload_key};
use super::fs::FilesystemStore;
use super::shard::{self, IndexEntry, KEY_BYTES};
use super::{Storage, StoreError};

/// How sessions map onto storage objects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StoreLayout {
    /// One object per chunk under `sessions/<id>/`.
    Plain,
    /// Chunks packed into `shards` shard objects with trailing indexes.
    Sharded { shards: u32 },
}

/// Default shard count — 1000 robots into 8 files (ISSUE 8 acceptance).
pub const DEFAULT_SHARDS: u32 = 8;

impl StoreLayout {
    /// Parse a CLI spelling: `plain`, `sharded`, or `sharded:N`.
    pub fn parse(s: &str) -> Option<StoreLayout> {
        match s {
            "plain" => Some(StoreLayout::Plain),
            "sharded" => Some(StoreLayout::Sharded { shards: DEFAULT_SHARDS }),
            _ => {
                let n = s.strip_prefix("sharded:")?.parse::<u32>().ok()?;
                if (1..=4096).contains(&n) {
                    Some(StoreLayout::Sharded { shards: n })
                } else {
                    None
                }
            }
        }
    }

    /// The canonical spelling `parse` accepts.
    pub fn name(&self) -> String {
        match self {
            StoreLayout::Plain => "plain".into(),
            StoreLayout::Sharded { shards } => format!("sharded:{shards}"),
        }
    }
}

/// Session ids become chunk-key components; bound them so every
/// `<id>/payload/<i>` fits the shard index's fixed key field.
const MAX_SESSION_ID: usize = KEY_BYTES - "/payload/4096".len();

fn validate_session_id(id: &str) -> Result<(), StoreError> {
    let ok = !id.is_empty()
        && id.len() <= MAX_SESSION_ID
        && id.bytes().all(|b| b.is_ascii_alphanumeric() || b"._-".contains(&b))
        && id != "."
        && id != "..";
    if ok {
        Ok(())
    } else {
        Err(StoreError::Io {
            op: "session id",
            key: id.to_string(),
            reason: format!("must be 1..={MAX_SESSION_ID} chars of [A-Za-z0-9._-]"),
        })
    }
}

/// The unified checkpoint facade over any [`Storage`].
#[derive(Clone)]
pub struct CheckpointStore {
    store: Arc<dyn Storage>,
    layout: StoreLayout,
    lock_timeout: Duration,
}

impl CheckpointStore {
    /// Wrap an existing storage backend.
    pub fn new(store: Arc<dyn Storage>, layout: StoreLayout) -> Self {
        Self { store, layout, lock_timeout: Duration::from_secs(10) }
    }

    /// Filesystem sugar: a store rooted at `dir`.
    pub fn open_dir(dir: &Path, layout: StoreLayout) -> Result<Self, StoreError> {
        Ok(Self::new(Arc::new(FilesystemStore::open(dir)?), layout))
    }

    /// Override the advisory-lock acquisition timeout (tests use tiny
    /// values to observe [`StoreError::LockHeld`] without waiting).
    pub fn with_lock_timeout(mut self, timeout: Duration) -> Self {
        self.lock_timeout = timeout;
        self
    }

    /// The backing storage (e.g. to wrap in a `CountingStore`).
    pub fn storage(&self) -> Arc<dyn Storage> {
        self.store.clone()
    }

    /// The configured layout.
    pub fn layout(&self) -> StoreLayout {
        self.layout
    }

    fn shard_object(&self, id: &str, shards: u32) -> String {
        format!("shard-{:04}.mxshard", fnv1a64(id.as_bytes()) % shards.max(1) as u64)
    }

    fn plain_key(id: &str, chunk: &str) -> String {
        format!("sessions/{id}/{chunk}")
    }

    fn legacy_key(id: &str) -> String {
        format!("{id}.mxckpt")
    }

    /// Persist one session's checkpoint (chunked).
    pub fn save(&self, id: &str, ck: &Checkpoint) -> Result<(), StoreError> {
        self.save_many(&[(id.to_string(), ck)])
    }

    /// Persist many sessions in one pass. Under the sharded layout the
    /// batch is grouped by destination shard so each shard is locked
    /// and its index rewritten **once** — the fleet's end-of-round
    /// persist does one append per shard, not per robot.
    pub fn save_many(&self, sessions: &[(String, &Checkpoint)]) -> Result<(), StoreError> {
        for (id, _) in sessions {
            validate_session_id(id)?;
        }
        match self.layout {
            StoreLayout::Plain => {
                for (id, ck) in sessions {
                    for (leaf, bytes) in chunk::split_checkpoint(ck) {
                        self.store.put(&Self::plain_key(id, &leaf), &bytes)?;
                    }
                }
                Ok(())
            }
            StoreLayout::Sharded { shards } => {
                // group by shard, preserving per-session chunk order
                let mut by_shard: Vec<(String, Vec<(String, Vec<u8>)>)> = Vec::new();
                for (id, ck) in sessions {
                    let shard = self.shard_object(id, shards);
                    let chunks: Vec<(String, Vec<u8>)> = chunk::split_checkpoint(ck)
                        .into_iter()
                        .map(|(leaf, bytes)| (format!("{id}/{leaf}"), bytes))
                        .collect();
                    match by_shard.iter_mut().find(|(s, _)| *s == shard) {
                        Some((_, acc)) => acc.extend(chunks),
                        None => by_shard.push((shard, chunks)),
                    }
                }
                for (shard, chunks) in &by_shard {
                    shard::append_chunks(&self.store, shard, chunks, self.lock_timeout)?;
                }
                Ok(())
            }
        }
    }

    /// Fetch the shard index entries for one session (sharded layout).
    fn session_entries(&self, id: &str, shards: u32) -> Result<Vec<IndexEntry>, StoreError> {
        let shard = self.shard_object(id, shards);
        let prefix = format!("{id}/");
        let entries = shard::read_index(self.store.as_ref(), &shard)?;
        Ok(entries.into_iter().filter(|e| e.key.starts_with(&prefix)).collect())
    }

    /// The `(chunk key, length)` manifest of one stored session — what
    /// a partial reader *would* fetch. Tests use this to bound the
    /// bytes a resume is allowed to read.
    pub fn chunk_manifest(&self, id: &str) -> Result<Vec<(String, u64)>, StoreError> {
        validate_session_id(id)?;
        match self.layout {
            StoreLayout::Plain => {
                let prefix = Self::plain_key(id, "");
                let mut out = Vec::new();
                for key in self.store.list(&prefix)? {
                    let len = self.store.size(&key)?;
                    out.push((key, len));
                }
                if out.is_empty() {
                    return Err(StoreError::MissingChunk { key: Self::plain_key(id, chunk::META) });
                }
                Ok(out)
            }
            StoreLayout::Sharded { shards } => {
                let entries = self.session_entries(id, shards)?;
                if entries.is_empty() {
                    return Err(StoreError::MissingChunk { key: format!("{id}/{}", chunk::META) });
                }
                Ok(entries.into_iter().map(|e| (e.key, e.len)).collect())
            }
        }
    }

    /// Load a session's checkpoint: chunked layout first, then the
    /// legacy monolithic `<id>.mxckpt` compat shim.
    pub fn load(&self, id: &str) -> Result<Checkpoint, StoreError> {
        validate_session_id(id)?;
        match self.load_chunked(id) {
            Ok(ck) => Ok(ck),
            Err(StoreError::MissingChunk { .. }) => self.load_legacy(id),
            Err(e) => Err(e),
        }
    }

    fn load_chunked(&self, id: &str) -> Result<Checkpoint, StoreError> {
        match self.layout {
            StoreLayout::Plain => {
                chunk::assemble_checkpoint(|leaf| self.store.get(&Self::plain_key(id, leaf)))
            }
            StoreLayout::Sharded { shards } => {
                let shard = self.shard_object(id, shards);
                let entries = self.session_entries(id, shards)?;
                if entries.is_empty() {
                    return Err(StoreError::MissingChunk { key: format!("{id}/{}", chunk::META) });
                }
                chunk::assemble_checkpoint(|leaf| {
                    let key = format!("{id}/{leaf}");
                    let entry = entries
                        .iter()
                        .find(|e| e.key == key)
                        .ok_or(StoreError::MissingChunk { key })?;
                    shard::read_chunk(self.store.as_ref(), &shard, entry)
                })
            }
        }
    }

    fn load_legacy(&self, id: &str) -> Result<Checkpoint, StoreError> {
        let key = Self::legacy_key(id);
        let bytes = self.store.get(&key)?;
        Checkpoint::from_bytes(&bytes)
            .map_err(|reason| StoreError::BadIndex { key, reason })
    }

    /// Load a single MX weight-image tensor without touching the rest
    /// of the checkpoint — the per-layer partial read.
    pub fn load_payload_tensor(
        &self,
        id: &str,
        i: usize,
    ) -> Result<crate::mx::tensor::MxTensor, StoreError> {
        validate_session_id(id)?;
        let leaf = payload_key(i);
        let bytes = match self.layout {
            StoreLayout::Plain => self.store.get(&Self::plain_key(id, &leaf))?,
            StoreLayout::Sharded { shards } => {
                let shard = self.shard_object(id, shards);
                let key = format!("{id}/{leaf}");
                let entries = self.session_entries(id, shards)?;
                let entry = entries
                    .iter()
                    .find(|e| e.key == key)
                    .ok_or(StoreError::MissingChunk { key })?;
                shard::read_chunk(self.store.as_ref(), &shard, entry)?
            }
        };
        let mut r = ByteReader::new(&bytes);
        let t = crate::mx::tensor::MxTensor::read_bytes(&mut r)
            .map_err(|e| StoreError::BadIndex { key: format!("{id}/{leaf}"), reason: e })?;
        if r.remaining() != 0 {
            return Err(StoreError::BadIndex {
                key: format!("{id}/{leaf}"),
                reason: format!("{} trailing bytes after tensor", r.remaining()),
            });
        }
        Ok(t)
    }

    /// Resume a training session from the store — the single
    /// checkpoint-restore entry point (partial read under the sharded
    /// layout, monolithic via the compat shim, bit-exact either way).
    pub fn resume(&self, id: &str, dataset: Dataset) -> Result<TrainSession, TrainError> {
        let ck = self.load(id)?;
        TrainSession::resume(dataset, &ck)
    }

    /// Ids of every session visible in the store (chunked and legacy).
    pub fn sessions(&self) -> Result<Vec<String>, StoreError> {
        let mut ids: Vec<String> = Vec::new();
        match self.layout {
            StoreLayout::Plain => {
                for key in self.store.list("sessions/")? {
                    if let Some(rest) = key.strip_prefix("sessions/") {
                        if let Some((id, leaf)) = rest.split_once('/') {
                            if leaf == chunk::META {
                                ids.push(id.to_string());
                            }
                        }
                    }
                }
            }
            StoreLayout::Sharded { .. } => {
                for shard in self.shard_files()? {
                    for e in shard::read_index(self.store.as_ref(), &shard)? {
                        if let Some((id, leaf)) = e.key.split_once('/') {
                            if leaf == chunk::META {
                                ids.push(id.to_string());
                            }
                        }
                    }
                }
            }
        }
        for key in self.store.list("")? {
            if let Some(id) = key.strip_suffix(".mxckpt") {
                if !key.contains('/') {
                    ids.push(id.to_string());
                }
            }
        }
        ids.sort();
        ids.dedup();
        Ok(ids)
    }

    /// The shard objects currently present (empty under `Plain`).
    pub fn shard_files(&self) -> Result<Vec<String>, StoreError> {
        let mut out: Vec<String> = self
            .store
            .list("")?
            .into_iter()
            .filter(|k| k.starts_with("shard-") && k.ends_with(".mxshard"))
            .collect();
        out.sort();
        Ok(out)
    }

    /// Remove a session's chunks (plain layout) or its legacy file.
    /// Sharded chunks are log-structured: erasing drops the legacy
    /// object only — shard space is reclaimed by rewriting shards,
    /// which is an offline compaction concern, not a hot-path one.
    pub fn erase(&self, id: &str) -> Result<(), StoreError> {
        validate_session_id(id)?;
        if let StoreLayout::Plain = self.layout {
            for key in self.store.list(&Self::plain_key(id, ""))? {
                self.store.erase(&key)?;
            }
        }
        self.store.erase(&Self::legacy_key(id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::store::MemoryStore;
    use crate::trainer::checkpoint::weight_payload;
    use crate::trainer::qat::QuantScheme;
    use crate::trainer::session::TrainConfig;
    use crate::util::rng::Pcg64;

    fn sample_checkpoint(seed: u64) -> Checkpoint {
        let mut rng = Pcg64::new(seed);
        let dims = vec![32usize, 16, 32];
        let mlp = crate::trainer::mlp::Mlp::new(&dims, &mut rng);
        let scheme = QuantScheme::MxSquare(ElementFormat::E4M3);
        let config = TrainConfig {
            scheme,
            backend: crate::backend::BackendKind::parse("fast").expect("fast backend"),
            dims: Some(dims),
            batch_size: 16,
            lr: 1e-3,
            steps: 40,
            eval_every: 10,
            seed,
        };
        Checkpoint {
            config,
            step: 5,
            adam_step: 5,
            train_curve: vec![(0, 1.0)],
            val_curve: vec![],
            params: mlp.flat_params(),
            opt: mlp.flat_opt_state(),
            scheme_log: vec![(0, scheme.name())],
            payload: weight_payload(&mlp.weights, scheme),
        }
    }

    fn mem_store(layout: StoreLayout) -> CheckpointStore {
        CheckpointStore::new(Arc::new(MemoryStore::new()), layout)
    }

    #[test]
    fn layout_parse_round_trips_and_rejects_garbage() {
        assert_eq!(StoreLayout::parse("plain"), Some(StoreLayout::Plain));
        assert_eq!(StoreLayout::parse("sharded"), Some(StoreLayout::Sharded { shards: 8 }));
        assert_eq!(StoreLayout::parse("sharded:3"), Some(StoreLayout::Sharded { shards: 3 }));
        for bad in ["", "shard", "sharded:0", "sharded:9999", "sharded:x"] {
            assert_eq!(StoreLayout::parse(bad), None, "{bad}");
        }
        let name = StoreLayout::Sharded { shards: 3 }.name();
        assert_eq!(StoreLayout::parse(&name).unwrap().name(), "sharded:3");
    }

    #[test]
    fn both_layouts_round_trip_bitwise() {
        for layout in [StoreLayout::Plain, StoreLayout::Sharded { shards: 2 }] {
            let cs = mem_store(layout);
            let ck = sample_checkpoint(1);
            cs.save("robot-00", &ck).unwrap();
            let back = cs.load("robot-00").unwrap();
            assert_eq!(back.to_bytes(), ck.to_bytes(), "{layout:?}");
            assert_eq!(cs.sessions().unwrap(), vec!["robot-00".to_string()]);
        }
    }

    #[test]
    fn resave_overwrites_and_newest_wins() {
        let cs = mem_store(StoreLayout::Sharded { shards: 1 });
        let ck1 = sample_checkpoint(1);
        let mut ck2 = sample_checkpoint(1);
        ck2.step = 99;
        cs.save("r", &ck1).unwrap();
        cs.save("r", &ck2).unwrap();
        assert_eq!(cs.load("r").unwrap().step, 99);
        assert_eq!(cs.sessions().unwrap().len(), 1);
    }

    #[test]
    fn legacy_monolithic_file_loads_through_the_compat_shim() {
        let cs = mem_store(StoreLayout::Sharded { shards: 4 });
        let ck = sample_checkpoint(2);
        cs.storage().put("old-robot.mxckpt", &ck.to_bytes()).unwrap();
        let back = cs.load("old-robot").unwrap();
        assert_eq!(back.to_bytes(), ck.to_bytes());
        assert!(cs.sessions().unwrap().contains(&"old-robot".to_string()));
        // and a corrupt legacy file is a structured error
        cs.storage().put("bad.mxckpt", b"MXCKgarbage").unwrap();
        assert!(matches!(cs.load("bad"), Err(StoreError::BadIndex { .. })));
    }

    #[test]
    fn save_many_packs_one_append_per_shard() {
        let cs = mem_store(StoreLayout::Sharded { shards: 2 });
        let cks: Vec<(String, Checkpoint)> =
            (0..6).map(|i| (format!("robot-{i:02}"), sample_checkpoint(i as u64))).collect();
        let refs: Vec<(String, &Checkpoint)> =
            cks.iter().map(|(id, ck)| (id.clone(), ck)).collect();
        cs.save_many(&refs).unwrap();
        assert!(cs.shard_files().unwrap().len() <= 2);
        for (id, ck) in &cks {
            assert_eq!(cs.load(id).unwrap().to_bytes(), ck.to_bytes(), "{id}");
        }
        let manifest = cs.chunk_manifest("robot-03").unwrap();
        assert!(manifest.iter().any(|(k, _)| k == "robot-03/meta"));
        assert!(manifest.iter().all(|(k, _)| k.starts_with("robot-03/")));
    }

    #[test]
    fn payload_tensor_partial_read_matches_full_load() {
        for layout in [StoreLayout::Plain, StoreLayout::Sharded { shards: 1 }] {
            let cs = mem_store(layout);
            let ck = sample_checkpoint(3);
            cs.save("r", &ck).unwrap();
            let t = cs.load_payload_tensor("r", 1).unwrap();
            let full = cs.load("r").unwrap();
            let bytes = |t: &crate::mx::tensor::MxTensor| {
                let mut w = crate::util::bytes::ByteWriter::new();
                t.write_bytes(&mut w);
                w.into_bytes()
            };
            assert_eq!(bytes(&t), bytes(&full.payload[1]), "{layout:?}");
            assert!(matches!(
                cs.load_payload_tensor("r", 9),
                Err(StoreError::MissingChunk { .. })
            ));
        }
    }

    #[test]
    fn bad_session_ids_are_rejected() {
        let cs = mem_store(StoreLayout::Plain);
        let ck = sample_checkpoint(4);
        let too_long = "i".repeat(MAX_SESSION_ID + 1);
        for bad in ["", "a/b", "..", "x y", too_long.as_str()] {
            assert!(cs.save(bad, &ck).is_err(), "{bad}");
            assert!(cs.load(bad).is_err(), "{bad}");
        }
    }
}
