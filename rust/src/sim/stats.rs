//! Simple running statistics (mean/min/max) for benchmark harnesses.

#![forbid(unsafe_code)]

/// Online mean/min/max/count accumulator.
#[derive(Debug, Clone, Copy, Default)]
pub struct Stats {
    pub n: u64,
    pub sum: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn push(&mut self, x: f64) {
        if self.n == 0 {
            self.min = x;
            self.max = x;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
        self.n += 1;
        self.sum += x;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_mean_min_max() {
        let mut s = Stats::default();
        for x in [3.0, 1.0, 2.0] {
            s.push(x);
        }
        assert_eq!(s.mean(), 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.n, 3);
    }
}
