//! Cycle bookkeeping and run statistics shared by the simulators.

pub mod stats;
