//! Admission control: who gets a core when sessions keep arriving.
//!
//! An [`Admission`] policy sees one [`SessionOffer`] (identity,
//! priority, step budget) and the executor's current [`LoadSnapshot`],
//! and answers with an [`AdmitDecision`]. Policies are pure — the
//! executor owns all state — so decisions are deterministic given the
//! same offer/load pair and trivially unit-testable.
//!
//! Two policies ship:
//!
//! - [`FixedRoster`] — the old `FleetScheduler` discipline expressed
//!   behind the trait: everything syntactically valid is admitted, load
//!   be damned. Useful as the closed-roster baseline and for tests that
//!   want the executor saturated.
//! - [`BudgetAware`] — the serving default: refuse invalid offers,
//!   admit while live sessions fit capacity, park a bounded overflow
//!   for later, shed the rest with [`crate::serve::ServeError::Overloaded`].

#![forbid(unsafe_code)]

/// One arriving session, as the admission layer sees it. The full
/// [`crate::fleet::SessionSpec`] rides alongside in the executor's
/// [`crate::serve::Arrival`]; policies only get the cheap summary so
/// they cannot depend on model state.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionOffer {
    pub id: String,
    /// Dispatch priority, clamped to [`crate::serve::MAX_PRIORITY`].
    pub priority: u8,
    /// The offer's step budget (`SessionBudget::max_steps`).
    pub budget_steps: usize,
}

/// The executor's load at the moment of an admission decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoadSnapshot {
    /// Sessions admitted and not yet completed/evicted/failed.
    pub live: usize,
    /// Admitted sessions waiting in dispatch queues (subset of `live`).
    pub queued: usize,
    /// Sessions parked by admission, waiting for capacity.
    pub parked: usize,
    /// The configured live-session ceiling.
    pub capacity: usize,
}

/// What to do with one offer.
#[derive(Debug, Clone, PartialEq)]
pub enum AdmitDecision {
    /// Build the session and queue it for dispatch.
    Admit,
    /// Hold the arrival (unbuilt, cheap) until capacity frees up.
    Park,
    /// Shed: reject with [`crate::serve::ServeError::Overloaded`].
    Overloaded,
    /// Reject the offer itself, independent of load.
    Refuse { reason: String },
}

/// Maps offers to decisions under load. `Send + Sync` because the
/// executor consults it from the serving loop while workers run.
pub trait Admission: Send + Sync {
    fn name(&self) -> &'static str;
    fn admit(&self, offer: &SessionOffer, load: &LoadSnapshot) -> AdmitDecision;
}

/// The old fixed-roster discipline as one policy behind the trait:
/// every well-formed offer is admitted regardless of load (the roster
/// was assembled up-front, so "arrival" pressure did not exist).
#[derive(Debug, Clone, Copy, Default)]
pub struct FixedRoster;

impl Admission for FixedRoster {
    fn name(&self) -> &'static str {
        "fixed-roster"
    }

    fn admit(&self, offer: &SessionOffer, _load: &LoadSnapshot) -> AdmitDecision {
        if offer.budget_steps == 0 {
            return AdmitDecision::Refuse {
                reason: "zero-step budget: the session could never run".into(),
            };
        }
        AdmitDecision::Admit
    }
}

/// Budget-aware shedding: admit while `live < capacity`, park up to
/// `max_parked` arrivals beyond that, shed the rest. Parking keeps the
/// *spec* (no model allocated), so a parked session costs bytes, not
/// cores — the point is to shed before step latency collapses, not to
/// queue unboundedly and collapse anyway.
#[derive(Debug, Clone, Copy)]
pub struct BudgetAware {
    /// Parking-lot ceiling; 0 sheds immediately at capacity.
    pub max_parked: usize,
}

impl Default for BudgetAware {
    fn default() -> Self {
        Self { max_parked: 256 }
    }
}

impl Admission for BudgetAware {
    fn name(&self) -> &'static str {
        "budget-aware"
    }

    fn admit(&self, offer: &SessionOffer, load: &LoadSnapshot) -> AdmitDecision {
        if offer.budget_steps == 0 {
            return AdmitDecision::Refuse {
                reason: "zero-step budget: the session could never run".into(),
            };
        }
        if load.live < load.capacity {
            AdmitDecision::Admit
        } else if load.parked < self.max_parked {
            AdmitDecision::Park
        } else {
            AdmitDecision::Overloaded
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn offer(steps: usize) -> SessionOffer {
        SessionOffer { id: "t-0".into(), priority: 1, budget_steps: steps }
    }

    #[test]
    fn zero_step_budget_is_refused_by_every_policy() {
        let load = LoadSnapshot { live: 0, queued: 0, parked: 0, capacity: 8 };
        for policy in [&FixedRoster as &dyn Admission, &BudgetAware::default()] {
            match policy.admit(&offer(0), &load) {
                AdmitDecision::Refuse { reason } => {
                    assert!(reason.contains("zero-step"), "{}: {reason}", policy.name())
                }
                other => panic!("{}: expected Refuse, got {other:?}", policy.name()),
            }
        }
    }

    #[test]
    fn budget_aware_admits_parks_then_sheds() {
        let p = BudgetAware { max_parked: 2 };
        let admit = LoadSnapshot { live: 7, queued: 3, parked: 0, capacity: 8 };
        assert_eq!(p.admit(&offer(10), &admit), AdmitDecision::Admit);
        let park = LoadSnapshot { live: 8, queued: 4, parked: 1, capacity: 8 };
        assert_eq!(p.admit(&offer(10), &park), AdmitDecision::Park);
        let shed = LoadSnapshot { live: 8, queued: 4, parked: 2, capacity: 8 };
        assert_eq!(p.admit(&offer(10), &shed), AdmitDecision::Overloaded);
    }

    #[test]
    fn fixed_roster_ignores_load() {
        let full = LoadSnapshot { live: 1000, queued: 1000, parked: 1000, capacity: 1 };
        assert_eq!(FixedRoster.admit(&offer(1), &full), AdmitDecision::Admit);
    }
}
