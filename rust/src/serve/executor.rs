//! The work-stealing serving executor.
//!
//! Dep-less by construction: per-worker deques plus steal
//! ([`crate::util::par::WorkStealQueues`]), a priority injector for
//! fresh admissions, and plain `std::thread::scope` workers layered on
//! the [`crate::util::par`] determinism contract (each worker calls
//! [`crate::util::par::enter_worker`], so block-level parallelism
//! *inside* a session degrades to serial — no nested forks, same as
//! the fleet scheduler).
//!
//! Scheduling never touches math: a session is owned by exactly one
//! worker at a time (it moves between deques, it is never aliased), is
//! internally seeded, and shares nothing with its neighbours, so
//! stealing and eviction reorder only *when* quanta run. Every
//! admitted session's curve is therefore bitwise equal to a standalone
//! run of the same spec — the load generator asserts this per run.
//!
//! Lease eviction: with `lease_quanta > 0` and a store attached, a
//! session that exhausts its lease is checkpointed *into* the store
//! ([`crate::fleet::FleetSession::evict`]) and handed back to the
//! serving loop as a resumable spec; re-admission goes through the
//! same [`Admission`] policy as any arrival. Save→resume is bit-exact
//! by the store contract, so eviction also preserves curves.

#![forbid(unsafe_code)]

use crate::chaos::{ExecFault, FaultClass, FaultPlan};
use crate::fleet::scheduler::FleetSession;
use crate::fleet::spec::SessionSpec;
use crate::serve::admission::{AdmitDecision, Admission, LoadSnapshot, SessionOffer};
use crate::serve::{ServeError, MAX_PRIORITY};
use crate::store::CheckpointStore;
use crate::util::par;
use crate::util::par::WorkStealQueues;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Executor parameters. `Default` is sized for tests; the CLI and the
/// load generator override everything.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Worker threads; 0 = [`par::threads`] (the pool's own sizing).
    pub workers: usize,
    /// Steps per dispatch quantum.
    pub quantum: usize,
    /// Live-session ceiling the admission policy sees.
    pub capacity: usize,
    /// Quanta a session may hold a core before it is evicted through
    /// the store; 0 = never evict. Requires `store`.
    pub lease_quanta: usize,
    /// Checkpoint store for lease eviction / re-admission.
    pub store: Option<Arc<CheckpointStore>>,
    /// Deterministic fault plan (chaos runs only). An executor-class
    /// plan requires `store`: faulted sessions are checkpointed at
    /// admission and re-admitted from that checkpoint after the
    /// injected crash/panic. `None` adds zero work anywhere.
    pub chaos: Option<FaultPlan>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { workers: 0, quantum: 8, capacity: 64, lease_quanta: 0, store: None, chaos: None }
    }
}

/// One arriving session: the cheap admission summary plus the full
/// buildable spec. The spec is only built (model allocated, dataset
/// bound) *after* admission says `Admit` — parked and shed arrivals
/// never pay construction.
pub struct Arrival {
    pub offer: SessionOffer,
    pub spec: SessionSpec,
}

/// One poll of an arrival stream.
pub enum Pull {
    /// A session arrived.
    Session(Box<Arrival>),
    /// Nothing right now — poll again (the stream may be pacing
    /// itself against the load snapshot).
    Pending,
    /// The stream is closed; no further sessions will arrive.
    Closed,
}

/// An open stream of arriving sessions. `poll` sees the executor's
/// current load, so synthetic generators can model closed-loop clients
/// (back-pressure) as well as open-loop floods.
pub trait ArrivalStream {
    fn poll(&mut self, load: &LoadSnapshot) -> Pull;
}

/// Any iterator of arrivals is a (load-blind) stream that closes when
/// the iterator ends.
impl<I: Iterator<Item = Arrival>> ArrivalStream for I {
    fn poll(&mut self, _load: &LoadSnapshot) -> Pull {
        match self.next() {
            Some(a) => Pull::Session(Box::new(a)),
            None => Pull::Closed,
        }
    }
}

/// Recover a poisoned lock: serving state is a bag of counters and
/// queues, each consistent on its own, so a panicked worker must not
/// wedge the whole front-end (L4: no unwrap in lib code).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// A dispatched session plus its lease bookkeeping.
struct Slot {
    session: FleetSession,
    /// Quanta run since admission (or re-admission).
    quanta: usize,
}

/// Priority buckets for fresh admissions, with aging: every
/// `AGE_EVERY`-th dispatch scans lowest-priority-first, which bounds
/// starvation — a parked-at-the-bottom session waits at most
/// `AGE_EVERY - 1` dispatches per turn of the wheel.
struct Injector {
    buckets: Vec<VecDeque<Slot>>,
    dispatched: usize,
}

const AGE_EVERY: usize = 4;

impl Injector {
    fn new() -> Self {
        let buckets = (0..=MAX_PRIORITY).map(|_| VecDeque::new()).collect();
        Self { buckets, dispatched: 0 }
    }

    fn push(&mut self, slot: Slot) {
        let p = slot.session.priority.min(MAX_PRIORITY) as usize;
        self.buckets[p].push_back(slot);
    }

    fn pop(&mut self) -> Option<Slot> {
        let n = self.buckets.len();
        let aged = self.dispatched % AGE_EVERY == AGE_EVERY - 1;
        for k in 0..n {
            let p = if aged { k } else { n - 1 - k };
            if let Some(slot) = self.buckets[p].pop_front() {
                self.dispatched += 1;
                return Some(slot);
            }
        }
        None
    }
}

/// State shared between the serving loop and the workers.
struct Shared {
    injector: Mutex<Injector>,
    queues: WorkStealQueues<Slot>,
    /// Admitted, not yet completed/evicted/failed.
    live: AtomicUsize,
    /// Slots sitting in the injector or a worker deque.
    queued: AtomicUsize,
    /// Set by the serving loop once everything has drained.
    closed: AtomicBool,
    completed: Mutex<Vec<FleetSession>>,
    /// Lease-evicted sessions, as resumable specs, awaiting re-admission.
    evicted: Mutex<Vec<SessionSpec>>,
    /// Sessions a chaos fault destroyed, as specs resuming from their
    /// admission checkpoint, awaiting re-admission.
    recovered: Mutex<Vec<SessionSpec>>,
    /// Ids whose planned chaos fault already fired (once per session).
    chaos_hit: Mutex<std::collections::BTreeSet<String>>,
    /// Sessions lost to an eviction-save failure (still accounted).
    failed: Mutex<Vec<(String, ServeError)>>,
    steals: AtomicUsize,
    steps: AtomicUsize,
}

fn worker_loop(w: usize, shared: &Shared, cfg: &ServeConfig) -> Vec<f64> {
    // layered executors: in-session block parallelism degrades serial
    par::enter_worker();
    let mut samples = Vec::new();
    loop {
        let slot = match shared.queues.pop(w) {
            Some(s) => Some(s),
            None => match shared.queues.steal(w) {
                Some(s) => {
                    shared.steals.fetch_add(1, Ordering::Relaxed);
                    Some(s)
                }
                None => lock(&shared.injector).pop(),
            },
        };
        let Some(mut slot) = slot else {
            if shared.closed.load(Ordering::Acquire) && shared.live.load(Ordering::Acquire) == 0
            {
                break;
            }
            std::thread::yield_now();
            continue;
        };
        shared.queued.fetch_sub(1, Ordering::Relaxed);
        // chaos seam: a planned executor fault fires once per session
        // id, before its quantum (plan-gated — `chaos: None` skips all
        // of this, the zero-overhead contract `tests/chaos.rs` pins)
        if let (Some(plan), Some(store)) = (&cfg.chaos, &cfg.store) {
            if let Some(fault) = plan.executor_fault(&slot.session.id) {
                if lock(&shared.chaos_hit).insert(slot.session.id.clone()) {
                    if matches!(fault, ExecFault::SessionPanic) {
                        // injected and caught right here: the worker
                        // survives the unwind, the session does not
                        let id = slot.session.id.clone();
                        let caught = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| crate::chaos::inject_panic(&id)),
                        );
                        debug_assert!(caught.is_err());
                    }
                    // crash and panic cost the same: the in-memory
                    // session is gone; hand back a spec resuming from
                    // the admission checkpoint for re-admission
                    lock(&shared.recovered).push(slot.session.crash_respec(store));
                    shared.live.fetch_sub(1, Ordering::Release);
                    continue;
                }
            }
        }
        let t0 = Instant::now();
        let ran = slot.session.run_quantum(cfg.quantum);
        if ran > 0 {
            shared.steps.fetch_add(ran, Ordering::Relaxed);
            samples.push(t0.elapsed().as_secs_f64() * 1e3 / ran as f64);
        }
        slot.quanta += 1;
        if slot.session.done() {
            // completed (or parked-on-error — the session carries it):
            // publish before releasing the live count, so live == 0
            // implies every outcome is visible
            lock(&shared.completed).push(slot.session);
            shared.live.fetch_sub(1, Ordering::Release);
        } else if cfg.lease_quanta > 0 && slot.quanta >= cfg.lease_quanta {
            match &cfg.store {
                Some(store) => {
                    let id = slot.session.id.clone();
                    match slot.session.evict(store) {
                        Ok(spec) => lock(&shared.evicted).push(spec),
                        Err(e) => lock(&shared.failed)
                            .push((id.clone(), ServeError::Train { id, source: e })),
                    }
                    shared.live.fetch_sub(1, Ordering::Release);
                }
                // unreachable — serve() rejects lease-without-store —
                // but degrade to "keep running" rather than panic
                None => {
                    shared.queued.fetch_add(1, Ordering::Relaxed);
                    shared.queues.push(w, slot);
                }
            }
        } else {
            shared.queued.fetch_add(1, Ordering::Relaxed);
            shared.queues.push(w, slot);
        }
    }
    samples
}

/// Aggregate outcome counters of one serve run. The accounting
/// identity `offered + re_admitted == completed + shed + evicted +
/// recovered` (with `shed = shed_overloaded + refused + failed`; both
/// `evicted` and `recovered` feed `re_admitted`) is what the
/// zero-lost-sessions CI gate checks.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Sessions the stream offered.
    pub offered: usize,
    /// Offers admitted and built.
    pub admitted: usize,
    /// Sessions that ran to their budget (including parked-on-error).
    pub completed: usize,
    /// Offers shed with [`ServeError::Overloaded`].
    pub shed_overloaded: usize,
    /// Offers refused at admission ([`ServeError::BadOffer`]).
    pub refused: usize,
    /// Sessions lost to a build/evict failure ([`ServeError::Train`]).
    pub failed: usize,
    /// Lease evictions (each produces one re-admission attempt).
    pub evicted: usize,
    /// Sessions destroyed by an injected chaos fault and handed back
    /// for re-admission from their checkpoint (chaos runs only).
    pub recovered: usize,
    /// Evicted sessions admitted back in.
    pub re_admitted: usize,
    /// Most arrivals parked at once.
    pub parked_peak: usize,
    /// Completed sessions that ended parked on a mid-run error.
    pub parked_errors: usize,
    /// Training steps executed across all sessions.
    pub total_steps: usize,
    /// Successful steals between worker deques.
    pub steals: usize,
    /// Host wall-clock of the run [s].
    pub wall_s: f64,
    /// Median per-step latency across all quanta [ms].
    pub p50_step_ms: f64,
    /// 99th-percentile per-step latency [ms].
    pub p99_step_ms: f64,
    /// Per-quantum latency samples behind the percentiles.
    pub latency_samples: usize,
}

impl ServeStats {
    /// Effective throughput [training steps / host second].
    pub fn steps_per_sec(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.total_steps as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Everything a serve run produced: the finished sessions (for twin
/// checks and reports), every shed offer with its structured reason,
/// and the counters.
pub struct Served {
    pub completed: Vec<FleetSession>,
    pub shed: Vec<(String, ServeError)>,
    pub stats: ServeStats,
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted.get(idx).copied().unwrap_or(0.0)
}

/// Run an open stream of sessions to completion under an admission
/// policy. Returns once the stream has closed and every admitted
/// session has completed, failed, or been shed — nothing is lost: each
/// offer is accounted in exactly one of `completed` / `shed`.
pub fn serve<S: ArrivalStream>(
    mut stream: S,
    admission: &dyn Admission,
    cfg: &ServeConfig,
) -> Result<Served, ServeError> {
    if cfg.quantum == 0 {
        return Err(ServeError::Config { reason: "quantum must be >= 1".into() });
    }
    if cfg.capacity == 0 {
        return Err(ServeError::Config { reason: "capacity must be >= 1".into() });
    }
    if cfg.lease_quanta > 0 && cfg.store.is_none() {
        return Err(ServeError::Config {
            reason: "lease eviction (lease_quanta > 0) requires a checkpoint store".into(),
        });
    }
    if let Some(plan) = &cfg.chaos {
        if plan.covers(FaultClass::Executor) && cfg.store.is_none() {
            return Err(ServeError::Config {
                reason: "executor-class chaos requires a checkpoint store to recover from".into(),
            });
        }
    }
    let workers = if cfg.workers == 0 { par::threads() } else { cfg.workers };
    let shared = Shared {
        injector: Mutex::new(Injector::new()),
        queues: WorkStealQueues::new(workers),
        live: AtomicUsize::new(0),
        queued: AtomicUsize::new(0),
        closed: AtomicBool::new(false),
        completed: Mutex::new(Vec::new()),
        evicted: Mutex::new(Vec::new()),
        recovered: Mutex::new(Vec::new()),
        chaos_hit: Mutex::new(std::collections::BTreeSet::new()),
        failed: Mutex::new(Vec::new()),
        steals: AtomicUsize::new(0),
        steps: AtomicUsize::new(0),
    };
    let t0 = Instant::now();
    let mut stats = ServeStats::default();
    let mut shed: Vec<(String, ServeError)> = Vec::new();
    let mut parked: VecDeque<Arrival> = VecDeque::new();

    let samples = std::thread::scope(|scope| {
        let shared = &shared;
        let handles: Vec<_> =
            (0..workers).map(|w| scope.spawn(move || worker_loop(w, shared, cfg))).collect();

        let snapshot = |parked_now: usize| LoadSnapshot {
            live: shared.live.load(Ordering::Acquire),
            queued: shared.queued.load(Ordering::Relaxed),
            parked: parked_now,
            capacity: cfg.capacity,
        };
        // admit one arrival: build only on Admit, park/shed otherwise
        let admit_one = |arrival: Arrival,
                         re_admission: bool,
                         parked: &mut VecDeque<Arrival>,
                         shed: &mut Vec<(String, ServeError)>,
                         stats: &mut ServeStats| {
            let load = snapshot(parked.len());
            match admission.admit(&arrival.offer, &load) {
                AdmitDecision::Admit => match arrival.spec.build() {
                    Ok(session) => {
                        // chaos admission checkpoint: a session the
                        // plan will fault needs a recovery base in the
                        // store *before* its first quantum (`chaos:
                        // None` never reaches the save)
                        if !re_admission {
                            if let (Some(plan), Some(store)) = (&cfg.chaos, &cfg.store) {
                                if plan.executor_fault(&session.id).is_some() {
                                    let ck = session.session().save_checkpoint();
                                    if let Err(e) = store.save(&session.id, &ck) {
                                        stats.failed += 1;
                                        let id = session.id.clone();
                                        shed.push((
                                            id.clone(),
                                            ServeError::Train { id, source: e.into() },
                                        ));
                                        return;
                                    }
                                }
                            }
                        }
                        shared.live.fetch_add(1, Ordering::Release);
                        shared.queued.fetch_add(1, Ordering::Relaxed);
                        lock(&shared.injector).push(Slot { session, quanta: 0 });
                        if re_admission {
                            stats.re_admitted += 1;
                        } else {
                            stats.admitted += 1;
                        }
                    }
                    Err(e) => {
                        stats.failed += 1;
                        let id = arrival.offer.id;
                        shed.push((id.clone(), ServeError::Train { id, source: e }));
                    }
                },
                AdmitDecision::Park => {
                    parked.push_back(arrival);
                    stats.parked_peak = stats.parked_peak.max(parked.len());
                }
                AdmitDecision::Overloaded => {
                    stats.shed_overloaded += 1;
                    let id = arrival.offer.id;
                    shed.push((
                        id.clone(),
                        ServeError::Overloaded {
                            id,
                            live: load.live,
                            queued: load.queued,
                            parked: load.parked,
                            capacity: load.capacity,
                        },
                    ));
                }
                AdmitDecision::Refuse { reason } => {
                    stats.refused += 1;
                    let id = arrival.offer.id;
                    shed.push((id.clone(), ServeError::BadOffer { id, reason }));
                }
            }
        };

        let mut stream_open = true;
        loop {
            // 1. evicted sessions come back as resumable specs and
            //    re-enter through the same admission policy
            let evictees: Vec<SessionSpec> = std::mem::take(&mut *lock(&shared.evicted));
            for spec in evictees {
                stats.evicted += 1;
                let offer = SessionOffer {
                    id: spec.id.clone(),
                    priority: spec.priority,
                    budget_steps: spec.budget.max_steps,
                };
                admit_one(Arrival { offer, spec }, true, &mut parked, &mut shed, &mut stats);
            }
            // 1a. sessions an injected fault destroyed come back as
            //     specs resuming from their admission checkpoint
            let crashed: Vec<SessionSpec> = std::mem::take(&mut *lock(&shared.recovered));
            for spec in crashed {
                stats.recovered += 1;
                let offer = SessionOffer {
                    id: spec.id.clone(),
                    priority: spec.priority,
                    budget_steps: spec.budget.max_steps,
                };
                admit_one(Arrival { offer, spec }, true, &mut parked, &mut shed, &mut stats);
            }
            // 2. parked arrivals drain in FIFO order while capacity lasts
            while let Some(front) = parked.front() {
                let load = snapshot(parked.len().saturating_sub(1));
                if admission.admit(&front.offer, &load) != AdmitDecision::Admit {
                    break;
                }
                if let Some(arrival) = parked.pop_front() {
                    admit_one(arrival, false, &mut parked, &mut shed, &mut stats);
                }
            }
            // 3. pull from the open stream
            if stream_open {
                match stream.poll(&snapshot(parked.len())) {
                    Pull::Session(arrival) => {
                        stats.offered += 1;
                        admit_one(*arrival, false, &mut parked, &mut shed, &mut stats);
                        continue; // keep pumping while sessions arrive
                    }
                    Pull::Pending => std::thread::sleep(Duration::from_micros(50)),
                    Pull::Closed => stream_open = false,
                }
            }
            // 4. drained? (live read before evicted: live can only
            //    fall once the stream closes, and each worker publishes
            //    its outcome before releasing its live count)
            if !stream_open
                && parked.is_empty()
                && shared.live.load(Ordering::Acquire) == 0
                && lock(&shared.evicted).is_empty()
                && lock(&shared.recovered).is_empty()
            {
                break;
            }
            if !stream_open {
                std::thread::yield_now();
            }
        }
        shared.closed.store(true, Ordering::Release);
        let mut samples = Vec::new();
        for h in handles {
            if let Ok(s) = h.join() {
                samples.extend(s);
            }
        }
        samples
    });

    // evict-save failures were accounted by workers; merge them in
    for (id, e) in lock(&shared.failed).drain(..) {
        stats.failed += 1;
        shed.push((id, e));
    }
    let completed = std::mem::take(&mut *lock(&shared.completed));
    stats.completed = completed.len();
    stats.parked_errors = completed.iter().filter(|s| s.error().is_some()).count();
    stats.total_steps = shared.steps.load(Ordering::Relaxed);
    stats.steals = shared.steals.load(Ordering::Relaxed);
    stats.wall_s = t0.elapsed().as_secs_f64();
    let mut sorted = samples;
    sorted.sort_by(|a, b| a.total_cmp(b));
    stats.latency_samples = sorted.len();
    stats.p50_step_ms = percentile(&sorted, 0.50);
    stats.p99_step_ms = percentile(&sorted, 0.99);
    Ok(Served { completed, shed, stats })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slot(id: &str, priority: u8) -> Slot {
        use crate::fleet::spec::SessionSpec;
        use crate::trainer::session::TrainConfig;
        use crate::workloads::{by_name, Dataset};
        let env = by_name("cartpole").unwrap();
        let ds = Dataset::collect(env.as_ref(), 2, 20, 3);
        let config = TrainConfig {
            dims: Some(vec![32, 8, 32]),
            steps: 4,
            eval_every: usize::MAX,
            ..Default::default()
        };
        let session = SessionSpec::new(id, "cartpole", ds, config)
            .priority(priority)
            .build()
            .unwrap();
        Slot { session, quanta: 0 }
    }

    #[test]
    fn injector_dispatches_by_priority_with_aging() {
        let mut inj = Injector::new();
        inj.push(slot("low", 0));
        for i in 0..6 {
            inj.push(slot(&format!("hi-{i}"), MAX_PRIORITY));
        }
        let order: Vec<String> = std::iter::from_fn(|| inj.pop())
            .map(|s| s.session.id)
            .collect();
        assert_eq!(order.len(), 7);
        // high priority leads, but the aged 4th dispatch (index 3)
        // reaches down and rescues the low-priority session
        assert_eq!(order[0], "hi-0");
        assert_eq!(order[3], "low", "{order:?}");
    }

    #[test]
    fn injector_clamps_out_of_range_priorities() {
        let mut inj = Injector::new();
        inj.push(slot("wild", u8::MAX));
        inj.push(slot("top", MAX_PRIORITY));
        let first = inj.pop().map(|s| s.session.id);
        assert_eq!(first.as_deref(), Some("wild"), "clamped into the top bucket, FIFO");
    }

    #[test]
    fn serve_rejects_lease_without_store() {
        let cfg = ServeConfig { lease_quanta: 2, ..Default::default() };
        let empty: Vec<Arrival> = Vec::new();
        let r = serve(empty.into_iter(), &crate::serve::BudgetAware::default(), &cfg);
        assert!(matches!(r, Err(ServeError::Config { .. })));
    }
}
