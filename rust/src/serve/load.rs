//! Deterministic synthetic load generator for the serving front-end.
//!
//! Drives `sessions` short-lived training sessions (10k+ for the
//! headline run) through [`crate::serve::serve`] against the real
//! trainer/backends/store stack. Everything is derived from
//! [`LoadSpec::seed`]: workload, scheme, per-session RNG seed, and
//! priority are pure functions of the arrival index, so a run is
//! reproducible and — crucially — any completed session can be
//! **rebuilt and re-run standalone** ([`LoadOutcome::twin_mismatches`]
//! counts curve divergences, which must be zero: the bit-identity
//! contract extends from the fleet scheduler to the stolen/queued/
//! evicted execution order).
//!
//! Arrival pacing is closed-loop with bursts: the stream holds back
//! (`Pull::Pending`) while live sessions sit at capacity — modelling
//! clients that wait for a slot — except every `burst_every`-th
//! arrival, which pushes through unpaced so admission control sees
//! genuine overload pressure. Shedding behaviour itself is pinned by
//! deterministic unit tests; the load run's job is throughput and
//! accounting (`BENCH_serve.json`, gated in CI).

#![forbid(unsafe_code)]

use crate::backend::BackendKind;
use crate::fleet::report::StoreSpec;
use crate::fleet::spec::SessionSpec;
use crate::mx::element::ElementFormat;
use crate::serve::admission::{BudgetAware, SessionOffer};
use crate::serve::executor::{serve, Arrival, ArrivalStream, Pull, ServeConfig, ServeStats};
use crate::serve::{ServeError, MAX_PRIORITY};
use crate::store::CheckpointStore;
use crate::trainer::mlp::hidden_dims;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::TrainConfig;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::workloads::{by_name, Dataset, ALL_WORKLOADS};
use std::collections::HashSet;
use std::sync::Arc;

/// Parameters of one synthetic load run (CLI defaults in [`Default`]:
/// the 10k-session headline shape, small per-session work).
#[derive(Debug, Clone)]
pub struct LoadSpec {
    /// Sessions the stream offers.
    pub sessions: usize,
    /// Steps per session (short-lived tenants).
    pub steps: usize,
    /// Hidden width of each session's MLP.
    pub hidden: usize,
    /// Dataset size: rollout episodes × horizon (shared per workload).
    pub episodes: usize,
    pub horizon: usize,
    pub batch: usize,
    pub eval_every: usize,
    /// Executor dispatch quantum.
    pub quantum: usize,
    /// Worker threads (0 = pool sizing).
    pub workers: usize,
    /// Live-session ceiling.
    pub capacity: usize,
    /// Parking-lot ceiling ([`BudgetAware::max_parked`]).
    pub max_parked: usize,
    /// Lease quanta before eviction through the store (0 = never).
    pub lease_quanta: usize,
    /// Every n-th arrival ignores back-pressure (0 = fully paced).
    pub burst_every: usize,
    /// Session `i` trains scheme `(i / 4) % schemes.len()`.
    pub schemes: Vec<QuantScheme>,
    pub backend: BackendKind,
    /// Twin-check every n-th completed session (0 = skip the check).
    pub twin_every: usize,
    /// Checkpoint persistence for lease eviction.
    pub store: Option<StoreSpec>,
    /// Deterministic fault plan (`mxscale serve --chaos`). Executor
    /// faults require `store`; the twin check must still come back
    /// clean — recovery is bit-exact or it is a failure.
    pub chaos: Option<crate::chaos::FaultPlan>,
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        Self {
            sessions: 10_000,
            steps: 12,
            hidden: 12,
            episodes: 2,
            horizon: 24,
            batch: 8,
            eval_every: 6,
            quantum: 4,
            workers: 0,
            capacity: 64,
            max_parked: 256,
            lease_quanta: 0,
            burst_every: 7,
            schemes: vec![
                QuantScheme::MxSquare(ElementFormat::Int8),
                QuantScheme::MxSquare(ElementFormat::E4M3),
            ],
            backend: BackendKind::Fast,
            twin_every: 97,
            store: None,
            chaos: None,
            seed: 0x5EDF00D,
        }
    }
}

/// The spec for arrival `i` — one pure function shared by the stream
/// and the twin check, so a standalone rebuild is identical by
/// construction. `store` is attached only on the serving side (the
/// twin runs uninterrupted and never checkpoints).
fn arrival_spec(
    i: usize,
    spec: &LoadSpec,
    datasets: &[Dataset],
    store: Option<Arc<CheckpointStore>>,
) -> (SessionOffer, SessionSpec) {
    let w = i % ALL_WORKLOADS.len();
    let scheme = spec.schemes[(i / ALL_WORKLOADS.len()) % spec.schemes.len()];
    let id = format!("tenant-{i:05}");
    let config = TrainConfig {
        scheme,
        backend: spec.backend,
        dims: Some(hidden_dims(spec.hidden)),
        batch_size: spec.batch,
        steps: spec.steps,
        eval_every: spec.eval_every,
        seed: spec.seed ^ ((i as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
        ..Default::default()
    };
    let priority = Pcg64::new(spec.seed ^ (i as u64)).below(MAX_PRIORITY as u64 + 1) as u8;
    let offer = SessionOffer { id: id.clone(), priority, budget_steps: spec.steps };
    let mut session_spec = SessionSpec::new(id, ALL_WORKLOADS[w], datasets[w].clone(), config)
        .priority(priority);
    if let Some(store) = store {
        session_spec = session_spec.store(store);
    }
    (offer, session_spec)
}

/// The synthetic arrival stream: closed-loop (holds back at capacity)
/// with periodic unpaced bursts.
struct LoadStream<'a> {
    spec: &'a LoadSpec,
    datasets: &'a [Dataset],
    store: Option<Arc<CheckpointStore>>,
    next: usize,
}

impl ArrivalStream for LoadStream<'_> {
    fn poll(&mut self, load: &crate::serve::admission::LoadSnapshot) -> Pull {
        if self.next >= self.spec.sessions {
            return Pull::Closed;
        }
        let i = self.next;
        let burst = self.spec.burst_every > 0 && (i + 1) % self.spec.burst_every == 0;
        if !burst && load.live >= load.capacity {
            return Pull::Pending;
        }
        self.next += 1;
        let (offer, spec) = arrival_spec(i, self.spec, self.datasets, self.store.clone());
        Pull::Session(Box::new(Arrival { offer, spec }))
    }
}

/// What a load run produced, beyond the executor counters.
#[derive(Debug, Clone)]
pub struct LoadOutcome {
    pub stats: ServeStats,
    /// Offers unaccounted for (must be 0): offered − completed − shed.
    pub lost: usize,
    /// Session ids appearing more than once across outcomes (must be 0).
    pub duplicated: usize,
    /// Completed sessions re-run standalone for the bit-identity check.
    pub twins_checked: usize,
    /// Twins whose loss curve diverged (must be 0).
    pub twin_mismatches: usize,
    /// First few shed reasons, for human-readable summaries.
    pub shed_sample: Vec<String>,
}

fn curves_bitwise_equal(a: &[(usize, f64)], b: &[(usize, f64)]) -> bool {
    a.len() == b.len()
        && a.iter().zip(b).all(|(x, y)| x.0 == y.0 && x.1.to_bits() == y.1.to_bits())
}

fn tenant_index(id: &str) -> Option<usize> {
    id.strip_prefix("tenant-").and_then(|s| s.parse().ok())
}

/// Run the synthetic load through the serving front-end, then account
/// every offer and twin-check a deterministic sample of completions.
pub fn run_load(spec: &LoadSpec) -> Result<LoadOutcome, ServeError> {
    if spec.sessions == 0 || spec.schemes.is_empty() {
        return Err(ServeError::Config {
            reason: "load needs at least one session and one scheme".into(),
        });
    }
    let store = match &spec.store {
        Some(ss) => Some(Arc::new(
            CheckpointStore::open_dir(&ss.dir, ss.layout)
                .map_err(|e| ServeError::Config { reason: e.to_string() })?,
        )),
        None => None,
    };
    // one dataset per workload, shared by every tenant on it (sessions
    // clone it; collection cost stays O(workloads), not O(sessions))
    let mut datasets = Vec::with_capacity(ALL_WORKLOADS.len());
    for (k, name) in ALL_WORKLOADS.iter().enumerate() {
        let env = by_name(name).ok_or_else(|| ServeError::Config {
            reason: format!("unknown workload `{name}`"),
        })?;
        datasets.push(Dataset::collect(
            env.as_ref(),
            spec.episodes,
            spec.horizon,
            spec.seed ^ (k as u64 + 1),
        ));
    }
    let cfg = ServeConfig {
        workers: spec.workers,
        quantum: spec.quantum,
        capacity: spec.capacity,
        lease_quanta: spec.lease_quanta,
        store: store.clone(),
        chaos: spec.chaos.clone(),
    };
    let admission = BudgetAware { max_parked: spec.max_parked };
    let stream = LoadStream { spec, datasets: &datasets, store, next: 0 };
    let served = serve(stream, &admission, &cfg)?;

    // accounting: every offer ends in exactly one bucket
    let lost =
        served.stats.offered.saturating_sub(served.stats.completed + served.shed.len());
    let mut seen: HashSet<&str> = HashSet::new();
    let mut duplicated = 0;
    for id in served
        .completed
        .iter()
        .map(|s| s.id.as_str())
        .chain(served.shed.iter().map(|(id, _)| id.as_str()))
    {
        if !seen.insert(id) {
            duplicated += 1;
        }
    }

    // twin check: rebuild a deterministic sample of completed sessions
    // from the same pure spec and run them standalone — curves must be
    // bitwise equal despite stealing, parking, and eviction
    let mut twins_checked = 0;
    let mut twin_mismatches = 0;
    if spec.twin_every > 0 {
        for s in &served.completed {
            let Some(i) = tenant_index(&s.id) else { continue };
            if i % spec.twin_every != 0 {
                continue;
            }
            let (_, twin_spec) = arrival_spec(i, spec, &datasets, None);
            twins_checked += 1;
            let mut twin = match twin_spec.build() {
                Ok(t) => t,
                Err(_) => {
                    twin_mismatches += 1;
                    continue;
                }
            };
            while twin.run_quantum(spec.quantum) > 0 {}
            let same = curves_bitwise_equal(
                &twin.session().train_curve,
                &s.session().train_curve,
            ) && twin.session().val_loss().to_bits() == s.session().val_loss().to_bits();
            if !same {
                twin_mismatches += 1;
            }
        }
    }

    let shed_sample =
        served.shed.iter().take(5).map(|(_, e)| e.to_string()).collect();
    Ok(LoadOutcome {
        stats: served.stats,
        lost,
        duplicated,
        twins_checked,
        twin_mismatches,
        shed_sample,
    })
}

/// Assemble the schema-versioned `BENCH_serve.json` document
/// (stamped by [`crate::coordinator::report::bench_doc`]; the caller
/// saves it, and `ci/check_bench.py` gates it).
pub fn bench_json(spec: &LoadSpec, out: &LoadOutcome) -> Json {
    let workers =
        if spec.workers == 0 { crate::util::par::threads() } else { spec.workers };
    crate::coordinator::report::bench_doc("serve")
        .set("sessions_offered", out.stats.offered)
        .set("sessions_admitted", out.stats.admitted)
        .set("sessions_completed", out.stats.completed)
        .set("sessions_shed", out.stats.shed_overloaded)
        .set("sessions_refused", out.stats.refused)
        .set("sessions_failed", out.stats.failed)
        .set("sessions_lost", out.lost)
        .set("sessions_duplicated", out.duplicated)
        .set("sessions_evicted", out.stats.evicted)
        .set("sessions_recovered", out.stats.recovered)
        .set("sessions_re_admitted", out.stats.re_admitted)
        .set("parked_peak", out.stats.parked_peak)
        .set("parked_errors", out.stats.parked_errors)
        .set("twins_checked", out.twins_checked)
        .set("twin_mismatches", out.twin_mismatches)
        .set("p50_step_ms", out.stats.p50_step_ms)
        .set("p99_step_ms", out.stats.p99_step_ms)
        .set("latency_samples", out.stats.latency_samples)
        .set("steps_total", out.stats.total_steps)
        .set("steps_per_sec", out.stats.steps_per_sec())
        .set("steals", out.stats.steals)
        .set("workers", workers)
        .set("capacity", spec.capacity)
        .set("quantum", spec.quantum)
        .set("lease_quanta", spec.lease_quanta)
        .set("wall_s", out.stats.wall_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_load_accounts_every_session_and_twins_match() {
        let spec = LoadSpec {
            sessions: 40,
            steps: 6,
            capacity: 8,
            max_parked: 8,
            workers: 2,
            twin_every: 5,
            eval_every: 3,
            ..Default::default()
        };
        let out = run_load(&spec).unwrap();
        assert_eq!(out.stats.offered, 40);
        assert_eq!(out.lost, 0, "{:?}", out.stats);
        assert_eq!(out.duplicated, 0);
        assert!(out.twins_checked > 0, "the sample must hit some completions");
        assert_eq!(out.twin_mismatches, 0);
        assert_eq!(
            out.stats.completed + out.stats.shed_overloaded + out.stats.refused
                + out.stats.failed,
            40
        );
    }

    #[test]
    fn bench_json_carries_the_gated_keys() {
        let spec = LoadSpec {
            sessions: 12,
            steps: 4,
            capacity: 4,
            workers: 1,
            twin_every: 6,
            eval_every: 2,
            ..Default::default()
        };
        let out = run_load(&spec).unwrap();
        let text = bench_json(&spec, &out).pretty();
        for key in [
            "\"bench\"",
            "\"schema_version\"",
            "\"sessions_offered\"",
            "\"sessions_lost\"",
            "\"sessions_duplicated\"",
            "\"twin_mismatches\"",
            "\"p50_step_ms\"",
            "\"p99_step_ms\"",
            "\"steps_per_sec\"",
        ] {
            assert!(text.contains(key), "missing {key}");
        }
    }

    #[test]
    fn run_load_rejects_empty_spec() {
        let spec = LoadSpec { sessions: 0, ..Default::default() };
        assert!(matches!(run_load(&spec), Err(ServeError::Config { .. })));
    }
}
