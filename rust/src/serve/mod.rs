//! Async multi-tenant serving front-end over the fleet.
//!
//! The fleet layer ([`crate::fleet`]) multiplexes a *fixed roster* of
//! sessions it was handed up-front. This module is the open-stream
//! counterpart the paper's edge-fleet premise actually needs: sessions
//! **arrive continuously** (tenants connecting, robots phoning home),
//! each carrying a priority and a step/energy budget, and the serving
//! layer decides per arrival whether to admit, park, or shed it —
//! *before* step latency collapses, not after.
//!
//! Three pieces:
//!
//! - **Admission** ([`admission`]): the [`Admission`] trait maps one
//!   [`SessionOffer`] plus the current [`LoadSnapshot`] to an
//!   [`AdmitDecision`]. The old fixed-roster discipline is one policy
//!   behind the trait ([`FixedRoster`]); [`BudgetAware`] is the serving
//!   default — refuse nonsense offers, admit while capacity lasts, park
//!   a bounded overflow, shed the rest with a structured
//!   [`ServeError::Overloaded`].
//! - **Executor** ([`executor`]): a dep-less work-stealing executor —
//!   per-worker deques plus steal, built on
//!   [`crate::util::par::WorkStealQueues`] and plain scoped threads —
//!   runs admitted sessions in quanta and keeps every core saturated
//!   under churn. Lease expiry evicts a session *through* the
//!   checkpoint store ([`crate::fleet::FleetSession::evict`]) and
//!   re-admits it later, bit-identical by the store contract.
//! - **Load generator** ([`load`]): a deterministic synthetic arrival
//!   stream (`mxscale serve --load`, `examples/serve_load.rs`) that
//!   drives 10k+ short-lived sessions against the real
//!   trainer/backends/store stack and emits the schema-versioned
//!   `BENCH_serve.json` gated by `ci/check_bench.py`.
//!
//! Determinism: admission order, parking, stealing, and eviction decide
//! only *when* a session runs, never *what* it computes — sessions
//! share nothing, are internally seeded, and are owned by exactly one
//! worker at a time, so every admitted session's loss curve is bitwise
//! equal to a standalone run of the same spec (asserted per run by the
//! load generator's twin check).

pub mod admission;
pub mod executor;
pub mod load;

pub use admission::{AdmitDecision, Admission, BudgetAware, FixedRoster, LoadSnapshot, SessionOffer};
pub use executor::{serve, Arrival, ArrivalStream, Pull, ServeConfig, ServeStats, Served};
pub use load::{run_load, LoadOutcome, LoadSpec};

use crate::trainer::session::TrainError;

/// Highest meaningful serving priority; [`SessionOffer::priority`]
/// values above it are clamped by the executor's dispatch queues.
pub const MAX_PRIORITY: u8 = 3;

/// Structured serving-layer errors. `Overloaded` is the load-shedding
/// signal — it carries the load snapshot that justified the shed, so
/// callers (and the bench report) can tell "capacity was genuinely
/// full" from a misconfigured ceiling.
#[derive(Debug, Clone, PartialEq)]
pub enum ServeError {
    /// Shed: admitting this session would exceed capacity and the
    /// parking lot is full.
    Overloaded { id: String, live: usize, queued: usize, parked: usize, capacity: usize },
    /// Refused at admission: the offer itself is invalid (e.g. a
    /// zero-step budget), independent of load.
    BadOffer { id: String, reason: String },
    /// The session failed to build, evict, or resume.
    Train { id: String, source: TrainError },
    /// The serving configuration itself is invalid.
    Config { reason: String },
}

impl std::fmt::Display for ServeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServeError::Overloaded { id, live, queued, parked, capacity } => write!(
                f,
                "session `{id}` shed: overloaded ({live} live + {queued} queued, \
                 {parked} parked, capacity {capacity})"
            ),
            ServeError::BadOffer { id, reason } => {
                write!(f, "session `{id}` refused at admission: {reason}")
            }
            ServeError::Train { id, source } => {
                write!(f, "session `{id}` failed: {source}")
            }
            ServeError::Config { reason } => write!(f, "bad serve configuration: {reason}"),
        }
    }
}

impl std::error::Error for ServeError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ServeError::Train { source, .. } => Some(source),
            _ => None,
        }
    }
}
