//! mxscale CLI entrypoint (L3 leader).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(mxscale::coordinator::run_cli(&argv));
}
