//! Per-session hardware cost ledger of the [`super::HardwareBackend`].

#![forbid(unsafe_code)]

use crate::arith::Events;
use crate::gemmcore::quantizer::QuantEvents;
use crate::gemmcore::schedule::CycleCost;
use crate::mx::element::ElementFormat;
use crate::util::json::Json;

/// Cost ledger of one *format segment* of a hardware session: the steps
/// executed between two precision transitions (or session edges) under
/// a single element format. A static session has exactly one segment;
/// a precision-scheduled session closes a segment at every
/// [`crate::backend::ExecBackend::transition`] so cycles, events,
/// energy, and traffic stay attributed to the format that incurred them
/// (the per-format accounting the scheduling subsystem reports on).
#[derive(Debug, Clone)]
pub struct HwSegmentCost {
    /// Scheme name active during this segment (e.g. "mx-e4m3").
    pub scheme: String,
    /// Element format of the segment's datapath mode.
    pub element: ElementFormat,
    /// Training steps executed in this segment.
    pub steps: u64,
    /// GeMMs executed in this segment.
    pub gemms: u64,
    /// Grid-pass schedule cost of this segment.
    pub cost: CycleCost,
    /// PE-array datapath events of this segment.
    pub events: Events,
    /// Output-quantizer events of this segment.
    pub quant: QuantEvents,
    /// Segment MAC energy, events priced at this segment's format [pJ].
    pub mac_energy_pj: f64,
    /// Interface bits moved during this segment.
    pub traffic_bits: u64,
    /// Worst datapath deviation observed in this segment.
    pub max_rel_err: f64,
}

impl HwSegmentCost {
    /// SRAM access energy over this segment's executed OPs [pJ].
    pub fn sram_energy_pj(&self) -> f64 {
        crate::energy::calib::SRAM_PJ_PER_OP * self.events.mul_ops as f64
    }

    /// Total segment energy [pJ].
    pub fn energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.sram_energy_pj()
    }

    /// Segment accelerator wall-clock at `freq_mhz` [us].
    pub fn micros(&self, freq_mhz: f64) -> f64 {
        self.cost.micros(freq_mhz)
    }

    fn to_json(&self, freq_mhz: f64) -> Json {
        Json::obj()
            .set("scheme", self.scheme.clone())
            .set("element", self.element.name())
            .set("steps", self.steps)
            .set("gemms", self.gemms)
            .set("cycles", self.cost.total())
            .set("us", self.micros(freq_mhz))
            .set("mac_pj", self.mac_energy_pj)
            .set("sram_pj", self.sram_energy_pj())
            .set("uj", self.energy_pj() * 1e-6)
            .set("traffic_bits", self.traffic_bits)
            .set("datapath_max_rel_err", self.max_rel_err)
    }
}

/// What one training session cost on the simulated accelerator.
///
/// Cycles come from the grid-pass schedule (per-stage, so weight-
/// gradient FP32 writeback stalls are charged), events from the bit-
/// exact MAC/quantizer walk, energy from pricing those events with the
/// calibrated model (data-dependent register switching included), and
/// memory traffic from the interface model in `gemmcore::memory`. The
/// resident footprint is filled in by the session, which knows the MLP
/// shape and batch size.
#[derive(Debug, Clone)]
pub struct HwCostReport {
    /// Backend identifier ("hw").
    pub backend: &'static str,
    /// Scheme name (e.g. "mx-int8").
    pub scheme: String,
    /// Element format of the datapath mode.
    pub element: ElementFormat,
    /// Core clock in MHz (wall-clock conversions).
    pub freq_mhz: f64,
    /// Training steps accounted.
    pub steps: u64,
    /// GeMMs executed across those steps.
    pub gemms: u64,
    /// Aggregated grid-pass schedule cost.
    pub cost: CycleCost,
    /// Aggregated PE-array datapath events.
    pub events: Events,
    /// Aggregated output-quantizer events.
    pub quant: QuantEvents,
    /// MAC-array energy: events priced by the calibrated model [pJ].
    pub mac_energy_pj: f64,
    /// SRAM access energy over executed OPs [pJ].
    pub sram_energy_pj: f64,
    /// Bits moved through the memory interface (operands + writebacks).
    pub mem_traffic_bits: u64,
    /// Resident on-chip footprint for this MLP shape + batch [KB].
    pub resident_kb: f64,
    /// Max per-GeMM deviation of the PE datapath output from the shared
    /// functional kernel, relative to the output's max magnitude.
    pub datapath_max_rel_err: f64,
    /// Per-format segments (the open segment included last); every
    /// aggregate above is the sum (or max, for the deviation) over
    /// these. One entry for a session that never transitioned.
    pub segments: Vec<HwSegmentCost>,
}

impl HwCostReport {
    /// Total core energy [pJ].
    pub fn energy_pj(&self) -> f64 {
        self.mac_energy_pj + self.sram_energy_pj
    }

    /// Total core energy [uJ] — the unit the fleet scheduler budgets in.
    pub fn uj_total(&self) -> f64 {
        self.energy_pj() * 1e-6
    }

    /// Accumulated accelerator wall-clock [us].
    pub fn micros(&self) -> f64 {
        self.cost.micros(self.freq_mhz)
    }

    /// Mean per-step latency [us] (0 before any step completes).
    pub fn us_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.micros() / self.steps as f64
        }
    }

    /// Mean per-step energy [uJ].
    pub fn uj_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.energy_pj() * 1e-6 / self.steps as f64
        }
    }

    /// Measured-on-model training throughput [steps/s].
    pub fn steps_per_sec(&self) -> f64 {
        let us = self.us_per_step();
        if us > 0.0 {
            1e6 / us
        } else {
            0.0
        }
    }

    /// Mean per-step interface traffic [KiB].
    pub fn traffic_kib_per_step(&self) -> f64 {
        if self.steps == 0 {
            0.0
        } else {
            self.mem_traffic_bits as f64 / 8.0 / 1024.0 / self.steps as f64
        }
    }

    /// JSON rendering for `results/` reports.
    pub fn to_json(&self) -> Json {
        let cycles = Json::obj()
            .set("compute", self.cost.compute)
            .set("input_stall", self.cost.input_stall)
            .set("writeback_stall", self.cost.writeback_stall)
            .set("overhead", self.cost.overhead)
            .set("total", self.cost.total())
            .set("mul_ops", self.cost.mul_ops);
        let energy = Json::obj()
            .set("mac_pj", self.mac_energy_pj)
            .set("sram_pj", self.sram_energy_pj)
            .set("total_uj", self.energy_pj() * 1e-6)
            .set("uj_per_step", self.uj_per_step());
        let mem = Json::obj()
            .set("traffic_bits", self.mem_traffic_bits)
            .set("traffic_kib_per_step", self.traffic_kib_per_step())
            .set("resident_kb", self.resident_kb);
        let events = Json::obj()
            .set("mul_ops", self.events.mul_ops)
            .set("mac_cycles", self.events.cycles)
            .set("mult2", self.events.mult2)
            .set("acc_add", self.events.acc_add)
            .set("acc_reg_toggles", self.events.acc_reg_toggles)
            .set("input_toggles", self.events.input_toggles);
        let quant = Json::obj()
            .set("blocks", self.quant.blocks)
            .set("encodes", self.quant.encodes)
            .set("max_scans", self.quant.max_scans);
        let mut segments = Json::arr();
        for s in &self.segments {
            segments = segments.push(s.to_json(self.freq_mhz));
        }
        Json::obj()
            .set("backend", self.backend)
            .set("scheme", self.scheme.clone())
            .set("element", self.element.name())
            .set("freq_mhz", self.freq_mhz)
            .set("steps", self.steps)
            .set("gemms", self.gemms)
            .set("cycles", cycles)
            .set("utilization", self.cost.utilization(self.element.mac_mode()))
            .set("us_total", self.micros())
            .set("us_per_step", self.us_per_step())
            .set("steps_per_sec", self.steps_per_sec())
            .set("energy", energy)
            .set("mem", mem)
            .set("events", events)
            .set("quantizer", quant)
            .set("segments", segments)
            .set("datapath_max_rel_err", self.datapath_max_rel_err)
    }
}
