//! The hardware backend: bit-exact GemmCore execution + cost ledger.

#![forbid(unsafe_code)]

use crate::backend::cost::{HwCostReport, HwSegmentCost};
use crate::backend::{backward_from_quant, gemm_fwd, ExecBackend, KernelRegistry, LayerGrads};
use crate::energy::EnergyModel;
use crate::gemmcore::quantizer::QuantEvents;
use crate::gemmcore::schedule::CycleCost;
use crate::gemmcore::memory::gemm_traffic_bits;
use crate::gemmcore::schedule::Stage;
use crate::gemmcore::GemmCore;
use crate::mx::element::ElementFormat;
use crate::mx::tensor::MxTensor;
use crate::trainer::qat::QuantScheme;
use crate::util::mat::Mat;

/// Epoch tag for "not quantized yet".
const NEVER: u64 = u64::MAX;

/// Executes every training-graph GeMM on the simulated GeMM core.
///
/// Operands enter through the output-quantizer unit (event-counted),
/// weights and activations are stored as square MX tensors — one copy
/// each, with the backward passes consuming free block-permutation
/// transposes exactly as the paper's architecture does — and every GeMM
/// walks the bit-exact PE arrays under the stage-specific grid schedule
/// (so weight-gradient FP32 writeback stalls are charged). The
/// training-graph *values* come from the shared backend kernels over the
/// same quantized codes, keeping this backend bit-identical to
/// [`super::FakeQuantBackend`]; the PE datapath output is compared
/// against that value per GeMM and the worst relative deviation lands in
/// the [`HwCostReport`].
pub struct HardwareBackend {
    scheme: QuantScheme,
    fmt: ElementFormat,
    core: GemmCore,
    /// Stored quantized weights (tensor + dequantized form, shared by
    /// both passes of a step), one per layer, refreshed per step.
    qw: Vec<Option<(MxTensor, Mat)>>,
    /// Step at which `qw[i]` was refreshed (NEVER = stale).
    qw_step: Vec<u64>,
    /// Stored quantized activations from this step's forward pass.
    qa: Vec<Option<MxTensor>>,
    step: u64,
    /// Steps / GeMMs / traffic / deviation of the **current format
    /// segment** — the core's own cost/event counters are segment-local
    /// too (the core is rebuilt on every transition). Closed segments
    /// live in `closed`; `cost_report` sums closed + current.
    steps: u64,
    gemms: u64,
    traffic_bits: u64,
    max_rel_err: f64,
    /// Ledgers of formats this session already trained under and left.
    closed: Vec<HwSegmentCost>,
}

impl HardwareBackend {
    /// The hardware executes square-block MX schemes only — FP32 and the
    /// vector-grouped baselines have no datapath on this core.
    pub fn new(scheme: QuantScheme) -> Result<Self, String> {
        let QuantScheme::MxSquare(fmt) = scheme else {
            return Err(format!(
                "hardware backend executes square-block MX schemes only (mx-int8 ... mx-e2m1); got `{}`",
                scheme.name()
            ));
        };
        Ok(Self {
            scheme,
            fmt,
            core: GemmCore::new(fmt),
            qw: Vec::new(),
            qw_step: Vec::new(),
            qa: Vec::new(),
            step: 0,
            steps: 0,
            gemms: 0,
            traffic_bits: 0,
            max_rel_err: 0.0,
            closed: Vec::new(),
        })
    }

    pub fn scheme(&self) -> QuantScheme {
        self.scheme
    }

    /// Snapshot the current (open) format segment's ledger.
    fn current_segment(&self) -> HwSegmentCost {
        let events = self.core.events();
        let model = EnergyModel::new(self.core.variant);
        HwSegmentCost {
            scheme: self.scheme.name(),
            element: self.fmt,
            steps: self.steps,
            gemms: self.gemms,
            cost: self.core.cost,
            events,
            quant: self.core.quantizer.events,
            mac_energy_pj: model.run_pj(self.fmt, &events),
            traffic_bits: self.traffic_bits,
            max_rel_err: self.max_rel_err,
        }
    }

    fn ensure(&mut self, layer: usize) {
        while self.qw.len() <= layer {
            self.qw.push(None);
            self.qw_step.push(NEVER);
            self.qa.push(None);
        }
    }

    /// Refresh the stored quantized weight for this step if stale.
    /// Quantization events are counted (and the dequantized form
    /// materialized) once per step per layer — the single-copy storage
    /// the square layout buys serves forward and backward alike.
    fn ensure_qw(&mut self, layer: usize, w: &Mat) {
        if self.qw_step[layer] != self.step {
            let q = self.core.quantizer.quantize(w, self.fmt);
            let d = q.dequantize();
            self.qw[layer] = Some((q, d));
            self.qw_step[layer] = self.step;
        }
    }

    /// Record one executed GeMM: interface traffic and the deviation of
    /// the datapath output from the functional value.
    fn observe(&mut self, func: &Mat, hw: &Mat, m: usize, k: usize, n: usize, stage: Stage) {
        self.gemms += 1;
        self.traffic_bits += gemm_traffic_bits(m, k, n, self.fmt, stage);
        let scale = (func.max_abs() as f64).max(1e-30);
        let mut dev = 0.0f64;
        for (a, b) in func.data.iter().zip(&hw.data) {
            dev = dev.max(((a - b) as f64).abs());
        }
        self.max_rel_err = self.max_rel_err.max(dev / scale);
    }
}

impl ExecBackend for HardwareBackend {
    fn name(&self) -> &'static str {
        "hw"
    }

    fn begin_step(&mut self) {
        self.step += 1;
        self.steps += 1;
    }

    fn forward_layer(&mut self, layer: usize, a: &Mat, w: &Mat) -> (Mat, Mat) {
        self.ensure(layer);
        let qa = self.core.quantizer.quantize(a, self.fmt);
        self.ensure_qw(layer, w);
        let aq = qa.dequantize();
        let (z, z_hw) = {
            let (qw, wq_mat) = self.qw[layer].as_ref().expect("just ensured");
            let z = gemm_fwd(KernelRegistry::dense_kernel(self.scheme), &aq, wq_mat);
            let z_hw = self.core.gemm_staged(&qa, qw, Stage::Forward);
            (z, z_hw)
        };
        self.observe(&z, &z_hw, a.rows, a.cols, w.cols, Stage::Forward);
        self.qa[layer] = Some(qa);
        (aq, z)
    }

    fn backward_layer(&mut self, layer: usize, e: &Mat, aq: &Mat, w: Option<&Mat>) -> LayerGrads {
        self.ensure(layer);
        let qe = self.core.quantizer.quantize(e, self.fmt);
        let eq = qe.dequantize();
        // weight-gradient GeMM: the stored quantized activation tensor,
        // transposed for free (block permutation), against Q(E)
        let qa = self.qa[layer].take().expect("forward_layer must precede backward_layer");
        let qat = qa.transpose().expect("square layout");
        let dw_hw = self.core.gemm_staged(&qat, &qe, Stage::WeightGrad);
        // error-backprop GeMM: the same stored weight, transposed free
        let mut back_hw_opt: Option<Mat> = None;
        if let Some(w) = w {
            self.ensure_qw(layer, w);
            let qwt =
                self.qw[layer].as_ref().expect("just ensured").0.transpose().expect("square");
            back_hw_opt = Some(self.core.gemm_staged(&qe, &qwt, Stage::Backward));
        }
        let wq_ref = match &back_hw_opt {
            Some(_) => self.qw[layer].as_ref().map(|(_, d)| d),
            None => None,
        };
        let grads = backward_from_quant(KernelRegistry::dense_kernel(self.scheme), &eq, aq, wq_ref);
        self.observe(&grads.d_w, &dw_hw, aq.cols, aq.rows, eq.cols, Stage::WeightGrad);
        if let (Some(back), Some(back_hw)) = (grads.back.as_ref(), back_hw_opt.as_ref()) {
            // back = Q(E)[batch, dout] @ Wᵀ[dout, din]
            self.observe(back, back_hw, eq.rows, eq.cols, aq.cols, Stage::Backward);
        }
        grads
    }

    /// Mid-session scheme switch: the open segment's ledger is closed
    /// (cycles/events/energy/traffic stay attributed to the format that
    /// incurred them) and the core is rebuilt for the new format — a
    /// fresh datapath mode, exactly as the precision-scalable hardware
    /// would reconfigure. Stored quantized tensors are dropped; the
    /// next step requantizes from the FP32 masters.
    fn transition(&mut self, scheme: QuantScheme) -> Result<(), String> {
        let QuantScheme::MxSquare(fmt) = scheme else {
            return Err(format!(
                "hardware backend executes square-block MX schemes only (mx-int8 ... mx-e2m1); got `{}`",
                scheme.name()
            ));
        };
        if self.qa.iter().any(|q| q.is_some()) {
            return Err("cannot transition mid-step: a forward tape is pending backward".into());
        }
        if self.steps > 0 || self.gemms > 0 {
            self.closed.push(self.current_segment());
        }
        self.scheme = scheme;
        self.fmt = fmt;
        self.core = GemmCore::new(fmt);
        for qw in &mut self.qw {
            *qw = None;
        }
        for step in &mut self.qw_step {
            *step = NEVER;
        }
        self.steps = 0;
        self.gemms = 0;
        self.traffic_bits = 0;
        self.max_rel_err = 0.0;
        Ok(())
    }

    fn cost_report(&self) -> Option<HwCostReport> {
        let mut segments = self.closed.clone();
        segments.push(self.current_segment());
        let mut cost = CycleCost::default();
        let mut events = crate::arith::Events::default();
        let mut quant = QuantEvents::default();
        let (mut steps, mut gemms, mut traffic_bits) = (0u64, 0u64, 0u64);
        let (mut mac_energy_pj, mut sram_energy_pj, mut max_rel_err) = (0.0f64, 0.0f64, 0.0f64);
        for s in &segments {
            cost.add(&s.cost);
            events.add(&s.events);
            quant.add(&s.quant);
            steps += s.steps;
            gemms += s.gemms;
            traffic_bits += s.traffic_bits;
            mac_energy_pj += s.mac_energy_pj;
            sram_energy_pj += s.sram_energy_pj();
            max_rel_err = max_rel_err.max(s.max_rel_err);
        }
        Some(HwCostReport {
            backend: self.name(),
            scheme: self.scheme.name(),
            element: self.fmt,
            freq_mhz: self.core.variant.freq_mhz(),
            steps,
            gemms,
            cost,
            events,
            quant,
            mac_energy_pj,
            sram_energy_pj,
            mem_traffic_bits: traffic_bits,
            resident_kb: 0.0, // filled by the session (knows shape/batch)
            datapath_max_rel_err: max_rel_err,
            segments,
        })
    }
}
