//! The kernel registry: (format × CPU features × shape) → kernel path.
//!
//! Replaces the ad-hoc per-scheme kernel match with an explicit
//! registration table. Resolution order (DESIGN.md §10):
//!
//! 1. **Forced path** (CLI `--kernel` > `MXSCALE_KERNEL` env var) —
//!    validated once at registry construction: forcing a path the CPU
//!    cannot run is a structured error, not a panic and not a silent
//!    fallback. A *forced* path skips the shape gate (you asked for
//!    it, you get it on every call) but still respects the per-format
//!    support table — formats without a SIMD leg run SWAR under any
//!    forcing, preserving bit-identity trivially.
//! 2. **Priority scan** of [`REGISTRATIONS`]: first entry whose path
//!    is available on the detected features, whose format table
//!    contains the operand format, and whose `min_macs` shape floor
//!    the call clears. Tiny GeMMs stay on SWAR — below a few thousand
//!    MACs the decode/dispatch overhead outweighs the vector win.
//! 3. **SWAR** — the terminal entry matches every format at any
//!    shape, so resolution always succeeds.
//!
//! Every path is bit-identical for every format (the `mx::simd`
//! contract), so resolution is a pure performance policy: it can
//! never change a training-graph value.

#![forbid(unsafe_code)]

use crate::mx::element::ElementFormat;
use crate::mx::packed::PackedTensor;
use crate::mx::simd::detect::{features, CpuFeatures};
use crate::mx::simd::{self, KernelPath, SIMD_FORMATS};
use crate::mx::ALL_ELEMENT_FORMATS;
use crate::trainer::qat::QuantScheme;
use crate::util::mat::Mat;
use std::sync::Mutex;

/// Environment variable forcing a kernel path (`swar|sse41|avx2|neon`).
pub const KERNEL_ENV: &str = "MXSCALE_KERNEL";

/// Process-wide CLI override (`mxscale train --kernel ...`). Takes
/// precedence over [`KERNEL_ENV`]; latest call wins.
static CLI_FORCE: Mutex<Option<KernelPath>> = Mutex::new(None);

/// Install (or clear, with `None`) the CLI kernel-path override.
pub fn force_kernel_path(path: Option<KernelPath>) {
    *CLI_FORCE.lock().unwrap_or_else(|e| e.into_inner()) = path;
}

fn cli_forced() -> Option<KernelPath> {
    *CLI_FORCE.lock().unwrap_or_else(|e| e.into_inner())
}

/// One registry entry: a path, the formats it has dedicated legs for,
/// and the MAC-count floor below which it declines in favor of SWAR.
struct Registration {
    path: KernelPath,
    formats: &'static [ElementFormat],
    min_macs: usize,
}

/// Shape floor for the vector paths: an 8×8×8 tile pair is 512 MACs;
/// below 4096 (one 16×16×16 problem) per-call overhead dominates.
const SIMD_MIN_MACS: usize = 4096;

/// Priority-ordered registrations — widest vectors first, SWAR last
/// (the always-matching terminal entry).
const REGISTRATIONS: [Registration; 4] = [
    Registration { path: KernelPath::Avx2, formats: &SIMD_FORMATS, min_macs: SIMD_MIN_MACS },
    Registration { path: KernelPath::Neon, formats: &SIMD_FORMATS, min_macs: SIMD_MIN_MACS },
    Registration { path: KernelPath::Sse41, formats: &SIMD_FORMATS, min_macs: SIMD_MIN_MACS },
    Registration { path: KernelPath::Swar, formats: &ALL_ELEMENT_FORMATS, min_macs: 0 },
];

/// Resolves (format, shape) → [`KernelPath`] against a CPU-feature
/// snapshot, and runs the packed kernels through the resolved path.
#[derive(Debug, Clone, Copy)]
pub struct KernelRegistry {
    features: CpuFeatures,
    forced: Option<KernelPath>,
}

impl KernelRegistry {
    /// Registry over an explicit feature snapshot and optional forced
    /// path. Errors (structured, no panic) when the forced path cannot
    /// run on the given features.
    pub fn with(
        features: CpuFeatures,
        forced: Option<KernelPath>,
    ) -> Result<KernelRegistry, String> {
        if let Some(p) = forced {
            if !p.available(features) {
                return Err(format!(
                    "kernel path `{}` was forced but is unavailable on this CPU \
                     (detected features: {}); use `swar` or drop the override",
                    p.name(),
                    features.describe()
                ));
            }
        }
        Ok(KernelRegistry { features, forced })
    }

    /// Registry for the running CPU, honoring the CLI override first
    /// and the [`KERNEL_ENV`] variable second. Unknown names and
    /// unavailable forced paths are structured errors.
    pub fn from_env() -> Result<KernelRegistry, String> {
        let forced = match cli_forced() {
            Some(p) => Some(p),
            None => match std::env::var(KERNEL_ENV) {
                Ok(s) if !s.trim().is_empty() => {
                    Some(KernelPath::parse(&s).map_err(|e| format!("{KERNEL_ENV}: {e}"))?)
                }
                _ => None,
            },
        };
        Self::with(features(), forced)
    }

    /// Registry for the running CPU with no forcing (bench provenance,
    /// fallback when overrides are absent).
    pub fn auto() -> KernelRegistry {
        KernelRegistry { features: features(), forced: None }
    }

    /// The forced path, if any.
    pub fn forced(&self) -> Option<KernelPath> {
        self.forced
    }

    /// Resolve the kernel path for one call: `format` is the operand
    /// element format, `macs` the problem size (M·K·N for a GeMM,
    /// element count for a quantize).
    pub fn resolve(&self, format: ElementFormat, macs: usize) -> KernelPath {
        if let Some(p) = self.forced {
            // forcing skips the shape gate, not the format table
            if p == KernelPath::Swar || SIMD_FORMATS.contains(&format) {
                return p;
            }
            return KernelPath::Swar;
        }
        for reg in &REGISTRATIONS {
            if reg.path.available(self.features)
                && reg.formats.contains(&format)
                && macs >= reg.min_macs
            {
                return reg.path;
            }
        }
        KernelPath::Swar
    }

    /// The path an unbounded INT8 GeMM resolves to — the headline
    /// answer to "which kernels is this process running", stamped into
    /// bench provenance.
    pub fn default_path(&self) -> KernelPath {
        self.resolve(ElementFormat::Int8, usize::MAX)
    }

    /// `a @ b` through the resolved path (bit-identical to
    /// [`crate::mx::packed::packed_gemm`] on every path).
    pub fn gemm(&self, a: &PackedTensor, b: &PackedTensor) -> Mat {
        let path = self.resolve(a.format, a.rows * a.cols * b.cols);
        simd::gemm(path, a, b)
    }

    /// `a @ bᵀ` through the resolved path (bit-identical to
    /// [`crate::mx::packed::packed_gemm_nt`] on every path).
    pub fn gemm_nt(&self, a: &PackedTensor, b: &PackedTensor) -> Mat {
        let path = self.resolve(a.format, a.rows * a.cols * b.rows);
        simd::gemm_nt(path, a, b)
    }

    /// Quantize-and-pack through the resolved path (bit-identical to
    /// [`PackedTensor::quantize_pack`] on every path).
    pub fn quantize_pack(&self, m: &Mat, format: ElementFormat) -> PackedTensor {
        let path = self.resolve(format, m.rows * m.cols);
        simd::quantize_pack(path, m, format)
    }

    /// Which dense GeMM kernel computes the training-graph *values*
    /// for a scheme (the value-semantics half the old
    /// `GemmKernel::for_scheme` match carried; lives here so every
    /// kernel-selection decision has one home).
    pub fn dense_kernel(scheme: QuantScheme) -> super::GemmKernel {
        match scheme {
            QuantScheme::MxSquare(_) => super::GemmKernel::MxBlock8,
            _ => super::GemmKernel::Plain,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::GemmKernel;

    const AVX2_CPU: CpuFeatures = CpuFeatures { sse41: true, avx2: true, neon: false };
    const SSE_CPU: CpuFeatures = CpuFeatures { sse41: true, avx2: false, neon: false };
    const NEON_CPU: CpuFeatures = CpuFeatures { sse41: false, avx2: false, neon: true };

    fn reg(f: CpuFeatures, forced: Option<KernelPath>) -> KernelRegistry {
        match KernelRegistry::with(f, forced) {
            Ok(r) => r,
            Err(e) => panic!("registry construction failed: {e}"),
        }
    }

    #[test]
    fn bare_cpu_resolves_swar_for_everything() {
        let r = reg(CpuFeatures::NONE, None);
        for fmt in ALL_ELEMENT_FORMATS {
            for macs in [0, SIMD_MIN_MACS, usize::MAX] {
                assert_eq!(r.resolve(fmt, macs), KernelPath::Swar, "{fmt:?} {macs}");
            }
        }
    }

    #[test]
    fn priority_prefers_widest_available_vectors() {
        let big = 1 << 24;
        assert_eq!(reg(AVX2_CPU, None).resolve(ElementFormat::Int8, big), KernelPath::Avx2);
        assert_eq!(reg(SSE_CPU, None).resolve(ElementFormat::Int8, big), KernelPath::Sse41);
        assert_eq!(reg(NEON_CPU, None).resolve(ElementFormat::E2M1, big), KernelPath::Neon);
    }

    #[test]
    fn shape_floor_keeps_small_problems_on_swar() {
        let r = reg(AVX2_CPU, None);
        assert_eq!(r.resolve(ElementFormat::Int8, SIMD_MIN_MACS - 1), KernelPath::Swar);
        assert_eq!(r.resolve(ElementFormat::Int8, SIMD_MIN_MACS), KernelPath::Avx2);
    }

    #[test]
    fn formats_without_simd_legs_resolve_swar() {
        let r = reg(AVX2_CPU, None);
        for fmt in [
            ElementFormat::E5M2,
            ElementFormat::E4M3,
            ElementFormat::E3M2,
            ElementFormat::E2M3,
        ] {
            assert_eq!(r.resolve(fmt, usize::MAX), KernelPath::Swar, "{fmt:?}");
        }
    }

    #[test]
    fn forcing_skips_the_shape_gate_but_not_the_format_table() {
        let r = reg(AVX2_CPU, Some(KernelPath::Avx2));
        // tiny problem: forced path still wins
        assert_eq!(r.resolve(ElementFormat::Int8, 1), KernelPath::Avx2);
        // format without a SIMD leg: SWAR regardless of forcing
        assert_eq!(r.resolve(ElementFormat::E4M3, usize::MAX), KernelPath::Swar);
    }

    #[test]
    fn forcing_an_unavailable_path_is_a_structured_error() {
        for p in [KernelPath::Sse41, KernelPath::Avx2, KernelPath::Neon] {
            let err = match KernelRegistry::with(CpuFeatures::NONE, Some(p)) {
                Err(e) => e,
                Ok(_) => panic!("{p:?} forced on a bare CPU must not construct"),
            };
            assert!(err.contains(p.name()), "error names the path: {err}");
            assert!(err.contains("swar"), "error suggests the fallback: {err}");
        }
        // swar itself is always forceable
        assert!(KernelRegistry::with(CpuFeatures::NONE, Some(KernelPath::Swar)).is_ok());
    }

    #[test]
    fn default_path_reports_the_unbounded_int8_resolution() {
        assert_eq!(reg(AVX2_CPU, None).default_path(), KernelPath::Avx2);
        assert_eq!(reg(CpuFeatures::NONE, None).default_path(), KernelPath::Swar);
        assert_eq!(reg(AVX2_CPU, Some(KernelPath::Swar)).default_path(), KernelPath::Swar);
    }

    #[test]
    fn dense_kernel_keeps_the_scheme_value_semantics() {
        assert_eq!(
            KernelRegistry::dense_kernel(QuantScheme::MxSquare(ElementFormat::Int8)),
            GemmKernel::MxBlock8
        );
        assert_eq!(KernelRegistry::dense_kernel(QuantScheme::Fp32), GemmKernel::Plain);
        assert_eq!(
            KernelRegistry::dense_kernel(QuantScheme::MxVector(ElementFormat::E4M3)),
            GemmKernel::Plain
        );
    }
}
