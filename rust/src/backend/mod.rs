//! Pluggable execution backends for the QAT training graph.
//!
//! The Fig. 5 training step is, per layer, three quantize→GeMM cut
//! points: `Z = Q(A) @ Q(W)` (forward), `E_prev = Q(E) @ Qt(W)ᵀ` (error
//! backprop), and `dW = Aqᵀ @ Q(E)` (weight gradient). [`ExecBackend`]
//! abstracts *who executes those cuts*:
//!
//! * [`FakeQuantBackend`] — the fast software path: in-place MX
//!   fake-quantization into per-layer scratch buffers and dense f32
//!   GeMMs. Handles every [`QuantScheme`]; for FP32 and square MX
//!   schemes the weight/error quant calls stop allocating after the
//!   first step (the vector and Dacapo baselines still allocate their
//!   transposed intermediates — part of the very cost the paper charges
//!   them).
//! * [`HardwareBackend`] — drives the bit-exact [`crate::gemmcore`]
//!   simulation: operands pass through the output-quantizer unit as
//!   square MX tensors, every GeMM walks the 64-MAC PE arrays, and the
//!   backend accumulates a per-session [`HwCostReport`] (schedule
//!   cycles, datapath events, event-priced energy, memory traffic) so
//!   training throughput is *measured on the model* rather than taken
//!   from the analytic schedule alone.
//! * [`PackedBackend`] — the sub-word-parallel fast path
//!   (`--backend packed`): element codes stay bit-packed in u64 lanes
//!   ([`crate::mx::packed`]), dot products run in integer SWAR
//!   arithmetic, the per-block scale applies once per 8×8 block, and
//!   one packed weight copy serves forward and both backward GeMMs via
//!   the free block-permutation transpose — the paper's throughput and
//!   storage story executed in software.
//!
//! **Equivalence contract** (asserted three-way by `tests/backend.rs`
//! for all six element formats): all backends produce bit-identical
//! training-graph values. They quantize through the same MX codecs
//! (`fake_quant_mat_*` is bit-identical to `quantize`→`dequantize`, the
//! square-block transpose is a pure permutation) and evaluate GeMMs
//! under one value semantics per scheme (see [`GemmKernel`]): for
//! square-block MX schemes that is the block-ordered accumulation of
//! [`Mat::matmul_blocked`], which the packed SWAR kernels reproduce
//! exactly because fake-quant values are integers times a per-block
//! power-of-two unit. Switching backend never changes a loss curve — it
//! only changes what is accounted. The PE datapath output (FP32
//! accumulated in hardware order, with the L2 alignment window) deviates
//! from the shared kernel by at most a few ULP per accumulation chain;
//! the hardware backend measures that deviation per GeMM and reports the
//! maximum, rather than silently substituting one rounding for the other
//! mid-training.

mod cost;
mod fake;
mod hw;
mod packed;
mod registry;

pub use cost::{HwCostReport, HwSegmentCost};
pub use fake::FakeQuantBackend;
pub use hw::HardwareBackend;
pub use packed::PackedBackend;
pub use registry::{force_kernel_path, KernelRegistry, KERNEL_ENV};

use crate::mx::tensor::SQ;
use crate::trainer::qat::QuantScheme;
use crate::util::mat::Mat;

/// Gradients of one layer produced by a backward cut.
pub struct LayerGrads {
    /// Weight gradient `Aqᵀ @ Q(E)`.
    pub d_w: Mat,
    /// Bias gradient: column sums of `Q(E)`.
    pub d_b: Vec<f32>,
    /// Un-masked backprop error `Q(E) @ Qt(W)ᵀ` (None for layer 0,
    /// which has nothing upstream).
    pub back: Option<Mat>,
}

/// Executes the training graph's quantize→GeMM cut points.
///
/// Object-safe so sessions can hold `Box<dyn ExecBackend + Send>`;
/// layer indices let implementations keep per-layer state (scratch
/// buffers, stored quantized tensors) across calls and steps.
pub trait ExecBackend {
    /// Short stable identifier ("fake-quant" / "hw") for reports.
    fn name(&self) -> &'static str;

    /// Mark a training-step boundary (cost ledgers, weight-cache epochs).
    fn begin_step(&mut self);

    /// Forward cut of `layer`: returns `(Q(A), Q(A) @ Q(W))`. The
    /// quantized activation is returned for the tape — backprop's
    /// weight-gradient GeMM consumes exactly this stored tensor.
    fn forward_layer(&mut self, layer: usize, a: &Mat, w: &Mat) -> (Mat, Mat);

    /// Backward cut of `layer`: quantizes the incoming error once and
    /// runs the weight-gradient GeMM against the stored quantized
    /// activation `aq`, plus (when `w` is given) the error-backprop GeMM
    /// against the transposed quantized weight.
    ///
    /// Contract: at most one backward cut per forward cut of the same
    /// layer — backends that store per-layer state in `forward_layer`
    /// (the hardware backend's quantized-activation tensors) consume it
    /// here and panic on a second backward over the same tape.
    fn backward_layer(&mut self, layer: usize, e: &Mat, aq: &Mat, w: Option<&Mat>) -> LayerGrads;

    /// Accumulated hardware cost, if this backend accounts one.
    fn cost_report(&self) -> Option<HwCostReport> {
        None
    }

    /// Switch the active [`QuantScheme`] at a **training-step boundary**
    /// (the runtime-precision-scheduling seam — DESIGN.md §8).
    ///
    /// Contract: implementations must validate *before* mutating (a
    /// rejected transition leaves the backend running the old scheme),
    /// must refuse a mid-step call (a pending forward tape would mix
    /// formats inside one backward pass), and must drop every per-layer
    /// cache derived from the old scheme — quantized/packed weight
    /// copies, scratch buffers, and the GeMM-kernel selection — so the
    /// next step quantizes fresh from the FP32 masters. Transitions
    /// never convert format-to-format: there is no persistent quantized
    /// state to convert, which is what makes a transition bit-identical
    /// to starting a new session at the new format with the same
    /// master/Adam state (`tests/backend.rs` asserts this).
    fn transition(&mut self, scheme: QuantScheme) -> Result<(), String> {
        let (name, scheme) = (self.name(), scheme.name());
        Err(format!("the `{name}` backend cannot switch schemes mid-session (to `{scheme}`)"))
    }
}

/// Which [`ExecBackend`] a session runs (CLI: `--backend fast|hw|packed`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendKind {
    /// Software fake-quantization (the default fast path).
    #[default]
    Fast,
    /// Bit-exact GemmCore simulation with cost accounting.
    Hardware,
    /// Sub-word-parallel packed SWAR kernels (`mx::packed`).
    Packed,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "fast" | "sw" | "fake" => Some(BackendKind::Fast),
            "hw" | "hardware" => Some(BackendKind::Hardware),
            "packed" | "swar" => Some(BackendKind::Packed),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Fast => "fast",
            BackendKind::Hardware => "hw",
            BackendKind::Packed => "packed",
        }
    }
}

/// Construct a backend for a scheme. The hardware and packed backends
/// only execute square-block MX schemes (the datapath the paper
/// builds); other schemes return an error naming the constraint.
pub fn make_backend(
    kind: BackendKind,
    scheme: QuantScheme,
) -> Result<Box<dyn ExecBackend + Send>, String> {
    match kind {
        BackendKind::Fast => Ok(Box::new(FakeQuantBackend::new(scheme))),
        BackendKind::Hardware => Ok(Box::new(HardwareBackend::new(scheme)?)),
        BackendKind::Packed => Ok(Box::new(PackedBackend::new(scheme)?)),
    }
}

/// Which dense GeMM kernel computes the training-graph *values* for a
/// scheme. Square-block MX schemes use the block-ordered accumulation
/// of [`Mat::matmul_blocked`] (chunk = the 8-wide block edge): within
/// one block pair the dot is exact, the per-block scale applies once,
/// and the f32 partials chain across blocks. That is the semantics the
/// sub-word packed kernels (`mx::packed`) compute natively, which is
/// what makes `fast`, `hw`, and `packed` bit-identical — a theorem over
/// exactly-representable fake-quant values, not a tolerance
/// (`tests/backend.rs` asserts it three-way). Every other scheme keeps
/// the plain element-ordered f32 kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum GemmKernel {
    /// Element-ordered f32 accumulation ([`Mat::matmul`] family).
    #[default]
    Plain,
    /// Per-8-block f64-exact partials, f32 chain across blocks.
    MxBlock8,
}

/// Shared forward GeMM kernel: every backend evaluates the training-
/// graph value with this exact call, which is what makes them
/// bit-identical.
pub(crate) fn gemm_fwd(kernel: GemmKernel, aq: &Mat, wq: &Mat) -> Mat {
    match kernel {
        GemmKernel::Plain => aq.matmul(wq),
        GemmKernel::MxBlock8 => aq.matmul_blocked(wq, SQ),
    }
}

/// Shared backward kernels over already-quantized operands: weight
/// gradient `aqᵀ @ eq`, bias gradient, and (optionally) the error
/// backprop `eq @ wqᵀ` — both transpose-free.
pub(crate) fn backward_from_quant(
    kernel: GemmKernel,
    eq: &Mat,
    aq: &Mat,
    wq: Option<&Mat>,
) -> LayerGrads {
    let d_w = match kernel {
        GemmKernel::Plain => aq.matmul_tn(eq),
        GemmKernel::MxBlock8 => aq.matmul_blocked_tn(eq, SQ),
    };
    let d_b = eq.col_sums();
    let back = wq.map(|w| match kernel {
        GemmKernel::Plain => eq.matmul_nt(w),
        GemmKernel::MxBlock8 => eq.matmul_blocked_nt(w, SQ),
    });
    LayerGrads { d_w, d_b, back }
}

/// Adapter backend over user hooks — keeps `Mlp::forward_with` /
/// `Mlp::backward_with` (and every test written against them) flowing
/// through the same trait and GeMM kernels as the real backends.
pub struct HookBackend<W, A, E>
where
    W: FnMut(usize, &Mat) -> Mat,
    A: FnMut(usize, &Mat) -> Mat,
    E: FnMut(usize, &Mat) -> Mat,
{
    w_hook: W,
    a_hook: A,
    e_hook: E,
    kernel: GemmKernel,
}

impl<W, A, E> HookBackend<W, A, E>
where
    W: FnMut(usize, &Mat) -> Mat,
    A: FnMut(usize, &Mat) -> Mat,
    E: FnMut(usize, &Mat) -> Mat,
{
    /// Hook backend over the plain element-ordered f32 kernels (the
    /// golden `forward_with`/`backward_with` and eval semantics).
    pub fn new(w_hook: W, a_hook: A, e_hook: E) -> Self {
        Self { w_hook, a_hook, e_hook, kernel: GemmKernel::Plain }
    }

    /// Hook backend evaluating GeMMs with the same kernel the real
    /// backends use for `scheme` — the configuration that is bitwise
    /// comparable against [`FakeQuantBackend`] et al. in tests.
    pub fn for_scheme(scheme: QuantScheme, w_hook: W, a_hook: A, e_hook: E) -> Self {
        Self { w_hook, a_hook, e_hook, kernel: KernelRegistry::dense_kernel(scheme) }
    }
}

impl<W, A, E> ExecBackend for HookBackend<W, A, E>
where
    W: FnMut(usize, &Mat) -> Mat,
    A: FnMut(usize, &Mat) -> Mat,
    E: FnMut(usize, &Mat) -> Mat,
{
    fn name(&self) -> &'static str {
        "hooks"
    }

    fn begin_step(&mut self) {}

    fn forward_layer(&mut self, layer: usize, a: &Mat, w: &Mat) -> (Mat, Mat) {
        let aq = (self.a_hook)(layer, a);
        let wq = (self.w_hook)(layer, w);
        let z = gemm_fwd(self.kernel, &aq, &wq);
        (aq, z)
    }

    fn backward_layer(&mut self, layer: usize, e: &Mat, aq: &Mat, w: Option<&Mat>) -> LayerGrads {
        let eq = (self.e_hook)(layer, e);
        let wq = w.map(|w| (self.w_hook)(layer, w));
        backward_from_quant(self.kernel, &eq, aq, wq.as_ref())
    }
}
