//! The packed backend: sub-word-parallel SWAR execution of the
//! training hot path (`--backend packed`).

#![forbid(unsafe_code)]

use crate::backend::{ExecBackend, KernelRegistry, LayerGrads};
use crate::mx::element::ElementFormat;
use crate::mx::packed::PackedTensor;
use crate::trainer::qat::QuantScheme;
use crate::util::mat::Mat;

/// Epoch tag for "not quantized yet".
const NEVER: u64 = u64::MAX;

/// Executes every training-graph GeMM on the bit-packed SWAR kernels of
/// [`crate::mx::packed`].
///
/// Weights are packed **once per step per layer** and that single
/// packed copy serves all three GeMM cut points: the forward GeMM reads
/// it directly, the error-backprop GeMM consumes it transposed at zero
/// cost ([`crate::mx::packed::packed_gemm_nt`] — the lanes already are
/// k-major), and the
/// weight-gradient GeMM consumes the stored packed *activation* through
/// the free block-permutation transpose. That is the paper's §IV
/// single-copy storage argument executed on the hot path rather than
/// merely checkpointed. Element codes never widen past their format
/// width until an f32 output is due, and per-block scales apply once
/// per 8×8 block pair instead of once per element.
///
/// Bit-identical to [`super::FakeQuantBackend`] and
/// [`super::HardwareBackend`] on all six square MX formats (the
/// three-way assertion in `tests/backend.rs`): all backends share the
/// block-ordered GeMM value semantics ([`super::GemmKernel::MxBlock8`]),
/// and over exactly-representable fake-quant values the packed integer
/// block dots equal the dense f64 block partials bit for bit.
pub struct PackedBackend {
    scheme: QuantScheme,
    fmt: ElementFormat,
    /// Kernel-path resolver (CPU features + any `--kernel` /
    /// `MXSCALE_KERNEL` forcing, validated at construction).
    registry: KernelRegistry,
    /// Packed weights, one per layer, refreshed once per step.
    pw: Vec<Option<PackedTensor>>,
    /// Step at which `pw[i]` was refreshed (NEVER = stale).
    pw_step: Vec<u64>,
    /// Packed activations stored by this step's forward pass.
    pa: Vec<Option<PackedTensor>>,
    step: u64,
}

impl PackedBackend {
    /// The packed kernels run square-block MX schemes only — FP32 and
    /// the vector-grouped baselines have no single packed copy to run
    /// on (their transposed grouping requantizes, which is the very
    /// cost this datapath removes).
    pub fn new(scheme: QuantScheme) -> Result<Self, String> {
        let QuantScheme::MxSquare(fmt) = scheme else {
            return Err(format!(
                "packed backend executes square-block MX schemes only (mx-int8 ... mx-e2m1); got `{}`",
                scheme.name()
            ));
        };
        let registry = KernelRegistry::from_env()?;
        Ok(Self {
            scheme,
            fmt,
            registry,
            pw: Vec::new(),
            pw_step: Vec::new(),
            pa: Vec::new(),
            step: 0,
        })
    }

    pub fn scheme(&self) -> QuantScheme {
        self.scheme
    }

    fn ensure(&mut self, layer: usize) {
        while self.pw.len() <= layer {
            self.pw.push(None);
            self.pw_step.push(NEVER);
            self.pa.push(None);
        }
    }

    /// Refresh the packed weight for this step if stale — quantized and
    /// packed once, consumed by forward and backward alike.
    fn ensure_pw(&mut self, layer: usize, w: &Mat) {
        if self.pw_step[layer] != self.step {
            self.pw[layer] = Some(self.registry.quantize_pack(w, self.fmt));
            self.pw_step[layer] = self.step;
        }
    }
}

impl ExecBackend for PackedBackend {
    fn name(&self) -> &'static str {
        "packed"
    }

    fn begin_step(&mut self) {
        self.step += 1;
    }

    fn forward_layer(&mut self, layer: usize, a: &Mat, w: &Mat) -> (Mat, Mat) {
        self.ensure(layer);
        let pa = self.registry.quantize_pack(a, self.fmt);
        self.ensure_pw(layer, w);
        // the tape owns a dense copy of Q(A) (the MLP hands it back to
        // backward_layer); GeMMs run on the packed codes
        let aq = pa.dequantize();
        let z = self.registry.gemm(&pa, self.pw[layer].as_ref().expect("just ensured"));
        self.pa[layer] = Some(pa);
        (aq, z)
    }

    /// Mid-session scheme switch: validates first (square MX only, and
    /// never between a forward and its backward), then drops every
    /// packed weight/activation so the next step re-packs from the FP32
    /// masters at the new element width.
    fn transition(&mut self, scheme: QuantScheme) -> Result<(), String> {
        let QuantScheme::MxSquare(fmt) = scheme else {
            return Err(format!(
                "packed backend executes square-block MX schemes only (mx-int8 ... mx-e2m1); got `{}`",
                scheme.name()
            ));
        };
        if self.pa.iter().any(|p| p.is_some()) {
            return Err("cannot transition mid-step: a forward tape is pending backward".into());
        }
        self.scheme = scheme;
        self.fmt = fmt;
        for pw in &mut self.pw {
            *pw = None;
        }
        for step in &mut self.pw_step {
            *step = NEVER;
        }
        Ok(())
    }

    fn backward_layer(&mut self, layer: usize, e: &Mat, _aq: &Mat, w: Option<&Mat>) -> LayerGrads {
        self.ensure(layer);
        let pe = self.registry.quantize_pack(e, self.fmt);
        // weight gradient: the stored packed activation, transposed for
        // free (block permutation), against Q(E)
        let pa = self.pa[layer].take().expect("forward_layer must precede backward_layer");
        let d_w = self.registry.gemm(&pa.transpose(), &pe);
        let d_b = pe.col_sums();
        // error backprop: the same packed weight copy, consumed
        // transposed at zero cost (row lanes are already k-major)
        let back = w.map(|w| {
            self.ensure_pw(layer, w);
            self.registry.gemm_nt(&pe, self.pw[layer].as_ref().expect("just ensured"))
        });
        LayerGrads { d_w, d_b, back }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::FakeQuantBackend;
    use crate::mx::dacapo::DacapoFormat;
    use crate::trainer::mlp::Mlp;
    use crate::trainer::qat::qat_step_with;
    use crate::util::rng::Pcg64;

    #[test]
    fn rejects_non_square_schemes() {
        for scheme in [
            QuantScheme::Fp32,
            QuantScheme::MxVector(ElementFormat::Int8),
            QuantScheme::Dacapo(DacapoFormat::Mx9),
        ] {
            let e = PackedBackend::new(scheme).err().unwrap();
            assert!(e.contains("square-block"), "{e}");
        }
    }

    #[test]
    fn tracks_fake_backend_across_steps() {
        // the backend-level pin (the exhaustive three-way equivalence
        // lives in tests/backend.rs): persistent packed state across
        // steps reproduces the fake-quant trainer bit for bit
        let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
        let mut rng = Pcg64::new(0x9AC);
        let mut mlp_p = Mlp::new(&[16, 24, 8], &mut rng);
        let mut mlp_f = mlp_p.clone();
        let x = Mat::randn(12, 16, 1.0, &mut rng);
        let y = Mat::randn(12, 8, 0.5, &mut rng);
        let mut packed = PackedBackend::new(scheme).unwrap();
        let mut fake = FakeQuantBackend::new(scheme);
        for step in 0..3 {
            let lp = qat_step_with(&mut mlp_p, &x, &y, &mut packed, 2e-3);
            let lf = qat_step_with(&mut mlp_f, &x, &y, &mut fake, 2e-3);
            assert_eq!(lp, lf, "step {step}");
        }
        let bits = |m: &Mlp| m.flat_params().iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&mlp_p), bits(&mlp_f));
    }

    #[test]
    #[should_panic(expected = "forward_layer must precede backward_layer")]
    fn double_backward_panics() {
        let scheme = QuantScheme::MxSquare(ElementFormat::E4M3);
        let mut rng = Pcg64::new(5);
        let mlp = Mlp::new(&[8, 8], &mut rng);
        let x = Mat::randn(4, 8, 1.0, &mut rng);
        let y = Mat::randn(4, 8, 1.0, &mut rng);
        let mut be = PackedBackend::new(scheme).unwrap();
        be.begin_step();
        let tape = mlp.forward_exec(&x, &mut be);
        let _ = mlp.backward_exec(&tape, &y, &mut be);
        let _ = mlp.backward_exec(&tape, &y, &mut be); // second consume
    }
}
