//! The fast software backend: buffer-reusing MX fake-quantization.

#![forbid(unsafe_code)]

use crate::backend::{
    backward_from_quant, gemm_fwd, ExecBackend, GemmKernel, KernelRegistry, LayerGrads,
};
use crate::mx::dacapo::DacapoTensor;
use crate::mx::tensor::{fake_quant_mat_fast_into, Layout};
use crate::trainer::qat::QuantScheme;
use crate::util::mat::Mat;

/// Epoch tag for "not quantized yet".
const NEVER: u64 = u64::MAX;

/// Software fake-quantization backend (every [`QuantScheme`]).
///
/// Per-layer scratch buffers hold the quantized weights and errors: for
/// FP32 and square MX schemes, after the first step the only per-quant
/// allocation left is the quantized activation that the tape must own.
/// Square-block schemes additionally reuse the *forward* quantized
/// weight for the backward error GeMM (the transpose is value-free —
/// the paper's single-copy storage property). Vector and Dacapo schemes
/// requantize along the other grouping, materializing transposed
/// intermediates on the way — exactly the Fig. 5 cost the paper
/// attributes to them, so their quant calls still allocate.
pub struct FakeQuantBackend {
    scheme: QuantScheme,
    /// Dense GeMM kernel defining this scheme's value semantics
    /// (block-ordered accumulation for square MX — see
    /// [`KernelRegistry::dense_kernel`]).
    kernel: GemmKernel,
    /// Forward-grouping quantized weights, refreshed once per step.
    wq: Vec<Mat>,
    /// Step at which `wq[i]` was refreshed (NEVER = stale).
    wq_step: Vec<u64>,
    /// Transpose-grouping quantized weights (vector/Dacapo schemes).
    wq_t: Vec<Mat>,
    /// Quantized-error scratch, one per layer.
    eq: Vec<Mat>,
    /// Step at which `layer`'s forward ran without its backward yet —
    /// the "pending tape" marker the transition guard checks (the fake
    /// backend stores no activations, so it tracks the step shape
    /// explicitly where hw/packed can just inspect their stored `qa`).
    fwd_pending: Vec<u64>,
    step: u64,
}

impl FakeQuantBackend {
    pub fn new(scheme: QuantScheme) -> Self {
        Self {
            scheme,
            kernel: KernelRegistry::dense_kernel(scheme),
            wq: Vec::new(),
            wq_step: Vec::new(),
            wq_t: Vec::new(),
            eq: Vec::new(),
            fwd_pending: Vec::new(),
            step: 0,
        }
    }

    pub fn scheme(&self) -> QuantScheme {
        self.scheme
    }

    fn ensure(&mut self, layer: usize) {
        while self.wq.len() <= layer {
            self.wq.push(Mat::zeros(0, 0));
            self.wq_t.push(Mat::zeros(0, 0));
            self.eq.push(Mat::zeros(0, 0));
            self.wq_step.push(NEVER);
            self.fwd_pending.push(NEVER);
        }
    }

    /// Quantize `m` under the scheme into a reusable buffer.
    fn quant_into(scheme: QuantScheme, m: &Mat, out: &mut Mat) {
        match scheme {
            QuantScheme::Fp32 => out.copy_from(m),
            QuantScheme::MxSquare(f) => fake_quant_mat_fast_into(m, f, Layout::Square8x8, out),
            QuantScheme::MxVector(f) => fake_quant_mat_fast_into(m, f, Layout::Vector32, out),
            QuantScheme::Dacapo(f) => *out = DacapoTensor::fake_quant(m, f),
        }
    }

    /// Quantize a tensor consumed transposed (the backward weight cut)
    /// into the buffer — delegates to [`QuantScheme::quant_for_transpose`]
    /// (the single source of truth for the second-grouping semantics);
    /// only called for schemes whose transposed grouping differs from
    /// the forward one, which all materialize intermediates anyway.
    fn quant_transposed_into(scheme: QuantScheme, m: &Mat, out: &mut Mat) {
        *out = scheme.quant_for_transpose(m);
    }

    /// Whether the forward-grouping weight serves the backward GeMM too.
    fn transpose_is_free(scheme: QuantScheme) -> bool {
        matches!(scheme, QuantScheme::Fp32 | QuantScheme::MxSquare(_))
    }
}

impl ExecBackend for FakeQuantBackend {
    fn name(&self) -> &'static str {
        "fake-quant"
    }

    fn begin_step(&mut self) {
        self.step += 1;
    }

    fn forward_layer(&mut self, layer: usize, a: &Mat, w: &Mat) -> (Mat, Mat) {
        self.ensure(layer);
        let aq = self.scheme.quant(a);
        Self::quant_into(self.scheme, w, &mut self.wq[layer]);
        self.wq_step[layer] = self.step;
        let z = gemm_fwd(self.kernel, &aq, &self.wq[layer]);
        self.fwd_pending[layer] = self.step;
        (aq, z)
    }

    /// Mid-session scheme switch: the software path handles every
    /// scheme, so the only refusal is the contract's mid-step guard (a
    /// pending forward tape would mix formats inside one backward
    /// pass, same as hw/packed). Otherwise it swaps the scheme and the
    /// GeMM kernel and invalidates the per-layer scratch so the next
    /// step requantizes everything from the FP32 masters under the new
    /// format (never format-to-format).
    fn transition(&mut self, scheme: QuantScheme) -> Result<(), String> {
        if self.fwd_pending.iter().any(|&p| p == self.step) {
            return Err("cannot transition mid-step: a forward tape is pending backward".into());
        }
        self.scheme = scheme;
        self.kernel = KernelRegistry::dense_kernel(scheme);
        for step in &mut self.wq_step {
            *step = NEVER;
        }
        for buf in self.wq.iter_mut().chain(&mut self.wq_t).chain(&mut self.eq) {
            *buf = Mat::zeros(0, 0);
        }
        Ok(())
    }

    fn backward_layer(&mut self, layer: usize, e: &Mat, aq: &Mat, w: Option<&Mat>) -> LayerGrads {
        self.ensure(layer);
        self.fwd_pending[layer] = NEVER;
        let scheme = self.scheme;
        Self::quant_into(scheme, e, &mut self.eq[layer]);
        let use_forward_copy = Self::transpose_is_free(scheme);
        if let Some(w) = w {
            if use_forward_copy {
                if self.wq_step[layer] != self.step {
                    Self::quant_into(scheme, w, &mut self.wq[layer]);
                    self.wq_step[layer] = self.step;
                }
            } else {
                Self::quant_transposed_into(scheme, w, &mut self.wq_t[layer]);
            }
        }
        let wq = match (w, use_forward_copy) {
            (Some(_), true) => Some(&self.wq[layer]),
            (Some(_), false) => Some(&self.wq_t[layer]),
            (None, _) => None,
        };
        backward_from_quant(self.kernel, &self.eq[layer], aq, wq)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::trainer::mlp::Mlp;
    use crate::util::rng::Pcg64;

    #[test]
    fn backend_matches_hook_path_bitwise_for_every_scheme() {
        // the refactor's no-regression pin: the buffer-reusing backend
        // must reproduce a kernel-matched hook backend (scheme.quant /
        // quant_for_transpose closures over the scheme's GeMM kernel)
        // bit-for-bit for every scheme family.
        use crate::backend::HookBackend;
        use crate::mx::dacapo::DacapoFormat;
        let mut rng = Pcg64::new(0xFA4E);
        let mlp = Mlp::new(&[16, 24, 8], &mut rng);
        let x = Mat::randn(12, 16, 1.0, &mut rng);
        let y = Mat::randn(12, 8, 0.5, &mut rng);
        for scheme in [
            QuantScheme::Fp32,
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxSquare(ElementFormat::E2M1),
            QuantScheme::MxVector(ElementFormat::E4M3),
            QuantScheme::Dacapo(DacapoFormat::Mx9),
        ] {
            let mut hooks = HookBackend::for_scheme(
                scheme,
                |_, w: &Mat| scheme.quant_for_transpose(w),
                |_, a: &Mat| scheme.quant(a),
                |_, e: &Mat| scheme.quant(e),
            );
            // the hook backend quantizes weights per cut; the forward
            // cut's weight hook must be the forward grouping
            let mut fwd_hooks = HookBackend::for_scheme(
                scheme,
                |_, w: &Mat| scheme.quant(w),
                |_, a: &Mat| scheme.quant(a),
                |_, e: &Mat| scheme.quant(e),
            );
            let tape_h = mlp.forward_exec(&x, &mut fwd_hooks);
            let grads_h = mlp.backward_exec(&tape_h, &y, &mut hooks);
            let mut be = FakeQuantBackend::new(scheme);
            be.begin_step();
            let tape_b = mlp.forward_exec(&x, &mut be);
            let grads_b = mlp.backward_exec(&tape_b, &y, &mut be);
            assert_eq!(tape_h.output.data, tape_b.output.data, "{}", scheme.name());
            for (a, b) in tape_h.activations.iter().zip(&tape_b.activations) {
                assert_eq!(a.data, b.data, "{} activations", scheme.name());
            }
            for (a, b) in grads_h.d_weights.iter().zip(&grads_b.d_weights) {
                assert_eq!(a.data, b.data, "{} d_w", scheme.name());
            }
            assert_eq!(grads_h.d_biases, grads_b.d_biases, "{} d_b", scheme.name());
        }
    }

    #[test]
    fn scratch_buffers_survive_multiple_steps() {
        let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
        let mut rng = Pcg64::new(3);
        let mut mlp = Mlp::new(&[16, 16, 8], &mut rng);
        let x = Mat::randn(8, 16, 1.0, &mut rng);
        let y = Mat::randn(8, 8, 0.5, &mut rng);
        let mut be = FakeQuantBackend::new(scheme);
        // three steps through the persistent backend vs three fresh ones
        let mut mlp2 = mlp.clone();
        for _ in 0..3 {
            let l1 = crate::trainer::qat::qat_step_with(&mut mlp, &x, &y, &mut be, 1e-3);
            let l2 = crate::trainer::qat::qat_step(&mut mlp2, &x, &y, scheme, 1e-3);
            assert_eq!(l1, l2);
        }
        assert_eq!(mlp.flat_params(), mlp2.flat_params());
    }
}
