//! Dacapo-style weight-stationary systolic array (the Table IV baseline).
//!
//! Dacapo (ISCA'24) executes GeMMs on a TPU-like systolic array with
//! MX9/6/4 vector-block operands. Under iso-peak-throughput (4096 MACs)
//! its training latency is dominated by systolically shifting operands
//! in and out of the array: every stationary weight tile pays a fill
//! phase, and results drain through the array diagonal. The paper's 4x
//! effective-throughput claim is precisely this overhead, so the model
//! here is a cycle model of fill / stream / drain per weight tile plus
//! Dacapo's published per-mode sub-word throughput scaling.
//!
//! Numerics for training comparisons come from [`DacapoTensor`]
//! fake-quantization (Fig. 8); this module provides the cycle/energy
//! side. Calibration notes live in `crate::energy::calib`.

#![forbid(unsafe_code)]

use crate::mx::dacapo::DacapoFormat;

/// Weight-stationary systolic array geometry.
#[derive(Debug, Clone, Copy)]
pub struct SystolicArray {
    pub rows: usize,
    pub cols: usize,
}

/// Cycle cost of a systolic GeMM.
#[derive(Debug, Clone, Copy, Default)]
pub struct SystolicCost {
    /// Weight-tile fill cycles (shift weights down the columns).
    pub fill: u64,
    /// Activation streaming cycles (throughput-scaled by mode).
    pub stream: u64,
    /// Pipeline drain cycles (results exit the diagonal).
    pub drain: u64,
    pub mul_ops: u64,
}

impl SystolicCost {
    pub fn total(&self) -> u64 {
        self.fill + self.stream + self.drain
    }

    pub fn micros(&self, freq_mhz: f64) -> f64 {
        self.total() as f64 / freq_mhz
    }
}

impl SystolicArray {
    /// The iso-peak-throughput configuration: 64x64 = 4096 MACs.
    pub fn dacapo() -> Self {
        Self { rows: 64, cols: 64 }
    }

    /// Per-mode shift-bandwidth scaling: Dacapo moves operands through
    /// the array bit-serially per lane, so fill, stream, and drain all
    /// scale with the element payload width (9 / 6 / 4 bits).
    pub fn bit_factor(fmt: DacapoFormat) -> f64 {
        match fmt {
            DacapoFormat::Mx9 => 1.0,
            DacapoFormat::Mx6 => 6.0 / 9.0,
            DacapoFormat::Mx4 => 4.0 / 9.0,
        }
    }

    /// Cycle cost of `C[M,N] = A[M,K] @ B[K,N]` with B stationary.
    pub fn gemm_cycles(&self, m: usize, k: usize, n: usize, fmt: DacapoFormat) -> SystolicCost {
        let tiles_k = k.div_ceil(self.rows) as u64;
        let tiles_n = n.div_ceil(self.cols) as u64;
        let tiles = tiles_k * tiles_n;
        let f = Self::bit_factor(fmt);
        let fill_per_tile = (self.rows as f64 * f).ceil() as u64;
        let stream_per_tile = (m as f64 * f).ceil() as u64;
        let drain_per_tile = ((self.rows + self.cols) as f64 * f).ceil() as u64;
        SystolicCost {
            fill: tiles * fill_per_tile,
            stream: tiles * stream_per_tile,
            drain: tiles * drain_per_tile,
            mul_ops: (m as u64) * (k as u64) * (n as u64),
        }
    }

    /// Whole training step (fwd + bwd + wgrad) over an MLP.
    pub fn train_step_cycles(&self, batch: usize, dims: &[usize], fmt: DacapoFormat) -> SystolicCost {
        let mut total = SystolicCost::default();
        for w in dims.windows(2) {
            let (din, dout) = (w[0], w[1]);
            for c in [
                self.gemm_cycles(batch, din, dout, fmt), // fwd
                self.gemm_cycles(batch, dout, din, fmt), // bwd
                self.gemm_cycles(din, batch, dout, fmt), // wgrad
            ] {
                total.fill += c.fill;
                total.stream += c.stream;
                total.drain += c.drain;
                total.mul_ops += c.mul_ops;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gemmcore::schedule::PUSHER_DIMS;

    #[test]
    fn fill_drain_overhead_dominates_small_batches() {
        let arr = SystolicArray::dacapo();
        let c = arr.gemm_cycles(32, 256, 256, DacapoFormat::Mx9);
        // batch-32 streaming is far smaller than fill+drain
        assert!(c.fill + c.drain > 4 * c.stream, "{c:?}");
    }

    #[test]
    fn pusher_train_latency_ballpark_table4() {
        // Table IV Dacapo: 40.4 / 24.56 / 20.6 us per batch-32 loop.
        let arr = SystolicArray::dacapo();
        let t9 = arr.train_step_cycles(32, &PUSHER_DIMS, DacapoFormat::Mx9).micros(500.0);
        let t6 = arr.train_step_cycles(32, &PUSHER_DIMS, DacapoFormat::Mx6).micros(500.0);
        let t4 = arr.train_step_cycles(32, &PUSHER_DIMS, DacapoFormat::Mx4).micros(500.0);
        assert!((t9 - 40.4).abs() / 40.4 < 0.35, "MX9 {t9} vs 40.4");
        assert!((t6 - 24.56).abs() / 24.56 < 0.35, "MX6 {t6} vs 24.56");
        assert!((t4 - 20.6).abs() / 20.6 < 0.35, "MX4 {t4} vs 20.6");
        assert!(t9 > t6 && t6 > t4);
    }

    #[test]
    fn ours_beats_dacapo_by_about_4x() {
        // the paper's headline: ~4x effective training throughput
        use crate::gemmcore::schedule::train_step_cycles;
        use crate::mx::element::ElementFormat;
        let arr = SystolicArray::dacapo();
        let ours = train_step_cycles(32, &PUSHER_DIMS, ElementFormat::Int8).micros(500.0);
        let theirs = arr.train_step_cycles(32, &PUSHER_DIMS, DacapoFormat::Mx9).micros(500.0);
        let speedup = theirs / ours;
        assert!(speedup > 2.5 && speedup < 6.0, "speedup {speedup}");
    }

    #[test]
    fn mode_ordering() {
        let arr = SystolicArray::dacapo();
        let c9 = arr.gemm_cycles(128, 256, 256, DacapoFormat::Mx9).total();
        let c6 = arr.gemm_cycles(128, 256, 256, DacapoFormat::Mx6).total();
        let c4 = arr.gemm_cycles(128, 256, 256, DacapoFormat::Mx4).total();
        assert!(c9 > c6 && c6 >= c4);
    }
}
