//! The square-based MX PE array (paper §IV-A, Fig. 6).
//!
//! 64 precision-scalable MACs, one per output element of an 8x8 tile.
//! One call to [`PeArray::mul_block`] performs the full 8x8 x 8x8 block
//! product — 8 clock cycles in INT8 mode, 2 in FP8/FP6, 1 in FP4 — and
//! accumulates output-stationary, so chaining calls over the K dimension
//! computes a GeMM tile without any intermediate writeback. Shared block
//! exponents are combined at PE level and applied inside each MAC's
//! accumulation step, exactly as the paper describes.

#![forbid(unsafe_code)]

use crate::arith::{Events, MacUnit, MacVariant, Mode};
use crate::mx::block::ScaledBlock;
use crate::mx::element::ElementFormat;
use crate::mx::tensor::{Layout, MxTensor, SQ};
use crate::mx::MxFormat;
use crate::util::mat::Mat;
use crate::util::par;

/// Minimum number of 8x8 block products (tiles x K-depth) before the
/// GeMM walk forks worker contexts; below this the fork-join overhead
/// exceeds the simulation work.
const PAR_MIN_BLOCK_PRODUCTS: usize = 32;

/// One 64-MAC square-block PE array.
///
/// Also serves as the reusable per-worker datapath context of the
/// tile-parallel GeMM walk: output tiles are independent (output-
/// stationary dataflow), so [`PeArray::gemm_quantized`] hands each
/// worker its own `PeArray` and reduces the per-worker [`Events`] and
/// cycle counts back into `self` — bit-identical to the serial walk.
#[derive(Debug, Clone)]
pub struct PeArray {
    macs: Vec<MacUnit>,
    pub format: ElementFormat,
    pub mode: Mode,
    pub variant: MacVariant,
    /// Total clock cycles consumed so far.
    pub cycles: u64,
    /// Events reduced from parallel worker contexts (serial `mul_block`
    /// activity lives inside the MACs; totals combine in `events()`).
    merged_events: Events,
}

impl PeArray {
    pub fn new(format: ElementFormat, variant: MacVariant) -> Self {
        let mode = format.mac_mode();
        Self {
            macs: (0..SQ * SQ).map(|_| MacUnit::new(mode, variant)).collect(),
            format,
            mode,
            variant,
            cycles: 0,
            merged_events: Events::default(),
        }
    }

    /// Clear the 64 output accumulators and operand registers (start of
    /// a new output tile). Resetting the operand registers makes each
    /// tile's event counts traversal-order independent, so the serial
    /// and tile-parallel walks produce identical `Events`.
    pub fn reset_outputs(&mut self) {
        for m in &mut self.macs {
            m.reset_acc();
            m.reset_operand_reg();
        }
    }

    /// Multiply-accumulate one pair of 8x8 blocks: `out += A_tile @ B_tile`.
    ///
    /// Advances the cycle counter by the mode's cycles-per-block (8/2/1).
    pub fn mul_block(&mut self, a: &ScaledBlock, b: &ScaledBlock) {
        debug_assert_eq!(a.codes.len(), SQ * SQ);
        debug_assert_eq!(b.codes.len(), SQ * SQ);
        debug_assert_eq!(a.format, self.format);
        debug_assert_eq!(b.format, self.format);
        match self.mode {
            Mode::Int8 => {
                // MXINT8 elements carry an implied 2^-6 each
                let se = a.scale_exp + b.scale_exp - 12;
                for i in 0..SQ {
                    for j in 0..SQ {
                        let mac = &mut self.macs[i * SQ + j];
                        for k in 0..SQ {
                            mac.cycle_int8(
                                a.codes[i * SQ + k] as i8,
                                b.codes[k * SQ + j] as i8,
                                se,
                            );
                        }
                    }
                }
            }
            Mode::Fp8Fp6 => {
                let se = a.scale_exp + b.scale_exp;
                for i in 0..SQ {
                    for j in 0..SQ {
                        let mac = &mut self.macs[i * SQ + j];
                        for half in 0..2 {
                            let mut pairs = [(0u8, 0u8); 4];
                            for (t, pair) in pairs.iter_mut().enumerate() {
                                let k = half * 4 + t;
                                *pair = (a.codes[i * SQ + k], b.codes[k * SQ + j]);
                            }
                            mac.cycle_fp86(self.format, &pairs, se);
                        }
                    }
                }
            }
            Mode::Fp4 => {
                let se = a.scale_exp + b.scale_exp;
                for i in 0..SQ {
                    for j in 0..SQ {
                        let mac = &mut self.macs[i * SQ + j];
                        let mut pairs = [(0u8, 0u8); 8];
                        for (k, pair) in pairs.iter_mut().enumerate() {
                            *pair = (a.codes[i * SQ + k], b.codes[k * SQ + j]);
                        }
                        mac.cycle_fp4(&pairs, se);
                    }
                }
            }
        }
        self.cycles += self.mode.cycles_per_block() as u64;
    }

    /// Read the 8x8 FP32 output tile.
    pub fn outputs(&self) -> Mat {
        Mat::from_fn(SQ, SQ, |i, j| self.macs[i * SQ + j].acc())
    }

    /// Aggregate event counters: the 64 MACs plus events reduced from
    /// parallel worker contexts.
    pub fn events(&self) -> Events {
        let mut total = self.merged_events;
        for m in &self.macs {
            total.add(&m.events);
        }
        total
    }

    /// Drain event counters.
    pub fn take_events(&mut self) -> Events {
        let mut total = std::mem::take(&mut self.merged_events);
        for m in &mut self.macs {
            total.add(&m.take_events());
        }
        total
    }

    /// Full GeMM `A @ B` through this single array (test/reference path;
    /// the 4x16 grid in `gemmcore` is the performance configuration).
    /// Quantizes both operands to square blocks in this array's format.
    pub fn gemm(&mut self, a: &Mat, b: &Mat) -> Mat {
        let fmt = MxFormat::square(self.format);
        let qa = MxTensor::quantize(a, fmt.element, Layout::Square8x8);
        let qb = MxTensor::quantize(b, fmt.element, Layout::Square8x8);
        self.gemm_quantized(&qa, &qb)
    }

    /// GeMM over already-quantized square tensors.
    ///
    /// Output tiles are mutually independent (output-stationary), so
    /// large GeMMs fan the tiles out over per-worker `PeArray` contexts
    /// and reduce their `Events`/cycles back into `self`. Results,
    /// events, and cycle counts are bit-identical to
    /// [`PeArray::gemm_quantized_serial`] (asserted by
    /// `tests/parallel.rs`): each tile's FP32 accumulation runs the same
    /// K-order from a cleared context either way, and event totals are
    /// sums of order-independent per-tile counts.
    pub fn gemm_quantized(&mut self, qa: &MxTensor, qb: &MxTensor) -> Mat {
        assert_eq!(qa.layout, Layout::Square8x8);
        assert_eq!(qb.layout, Layout::Square8x8);
        assert_eq!(qa.cols, qb.rows, "inner dims");
        let (brows, bcols, kb) = (qa.brows, qb.bcols, qa.bcols);
        if brows * bcols * kb < PAR_MIN_BLOCK_PRODUCTS {
            return self.gemm_quantized_serial(qa, qb);
        }
        let (format, variant) = (self.format, self.variant);
        let tiles = par::par_map(brows * bcols, 2, |t| {
            let (br, bc) = (t / bcols, t % bcols);
            let mut ctx = PeArray::new(format, variant);
            ctx.reset_outputs();
            for bk in 0..kb {
                ctx.mul_block(qa.square_block(br, bk), qb.square_block(bk, bc));
            }
            (ctx.outputs(), ctx.take_events(), ctx.cycles)
        });
        let mut out = Mat::zeros(qa.rows, qb.cols);
        for (t, (tile, ev, cycles)) in tiles.into_iter().enumerate() {
            let (br, bc) = (t / bcols, t % bcols);
            out.set_block(br * SQ, bc * SQ, &tile);
            self.merged_events.add(&ev);
            self.cycles += cycles;
        }
        out
    }

    /// Serial reference GeMM: one context walks every output tile in
    /// row-major order — the path the parallel walk must reproduce
    /// bit-for-bit.
    pub fn gemm_quantized_serial(&mut self, qa: &MxTensor, qb: &MxTensor) -> Mat {
        assert_eq!(qa.layout, Layout::Square8x8);
        assert_eq!(qb.layout, Layout::Square8x8);
        assert_eq!(qa.cols, qb.rows, "inner dims");
        let mut out = Mat::zeros(qa.rows, qb.cols);
        for br in 0..qa.brows {
            for bc in 0..qb.bcols {
                self.reset_outputs();
                for bk in 0..qa.bcols {
                    self.mul_block(qa.square_block(br, bk), qb.square_block(bk, bc));
                }
                out.set_block(br * SQ, bc * SQ, &self.outputs());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;
    use crate::util::rng::Pcg64;

    fn quantized_golden(a: &Mat, b: &Mat, fmt: ElementFormat) -> Mat {
        // f64 matmul over the dequantized operands
        let qa = MxTensor::fake_quant(a, fmt, Layout::Square8x8);
        let qb = MxTensor::fake_quant(b, fmt, Layout::Square8x8);
        qa.matmul(&qb)
    }

    #[test]
    fn block_product_cycle_counts() {
        for (fmt, want) in [
            (ElementFormat::Int8, 8),
            (ElementFormat::E4M3, 2),
            (ElementFormat::E5M2, 2),
            (ElementFormat::E3M2, 2),
            (ElementFormat::E2M3, 2),
            (ElementFormat::E2M1, 1),
        ] {
            let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
            let mut rng = Pcg64::new(1);
            let a = Mat::randn(8, 8, 1.0, &mut rng);
            let b = Mat::randn(8, 8, 1.0, &mut rng);
            let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
            let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
            pe.mul_block(qa.square_block(0, 0), qb.square_block(0, 0));
            assert_eq!(pe.cycles, want, "{fmt:?}");
        }
    }

    #[test]
    fn single_block_product_matches_dequantized_math() {
        let mut rng = Pcg64::new(2);
        for fmt in ALL_ELEMENT_FORMATS {
            let a = Mat::randn(8, 8, 2.0, &mut rng);
            let b = Mat::randn(8, 8, 2.0, &mut rng);
            let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
            let out = pe.gemm(&a, &b);
            let golden = quantized_golden(&a, &b, fmt);
            // FP32-accumulation-grade agreement
            let tol = (golden.max_abs() as f64 + 1.0) * 1e-5;
            assert!(out.mse(&golden).sqrt() < tol, "{fmt:?}: {}", out.mse(&golden));
        }
    }

    #[test]
    fn int8_gemm_is_bit_exact_vs_integer_golden() {
        // INT8 products & FP32 accumulation of <=2^26 sums are exact:
        // the PE output must match an i64 dot product of the codes.
        let mut rng = Pcg64::new(3);
        let a = Mat::randn(16, 24, 1.5, &mut rng);
        let b = Mat::randn(24, 16, 1.5, &mut rng);
        let qa = MxTensor::quantize(&a, ElementFormat::Int8, Layout::Square8x8);
        let qb = MxTensor::quantize(&b, ElementFormat::Int8, Layout::Square8x8);
        let mut pe = PeArray::new(ElementFormat::Int8, MacVariant::ExtMantissaBypass);
        let out = pe.gemm_quantized(&qa, &qb);
        let golden = qa.dequantize().matmul(&qb.dequantize());
        // each block-pair contribution is exact; FP32 accumulation across
        // K blocks rounds — compare within 1e-6 relative
        let scale = golden.max_abs().max(1.0) as f64;
        assert!(out.mse(&golden).sqrt() / scale < 1e-6, "mse {}", out.mse(&golden));
    }

    #[test]
    fn output_stationary_accumulation_over_k() {
        // multi-K-block GeMM equals sum of per-block products
        let mut rng = Pcg64::new(4);
        let fmt = ElementFormat::E4M3;
        let a = Mat::randn(8, 32, 1.0, &mut rng); // 4 K-blocks
        let b = Mat::randn(32, 8, 1.0, &mut rng);
        let qa = MxTensor::quantize(&a, fmt, Layout::Square8x8);
        let qb = MxTensor::quantize(&b, fmt, Layout::Square8x8);
        let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let full = pe.gemm_quantized(&qa, &qb);

        let mut manual = Mat::zeros(8, 8);
        for bk in 0..4 {
            let mut pe2 = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
            pe2.reset_outputs();
            pe2.mul_block(qa.square_block(0, bk), qb.square_block(bk, 0));
            manual.axpy(1.0, &pe2.outputs());
        }
        // full (FP32-accumulated in sequence) vs manual (f32 adds of
        // per-block f32 results): same up to FP32 associativity
        assert!(full.mse(&manual).sqrt() < manual.max_abs() as f64 * 1e-6);
    }

    #[test]
    fn gemm_cycles_scale_with_problem_size() {
        let mut rng = Pcg64::new(5);
        let a = Mat::randn(16, 16, 1.0, &mut rng);
        let b = Mat::randn(16, 16, 1.0, &mut rng);
        let mut pe = PeArray::new(ElementFormat::Int8, MacVariant::ExtMantissaBypass);
        pe.gemm(&a, &b);
        // 2x2 output tiles x 2 K-blocks x 8 cycles = 64
        assert_eq!(pe.cycles, 64);

        let mut pe4 = PeArray::new(ElementFormat::E2M1, MacVariant::ExtMantissaBypass);
        pe4.gemm(&a, &b);
        assert_eq!(pe4.cycles, 8, "FP4 is 8x fewer cycles than INT8");
    }

    #[test]
    fn transpose_reuse_backprop_identity() {
        // The architectural payoff: using q(W) and transpose(q(W)) in the
        // two passes gives the same numerics as storing two copies.
        let mut rng = Pcg64::new(6);
        let fmt = ElementFormat::Int8;
        let w = Mat::randn(16, 16, 1.0, &mut rng);
        let e = Mat::randn(8, 16, 1.0, &mut rng);
        let qw = MxTensor::quantize(&w, fmt, Layout::Square8x8);
        let qwt = qw.transpose().unwrap(); // free, no requantization
        let qe = MxTensor::quantize(&e, fmt, Layout::Square8x8);
        let mut pe = PeArray::new(fmt, MacVariant::ExtMantissaBypass);
        let bwd = pe.gemm_quantized(&qe, &qwt);
        let golden = qe.dequantize().matmul(&qw.dequantize().transpose());
        assert!(bwd.mse(&golden).sqrt() < golden.max_abs() as f64 * 1e-6);
    }

    #[test]
    fn events_aggregate_over_64_macs() {
        let mut rng = Pcg64::new(7);
        let a = Mat::randn(8, 8, 1.0, &mut rng);
        let b = Mat::randn(8, 8, 1.0, &mut rng);
        let mut pe = PeArray::new(ElementFormat::Int8, MacVariant::ExtMantissaBypass);
        pe.gemm(&a, &b);
        let ev = pe.events();
        // 64 MACs x 8 cycles x 16 mult2 = 8192
        assert_eq!(ev.mult2, 64 * 8 * 16);
        assert_eq!(ev.mul_ops, 64 * 8);
        assert_eq!(ev.cycles, 64 * 8); // MAC-cycles (64 lanes x 8 clocks)
    }
}
