//! PE arrays: the paper's square-block array and the Dacapo baseline.
//!
//! [`array::PeArray`] is the paper's §IV-A contribution: 64 precision-
//! scalable MACs multiplying two 8x8 shared-exponent square blocks in
//! 8 / 2 / 1 cycles (INT8 / FP8-FP6 / FP4), output-stationary.
//!
//! [`systolic::SystolicArray`] is the Dacapo (ISCA'24) reference point: a
//! weight-stationary systolic array with MX9/6/4 vector blocks, whose
//! fill/drain overhead is what Table IV's latency comparison measures.
//!
//! Besides the standalone experiments, the array is the execution engine
//! of the hardware training backend ([`crate::backend::HardwareBackend`]
//! via [`crate::gemmcore::GemmCore`]): every quantize→GeMM cut of a
//! `--backend hw` QAT session walks these MACs bit-exactly, and their
//! [`crate::arith::Events`] feed the per-session cost report.

pub mod array;
pub mod systolic;

pub use array::PeArray;
pub use systolic::SystolicArray;
