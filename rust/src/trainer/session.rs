//! Training sessions: dataset + model + scheme + backend -> loss curves
//! (and, on the hardware backend, a per-session cost report).

#![forbid(unsafe_code)]

use crate::backend::{make_backend, BackendKind, ExecBackend, HwCostReport};
use crate::gemmcore::memory::{footprint_ours, MlpShape};
use crate::trainer::checkpoint::{weight_payload, Checkpoint};
use crate::trainer::mlp::{Mlp, MLP_DIMS};
use crate::trainer::policy::PrecisionPolicy;
use crate::trainer::qat::{qat_eval, qat_step_with, QuantScheme};
use crate::util::rng::Pcg64;
use crate::workloads::Dataset;

/// Why a [`TrainSession`] could not be built — structured so callers
/// (CLI, fleet scheduler, checkpoint restore) can react per cause
/// instead of string-matching.
#[derive(Debug, Clone, PartialEq)]
pub enum TrainError {
    /// The layer dims don't form an MLP that fits the dataset.
    BadDims { dims: Vec<usize>, reason: String },
    /// The scheme × backend combination has no implementation.
    UnsupportedScheme { scheme: String, backend: &'static str, reason: String },
    /// A non-dims configuration field is out of range.
    BadConfig { reason: String },
    /// A checkpoint doesn't match the session it should restore.
    BadCheckpoint { reason: String },
}

impl std::fmt::Display for TrainError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TrainError::BadDims { dims, reason } => {
                write!(f, "invalid MLP dims {dims:?}: {reason}")
            }
            TrainError::UnsupportedScheme { scheme, backend, reason } => {
                write!(f, "scheme `{scheme}` unsupported on the `{backend}` backend: {reason}")
            }
            TrainError::BadConfig { reason } => write!(f, "invalid train config: {reason}"),
            TrainError::BadCheckpoint { reason } => write!(f, "checkpoint mismatch: {reason}"),
        }
    }
}

impl std::error::Error for TrainError {}

/// Configuration of a training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub scheme: QuantScheme,
    /// Which execution backend runs the quantize→GeMM cut points.
    pub backend: BackendKind,
    /// MLP layer dims; `None` = the paper's [`MLP_DIMS`]. Input/output
    /// widths must match the dataset (32/32 for the bundled workloads).
    pub dims: Option<Vec<usize>>,
    pub batch_size: usize,
    pub lr: f32,
    pub steps: usize,
    /// Evaluate validation loss every `eval_every` steps.
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            scheme: QuantScheme::Fp32,
            backend: BackendKind::Fast,
            dims: None,
            batch_size: 32,
            lr: 1e-3,
            steps: 400,
            eval_every: 20,
            seed: 0xC0FFEE,
        }
    }
}

/// A running (or finished) training session.
pub struct TrainSession {
    pub config: TrainConfig,
    pub mlp: Mlp,
    pub dataset: Dataset,
    /// (step, train_loss) samples.
    pub train_curve: Vec<(usize, f64)>,
    /// (step, val_loss) samples.
    pub val_curve: Vec<(usize, f64)>,
    backend: Box<dyn ExecBackend + Send>,
    dims: Vec<usize>,
    step: usize,
    /// Precision segments: `(start_step, scheme)`, ascending; entry 0
    /// is the configured scheme at step 0, and every
    /// [`TrainSession::transition_scheme`] appends one.
    scheme_log: Vec<(usize, QuantScheme)>,
}

impl TrainSession {
    /// Build a session, or explain why the configuration is invalid:
    /// bad layer dims (too few, zero-width, or not matching the dataset
    /// IO widths), a zero batch size, or a scheme the chosen backend
    /// cannot execute (the hardware backend runs square MX schemes only).
    pub fn try_new(dataset: Dataset, config: TrainConfig) -> Result<Self, TrainError> {
        if config.batch_size == 0 {
            return Err(TrainError::BadConfig { reason: "batch_size must be positive".into() });
        }
        if config.eval_every == 0 {
            // step_once computes `step % eval_every` — reject the
            // divide-by-zero here, where it is a structured error
            return Err(TrainError::BadConfig { reason: "eval_every must be positive".into() });
        }
        let dims: Vec<usize> = config.dims.clone().unwrap_or_else(|| MLP_DIMS.to_vec());
        if dims.len() < 2 {
            return Err(TrainError::BadDims {
                dims,
                reason: "need at least an input and an output width".into(),
            });
        }
        if dims.contains(&0) {
            return Err(TrainError::BadDims { dims, reason: "zero-width layer".into() });
        }
        let (din, dout) = (dims[0], dims[dims.len() - 1]);
        if din != dataset.train_x.cols || dout != dataset.train_y.cols {
            let reason = format!(
                "dataset `{}` feeds {}-wide inputs and {}-wide targets",
                dataset.name, dataset.train_x.cols, dataset.train_y.cols
            );
            return Err(TrainError::BadDims { dims, reason });
        }
        let backend = make_backend(config.backend, config.scheme).map_err(|reason| {
            TrainError::UnsupportedScheme {
                scheme: config.scheme.name(),
                backend: config.backend.name(),
                reason,
            }
        })?;
        let mut rng = Pcg64::with_stream(config.seed, 0x11F);
        let mlp = Mlp::new(&dims, &mut rng);
        let scheme_log = vec![(0, config.scheme)];
        Ok(Self {
            config,
            mlp,
            dataset,
            train_curve: Vec::new(),
            val_curve: Vec::new(),
            backend,
            dims,
            step: 0,
            scheme_log,
        })
    }

    /// [`TrainSession::try_new`], panicking on an invalid configuration.
    pub fn new(dataset: Dataset, config: TrainConfig) -> Self {
        Self::try_new(dataset, config).unwrap_or_else(|e| panic!("invalid train config: {e}"))
    }

    /// Current step count.
    pub fn step_count(&self) -> usize {
        self.step
    }

    /// MLP layer dims this session trains.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Precision segments so far: `(start_step, scheme)`, ascending.
    /// Always non-empty; the last entry is the active scheme.
    pub fn scheme_history(&self) -> &[(usize, QuantScheme)] {
        &self.scheme_log
    }

    /// Switch the active [`QuantScheme`] at the current step boundary
    /// (the runtime-precision-scheduling seam, DESIGN.md §8).
    ///
    /// The live weights are *not* converted format-to-format — they are
    /// FP32 masters, and the backend drops every quantized cache, so
    /// from the next step on the session is bit-identical to one that
    /// started fresh at the new format with this master/Adam state
    /// (`tests/backend.rs` asserts this for all three backends).
    /// Evaluation ([`TrainSession::val_loss`]) follows the new scheme
    /// immediately. A same-scheme transition is a no-op; a scheme the
    /// backend cannot execute is a structured error and the session
    /// keeps training under the old scheme.
    pub fn transition_scheme(&mut self, scheme: QuantScheme) -> Result<(), TrainError> {
        if scheme == self.config.scheme {
            return Ok(());
        }
        self.backend.transition(scheme).map_err(|reason| TrainError::UnsupportedScheme {
            scheme: scheme.name(),
            backend: self.config.backend.name(),
            reason,
        })?;
        self.config.scheme = scheme;
        self.scheme_log.push((self.step, scheme));
        Ok(())
    }

    /// One training step under a [`PrecisionPolicy`]: the policy is
    /// consulted *before* the step (so a decision at step `k` makes
    /// step `k` the first step of the new segment) and fed the step's
    /// training loss afterwards (the adaptive watchdog's signal).
    pub fn step_with_policy(&mut self, policy: &mut PrecisionPolicy) -> Result<f64, TrainError> {
        if let Some(next) = policy.decide(self.step, self.config.scheme) {
            self.transition_scheme(next)?;
        }
        let loss = self.step_once();
        policy.observe(loss);
        Ok(loss)
    }

    /// Run to the configured step budget under a precision policy. An
    /// adaptive policy whose ladder does not contain the active scheme
    /// is a configuration error (its rung semantics would be undefined).
    pub fn run_with_policy(&mut self, policy: &mut PrecisionPolicy) -> Result<(), TrainError> {
        policy
            .validate_start(self.config.scheme)
            .map_err(|reason| TrainError::BadConfig { reason })?;
        while self.step < self.config.steps {
            self.step_with_policy(policy)?;
        }
        let v = self.val_loss();
        self.val_curve.push((self.step, v));
        Ok(())
    }

    /// Run one training step; returns the train loss.
    pub fn step_once(&mut self) -> f64 {
        let batch = self.dataset.batch(self.step, self.config.batch_size);
        let loss = qat_step_with(
            &mut self.mlp,
            &batch.x,
            &batch.y,
            self.backend.as_mut(),
            self.config.lr,
        );
        if self.step % self.config.eval_every == 0 {
            self.train_curve.push((self.step, loss));
            self.val_curve.push((self.step, self.val_loss()));
        }
        self.step += 1;
        loss
    }

    /// Run to the configured step budget (no precision transitions).
    /// Equivalent to `run_with_policy(Static)`, inlined so the
    /// infallible path stays infallible.
    pub fn run(&mut self) {
        while self.step < self.config.steps {
            self.step_once();
        }
        let v = self.val_loss();
        self.val_curve.push((self.step, v));
    }

    /// Quantized validation loss over the held-out split. Evaluation
    /// runs the fake-quant path — bit-identical values on either backend
    /// (the equivalence contract), and it keeps validation out of the
    /// hardware cost ledger, which accounts *training* steps.
    pub fn val_loss(&self) -> f64 {
        qat_eval(&self.mlp, &self.dataset.val_x, &self.dataset.val_y, self.config.scheme)
    }

    /// Replace the dataset mid-run (a domain-shift event): training
    /// continues from the current weights and optimizer state on the new
    /// data. Curves keep accumulating — the shift shows up as a loss
    /// jump at the swap step.
    pub fn swap_dataset(&mut self, dataset: Dataset) {
        self.dataset = dataset;
    }

    /// Snapshot the complete training state as an MX-native
    /// [`Checkpoint`]: the quantized weight image under this session's
    /// **active** scheme (square groups stored single-copy) plus the
    /// bit-exact FP32 master/optimizer sidecar, the loss curves, and
    /// the precision-segment log — so a precision-scheduled session
    /// resumes mid-schedule at the format it was actually running.
    pub fn save_checkpoint(&self) -> Checkpoint {
        Checkpoint {
            config: TrainConfig { dims: Some(self.dims.clone()), ..self.config.clone() },
            step: self.step,
            adam_step: self.mlp.step,
            train_curve: self.train_curve.clone(),
            val_curve: self.val_curve.clone(),
            params: self.mlp.flat_params(),
            opt: self.mlp.flat_opt_state(),
            scheme_log: self.scheme_log.iter().map(|&(s, sch)| (s, sch.name())).collect(),
            payload: weight_payload(&self.mlp.weights, self.config.scheme),
        }
    }

    /// Rebuild a session from a [`Checkpoint`] and a dataset (the same
    /// one to continue, or a shifted one to adapt). Restored training is
    /// bit-exact: stepping the resumed session reproduces the
    /// uninterrupted run's tape, Adam moments, and loss curve
    /// (`tests/checkpoint.rs` asserts this for all six formats on both
    /// backends).
    pub fn resume(dataset: Dataset, ck: &Checkpoint) -> Result<Self, TrainError> {
        let mut s = Self::try_new(dataset, ck.config.clone())?;
        if ck.params.len() != s.mlp.flat_params().len() {
            return Err(TrainError::BadCheckpoint {
                reason: format!("{} parameters for dims {:?}", ck.params.len(), s.dims),
            });
        }
        if ck.opt.len() != 2 * ck.params.len() {
            let reason =
                format!("{} optimizer values, expected {}", ck.opt.len(), 2 * ck.params.len());
            return Err(TrainError::BadCheckpoint { reason });
        }
        s.mlp.load_flat_params(&ck.params);
        s.mlp.load_flat_opt_state(&ck.opt);
        s.mlp.step = ck.adam_step;
        s.step = ck.step;
        s.train_curve = ck.train_curve.clone();
        s.val_curve = ck.val_curve.clone();
        if !ck.scheme_log.is_empty() {
            let mut log = Vec::with_capacity(ck.scheme_log.len());
            for (at, name) in &ck.scheme_log {
                let scheme = QuantScheme::parse(name).ok_or_else(|| TrainError::BadCheckpoint {
                    reason: format!("scheme log names unknown scheme `{name}`"),
                })?;
                log.push((*at, scheme));
            }
            if log.last().map(|&(_, sch)| sch) != Some(ck.config.scheme) {
                return Err(TrainError::BadCheckpoint {
                    reason: "scheme log does not end at the active scheme".into(),
                });
            }
            s.scheme_log = log;
        }
        Ok(s)
    }

    /// The accumulated hardware cost of this session's training steps
    /// (None on the fast backend), with the resident on-chip footprint
    /// filled in from the session's MLP shape and batch size.
    pub fn hw_report(&self) -> Option<HwCostReport> {
        let mut r = self.backend.cost_report()?;
        let shape = MlpShape { dims: self.dims.clone() };
        r.resident_kb = footprint_ours(&shape, self.config.batch_size, r.element).total();
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::workloads::by_name;

    fn quick_dataset(name: &str) -> Dataset {
        let env = by_name(name).unwrap();
        Dataset::collect(env.as_ref(), 6, 60, 0xDD)
    }

    #[test]
    fn fp32_session_learns_cartpole_dynamics() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig { steps: 300, lr: 2e-3, ..Default::default() },
        );
        let v0 = s.val_loss();
        s.run();
        let v1 = s.val_loss();
        assert!(v1 < v0 * 0.5, "val {v0} -> {v1}");
        assert!(!s.val_curve.is_empty());
        assert!(s.hw_report().is_none(), "fast backend accounts no hardware cost");
    }

    #[test]
    fn mxint8_session_learns_too() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                steps: 300,
                lr: 2e-3,
                ..Default::default()
            },
        );
        let v0 = s.val_loss();
        s.run();
        assert!(s.val_loss() < v0 * 0.7, "{v0} -> {}", s.val_loss());
    }

    #[test]
    fn sessions_are_reproducible() {
        let run = || {
            let mut s = TrainSession::new(
                quick_dataset("reacher"),
                TrainConfig { steps: 50, ..Default::default() },
            );
            s.run();
            s.val_loss()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn hw_backend_rejects_non_square_schemes() {
        for scheme in [QuantScheme::Fp32, QuantScheme::MxVector(ElementFormat::Int8)] {
            let r = TrainSession::try_new(
                quick_dataset("cartpole"),
                TrainConfig { scheme, backend: BackendKind::Hardware, ..Default::default() },
            );
            assert!(
                matches!(r, Err(TrainError::UnsupportedScheme { backend: "hw", .. })),
                "{}",
                scheme.name()
            );
        }
    }

    #[test]
    fn packed_backend_rejects_non_square_schemes() {
        for scheme in [QuantScheme::Fp32, QuantScheme::MxVector(ElementFormat::Int8)] {
            let r = TrainSession::try_new(
                quick_dataset("cartpole"),
                TrainConfig { scheme, backend: BackendKind::Packed, ..Default::default() },
            );
            assert!(
                matches!(r, Err(TrainError::UnsupportedScheme { backend: "packed", .. })),
                "{}",
                scheme.name()
            );
        }
    }

    #[test]
    fn packed_backend_session_learns() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                backend: BackendKind::Packed,
                dims: Some(vec![32, 48, 48, 32]),
                steps: 200,
                lr: 2e-3,
                ..Default::default()
            },
        );
        let v0 = s.val_loss();
        s.run();
        assert!(s.val_loss() < v0 * 0.8, "{v0} -> {}", s.val_loss());
        assert!(s.hw_report().is_none(), "packed backend accounts no hardware cost");
    }

    #[test]
    fn bad_dims_and_config_are_structured_errors() {
        let err = |config| TrainSession::try_new(quick_dataset("cartpole"), config).unwrap_err();
        // input width not matching the 32-wide dataset
        let e = err(TrainConfig { dims: Some(vec![16, 8, 32]), ..Default::default() });
        assert!(matches!(e, TrainError::BadDims { .. }), "{e}");
        // zero-width hidden layer
        let e = err(TrainConfig { dims: Some(vec![32, 0, 32]), ..Default::default() });
        assert!(matches!(e, TrainError::BadDims { .. }), "{e}");
        // single-entry dims
        let e = err(TrainConfig { dims: Some(vec![32]), ..Default::default() });
        assert!(matches!(e, TrainError::BadDims { .. }), "{e}");
        // zero batch size
        let e = err(TrainConfig { batch_size: 0, ..Default::default() });
        assert!(matches!(e, TrainError::BadConfig { .. }), "{e}");
        // zero eval interval (step_once would divide by it)
        let e = err(TrainConfig { eval_every: 0, ..Default::default() });
        assert!(matches!(e, TrainError::BadConfig { .. }), "{e}");
    }

    #[test]
    fn checkpoint_resume_continues_bitwise() {
        let cfg = TrainConfig {
            scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
            dims: Some(vec![32, 24, 32]),
            steps: 0,
            eval_every: 5,
            ..Default::default()
        };
        let ds = quick_dataset("reacher");
        let mut full = TrainSession::new(ds.clone(), cfg.clone());
        let mut half = TrainSession::new(ds.clone(), cfg);
        for _ in 0..8 {
            full.step_once();
            half.step_once();
        }
        let ck = half.save_checkpoint();
        assert_eq!(ck.step, 8);
        let mut resumed = TrainSession::resume(ds, &ck).unwrap();
        for _ in 0..6 {
            full.step_once();
            resumed.step_once();
        }
        assert_eq!(resumed.mlp.flat_params(), full.mlp.flat_params());
        assert_eq!(resumed.train_curve, full.train_curve);
        assert_eq!(resumed.val_curve, full.val_curve);
        assert_eq!(resumed.val_loss(), full.val_loss());
    }

    #[test]
    fn swap_dataset_continues_training_in_place() {
        // the lightweight (no-checkpoint) domain-shift path: weights,
        // optimizer state, and step counter all survive the swap, and
        // training keeps improving on the new data
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                dims: Some(vec![32, 48, 48, 32]),
                steps: 0,
                lr: 2e-3,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        for _ in 0..100 {
            s.step_once();
        }
        let params = s.mlp.flat_params();
        let shifted_env = crate::workloads::shifted_by_name("cartpole").unwrap();
        s.swap_dataset(Dataset::collect(shifted_env.as_ref(), 6, 60, 0xDE));
        assert_eq!(s.mlp.flat_params(), params, "swap must not touch the model");
        assert_eq!(s.step_count(), 100);
        let v0 = s.val_loss();
        for _ in 0..100 {
            s.step_once();
        }
        assert!(s.val_loss() < v0, "must keep learning on the swapped data: {v0}");
    }

    #[test]
    fn resume_rejects_mismatched_checkpoint() {
        let mut ck = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig { dims: Some(vec![32, 16, 32]), steps: 0, ..Default::default() },
        )
        .save_checkpoint();
        ck.params.pop();
        let e = TrainSession::resume(quick_dataset("cartpole"), &ck).unwrap_err();
        assert!(matches!(e, TrainError::BadCheckpoint { .. }), "{e}");
    }

    #[test]
    fn transition_scheme_switches_eval_and_logs_history() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
                dims: Some(vec![32, 16, 32]),
                steps: 0,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        for _ in 0..3 {
            s.step_once();
        }
        let v_e4m3 = s.val_loss();
        // same-scheme transition is a no-op (no new segment)
        s.transition_scheme(QuantScheme::MxSquare(ElementFormat::E4M3)).unwrap();
        assert_eq!(s.scheme_history().len(), 1);
        s.transition_scheme(QuantScheme::MxSquare(ElementFormat::E2M1)).unwrap();
        assert_eq!(s.config.scheme, QuantScheme::MxSquare(ElementFormat::E2M1));
        let want = [
            (0, QuantScheme::MxSquare(ElementFormat::E4M3)),
            (3, QuantScheme::MxSquare(ElementFormat::E2M1)),
        ];
        assert_eq!(s.scheme_history(), &want);
        // eval follows the active scheme immediately (coarser -> worse)
        let v_e2m1 = s.val_loss();
        assert_ne!(v_e4m3, v_e2m1, "eval must requantize under the new scheme");
        for _ in 0..3 {
            s.step_once();
        }
        assert_eq!(s.step_count(), 6);
    }

    #[test]
    fn rejected_transition_leaves_the_session_running() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                backend: BackendKind::Packed,
                dims: Some(vec![32, 16, 32]),
                steps: 0,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        s.step_once();
        let e = s.transition_scheme(QuantScheme::Fp32).unwrap_err();
        assert!(matches!(e, TrainError::UnsupportedScheme { backend: "packed", .. }), "{e}");
        assert_eq!(s.config.scheme, QuantScheme::MxSquare(ElementFormat::Int8));
        assert_eq!(s.scheme_history().len(), 1);
        s.step_once(); // still trains under the old scheme
        assert_eq!(s.step_count(), 2);
    }

    #[test]
    fn scheduled_policy_drives_transitions_at_the_right_steps() {
        use crate::trainer::policy::PrecisionPolicy;
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E2M1),
                dims: Some(vec![32, 16, 32]),
                steps: 12,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        let mut policy = PrecisionPolicy::parse("6:mx-int8").unwrap();
        s.run_with_policy(&mut policy).unwrap();
        assert_eq!(s.step_count(), 12);
        let want = [
            (0, QuantScheme::MxSquare(ElementFormat::E2M1)),
            (6, QuantScheme::MxSquare(ElementFormat::Int8)),
        ];
        assert_eq!(s.scheme_history(), &want);
        assert_eq!(s.config.scheme, QuantScheme::MxSquare(ElementFormat::Int8));
    }

    #[test]
    fn checkpoint_carries_the_scheme_log() {
        let mut s = TrainSession::new(
            quick_dataset("reacher"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
                dims: Some(vec![32, 16, 32]),
                steps: 0,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        for _ in 0..4 {
            s.step_once();
        }
        s.transition_scheme(QuantScheme::MxSquare(ElementFormat::Int8)).unwrap();
        s.step_once();
        let ck = s.save_checkpoint();
        assert_eq!(ck.config.scheme, QuantScheme::MxSquare(ElementFormat::Int8));
        assert_eq!(ck.scheme_log, vec![(0, "mx-e4m3".to_string()), (4, "mx-int8".to_string())]);
        let resumed = TrainSession::resume(quick_dataset("reacher"), &ck).unwrap();
        assert_eq!(resumed.scheme_history(), s.scheme_history());
        // a log that does not end at the active scheme is rejected
        let mut bad = ck.clone();
        bad.scheme_log.pop();
        let e = TrainSession::resume(quick_dataset("reacher"), &bad).unwrap_err();
        assert!(matches!(e, TrainError::BadCheckpoint { .. }), "{e}");
    }

    #[test]
    fn custom_dims_session_trains() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
                dims: Some(vec![32, 24, 32]),
                steps: 60,
                lr: 3e-3,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        let v0 = s.val_loss();
        s.run();
        assert_eq!(s.dims(), &[32, 24, 32]);
        assert!(s.val_loss() < v0, "{v0} -> {}", s.val_loss());
    }
}
