//! Training sessions: dataset + model + scheme -> loss curves.

use crate::trainer::mlp::{Mlp, MLP_DIMS};
use crate::trainer::qat::{qat_eval, qat_step, QuantScheme};
use crate::util::rng::Pcg64;
use crate::workloads::Dataset;

/// Configuration of a training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub scheme: QuantScheme,
    pub batch_size: usize,
    pub lr: f32,
    pub steps: usize,
    /// Evaluate validation loss every `eval_every` steps.
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            scheme: QuantScheme::Fp32,
            batch_size: 32,
            lr: 1e-3,
            steps: 400,
            eval_every: 20,
            seed: 0xC0FFEE,
        }
    }
}

/// A running (or finished) training session.
pub struct TrainSession {
    pub config: TrainConfig,
    pub mlp: Mlp,
    pub dataset: Dataset,
    /// (step, train_loss) samples.
    pub train_curve: Vec<(usize, f64)>,
    /// (step, val_loss) samples.
    pub val_curve: Vec<(usize, f64)>,
    step: usize,
}

impl TrainSession {
    pub fn new(dataset: Dataset, config: TrainConfig) -> Self {
        let mut rng = Pcg64::with_stream(config.seed, 0x11F);
        let mlp = Mlp::new(&MLP_DIMS, &mut rng);
        Self { config, mlp, dataset, train_curve: Vec::new(), val_curve: Vec::new(), step: 0 }
    }

    /// Current step count.
    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Run one training step; returns the train loss.
    pub fn step_once(&mut self) -> f64 {
        let batch = self.dataset.batch(self.step, self.config.batch_size);
        let loss = qat_step(&mut self.mlp, &batch.x, &batch.y, self.config.scheme, self.config.lr);
        if self.step % self.config.eval_every == 0 {
            self.train_curve.push((self.step, loss));
            self.val_curve.push((self.step, self.val_loss()));
        }
        self.step += 1;
        loss
    }

    /// Run to the configured step budget.
    pub fn run(&mut self) {
        while self.step < self.config.steps {
            self.step_once();
        }
        let v = self.val_loss();
        self.val_curve.push((self.step, v));
    }

    /// Quantized validation loss over the held-out split.
    pub fn val_loss(&self) -> f64 {
        qat_eval(&self.mlp, &self.dataset.val_x, &self.dataset.val_y, self.config.scheme)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::workloads::by_name;

    fn quick_dataset(name: &str) -> Dataset {
        let env = by_name(name).unwrap();
        Dataset::collect(env.as_ref(), 6, 60, 0xDD)
    }

    #[test]
    fn fp32_session_learns_cartpole_dynamics() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig { steps: 300, lr: 2e-3, ..Default::default() },
        );
        let v0 = s.val_loss();
        s.run();
        let v1 = s.val_loss();
        assert!(v1 < v0 * 0.5, "val {v0} -> {v1}");
        assert!(!s.val_curve.is_empty());
    }

    #[test]
    fn mxint8_session_learns_too() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                steps: 300,
                lr: 2e-3,
                ..Default::default()
            },
        );
        let v0 = s.val_loss();
        s.run();
        assert!(s.val_loss() < v0 * 0.7, "{v0} -> {}", s.val_loss());
    }

    #[test]
    fn sessions_are_reproducible() {
        let run = || {
            let mut s = TrainSession::new(
                quick_dataset("reacher"),
                TrainConfig { steps: 50, ..Default::default() },
            );
            s.run();
            s.val_loss()
        };
        assert_eq!(run(), run());
    }
}
