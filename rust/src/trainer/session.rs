//! Training sessions: dataset + model + scheme + backend -> loss curves
//! (and, on the hardware backend, a per-session cost report).

use crate::backend::{make_backend, BackendKind, ExecBackend, HwCostReport};
use crate::gemmcore::memory::{footprint_ours, MlpShape};
use crate::trainer::mlp::{Mlp, MLP_DIMS};
use crate::trainer::qat::{qat_eval, qat_step_with, QuantScheme};
use crate::util::rng::Pcg64;
use crate::workloads::Dataset;

/// Configuration of a training run.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub scheme: QuantScheme,
    /// Which execution backend runs the quantize→GeMM cut points.
    pub backend: BackendKind,
    /// MLP layer dims; `None` = the paper's [`MLP_DIMS`]. Input/output
    /// widths must match the dataset (32/32 for the bundled workloads).
    pub dims: Option<Vec<usize>>,
    pub batch_size: usize,
    pub lr: f32,
    pub steps: usize,
    /// Evaluate validation loss every `eval_every` steps.
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            scheme: QuantScheme::Fp32,
            backend: BackendKind::Fast,
            dims: None,
            batch_size: 32,
            lr: 1e-3,
            steps: 400,
            eval_every: 20,
            seed: 0xC0FFEE,
        }
    }
}

/// A running (or finished) training session.
pub struct TrainSession {
    pub config: TrainConfig,
    pub mlp: Mlp,
    pub dataset: Dataset,
    /// (step, train_loss) samples.
    pub train_curve: Vec<(usize, f64)>,
    /// (step, val_loss) samples.
    pub val_curve: Vec<(usize, f64)>,
    backend: Box<dyn ExecBackend + Send>,
    dims: Vec<usize>,
    step: usize,
}

impl TrainSession {
    /// Build a session, or explain why the scheme/backend combination is
    /// invalid (the hardware backend executes square MX schemes only).
    pub fn try_new(dataset: Dataset, config: TrainConfig) -> Result<Self, String> {
        let backend = make_backend(config.backend, config.scheme)?;
        let dims: Vec<usize> = config.dims.clone().unwrap_or_else(|| MLP_DIMS.to_vec());
        let mut rng = Pcg64::with_stream(config.seed, 0x11F);
        let mlp = Mlp::new(&dims, &mut rng);
        Ok(Self {
            config,
            mlp,
            dataset,
            train_curve: Vec::new(),
            val_curve: Vec::new(),
            backend,
            dims,
            step: 0,
        })
    }

    /// [`TrainSession::try_new`], panicking on an invalid configuration.
    pub fn new(dataset: Dataset, config: TrainConfig) -> Self {
        Self::try_new(dataset, config).expect("invalid train config")
    }

    /// Current step count.
    pub fn step_count(&self) -> usize {
        self.step
    }

    /// MLP layer dims this session trains.
    pub fn dims(&self) -> &[usize] {
        &self.dims
    }

    /// Run one training step; returns the train loss.
    pub fn step_once(&mut self) -> f64 {
        let batch = self.dataset.batch(self.step, self.config.batch_size);
        let loss = qat_step_with(
            &mut self.mlp,
            &batch.x,
            &batch.y,
            self.backend.as_mut(),
            self.config.lr,
        );
        if self.step % self.config.eval_every == 0 {
            self.train_curve.push((self.step, loss));
            self.val_curve.push((self.step, self.val_loss()));
        }
        self.step += 1;
        loss
    }

    /// Run to the configured step budget.
    pub fn run(&mut self) {
        while self.step < self.config.steps {
            self.step_once();
        }
        let v = self.val_loss();
        self.val_curve.push((self.step, v));
    }

    /// Quantized validation loss over the held-out split. Evaluation
    /// runs the fake-quant path — bit-identical values on either backend
    /// (the equivalence contract), and it keeps validation out of the
    /// hardware cost ledger, which accounts *training* steps.
    pub fn val_loss(&self) -> f64 {
        qat_eval(&self.mlp, &self.dataset.val_x, &self.dataset.val_y, self.config.scheme)
    }

    /// The accumulated hardware cost of this session's training steps
    /// (None on the fast backend), with the resident on-chip footprint
    /// filled in from the session's MLP shape and batch size.
    pub fn hw_report(&self) -> Option<HwCostReport> {
        let mut r = self.backend.cost_report()?;
        let shape = MlpShape { dims: self.dims.clone() };
        r.resident_kb = footprint_ours(&shape, self.config.batch_size, r.element).total();
        Some(r)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::workloads::by_name;

    fn quick_dataset(name: &str) -> Dataset {
        let env = by_name(name).unwrap();
        Dataset::collect(env.as_ref(), 6, 60, 0xDD)
    }

    #[test]
    fn fp32_session_learns_cartpole_dynamics() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig { steps: 300, lr: 2e-3, ..Default::default() },
        );
        let v0 = s.val_loss();
        s.run();
        let v1 = s.val_loss();
        assert!(v1 < v0 * 0.5, "val {v0} -> {v1}");
        assert!(!s.val_curve.is_empty());
        assert!(s.hw_report().is_none(), "fast backend accounts no hardware cost");
    }

    #[test]
    fn mxint8_session_learns_too() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::Int8),
                steps: 300,
                lr: 2e-3,
                ..Default::default()
            },
        );
        let v0 = s.val_loss();
        s.run();
        assert!(s.val_loss() < v0 * 0.7, "{v0} -> {}", s.val_loss());
    }

    #[test]
    fn sessions_are_reproducible() {
        let run = || {
            let mut s = TrainSession::new(
                quick_dataset("reacher"),
                TrainConfig { steps: 50, ..Default::default() },
            );
            s.run();
            s.val_loss()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn hw_backend_rejects_non_square_schemes() {
        for scheme in [QuantScheme::Fp32, QuantScheme::MxVector(ElementFormat::Int8)] {
            let r = TrainSession::try_new(
                quick_dataset("cartpole"),
                TrainConfig { scheme, backend: BackendKind::Hardware, ..Default::default() },
            );
            assert!(r.is_err(), "{}", scheme.name());
        }
    }

    #[test]
    fn custom_dims_session_trains() {
        let mut s = TrainSession::new(
            quick_dataset("cartpole"),
            TrainConfig {
                scheme: QuantScheme::MxSquare(ElementFormat::E4M3),
                dims: Some(vec![32, 24, 32]),
                steps: 60,
                lr: 3e-3,
                eval_every: usize::MAX,
                ..Default::default()
            },
        );
        let v0 = s.val_loss();
        s.run();
        assert_eq!(s.dims(), &[32, 24, 32]);
        assert!(s.val_loss() < v0, "{v0} -> {}", s.val_loss());
    }
}
