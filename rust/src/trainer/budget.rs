//! Budgeted training: validation loss under a wall-clock or energy
//! budget *on the simulated hardware* (regenerates Fig. 8).
//!
//! The two accelerators train the same workload, but each step costs
//! them different time (Table IV latency) and energy (Table IV E/op):
//! our core trains MXFP8 ~5x faster per step than Dacapo trains MX6, so
//! under a fixed microsecond budget it completes many more steps — the
//! Fig. 8 (left) effect. Under an energy budget the two are comparable —
//! Fig. 8 (right).

#![forbid(unsafe_code)]

use crate::energy::EnergyModel;
use crate::gemmcore::schedule::{train_step_cycles, PUSHER_DIMS};
use crate::pearray::SystolicArray;
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainConfig, TrainSession};
use crate::workloads::Dataset;

/// Per-step hardware cost of a scheme on its native accelerator.
#[derive(Debug, Clone, Copy)]
pub struct StepCost {
    pub micros: f64,
    pub microjoules: f64,
}

/// Hardware cost of one training step of an MLP with the given layer
/// dims on the scheme's native accelerator (both cycle models are
/// shape-parameterized).
pub fn step_cost_for(scheme: QuantScheme, batch: usize, dims: &[usize]) -> StepCost {
    match scheme {
        QuantScheme::Fp32 => {
            // FP32 reference runs nowhere on these accelerators; cost it
            // as 4x INT8 time (4 bytes vs 1) on our core for context.
            let c = train_step_cycles(batch, dims, crate::mx::ElementFormat::Int8);
            let m = EnergyModel::proposed();
            StepCost {
                micros: 4.0 * c.micros(500.0),
                microjoules: 4.0 * m.core_run_pj(crate::mx::ElementFormat::Int8, c.mul_ops) * 1e-6,
            }
        }
        QuantScheme::MxSquare(f) | QuantScheme::MxVector(f) => {
            let c = train_step_cycles(batch, dims, f);
            let m = EnergyModel::proposed();
            StepCost { micros: c.micros(500.0), microjoules: m.core_run_pj(f, c.mul_ops) * 1e-6 }
        }
        QuantScheme::Dacapo(f) => {
            let arr = SystolicArray::dacapo();
            let c = arr.train_step_cycles(batch, dims, f);
            StepCost {
                micros: c.micros(500.0),
                microjoules: EnergyModel::dacapo_run_pj(f, c.mul_ops) * 1e-6,
            }
        }
    }
}

/// [`step_cost_for`] on the paper MLP (batch-32 pusher shape).
pub fn step_cost(scheme: QuantScheme, batch: usize) -> StepCost {
    step_cost_for(scheme, batch, &PUSHER_DIMS)
}

/// What a budgeted run is limited by.
#[derive(Debug, Clone, Copy)]
pub enum Budget {
    /// Wall-clock on the accelerator, microseconds.
    TimeMicros(f64),
    /// Energy, microjoules.
    EnergyMicrojoules(f64),
}

/// A (budget-consumed, val-loss) curve point.
#[derive(Debug, Clone, Copy)]
pub struct BudgetPoint {
    pub consumed: f64,
    pub steps: usize,
    pub val_loss: f64,
}

/// Train under a hardware budget, sampling the validation loss as the
/// budget is consumed. Returns the sampled curve.
pub fn train_with_budget(
    dataset: Dataset,
    scheme: QuantScheme,
    budget: Budget,
    samples: usize,
    config: TrainConfig,
) -> Vec<BudgetPoint> {
    let cost = step_cost(scheme, config.batch_size);
    let per_step = match budget {
        Budget::TimeMicros(_) => cost.micros,
        Budget::EnergyMicrojoules(_) => cost.microjoules,
    };
    let limit = match budget {
        Budget::TimeMicros(t) => t,
        Budget::EnergyMicrojoules(e) => e,
    };
    let max_steps = (limit / per_step).floor() as usize;
    let mut session = TrainSession::new(dataset, TrainConfig { scheme, ..config });
    let mut curve = Vec::new();
    curve.push(BudgetPoint { consumed: 0.0, steps: 0, val_loss: session.val_loss() });
    if max_steps == 0 {
        return curve;
    }
    let stride = (max_steps / samples.max(1)).max(1);
    for step in 1..=max_steps {
        session.step_once();
        if step % stride == 0 || step == max_steps {
            curve.push(BudgetPoint {
                consumed: step as f64 * per_step,
                steps: step,
                val_loss: session.val_loss(),
            });
        }
    }
    curve
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::dacapo::DacapoFormat;
    use crate::mx::element::ElementFormat;
    use crate::workloads::by_name;

    #[test]
    fn step_cost_is_dims_aware() {
        // a narrow MLP must be strictly cheaper per step than the paper
        // MLP — the fleet prices --hidden sessions with their real shape
        let scheme = QuantScheme::MxSquare(ElementFormat::Int8);
        let small = step_cost_for(scheme, 32, &[32, 24, 32]);
        let paper = step_cost(scheme, 32);
        assert!(small.microjoules < paper.microjoules);
        assert!(small.micros < paper.micros);
    }

    #[test]
    fn step_costs_follow_table4() {
        let ours_fp8 = step_cost(QuantScheme::MxSquare(ElementFormat::E4M3), 32);
        let dacapo_mx6 = step_cost(QuantScheme::Dacapo(DacapoFormat::Mx6), 32);
        // our FP8 step is several times faster than Dacapo's MX6 step
        assert!(dacapo_mx6.micros / ours_fp8.micros > 3.0);
        // energy per step is comparable (same ballpark)
        let ratio = ours_fp8.microjoules / dacapo_mx6.microjoules;
        assert!((0.5..2.0).contains(&ratio), "{ratio}");
    }

    #[test]
    fn time_budget_gives_ours_more_steps() {
        let env = by_name("pusher").unwrap();
        let ds = Dataset::collect(env.as_ref(), 4, 40, 1);
        let cfg = TrainConfig { steps: 0, eval_every: usize::MAX, ..Default::default() };
        let ours = train_with_budget(
            ds.clone(),
            QuantScheme::MxSquare(ElementFormat::E4M3),
            Budget::TimeMicros(1000.0),
            4,
            cfg.clone(),
        );
        let theirs = train_with_budget(
            ds,
            QuantScheme::Dacapo(DacapoFormat::Mx6),
            Budget::TimeMicros(1000.0),
            4,
            cfg,
        );
        let ours_steps = ours.last().unwrap().steps;
        let theirs_steps = theirs.last().unwrap().steps;
        assert!(
            ours_steps > 3 * theirs_steps,
            "ours {ours_steps} vs dacapo {theirs_steps}"
        );
    }

    #[test]
    fn budget_curve_is_monotone_in_consumption() {
        let env = by_name("pusher").unwrap();
        let ds = Dataset::collect(env.as_ref(), 3, 30, 2);
        let curve = train_with_budget(
            ds,
            QuantScheme::MxSquare(ElementFormat::Int8),
            Budget::EnergyMicrojoules(200.0),
            5,
            TrainConfig { eval_every: usize::MAX, ..Default::default() },
        );
        for w in curve.windows(2) {
            assert!(w[1].consumed >= w[0].consumed);
        }
    }
}
