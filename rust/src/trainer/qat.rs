//! Quantization-aware training step (paper Fig. 5).
//!
//! One [`QuantScheme`] selects what the training graph quantizes and
//! how: the FP32 baseline, an MX format over square (ours) or vector
//! (OCP/Dacapo-style) blocks, or Dacapo's MX9/6/4. Quantization is
//! applied at the Fig. 5 cut points — weights entering each GeMM,
//! activations entering each GeMM, and backprop errors entering the
//! error/weight-gradient GeMMs — with FP32 master weights (standard QAT).

#![forbid(unsafe_code)]

use crate::backend::{ExecBackend, FakeQuantBackend};
use crate::mx::dacapo::{DacapoFormat, DacapoTensor};
use crate::mx::element::ElementFormat;
use crate::mx::tensor::{fake_quant_mat_fast, Layout};
use crate::trainer::mlp::{Mlp, MlpGrads};
use crate::util::mat::Mat;

/// What numeric scheme the training step runs under.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantScheme {
    /// Unquantized FP32 baseline.
    Fp32,
    /// Our design: MX element format over 8x8 square blocks.
    MxSquare(ElementFormat),
    /// OCP-standard 32-element vector blocks (requantizes transposes).
    MxVector(ElementFormat),
    /// Dacapo baseline: MX9/6/4 vector blocks.
    Dacapo(DacapoFormat),
}

impl QuantScheme {
    pub fn name(&self) -> String {
        match self {
            QuantScheme::Fp32 => "fp32".into(),
            QuantScheme::MxSquare(f) => format!("mx-{}", f.name()),
            QuantScheme::MxVector(f) => format!("mxvec-{}", f.name()),
            QuantScheme::Dacapo(f) => f.name().into(),
        }
    }

    /// Parse CLI names: `fp32`, `mx9/mx6/mx4` (Dacapo), `mxvec-<fmt>`
    /// (OCP vector grouping), `mx-<fmt>` or bare `<fmt>` (square
    /// grouping) — the exact inverse of [`QuantScheme::name`], so every
    /// scheme the code can name is reachable from the CLI (round-trip
    /// asserted below).
    pub fn parse(s: &str) -> Option<QuantScheme> {
        match s {
            "fp32" => Some(QuantScheme::Fp32),
            "mx9" => Some(QuantScheme::Dacapo(DacapoFormat::Mx9)),
            "mx6" => Some(QuantScheme::Dacapo(DacapoFormat::Mx6)),
            "mx4" => Some(QuantScheme::Dacapo(DacapoFormat::Mx4)),
            _ => {
                if let Some(rest) = s.strip_prefix("mxvec-") {
                    ElementFormat::parse(rest).map(QuantScheme::MxVector)
                } else if let Some(rest) = s.strip_prefix("mx-") {
                    ElementFormat::parse(rest).map(QuantScheme::MxSquare)
                } else {
                    ElementFormat::parse(s).map(QuantScheme::MxSquare)
                }
            }
        }
    }

    /// Fake-quantize a tensor under this scheme.
    pub fn quant(&self, m: &Mat) -> Mat {
        match self {
            QuantScheme::Fp32 => m.clone(),
            QuantScheme::MxSquare(f) => fake_quant_mat_fast(m, *f, Layout::Square8x8),
            QuantScheme::MxVector(f) => fake_quant_mat_fast(m, *f, Layout::Vector32),
            QuantScheme::Dacapo(f) => DacapoTensor::fake_quant(m, *f),
        }
    }

    /// Fake-quantize a tensor that is consumed *transposed*. Square
    /// blocks quantize once and permute (free); vector-grouped schemes
    /// must requantize along the other direction — the Fig. 5(a) cost.
    pub fn quant_for_transpose(&self, m: &Mat) -> Mat {
        match self {
            QuantScheme::Fp32 => m.clone(),
            QuantScheme::MxSquare(f) => {
                // square blocks: the block-permute transpose is value-
                // identical to the forward quantization (asserted in
                // tests), so the fast path applies directly
                fake_quant_mat_fast(m, *f, Layout::Square8x8)
            }
            QuantScheme::MxVector(f) => {
                // requantize the transposed matrix (second grouping)
                fake_quant_mat_fast(&m.transpose(), *f, Layout::Vector32).transpose()
            }
            QuantScheme::Dacapo(f) => DacapoTensor::fake_quant(&m.transpose(), *f).transpose(),
        }
    }

    /// Element format for hardware cost accounting (None for FP32 and
    /// Dacapo, which use their own models).
    pub fn element(&self) -> Option<ElementFormat> {
        match self {
            QuantScheme::MxSquare(f) | QuantScheme::MxVector(f) => Some(*f),
            _ => None,
        }
    }
}

/// One quantization-aware training step: quantized forward + backward,
/// Adam on FP32 masters. Returns the (quantized-forward) training loss.
///
/// Convenience over [`qat_step_with`] with a transient
/// [`FakeQuantBackend`]; sessions hold a persistent backend instead so
/// its scratch buffers (and, for the hardware backend, its cost ledger)
/// survive across steps.
pub fn qat_step(mlp: &mut Mlp, x: &Mat, y: &Mat, scheme: QuantScheme, lr: f32) -> f64 {
    let mut be = FakeQuantBackend::new(scheme);
    qat_step_with(mlp, x, y, &mut be, lr)
}

/// One QAT step through an execution backend (fake-quant or hardware).
pub fn qat_step_with(mlp: &mut Mlp, x: &Mat, y: &Mat, be: &mut dyn ExecBackend, lr: f32) -> f64 {
    be.begin_step();
    let (tape, grads) = qat_forward_backward_with(mlp, x, y, be);
    let loss = Mlp::mse_loss(&tape.output, y);
    mlp.adam_step(&grads, lr);
    loss
}

/// Forward + backward without the update (shared with tests/session).
pub fn qat_forward_backward(
    mlp: &Mlp,
    x: &Mat,
    y: &Mat,
    scheme: QuantScheme,
) -> (crate::trainer::mlp::Tape, MlpGrads) {
    let mut be = FakeQuantBackend::new(scheme);
    be.begin_step();
    qat_forward_backward_with(mlp, x, y, &mut be)
}

/// Forward + backward through an execution backend. The error GeMM
/// consumes Wᵀ: square blocks reuse the forward quantized copy (free
/// block-permutation transpose), vector schemes requantize — exactly
/// the paper's Fig. 5 point, now enforced inside each backend.
pub fn qat_forward_backward_with(
    mlp: &Mlp,
    x: &Mat,
    y: &Mat,
    be: &mut dyn ExecBackend,
) -> (crate::trainer::mlp::Tape, MlpGrads) {
    let tape = mlp.forward_exec(x, be);
    let grads = mlp.backward_exec(&tape, y, be);
    (tape, grads)
}

/// Quantized validation loss (quantized weights + activations, as the
/// deployed accelerator would run inference). Evaluates under the
/// scheme's own GeMM value semantics ([`crate::backend::GemmKernel`]):
/// square MX schemes use the block-ordered accumulation the packed and
/// hardware datapaths compute, so eval and training share one
/// definition of "the value of this GeMM".
pub fn qat_eval(mlp: &Mlp, x: &Mat, y: &Mat, scheme: QuantScheme) -> f64 {
    let mut be = crate::backend::HookBackend::for_scheme(
        scheme,
        |_, w: &Mat| scheme.quant(w),
        |_, a: &Mat| scheme.quant(a),
        |_, e: &Mat| e.clone(),
    );
    let tape = mlp.forward_exec(x, &mut be);
    Mlp::mse_loss(&tape.output, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn toy_problem(rng: &mut Pcg64) -> (Mat, Mat) {
        let x = Mat::randn(64, 32, 1.0, rng);
        let y = Mat::from_fn(64, 32, |r, c| {
            if c < 8 {
                (x.at(r, c) * 0.8 + x.at(r, c + 1)).tanh() * 0.5
            } else {
                0.0
            }
        });
        (x, y)
    }

    #[test]
    fn fp32_scheme_is_identity() {
        let mut rng = Pcg64::new(1);
        let m = Mat::randn(16, 16, 1.0, &mut rng);
        assert_eq!(QuantScheme::Fp32.quant(&m), m);
    }

    #[test]
    fn square_transpose_quant_is_consistent() {
        // quant_for_transpose == quant for square blocks (free transpose)
        let mut rng = Pcg64::new(2);
        let m = Mat::randn(32, 32, 1.0, &mut rng);
        let s = QuantScheme::MxSquare(ElementFormat::Int8);
        assert_eq!(s.quant(&m).data, s.quant_for_transpose(&m).data);
    }

    #[test]
    fn vector_transpose_quant_differs() {
        let mut rng = Pcg64::new(3);
        let m = Mat::from_fn(32, 32, |r, _| rng.normal_f32() * ((r % 5) as f32 - 2.0).exp2());
        let s = QuantScheme::MxVector(ElementFormat::Int8);
        assert_ne!(s.quant(&m).data, s.quant_for_transpose(&m).data);
    }

    #[test]
    fn all_schemes_train_toy_problem() {
        let mut rng = Pcg64::new(4);
        let (x, y) = toy_problem(&mut rng);
        for scheme in [
            QuantScheme::Fp32,
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxSquare(ElementFormat::E4M3),
            QuantScheme::MxSquare(ElementFormat::E5M2),
            QuantScheme::Dacapo(DacapoFormat::Mx9),
        ] {
            let mut mlp = Mlp::new(&[32, 64, 64, 32], &mut rng);
            let l0 = qat_eval(&mlp, &x, &y, scheme);
            for _ in 0..200 {
                qat_step(&mut mlp, &x, &y, scheme, 2e-3);
            }
            let l1 = qat_eval(&mlp, &x, &y, scheme);
            assert!(l1 < l0 * 0.5, "{}: {l0} -> {l1}", scheme.name());
        }
    }

    #[test]
    fn coarser_formats_train_worse() {
        // E2M1 (4-bit) should converge to a worse loss than FP32 on the
        // same problem/seed — the precision-accuracy tradeoff of Fig. 2.
        let mut rng = Pcg64::new(5);
        let (x, y) = toy_problem(&mut rng);
        let run = |scheme: QuantScheme| {
            let mut r2 = Pcg64::new(99);
            let mut mlp = Mlp::new(&[32, 64, 64, 32], &mut r2);
            for _ in 0..300 {
                qat_step(&mut mlp, &x, &y, scheme, 2e-3);
            }
            qat_eval(&mlp, &x, &y, QuantScheme::Fp32)
        };
        let l_fp32 = run(QuantScheme::Fp32);
        let l_fp4 = run(QuantScheme::MxSquare(ElementFormat::E2M1));
        assert!(l_fp4 > l_fp32, "fp32 {l_fp32} vs fp4 {l_fp4}");
    }

    #[test]
    fn scheme_parsing() {
        assert_eq!(QuantScheme::parse("fp32"), Some(QuantScheme::Fp32));
        assert_eq!(
            QuantScheme::parse("e4m3"),
            Some(QuantScheme::MxSquare(ElementFormat::E4M3))
        );
        assert_eq!(
            QuantScheme::parse("mxvec-int8"),
            Some(QuantScheme::MxVector(ElementFormat::Int8))
        );
        assert_eq!(QuantScheme::parse("mx9"), Some(QuantScheme::Dacapo(DacapoFormat::Mx9)));
        assert_eq!(QuantScheme::parse("nope"), None);
        assert_eq!(QuantScheme::parse("mxvec-nope"), None);
    }

    #[test]
    fn scheme_name_parse_round_trip_over_all_schemes() {
        // the name()/parse() asymmetry regression: every nameable scheme
        // (including the previously unreachable mxvec-* family) must
        // round-trip through its CLI name.
        let mut all = vec![QuantScheme::Fp32];
        for f in crate::mx::ALL_ELEMENT_FORMATS {
            all.push(QuantScheme::MxSquare(f));
            all.push(QuantScheme::MxVector(f));
        }
        for d in [DacapoFormat::Mx9, DacapoFormat::Mx6, DacapoFormat::Mx4] {
            all.push(QuantScheme::Dacapo(d));
        }
        for scheme in all {
            let name = scheme.name();
            assert_eq!(QuantScheme::parse(&name), Some(scheme), "{name}");
        }
    }
}
