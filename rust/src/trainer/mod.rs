//! Training: the dynamics MLP, MX quantization-aware training, and
//! budgeted (time / energy) training runs.
//!
//! Two interchangeable backends execute the train step:
//!
//! * the **native golden path** ([`mlp`], [`qat`]): f32 forward/backward
//!   with MX fake-quantization at the Fig. 5 cut points — fast, pure
//!   Rust, used by the Fig. 2 / Fig. 8 experiment harnesses;
//! * the **XLA runtime path** (`crate::runtime`): the same step AOT-
//!   lowered from JAX (`python/compile/`) and executed through PJRT —
//!   the production path proving the three-layer stack composes
//!   (`examples/train_pusher.rs`).
//!
//! Both backends implement the same quantization semantics; a pytest on
//! the Python side and `session::tests` on this side pin them together.

pub mod batched;
pub mod budget;
pub mod mlp;
pub mod qat;
pub mod session;

pub use batched::{BatchedTrainer, TrainOutcome};
pub use mlp::{Mlp, MlpGrads};
pub use qat::QuantScheme;
pub use session::{TrainConfig, TrainSession};
