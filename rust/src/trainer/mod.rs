//! Training: the dynamics MLP, MX quantization-aware training, and
//! budgeted (time / energy) training runs.
//!
//! The train step executes through a pluggable [`crate::backend`]
//! seam — [`Mlp::forward_exec`]/[`Mlp::backward_exec`] drive an
//! `ExecBackend` at every Fig. 5 quantize→GeMM cut point:
//!
//! * the **fake-quant backend** (default): f32 forward/backward with
//!   buffer-reusing MX fake-quantization — fast, pure Rust, used by the
//!   Fig. 2 / Fig. 8 experiment harnesses;
//! * the **hardware backend** (`--backend hw`): the same values,
//!   bit-identically, executed through the cycle/event-accounted
//!   `GemmCore` simulation, yielding a per-session `HwCostReport`;
//! * the **XLA runtime path** (`crate::runtime`): the step AOT-lowered
//!   from JAX (`python/compile/`) and executed through PJRT
//!   (`examples/train_pusher.rs`).
//!
//! All paths implement the same quantization semantics; a pytest on the
//! Python side, `session::tests`, and `tests/backend.rs` pin them
//! together.
//!
//! Sessions are checkpointable ([`checkpoint`]): the MX-quantized weight
//! image (square groups stored single-copy) plus a bit-exact FP32
//! master/optimizer sidecar, so a resumed session is indistinguishable
//! from one that never paused — the substrate of the continual-learning
//! fleet layer ([`crate::fleet`]).
//!
//! Sessions are also **precision-schedulable** ([`policy`]): a
//! [`PrecisionPolicy`] (step schedule or Dacapo-style loss watchdog)
//! can switch the active MX format at any step boundary via
//! [`TrainSession::transition_scheme`]. Transitions requantize from the
//! FP32 masters — never format-to-format — so every segment is
//! bit-identical to a fresh session at that format with the same
//! master/Adam state (DESIGN.md §8, `tests/backend.rs`).

pub mod batched;
pub mod budget;
pub mod checkpoint;
pub mod mlp;
pub mod policy;
pub mod qat;
pub mod session;

pub use batched::{BatchedTrainer, TrainOutcome};
pub use checkpoint::Checkpoint;
pub use mlp::{Mlp, MlpGrads};
pub use policy::{PrecisionPolicy, Watchdog};
pub use qat::QuantScheme;
pub use session::{TrainConfig, TrainError, TrainSession};

pub use crate::backend::BackendKind;
