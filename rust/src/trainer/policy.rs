//! Runtime precision scheduling: *when* a session changes MX format.
//!
//! The paper builds precision-*scalable* hardware — all six MX element
//! formats on one datapath — but scalability only pays off if the
//! trainer actually changes precision while learning, the way Dacapo
//! progressively adapts precision during continual learning. A
//! [`PrecisionPolicy`] is that decision logic, factored out of the
//! session: it inspects the step index and the live loss stream and
//! says which [`QuantScheme`] the next step should run under. The
//! session applies the decision through
//! [`crate::trainer::TrainSession::transition_scheme`], which drives
//! [`crate::backend::ExecBackend::transition`] — every transition
//! requantizes from the FP32 masters (never format-to-format), so a
//! transition is bit-identical to starting fresh at the new format with
//! the same master/Adam state (DESIGN.md §8, `tests/backend.rs`).
//!
//! Three policy families:
//!
//! * [`PrecisionPolicy::Static`] — never transitions (the pre-policy
//!   behavior; `TrainSession::run` is this policy).
//! * [`PrecisionPolicy::Schedule`] — step-indexed transitions, e.g.
//!   "e2m1 until step 200, int8 after": the *planned* curriculum, cheap
//!   coarse steps early, fine steps late. Stateless: the decision is a
//!   pure function of the step index, which is what makes a
//!   checkpoint-resumed session re-join its schedule bitwise.
//! * [`PrecisionPolicy::Adaptive`] — a Dacapo-style [`Watchdog`] over
//!   the training-loss stream: *demotes* precision (coarser format,
//!   cheaper steps) while training is stable, *promotes* it (finer
//!   format) when the loss spikes or diverges.

#![forbid(unsafe_code)]

use crate::backend::BackendKind;
use crate::trainer::qat::QuantScheme;

/// A step-indexed schedule entry: from `at_step` on, run `scheme`.
pub type ScheduleEntry = (usize, QuantScheme);

/// Decides which scheme each training step runs under.
#[derive(Debug, Clone, Default)]
pub enum PrecisionPolicy {
    /// Keep the session's configured scheme forever.
    #[default]
    Static,
    /// Step-indexed transitions, ascending by step. The entry with the
    /// largest `at_step <= step` is active; before the first entry the
    /// session's configured scheme runs.
    Schedule(Vec<ScheduleEntry>),
    /// Loss-watchdog adaptation over a precision ladder.
    Adaptive(Watchdog),
}

impl PrecisionPolicy {
    /// Build a validated step schedule (entries sorted, none empty,
    /// step indices unique — a duplicate would silently shadow the
    /// earlier entry while `name()` still advertised both).
    pub fn schedule(mut entries: Vec<ScheduleEntry>) -> Result<PrecisionPolicy, String> {
        if entries.is_empty() {
            return Err("a precision schedule needs at least one step:scheme entry".into());
        }
        entries.sort_by_key(|&(step, _)| step);
        if let Some(w) = entries.windows(2).find(|w| w[0].0 == w[1].0) {
            return Err(format!(
                "duplicate schedule step {}: `{}` and `{}` cannot both start there",
                w[0].0,
                w[0].1.name(),
                w[1].1.name()
            ));
        }
        Ok(PrecisionPolicy::Schedule(entries))
    }

    /// Parse a CLI policy spec:
    ///
    /// * `static` — no transitions;
    /// * `<step>:<scheme>[,<step>:<scheme>...]` — a step schedule, e.g.
    ///   `0:mx-e2m1,200:mx-int8` (scheme names as in `--scheme`);
    /// * `adaptive:<scheme>><scheme>[>...]` — a watchdog over the given
    ///   ladder, highest precision first, e.g.
    ///   `adaptive:mx-int8>mx-e2m3>mx-e2m1` (default knobs).
    pub fn parse(spec: &str) -> Result<PrecisionPolicy, String> {
        let spec = spec.trim();
        if spec == "static" {
            return Ok(PrecisionPolicy::Static);
        }
        if let Some(ladder_spec) = spec.strip_prefix("adaptive:") {
            let mut ladder = Vec::new();
            for name in ladder_spec.split('>') {
                let name = name.trim();
                let scheme = QuantScheme::parse(name)
                    .ok_or_else(|| format!("unknown scheme `{name}` in policy `{spec}`"))?;
                ladder.push(scheme);
            }
            return Ok(PrecisionPolicy::Adaptive(Watchdog::new(ladder)?));
        }
        let mut entries = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            let (step, name) = part
                .split_once(':')
                .ok_or_else(|| format!("policy entry `{part}` is not <step>:<scheme>"))?;
            let step: usize = step
                .trim()
                .parse()
                .map_err(|_| format!("bad step index in policy entry `{part}`"))?;
            let scheme = QuantScheme::parse(name.trim())
                .ok_or_else(|| format!("unknown scheme `{name}` in policy entry `{part}`"))?;
            entries.push((step, scheme));
        }
        PrecisionPolicy::schedule(entries)
    }

    /// Every scheme this policy can ever select (for up-front backend
    /// validation — a fleet rejects a policy its backend can't run
    /// instead of panicking mid-quantum).
    pub fn schemes(&self) -> Vec<QuantScheme> {
        match self {
            PrecisionPolicy::Static => Vec::new(),
            PrecisionPolicy::Schedule(entries) => entries.iter().map(|&(_, s)| s).collect(),
            PrecisionPolicy::Adaptive(w) => w.ladder.clone(),
        }
    }

    /// Check every reachable scheme against a backend kind.
    pub fn validate(&self, backend: BackendKind) -> Result<(), String> {
        for scheme in self.schemes() {
            if let Err(reason) = crate::backend::make_backend(backend, scheme) {
                let (s, b) = (scheme.name(), backend.name());
                return Err(format!("policy scheme `{s}` unsupported on `{b}`: {reason}"));
            }
        }
        Ok(())
    }

    /// Check the scheme a session will *start* under. An adaptive
    /// ladder must contain the start scheme: "demote"/"promote" are
    /// rungs relative to the current format, which is undefined for a
    /// format the ladder doesn't name (the watchdog would park forever
    /// — make that a loud configuration error instead). Static and
    /// step-scheduled policies accept any start scheme.
    pub fn validate_start(&self, start: QuantScheme) -> Result<(), String> {
        match self {
            PrecisionPolicy::Adaptive(w) if !w.ladder.contains(&start) => Err(format!(
                "adaptive ladder `{}` does not contain the session's start scheme `{}`",
                self.name(),
                start.name()
            )),
            _ => Ok(()),
        }
    }

    /// Which scheme the step about to run (`step`) should use, or
    /// `None` to keep `current`. Called **before** the step executes.
    pub fn decide(&mut self, step: usize, current: QuantScheme) -> Option<QuantScheme> {
        match self {
            PrecisionPolicy::Static => None,
            PrecisionPolicy::Schedule(entries) => entries
                .iter()
                .rev()
                .find(|&&(at, _)| at <= step)
                .map(|&(_, scheme)| scheme)
                .filter(|&scheme| scheme != current),
            PrecisionPolicy::Adaptive(w) => w.decide(current),
        }
    }

    /// Feed the training loss of the step that just ran (the adaptive
    /// watchdog's signal; a no-op for the stateless policies).
    pub fn observe(&mut self, loss: f64) {
        if let PrecisionPolicy::Adaptive(w) = self {
            w.observe(loss);
        }
    }

    /// Short display name for tables and reports.
    pub fn name(&self) -> String {
        match self {
            PrecisionPolicy::Static => "static".into(),
            PrecisionPolicy::Schedule(entries) => {
                let parts: Vec<String> =
                    entries.iter().map(|(s, sch)| format!("{s}:{}", sch.name())).collect();
                parts.join(",")
            }
            PrecisionPolicy::Adaptive(w) => {
                let parts: Vec<String> = w.ladder.iter().map(|s| s.name()).collect();
                format!("adaptive:{}", parts.join(">"))
            }
        }
    }
}

/// Dacapo-style loss watchdog over a precision ladder.
///
/// The ladder is ordered **highest precision first** (index 0). After
/// every step the watchdog records the training loss; once it has two
/// full windows at the current rung it compares the mean loss of the
/// older window against the newer one:
///
/// * **spike** — the newer window is `spike_tol` worse: training is
///   diverging at this precision; *promote* (move one rung up, toward
///   finer formats).
/// * **plateau** — the newer window improved by less than
///   `plateau_tol`: training is stable; *demote* (one rung down, toward
///   coarser/cheaper formats) and bank the throughput.
///
/// After any rung change the loss history is cleared and a `cooldown`
/// of steps must pass before the next decision, so the watchdog judges
/// each format on losses produced *under that format*.
#[derive(Debug, Clone)]
pub struct Watchdog {
    /// Precision ladder, highest precision first.
    pub ladder: Vec<QuantScheme>,
    /// Window length (steps) for the plateau/spike comparison.
    pub window: usize,
    /// Relative improvement below which the window pair is a plateau.
    pub plateau_tol: f64,
    /// Relative worsening above which the window pair is a spike.
    pub spike_tol: f64,
    /// Steps to hold after a transition before judging again.
    pub cooldown: usize,
    rung: usize,
    since_change: usize,
    losses: Vec<f64>,
}

impl Watchdog {
    /// Watchdog with default knobs (window 32, plateau 2%, spike 20%,
    /// cooldown one window). The ladder must name at least two rungs.
    pub fn new(ladder: Vec<QuantScheme>) -> Result<Watchdog, String> {
        if ladder.len() < 2 {
            return Err("an adaptive ladder needs at least two schemes (high>low)".into());
        }
        // a duplicate rung would let the demote branch land on a
        // *higher*-precision format (e.g. int8>e2m1>int8) — the exact
        // inversion the rung logic exists to prevent
        for (i, s) in ladder.iter().enumerate() {
            if ladder[..i].contains(s) {
                return Err(format!("scheme `{}` appears twice in the adaptive ladder", s.name()));
            }
        }
        Ok(Watchdog {
            ladder,
            window: 32,
            plateau_tol: 0.02,
            spike_tol: 0.2,
            cooldown: 32,
            rung: 0,
            since_change: 0,
            losses: Vec::new(),
        })
    }

    /// Current rung index (0 = highest precision).
    pub fn rung(&self) -> usize {
        self.rung
    }

    fn observe(&mut self, loss: f64) {
        self.since_change += 1;
        self.losses.push(loss);
        let cap = 2 * self.window;
        if self.losses.len() > cap {
            let drop = self.losses.len() - cap;
            self.losses.drain(..drop);
        }
    }

    fn mean(xs: &[f64]) -> f64 {
        xs.iter().sum::<f64>() / xs.len() as f64
    }

    fn decide(&mut self, current: QuantScheme) -> Option<QuantScheme> {
        // sync the rung to the scheme the session is actually running:
        // a session may start (or resume) at any rung of the ladder,
        // and "demote"/"promote" are relative to the *current* rung —
        // otherwise a plateau at the bottom rung could fire a
        // precision *increase* out of the demotion branch. A format the
        // ladder doesn't name has no rung: park rather than act on a
        // stale index (`validate_start` rejects that setup up front).
        if self.ladder.get(self.rung) != Some(&current) {
            match self.ladder.iter().position(|&s| s == current) {
                Some(pos) => self.rung = pos,
                None => return None,
            }
        }
        if self.since_change < self.cooldown || self.losses.len() < 2 * self.window {
            return None;
        }
        let split = self.losses.len() - self.window;
        let older = Self::mean(&self.losses[split - self.window..split]);
        let newer = Self::mean(&self.losses[split..]);
        if !older.is_finite() || !newer.is_finite() || older <= 0.0 {
            return None;
        }
        let next_rung = if newer > older * (1.0 + self.spike_tol) {
            // diverging: promote toward precision (if any rung is left)
            self.rung.saturating_sub(1)
        } else if newer > older * (1.0 - self.plateau_tol) {
            // plateaued: demote toward cheap formats
            (self.rung + 1).min(self.ladder.len() - 1)
        } else {
            return None; // still improving at a healthy rate
        };
        if next_rung == self.rung && self.ladder[next_rung] == current {
            // at the end of the ladder already; re-judge after a window
            self.losses.clear();
            self.since_change = 0;
            return None;
        }
        self.rung = next_rung;
        self.losses.clear();
        self.since_change = 0;
        let target = self.ladder[self.rung];
        if target == current {
            None
        } else {
            Some(target)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;

    fn sq(f: ElementFormat) -> QuantScheme {
        QuantScheme::MxSquare(f)
    }

    #[test]
    fn parse_round_trips_the_three_families() {
        assert!(matches!(PrecisionPolicy::parse("static").unwrap(), PrecisionPolicy::Static));
        let p = PrecisionPolicy::parse("0:mx-e2m1,200:mx-int8").unwrap();
        match &p {
            PrecisionPolicy::Schedule(e) => {
                assert_eq!(e.len(), 2);
                assert_eq!(e[0], (0, sq(ElementFormat::E2M1)));
                assert_eq!(e[1], (200, sq(ElementFormat::Int8)));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(PrecisionPolicy::parse(&p.name()).unwrap().name(), p.name());
        let a = PrecisionPolicy::parse("adaptive:mx-int8>mx-e2m3>mx-e2m1").unwrap();
        match &a {
            PrecisionPolicy::Adaptive(w) => assert_eq!(w.ladder.len(), 3),
            other => panic!("{other:?}"),
        }
        assert_eq!(PrecisionPolicy::parse(&a.name()).unwrap().name(), a.name());
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        for bad in [
            "",
            "0:nope",
            "x:int8",
            "0=int8",
            "adaptive:int8",
            "adaptive:int8>nope",
            "100:mx-int8,100:mx-e2m1",          // duplicate step would silently shadow
            "adaptive:mx-int8>mx-e2m1>mx-int8", // duplicate rung inverts demote
        ] {
            assert!(PrecisionPolicy::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn adaptive_start_must_be_on_the_ladder() {
        let p = PrecisionPolicy::parse("adaptive:mx-int8>mx-e2m1").unwrap();
        assert!(p.validate_start(sq(ElementFormat::Int8)).is_ok());
        assert!(p.validate_start(sq(ElementFormat::E2M1)).is_ok());
        let e = p.validate_start(sq(ElementFormat::E4M3)).unwrap_err();
        assert!(e.contains("mx-e4m3"), "{e}");
        // the stateless policies accept any start scheme
        assert!(PrecisionPolicy::Static.validate_start(sq(ElementFormat::E4M3)).is_ok());
        let s = PrecisionPolicy::parse("5:mx-int8").unwrap();
        assert!(s.validate_start(sq(ElementFormat::E4M3)).is_ok());
    }

    #[test]
    fn schedule_decides_by_step_and_is_resumable() {
        let mut p = PrecisionPolicy::parse("10:mx-int8,20:mx-e2m1").unwrap();
        let start = sq(ElementFormat::E4M3);
        assert_eq!(p.decide(0, start), None, "before the first entry");
        assert_eq!(p.decide(9, start), None);
        assert_eq!(p.decide(10, start), Some(sq(ElementFormat::Int8)));
        // stateless: a resumed session mid-schedule gets the same answer
        let mut q = PrecisionPolicy::parse("10:mx-int8,20:mx-e2m1").unwrap();
        assert_eq!(q.decide(15, sq(ElementFormat::Int8)), None, "already active");
        assert_eq!(q.decide(25, sq(ElementFormat::Int8)), Some(sq(ElementFormat::E2M1)));
    }

    #[test]
    fn watchdog_demotes_on_plateau_and_promotes_on_spike() {
        let ladder = vec![sq(ElementFormat::Int8), sq(ElementFormat::E2M1)];
        let mut w = Watchdog::new(ladder).unwrap();
        w.window = 4;
        w.cooldown = 4;
        let mut p = PrecisionPolicy::Adaptive(w);
        let current = sq(ElementFormat::Int8);
        // flat loss stream -> plateau -> demote to the coarse rung
        let mut demoted = None;
        for step in 0..32 {
            if let Some(next) = p.decide(step, current) {
                demoted = Some(next);
                break;
            }
            p.observe(1.0);
        }
        assert_eq!(demoted, Some(sq(ElementFormat::E2M1)), "plateau must demote");
        // now a diverging stream at the coarse rung -> promote back
        let current = sq(ElementFormat::E2M1);
        let mut promoted = None;
        for step in 0..64 {
            if let Some(next) = p.decide(step, current) {
                promoted = Some(next);
                break;
            }
            p.observe(1.0 + step as f64 * 0.5);
        }
        assert_eq!(promoted, Some(sq(ElementFormat::Int8)), "spike must promote");
    }

    #[test]
    fn watchdog_syncs_its_rung_to_the_running_scheme() {
        // session starts at the *bottom* rung: a plateau must park
        // there, not fire the demotion branch relative to a stale
        // rung-0 index (which would raise precision and cost)
        let ladder = vec![sq(ElementFormat::Int8), sq(ElementFormat::E2M1)];
        let mut w = Watchdog::new(ladder).unwrap();
        w.window = 4;
        w.cooldown = 4;
        let mut p = PrecisionPolicy::Adaptive(w);
        let current = sq(ElementFormat::E2M1);
        for step in 0..32 {
            assert_eq!(p.decide(step, current), None, "step {step}: plateau at bottom rung");
            p.observe(1.0);
        }
    }

    #[test]
    fn watchdog_keeps_quiet_while_improving() {
        let ladder = vec![sq(ElementFormat::Int8), sq(ElementFormat::E2M1)];
        let mut w = Watchdog::new(ladder).unwrap();
        w.window = 4;
        w.cooldown = 4;
        let mut p = PrecisionPolicy::Adaptive(w);
        let current = sq(ElementFormat::Int8);
        for step in 0..40 {
            assert_eq!(p.decide(step, current), None, "step {step}");
            p.observe(100.0 / (step + 1) as f64); // healthy descent
        }
    }

    #[test]
    fn validate_catches_backend_mismatches() {
        let p = PrecisionPolicy::parse("0:mx-e2m1,10:mxvec-int8").unwrap();
        assert!(p.validate(BackendKind::Fast).is_ok());
        let e = p.validate(BackendKind::Packed).unwrap_err();
        assert!(e.contains("mxvec-int8"), "{e}");
        assert!(p.validate(BackendKind::Hardware).is_err());
        assert!(PrecisionPolicy::Static.validate(BackendKind::Packed).is_ok());
    }
}
