//! The dynamics-model MLP: f32 golden forward/backward + Adam.
//!
//! Architecture per the paper §V-C: 4 fully-connected layers, input and
//! output width 32, hidden width 256, ReLU activations, MSE loss on
//! normalized delta-state targets.

#![forbid(unsafe_code)]

use crate::backend::{ExecBackend, HookBackend};
use crate::util::mat::Mat;
use crate::util::rng::Pcg64;

/// Layer dims of the paper's MLP.
pub const MLP_DIMS: [usize; 5] = [32, 256, 256, 256, 32];

/// The paper MLP's shape with a custom hidden width — same depth and IO
/// widths, `hidden`-wide hidden layers (the CLI `--hidden` override,
/// shared by `train` and `fleet`).
pub fn hidden_dims(hidden: usize) -> Vec<usize> {
    let mut dims = MLP_DIMS.to_vec();
    for d in &mut dims[1..MLP_DIMS.len() - 1] {
        *d = hidden;
    }
    dims
}

/// A fully-connected network (weights `[din, dout]`, row-major).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub weights: Vec<Mat>,
    pub biases: Vec<Vec<f32>>,
    // Adam state
    m_w: Vec<Mat>,
    v_w: Vec<Mat>,
    m_b: Vec<Vec<f32>>,
    v_b: Vec<Vec<f32>>,
    pub step: u64,
}

/// Gradients matching an [`Mlp`]'s parameters.
#[derive(Debug, Clone)]
pub struct MlpGrads {
    pub d_weights: Vec<Mat>,
    pub d_biases: Vec<Vec<f32>>,
}

/// Forward-pass tape for backprop.
#[derive(Debug, Clone)]
pub struct Tape {
    /// Layer inputs: activations[0] = X, activations[i] = input of layer i.
    pub activations: Vec<Mat>,
    /// Pre-activation values of each layer (for the ReLU mask).
    pub pre_acts: Vec<Mat>,
    /// Network output.
    pub output: Mat,
}

impl Mlp {
    /// He-initialized network.
    pub fn new(dims: &[usize], rng: &mut Pcg64) -> Self {
        let mut weights = Vec::new();
        let mut biases = Vec::new();
        for w in dims.windows(2) {
            let sigma = (2.0 / w[0] as f32).sqrt();
            weights.push(Mat::randn(w[0], w[1], sigma, rng));
            biases.push(vec![0.0; w[1]]);
        }
        let m_w = weights.iter().map(|w| Mat::zeros(w.rows, w.cols)).collect();
        let v_w = weights.iter().map(|w| Mat::zeros(w.rows, w.cols)).collect();
        let m_b = biases.iter().map(|b| vec![0.0; b.len()]).collect();
        let v_b = biases.iter().map(|b| vec![0.0; b.len()]).collect();
        Self { weights, biases, m_w, v_w, m_b, v_b, step: 0 }
    }

    pub fn paper_mlp(rng: &mut Pcg64) -> Self {
        Self::new(&MLP_DIMS, rng)
    }

    pub fn n_layers(&self) -> usize {
        self.weights.len()
    }

    /// Forward pass through an execution backend: each layer is one
    /// quantize→GeMM cut executed by `be` (fake-quant, simulated
    /// hardware, or hook adapter — see [`crate::backend`]).
    pub fn forward_exec(&self, x: &Mat, be: &mut dyn ExecBackend) -> Tape {
        let n = self.n_layers();
        let mut activations = Vec::with_capacity(n);
        let mut pre_acts: Vec<Mat> = Vec::with_capacity(n);
        let mut a = x.clone();
        for i in 0..n {
            let (aq, mut z) = be.forward_layer(i, &a, &self.weights[i]);
            z.add_bias_in_place(&self.biases[i]);
            if i + 1 < n {
                a = z.map(|v| v.max(0.0));
            }
            activations.push(aq);
            pre_acts.push(z);
        }
        let output = pre_acts.last().cloned().unwrap_or_else(|| x.clone());
        Tape { output, activations, pre_acts }
    }

    /// Forward pass through possibly-transformed weights/activations.
    ///
    /// `w_hook(i, W)` returns the weight used by layer i (e.g. its MX
    /// fake-quantization); `a_hook(i, A)` transforms the layer input.
    /// Identity hooks give the plain f32 forward. Implemented as a hook
    /// adapter over [`Mlp::forward_exec`], so every forward — hooked or
    /// backend-driven — runs the same GeMM kernels.
    pub fn forward_with(
        &self,
        x: &Mat,
        w_hook: impl FnMut(usize, &Mat) -> Mat,
        a_hook: impl FnMut(usize, &Mat) -> Mat,
    ) -> Tape {
        let mut be = HookBackend::new(w_hook, a_hook, |_, e: &Mat| e.clone());
        self.forward_exec(x, &mut be)
    }

    /// Plain forward (identity hooks).
    pub fn forward(&self, x: &Mat) -> Tape {
        self.forward_with(x, |_, w| w.clone(), |_, a| a.clone())
    }

    /// MSE loss (mean over batch and output dims).
    pub fn mse_loss(output: &Mat, target: &Mat) -> f64 {
        output.mse(target)
    }

    /// Backward pass through an execution backend: per layer, `be`
    /// quantizes the error once and runs the weight-gradient GeMM over
    /// the tape's stored quantized activation, plus (above layer 0) the
    /// error-backprop GeMM against the transposed quantized weight.
    pub fn backward_exec(&self, tape: &Tape, target: &Mat, be: &mut dyn ExecBackend) -> MlpGrads {
        let n = self.n_layers();
        let batch = tape.output.rows as f32;
        let scale = 2.0 / (batch * tape.output.cols as f32);
        // dL/d(output)
        let mut err = tape.output.zip(target, |o, t| scale * (o - t));
        let mut d_weights = vec![Mat::zeros(0, 0); n];
        let mut d_biases = vec![Vec::new(); n];
        for i in (0..n).rev() {
            let w = if i > 0 { Some(&self.weights[i]) } else { None };
            let out = be.backward_layer(i, &err, &tape.activations[i], w);
            d_weights[i] = out.d_w;
            d_biases[i] = out.d_b;
            if let Some(back) = out.back {
                // mask by the ReLU derivative of the layer below
                err = back.zip(&tape.pre_acts[i - 1], |e, z| if z > 0.0 { e } else { 0.0 });
            }
        }
        MlpGrads { d_weights, d_biases }
    }

    /// Backward pass from an MSE loss, with transform hooks mirroring
    /// the forward: `w_hook` for the weights used in the error GeMM
    /// (`E @ Wᵀ`), `e_hook(i, E)` for the backprop error fed to layer i's
    /// weight-gradient GeMM (`Aᵀ @ E`). A hook adapter over
    /// [`Mlp::backward_exec`].
    pub fn backward_with(
        &self,
        tape: &Tape,
        target: &Mat,
        w_hook: impl FnMut(usize, &Mat) -> Mat,
        e_hook: impl FnMut(usize, &Mat) -> Mat,
    ) -> MlpGrads {
        let mut be = HookBackend::new(w_hook, |_, a: &Mat| a.clone(), e_hook);
        self.backward_exec(tape, target, &mut be)
    }

    /// Plain backward.
    pub fn backward(&self, tape: &Tape, target: &Mat) -> MlpGrads {
        self.backward_with(tape, target, |_, w| w.clone(), |_, e| e.clone())
    }

    /// Adam update (beta1 0.9, beta2 0.999, eps 1e-8) on f32 masters.
    pub fn adam_step(&mut self, grads: &MlpGrads, lr: f32) {
        const B1: f32 = 0.9;
        const B2: f32 = 0.999;
        const EPS: f32 = 1e-8;
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - B1.powf(t);
        let bc2 = 1.0 - B2.powf(t);
        for i in 0..self.n_layers() {
            for j in 0..self.weights[i].data.len() {
                let g = grads.d_weights[i].data[j];
                let m = &mut self.m_w[i].data[j];
                *m = B1 * *m + (1.0 - B1) * g;
                let v = &mut self.v_w[i].data[j];
                *v = B2 * *v + (1.0 - B2) * g * g;
                self.weights[i].data[j] -= lr * (*m / bc1) / ((*v / bc2).sqrt() + EPS);
            }
            for j in 0..self.biases[i].len() {
                let g = grads.d_biases[i][j];
                let m = &mut self.m_b[i][j];
                *m = B1 * *m + (1.0 - B1) * g;
                let v = &mut self.v_b[i][j];
                *v = B2 * *v + (1.0 - B2) * g * g;
                self.biases[i][j] -= lr * (*m / bc1) / ((*v / bc2).sqrt() + EPS);
            }
        }
    }

    /// Flatten all parameters (for runtime interchange and tests).
    pub fn flat_params(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for (w, b) in self.weights.iter().zip(&self.biases) {
            out.extend_from_slice(&w.data);
            out.extend_from_slice(b);
        }
        out
    }

    /// Load parameters from a flat buffer (inverse of `flat_params`).
    pub fn load_flat_params(&mut self, flat: &[f32]) {
        let mut off = 0;
        for i in 0..self.n_layers() {
            let wn = self.weights[i].data.len();
            self.weights[i].data.copy_from_slice(&flat[off..off + wn]);
            off += wn;
            let bn = self.biases[i].len();
            self.biases[i].copy_from_slice(&flat[off..off + bn]);
            off += bn;
        }
        assert_eq!(off, flat.len());
    }

    /// Flatten the Adam moments (per layer: m_w, v_w, m_b, v_b). With
    /// [`Mlp::flat_params`] and [`Mlp::step`] this is the complete
    /// optimizer state — restoring all three makes further `adam_step`
    /// calls bitwise indistinguishable from never having paused
    /// (the checkpoint-resume contract, `tests/checkpoint.rs`).
    pub fn flat_opt_state(&self) -> Vec<f32> {
        let mut out = Vec::new();
        for i in 0..self.n_layers() {
            out.extend_from_slice(&self.m_w[i].data);
            out.extend_from_slice(&self.v_w[i].data);
            out.extend_from_slice(&self.m_b[i]);
            out.extend_from_slice(&self.v_b[i]);
        }
        out
    }

    /// Load Adam moments from a flat buffer (inverse of
    /// [`Mlp::flat_opt_state`]).
    pub fn load_flat_opt_state(&mut self, flat: &[f32]) {
        let mut off = 0;
        for i in 0..self.n_layers() {
            let wn = self.m_w[i].data.len();
            self.m_w[i].data.copy_from_slice(&flat[off..off + wn]);
            self.v_w[i].data.copy_from_slice(&flat[off + wn..off + 2 * wn]);
            off += 2 * wn;
            let bn = self.m_b[i].len();
            self.m_b[i].copy_from_slice(&flat[off..off + bn]);
            self.v_b[i].copy_from_slice(&flat[off + bn..off + 2 * bn]);
            off += 2 * bn;
        }
        assert_eq!(off, flat.len());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_mlp(rng: &mut Pcg64) -> Mlp {
        Mlp::new(&[4, 8, 8, 2], rng)
    }

    #[test]
    fn hidden_dims_keeps_depth_and_io_widths() {
        assert_eq!(hidden_dims(64), vec![32, 64, 64, 64, 32]);
    }

    #[test]
    fn forward_shapes() {
        let mut rng = Pcg64::new(1);
        let mlp = Mlp::paper_mlp(&mut rng);
        let x = Mat::randn(32, 32, 1.0, &mut rng);
        let tape = mlp.forward(&x);
        assert_eq!((tape.output.rows, tape.output.cols), (32, 32));
        assert_eq!(tape.activations.len(), 4);
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Pcg64::new(2);
        let mut mlp = tiny_mlp(&mut rng);
        let x = Mat::randn(6, 4, 1.0, &mut rng);
        let y = Mat::randn(6, 2, 1.0, &mut rng);
        let tape = mlp.forward(&x);
        let grads = mlp.backward(&tape, &y);
        let eps = 1e-3f32;
        // check a scatter of weight entries in every layer
        for layer in 0..3 {
            for &j in &[0usize, 3, 7] {
                let orig = mlp.weights[layer].data[j];
                mlp.weights[layer].data[j] = orig + eps;
                let lp = Mlp::mse_loss(&mlp.forward(&x).output, &y);
                mlp.weights[layer].data[j] = orig - eps;
                let lm = Mlp::mse_loss(&mlp.forward(&x).output, &y);
                mlp.weights[layer].data[j] = orig;
                let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
                let an = grads.d_weights[layer].data[j];
                assert!(
                    (fd - an).abs() < 2e-3 + 0.05 * an.abs(),
                    "layer {layer} w[{j}]: fd {fd} vs analytic {an}"
                );
            }
        }
    }

    #[test]
    fn bias_gradients_match_finite_differences() {
        let mut rng = Pcg64::new(3);
        let mut mlp = tiny_mlp(&mut rng);
        let x = Mat::randn(5, 4, 1.0, &mut rng);
        let y = Mat::randn(5, 2, 1.0, &mut rng);
        let tape = mlp.forward(&x);
        let grads = mlp.backward(&tape, &y);
        let eps = 1e-3f32;
        for layer in 0..3 {
            let orig = mlp.biases[layer][0];
            mlp.biases[layer][0] = orig + eps;
            let lp = Mlp::mse_loss(&mlp.forward(&x).output, &y);
            mlp.biases[layer][0] = orig - eps;
            let lm = Mlp::mse_loss(&mlp.forward(&x).output, &y);
            mlp.biases[layer][0] = orig;
            let fd = ((lp - lm) / (2.0 * eps as f64)) as f32;
            let an = grads.d_biases[layer][0];
            assert!((fd - an).abs() < 2e-3 + 0.05 * an.abs(), "layer {layer}: {fd} vs {an}");
        }
    }

    #[test]
    fn adam_reduces_loss_on_regression() {
        let mut rng = Pcg64::new(4);
        let mut mlp = tiny_mlp(&mut rng);
        let x = Mat::randn(64, 4, 1.0, &mut rng);
        // learn a smooth target function
        let y = Mat::from_fn(64, 2, |r, c| {
            let v = x.at(r, 0) * 0.5 + x.at(r, (c + 1) % 4).sin();
            v * 0.5
        });
        let l0 = Mlp::mse_loss(&mlp.forward(&x).output, &y);
        for _ in 0..300 {
            let tape = mlp.forward(&x);
            let grads = mlp.backward(&tape, &y);
            mlp.adam_step(&grads, 3e-3);
        }
        let l1 = Mlp::mse_loss(&mlp.forward(&x).output, &y);
        assert!(l1 < l0 * 0.1, "loss {l0} -> {l1}");
    }

    #[test]
    fn flat_params_roundtrip() {
        let mut rng = Pcg64::new(5);
        let mlp = tiny_mlp(&mut rng);
        let flat = mlp.flat_params();
        let mut mlp2 = tiny_mlp(&mut rng); // different init
        mlp2.load_flat_params(&flat);
        assert_eq!(mlp2.flat_params(), flat);
    }

    #[test]
    fn opt_state_roundtrip_restores_adam_trajectory() {
        let mut rng = Pcg64::new(6);
        let mut mlp = tiny_mlp(&mut rng);
        let x = Mat::randn(8, 4, 1.0, &mut rng);
        let y = Mat::randn(8, 2, 1.0, &mut rng);
        for _ in 0..5 {
            let tape = mlp.forward(&x);
            let grads = mlp.backward(&tape, &y);
            mlp.adam_step(&grads, 1e-3);
        }
        // snapshot, run 3 more steps, then rebuild from the snapshot
        let (params, opt, step) = (mlp.flat_params(), mlp.flat_opt_state(), mlp.step);
        let mut cont = mlp.clone();
        let mut restored = tiny_mlp(&mut rng); // different init + zero moments
        restored.load_flat_params(&params);
        restored.load_flat_opt_state(&opt);
        restored.step = step;
        for m in [&mut cont, &mut restored] {
            for _ in 0..3 {
                let tape = m.forward(&x);
                let grads = m.backward(&tape, &y);
                m.adam_step(&grads, 1e-3);
            }
        }
        assert_eq!(cont.flat_params(), restored.flat_params());
        assert_eq!(cont.flat_opt_state(), restored.flat_opt_state());
    }
}
