//! MX-native training checkpoints (`.mxckpt`).
//!
//! A checkpoint carries two things:
//!
//! 1. **The MX weight image** — the session's weights quantized exactly
//!    as the accelerator stores them ([`MxTensor::write_bytes`]: one
//!    scale byte per block + bit-packed element codes). Square-grouped
//!    schemes write **one copy per layer**: the block-permutation
//!    transpose means the same stored tensor serves forward and backward
//!    after restore — the paper's §IV single-copy storage, now on disk.
//!    Vector-grouped schemes must write **two copies** (the `W` and `Wᵀ`
//!    groupings quantize differently), which is exactly the Dacapo-class
//!    footprint penalty the fleet report measures.
//! 2. **The trainer sidecar** — FP32 master weights, Adam moments, the
//!    optimizer step, and the loss curves, stored as raw little-endian
//!    bit patterns. This is what makes resume *bit-exact*: training from
//!    a restored checkpoint is indistinguishable from never having
//!    paused, for every scheme and both execution backends
//!    (`tests/checkpoint.rs` asserts it). Standard mixed-precision
//!    practice: the quantized image is the deployment artifact, the FP32
//!    masters are the training state.
//!
//! The binary format is versioned and fully bounds-checked — corrupt or
//! truncated files come back as `Err`, never a panic.
//!
//! Scope note: the hardware backend's *cost ledger* (cycles, events,
//! energy) is measurement, not training state, and is not part of the
//! checkpoint — a resumed session starts a fresh ledger. Callers that
//! account energy across resumes carry the ledger themselves, as
//! [`crate::fleet::FleetSession::hw_measured_uj`] does.

#![forbid(unsafe_code)]

use crate::backend::BackendKind;
use crate::mx::element::ElementFormat;
use crate::mx::tensor::{Layout, MxTensor};
use crate::store::{FilesystemStore, Storage, StoreError};
use crate::trainer::qat::QuantScheme;
use crate::trainer::session::TrainConfig;
use crate::util::bytes::{ByteReader, ByteWriter};
use crate::util::mat::Mat;
use std::path::Path;

/// File magic ("MXCK") + format version.
///
/// v2 added the precision-segment log (`scheme_log`): the step-indexed
/// history of formats a precision-scheduled session trained under, so
/// resuming mid-schedule restores both the *active* format (which also
/// governs the weight image and `config.scheme`) and the trajectory
/// that led there.
const MAGIC: [u8; 4] = *b"MXCK";
const VERSION: u32 = 2;

/// Serialized training state of one [`crate::trainer::TrainSession`].
#[derive(Debug, Clone)]
pub struct Checkpoint {
    /// Session configuration (`dims` is always `Some` — the concrete
    /// layer widths, so restore never depends on a default).
    pub config: TrainConfig,
    /// Training steps completed when the checkpoint was taken.
    pub step: usize,
    /// Adam step counter (bias-correction epoch) at save time.
    pub adam_step: u64,
    /// (step, train-loss) samples up to `step`.
    pub train_curve: Vec<(usize, f64)>,
    /// (step, val-loss) samples up to `step`.
    pub val_curve: Vec<(usize, f64)>,
    /// FP32 master parameters ([`crate::trainer::Mlp::flat_params`]).
    pub params: Vec<f32>,
    /// Adam moments ([`crate::trainer::Mlp::flat_opt_state`]).
    pub opt: Vec<f32>,
    /// Precision segments `(start_step, scheme name)`, ascending — the
    /// session's format trajectory up to this checkpoint. The last
    /// entry must name `config.scheme` (the active format); resume
    /// rejects an inconsistent log.
    pub scheme_log: Vec<(usize, String)>,
    /// The MX weight image: square schemes one tensor per layer,
    /// vector schemes two (both groupings), FP32/Dacapo none.
    pub payload: Vec<MxTensor>,
}

/// Quantize a weight stack into its on-disk MX image under `scheme`.
pub fn weight_payload(weights: &[Mat], scheme: QuantScheme) -> Vec<MxTensor> {
    match scheme {
        QuantScheme::MxSquare(f) => {
            // single copy: the square-block transpose is a permutation
            weights.iter().map(|w| MxTensor::quantize(w, f, Layout::Square8x8)).collect()
        }
        QuantScheme::MxVector(f) => {
            // two copies: W row-grouped and Wᵀ row-grouped differ
            weights
                .iter()
                .flat_map(|w| {
                    [
                        MxTensor::quantize(w, f, Layout::Vector32),
                        MxTensor::quantize(&w.transpose(), f, Layout::Vector32),
                    ]
                })
                .collect()
        }
        QuantScheme::Fp32 | QuantScheme::Dacapo(_) => Vec::new(),
    }
}

/// On-disk bytes of an MX weight image (scale bytes + packed element
/// payloads, per [`MxTensor::write_bytes`]).
pub fn image_bytes(payload: &[MxTensor]) -> usize {
    payload.iter().map(|t| t.storage_bits().div_ceil(8)).sum()
}

/// On-disk bytes of the MX weight image for a weight stack under both
/// groupings: `(square single-copy, vector two-copy)` — the §IV storage
/// comparison the fleet report surfaces. Derived from [`weight_payload`]
/// so these numbers can never diverge from what a checkpoint writes.
pub fn grouping_footprint(weights: &[Mat], fmt: ElementFormat) -> (usize, usize) {
    let square = image_bytes(&weight_payload(weights, QuantScheme::MxSquare(fmt)));
    let vector = image_bytes(&weight_payload(weights, QuantScheme::MxVector(fmt)));
    (square, vector)
}

/// Parameter count implied by MLP layer dims (weights + biases).
/// `pub(crate)`: the chunked store (`store::chunk`) applies the same
/// plausibility check when reassembling from chunks.
pub(crate) fn expected_params(dims: &[usize]) -> Option<usize> {
    let mut total = 0usize;
    for w in dims.windows(2) {
        total = total.checked_add(w[0].checked_mul(w[1])?.checked_add(w[1])?)?;
    }
    Some(total)
}

pub(crate) fn write_curve(w: &mut ByteWriter, curve: &[(usize, f64)]) {
    w.put_u64(curve.len() as u64);
    for &(step, loss) in curve {
        w.put_u64(step as u64);
        w.put_f64(loss);
    }
}

pub(crate) fn read_curve(r: &mut ByteReader<'_>) -> Result<Vec<(usize, f64)>, String> {
    let n = r.get_u64()? as usize;
    if n > r.remaining() / 16 {
        return Err(format!("curve length {n} exceeds remaining bytes"));
    }
    let mut curve = Vec::with_capacity(n);
    for _ in 0..n {
        let step = r.get_u64()? as usize;
        curve.push((step, r.get_f64()?));
    }
    Ok(curve)
}

impl Checkpoint {
    /// Layer dims of the checkpointed MLP. `save_checkpoint` always
    /// stores concrete dims; a hand-built checkpoint with `dims: None`
    /// serializes an empty dims list, which `from_bytes` rejects.
    pub fn dims(&self) -> &[usize] {
        self.config.dims.as_deref().unwrap_or(&[])
    }

    /// Bytes of the MX weight image alone (scale bytes + packed element
    /// payloads) — the footprint a deployed accelerator would store.
    pub fn payload_bytes(&self) -> usize {
        image_bytes(&self.payload)
    }

    /// Serialize to the versioned binary format.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        w.put_u8(MAGIC[0]);
        w.put_u8(MAGIC[1]);
        w.put_u8(MAGIC[2]);
        w.put_u8(MAGIC[3]);
        w.put_u32(VERSION);
        w.put_str(&self.config.scheme.name());
        w.put_str(self.config.backend.name());
        let dims = self.dims();
        w.put_u32(dims.len() as u32);
        for &d in dims {
            w.put_u32(d as u32);
        }
        w.put_u32(self.config.batch_size as u32);
        w.put_f32(self.config.lr);
        w.put_u64(self.config.eval_every as u64);
        w.put_u64(self.config.steps as u64);
        w.put_u64(self.config.seed);
        w.put_u64(self.step as u64);
        w.put_u64(self.adam_step);
        write_curve(&mut w, &self.train_curve);
        write_curve(&mut w, &self.val_curve);
        w.put_f32s(&self.params);
        w.put_f32s(&self.opt);
        w.put_u32(self.scheme_log.len() as u32);
        for (at, name) in &self.scheme_log {
            w.put_u64(*at as u64);
            w.put_str(name);
        }
        w.put_u32(self.payload.len() as u32);
        for t in &self.payload {
            t.write_bytes(&mut w);
        }
        w.into_bytes()
    }

    /// Parse and validate the binary format.
    pub fn from_bytes(bytes: &[u8]) -> Result<Checkpoint, String> {
        let mut r = ByteReader::new(bytes);
        let magic = [r.get_u8()?, r.get_u8()?, r.get_u8()?, r.get_u8()?];
        if magic != MAGIC {
            return Err("not an mxscale checkpoint (bad magic)".into());
        }
        let version = r.get_u32()?;
        if !(1..=VERSION).contains(&version) {
            return Err(format!("unsupported checkpoint version {version} (expected <= {VERSION})"));
        }
        let scheme_name = r.get_str()?;
        let scheme = QuantScheme::parse(&scheme_name)
            .ok_or_else(|| format!("checkpoint names unknown scheme `{scheme_name}`"))?;
        let backend_name = r.get_str()?;
        let backend = BackendKind::parse(&backend_name)
            .ok_or_else(|| format!("checkpoint names unknown backend `{backend_name}`"))?;
        let ndims = r.get_u32()? as usize;
        if !(2..=64).contains(&ndims) {
            return Err(format!("implausible layer count {ndims}"));
        }
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            let d = r.get_u32()? as usize;
            if d == 0 || d > (1 << 20) {
                return Err(format!("implausible layer width {d}"));
            }
            dims.push(d);
        }
        let batch_size = r.get_u32()? as usize;
        let lr = r.get_f32()?;
        let eval_every = r.get_u64()? as usize;
        let steps = r.get_u64()? as usize;
        let seed = r.get_u64()?;
        let step = r.get_u64()? as usize;
        let adam_step = r.get_u64()?;
        let train_curve = read_curve(&mut r)?;
        let val_curve = read_curve(&mut r)?;
        let params = r.get_f32s()?;
        let opt = r.get_f32s()?;
        let expected = expected_params(&dims).ok_or("parameter count overflow")?;
        if params.len() != expected {
            return Err(format!(
                "parameter section holds {} values, dims {:?} imply {}",
                params.len(),
                dims,
                expected
            ));
        }
        if opt.len() != 2 * expected {
            return Err(format!(
                "optimizer section holds {} values, expected {}",
                opt.len(),
                2 * expected
            ));
        }
        let scheme_log = if version >= 2 {
            let n_segments = r.get_u32()? as usize;
            if n_segments > 65536 {
                return Err(format!("implausible precision-segment count {n_segments}"));
            }
            let mut log = Vec::with_capacity(n_segments);
            for _ in 0..n_segments {
                let at = r.get_u64()? as usize;
                let name = r.get_str()?;
                if QuantScheme::parse(&name).is_none() {
                    return Err(format!("scheme log names unknown scheme `{name}`"));
                }
                log.push((at, name));
            }
            log
        } else {
            // v1 predates precision scheduling: the session ran one
            // scheme for its whole life — exactly what save_checkpoint
            // writes for a never-transitioned session today
            vec![(0, scheme_name.clone())]
        };
        let n_tensors = r.get_u32()? as usize;
        if n_tensors > 4096 {
            return Err(format!("implausible payload tensor count {n_tensors}"));
        }
        let mut payload = Vec::with_capacity(n_tensors);
        for _ in 0..n_tensors {
            payload.push(MxTensor::read_bytes(&mut r)?);
        }
        if r.remaining() != 0 {
            return Err(format!("{} trailing bytes after checkpoint", r.remaining()));
        }
        let config = TrainConfig {
            scheme,
            backend,
            dims: Some(dims),
            batch_size,
            lr,
            steps,
            eval_every,
            seed,
        };
        Ok(Checkpoint {
            config,
            step,
            adam_step,
            train_curve,
            val_curve,
            params,
            opt,
            scheme_log,
            payload,
        })
    }

    /// Split `path` into a store root (parent dir) and an object key
    /// (file name), so single-file checkpoints go through the same
    /// [`crate::store::Storage`] seam as everything else.
    fn path_store(path: &Path) -> Result<(FilesystemStore, String), StoreError> {
        let parent = match path.parent() {
            Some(p) if !p.as_os_str().is_empty() => p,
            _ => Path::new("."),
        };
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        Ok((FilesystemStore::open(parent)?, name))
    }

    /// Write the checkpoint to `path`, creating parent directories.
    /// This is the legacy monolithic spelling — one `.mxckpt` object
    /// through the store's `FilesystemStore`; `store::CheckpointStore`
    /// is the chunked/sharded face of the same seam.
    pub fn save(&self, path: &Path) -> std::io::Result<()> {
        let io_err = |e: StoreError| std::io::Error::new(std::io::ErrorKind::Other, e.to_string());
        let (store, name) = Self::path_store(path).map_err(io_err)?;
        store.put(&name, &self.to_bytes()).map_err(io_err)
    }

    /// Read a checkpoint back from `path`.
    pub fn load(path: &Path) -> Result<Checkpoint, String> {
        let (store, name) = Self::path_store(path).map_err(|e| e.to_string())?;
        let bytes = store.get(&name).map_err(|e| format!("read {}: {e}", path.display()))?;
        Self::from_bytes(&bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::ALL_ELEMENT_FORMATS;
    use crate::util::rng::Pcg64;

    fn weight_stack(rng: &mut Pcg64) -> Vec<Mat> {
        vec![Mat::randn(32, 48, 1.0, rng), Mat::randn(48, 32, 0.5, rng)]
    }

    #[test]
    fn square_payload_is_single_copy_vector_is_double() {
        let mut rng = Pcg64::new(1);
        let ws = weight_stack(&mut rng);
        let sq = weight_payload(&ws, QuantScheme::MxSquare(ElementFormat::Int8));
        let vec = weight_payload(&ws, QuantScheme::MxVector(ElementFormat::Int8));
        assert_eq!(sq.len(), ws.len());
        assert_eq!(vec.len(), 2 * ws.len());
        assert!(weight_payload(&ws, QuantScheme::Fp32).is_empty());
    }

    #[test]
    fn grouping_footprint_reproduces_the_51pct_headline() {
        let mut rng = Pcg64::new(2);
        let ws = vec![Mat::randn(256, 256, 1.0, &mut rng)];
        for fmt in ALL_ELEMENT_FORMATS {
            let (square, vector) = grouping_footprint(&ws, fmt);
            let reduction = 1.0 - square as f64 / vector as f64;
            // single square copy ~halves the two-copy vector footprint
            assert!(
                (0.45..0.55).contains(&reduction),
                "{fmt:?}: square {square} vector {vector} -> reduction {reduction}"
            );
        }
    }

    #[test]
    fn v1_checkpoints_without_a_scheme_log_still_parse() {
        // a pre-scheduling (v1) file has no scheme_log section; it must
        // load with a synthesized single-segment log (the session ran
        // one scheme for its whole life) instead of being rejected
        let mut rng = Pcg64::new(9);
        let dims = vec![32usize, 16, 32];
        let mlp = crate::trainer::mlp::Mlp::new(&dims, &mut rng);
        let scheme = QuantScheme::MxSquare(ElementFormat::E4M3);
        let mut w = ByteWriter::new();
        for b in MAGIC {
            w.put_u8(b);
        }
        w.put_u32(1); // version 1
        w.put_str("mx-e4m3");
        w.put_str("fast");
        w.put_u32(dims.len() as u32);
        for &d in &dims {
            w.put_u32(d as u32);
        }
        w.put_u32(32); // batch_size
        w.put_f32(1e-3); // lr
        w.put_u64(20); // eval_every
        w.put_u64(0); // steps
        w.put_u64(0xC0FFEE); // seed
        w.put_u64(3); // step
        w.put_u64(3); // adam_step
        write_curve(&mut w, &[]);
        write_curve(&mut w, &[]);
        w.put_f32s(&mlp.flat_params());
        w.put_f32s(&mlp.flat_opt_state());
        let payload = weight_payload(&mlp.weights, scheme);
        w.put_u32(payload.len() as u32);
        for t in &payload {
            t.write_bytes(&mut w);
        }
        let ck = Checkpoint::from_bytes(&w.into_bytes()).unwrap();
        assert_eq!(ck.config.scheme, scheme);
        assert_eq!(ck.scheme_log, vec![(0, "mx-e4m3".to_string())]);
        assert_eq!(ck.step, 3);
        // and it reserializes forward as v2
        assert!(Checkpoint::from_bytes(&ck.to_bytes()).is_ok());
    }

    #[test]
    fn expected_params_matches_mlp() {
        let mut rng = Pcg64::new(3);
        let dims = [32usize, 24, 16, 32];
        let mlp = crate::trainer::mlp::Mlp::new(&dims, &mut rng);
        assert_eq!(expected_params(&dims), Some(mlp.flat_params().len()));
    }
}
