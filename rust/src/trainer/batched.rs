//! Batched training: run independent QAT sessions on all cores.
//!
//! The experiment harnesses sweep formats x schemes x workloads
//! (`coordinator::experiments::fig2`, the precision-sweep example,
//! Fig. 8's budget grid), and every run in such a sweep is completely
//! independent: its own `TrainSession`, its own deterministic RNG
//! streams, its own dataset clone. [`BatchedTrainer`] fans those runs
//! out over the parallel engine (`util::par`) and returns them in
//! submission order.
//!
//! Determinism: each session is seeded by its `TrainConfig` alone, and
//! the block-level parallel kernels it uses internally are bit-identical
//! to their serial forms, so a batched sweep produces exactly the same
//! losses and curves as running the sessions one after another
//! (asserted by the tests below and `tests/parallel.rs`). Workers never
//! nest-fork — inside a batched run the per-matrix parallelism degrades
//! to serial automatically, so the sweep scales by run count without
//! oversubscription.
//!
//! Backends compose transparently: `TrainConfig::backend` selects the
//! per-session [`crate::backend::ExecBackend`], so a sweep can mix
//! fast fake-quant runs with hardware-accounted runs — each session
//! owns its backend (and cost ledger), and the equivalence contract
//! guarantees the losses don't depend on the choice.

#![forbid(unsafe_code)]

use crate::trainer::qat::QuantScheme;
use crate::trainer::session::{TrainConfig, TrainSession};
use crate::util::par;
use crate::workloads::Dataset;

/// One unit of batched work: a labelled training run.
#[derive(Debug, Clone)]
pub struct TrainJob {
    pub label: String,
    pub dataset: Dataset,
    pub config: TrainConfig,
}

/// A finished run, label preserved.
pub struct TrainOutcome {
    pub label: String,
    pub session: TrainSession,
}

/// Collects independent training runs and executes them concurrently.
#[derive(Debug, Default)]
pub struct BatchedTrainer {
    jobs: Vec<TrainJob>,
}

impl BatchedTrainer {
    pub fn new() -> Self {
        Self { jobs: Vec::new() }
    }

    /// Queue one run.
    pub fn push(&mut self, label: impl Into<String>, dataset: Dataset, config: TrainConfig) {
        self.jobs.push(TrainJob { label: label.into(), dataset, config });
    }

    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }

    /// Run every queued job to its configured step budget, one worker
    /// per core, returning outcomes in submission order.
    pub fn run(self) -> Vec<TrainOutcome> {
        let jobs = self.jobs;
        par::par_map(jobs.len(), 1, |i| {
            let job = jobs[i].clone();
            let mut session = TrainSession::new(job.dataset, job.config);
            session.run();
            TrainOutcome { label: job.label, session }
        })
    }
}

/// Sweep convenience: train `schemes` over one dataset concurrently
/// (the Fig. 2 / precision-sweep shape). `base` supplies everything but
/// the scheme; outcomes come back in `schemes` order, labelled by
/// `QuantScheme::name`.
pub fn sweep_schemes(
    dataset: &Dataset,
    schemes: &[QuantScheme],
    base: &TrainConfig,
) -> Vec<TrainOutcome> {
    let mut batch = BatchedTrainer::new();
    for scheme in schemes {
        batch.push(
            scheme.name(),
            dataset.clone(),
            TrainConfig { scheme: *scheme, ..base.clone() },
        );
    }
    batch.run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mx::element::ElementFormat;
    use crate::workloads::by_name;

    fn quick_dataset() -> Dataset {
        let env = by_name("cartpole").unwrap();
        Dataset::collect(env.as_ref(), 4, 40, 0xBA7C)
    }

    #[test]
    fn batched_matches_sequential_exactly() {
        let ds = quick_dataset();
        let schemes = [
            QuantScheme::Fp32,
            QuantScheme::MxSquare(ElementFormat::Int8),
            QuantScheme::MxSquare(ElementFormat::E4M3),
        ];
        let cfg = TrainConfig { steps: 40, eval_every: 10, ..Default::default() };
        // sequential reference
        let serial: Vec<f64> = schemes
            .iter()
            .map(|&scheme| {
                let mut s =
                    TrainSession::new(ds.clone(), TrainConfig { scheme, ..cfg.clone() });
                s.run();
                s.val_loss()
            })
            .collect();
        // batched
        let outcomes = sweep_schemes(&ds, &schemes, &cfg);
        assert_eq!(outcomes.len(), schemes.len());
        for ((scheme, want), got) in schemes.iter().zip(&serial).zip(&outcomes) {
            assert_eq!(got.label, scheme.name());
            assert_eq!(
                got.session.val_loss(),
                *want,
                "{}: batched run must be bit-identical to sequential",
                scheme.name()
            );
        }
    }

    #[test]
    fn outcomes_preserve_submission_order() {
        let ds = quick_dataset();
        let mut batch = BatchedTrainer::new();
        for (i, steps) in [30usize, 5, 20, 10].into_iter().enumerate() {
            batch.push(
                format!("job{i}"),
                ds.clone(),
                TrainConfig { steps, eval_every: usize::MAX, ..Default::default() },
            );
        }
        assert_eq!(batch.len(), 4);
        let out = batch.run();
        let labels: Vec<&str> = out.iter().map(|o| o.label.as_str()).collect();
        assert_eq!(labels, vec!["job0", "job1", "job2", "job3"]);
        assert_eq!(out[1].session.step_count(), 5);
        assert_eq!(out[2].session.step_count(), 20);
    }

    #[test]
    fn empty_batch_is_fine() {
        assert!(BatchedTrainer::new().run().is_empty());
    }
}
