//! `mxlint` — the repo's invariant checker (DESIGN.md §9).
//!
//! Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::PathBuf;
use std::process::ExitCode;

use mxscale::lint;

const USAGE: &str = "\
mxlint: static-analysis gate for the mxscale bit-identity contracts

USAGE:
    mxlint [--root PATH] [--config PATH] [--manifest PATH]
           [--json] [--diff REV] [--update-manifest]

OPTIONS:
    --root PATH        repo root (default: ascend from cwd to rust/src/lib.rs)
    --config PATH      allowlist config (default: <root>/rust/lint.toml)
    --manifest PATH    byte-layout manifest (default: <root>/rust/lint.manifest)
    --json             emit the machine-readable report on stdout
    --diff REV         only report findings on lines changed since REV
    --update-manifest  rewrite the manifest from current sources and exit
    -h, --help         show this help
";

struct Args {
    root: Option<PathBuf>,
    config: Option<PathBuf>,
    manifest: Option<PathBuf>,
    json: bool,
    diff: Option<String>,
    update_manifest: bool,
}

fn parse_args() -> Result<Args, String> {
    let mut args = Args {
        root: None,
        config: None,
        manifest: None,
        json: false,
        diff: None,
        update_manifest: false,
    };
    let mut it = std::env::args().skip(1);
    while let Some(a) = it.next() {
        match a.as_str() {
            "--root" => args.root = Some(it.next().ok_or("--root needs a value")?.into()),
            "--config" => args.config = Some(it.next().ok_or("--config needs a value")?.into()),
            "--manifest" => {
                args.manifest = Some(it.next().ok_or("--manifest needs a value")?.into())
            }
            "--json" => args.json = true,
            "--diff" => args.diff = Some(it.next().ok_or("--diff needs a revision")?),
            "--update-manifest" => args.update_manifest = true,
            "-h" | "--help" => {
                print!("{USAGE}");
                std::process::exit(0);
            }
            other => return Err(format!("unknown argument `{other}`")),
        }
    }
    Ok(args)
}

/// Ascend from the current directory until `rust/src/lib.rs` exists.
fn find_root() -> Result<PathBuf, String> {
    let mut dir = std::env::current_dir().map_err(|e| format!("cwd: {e}"))?;
    loop {
        if dir.join("rust/src/lib.rs").is_file() {
            return Ok(dir);
        }
        if !dir.pop() {
            return Err("could not find repo root (no rust/src/lib.rs above cwd); \
                        pass --root"
                .into());
        }
    }
}

fn run() -> Result<bool, String> {
    let args = parse_args()?;
    let root = match args.root {
        Some(r) => r,
        None => find_root()?,
    };
    let (src, tests) =
        lint::collect_sources(&root).map_err(|e| format!("reading sources: {e}"))?;

    let manifest_path = args.manifest.unwrap_or_else(|| root.join("rust/lint.manifest"));
    if args.update_manifest {
        let m = lint::current_manifest(&src);
        std::fs::write(&manifest_path, lint::render_manifest(&m))
            .map_err(|e| format!("writing {}: {e}", manifest_path.display()))?;
        eprintln!(
            "mxlint: wrote {} ({} entries, version {})",
            manifest_path.display(),
            m.entries.len(),
            m.version
        );
        return Ok(true);
    }

    let config_path = args.config.unwrap_or_else(|| root.join("rust/lint.toml"));
    let cfg_text = std::fs::read_to_string(&config_path)
        .map_err(|e| format!("reading {}: {e}", config_path.display()))?;
    let cfg = lint::parse_config(&cfg_text)
        .map_err(|e| format!("{}: {e}", config_path.display()))?;
    let manifest_text = std::fs::read_to_string(&manifest_path)
        .map_err(|e| format!("reading {}: {e}", manifest_path.display()))?;
    let manifest = lint::parse_manifest(&manifest_text)
        .map_err(|e| format!("{}: {e}", manifest_path.display()))?;

    let mut findings = lint::lint(&src, &tests, &cfg, &manifest);
    if let Some(rev) = &args.diff {
        let changed = lint::changed_lines(&root, rev)?;
        findings = lint::filter_to_changed(findings, &changed);
    }

    if args.json {
        println!("{}", lint::render_json(&findings));
    } else {
        for f in &findings {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
        if findings.is_empty() {
            eprintln!("mxlint: clean ({} source files)", src.len());
        } else {
            eprintln!("mxlint: {} finding(s)", findings.len());
        }
    }
    Ok(findings.is_empty())
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("mxlint: error: {e}");
            ExitCode::from(2)
        }
    }
}
