//! PCG64 pseudo-random number generator.
//!
//! Deterministic, seedable, and identical across platforms — every
//! experiment in the repo derives its streams from explicit seeds so the
//! tables/figures regenerate bit-identically. Implements the PCG XSL-RR
//! 128/64 variant (O'Neill 2014), the same generator `rand_pcg::Pcg64`
//! uses, without depending on the `rand` ecosystem (unavailable offline).

#![forbid(unsafe_code)]

/// PCG XSL-RR 128/64 generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a 64-bit seed (stream fixed).
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xa02b_df95_3769_fde2)
    }

    /// Create a generator from a seed and an explicit stream id, so
    /// experiments can split independent substreams deterministically.
    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let inc = ((stream as u128) << 1) | 1;
        let mut rng = Self { state: 0, inc };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(inc);
        rng
    }

    /// Derive an independent child generator (for parallel substreams).
    pub fn split(&mut self, tag: u64) -> Pcg64 {
        let s = self.next_u64();
        Pcg64::with_stream(s ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15), tag)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Next 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform in [lo, hi) as f32.
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform integer in [lo, hi].
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box-Muller (caches nothing; fine for our use).
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Standard normal as f32.
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with N(0, sigma) samples.
    pub fn fill_normal(&mut self, buf: &mut [f32], sigma: f32) {
        for v in buf.iter_mut() {
            *v = self.normal_f32() * sigma;
        }
    }

    /// Fill a slice with U[lo, hi) samples.
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf.iter_mut() {
            *v = self.range_f32(lo, hi);
        }
    }

    /// Random sign-extended `bits`-bit integer (for format fuzzing).
    pub fn bits(&mut self, bits: u32) -> u64 {
        debug_assert!(bits >= 1 && bits <= 64);
        if bits == 64 {
            self.next_u64()
        } else {
            self.next_u64() & ((1u64 << bits) - 1)
        }
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random f32 whose magnitude spans many binades — exercises
    /// shared-exponent extraction far better than uniform samples.
    pub fn wide_f32(&mut self) -> f32 {
        let exp = self.int_range(-40, 40) as i32;
        let mant = self.range_f32(-1.0, 1.0);
        mant * (exp as f32).exp2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_is_bounded_and_covers() {
        let mut r = Pcg64::new(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Pcg64::new(11);
        let n = 100_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg64::new(3);
        let mut a = root.split(1);
        let mut b = root.split(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut r = Pcg64::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }
}
